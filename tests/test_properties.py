"""Hypothesis property tests on system invariants."""
import math

import pytest

pytest.importorskip("hypothesis")

import hypothesis
import hypothesis.strategies as st
import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings

from repro.core.apps.hpl import numroc
from repro.core.engine import Engine
from repro.core.simblas import SimBLAS
from repro.core.hardware.node import local_node
from repro.core.simxla import ring_allreduce_time, ring_allgather_time
from repro.kernels.maxmin_fair.ref import waterfill_ref

SETTINGS = settings(max_examples=30, deadline=None)


@SETTINGS
@given(n=st.integers(1, 100000), nb=st.integers(1, 512),
       p=st.integers(1, 64))
def test_numroc_partition_property(n, nb, p):
    assert sum(numroc(n, nb, i, p) for i in range(p)) == n


@SETTINGS
@given(st.lists(st.floats(1e-6, 10.0), min_size=1, max_size=20))
def test_engine_time_monotone(waits):
    eng = Engine()
    seen = []

    def proc():
        for w in waits:
            yield w
            seen.append(eng.now)
    eng.spawn(proc())
    eng.run_all()
    assert seen == sorted(seen)
    assert abs(seen[-1] - sum(waits)) < 1e-9 * max(1.0, sum(waits))


@SETTINGS
@given(m=st.integers(1, 4096), n=st.integers(1, 4096),
       k=st.integers(1, 4096))
def test_simblas_monotone_and_positive(m, n, k):
    blas = SimBLAS(local_node())
    t = blas.dgemm(m, n, k)
    assert t > 0
    assert blas.dgemm(m + 64, n, k) >= t


@SETTINGS
@given(nbytes=st.floats(1.0, 1e9), n=st.integers(2, 64))
def test_collective_time_positive_and_scales(nbytes, n):
    t = ring_allreduce_time(nbytes, n)
    assert t > 0
    assert ring_allreduce_time(2 * nbytes, n) > t
    assert ring_allgather_time(nbytes, n) < t + 1e-12 or n == 2


@settings(max_examples=15, deadline=None)
@given(data=st.data())
def test_waterfill_maxmin_properties(data):
    F = data.draw(st.integers(2, 24))
    L = data.draw(st.integers(2, 24))
    rng = np.random.default_rng(data.draw(st.integers(0, 2 ** 16)))
    adj = (rng.random((F, L)) < 0.3).astype(np.int8)
    caps = rng.random(L).astype(np.float32) * 1e9 + 1e7
    rates = np.asarray(waterfill_ref(jnp.asarray(adj), jnp.asarray(caps)))
    finite = np.minimum(rates.astype(np.float64), 1e30)
    usage = adj.T.astype(np.float64) @ np.where(adj.sum(1)[:, None] > 0,
                                                finite[:, None], 0)[:, 0]
    # conservation
    assert (usage <= caps * (1 + 1e-3) + 1).all()
    # max-min: every flow with links has a saturated bottleneck link where
    # it is among the max-rate flows
    for f in range(F):
        links = np.nonzero(adj[f])[0]
        if len(links) == 0:
            continue
        ok = False
        for l in links:
            flows_l = np.nonzero(adj[:, l])[0]
            if (usage[l] >= caps[l] * (1 - 1e-2)
                    and finite[f] >= finite[flows_l].max() * (1 - 1e-3)):
                ok = True
                break
        assert ok, (f, rates[f])


_FINITE = dict(allow_nan=False, allow_infinity=False)


@st.composite
def top500_rows(draw):
    """Arbitrary-ish list rows: unicode site/system names, any of the
    known processor/interconnect vocabularies plus unknown strings,
    optional fields missing (zero/empty)."""
    from repro.top500 import Top500Row
    procs = ["Intel Xeon Platinum 8280 28C 2.7GHz",
             "Fujitsu A64FX 48C 2.2GHz", "Power BQC 16C 1.60GHz",
             "Sunway SW26010 260C 1.45GHz", "Mystery Chip 9000",
             "AMD EPYC 7742 64C 2.25GHz", "IBM POWER9 22C 3.07GHz"]
    nets = ["Mellanox InfiniBand HDR", "Aries interconnect",
            "Tofu interconnect D", "Custom 5D Torus", "25G Ethernet",
            "Intel Omni-Path", "Slingshot-10", "something bespoke"]
    cores = draw(st.integers(64, 10_000_000))
    rpeak = draw(st.floats(1.0, 1e6, **_FINITE))
    return Top500Row(
        rank=draw(st.integers(1, 500)),
        site=draw(st.text(max_size=40)),
        system=draw(st.text(max_size=40)),
        processor=draw(st.sampled_from(procs)),
        cores=cores,
        interconnect=draw(st.sampled_from(nets)),
        rmax_tflops=rpeak * draw(st.floats(0.05, 1.0, **_FINITE)),
        rpeak_tflops=rpeak,
        accel_cores=draw(st.sampled_from([0, 0, cores // 2])),
        accelerator=draw(st.sampled_from(["", "NVIDIA Tesla V100"])),
        country=draw(st.text(max_size=20)),
        year=draw(st.sampled_from([0, 2016, 2020])),
        power_kw=draw(st.floats(0, 1e5, **_FINITE)))


@settings(max_examples=40, deadline=None)
@given(row=top500_rows())
def test_inferred_platform_json_round_trip(row):
    """Satellite invariant: Platform JSON serialization survives any
    inferred spec — unicode site/system names in name/notes/provenance,
    missing optional fields, every fabric kind the tables emit."""
    from repro.platforms import Platform
    from repro.top500 import infer_platform
    plat = infer_platform(row)
    assert Platform.from_dict(plat.to_dict()) == plat
    back = Platform.from_json(plat.to_json())
    assert back == plat
    assert back.provenance == plat.provenance
    # the round-tripped spec still builds fastsim params
    assert back.fastsim().peak_flops > 0


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 2 ** 16))
def test_model_causality(seed):
    """Changing future tokens must not change logits at earlier positions."""
    import dataclasses
    from repro.configs import get_config, reduced
    from repro.models import build_model
    cfg = dataclasses.replace(reduced(get_config("qwen2-0.5b")),
                              dtype="float32", num_layers=2)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(seed)
    toks = rng.integers(0, cfg.vocab_size, (1, 24)).astype(np.int32)
    t = 11
    toks2 = toks.copy()
    toks2[:, t + 1:] = rng.integers(0, cfg.vocab_size,
                                    toks2[:, t + 1:].shape)
    fwd = jax.jit(model.forward)
    l1, _ = fwd(params, {"tokens": jnp.asarray(toks)})
    l2, _ = fwd(params, {"tokens": jnp.asarray(toks2)})
    np.testing.assert_allclose(np.asarray(l1[:, :t + 1]),
                               np.asarray(l2[:, :t + 1]), atol=1e-5)


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 2 ** 16))
def test_ssm_causality(seed):
    import dataclasses
    from repro.configs import get_config, reduced
    from repro.models import build_model
    cfg = dataclasses.replace(reduced(get_config("mamba2-780m")),
                              dtype="float32", num_layers=2)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(seed)
    toks = rng.integers(0, cfg.vocab_size, (1, 40)).astype(np.int32)
    t = 17
    toks2 = toks.copy()
    toks2[:, t + 1:] = rng.integers(0, cfg.vocab_size,
                                    toks2[:, t + 1:].shape)
    fwd = jax.jit(model.forward)
    l1, _ = fwd(params, {"tokens": jnp.asarray(toks)})
    l2, _ = fwd(params, {"tokens": jnp.asarray(toks2)})
    np.testing.assert_allclose(np.asarray(l1[:, :t + 1]),
                               np.asarray(l2[:, :t + 1]), atol=1e-4)
