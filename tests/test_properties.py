"""Hypothesis property tests on system invariants."""
import math

import pytest

pytest.importorskip("hypothesis")

import hypothesis
import hypothesis.strategies as st
import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings

from repro.core.apps.hpl import numroc
from repro.core.engine import Engine
from repro.core.simblas import SimBLAS
from repro.core.hardware.node import local_node
from repro.core.simxla import ring_allreduce_time, ring_allgather_time
from repro.kernels.maxmin_fair.ref import waterfill_ref

SETTINGS = settings(max_examples=30, deadline=None)


@SETTINGS
@given(n=st.integers(1, 100000), nb=st.integers(1, 512),
       p=st.integers(1, 64))
def test_numroc_partition_property(n, nb, p):
    assert sum(numroc(n, nb, i, p) for i in range(p)) == n


@SETTINGS
@given(st.lists(st.floats(1e-6, 10.0), min_size=1, max_size=20))
def test_engine_time_monotone(waits):
    eng = Engine()
    seen = []

    def proc():
        for w in waits:
            yield w
            seen.append(eng.now)
    eng.spawn(proc())
    eng.run_all()
    assert seen == sorted(seen)
    assert abs(seen[-1] - sum(waits)) < 1e-9 * max(1.0, sum(waits))


@SETTINGS
@given(m=st.integers(1, 4096), n=st.integers(1, 4096),
       k=st.integers(1, 4096))
def test_simblas_monotone_and_positive(m, n, k):
    blas = SimBLAS(local_node())
    t = blas.dgemm(m, n, k)
    assert t > 0
    assert blas.dgemm(m + 64, n, k) >= t


@SETTINGS
@given(nbytes=st.floats(1.0, 1e9), n=st.integers(2, 64))
def test_collective_time_positive_and_scales(nbytes, n):
    t = ring_allreduce_time(nbytes, n)
    assert t > 0
    assert ring_allreduce_time(2 * nbytes, n) > t
    assert ring_allgather_time(nbytes, n) < t + 1e-12 or n == 2


@settings(max_examples=15, deadline=None)
@given(data=st.data())
def test_waterfill_maxmin_properties(data):
    F = data.draw(st.integers(2, 24))
    L = data.draw(st.integers(2, 24))
    rng = np.random.default_rng(data.draw(st.integers(0, 2 ** 16)))
    adj = (rng.random((F, L)) < 0.3).astype(np.int8)
    caps = rng.random(L).astype(np.float32) * 1e9 + 1e7
    rates = np.asarray(waterfill_ref(jnp.asarray(adj), jnp.asarray(caps)))
    finite = np.minimum(rates.astype(np.float64), 1e30)
    usage = adj.T.astype(np.float64) @ np.where(adj.sum(1)[:, None] > 0,
                                                finite[:, None], 0)[:, 0]
    # conservation
    assert (usage <= caps * (1 + 1e-3) + 1).all()
    # max-min: every flow with links has a saturated bottleneck link where
    # it is among the max-rate flows
    for f in range(F):
        links = np.nonzero(adj[f])[0]
        if len(links) == 0:
            continue
        ok = False
        for l in links:
            flows_l = np.nonzero(adj[:, l])[0]
            if (usage[l] >= caps[l] * (1 - 1e-2)
                    and finite[f] >= finite[flows_l].max() * (1 - 1e-3)):
                ok = True
                break
        assert ok, (f, rates[f])


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 2 ** 16))
def test_model_causality(seed):
    """Changing future tokens must not change logits at earlier positions."""
    import dataclasses
    from repro.configs import get_config, reduced
    from repro.models import build_model
    cfg = dataclasses.replace(reduced(get_config("qwen2-0.5b")),
                              dtype="float32", num_layers=2)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(seed)
    toks = rng.integers(0, cfg.vocab_size, (1, 24)).astype(np.int32)
    t = 11
    toks2 = toks.copy()
    toks2[:, t + 1:] = rng.integers(0, cfg.vocab_size,
                                    toks2[:, t + 1:].shape)
    fwd = jax.jit(model.forward)
    l1, _ = fwd(params, {"tokens": jnp.asarray(toks)})
    l2, _ = fwd(params, {"tokens": jnp.asarray(toks2)})
    np.testing.assert_allclose(np.asarray(l1[:, :t + 1]),
                               np.asarray(l2[:, :t + 1]), atol=1e-5)


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 2 ** 16))
def test_ssm_causality(seed):
    import dataclasses
    from repro.configs import get_config, reduced
    from repro.models import build_model
    cfg = dataclasses.replace(reduced(get_config("mamba2-780m")),
                              dtype="float32", num_layers=2)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(seed)
    toks = rng.integers(0, cfg.vocab_size, (1, 40)).astype(np.int32)
    t = 17
    toks2 = toks.copy()
    toks2[:, t + 1:] = rng.integers(0, cfg.vocab_size,
                                    toks2[:, t + 1:].shape)
    fwd = jax.jit(model.forward)
    l1, _ = fwd(params, {"tokens": jnp.asarray(toks)})
    l2, _ = fwd(params, {"tokens": jnp.asarray(toks2)})
    np.testing.assert_allclose(np.asarray(l1[:, :t + 1]),
                               np.asarray(l2[:, :t + 1]), atol=1e-4)
