"""Hypothesis property tests for trace invariants (satellite of the
trace subsystem): across random HPL geometries and serial chains,
(a) critical-path length <= makespan, and == makespan for a serial
chain, (b) per-rank compute+comm+idle sums to the makespan, (c) the
Chrome export is valid trace-event JSON."""
import json

import pytest

pytest.importorskip("hypothesis")

import hypothesis.strategies as st
from hypothesis import given, settings

from repro.core.apps.hpl import HPLConfig, HPLSim
from repro.core.engine import Engine
from repro.core.hardware.node import local_node
from repro.core.hardware.topology import FatTreeTwoLevel
from repro.trace import critical_path, rank_breakdown, validate_chrome_events

REL = 1e-9
TRACE_SETTINGS = settings(max_examples=12, deadline=None)


@TRACE_SETTINGS
@given(nb=st.integers(16, 96), P=st.integers(1, 3), Q=st.integers(1, 3),
       panels=st.integers(2, 5), bcast=st.sampled_from(["1ring", "long"]))
def test_trace_invariants_random_hpl(nb, P, Q, panels, bcast):
    N = nb * panels - nb // 2           # exercise the partial last panel
    node = local_node()
    topo = FatTreeTwoLevel(max(P * Q, 16), 4, 2, link_bw=100e9 / 8)
    cfg = HPLConfig(N=N, nb=nb, P=P, Q=Q, bcast=bcast)
    sim = HPLSim(cfg, node, topo, trace=True)
    res = sim.run()
    tr = sim.trace
    cp = critical_path(tr)
    assert cp.length_s <= res.time_s * (1 + REL)
    for r, acc in rank_breakdown(tr).items():
        assert acc["idle"] >= -REL * res.time_s, (r, acc)
        total = acc["compute"] + acc["comm"] + acc["idle"]
        assert total == pytest.approx(res.time_s, rel=REL)
    doc = tr.to_chrome_json()
    validate_chrome_events(doc)
    json.dumps(doc)                      # JSON-serializable end to end


@TRACE_SETTINGS
@given(waits=st.lists(st.floats(1e-6, 1.0), min_size=1, max_size=12))
def test_serial_chain_critical_path_property(waits):
    eng = Engine(trace=True)

    def proc():
        for i, w in enumerate(waits):
            eng.trace.compute(0, f"s{i}", w)
            yield w
    eng.spawn(proc())
    makespan = eng.run_all()
    cp = critical_path(eng.trace)
    assert cp.length_s == pytest.approx(makespan, rel=1e-9)
