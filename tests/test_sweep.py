"""Batched sweep engine: batched-vs-loop agreement (including bucket
padding edge cases), compile-cache behavior, the sweep-aware whatif
grid, the batch-prediction service, and gradient calibration."""
import dataclasses

import jax
import numpy as np
import pytest
from jax.experimental import enable_x64

from repro.core.apps.hpl import HPLConfig
from repro.core import fastsim
from repro.core.fastsim import (FastSimParams, bucket_key,
                                simulate_hpl_fast, simulate_time_traced,
                                sweep_hpl)
from repro.core.hardware.node import local_node

BASE = FastSimParams.from_node(local_node(), link_bw=100e9 / 8)

# >= 20 mixed configs, covering P=1, Q=1, N % nb != 0, non-power-of-two
# grids, and repeated geometry (exercises the params-batched fast path).
CONFIGS = [
    HPLConfig(N=1024, nb=128, P=1, Q=1),
    HPLConfig(N=1000, nb=96, P=1, Q=4),      # N % nb != 0, P=1
    HPLConfig(N=2048, nb=128, P=4, Q=1),     # Q=1
    HPLConfig(N=3000, nb=128, P=2, Q=3),     # N % nb != 0
    HPLConfig(N=2048, nb=64, P=3, Q=5),
    HPLConfig(N=4096, nb=128, P=4, Q=4),
    HPLConfig(N=4096, nb=192, P=2, Q=8),
    HPLConfig(N=5000, nb=128, P=5, Q=7),     # N % nb != 0
    HPLConfig(N=3072, nb=96, P=7, Q=3),
    HPLConfig(N=8192, nb=256, P=6, Q=6),
    HPLConfig(N=1536, nb=128, P=1, Q=8),
    HPLConfig(N=1537, nb=128, P=8, Q=1),     # N % nb != 0, Q=1
    HPLConfig(N=2500, nb=100, P=2, Q=2),
    HPLConfig(N=6144, nb=192, P=4, Q=6),
    HPLConfig(N=2048, nb=128, P=2, Q=5),
    HPLConfig(N=4097, nb=128, P=3, Q=3),     # N % nb != 0
    HPLConfig(N=4096, nb=128, P=4, Q=4),     # duplicate geometry
    HPLConfig(N=4096, nb=128, P=4, Q=4),
    HPLConfig(N=7000, nb=224, P=5, Q=5),     # N % nb != 0
    HPLConfig(N=1024, nb=512, P=2, Q=2),     # 2 panels
    HPLConfig(N=512, nb=512, P=1, Q=1),      # single panel
]


def _params_for(i: int) -> FastSimParams:
    return dataclasses.replace(
        BASE, link_bw=BASE.link_bw * (1.0 + 0.15 * (i % 5)),
        gemm_eff=BASE.gemm_eff * (0.9 + 0.02 * (i % 4)),
        lookahead=float(i % 2))


def test_sweep_matches_loop_of_singles():
    prms = [_params_for(i) for i in range(len(CONFIGS))]
    batched = sweep_hpl(CONFIGS, prms)
    assert len(batched) == len(CONFIGS)
    for cfg, prm, b in zip(CONFIGS, prms, batched):
        single = simulate_hpl_fast(cfg, prm)
        rel = abs(b["time_s"] - single["time_s"]) / single["time_s"]
        assert rel < 1e-6, (cfg, rel)
        assert b["gflops"] == pytest.approx(single["gflops"], rel=1e-6)


def test_sweep_broadcasts_single_config_and_single_params():
    prms = [_params_for(i) for i in range(4)]
    res = sweep_hpl(CONFIGS[5], prms)
    assert len(res) == 4
    for prm, r in zip(prms, res):
        assert r["time_s"] == pytest.approx(
            simulate_hpl_fast(CONFIGS[5], prm)["time_s"], rel=1e-6)
    res = sweep_hpl(CONFIGS[:3], BASE)
    assert len(res) == 3
    with pytest.raises(ValueError):
        sweep_hpl(CONFIGS[:3], prms)


def test_params_only_change_does_not_retrace():
    cfg = HPLConfig(N=2048, nb=128, P=4, Q=4)
    simulate_hpl_fast(cfg, BASE)
    n0 = fastsim.trace_count()
    simulate_hpl_fast(cfg, dataclasses.replace(
        BASE, link_bw=1e9, gemm_eff=0.5, mem_bw=BASE.mem_bw * 3,
        lookahead=0.0, net_latency=5e-6))
    assert fastsim.trace_count() == n0


def test_sweep_cache_hits_after_warmup():
    prms = [_params_for(i) for i in range(len(CONFIGS))]
    sweep_hpl(CONFIGS, prms)
    n0 = fastsim.trace_count()
    sweep_hpl(CONFIGS, [_params_for(i + 7) for i in range(len(CONFIGS))])
    assert fastsim.trace_count() == n0


def test_nearby_geometries_share_buckets():
    # same panel/grid buckets -> same compiled program
    assert bucket_key(HPLConfig(N=2048, nb=128, P=5, Q=6)) == \
        bucket_key(HPLConfig(N=2048, nb=128, P=6, Q=5))
    # P=1 must get its own bucket (the column-sync branch is static)
    assert bucket_key(HPLConfig(N=2048, nb=128, P=1, Q=4))[1] == 1


def test_whatif_grid_rows_match_singles():
    from repro.core.predict import whatif_grid
    cfg = HPLConfig(N=4096, nb=128, P=4, Q=4)
    rows = whatif_grid(cfg, BASE, {"link_bw": [1.0, 2.0],
                                   "mem_bw": [1.0, 1.5]})
    assert len(rows) == 4
    for row in rows:
        prm = dataclasses.replace(BASE,
                                  link_bw=BASE.link_bw * row["link_bw"],
                                  mem_bw=BASE.mem_bw * row["mem_bw"])
        assert row["time_s"] == pytest.approx(
            simulate_hpl_fast(cfg, prm)["time_s"], rel=1e-6)
    base_t = simulate_hpl_fast(cfg, BASE)["time_s"]
    for row in rows:
        assert row["speedup"] == pytest.approx(base_t / row["time_s"],
                                               rel=1e-6)


def test_prediction_service_batches_and_matches():
    from repro.serve import HPLPredictionService, PredictRequest
    svc = HPLPredictionService(max_batch=8)
    reqs = [PredictRequest(rid=i, cfg=CONFIGS[i % 6],
                           params=_params_for(i)) for i in range(12)]
    out = svc.predict_batch(reqs)
    assert set(out) == set(range(12))
    assert svc.stats["requests"] == 12
    assert svc.stats["batches"] == 2          # 12 reqs / max_batch 8
    for req in reqs:
        assert out[req.rid]["time_s"] == pytest.approx(
            simulate_hpl_fast(req.cfg, req.params)["time_s"], rel=1e-6)


def test_gradient_flows_through_recurrence():
    cfg = HPLConfig(N=2048, nb=128, P=4, Q=4)
    with enable_x64(True):
        g = jax.grad(lambda p: simulate_time_traced(cfg, p))(
            fastsim._f64_params(BASE))
    leaves = jax.tree_util.tree_leaves(g)
    assert all(np.isfinite(np.asarray(l)).all() for l in leaves)
    # more bandwidth / efficiency => faster: negative sensitivities
    assert float(g.gemm_eff) < 0
    assert float(g.mem_bw) < 0
    assert float(g.link_bw) < 0
    assert float(g.net_latency) > 0


def test_calibration_recovers_true_params():
    from repro.core.calibrate import fit_fastsim_params
    true = BASE
    runs = []
    for (N, nb, P, Q) in [(2048, 128, 2, 4), (4096, 128, 4, 4),
                          (3072, 128, 4, 2), (4096, 192, 2, 8)]:
        cfg = HPLConfig(N=N, nb=nb, P=P, Q=Q)
        runs.append((cfg, simulate_hpl_fast(cfg, true)["time_s"]))
    init = dataclasses.replace(true, gemm_eff=true.gemm_eff * 1.6,
                               link_bw=true.link_bw * 0.5)
    fit = fit_fastsim_params(runs, init, fields=("gemm_eff", "link_bw"),
                             steps=250, lr=0.1)
    assert fit.loss < fit.loss0 / 100
    assert fit.params.gemm_eff == pytest.approx(true.gemm_eff, rel=0.05)
    assert fit.params.link_bw == pytest.approx(true.link_bw, rel=0.10)
