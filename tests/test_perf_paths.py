"""Tests for the §Perf hillclimb paths: scatter MoE, dp scheme, remat
policies, kernel-adjusted roofline plumbing."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, reduced
from repro.models import build_model
from repro.models.moe import apply_moe, apply_moe_scatter
from repro.sharding.specs import make_rules, scheme_for


def _moe_cfg(cap=8.0, **over):
    cfg0 = reduced(get_config("phi3.5-moe-42b-a6.6b"))
    return dataclasses.replace(
        cfg0, dtype="float32",
        moe=dataclasses.replace(cfg0.moe, capacity_factor=cap), **over)


def test_scatter_moe_matches_einsum_no_drops(rng):
    cfg = _moe_cfg()
    model = build_model(cfg)
    p_moe = jax.tree.map(lambda a: a[0], model.init(rng)["layers"])["moe"]
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 32, cfg.d_model)) * 0.5
    y1, aux1 = jax.jit(lambda p, x: apply_moe(p, x, cfg))(p_moe, x)
    y2, aux2 = jax.jit(lambda p, x: apply_moe_scatter(p, x, cfg))(p_moe, x)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), atol=1e-4)
    np.testing.assert_allclose(float(aux1), float(aux2), rtol=1e-5)


def test_scatter_moe_capacity_drops_bounded(rng):
    """With tight capacity both impls drop tokens; outputs stay finite and
    the drop fraction is bounded by the capacity factor."""
    cfg = _moe_cfg(cap=1.0)
    model = build_model(cfg)
    p_moe = jax.tree.map(lambda a: a[0], model.init(rng)["layers"])["moe"]
    x = jax.random.normal(jax.random.PRNGKey(2), (2, 64, cfg.d_model)) * 0.5
    y, _ = jax.jit(lambda p, x: apply_moe_scatter(p, x, cfg))(p_moe, x)
    assert bool(jnp.isfinite(y).all())


def test_scatter_moe_grad_flows(rng):
    cfg = dataclasses.replace(_moe_cfg(), moe_impl="scatter")
    model = build_model(cfg)
    params = model.init(rng)
    batch = {"tokens": jax.random.randint(rng, (2, 32), 0, cfg.vocab_size)}
    loss, grads = jax.value_and_grad(
        lambda p: model.loss(p, batch)[0])(params)
    gn = sum(float(jnp.sum(jnp.abs(g))) for g in jax.tree.leaves(grads))
    assert np.isfinite(float(loss)) and gn > 0


def test_dp_scheme_rules():
    cfg = dataclasses.replace(get_config("mamba2-780m"), force_scheme="dp")
    assert scheme_for(cfg, 16) == "dp"
    rules = make_rules(cfg, mode="train", global_batch=256)
    assert rules["dp"] == ("data", "model")
    assert rules["tp"] == ()
    # batch not divisible by 256 -> falls back to data-only dp
    rules2 = make_rules(cfg, mode="train", global_batch=32)
    assert rules2["dp"] == ("data",)


@pytest.mark.parametrize("remat", ["full", "dots", "dots_nb", "none"])
def test_remat_policies_train(remat, rng):
    from repro.train.step import make_train_state, make_train_step
    cfg = dataclasses.replace(reduced(get_config("qwen2-0.5b")),
                              remat=remat)
    state = make_train_state(cfg, rng)
    step_fn, _ = make_train_step(cfg, lr=1e-3)
    batch = {"tokens": jax.random.randint(rng, (2, 32), 0, cfg.vocab_size)}
    state, m = jax.jit(step_fn)(state, batch)
    assert np.isfinite(float(m["loss"]))


def test_remat_policies_same_loss(rng):
    """Remat changes memory/compute, never numerics (same fwd graph)."""
    from repro.train.step import make_train_state, make_train_step
    losses = {}
    for remat in ("full", "dots_nb"):
        cfg = dataclasses.replace(reduced(get_config("qwen2-0.5b")),
                                  remat=remat, dtype="float32")
        state = make_train_state(cfg, jax.random.PRNGKey(0))
        step_fn, _ = make_train_step(cfg, lr=1e-3)
        batch = {"tokens": jax.random.randint(jax.random.PRNGKey(1),
                                              (2, 32), 0, cfg.vocab_size)}
        _, m = jax.jit(step_fn)(state, batch)
        losses[remat] = float(m["loss"])
    assert losses["full"] == pytest.approx(losses["dots_nb"], rel=1e-6)


def test_attn_block_size_invariance(rng):
    """Blockwise attention output must not depend on the block size."""
    cfg = dataclasses.replace(reduced(get_config("granite-34b")),
                              dtype="float32")
    model = build_model(cfg)
    params = model.init(rng)
    batch = {"tokens": jax.random.randint(rng, (1, 64), 0, cfg.vocab_size)}
    outs = []
    for blk in (16, 32, 64):
        cfg_b = dataclasses.replace(cfg, attn_block=blk)
        m = build_model(cfg_b)
        logits, _ = jax.jit(m.forward)(params, batch)
        outs.append(np.asarray(logits))
    np.testing.assert_allclose(outs[0], outs[1], atol=1e-4)
    np.testing.assert_allclose(outs[0], outs[2], atol=1e-4)


def test_pattern_traffic_matchers():
    from repro.roofline.hlo_parse import score_matcher, chunk_matcher
    m = score_matcher(4096, 1024)
    assert m([16, 4, 4096, 1024])
    assert m([16, 12288, 1024])       # head-merged
    assert m([16, 1024, 12288])       # transposed
    assert not m([16, 4096, 128])     # attention output (hd), not scores
    c = chunk_matcher(256)
    assert c([1, 256, 256, 48])       # (..., Q, Q, H)
    assert c([48, 256, 256])
    assert c([16, 256, 12288])        # head-merged
    assert not c([16, 100, 48])
