"""Hypothesis property tests for the fault layer: any valid Fault/
FaultSpec round-trips through JSON exactly (the seeded-random fallback
in test_faults.py covers environments without hypothesis)."""
import pytest

pytest.importorskip("hypothesis")

import hypothesis.strategies as st
from hypothesis import given, settings

from repro.faults import Fault, FaultSpec

SETTINGS = settings(max_examples=60, deadline=None)

finite = dict(allow_nan=False, allow_infinity=False)


@st.composite
def faults(draw):
    kind = draw(st.sampled_from(("straggler", "fail_stop", "link_degrade",
                                 "link_flap", "latency_jitter")))
    kw = dict(start=draw(st.floats(0, 1e3, **finite)),
              duration=draw(st.floats(0, 1e3, **finite)))
    if kind == "straggler":
        kw.update(rank=draw(st.integers(0, 4095)),
                  factor=draw(st.floats(1e-3, 64, **finite)))
    elif kind == "fail_stop":
        kw.update(rank=draw(st.integers(0, 4095)),
                  node=draw(st.integers(-1, 255)))
    elif kind in ("link_degrade", "link_flap"):
        kw.update(link_frac=draw(st.floats(1e-6, 1.0, **finite)),
                  factor=draw(st.floats(1e-6, 1.0, **finite)))
        if kind == "link_flap":
            kw.update(period=draw(st.floats(1e-6, 10, **finite)),
                      duty=draw(st.floats(0.01, 0.99, **finite)),
                      cycles=draw(st.integers(1, 100)))
    else:
        kw.update(sigma=draw(st.floats(0.01, 0.99, **finite)))
    return Fault(kind, **kw)


@SETTINGS
@given(fs=st.lists(faults(), max_size=6), seed=st.integers(0, 2**31 - 1),
       name=st.text(max_size=12))
def test_fault_spec_json_roundtrip_property(fs, seed, name):
    spec = FaultSpec(faults=tuple(fs), seed=seed, name=name)
    assert FaultSpec.from_json(spec.to_json()) == spec
    assert hash(FaultSpec.from_json(spec.to_json())) == hash(spec)
