"""HPL application model: numroc correctness, DES vs fastsim agreement,
and the paper's headline predictions (Table II band)."""
import dataclasses

import pytest

from repro.core.apps.hpl import HPLConfig, HPLSim, numroc
from repro.core.fastsim import FastSimParams, simulate_hpl_fast
from repro.core.hardware.node import (frontera_node, local_node,
                                      pupmaya_node)
from repro.core.hardware.topology import FatTreeTwoLevel


def test_numroc_partitions_completely():
    for n, nb, p in [(1000, 32, 4), (4096, 128, 3), (999, 7, 5)]:
        total = sum(numroc(n, nb, i, p) for i in range(p))
        assert total == n, (n, nb, p, total)


def test_des_fastsim_cross_validation():
    node = local_node()
    topo = FatTreeTwoLevel(16, 4, 2, link_bw=100e9 / 8)
    for (N, nb, P, Q) in [(2048, 128, 4, 4), (4096, 128, 2, 8)]:
        cfg = HPLConfig(N=N, nb=nb, P=P, Q=Q)
        des = HPLSim(cfg, node, topo).run()
        prm = dataclasses.replace(
            FastSimParams.from_node(node, link_bw=100e9 / 8), lookahead=0.0)
        fast = simulate_hpl_fast(cfg, prm)
        rel = abs(des.time_s - fast["time_s"]) / des.time_s
        assert rel < 0.15, (N, nb, P, Q, des.time_s, fast["time_s"])


def test_gflops_below_peak_and_sane():
    node = local_node()
    topo = FatTreeTwoLevel(16, 4, 2, link_bw=100e9 / 8)
    cfg = HPLConfig(N=4096, nb=128, P=4, Q=4)
    res = HPLSim(cfg, node, topo).run()
    agg_peak = 16 * node.peak_flops / 1e9
    assert 0.01 * agg_peak < res.gflops < agg_peak


@pytest.mark.slow
def test_table2_frontera_prediction_band():
    """Paper Table II: Frontera Rmax 23,516 TF; paper's sim says 22,566
    (-4%).  Our prediction must land within 10% of the reported Rmax."""
    cfg = HPLConfig(N=9_282_848, nb=384, P=88, Q=91)
    prm = FastSimParams.from_node(frontera_node(), link_bw=100e9 / 8)
    res = simulate_hpl_fast(cfg, prm)
    assert abs(res["tflops"] - 23516) / 23516 < 0.10, res["tflops"]


@pytest.mark.slow
def test_table2_pupmaya_prediction_band():
    cfg = HPLConfig(N=4_748_928, nb=384, P=59, Q=72)
    prm = FastSimParams.from_node(pupmaya_node(), link_bw=100e9 / 8)
    res = simulate_hpl_fast(cfg, prm)
    assert abs(res["tflops"] - 7484) / 7484 < 0.10, res["tflops"]


def test_hplconfig_validation_rejects_nonsense():
    with pytest.raises(ValueError):
        HPLConfig(N=0, nb=128, P=2, Q=2)
    with pytest.raises(ValueError):
        HPLConfig(N=1024, nb=0, P=2, Q=2)
    with pytest.raises(ValueError):
        HPLConfig(N=1024, nb=128, P=0, Q=2)
    with pytest.raises(ValueError):
        HPLConfig(N=1024, nb=128, P=2, Q=-1)
    with pytest.raises(ValueError):
        HPLConfig(N=1024, nb=128, P=2, Q=2, bcast="ring9")
    with pytest.raises(ValueError):
        HPLConfig(N=1024, nb=128, P=2, Q=2, lookahead=3)


def test_partial_trailing_panel_is_modeled():
    """N=1000, nb=96: 10 full panels + one 40-wide panel.  Both
    simulators must charge for the extra panel (not silently drop it)
    and still agree with each other."""
    node = local_node()
    topo = FatTreeTwoLevel(16, 4, 2, link_bw=100e9 / 8)
    prm = dataclasses.replace(
        FastSimParams.from_node(node, link_bw=100e9 / 8), lookahead=0.0)

    cfg_partial = HPLConfig(N=1000, nb=96, P=2, Q=2)
    cfg_floor = HPLConfig(N=960, nb=96, P=2, Q=2)
    assert cfg_partial.n_panels == 11 and cfg_floor.n_panels == 10

    des_partial = HPLSim(cfg_partial, node, topo).run()
    des_floor = HPLSim(cfg_floor, node, topo).run()
    fast_partial = simulate_hpl_fast(cfg_partial, prm)
    fast_floor = simulate_hpl_fast(cfg_floor, prm)

    # the trailing 40 columns cost strictly positive time in both worlds
    assert des_partial.time_s > des_floor.time_s
    assert fast_partial["time_s"] > fast_floor["time_s"]
    # and the two fidelities still tell the same story
    rel = abs(des_partial.time_s - fast_partial["time_s"]) \
        / des_partial.time_s
    assert rel < 0.20, (des_partial.time_s, fast_partial["time_s"])


def test_whatif_network_upgrade_small_gain():
    """Paper §V: doubling fabric bandwidth buys only a few percent."""
    cfg = HPLConfig(N=1_000_000, nb=384, P=32, Q=32)
    node = frontera_node()
    r100 = simulate_hpl_fast(cfg, FastSimParams.from_node(
        node, link_bw=100e9 / 8))
    r200 = simulate_hpl_fast(cfg, FastSimParams.from_node(
        node, link_bw=200e9 / 8))
    gain = r200["tflops"] / r100["tflops"] - 1
    assert 0.0 <= gain < 0.15
