"""Hot-loop rewrite equivalence: the array-backed engine vs the frozen
pre-rewrite loop (core/_legacy_engine.py).

The rewrite's contract is *bit-identity*: same event order, same
simulated times, same traces — only faster.  These tests hold it to
that on randomized spawn/wait/event/kill programs (hypothesis), on the
wall-deadline dispatch loop (a separate code path that must mirror the
hot one exactly), and on full DES applications under fault injection,
where event/flow recycling gets exercised hardest.  The ``Event.set``
re-entrancy test pins the FIFO hazard the same-timestamp batch drain
was built around.
"""
import math

import pytest

from repro.core._legacy_engine import LegacyEngine, legacy_des
from repro.core.engine import Engine, SimWallDeadline


# --------------------------------------------------------------- driver
def _execute(engine_cls, spec, *, deadline_s=None):
    """Run a program spec on either engine; return (log, final_t, events).

    ``spec`` is a list of top-level processes, each a list of ops:

        ("wait", dt)       yield a wait
        ("set", e, pay)    fire event e with payload pay
        ("waitev", e)      park on event e (logs the payload on wake)
        ("spawn", ops)     start a child running ops
        ("kill", p)        fail-stop top-level process p (self-kill is
                           skipped — real fault runtimes kill from
                           outside the victim, never from within)

    Events and processes are referenced by index so the same spec
    replays identically on both engines.
    """
    eng = engine_cls()
    if deadline_s is not None:
        eng.set_wall_deadline(deadline_s)

    def leaf_ops(ops):
        for op in ops:
            if op[0] == "spawn":
                yield from leaf_ops(op[1])
            else:
                yield op

    n_events = 1 + max((op[1] for _, ops in spec for op in leaf_ops(ops)
                        if op[0] in ("set", "waitev")), default=0)
    events = [eng.event() for _ in range(n_events)]
    procs = []
    log = []

    def run_ops(pid, ops, own=None):
        for i, op in enumerate(ops):
            kind = op[0]
            if kind == "wait":
                yield op[1]
            elif kind == "set":
                events[op[1]].set((pid, i, op[2]))
            elif kind == "waitev":
                payload = yield events[op[1]]
                log.append(("woke", pid, i, payload, eng.now))
                continue
            elif kind == "spawn":
                yield ("spawn", run_ops(f"{pid}/c{i}", op[1], own=own))
            elif kind == "kill":
                if op[1] < len(procs) and op[1] != own:
                    procs[op[1]].kill()
            log.append((pid, i, eng.now))

    for idx, (pid, ops) in enumerate(spec):
        procs.append(eng.spawn(run_ops(f"p{pid}", ops, own=idx),
                               name=f"p{pid}"))
    final = eng.run_all()
    return log, final, eng.event_count


def _assert_equivalent(spec, *, deadline_s=None):
    new = _execute(Engine, spec, deadline_s=deadline_s)
    old = _execute(LegacyEngine, spec, deadline_s=deadline_s)
    assert new[0] == old[0], "event order diverged"
    assert new[1] == old[1], "final simulated time diverged"
    assert new[2] == old[2], "event count diverged"


# ------------------------------------------------- randomized programs
# hypothesis is a CI dependency, not a runtime one: the randomized
# equivalence sweep skips cleanly where it's absent (the targeted
# regressions below still run everywhere)
try:
    import hypothesis.strategies as st
    from hypothesis import given, settings
    HAS_HYPOTHESIS = True
except ImportError:                                  # pragma: no cover
    HAS_HYPOTHESIS = False

if HAS_HYPOTHESIS:
    SETTINGS = settings(max_examples=40, deadline=None)

    # small dt alphabet with heavy collisions: equal timestamps are
    # where tie-breaking (and therefore the FIFO/heap merge) can go
    # wrong
    _DT = st.sampled_from([0.0, 0.0, 0.5, 1.0, 1.0, 2.0])
    _EV = st.integers(0, 3)

    _leaf_op = st.one_of(
        st.tuples(st.just("wait"), _DT),
        st.tuples(st.just("set"), _EV, st.integers(0, 9)),
        st.tuples(st.just("waitev"), _EV),
        st.tuples(st.just("kill"), st.integers(0, 3)),
    )
    _child_ops = st.lists(_leaf_op, min_size=1, max_size=4)
    _op = st.one_of(_leaf_op, st.tuples(st.just("spawn"), _child_ops))
    _program = st.lists(
        st.tuples(st.integers(0, 99),
                  st.lists(_op, min_size=1, max_size=6)),
        min_size=1, max_size=5)

    @SETTINGS
    @given(spec=_program)
    def test_random_programs_identical_old_vs_new(spec):
        _assert_equivalent(spec)

    @SETTINGS
    @given(spec=_program)
    def test_random_programs_identical_under_wall_deadline(spec):
        # a generous wall deadline routes dispatch through
        # _run_deadline, which must mirror the hot loop exactly
        _assert_equivalent(spec, deadline_s=60.0)


# ------------------------------------------------- targeted regressions
def test_event_set_reentrancy_keeps_fifo_order():
    """A waiter that re-entrantly fires another event mid-drain must not
    jump its wakeups ahead of already-queued ones: dispatch is global
    ``(time, seq)`` order, so C (registered after B's wakeup was queued)
    runs after B."""
    for engine_cls in (Engine, LegacyEngine):
        eng = engine_cls()
        ev1, ev2 = eng.event(), eng.event()
        order = []

        def waiter(name, ev, then_set=None):
            yield ev
            order.append(name)
            if then_set is not None:
                then_set.set()

        eng.spawn(waiter("A", ev1, then_set=ev2))
        eng.spawn(waiter("B", ev1))
        eng.spawn(waiter("C", ev2))

        def kick():
            yield 1.0
            ev1.set()
        eng.spawn(kick())
        eng.run_all()
        assert order == ["A", "B", "C"], engine_cls.__name__


def test_event_set_is_idempotent_and_sticky():
    eng = Engine()
    ev = eng.event()
    got = []

    def w():
        got.append((yield ev))
    eng.spawn(w())
    ev.set("first")
    ev.set("second")              # ignored: events fire once
    eng.run_all()
    assert got == ["first"] and ev.payload == "first"

    late = []

    def w2():
        late.append((yield ev))   # already-set event: continue at once
    eng.spawn(w2())
    eng.run_all()
    assert late == ["first"]


def test_recycled_event_slot_comes_back_fresh():
    """Slot reuse must not leak state: a recycled event fetched from
    the pool behaves exactly like a fresh one."""
    eng = Engine()
    ev = eng.event()
    ev.set("stale payload")
    eng._recycle_event(ev)
    ev2 = eng.event()
    assert ev2 is ev                      # pooled slot actually reused
    assert not ev2.is_set and ev2.payload is None and ev2.waiters == []
    fired = []

    def w():
        fired.append((yield ev2))
    eng.spawn(w())

    def s():
        yield 1.0
        ev2.set("fresh")
    eng.spawn(s())
    eng.run_all()
    assert fired == ["fresh"]


def test_kill_under_slot_reuse_strands_joiners_identically():
    """Fail-stop mid-wait: the killed process takes no further steps and
    its joiner parks forever — identical on both engines even with the
    killed process's wakeup already queued."""
    def program(engine_cls):
        eng = engine_cls()
        log = []

        def victim():
            yield 1.0
            log.append(("victim-step", eng.now))
            yield 5.0
            log.append(("victim-end", eng.now))     # must never happen

        def joiner(p):
            yield p
            log.append(("joined", eng.now))         # must never happen

        def killer(p):
            yield 3.0
            p.kill()
            log.append(("killed", eng.now))

        v = eng.spawn(victim())
        eng.spawn(joiner(v))
        eng.spawn(killer(v))
        t = eng.run_all()
        return log, t, eng.event_count

    assert program(Engine) == program(LegacyEngine)
    log, t, _ = program(Engine)
    # the victim's queued wakeup still pops (a no-op on a killed
    # process), so sim time reaches 6.0 — but the victim takes no step
    # and the joiner never resumes
    assert ("killed", 3.0) in log and t == 6.0
    assert not any(x[0] in ("victim-end", "joined") for x in log)


def test_wall_deadline_raises_on_both_engines():
    def spin():
        while True:
            yield 0.0

    for engine_cls in (Engine, LegacyEngine):
        eng = engine_cls()
        eng.spawn(spin())
        eng.set_wall_deadline(0.05)
        with pytest.raises(SimWallDeadline):
            eng.run_all()


# ------------------------------------------- full applications, faulted
def _hpl_result(cfg_kw, platform, faults=None, trace=False):
    from repro.core.apps.hpl import HPLConfig, HPLSim
    cfg = HPLConfig(**cfg_kw)
    res = HPLSim(cfg, platform, trace=trace, faults=faults).run()
    summary = res.trace.summary() if trace and res.trace else None
    return res.time_s, res.events, res.failed, res.n_finished, summary


@pytest.mark.parametrize("faults_kw", [
    None,
    {"kind": "straggler", "rank": 1, "slowdown": 2.0},
    {"kind": "degraded_links", "fraction": 0.2, "factor": 0.5, "seed": 7},
    {"kind": "fail_stop", "rank": 3, "at_s": 0.005},
])
def test_hpl_bit_identical_old_vs_new(faults_kw):
    from repro.faults import FaultSpec
    from repro.platforms import get_platform

    plat = get_platform("frontera")
    cfg_kw = dict(N=2048, nb=128, P=2, Q=4, lookahead=0,
                  bcast=plat.mpi.bcast)
    faults = FaultSpec.from_dict(faults_kw) if faults_kw else None
    new = _hpl_result(cfg_kw, plat, faults=faults, trace=True)
    with legacy_des():
        old = _hpl_result(cfg_kw, plat, faults=faults, trace=True)
    assert new == old


def test_transformer_bit_identical_old_vs_new():
    from repro.platforms import get_platform
    from repro.workloads import get_workload

    plat = get_platform("tpu-v5e-pod")
    wl = get_workload("transformer", mesh=(4, 8), num_layers=3)
    new = wl.predict_des(plat)
    with legacy_des():
        old = wl.predict_des(plat)
    assert new == old
