"""Campaign layer (repro.campaign, DESIGN.md §19).

Five contracts under test:

  * spec semantics — frozen, normalized, exact JSON round-trip; difflib
    close-match hints on unknown workload kinds / platform names / axis
    keys (the ``get_platform`` error UX); budget enforcement;
  * deterministic expansion — same spec, same matrix, and same
    byte-equal ``campaign_run`` journal lines (timing lives only in the
    summary record);
  * batched execution — the acceptance matrix (2 workloads x 3
    platforms x 2 seeds x a fault scenario) costs ONE compiled sweep
    per model family, asserted via the obs compile counters, with one
    NDJSON manifest line per run;
  * the longitudinal TOP500 study — two vendored editions in, per-
    machine prediction drift and per-fabric calibration-factor drift
    out;
  * merge/report/CLI — journal folding (torn lines tolerated) with the
    metrics monoid, ranked + drift rendering, CSV/JSON artifacts.
"""
import dataclasses
import json

import pytest

from repro.campaign import (Budget, CampaignSpec, PlatformSelector,
                            campaign_report, dispatch_counts,
                            edition_study_spec, expand, machine_key,
                            merge_journals, render_markdown, render_text,
                            run_campaign, write_csv)
from repro.campaign.cli import main as campaign_main
from repro.faults import FaultSpec
from repro.top500 import FleetTuning

SMOKE_TUNING = FleetTuning(max_ranks=256, panels_cap=2048)

#: torus/multipod registry machines both test workloads accept
TORUS_PLATFORMS = ("tpu-v5e-pod", "syn-torus-fugaku-4k",
                   "syn-torus-bgq-8k")


def accept_spec(**over):
    """The ISSUE's acceptance matrix: 2 workloads x 3 platforms x
    2 seeds x a fault scenario (N axis keeps HPL cells small)."""
    kw = dict(workloads=["hpl", "transformer"],
              platforms=list(TORUS_PLATFORMS),
              axes={"N": [1536, 1920]},
              faults=[None, FaultSpec.straggler(rank=0, slowdown=1.5)],
              seeds=[0, 1])
    kw.update(over)
    return CampaignSpec.make("accept", **kw)


# ------------------------------------------------------------- spec layer

def test_spec_json_round_trip_exact():
    spec = accept_spec()
    assert CampaignSpec.from_json(spec.to_json()) == spec
    # dict form too, and the round-trip normalizes identically
    assert CampaignSpec.from_dict(json.loads(spec.to_json())) == spec


def test_spec_normalization_orders_axes_and_freezes():
    a = CampaignSpec.make("n", workloads=["hpl"], platforms=["frontera"],
                          axes={"nb": [128, 192], "N": [2048]})
    b = CampaignSpec.make("n", workloads=["hpl"], platforms=["frontera"],
                          axes={"N": [2048], "nb": (128, 192)})
    assert a == b and hash(a) == hash(b)
    assert [k for k, _ in a.axes] == ["N", "nb"]    # sorted


def test_bare_kind_name_resolves_to_default_spec():
    spec = CampaignSpec.make("d", workloads=["transformer"],
                             platforms=["tpu-v5e-pod"])
    params = dict(spec.workloads[0].params)
    assert params["num_layers"] >= 1     # defaults journaled, not empty


def test_selector_needs_exactly_one_source():
    with pytest.raises(ValueError, match="exactly one"):
        PlatformSelector()
    with pytest.raises(ValueError, match="exactly one"):
        PlatformSelector(registry="frontera", top500="sample:2020_06")
    with pytest.raises(ValueError, match="top500 selectors only"):
        PlatformSelector(registry="frontera", edition="x")


def test_selector_edition_label_defaults():
    assert PlatformSelector(top500="sample:2020_11").edition_label() \
        == "2020_11"
    assert PlatformSelector(top500="/data/nov.csv").edition_label() \
        == "nov"
    assert PlatformSelector(top500="sample:2020_11",
                            edition="late").edition_label() == "late"


# ----------------------------------------------- difflib hints (satellite)

def test_unknown_workload_kind_hints_close_match():
    spec = CampaignSpec.make("bad", workloads=["hpll"],
                             platforms=["frontera"])
    with pytest.raises(ValueError,
                       match=r"unknown workload kind 'hpll'; did you "
                             r"mean: hpl\?"):
        spec.validate()


def test_unknown_platform_name_hints_close_match():
    spec = CampaignSpec.make("bad", workloads=["hpl"],
                             platforms=["fronterra"])
    with pytest.raises(ValueError,
                       match=r"unknown platform 'fronterra'; did you "
                             r"mean: frontera"):
        spec.validate()


def test_unknown_axis_key_hints_close_match():
    spec = CampaignSpec.make("bad", workloads=["hpl"],
                             platforms=["frontera"], axes={"nbb": [128]})
    with pytest.raises(ValueError,
                       match=r"axis key 'nbb' .*did you mean: nb\?"):
        spec.validate()


def test_axis_key_legal_when_any_workload_knows_it():
    # num_layers is a transformer knob; hpl ignores it, transformer
    # sweeps it — legal because one campaign workload knows the key
    spec = CampaignSpec.make(
        "mixed", workloads=["hpl", "transformer"],
        platforms=["tpu-v5e-pod"], axes={"num_layers": [2, 4]})
    spec.validate()
    m = expand(spec)
    hpl = [c for c in m.grid_cases if c.workload.kind == "hpl"]
    tf = [c for c in m.grid_cases if c.workload.kind == "transformer"]
    assert len(hpl) == 1 and len(tf) == 2
    assert all(c.overrides for c in tf) and not hpl[0].overrides


def test_budget_caps_expansion():
    spec = accept_spec(max_runs=10)
    with pytest.raises(ValueError, match="over budget max_runs=10"):
        expand(spec)
    assert Budget().max_runs == 4096
    with pytest.raises(ValueError, match=">= 1"):
        Budget(max_runs=0)


# ------------------------------------------------- deterministic expansion

def test_expand_is_deterministic():
    spec = accept_spec()
    m1, m2 = expand(spec), expand(spec)
    assert [c.key for c in m1.cases] == [c.key for c in m2.cases]
    assert m1.cases == m2.cases
    # 2 wl x 3 plat x (2 N-cells for hpl, 1 for transformer) x 2 faults
    # x 2 seeds = 24 + 12
    assert len(m1.grid_cases) == 36
    assert [c.index for c in m1.cases] == list(range(len(m1.cases)))


def test_expand_reseeds_faults_per_seed_axis():
    spec = accept_spec()
    faulted = [c for c in expand(spec).grid_cases if c.fault is not None]
    assert faulted and all(c.fault.seed == c.seed for c in faulted)
    seeds = {c.fault.seed for c in faulted}
    assert seeds == {0, 1}


def test_expand_skips_incompatible_cells_leniently():
    # frontera is a fat-tree: transformer can't run there
    spec = CampaignSpec.make("skew", workloads=["hpl", "transformer"],
                             platforms=["frontera", "tpu-v5e-pod"],
                             seeds=[0])
    m = expand(spec)
    assert any("transformer" in key and "frontera" in key
               for key, _ in m.skipped)
    assert all("torus or multipod" in reason for key, reason in m.skipped)
    kinds = {(c.workload.kind, c.platform) for c in m.grid_cases}
    assert ("transformer", "frontera") not in kinds
    assert ("hpl", "frontera") in kinds
    with pytest.raises(ValueError, match="torus or multipod"):
        expand(spec, strict=True)


def test_machine_key_strips_list_position_prefix():
    assert machine_key("r017-selene") == "selene"
    assert machine_key("r1017-selene") == "selene"
    assert machine_key("frontera") == "frontera"


# --------------------------------------------------- batched execution

@pytest.fixture(scope="module")
def accept_result(tmp_path_factory):
    journal = tmp_path_factory.mktemp("accept") / "runs.ndjson"
    res = run_campaign(accept_spec(), journal=journal)
    return res, journal


def test_acceptance_matrix_one_compile_per_family(accept_result):
    res, _ = accept_result
    d = res.summary["meta"]["dispatches"]
    # 36 scenarios over 3 heterogeneous platforms: ONE compiled fastsim
    # sweep for every HPL cell (shared forced bucket), ONE stepsim sweep
    # for every transformer cell, one serve dispatch per family
    assert d["fastsim_dispatches"] == 1
    assert d["stepsim_dispatches"] == 1
    assert d["serve_sweeps"] == 2
    assert res.summary["meta"]["runs"] == 36


def test_acceptance_matrix_journals_one_line_per_run(accept_result):
    res, journal = accept_result
    lines = journal.read_text().splitlines()
    runs = [json.loads(l) for l in lines if l]
    assert len(runs) == 36 + 1          # one per run + summary
    kinds = [r["kind"] for r in runs]
    assert kinds.count("campaign_run") == 36
    assert kinds[-1] == "campaign_summary"
    # every grid run served ok and carries its full identity
    for r in runs[:-1]:
        meta = r["meta"]
        assert meta["campaign"] == "accept"
        assert meta["result"]["status"] != "error"
        assert meta["result"]["time_s"] > 0
        kind = meta["workload"]["kind"]
        assert kind in ("hpl", "transformer")
        # family-specific payloads survive into the journal
        assert meta["result"]["tflops" if kind == "hpl"
                              else "tokens_per_s"] > 0


def test_faulted_runs_are_slower_than_clean(accept_result):
    res, _ = accept_result
    by_key = {r["meta"]["cell"]: r["meta"] for r in res.run_records}
    slower = checked = 0
    for key, meta in by_key.items():
        if meta["fault"] is None:
            continue
        clean = by_key.get(key.replace("f1", "f0"))
        if clean is None or meta["workload"]["kind"] != "hpl":
            continue
        checked += 1
        slower += (meta["result"]["time_s"]
                   >= clean["result"]["time_s"] - 1e-12)
    assert checked and slower == checked


def test_same_spec_gives_byte_equal_run_lines(accept_result):
    res, _ = accept_result
    res2 = run_campaign(accept_spec())
    l1 = [l for l in res.lines() if '"campaign_run"' in l]
    l2 = [l for l in res2.lines() if '"campaign_run"' in l]
    assert l1 == l2
    # the summaries differ only in timing and compile-cache state
    # (the rerun hits the warm bucket: misses become hits, dispatch
    # totals stay put)
    s1, s2 = dict(res.summary["meta"]), dict(res2.summary["meta"])
    s1.pop("wall_s"), s2.pop("wall_s")
    d1, d2 = s1.pop("dispatches"), s2.pop("dispatches")
    assert s1 == s2
    for k in ("fastsim_dispatches", "stepsim_dispatches", "serve_sweeps"):
        assert d1[k] == d2[k]


def test_rerun_against_warm_cached_service_is_all_hits(accept_result):
    from repro.serve import PredictionService
    res, _ = accept_result
    svc = PredictionService(cache=True)
    spec = accept_spec()
    first = run_campaign(spec, service=svc)
    second = run_campaign(spec, service=svc)
    d1 = first.summary["meta"]["dispatches"]
    d2 = second.summary["meta"]["dispatches"]
    grid = first.summary["meta"]["grid_runs"]
    # cold pass: every grid cell is a miss (duplicate cells coalesce)
    assert d1["cache_hits"] == 0 and d1["cache_misses"] == grid
    # warm pass: all-hits — zero sweeps, zero model dispatches
    assert d2["cache_hits"] == grid and d2["cache_misses"] == 0
    assert d2["serve_sweeps"] == 0
    assert d2["fastsim_dispatches"] == 0 == d2["stepsim_dispatches"]
    # results are unchanged: byte-equal campaign_run lines, and equal
    # to the plain uncached run's lines (the cached stamp is stripped)
    warm_lines = [l for l in second.lines() if '"campaign_run"' in l]
    cold_lines = [l for l in first.lines() if '"campaign_run"' in l]
    base_lines = [l for l in res.lines() if '"campaign_run"' in l]
    assert warm_lines == cold_lines == base_lines


def test_strict_run_raises_on_bad_cell():
    # fail_stop has no closed-form fastsim mapping: resolution fails at
    # serve time (expand can't see it — faults aren't platform checks)
    spec = CampaignSpec.make("badcell", workloads=["hpl"],
                             platforms=["tpu-v5e-pod"],
                             axes={"N": [1536]},
                             faults=[FaultSpec.fail_stop(rank=0)],
                             seeds=[0])
    res = run_campaign(spec)            # lenient: isolated error record
    rec = res.run_records[0]["meta"]["result"]
    assert rec["status"] == "error" and "fail_stop" in rec["error"]
    with pytest.raises(ValueError, match="fail_stop"):
        run_campaign(spec, strict=True)


# ------------------------------------------- the longitudinal TOP500 study

@pytest.fixture(scope="module")
def drift_result(tmp_path_factory):
    journal = tmp_path_factory.mktemp("drift") / "drift.ndjson"
    spec = edition_study_spec(["2020_06", "2020_11"], limit=8)
    res = run_campaign(spec, journal=journal, tuning=SMOKE_TUNING)
    return res, journal


def test_edition_study_runs_both_fleets(drift_result):
    res, _ = drift_result
    assert sorted(res.fleet_reports) == ["2020_06", "2020_11"]
    assert len(res.matrix.fleet_cases) == 16
    for rec in res.run_records:
        meta = rec["meta"]
        assert meta["kind"] == "fleet"
        assert meta["edition"] in ("2020_06", "2020_11")
        assert meta["machine"] == machine_key(meta["platform"])
        assert meta["result"]["published_tflops"] > 0
    eds = res.summary["meta"]["editions"]
    assert eds["2020_06"]["calibration_factors"]
    # each edition costs at most one fresh compile (shared bucket; a
    # warm cache from an earlier test can make it zero)
    assert all(e["compiles"] <= 1 for e in eds.values())


def test_drift_report_has_machine_and_factor_drift(drift_result):
    res, _ = drift_result
    report = campaign_report(res.records)
    drift = report["drift"]
    assert drift["from"] == "2020_06" and drift["to"] == "2020_11"
    by_machine = {d["machine"]: d for d in drift["machines"]}
    # Fugaku was upgraded between the editions: published Rmax rose
    # ~6%, and the prediction tracks the larger machine
    fugaku = by_machine["fugaku"]
    assert fugaku["published_drift"] == pytest.approx(0.0637, abs=0.01)
    assert fugaku["predicted_drift"] > 0.0
    # Selene doubled; machines absent from one edition are listed
    assert by_machine["selene"]["predicted_drift"] > 0.5
    assert "juwels-booster-module" in drift["appeared"]
    assert "tianhe-2a" in by_machine          # present in both
    fams = {f["family"]: f for f in drift["calibration_factors"]}
    assert "infiniband" in fams
    assert fams["infiniband"]["drift"] is not None


def test_drift_render_mentions_both_editions(drift_result):
    res, _ = drift_result
    report = campaign_report(res.records)
    md = render_markdown(report)
    txt = render_text(report)
    for out in (md, txt):
        assert "2020_06 -> 2020_11" in out and "fugaku" in out
    assert "## Calibration-factor drift" in md
    assert "CALIBRATION-FACTOR DRIFT" in txt
    assert md.startswith("# Campaign report")


# --------------------------------------------------- merge / report / CLI

def test_merge_tolerates_torn_journal(tmp_path, accept_result):
    res, journal = accept_result
    torn = tmp_path / "torn.ndjson"
    torn.write_text(journal.read_text() + '{"kind": "campaign_ru')
    merged = merge_journals([journal, torn])
    meta = merged[-1]["meta"]
    assert merged[-1]["kind"] == "campaign_merged"
    assert meta["n_runs"] == 72 and meta["n_summaries"] == 2
    # the monoid fold doubled the dispatch counters
    assert meta["dispatches"]["serve_sweeps"] == 4
    with pytest.raises(ValueError, match="line 38"):
        merge_journals([torn], strict=True)


def test_csv_has_one_row_per_run(tmp_path, accept_result):
    res, _ = accept_result
    path = tmp_path / "runs.csv"
    assert write_csv(res.records, path) == 36
    lines = path.read_text().splitlines()
    assert len(lines) == 37 and lines[0].startswith("campaign,run,cell")


def test_cli_run_merge_report_round_trip(tmp_path, capsys):
    spec = CampaignSpec.make("cli", workloads=["hpl"],
                             platforms=["tpu-v5e-pod"],
                             axes={"N": [1536]}, seeds=[0, 1])
    spec_path = tmp_path / "spec.json"
    spec_path.write_text(spec.to_json())
    j1 = tmp_path / "a.ndjson"
    assert campaign_main(["run", str(spec_path),
                          "--journal", str(j1)]) == 0
    out = capsys.readouterr().out
    assert "CAMPAIGN REPORT: cli" in out and "tpu-v5e-pod" in out

    merged = tmp_path / "merged.ndjson"
    assert campaign_main(["merge", str(j1), str(j1),
                          "--out", str(merged)]) == 0
    rep_json = tmp_path / "report.json"
    rep_csv = tmp_path / "runs.csv"
    rep_md = tmp_path / "report.md"
    assert campaign_main(["report", str(merged),
                          "--json", str(rep_json),
                          "--csv", str(rep_csv),
                          "--md", str(rep_md)]) == 0
    capsys.readouterr()
    report = json.loads(rep_json.read_text())
    assert report["n_runs"] == 4         # two journal copies merged
    assert rep_csv.read_text().count("\n") == 5
    assert rep_md.read_text().startswith("# Campaign report")


def test_cli_edition_study_reports_drift(tmp_path, capsys):
    j = tmp_path / "drift.ndjson"
    assert campaign_main(["run", "--edition-study", "2020_06", "2020_11",
                          "--limit", "6", "--max-ranks", "128",
                          "--journal", str(j)]) == 0
    out = capsys.readouterr().out
    assert "EDITION DRIFT: 2020_06 -> 2020_11" in out
    assert "CALIBRATION-FACTOR DRIFT" in out
    assert "fugaku" in out
    assert len([l for l in j.read_text().splitlines() if l]) == 13


def test_cli_run_without_spec_errors(capsys):
    assert campaign_main(["run"]) == 2
    assert "need a spec file" in capsys.readouterr().err


def test_spec_load_from_file(tmp_path):
    spec = accept_spec()
    p = tmp_path / "spec.json"
    p.write_text(spec.to_json())
    assert CampaignSpec.load(p) == spec
