"""Real sharded EXECUTION tests (not just lower/compile): run reduced
models on multi-device host meshes in subprocesses, including an elastic
checkpoint restore onto a different mesh shape."""
import os
import subprocess
import sys

import pytest

ENV = {**os.environ, "PYTHONPATH": "src"}


def _run(code, timeout=900):
    res = subprocess.run([sys.executable, "-c", code], capture_output=True,
                         text=True, timeout=timeout, env=ENV)
    assert res.returncode == 0, (res.stdout[-1500:], res.stderr[-3000:])
    return res.stdout


@pytest.mark.slow
def test_sharded_train_step_executes_on_8_devices():
    """tp-scheme reduced model trains on a (2, 4) mesh with the same
    rules/shardings the production dry-run uses; loss decreases."""
    out = _run(r"""
import os
os.environ['XLA_FLAGS'] = '--xla_force_host_platform_device_count=8'
import dataclasses
import jax, jax.numpy as jnp
from repro.configs import get_config, reduced
from repro.models import build_model
from repro.models.api import abstract_state
from repro.sharding.specs import make_rules, tree_shardings, use_rules
from repro.train.step import make_train_state, make_train_step, state_specs

cfg = dataclasses.replace(reduced(get_config('granite-34b')),
                          n_heads=8, n_kv_heads=1, head_dim=32, d_model=128,
                          d_ff=256, num_layers=2)
mesh = jax.make_mesh((2, 4), ('data', 'model'))
rules = make_rules(cfg, mode='train', tp_size=4, dp_size=2, global_batch=4)
model = build_model(cfg)
with mesh, use_rules(rules, mesh):
    step_fn, _ = make_train_step(cfg, lr=1e-3)
    state = make_train_state(cfg, jax.random.PRNGKey(0))
    sh = tree_shardings(state_specs(cfg, model), mesh, rules, state)
    state = jax.device_put(state, sh)
    batch = {'tokens': jax.random.randint(jax.random.PRNGKey(1), (4, 64),
                                          0, cfg.vocab_size)}
    step = jax.jit(step_fn, in_shardings=(sh, None), out_shardings=(sh, None),
                   donate_argnums=(0,))
    state, m1 = step(state, batch)
    state, m2 = step(state, batch)
l1, l2 = float(m1['loss']), float(m2['loss'])
assert l2 < l1, (l1, l2)
print('OK sharded train', l1, '->', l2)
""")
    assert "OK sharded train" in out


@pytest.mark.slow
def test_elastic_restore_across_mesh_shapes(tmp_path):
    """Save on a (4, 2) mesh, restore + continue on (2, 4) — the elastic
    resize path (checkpoint stores full logical arrays)."""
    ck = str(tmp_path / "ck")
    code_tpl = r"""
import os
os.environ['XLA_FLAGS'] = '--xla_force_host_platform_device_count=8'
import dataclasses
import jax, jax.numpy as jnp
from repro.checkpoint import save_checkpoint, restore_checkpoint, latest_step
from repro.configs import get_config, reduced
from repro.models import build_model
from repro.sharding.specs import make_rules, tree_shardings, use_rules
from repro.train.step import make_train_state, make_train_step, state_specs

MESH = %s
cfg = dataclasses.replace(reduced(get_config('stablelm-3b')),
                          n_heads=8, n_kv_heads=8, head_dim=16, d_model=128,
                          d_ff=256, num_layers=2)
mesh = jax.make_mesh(MESH, ('data', 'model'))
rules = make_rules(cfg, mode='train', tp_size=MESH[1], dp_size=MESH[0],
                   global_batch=4)
model = build_model(cfg)
with mesh, use_rules(rules, mesh):
    step_fn, _ = make_train_step(cfg, lr=1e-3)
    state = make_train_state(cfg, jax.random.PRNGKey(0))
    sh = tree_shardings(state_specs(cfg, model), mesh, rules, state)
    last = latest_step(%r)
    if last is not None:
        state = restore_checkpoint(%r, last, state, shardings=sh)
    else:
        state = jax.device_put(state, sh)
    batch = {'tokens': jax.random.randint(jax.random.PRNGKey(1), (4, 64),
                                          0, cfg.vocab_size)}
    step = jax.jit(step_fn, in_shardings=(sh, None), out_shardings=(sh, None))
    state, m = step(state, batch)
    save_checkpoint(%r, int(state.step), state)
print('OK phase loss', float(m['loss']), 'step', int(state.step))
"""
    out1 = _run(code_tpl % ((4, 2), ck, ck, ck))
    assert "step 1" in out1
    out2 = _run(code_tpl % ((2, 4), ck, ck, ck))   # resized mesh
    assert "step 2" in out2


def test_hpl_on_dragonfly_topology():
    """The paper's dragonfly support: HPL DES runs on a dragonfly with
    minimal routing and produces sane throughput."""
    from repro.core.apps.hpl import HPLConfig, HPLSim
    from repro.core.hardware.node import local_node
    from repro.core.hardware.topology import Dragonfly
    topo = Dragonfly(4, 4, 2, link_bw=100e9 / 8)   # 32 nodes
    cfg = HPLConfig(N=2048, nb=128, P=4, Q=4)
    res = HPLSim(cfg, local_node(), topo).run()
    agg = 16 * local_node().peak_flops / 1e9
    assert 0.005 * agg < res.gflops < agg


def test_hpl_bcast_long_variant():
    from repro.core.apps.hpl import HPLConfig, HPLSim
    from repro.core.hardware.node import local_node
    from repro.core.hardware.topology import FatTreeTwoLevel
    topo = FatTreeTwoLevel(16, 4, 2, link_bw=100e9 / 8)
    t = {}
    for variant in ("1ring", "long"):
        cfg = HPLConfig(N=2048, nb=128, P=2, Q=8, bcast=variant)
        t[variant] = HPLSim(cfg, local_node(), topo).run().time_s
    # both complete; scatter+allgather beats store&forward on wide rows
    assert t["long"] < t["1ring"] * 1.5
