"""Metrics & telemetry subsystem (repro.obs, DESIGN.md §18).

Four contracts under test:

  * instrument semantics — counters/gauges/histograms/timers, keying,
    deterministic snapshots, JSON round-trip, merge algebra;
  * exporters — Prometheus text held to the exposition grammar by the
    repo's own validator, NDJSON run manifests;
  * the observe-only guarantee — instrumented runs are bit-identical to
    uninstrumented ones on every layer (DES engine, fastsim, stepsim,
    the serving front ends, the fleet path);
  * serving telemetry — every hardening path (retries, deadline
    fallbacks, rank-guard trips, isolated errors, dispatch failures)
    increments its counter, and one mixed wave surfaces all of them in
    both the Prometheus text and the manifest line.
"""
import json

import pytest

from repro.obs import (COUNT_BUCKETS, NULL_METRICS, MetricsRegistry,
                       global_metrics, manifest_record, merge_snapshots,
                       read_manifest, validate_prometheus_text)
from repro.obs.metrics import flatten_key, parse_key

HPL_SMALL = dict(N=1536, nb=128, P=2, Q=2, lookahead=0)
TF_SMALL = {"mesh": (2, 4), "num_layers": 2}


# ------------------------------------------------------------ instruments

def test_counter_gauge_histogram_basics():
    m = MetricsRegistry()
    c = m.counter("c")
    c.inc()
    c.inc(2.5)
    assert c.value == 3.5
    with pytest.raises(ValueError, match=">= 0"):
        c.inc(-1)
    g = m.gauge("g")
    g.set(5)
    g.set(2)
    assert (g.value, g.max, g.min) == (2.0, 5.0, 2.0)
    h = m.histogram("h", buckets=(1.0, 10.0))
    for v in (0.5, 5.0, 50.0):
        h.observe(v)
    assert h.counts == [1, 1, 1] and h.count == 3
    assert h.sum == 55.5 and (h.min, h.max) == (0.5, 50.0)
    assert h.mean == pytest.approx(18.5)
    assert 0.0 < h.quantile(0.5) <= 10.0


def test_histogram_bad_bounds_raise():
    from repro.obs import Histogram
    with pytest.raises(ValueError, match="ascending"):
        Histogram(bounds=(2.0, 1.0))
    with pytest.raises(ValueError, match="ascending"):
        Histogram(bounds=(1.0, 1.0))


def test_instruments_are_cached_and_keyed_by_labels():
    m = MetricsRegistry()
    assert m.counter("x", a="1") is m.counter("x", a="1")
    assert m.counter("x", a="1") is not m.counter("x", a="2")
    assert m.counter("x") is not m.counter("x", a="1")


def test_timer_records_elapsed():
    m = MetricsRegistry()
    with m.timer("span") as t:
        pass
    assert t.elapsed is not None and t.elapsed >= 0.0
    assert m.histogram("span").count == 1


def test_key_flatten_parse_round_trip():
    key = flatten_key("serve.latency", (("kind", "hpl"), ("zone", "a")))
    assert key == 'serve.latency{kind="hpl",zone="a"}'
    assert parse_key(key) == ("serve.latency",
                              (("kind", "hpl"), ("zone", "a")))
    assert parse_key("bare") == ("bare", ())


# ------------------------------------------- snapshots, JSON, merge

def _sample_registry():
    m = MetricsRegistry()
    m.counter("c", kind="x").inc(3)
    m.gauge("g").set(7)
    m.gauge("g").set(2)
    h = m.histogram("h", buckets=(1.0, 10.0))
    h.observe(0.5)
    h.observe(5.0)
    return m


def test_snapshot_is_deterministic_and_round_trips():
    a, b = _sample_registry(), _sample_registry()
    assert a.to_json() == b.to_json()          # equal histories, equal bytes
    back = MetricsRegistry.from_json(a.to_json())
    assert back.to_json() == a.to_json()


def test_merge_semantics():
    a, b = _sample_registry(), _sample_registry()
    a.merge(b)
    snap = a.snapshot()
    assert snap["counters"]['c{kind="x"}'] == 6.0      # counters sum
    g = snap["gauges"]["g"]
    assert g["max"] == 7.0 and g["min"] == 2.0         # extremes merge
    h = snap["histograms"]["h"]
    assert h["counts"] == [2, 2, 0] and h["count"] == 4
    assert h["sum"] == 11.0


def test_merge_rejects_mismatched_bounds():
    a, b = MetricsRegistry(), MetricsRegistry()
    a.histogram("h", buckets=(1.0, 2.0)).observe(0.5)
    b.histogram("h", buckets=(1.0, 3.0)).observe(0.5)
    with pytest.raises(ValueError, match="bounds differ"):
        a.merge(b)


def test_merge_snapshots_commutes():
    a, b = _sample_registry().snapshot(), MetricsRegistry().snapshot()
    c = _sample_registry()
    c.counter("other").inc()
    c = c.snapshot()
    assert merge_snapshots(a, c) == merge_snapshots(c, a)
    assert merge_snapshots(a, b, c) == merge_snapshots(
        a, merge_snapshots(b, c))


def test_null_metrics_is_inert():
    n = NULL_METRICS
    assert not n.enabled
    n.counter("x").inc()
    n.gauge("x").set(1)
    n.histogram("x").observe(1.0)
    with n.timer("x"):
        pass
    assert n.snapshot() == {"counters": {}, "gauges": {},
                            "histograms": {}}
    assert n.to_prometheus() == ""


def test_global_metrics_hook_scopes_and_restores():
    from repro.obs import get_global_metrics
    assert get_global_metrics() is NULL_METRICS
    m = MetricsRegistry()
    with global_metrics(m):
        assert get_global_metrics() is m
    assert get_global_metrics() is NULL_METRICS


# ------------------------------------------------------------- exporters

def test_prometheus_export_passes_own_validator():
    m = _sample_registry()
    text = m.to_prometheus()
    samples = validate_prometheus_text(text)
    by_name = {}
    for name, labels, value in samples:
        by_name.setdefault(name, []).append((labels, value))
    assert by_name["c_total"] == [({"kind": "x"}, 3.0)]   # counter suffix
    assert ("g", [({}, 2.0)]) in by_name.items()
    assert by_name["g_peak"] == [({}, 7.0)]               # gauge peak
    les = [l["le"] for l, _ in by_name["h_bucket"]]
    assert les[-1] == "+Inf"                              # cumulative tail
    assert by_name["h_count"] == [({}, 2.0)]


def test_prometheus_validator_rejects_bad_text():
    with pytest.raises(ValueError, match="bad sample line"):
        validate_prometheus_text("9bad_name 1")
    with pytest.raises(ValueError, match="not cumulative"):
        validate_prometheus_text(
            'h_bucket{le="1"} 5\nh_bucket{le="+Inf"} 3\n')
    with pytest.raises(ValueError, match='le="\\+Inf"'):
        validate_prometheus_text('h_bucket{le="1"} 1\n')
    with pytest.raises(ValueError, match="!= _count"):
        validate_prometheus_text(
            'h_bucket{le="+Inf"} 3\nh_count 4\n')


def test_manifest_round_trip(tmp_path):
    from repro.obs import append_manifest
    m = _sample_registry()
    rec = manifest_record("bench", meta={"n": 3}, metrics=m)
    assert rec["manifest"] == 1 and rec["kind"] == "bench"
    assert rec["meta"] == {"n": 3}
    assert rec["metrics"] == m.snapshot()
    p = tmp_path / "runs.ndjson"
    l1 = append_manifest(p, "bench", meta={"n": 3}, metrics=m)
    l2 = append_manifest(p, "bench", meta={"n": 3},
                         metrics=_sample_registry())
    assert l1 == l2                       # equal runs, byte-equal lines
    recs = read_manifest(p)
    assert len(recs) == 2 and recs[0] == rec


# ------------------------------------------- bit-identity, layer by layer

def test_engine_metrics_do_not_perturb_hpl_des():
    from repro.core.apps.hpl import HPLConfig, HPLSim
    from repro.platforms import get_platform
    plat = get_platform("bdw-local")
    cfg = HPLConfig(**HPL_SMALL, bcast=plat.mpi.bcast)
    ref = HPLSim(cfg, plat).run()
    sim = HPLSim(cfg, plat)
    sim.engine.metrics = m = MetricsRegistry()
    res = sim.run()
    assert res.time_s == ref.time_s and res.events == ref.events
    snap = m.snapshot()
    assert snap["counters"]["engine.events"] == ref.events
    assert snap["counters"]["engine.runs"] == 1.0
    assert snap["gauges"]["engine.queue_depth_peak"]["max"] > 0
    assert snap["histograms"]["engine.events_per_s"]["count"] == 1


def test_engine_metrics_do_not_perturb_transformer_des():
    from repro.platforms import get_platform
    from repro.workloads import get_workload
    plat = get_platform("tpu-v5e-pod")
    wl = get_workload("transformer", **TF_SMALL)
    ref = wl.des_app(plat).run()
    app = wl.des_app(plat)
    app.engine.metrics = m = MetricsRegistry()
    res = app.run()
    assert res["step_s"] == ref["step_s"]
    assert res["events"] == ref["events"]
    assert m.snapshot()["counters"]["engine.events"] == ref["events"]


def test_engine_metrics_flush_on_deadline_path():
    from repro.core.apps.hpl import HPLConfig, HPLSim
    from repro.platforms import get_platform
    plat = get_platform("bdw-local")
    cfg = HPLConfig(**HPL_SMALL, bcast=plat.mpi.bcast)
    ref = HPLSim(cfg, plat).run()
    sim = HPLSim(cfg, plat)
    sim.engine.metrics = m = MetricsRegistry()
    sim.engine.set_wall_deadline(60.0)       # generous: runs to completion
    res = sim.run()
    assert res.time_s == ref.time_s and res.events == ref.events
    assert m.snapshot()["counters"]["engine.events"] == ref.events


def test_fastsim_sweep_metrics_observe_only():
    from repro.core.apps.hpl import HPLConfig
    from repro.core.fastsim import sweep_hpl
    from repro.platforms import get_platform
    plat = get_platform("frontera")
    # panel counts 14/15/16 share shape bucket 16: one batched group,
    # three live lanes padded to four
    cfgs = [HPLConfig(N=n, nb=128, P=2, Q=2, bcast=plat.mpi.bcast)
            for n in (1792, 1920, 2048)]
    prms = [plat.fastsim()] * len(cfgs)
    ref = sweep_hpl(cfgs, prms)
    m = MetricsRegistry()
    with global_metrics(m):
        res = sweep_hpl(cfgs, prms)
    assert [r["time_s"] for r in res] == [r["time_s"] for r in ref]
    c = m.snapshot()["counters"]
    hits = sum(v for k, v in c.items()
               if k.startswith("fastsim.compile_hits"))
    misses = sum(v for k, v in c.items()
                 if k.startswith("fastsim.compile_misses"))
    assert hits + misses >= 1            # the dispatch was recorded
    assert c["fastsim.lanes_live"] == 3.0
    assert c["fastsim.lanes_padded"] == 1.0           # padded to 4 lanes
    occ = m.snapshot()["histograms"]["fastsim.sweep_occupancy"]
    assert occ["count"] == 1 and occ["sum"] == pytest.approx(0.75)


def test_stepsim_sweep_metrics_observe_only():
    from repro.platforms import get_platform
    from repro.workloads import get_workload
    plat = get_platform("tpu-v5e-pod")
    wl = get_workload("transformer", **TF_SMALL)
    ref = wl.fastsim_model(plat).predict()
    m = MetricsRegistry()
    with global_metrics(m):
        res = wl.fastsim_model(plat).predict()
    assert res["step_s"] == ref["step_s"]
    c = m.snapshot()["counters"]
    assert (c.get('stepsim.compile_hits{bucket="step"}', 0)
            + c.get('stepsim.compile_misses{bucket="step"}', 0)) >= 1
    assert c["stepsim.lanes_live"] == 1.0


def test_serving_results_bit_identical_with_metrics_off():
    from repro.serve import PredictionService, WorkloadRequest

    def reqs():
        return [
            WorkloadRequest(rid=0, workload="hpl", platform="bdw-local",
                            params=dict(HPL_SMALL)),
            WorkloadRequest(rid=1, workload="transformer",
                            platform="tpu-v5e-pod",
                            params=dict(TF_SMALL)),
            WorkloadRequest(rid=2, workload="hpl", platform="bdw-local",
                            params=dict(HPL_SMALL), breakdown=True),
        ]

    on = PredictionService().predict_batch(reqs())
    off = PredictionService(metrics=NULL_METRICS).predict_batch(reqs())
    assert on == off


# ------------------------------------------------------ serving telemetry

def test_serve_wave_metrics_and_latency():
    from repro.serve import PredictionService, WorkloadRequest
    svc = PredictionService()
    svc.predict_batch([
        WorkloadRequest(rid=i, workload="hpl", platform="bdw-local",
                        params=dict(HPL_SMALL)) for i in range(3)])
    snap = svc.metrics.snapshot()
    c = snap["counters"]
    assert c["serve.requests"] == 3.0
    assert c["serve.scenarios"] == 3.0
    assert c["serve.batches"] == 1.0 and c["serve.sweeps"] == 1.0
    assert snap["gauges"]["serve.queue_depth"]["max"] == 3.0
    assert snap["gauges"]["serve.queue_depth"]["value"] == 0.0
    ws = snap["histograms"]["serve.wave_size"]
    assert ws["count"] == 1 and ws["sum"] == 3.0
    assert ws["bounds"] == list(COUNT_BUCKETS)
    assert snap["histograms"]["serve.request_latency_s"]["count"] == 3


def test_acceptance_wave_retry_fallback_isolation_all_visible():
    # ISSUE 8 acceptance: ONE wave exercising a retry, a deadline
    # fallback, and an isolated error yields nonzero counters for each,
    # visible in the Prometheus text AND the NDJSON manifest.
    from repro.serve import PredictionService, WorkloadRequest
    from repro.workloads import HPLFastModel

    svc = PredictionService(backoff_s=0.001)
    orig = HPLFastModel.sweep_models.__func__
    state = {"n": 0}

    def flaky(cls, models):
        state["n"] += 1
        if state["n"] == 1:
            raise RuntimeError("transient hiccup")
        return orig(cls, models)

    HPLFastModel.sweep_models = classmethod(flaky)
    try:
        out = svc.predict_batch(
            [WorkloadRequest(rid=0, workload="hpl", platform="bdw-local",
                             params=dict(HPL_SMALL)),
             WorkloadRequest(rid=1, workload="transformer",
                             platform="tpu-v5e-pod",
                             params=dict(TF_SMALL),
                             breakdown=True, timeout_s=1e-9),
             WorkloadRequest(rid=2, workload="hpl", platform="nope")],
            isolate_errors=True)
    finally:
        HPLFastModel.sweep_models = classmethod(orig)
    assert out[0]["status"] == "ok"
    assert out[1]["degraded"] and out[2]["status"] == "error"

    c = svc.metrics.snapshot()["counters"]
    for key in ("serve.retries", "serve.fallbacks",
                "serve.deadline_fallbacks", "serve.errors_isolated"):
        assert c[key] > 0, key

    samples = {name: value
               for name, labels, value in
               validate_prometheus_text(svc.prometheus())}
    assert samples["serve_retries_total"] > 0
    assert samples["serve_deadline_fallbacks_total"] > 0
    assert samples["serve_errors_isolated_total"] > 0

    rec = json.loads(svc.manifest())
    mc = rec["metrics"]["counters"]
    assert mc["serve.retries"] > 0
    assert mc["serve.deadline_fallbacks"] > 0
    assert mc["serve.errors_isolated"] > 0
    assert rec["meta"]["service"] == "PredictionService"
    assert rec["meta"]["stats"] == svc.stats


def test_rank_guard_trip_counter():
    from repro.serve import PredictionService, WorkloadRequest
    svc = PredictionService()
    out = svc.predict_batch([WorkloadRequest(
        rid=0, workload="transformer", platform="syn-torus-fugaku-4k",
        breakdown=True, timeout_s=60.0)])
    assert out[0]["degraded"]
    c = svc.metrics.snapshot()["counters"]
    assert c["serve.rank_guard_trips"] == 1.0
    assert c["serve.fallbacks"] == 1.0
    assert "serve.deadline_fallbacks" not in c


def test_dispatch_failure_stamps_wave_and_keeps_queue_clean():
    # Satellite 1: resolve-all-before-enqueue extended to dispatch time.
    # A sweep that fails after retries stamps EVERY request in the wave
    # with an error result, re-raises, and leaves the queue clean — the
    # service stays reusable.
    from repro.serve import PredictionService, WorkloadRequest
    from repro.workloads import HPLFastModel

    svc = PredictionService(retries=0)
    orig = HPLFastModel.sweep_models.__func__

    def broken(cls, models):
        raise RuntimeError("backend down")

    reqs = [WorkloadRequest(rid=0, workload="hpl", platform="bdw-local",
                            params=dict(HPL_SMALL)),
            WorkloadRequest(rid=1, workload="transformer",
                            platform="tpu-v5e-pod",
                            params=dict(TF_SMALL))]
    HPLFastModel.sweep_models = classmethod(broken)
    try:
        with pytest.raises(RuntimeError, match="backend down"):
            svc.predict_batch(reqs)
    finally:
        HPLFastModel.sweep_models = classmethod(orig)
    assert svc._queue == []
    for r in reqs:
        assert r.result["status"] == "error"
        assert r.result["error_type"] == "RuntimeError"
    c = svc.metrics.snapshot()["counters"]
    assert c["serve.dispatch_failures"] == 1.0
    # the service serves the next wave normally
    out = svc.predict_batch([WorkloadRequest(
        rid=9, workload="hpl", platform="bdw-local",
        params=dict(HPL_SMALL))])
    assert out[9]["time_s"] > 0


def test_hpl_service_metric_parity():
    # Satellite 2: the back-compat HPL endpoint reports through the
    # same metric names, so equivalent traffic gives equal counters.
    from repro.serve import (HPLPredictionService, PredictRequest,
                             PredictionService, WorkloadRequest)
    names = ["frontera", "bdw-local"]
    svc_g, svc_h = PredictionService(), HPLPredictionService()
    svc_g.predict_batch([
        WorkloadRequest(rid=i, workload="hpl", platform=n)
        for i, n in enumerate(names)])
    svc_h.predict_batch([
        PredictRequest(rid=i, platform=n) for i, n in enumerate(names)])
    cg = svc_g.metrics.snapshot()["counters"]
    ch = svc_h.metrics.snapshot()["counters"]
    for key in ("serve.requests", "serve.batches", "serve.scenarios",
                "serve.sweeps"):
        assert cg[key] == ch[key], key
    hg = svc_g.metrics.snapshot()["histograms"]
    hh = svc_h.metrics.snapshot()["histograms"]
    assert hg["serve.request_latency_s"]["count"] == 2
    assert hh["serve.request_latency_s"]["count"] == 2
    assert hg["serve.wave_size"]["sum"] == hh["serve.wave_size"]["sum"]


def test_service_registries_merge_across_replicas():
    from repro.serve import PredictionService, WorkloadRequest
    svcs = [PredictionService() for _ in range(2)]
    for i, svc in enumerate(svcs):
        svc.predict_batch([WorkloadRequest(
            rid=i, workload="hpl", platform="bdw-local",
            params=dict(HPL_SMALL))])
    fleet = MetricsRegistry()
    for svc in svcs:
        fleet.merge(svc.metrics)
    assert fleet.snapshot()["counters"]["serve.requests"] == 2.0


# ------------------------------------------------------- fleet telemetry

def test_fleet_metrics_and_run_manifest(tmp_path):
    from repro.platforms import get_platform
    from repro.top500 import FleetTuning, predict_fleet
    plats = [get_platform("bdw-local"), get_platform("frontera")]
    tuning = FleetTuning(max_ranks=64)
    ref = predict_fleet(plats, tuning=tuning)
    m = MetricsRegistry()
    report = predict_fleet(plats, tuning=tuning, metrics=m)
    for e1, e2 in zip(ref.entries, report.entries):
        assert e1.predicted_tflops == e2.predicted_tflops   # observe-only
    snap = m.snapshot()
    c = snap["counters"]
    assert c["fleet.machines"] == 2.0
    phases = {parse_key(k)[1][0][1]
              for k in snap["histograms"] if k.startswith("fleet.phase")}
    assert phases == {"tune", "sweep", "calibrate"}
    assert any(k.startswith("fleet.calibration_factor")
               for k in snap["gauges"])

    p = tmp_path / "fleet.ndjson"
    report.run_manifest(p, campaign="unit")
    rec = read_manifest(p)[0]
    assert rec["kind"] == "fleet_run"
    assert rec["meta"]["machines"] == 2
    assert rec["meta"]["campaign"] == "unit"
    assert rec["metrics"]["counters"]["fleet.machines"] == 2.0
    # uninstrumented report still emits a (metrics-free) manifest line
    rec2 = json.loads(ref.run_manifest())
    assert rec2["meta"]["machines"] == 2 and "metrics" not in rec2


def test_predict_top500_counts_rows(tmp_path):
    from repro.serve import predict_top500
    from repro.top500 import FleetTuning
    csv = tmp_path / "list.csv"
    csv.write_text(
        "Rank,Processor,Total Cores,Interconnect,Rmax,Rpeak\n"
        "1,Xeon Gold 6148 20C 2.4GHz,40000,EDR,500,768\n"
        "2,Xeon Gold 6148 20C 2.4GHz,bogus,EDR,500,768\n",
        encoding="utf-8")
    m = MetricsRegistry()
    report = predict_top500(str(csv), tuning=FleetTuning(max_ranks=64),
                            calibrate=False, metrics=m)
    c = m.snapshot()["counters"]
    assert c["fleet.rows_parsed"] == 1.0
    assert c["fleet.rows_skipped"] == 1.0
    assert len(report.entries) == 1


# ------------------- manifest read hardening (campaign satellite)

def _torn_journal(tmp_path):
    """Two good lines, a blank, a non-object, and a torn tail — the
    shape a killed campaign run leaves behind."""
    from repro.obs.export import manifest_line
    path = tmp_path / "torn.ndjson"
    path.write_text(manifest_line("run", meta={"i": 0}) + "\n"
                    "\n"
                    + manifest_line("run", meta={"i": 1}) + "\n"
                    '["not", "an", "object"]\n'
                    '{"kind": "run", "meta": {"i": 2')
    return path


def test_read_manifest_lenient_skips_with_count(tmp_path):
    from repro.obs import read_manifest_report
    report = read_manifest_report(_torn_journal(tmp_path))
    assert [r["meta"]["i"] for r in report.records] == [0, 1]
    assert len(report) == 2 and list(report) == report.records
    # blank lines are never an error; the two corrupt lines are
    # counted with their 1-based line numbers and a reason each
    assert [lineno for lineno, _ in report.skipped] == [4, 5]
    assert "expected a JSON object" in report.skipped[0][1]


def test_read_manifest_lenient_list_form_unchanged(tmp_path):
    recs = read_manifest(_torn_journal(tmp_path))
    assert isinstance(recs, list) and len(recs) == 2


def test_read_manifest_strict_raises_with_location(tmp_path):
    path = _torn_journal(tmp_path)
    with pytest.raises(ValueError, match=r"line 4: expected a JSON "
                                         r"object, got list"):
        read_manifest(path, strict=True)
    from repro.obs.export import manifest_line
    clean = tmp_path / "clean.ndjson"
    clean.write_text(manifest_line("run", meta={"i": 0}) + "\n"
                     + manifest_line("run", meta={"i": 1}) + "\n")
    assert len(read_manifest(clean, strict=True)) == 2


def test_read_manifest_empty_and_blank_files(tmp_path):
    empty = tmp_path / "empty.ndjson"
    empty.write_text("")
    blank = tmp_path / "blank.ndjson"
    blank.write_text("\n\n\n")
    for p in (empty, blank):
        assert read_manifest(p, strict=True) == []
