"""Serving-throughput layer (repro.serve.cache + predict, DESIGN.md §20).

Contracts under test:

  * content addressing — the key is a digest of the resolved scenario
    tuple; any field change in spec/platform/faults/regions/breakdown
    misses; equal scenarios in different notations collide;
  * hit/miss bit-identity — a cache hit is byte-identical to the miss
    that populated it, modulo the ``cached=True`` provenance stamp;
  * LRU bounds — eviction is oldest-first and hits refresh recency;
  * invalidation — re-registering (or unregistering) a platform name
    drops every entry derived from it;
  * coalescing — duplicate in-flight keys dispatch exactly once (one
    sweep, one live lane) and fan identical results back out;
  * error hygiene — failed dispatches and degraded answers are never
    inserted into the cache;
  * warm pool — ``svc.warm`` precompiles the sweep buckets so the first
    real wave pays zero compiles (asserted via the §18 trace counters);
  * sharding — the single-device fallback is bitwise-identical to the
    unsharded path, and a forced multi-device run agrees bitwise too.
"""
import dataclasses
import os
import subprocess
import sys
import textwrap

import pytest

from repro.platforms import get_platform, register, unregister
from repro.serve import (PredictionService, ResultCache, WorkloadRequest,
                         as_result_cache, request_key)
from repro.serve.cache import platform_digest, spec_digest


def _req(rid, **kw):
    kw.setdefault("workload", "hpl")
    kw.setdefault("platform", "frontera")
    kw.setdefault("params", {"N": 1536})
    return WorkloadRequest(rid=rid, **kw)


# -------------------------------------------------------- key semantics

def test_key_is_content_addressed_and_fully_sensitive():
    from repro.faults import FaultSpec
    from repro.scale import RegionSpec
    from repro.workloads import get_workload
    wl = get_workload("hpl", N=2048).spec
    plat = get_platform("frontera")
    base = request_key(wl, plat)
    # equal scenario -> equal key, regardless of how it was spelled
    assert request_key(get_workload("hpl", N=2048).spec, plat) == base
    # any field change anywhere misses
    assert request_key(get_workload("hpl", N=2049).spec, plat) != base
    assert request_key(wl, dataclasses.replace(plat, name="other")) != base
    assert request_key(wl, plat,
                       faults=FaultSpec.straggler(rank=0)) != base
    assert request_key(wl, plat, regions=12) != base
    assert request_key(wl, plat, breakdown=True) != base
    # notation-independence: int regions == the equivalent RegionSpec,
    # and a fault dict == the FaultSpec it normalizes to
    assert request_key(wl, plat, regions=12) == \
        request_key(wl, plat, regions=RegionSpec(panels=12, warmup=2))
    f = FaultSpec.straggler(rank=1, slowdown=2.0)
    import json
    assert request_key(wl, plat, faults=f) == \
        request_key(wl, plat, faults=json.loads(f.to_json()))


def test_digests_are_stable_across_equal_instances():
    plat = get_platform("frontera")
    assert platform_digest(plat) == platform_digest(
        dataclasses.replace(plat))
    from repro.workloads import get_workload
    assert spec_digest(get_workload("hpl", N=4096).spec) == \
        spec_digest(get_workload("hpl", N=4096).spec)


def test_as_result_cache_normalization():
    assert as_result_cache(None) is None
    assert as_result_cache(False) is None
    assert isinstance(as_result_cache(True), ResultCache)
    assert as_result_cache(7).max_entries == 7
    rc = ResultCache()
    assert as_result_cache(rc) is rc
    with pytest.raises(TypeError):
        as_result_cache("big")
    with pytest.raises(ValueError):
        ResultCache(max_entries=0)


# ------------------------------------------------------- hit/miss paths

def test_hit_is_bit_identical_to_miss_modulo_stamp():
    svc = PredictionService(cache=True)
    miss = svc.predict_batch([_req(0)])[0]
    hit = svc.predict_batch([_req(1)])[1]
    assert hit.pop("cached") is True
    assert "cached" not in miss
    assert hit == miss
    assert svc.stats["cache_hits"] == 1 and svc.stats["cache_misses"] == 1


def test_breakdown_hits_skip_the_des_but_carry_the_breakdown():
    svc = PredictionService(cache=True)
    miss = svc.predict_batch(
        [_req(0, platform="bdw-local", breakdown=True)])[0]
    assert "breakdown" in miss and svc.stats["des_breakdowns"] == 1
    hit = svc.predict_batch(
        [_req(1, platform="bdw-local", breakdown=True)])[1]
    assert hit["breakdown"] == miss["breakdown"]
    assert svc.stats["des_breakdowns"] == 1      # DES ran exactly once


def test_hit_payload_mutation_does_not_poison_the_cache():
    svc = PredictionService(cache=True)
    svc.predict_batch([_req(0)])
    first = svc.predict_batch([_req(1)])[1]
    first["time_s"] = -1.0
    again = svc.predict_batch([_req(2)])[2]
    assert again["time_s"] != -1.0


def test_lru_eviction_is_oldest_first_and_hits_refresh():
    rc = ResultCache(max_entries=2)
    rc.put("a", {"v": 1})
    rc.put("b", {"v": 2})
    assert rc.keys() == ["a", "b"]
    assert rc.get("a") == {"v": 1}       # refreshes "a"
    rc.put("c", {"v": 3})                # evicts "b", the LRU entry
    assert rc.keys() == ["a", "c"]
    assert rc.get("b") is None
    assert rc.stats()["evictions"] == 1


def test_service_cache_respects_max_entries():
    svc = PredictionService(cache=1)
    svc.predict_batch([_req(0, params={"N": 1536})])
    svc.predict_batch([_req(1, params={"N": 1920})])
    assert len(svc.cache) == 1
    # the first scenario was evicted: asking again is a miss
    svc.predict_batch([_req(2, params={"N": 1536})])
    assert svc.stats["cache_hits"] == 0


# --------------------------------------------------------- invalidation

def test_platform_reregistration_invalidates_by_name():
    plat = dataclasses.replace(get_platform("frontera"),
                               name="cachetest-inval")
    register(plat)
    try:
        svc = PredictionService(cache=True)
        svc.predict_batch([_req(0, platform="cachetest-inval")])
        assert len(svc.cache) == 1
        register(plat, overwrite=True)           # re-registration event
        assert len(svc.cache) == 0
        assert svc.cache.stats()["invalidations"] == 1
        # entries from other platforms survive
        svc.predict_batch([_req(1, platform="frontera"),
                           _req(2, platform="cachetest-inval")])
        assert len(svc.cache) == 2
        unregister(["cachetest-inval"])          # unregister drops too
        assert len(svc.cache) == 1
    finally:
        unregister(["cachetest-inval"])


# ----------------------------------------------------------- coalescing

def test_duplicate_in_flight_keys_dispatch_exactly_once():
    from repro.obs import global_metrics
    svc = PredictionService(cache=True)
    with global_metrics(svc.metrics):     # route fastsim counters here
        out = svc.predict_batch([_req(i) for i in range(8)])
    assert svc.stats["sweeps"] == 1 and svc.stats["coalesced"] == 7
    snap = svc.metrics.snapshot()["counters"]
    # ONE live lane went through the sweep engine for all 8 requests
    assert snap.get("fastsim.lanes_live") == 1
    assert len({repr(sorted(r.items())) for r in out.values()}) == 1


def test_coalescing_preserves_per_request_results_on_mixed_waves():
    svc = PredictionService(cache=True)
    reqs = [_req(0, params={"N": 1536}), _req(1, params={"N": 1920}),
            _req(2, params={"N": 1536}), _req(3, params={"N": 1920})]
    out = svc.predict_batch(reqs)
    assert out[0] == out[2] and out[1] == out[3]
    assert out[0]["time_s"] != out[1]["time_s"]
    assert svc.stats["sweeps"] == 1 and svc.stats["coalesced"] == 2


# -------------------------------------------------------- error hygiene

def test_dispatch_failure_caches_nothing_and_stamps_unserved(monkeypatch):
    svc = PredictionService(cache=True, retries=0)
    svc.predict_batch([_req(0)])                  # one good cached entry
    boom = RuntimeError("backend down")

    def explode(self, model_cls, reqs):
        raise boom
    monkeypatch.setattr(PredictionService, "_dispatch", explode)
    hit_req = _req(1)                             # served from cache
    fail_req = _req(2, params={"N": 1920})        # needs a dispatch
    svc.submit(hit_req)
    svc.submit(fail_req)
    with pytest.raises(RuntimeError):
        svc.flush()
    assert hit_req.result.get("cached") is True   # hit kept its answer
    assert fail_req.result["status"] == "error"
    assert len(svc.cache) == 1                    # nothing new was cached
    monkeypatch.undo()
    # the failed scenario is a miss (never cached), and recomputes fine
    out = svc.predict_batch([_req(3, params={"N": 1920})])
    assert "cached" not in out[3]


def test_budgeted_and_degraded_requests_are_never_cached():
    svc = PredictionService(cache=True, max_des_ranks=1)
    # rank-guard degrade (timeout_s set, DES over the cap)
    out = svc.predict_batch([_req(0, breakdown=True, timeout_s=60.0)])[0]
    assert out["degraded"] is True
    assert len(svc.cache) == 0
    # plain budgeted request: uncacheable even when it succeeds
    out = svc.predict_batch([_req(1, timeout_s=60.0)])[1]
    assert "cached" not in out and len(svc.cache) == 0
    assert svc.stats["cache_hits"] == 0 == svc.stats["cache_misses"]


def test_isolated_resolution_errors_never_touch_the_cache():
    svc = PredictionService(cache=True)
    out = svc.predict_batch(
        [_req(0), WorkloadRequest(rid=1, workload="hpl",
                                  platform="no-such-machine")],
        isolate_errors=True)
    assert out[1]["status"] == "error"
    assert len(svc.cache) == 1                    # only the good result


# ------------------------------------------------------------- warm pool

def test_warm_pool_first_wave_pays_zero_compiles():
    from repro.core import fastsim
    from repro.workloads import stepsim
    fastsim._compiled.cache_clear()               # cold process state
    stepsim._compiled.cache_clear()
    svc = PredictionService()
    report = svc.warm(["hpl", "transformer"],
                      ["tpu-v5e-pod"], count=4)
    assert report["compiles"] > 0 and report["dispatches"] == 2
    # an identical second warm is fully warm already
    assert svc.warm(["hpl", "transformer"], ["tpu-v5e-pod"],
                    count=4)["compiles"] == 0
    # a real wave with the SAME per-family lane count the warm used
    # (the jit cache is keyed on the padded batch shape)
    pre = fastsim.trace_count() + stepsim.trace_count()
    reqs = [WorkloadRequest(rid=i, workload=w, platform="tpu-v5e-pod")
            for i, w in enumerate(["hpl", "transformer"] * 4)]
    out = svc.predict_batch(reqs)
    assert len(out) == 8
    assert fastsim.trace_count() + stepsim.trace_count() == pre
    snap = svc.metrics.snapshot()["counters"]
    assert snap.get("serve.warm_compiles", 0) == report["compiles"]
    assert snap.get("serve.warm_dispatches") == 4


def test_warm_can_prime_the_result_cache():
    svc = PredictionService(cache=True)
    svc.warm(["hpl"], ["frontera"], count=2, prime_cache=True)
    out = svc.predict_batch([_req(0, params={})])
    assert out[0]["cached"] is True
    assert svc.stats["cache_misses"] == 0


# -------------------------------------------------------------- sharding

def test_shard_single_device_is_bitwise_identical():
    base = PredictionService().predict_batch(
        [_req(i, params={"N": 1536 + 384 * i}) for i in range(3)])
    shard = PredictionService(shard=True).predict_batch(
        [_req(i, params={"N": 1536 + 384 * i}) for i in range(3)])
    assert shard == base                          # exact, not approx


def test_shard_lanes_fallback_is_identity():
    import numpy as np
    from repro.core.fastsim import _shard_lanes, lane_sharding
    x = np.arange(8.0)
    trees, sharded = _shard_lanes(8, x)           # sharding off
    assert trees[0] is x and not sharded
    with lane_sharding(True):                     # on, but 1 device
        trees, sharded = _shard_lanes(8, x)
        assert not sharded


def test_forced_multi_device_shard_is_bitwise_identical():
    script = textwrap.dedent("""
        import jax
        assert jax.device_count() == 4, jax.device_count()
        from repro.serve import PredictionService, WorkloadRequest
        def reqs():
            return [WorkloadRequest(rid=i, workload="hpl",
                                    platform="frontera",
                                    params={"N": 1536 + 384 * i})
                    for i in range(4)]
        from repro.obs import global_metrics
        base = PredictionService().predict_batch(reqs())
        svc = PredictionService(shard=True)
        with global_metrics(svc.metrics):
            shard = svc.predict_batch(reqs())
        assert shard == base, (shard, base)
        c = svc.metrics.snapshot()["counters"]
        assert c.get("fastsim.sharded_dispatches", 0) >= 1, c
        print("OK")
    """)
    env = dict(os.environ,
               XLA_FLAGS="--xla_force_host_platform_device_count=4",
               PYTHONPATH="src")
    proc = subprocess.run([sys.executable, "-c", script], env=env,
                          cwd=os.path.dirname(os.path.dirname(
                              os.path.abspath(__file__))),
                          capture_output=True, text=True, timeout=600)
    assert proc.returncode == 0, proc.stderr[-2000:]
    assert "OK" in proc.stdout


def test_resolution_memo_skips_unhashable_params():
    # list-valued params (e.g. transformer mesh=[4, 8]) build a tuple
    # fine but fail at hash time — the memo must fall back to a fresh
    # resolve, not raise
    from repro.serve import PredictionService, WorkloadRequest
    svc = PredictionService()
    req = WorkloadRequest(rid=0, workload="transformer",
                          platform="tpu-v5e-pod",
                          params={"mesh": [4, 8], "num_layers": 8})
    assert svc._memo_key(req) is None
    out = svc.predict_batch([req])
    assert out[0].get("status") != "error" and "step_s" in out[0]
    assert not svc._resolve_memo
