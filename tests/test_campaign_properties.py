"""Hypothesis property tests for the campaign layer: any constructible
``CampaignSpec`` — arbitrary workload params, selector mixes, axis
grids, fault scenarios, seeds, budgets — round-trips through JSON
exactly (``from_json(to_json(s)) == s``), the serialization contract
the journal's spec echo and ``CampaignSpec.load`` depend on."""
import json

import pytest

pytest.importorskip("hypothesis")

import hypothesis.strategies as st
from hypothesis import given, settings

from repro.campaign import Budget, CampaignSpec, PlatformSelector
from repro.faults import FaultSpec
from repro.workloads import WorkloadSpec

SETTINGS = settings(max_examples=60, deadline=None)

names = st.text(alphabet="abcdefghijklmnopqrstuvwxyz0123456789-_",
                min_size=1, max_size=16)
#: JSON-stable scalars (finite floats survive dumps/loads exactly)
scalars = st.one_of(st.integers(-2**31, 2**31),
                    st.floats(allow_nan=False, allow_infinity=False,
                              width=32),
                    names)


@st.composite
def workload_specs(draw):
    kind = draw(st.sampled_from(("hpl", "transformer")))
    params = draw(st.dictionaries(names, scalars, max_size=4))
    return WorkloadSpec(kind=kind, name=draw(names) if draw(st.booleans())
                        else "", params=tuple(sorted(params.items())))


@st.composite
def selectors(draw):
    if draw(st.booleans()):
        return PlatformSelector(registry=draw(names))
    return PlatformSelector(
        top500=draw(st.sampled_from(("sample:2020_06", "sample:2020_11",
                                     "/data/fleet.csv"))),
        edition=draw(names) if draw(st.booleans()) else "",
        limit=draw(st.integers(0, 500)))


@st.composite
def fault_specs(draw):
    if draw(st.booleans()):
        return None
    return FaultSpec.straggler(rank=draw(st.integers(0, 4095)),
                               slowdown=draw(st.floats(
                                   1.01, 32, allow_nan=False)),
                               seed=draw(st.integers(0, 2**31)))


@st.composite
def campaign_specs(draw):
    axes = draw(st.dictionaries(
        names, st.lists(scalars, min_size=1, max_size=4, unique=True),
        max_size=3))
    return CampaignSpec.make(
        draw(names),
        workloads=draw(st.lists(workload_specs(), max_size=3)),
        platforms=draw(st.lists(selectors(), min_size=1, max_size=3)),
        axes=axes,
        faults=draw(st.lists(fault_specs(), min_size=1, max_size=3)),
        seeds=draw(st.lists(st.integers(0, 2**31), min_size=1,
                            max_size=4, unique=True)),
        max_runs=draw(st.integers(1, 10**6)))


@SETTINGS
@given(campaign_specs())
def test_spec_round_trips_through_json(spec):
    assert CampaignSpec.from_json(spec.to_json()) == spec


@SETTINGS
@given(campaign_specs())
def test_spec_dict_form_is_json_safe_and_exact(spec):
    d = spec.to_dict()
    back = CampaignSpec.from_dict(json.loads(json.dumps(d)))
    assert back == spec and hash(back) == hash(spec)
    assert back.to_json() == spec.to_json()


@SETTINGS
@given(campaign_specs())
def test_spec_is_frozen_and_hashable(spec):
    with pytest.raises(Exception):
        spec.name = "other"
    assert isinstance(hash(spec), int)
    assert Budget(max_runs=spec.budget.max_runs) == spec.budget
