"""DES engine + stream-level network unit tests."""
import math

import pytest

from repro.core.engine import Engine
from repro.core.hardware.network import Network, Link
from repro.core.hardware.topology import (FatTreeTwoLevel, Dragonfly, Torus,
                                          MultiPod)


def test_engine_wait_ordering():
    eng = Engine()
    log = []

    def proc(name, waits):
        for w in waits:
            yield w
            log.append((name, eng.now))
    eng.spawn(proc("a", [1.0, 2.0]))
    eng.spawn(proc("b", [1.5]))
    eng.run_all()
    assert log == [("a", 1.0), ("b", 1.5), ("a", 3.0)]


def test_engine_events_and_join():
    eng = Engine()
    ev = eng.event()
    out = []

    def waiter():
        payload = yield ev
        out.append((eng.now, payload))

    def setter():
        yield 2.5
        ev.set("hello")
    w = eng.spawn(waiter())

    def joiner():
        yield w
        out.append(("joined", eng.now))
    eng.spawn(setter())
    eng.spawn(joiner())
    eng.run_all()
    assert out == [(2.5, "hello"), ("joined", 2.5)]


def test_network_single_flow_rate():
    eng = Engine()

    class T:
        base_latency = 1e-6
        l = Link(1e9)
        def route(self, s, d):
            return [self.l]
    net = Network(eng, T())
    net.send(0, 1, 1e9)
    eng.run_all()
    assert abs(eng.now - (1.0 + 1e-6)) < 1e-3


def test_network_fair_sharing_two_flows():
    eng = Engine()

    class T:
        base_latency = 0.0
        l = Link(1e9)
        def route(self, s, d):
            return [self.l]
    net = Network(eng, T())
    net.send(0, 1, 1e9)
    net.send(2, 3, 1e9)
    eng.run_all()
    # both share 0.5 GB/s -> both finish at 2.0 s
    assert abs(eng.now - 2.0) < 1e-3


def test_deterministic_event_ordering_under_contention():
    """Regression: heap ties break by insertion seq and flow sets iterate
    in insertion order (they were id()-ordered Python sets, which made
    same-timestamp completions — and traces — vary run-to-run).  Two
    fresh identical contended runs must log identical sequences."""
    def run_once():
        eng = Engine()

        class T:
            base_latency = 0.0
            shared = Link(1e9)
            def route(self, s, d):
                return [self.shared]
        net = Network(eng, T())
        log = []
        # 8 equal flows: all complete at the same instant -> pure tie
        for i in range(8):
            ev = net.send(i, 100 + i, 1e8)

            def watch(name, ev=ev):
                yield ev
                log.append((name, eng.now))
            eng.spawn(watch(i))
        eng.run_all()
        return log, eng.now

    log_a, t_a = run_once()
    log_b, t_b = run_once()
    assert t_a == t_b
    assert log_a == log_b                       # same order, same times
    assert sorted(n for n, _ in log_a) == list(range(8))
    assert all(t == t_a for _, t in log_a)      # genuinely tied


def test_network_components_are_independent():
    eng = Engine()

    class T:
        base_latency = 0.0
        l1, l2 = Link(1e9), Link(2e9)
        def route(self, s, d):
            return [self.l1] if s == 0 else [self.l2]
    net = Network(eng, T())
    d1 = net.send(0, 1, 1e9)
    d2 = net.send(2, 3, 1e9)
    times = {}

    def watch(name, ev):
        yield ev
        times[name] = eng.now
    eng.spawn(watch("f1", d1))
    eng.spawn(watch("f2", d2))
    eng.run_all()
    assert abs(times["f1"] - 1.0) < 1e-3
    assert abs(times["f2"] - 0.5) < 1e-3


# --------------------------------------------------------------- topology
def test_fat_tree_dmodk_routes():
    t = FatTreeTwoLevel(64, 8, 4, link_bw=1e9)
    # same edge: 2 hops
    assert len(t.route(0, 1)) == 2
    # cross edge: 4 hops through core dst % 4
    path = t.route(0, 13)
    assert len(path) == 4
    assert path[1] is t.edge_up[0][13 % 4]
    assert t.route(5, 5) == []


def test_fat_tree_no_routing_tables():
    """Dynamic routing: memory footprint is O(nodes), not O(nodes^2)."""
    t = FatTreeTwoLevel(10008, 18, 18, link_bw=1e9)
    n_links = (len(t.node_up) + len(t.node_down)
               + sum(len(r) for r in t.edge_up)
               + sum(len(r) for r in t.edge_down))
    assert n_links < 3 * 10008 + 2 * 556 * 18 + 10


def test_dragonfly_routes():
    t = Dragonfly(4, 4, 2, link_bw=1e9)
    # same router
    assert len(t.route(0, 1)) == 2
    # same group, different router
    assert len(t.route(0, 3)) == 3
    # cross group: up, (local), global, (local), down
    path = t.route(0, t.p * t.a * 2 + 3)
    assert 3 <= len(path) <= 5


def test_torus_routes_shortest_wrap():
    t = Torus((4, 4), link_bw=1e9)
    # neighbor: 1 link
    assert len(t.route(0, 1)) == 1
    # wraparound shorter: 0 -> 3 in a ring of 4 is 1 hop backwards
    assert len(t.route(0, 3)) == 1
    assert len(t.route(0, 5)) == 2   # diagonal: 1+1


def test_multipod_routes_cross_dcn():
    pods = [Torus((2, 2), link_bw=1e9) for _ in range(2)]
    t = MultiPod(pods, 4)
    path = t.route(1, 6)
    assert any(l in t.dcn_up for l in path)
