"""Trace subsystem: recorder invariants, Chrome export schema, critical
path, determinism, and the zero-perturbation overhead contract."""
import json
import math

import pytest

from repro.core.apps.hpl import HPLConfig, HPLSim
from repro.core.apps.transformer import (LayerWork, StepWorkload,
                                         TransformerStepSim)
from repro.core.engine import Engine
from repro.core.hardware.node import local_node
from repro.core.hardware.topology import FatTreeTwoLevel
from repro.trace import (NULL_RECORDER, REQUIRED_KEYS, critical_path,
                         rank_breakdown, validate_chrome_events)

REL = 1e-9      # float tolerance for interval-sum identities


def _traced_hpl(N=1024, nb=128, P=2, Q=4, **kw):
    node = local_node()
    topo = FatTreeTwoLevel(max(P * Q, 16), 4, 2, link_bw=100e9 / 8)
    cfg = HPLConfig(N=N, nb=nb, P=P, Q=Q, **kw)
    sim = HPLSim(cfg, node, topo, trace=True)
    return sim, sim.run()


# ------------------------------------------------------------ contract
def test_trace_off_is_null_recorder_and_bit_identical():
    """trace=False costs nothing and trace=True perturbs nothing: both
    runs produce the exact same simulated time and event count."""
    node = local_node()
    cfg = HPLConfig(N=1024, nb=128, P=2, Q=4)

    def run(trace):
        topo = FatTreeTwoLevel(16, 4, 2, link_bw=100e9 / 8)
        return HPLSim(cfg, node, topo, trace=trace)

    off = run(False)
    assert off.trace is NULL_RECORDER
    assert not off.trace.enabled
    r_off = off.run()
    assert r_off.trace is None
    r_on = run(True).run()
    assert r_on.time_s == r_off.time_s          # bit-identical
    assert r_on.events == r_off.events
    assert r_on.trace is not None and r_on.trace.enabled


def test_traced_runs_are_deterministic():
    """Regression (same-timestamp tie-breaking + ordered flow sets): two
    fresh identical runs produce identical traces and results."""
    sims = []
    for _ in range(2):
        sim, res = _traced_hpl()
        sims.append((res, [(s.rank, s.cat, s.name, s.t0, s.t1)
                           for s in sim.trace.spans]))
    (res_a, spans_a), (res_b, spans_b) = sims
    assert res_a.time_s == res_b.time_s
    assert res_a.events == res_b.events
    assert spans_a == spans_b


# ----------------------------------------------------------- breakdown
def test_rank_breakdown_sums_to_makespan():
    sim, res = _traced_hpl()
    bd = rank_breakdown(sim.trace)
    assert set(bd) == set(range(sim.cfg.n_ranks))
    for r, acc in bd.items():
        assert acc["total"] == res.time_s
        assert acc["compute"] >= 0 and acc["comm"] >= 0
        assert acc["idle"] >= -REL * res.time_s, (r, acc)
        s = acc["compute"] + acc["comm"] + acc["idle"]
        assert s == pytest.approx(res.time_s, rel=REL), (r, acc)


def test_phase_and_collective_attribution():
    sim, res = _traced_hpl()
    s = sim.trace.summary()
    assert {"panel_fact", "panel_bcast", "row_swap",
            "trailing_update"} <= set(s["phases"])
    assert all(v > 0 for v in s["phases"].values())
    assert "barrier" in s["collectives"]          # pivot-sync collective
    ncalls = s["collectives"]["barrier"]["calls"]
    assert ncalls == sim.cfg.n_panels * sim.cfg.P  # one per panel per col rank


# ------------------------------------------------------- critical path
def test_critical_path_le_makespan_hpl():
    sim, res = _traced_hpl()
    cp = critical_path(sim.trace)
    assert cp.length_s <= res.time_s * (1 + REL)
    assert cp.length_s > 0.5 * res.time_s          # explains most of the run
    assert cp.spans[0].t0 <= cp.spans[-1].t0       # ordered start -> finish


def test_critical_path_equals_makespan_for_serial_chain():
    eng = Engine(trace=True)
    tr = eng.trace

    def proc():
        for i, dur in enumerate([0.5, 0.25, 1.0, 0.125]):
            tr.compute(0, f"step{i}", dur)
            yield dur
    eng.spawn(proc())
    makespan = eng.run_all()
    cp = critical_path(tr)
    assert cp.length_s == pytest.approx(makespan, rel=1e-12)
    assert len(cp.spans) == 4
    bd = rank_breakdown(tr)
    assert bd[0]["compute"] == pytest.approx(makespan, rel=1e-12)
    assert bd[0]["idle"] == pytest.approx(0.0, abs=1e-12)


def test_critical_path_follows_send_recv_edge():
    """Two ranks: r1 computes, sends to r0 which waited idle; the path
    must route through r1's work, not r0's idleness."""
    from repro.core.hardware.network import Network
    from repro.core.simmpi import SimMPI
    eng = Engine(trace=True)
    topo = FatTreeTwoLevel(16, 4, 2, link_bw=1e9)
    mpi = SimMPI(eng, Network(eng, topo), 2)
    tr = eng.trace

    def r0():
        yield from mpi.recv(1, 0, tag="x")
        tr.compute(0, "after", 1e-4)
        yield 1e-4

    def r1():
        tr.compute(1, "work", 5e-3)
        yield 5e-3
        yield from mpi.send(1, 0, 4 * 1024 * 1024, tag="x")
    eng.spawn(r0())
    eng.spawn(r1())
    makespan = eng.run_all()
    cp = critical_path(tr)
    names = [(s.rank, s.name) for s in cp.spans]
    assert (1, "work") in names            # crossed to the sender's rank
    assert (0, "after") in names
    assert cp.length_s <= makespan * (1 + REL)
    assert cp.length_s > 0.95 * makespan   # chain is essentially serial


# ---------------------------------------------------------- chrome json
def test_chrome_export_schema_and_roundtrip(tmp_path):
    sim, res = _traced_hpl()
    path = tmp_path / "trace.json"
    doc = sim.trace.to_chrome_json(str(path))
    validate_chrome_events(doc)
    on_disk = json.loads(path.read_text())
    assert on_disk["traceEvents"] == doc["traceEvents"]
    evs = doc["traceEvents"]
    for ev in evs:
        for k in REQUIRED_KEYS:
            assert k in ev, ev
    phs = {ev["ph"] for ev in evs}
    assert {"M", "X", "b", "e"} <= phs
    # one thread_name per rank, async slices begin<=end and balance
    names = [ev for ev in evs if ev["name"] == "thread_name"]
    assert len(names) == sim.cfg.n_ranks
    begins = [ev for ev in evs if ev["ph"] == "b"]
    ends = {(ev["cat"], ev["id"]): ev for ev in evs if ev["ph"] == "e"}
    assert len(begins) == len(ends)
    for b in begins:
        e = ends[(b["cat"], b["id"])]
        assert b["ts"] <= e["ts"]
    # complete events nest within the run and carry sane durations
    for ev in evs:
        if ev["ph"] == "X":
            assert ev["dur"] >= 0
            assert 0 <= ev["ts"] <= res.time_s * 1e6 * (1 + REL)


def test_validate_chrome_events_rejects_bad_docs():
    with pytest.raises(ValueError):
        validate_chrome_events({})
    with pytest.raises(ValueError):
        validate_chrome_events({"traceEvents": [{"ph": "X", "ts": 0}]})
    with pytest.raises(ValueError):
        validate_chrome_events({"traceEvents": [
            {"ph": "X", "ts": "zero", "pid": 0, "tid": 0, "name": "n",
             "dur": 1}]})


# --------------------------------------------------------- transformer
def test_transformer_trace_phases_and_invariants():
    wl = StepWorkload(
        layers=[LayerWork(1e-3, [("all-reduce", 1 << 20, "model")])] * 3,
        tail_collectives=[("all-reduce", 1 << 22, "data")])
    sim = TransformerStepSim(wl, mesh=(4, 4), trace=True)
    out = sim.run()
    s = sim.trace.summary()
    assert {"layer0", "layer1", "layer2", "tail"} <= set(s["phases"])
    assert "all-reduce" in s["collectives"]
    assert s["critical_path_s"] <= out["step_s"] * (1 + REL)
    for acc in rank_breakdown(sim.trace).values():
        assert acc["compute"] + acc["comm"] <= out["step_s"] * (1 + REL)
    # untraced run unchanged
    out_off = TransformerStepSim(wl, mesh=(4, 4)).run()
    assert out_off["step_s"] == out["step_s"]
    assert out_off["events"] == out["events"]


# ------------------------------------------------------------- wiring
def test_platform_des_trace_flag_flows_through():
    from repro.platforms import get_platform
    plat = get_platform("bdw-local")
    cfg = plat.hpl_config(N=512, nb=64, P=2, Q=2)
    stack = plat.des(trace=True)
    assert stack.trace
    res = HPLSim(cfg, stack).run()
    assert res.trace is not None and res.trace.enabled
    assert len(res.trace.spans) > 0
    # default stays off
    assert HPLSim(cfg, plat).engine.trace is NULL_RECORDER


def test_service_breakdown_option():
    pytest.importorskip("jax")
    from repro.serve import HPLPredictionService, PredictRequest
    from repro.platforms import get_platform
    svc = HPLPredictionService()
    cfg = get_platform("bdw-local").hpl_config(N=512, nb=64, P=2, Q=2)
    out = svc.predict_batch([
        PredictRequest(rid=0, cfg=cfg, platform="bdw-local"),
        PredictRequest(rid=1, cfg=cfg, platform="bdw-local",
                       breakdown=True)])
    assert "breakdown" not in out[0]
    bd = out[1]["breakdown"]
    assert bd["makespan_s"] > 0
    assert bd["compute_frac"] + bd["comm_frac"] + bd["idle_frac"] \
        == pytest.approx(1.0, rel=1e-6)
    assert bd["critical_path_s"] <= bd["makespan_s"] * (1 + REL)
    assert "panel_bcast" in bd["phases"]
    assert svc.stats["des_breakdowns"] == 1


def test_service_breakdown_guards():
    from repro.serve import HPLPredictionService, PredictRequest
    svc = HPLPredictionService(max_des_ranks=4)
    cfg = HPLConfig(N=512, nb=64, P=4, Q=4)
    with pytest.raises(ValueError, match="max_des_ranks"):
        svc.submit(PredictRequest(rid=0, cfg=cfg, platform="bdw-local",
                                  breakdown=True))


# Hypothesis property tests over random geometries live in
# tests/test_trace_properties.py (module-level importorskip would skip
# this whole file on hypothesis-less containers).
