"""SimMPI p2p + collective tests against analytic bounds."""
import math

import pytest

from repro.core.engine import Engine
from repro.core.hardware.network import Network
from repro.core.hardware.topology import FatTreeTwoLevel, Torus
from repro.core.simmpi import SimMPI, EAGER_LIMIT


def _setup(n=8, bw=12.5e9):
    eng = Engine()
    topo = FatTreeTwoLevel(max(n, 16), 4, 2, link_bw=bw, base_latency=1e-6)
    net = Network(eng, topo)
    return eng, SimMPI(eng, net, n)


def test_p2p_eager_sender_returns_early():
    eng, mpi = _setup()
    t_send, t_recv = {}, {}

    def sender():
        yield from mpi.send(0, 1, 1024)      # eager
        t_send["t"] = eng.now

    def receiver():
        yield from mpi.recv(0, 1)
        t_recv["t"] = eng.now
    eng.spawn(sender())
    eng.spawn(receiver())
    eng.run_all()
    assert t_send["t"] < t_recv["t"]         # buffered send returns first


def test_p2p_rendezvous_blocks_sender():
    eng, mpi = _setup()
    times = {}
    size = 10 * EAGER_LIMIT

    def sender():
        yield from mpi.send(0, 1, size)
        times["send"] = eng.now

    def receiver():
        yield from mpi.recv(0, 1)
        times["recv"] = eng.now
    eng.spawn(sender())
    eng.spawn(receiver())
    eng.run_all()
    assert abs(times["send"] - times["recv"]) < 1e-9
    # >= pure bandwidth time
    assert times["recv"] >= size / 12.5e9


@pytest.mark.parametrize("n", [2, 4, 8])
def test_allreduce_completes_and_bounded(n):
    eng, mpi = _setup(n)
    nbytes = 1 << 20
    done = []

    def rank(r):
        yield from mpi.allreduce(r, list(range(n)), nbytes, op_id=("ar",))
        done.append(eng.now)
    for r in range(n):
        eng.spawn(rank(r))
    eng.run_all()
    assert len(done) == n
    t = max(done)
    floor = 2 * (n - 1) / n * nbytes / 12.5e9     # ring lower bound
    assert t >= floor * 0.5
    assert t <= floor * 10 + 1e-3


def test_bcast_binomial_latency_scales_log():
    times = {}
    for n in (4, 16):
        eng, mpi = _setup(n)
        done = []

        def rank(r, n=n, eng=eng, mpi=mpi, done=done):
            yield from mpi.bcast(r, 0, list(range(n)), 4096, op_id=("b",))
            done.append(eng.now)
        for r in range(n):
            eng.spawn(rank(r))
        eng.run_all()
        times[n] = max(done)
    # binomial: ~log2(n) rounds -> 16 ranks ~2x the 4-rank time, not 4x
    assert times[16] < times[4] * 3.0


def test_alltoall_completes():
    eng, mpi = _setup(8)
    done = []

    def rank(r):
        yield from mpi.alltoall(r, list(range(8)), 65536, op_id=("a2a",))
        done.append(eng.now)
    for r in range(8):
        eng.spawn(rank(r))
    eng.run_all()
    assert len(done) == 8


def test_overlapping_tags_never_crossmatch():
    """Op-id hygiene: tags are matched exactly (structured tuples), so a
    posted recv for one collective can never swallow another collective's
    in-flight message on the same (src, dst) pair.  (The old 16-bit
    ``hash(op_id) & 0xffff`` truncation could collide two op_ids.)"""
    eng, mpi = _setup(2)
    size = 10 * EAGER_LIMIT            # rendezvous: transfer takes a while
    t = {}

    def sender():
        yield from mpi.send(0, 1, size, tag=("collA", 1))
        t["send_a"] = eng.now
        yield 5e-3                     # B posted long after A's transfer
        yield from mpi.send(0, 1, 1024, tag=("collB", 1))

    def receiver():
        # recv for B is posted FIRST; only exact-tag matching keeps it
        # from grabbing A's transfer
        yield from mpi.recv(0, 1, tag=("collB", 1))
        t["recv_b"] = eng.now
        yield from mpi.recv(0, 1, tag=("collA", 1))
        t["recv_a"] = eng.now
    eng.spawn(sender())
    eng.spawn(receiver())
    eng.run_all()
    # a cross-match would complete recv_b at A's transfer time; instead
    # it waited out the sender's 5 ms pause for the real B message
    assert t["recv_b"] >= t["send_a"] + 5e-3
    # A's message was sitting buffered the whole time: consumed instantly
    assert t["recv_a"] == t["recv_b"]


def test_interleaved_collectives_same_group_correct_timing():
    """Two collectives on one group, issued back-to-back with distinct
    op_ids and skewed entry times: both must complete, with per-rank op
    ordering intact (op 'a' done before op 'b' starts on every rank) and
    message accounting consistent."""
    n = 4
    eng, mpi = _setup(n)
    group = list(range(n))
    marks = {}

    def rank(r):
        if r == 0:
            yield 2e-3                 # rank 0 arrives late to op 'a'
        yield from mpi.allreduce(r, group, 1 << 10, op_id=("a",))
        t_a = eng.now
        yield from mpi.allreduce(r, group, 1 << 18, op_id=("b",))
        marks[r] = (t_a, eng.now)
    for r in range(n):
        eng.spawn(rank(r))
    eng.run_all()
    assert len(marks) == n
    for r, (t_a, t_b) in marks.items():
        assert t_a >= 2e-3             # nobody finished 'a' before rank 0 fed it
        assert t_b > t_a, r
    # small allreduce: recursive doubling = log2(n) sendrecvs per rank;
    # large: Rabenseifner ring rs+ag = 2*(n-1) msgs per rank
    assert mpi.counters["p2p_msgs"] == n * math.log2(n) + n * 2 * (n - 1)
    assert mpi.counters["colls"] == 2 * n


@pytest.mark.parametrize("n", [3, 5, 6, 7])
def test_alltoall_nonpow2_exchanges_every_pair(n):
    """(me+k)%n pairing: every rank sends to all n-1 peers even when the
    group is not a power of two (the old XOR pairing dropped pairs)."""
    eng, mpi = _setup(n)
    done = []

    def rank(r):
        yield from mpi.alltoall(r, list(range(n)), 4096, op_id=("a2a", n))
        done.append(eng.now)
    for r in range(n):
        eng.spawn(rank(r))
    eng.run_all()
    assert len(done) == n
    assert mpi.counters["p2p_msgs"] == n * (n - 1)
