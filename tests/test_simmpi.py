"""SimMPI p2p + collective tests against analytic bounds."""
import math

import pytest

from repro.core.engine import Engine
from repro.core.hardware.network import Network
from repro.core.hardware.topology import FatTreeTwoLevel, Torus
from repro.core.simmpi import SimMPI, EAGER_LIMIT


def _setup(n=8, bw=12.5e9):
    eng = Engine()
    topo = FatTreeTwoLevel(max(n, 16), 4, 2, link_bw=bw, base_latency=1e-6)
    net = Network(eng, topo)
    return eng, SimMPI(eng, net, n)


def test_p2p_eager_sender_returns_early():
    eng, mpi = _setup()
    t_send, t_recv = {}, {}

    def sender():
        yield from mpi.send(0, 1, 1024)      # eager
        t_send["t"] = eng.now

    def receiver():
        yield from mpi.recv(0, 1)
        t_recv["t"] = eng.now
    eng.spawn(sender())
    eng.spawn(receiver())
    eng.run_all()
    assert t_send["t"] < t_recv["t"]         # buffered send returns first


def test_p2p_rendezvous_blocks_sender():
    eng, mpi = _setup()
    times = {}
    size = 10 * EAGER_LIMIT

    def sender():
        yield from mpi.send(0, 1, size)
        times["send"] = eng.now

    def receiver():
        yield from mpi.recv(0, 1)
        times["recv"] = eng.now
    eng.spawn(sender())
    eng.spawn(receiver())
    eng.run_all()
    assert abs(times["send"] - times["recv"]) < 1e-9
    # >= pure bandwidth time
    assert times["recv"] >= size / 12.5e9


@pytest.mark.parametrize("n", [2, 4, 8])
def test_allreduce_completes_and_bounded(n):
    eng, mpi = _setup(n)
    nbytes = 1 << 20
    done = []

    def rank(r):
        yield from mpi.allreduce(r, list(range(n)), nbytes, op_id=("ar",))
        done.append(eng.now)
    for r in range(n):
        eng.spawn(rank(r))
    eng.run_all()
    assert len(done) == n
    t = max(done)
    floor = 2 * (n - 1) / n * nbytes / 12.5e9     # ring lower bound
    assert t >= floor * 0.5
    assert t <= floor * 10 + 1e-3


def test_bcast_binomial_latency_scales_log():
    times = {}
    for n in (4, 16):
        eng, mpi = _setup(n)
        done = []

        def rank(r, n=n, eng=eng, mpi=mpi, done=done):
            yield from mpi.bcast(r, 0, list(range(n)), 4096, op_id=("b",))
            done.append(eng.now)
        for r in range(n):
            eng.spawn(rank(r))
        eng.run_all()
        times[n] = max(done)
    # binomial: ~log2(n) rounds -> 16 ranks ~2x the 4-rank time, not 4x
    assert times[16] < times[4] * 3.0


def test_alltoall_completes():
    eng, mpi = _setup(8)
    done = []

    def rank(r):
        yield from mpi.alltoall(r, list(range(8)), 65536, op_id=("a2a",))
        done.append(eng.now)
    for r in range(8):
        eng.spawn(rank(r))
    eng.run_all()
    assert len(done) == 8


@pytest.mark.parametrize("n", [3, 5, 6, 7])
def test_alltoall_nonpow2_exchanges_every_pair(n):
    """(me+k)%n pairing: every rank sends to all n-1 peers even when the
    group is not a power of two (the old XOR pairing dropped pairs)."""
    eng, mpi = _setup(n)
    done = []

    def rank(r):
        yield from mpi.alltoall(r, list(range(n)), 4096, op_id=("a2a", n))
        done.append(eng.now)
    for r in range(n):
        eng.spawn(rank(r))
    eng.run_all()
    assert len(done) == n
    assert mpi.counters["p2p_msgs"] == n * (n - 1)
