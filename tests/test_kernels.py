"""Per-Pallas-kernel shape/dtype sweeps vs the pure-jnp oracles
(interpret=True executes the kernel bodies in Python on CPU)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_attention.kernel import flash_attention_fwd
from repro.kernels.flash_attention.ref import attention_ref
from repro.kernels.ssd_scan.kernel import ssd_scan
from repro.kernels.ssd_scan.ref import ssd_ref_sequential
from repro.kernels.maxmin_fair.kernel import masked_min_rows
from repro.kernels.maxmin_fair.ref import masked_min_rows_ref, waterfill_ref
from repro.kernels.maxmin_fair.ops import waterfill


# ---------------------------------------------------------------- flash
@pytest.mark.parametrize("b,s,g,r,hd", [
    (1, 128, 1, 1, 64),
    (2, 256, 2, 4, 64),
    (1, 256, 1, 7, 32),      # qwen2-like odd R
    (1, 512, 4, 2, 128),
])
@pytest.mark.parametrize("causal", [True, False])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_sweep(b, s, g, r, hd, causal, dtype):
    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(k1, (b, s, g, r, hd), dtype)
    k = jax.random.normal(k2, (b, s, g, hd), dtype)
    v = jax.random.normal(k3, (b, s, g, hd), dtype)
    out = flash_attention_fwd(q, k, v, causal=causal, bq=128, bk=128,
                              interpret=True)
    ref = attention_ref(q, k, v, causal=causal)
    tol = 2e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32),
                               atol=tol, rtol=tol)


def test_flash_attention_block_sizes():
    q = jax.random.normal(jax.random.PRNGKey(0), (1, 256, 1, 2, 64))
    k = jax.random.normal(jax.random.PRNGKey(1), (1, 256, 1, 64))
    v = jax.random.normal(jax.random.PRNGKey(2), (1, 256, 1, 64))
    ref = attention_ref(q, k, v, causal=True)
    for bq, bk in [(64, 64), (128, 64), (64, 128), (256, 256)]:
        out = flash_attention_fwd(q, k, v, causal=True, bq=bq, bk=bk,
                                  interpret=True)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   atol=2e-5, rtol=2e-5)


# ---------------------------------------------------------------- ssd
@pytest.mark.parametrize("b,s,h,p,n,chunk", [
    (1, 64, 1, 8, 4, 16),
    (2, 128, 3, 16, 8, 32),
    (1, 256, 2, 64, 16, 64),
    (1, 128, 2, 32, 128, 128),   # full-seq single chunk
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_ssd_scan_sweep(b, s, h, p, n, chunk, dtype):
    ks = jax.random.split(jax.random.PRNGKey(1), 5)
    xh = jax.random.normal(ks[0], (b, s, h, p), dtype)
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, s, h),
                                           jnp.float32))
    A = -jnp.exp(jax.random.normal(ks[2], (h,), jnp.float32))
    Bh = jax.random.normal(ks[3], (b, s, h, n), dtype)
    Ch = jax.random.normal(ks[4], (b, s, h, n), dtype)
    out = ssd_scan(xh, dt, A, Bh, Ch, chunk, interpret=True)
    ref = ssd_ref_sequential(xh, dt, A, Bh, Ch)
    tol = 2e-4 if dtype == jnp.float32 else 5e-2
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32),
                               atol=tol * 10, rtol=tol)


# ------------------------------------------------------------- maxmin
@pytest.mark.parametrize("f,l,density", [(64, 128, 0.1), (256, 256, 0.03),
                                         (8, 128, 0.5)])
def test_masked_min_rows(f, l, density):
    adj = (jax.random.uniform(jax.random.PRNGKey(2), (f, l))
           < density).astype(jnp.int8)
    vals = jax.random.uniform(jax.random.PRNGKey(3), (l,)) * 100
    out = masked_min_rows(adj, vals, bf=min(256, f), bl=128, interpret=True)
    ref = masked_min_rows_ref(adj, vals)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=1e-6)


def test_waterfill_matches_ref_and_conserves():
    adj = (jax.random.uniform(jax.random.PRNGKey(4), (128, 128))
           < 0.05).astype(jnp.int8)
    caps = jax.random.uniform(jax.random.PRNGKey(5), (128,)) * 1e9 + 1e8
    r_k = waterfill(adj, caps, use_kernel=True)
    r_r = waterfill_ref(adj, caps)
    np.testing.assert_allclose(np.asarray(r_k), np.asarray(r_r), rtol=1e-4)
    rates = np.minimum(np.asarray(r_r, np.float64), 1e30)
    usage = np.asarray(adj, np.float64).T @ rates
    assert (usage <= np.asarray(caps) * (1 + 1e-3)).all()


def test_waterfill_matches_des_network():
    """The kernel waterfill and the DES network's progressive filling agree
    on a shared-bottleneck case."""
    import math
    from repro.core.engine import Engine
    from repro.core.hardware.network import Network, Link

    class _Topo:
        base_latency = 0.0
        def __init__(self):
            self.shared = Link(10e9)
            self.a = Link(100e9)
            self.b = Link(2e9)
        def route(self, s, d):
            return {(0, 1): [self.shared, self.a],
                    (2, 3): [self.shared, self.b]}[(s, d)]

    topo = _Topo()
    eng = Engine()
    net = Network(eng, topo)
    done1 = net.send(0, 1, 1e9)
    done2 = net.send(2, 3, 1e9)
    eng.run_all()
    # flow2 bottlenecked by its 2 GB/s link; flow1 then gets 8 GB/s
    f1 = [f for f in [] ]
    # completion: flow2 at 0.5 s; flow1: rate 8 until 0.125? max-min: f2=2,
    # f1=8 -> f1 done at 1/8=0.125s, then f2 continues at 2 (own bottleneck)
    assert abs(eng.now - 0.5) < 0.02, eng.now
    adj = jnp.array([[1, 1, 0], [1, 0, 1]], jnp.int8)
    caps = jnp.array([10e9, 100e9, 2e9], jnp.float32)
    rates = np.asarray(waterfill(adj, caps, use_kernel=False))
    np.testing.assert_allclose(rates, [8e9, 2e9], rtol=1e-5)
