"""Checkpoint roundtrip/async/gc + deterministic elastic data pipeline."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import (AsyncCheckpointer, latest_step,
                              restore_checkpoint, save_checkpoint)
from repro.configs import get_config, reduced
from repro.data import DataConfig, SyntheticLM
from repro.ft import elastic_restart_plan
from repro.train.step import make_train_state


def test_checkpoint_roundtrip(tmp_path, rng):
    cfg = reduced(get_config("qwen2-0.5b"))
    state = make_train_state(cfg, rng)
    save_checkpoint(tmp_path, 7, state)
    assert latest_step(tmp_path) == 7
    restored = restore_checkpoint(tmp_path, 7, state)
    for a, b in zip(jax.tree.leaves(state), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_checkpoint_gc_keeps_last_k(tmp_path, rng):
    cfg = reduced(get_config("qwen2-0.5b"))
    state = make_train_state(cfg, rng)
    for s in (1, 2, 3, 4):
        save_checkpoint(tmp_path, s, state, keep_last=2)
    steps = sorted(int(p.name.split("_")[1])
                   for p in tmp_path.glob("step_*"))
    assert steps == [3, 4]


def test_async_checkpointer(tmp_path, rng):
    cfg = reduced(get_config("qwen2-0.5b"))
    state = make_train_state(cfg, rng)
    ck = AsyncCheckpointer(tmp_path)
    ck.save(5, state)
    ck.wait()
    assert latest_step(tmp_path) == 5
    restored = restore_checkpoint(tmp_path, 5, state)
    np.testing.assert_array_equal(
        np.asarray(jax.tree.leaves(state)[0]),
        np.asarray(jax.tree.leaves(restored)[0]))


def test_data_elastic_repartition_identical():
    cfg = DataConfig(vocab_size=1024, seq_len=32, global_batch=8, seed=3)
    ds = SyntheticLM(cfg)
    full = ds.global_batch_at(step=11)
    for dp in (1, 2, 4, 8):
        parts = np.concatenate([ds.shard_at(11, r, dp) for r in range(dp)])
        np.testing.assert_array_equal(parts, full)


def test_data_restart_replays():
    cfg = DataConfig(vocab_size=512, seq_len=16, global_batch=4)
    ds1, ds2 = SyntheticLM(cfg), SyntheticLM(cfg)
    np.testing.assert_array_equal(ds1.shard_at(5, 0, 2), ds2.shard_at(5, 0, 2))


def test_elastic_plan_validates():
    plan = elastic_restart_plan(global_batch=256, resume_step=100,
                                old_mesh=(16, 16), new_mesh=(8, 16))
    assert plan.per_device_batch_new == 32
    with pytest.raises(ValueError):
        elastic_restart_plan(global_batch=100, resume_step=1,
                             old_mesh=(16, 16), new_mesh=(7, 16))
