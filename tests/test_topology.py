"""Topology routing validity and structural link counts.

The dragonfly route walker reconstructs each hop from link identity and
checks the path is physically consistent: every link exists in the
topology's link collections, consecutive hops share a router, global
links are entered at their egress router and exited at their ingress
router, and no link repeats (loop-free)."""
import math

import pytest

from repro.core.hardware.topology import (Dragonfly, FatTreeTwoLevel,
                                          MultiPod, Torus)


def _dragonfly_link_table(t: Dragonfly):
    table = {}
    for i, l in enumerate(t.node_up):
        table[id(l)] = ("up", i)
    for i, l in enumerate(t.node_down):
        table[id(l)] = ("down", i)
    for (g, i, j), l in t.local.items():
        table[id(l)] = ("local", g, i, j)
    for (s, d), l in t.glob.items():
        table[id(l)] = ("glob", s, d)
    return table


def _walk_dragonfly(t: Dragonfly, src: int, dst: int):
    """Validate route(src, dst) hop by hop; returns the path."""
    path = t.route(src, dst)
    table = _dragonfly_link_table(t)
    assert len({id(l) for l in path}) == len(path), "loop: repeated link"
    for l in path:
        assert id(l) in table, "foreign link in path"
    if src == dst:
        assert path == []
        return path
    sg, sr = t._locate(src)
    dg, dr = t._locate(dst)
    assert table[id(path[0])] == ("up", src)
    assert table[id(path[-1])] == ("down", dst)
    g, r = sg, sr
    for l in path[1:-1]:
        kind = table[id(l)]
        if kind[0] == "local":
            _, lg, li, lj = kind
            assert (lg, li) == (g, r), "local hop leaves wrong router"
            assert li != lj
            r = lj
        else:
            _, ls, ld = kind
            assert ls == g, "global hop from wrong group"
            assert r == ld % t.a, "global hop not at its egress router"
            g, r = ld, ls % t.a          # land on the ingress router
    assert (g, r) == (dg, dr), "path does not terminate at dst router"
    return path


@pytest.mark.parametrize("nonminimal", [False, True])
def test_dragonfly_all_pairs_routes_valid(nonminimal):
    t = Dragonfly(n_groups=4, routers_per_group=3, nodes_per_router=2,
                  link_bw=1e9, nonminimal=nonminimal)
    for src in range(t.n_nodes):
        for dst in range(t.n_nodes):
            _walk_dragonfly(t, src, dst)


def test_dragonfly_minimal_uses_single_global_hop():
    t = Dragonfly(n_groups=5, routers_per_group=4, nodes_per_router=2,
                  link_bw=1e9)
    table = _dragonfly_link_table(t)
    for src, dst in [(0, 39), (8, 17), (3, 30)]:
        hops = [table[id(l)][0] for l in t.route(src, dst)]
        if t._locate(src)[0] != t._locate(dst)[0]:
            assert hops.count("glob") == 1


def test_dragonfly_nonminimal_detours_through_mid_group():
    t = Dragonfly(n_groups=5, routers_per_group=4, nodes_per_router=2,
                  link_bw=1e9, nonminimal=True)
    table = _dragonfly_link_table(t)
    # sg=0, dg=3 -> mid = 3 % 5 = 3 == dg, stays minimal; sg=1, dg=3 ->
    # mid = 4: two global hops through group 4
    src, dst = t.p * t.a * 1, t.p * t.a * 3      # first node of groups 1, 3
    globs = [table[id(l)] for l in t.route(src, dst)
             if table[id(l)][0] == "glob"]
    assert globs == [("glob", 1, 4), ("glob", 4, 3)]
    _walk_dragonfly(t, src, dst)


# ----------------------------------------------------------- link counts

def test_fat_tree_n_links_counts_every_physical_link():
    t = FatTreeTwoLevel(n_nodes=100, nodes_per_edge=18, n_core=6,
                        link_bw=1e9)
    n_edge = math.ceil(100 / 18)
    assert t.n_links == 2 * 100 + 2 * n_edge * 6
    assert t.n_links == (len(t.node_up) + len(t.node_down)
                         + sum(len(row) for row in t.edge_up)
                         + sum(len(row) for row in t.edge_down))


def test_dragonfly_n_links_counts_every_physical_link():
    t = Dragonfly(n_groups=4, routers_per_group=3, nodes_per_router=2,
                  link_bw=1e9)
    expect = (2 * t.n_nodes                  # node up/down
              + 4 * 3 * 2                    # local: a*(a-1) per group
              + 4 * 3)                       # global: g*(g-1) ordered pairs
    assert t.n_links == expect


def test_torus_n_links_counts_every_physical_link():
    t = Torus((4, 4, 2), link_bw=1e9)
    assert t.n_links == 32 * 3 * 2           # n * dims * 2 directions


def test_multipod_n_links_sums_pods_plus_dcn():
    pods = [Torus((4, 4), link_bw=1e9) for _ in range(3)]
    t = MultiPod(pods, pod_size=16)
    assert t.n_links == 3 * (16 * 2 * 2) + 2 * 3
    # cross-pod routes traverse the DCN exactly once each way
    path = t.route(0, 17)
    assert t.dcn_up[0] in path and t.dcn_down[1] in path


def test_torus_route_is_shortest_wrap():
    t = Torus((8, 8), link_bw=1e9)
    # 0 -> (0, 7): one hop in the wrap direction, not seven forward
    assert len(t.route(0, 7)) == 1
    assert len(t.route(0, t.node_at((4, 4)))) == 8
