"""TOP500 ingestion subsystem: parser schema/leniency, spec inference
heuristics + provenance, registry bulk namespacing, the one-compile
fleet sweep, and the calibration acceptance bound (held-out median
relative error <= 15% on the vendored sample)."""
import json

import pytest

from repro.platforms import (Platform, bulk_register, get_platform,
                             list_platforms, unregister)
from repro.top500 import (CPUFamilyRule, FleetTuning, ROW_SCHEMA_VERSION,
                          Top500Row, fabric_group, infer_platform,
                          infer_platforms, load_sample, parse_top500,
                          predict_fleet, sample_list_path, tune_scenario)

SMOKE_TUNING = FleetTuning(max_ranks=256, panels_cap=2048)


def _row(**over):
    base = dict(rank=5, site="Test Site", system="Test Machine",
                processor="Intel Xeon Platinum 8280 28C 2.7GHz",
                cores=448448, interconnect="Mellanox InfiniBand HDR",
                rmax_tflops=23516.4, rpeak_tflops=38745.9)
    base.update(over)
    return Top500Row(**base)


# ------------------------------------------------------------- parser

def test_parse_vendored_sample_is_clean():
    report = parse_top500(sample_list_path(), strict=True)
    assert len(report.rows) >= 50
    assert not report.skipped
    ranks = [r.rank for r in report.rows]
    assert ranks == sorted(ranks) and len(set(ranks)) == len(ranks)
    for r in report.rows:
        assert r.schema_version == ROW_SCHEMA_VERSION
        assert 0 < r.rmax_tflops <= r.rpeak_tflops
        assert r.cpu_cores > 0


def test_parse_header_aliases_and_tsv():
    text = ("Rank\tName\tProcessor\tCores\tInterconnect\t"
            "Rmax\tRpeak\n"
            "7\tBox\tXeon Gold 6148 20C 2.4GHz\t4,000\tEDR\t"
            "100.5\t200.0\n")
    rows = parse_top500(text).rows
    assert len(rows) == 1
    r = rows[0]
    assert (r.rank, r.system, r.cores) == (7, "Box", 4000)
    assert r.rmax_tflops == pytest.approx(100.5)


def test_parse_gflops_era_columns():
    text = ("Rank,Processor,Total Cores,Interconnect,"
            "Rmax [GFlop/s],Rpeak [GFlop/s]\n"
            "1,Xeon E5-2680v3 12C 2.5GHz,1000,Aries,50000,80000\n")
    r = parse_top500(text).rows[0]
    assert r.rmax_tflops == pytest.approx(50.0)
    assert r.rpeak_tflops == pytest.approx(80.0)


def test_parse_lenient_skips_and_strict_raises():
    text = ("Rank,Processor,Total Cores,Interconnect,Rmax,Rpeak\n"
            "1,Xeon Gold 6148 20C 2.4GHz,1000,EDR,10,20\n"
            "2,Xeon Gold 6148 20C 2.4GHz,not-a-number,EDR,10,20\n"
            "3,Xeon Gold 6148 20C 2.4GHz,1000,EDR,0,20\n")
    report = parse_top500(text)
    assert [r.rank for r in report.rows] == [1]
    assert [line for line, _ in report.skipped] == [2, 3]
    with pytest.raises(ValueError, match="row 2"):
        parse_top500(text, strict=True)


def test_parse_skips_empty_processor_or_interconnect_cells():
    # a blank required cell is a bad row (lenient skip), never a
    # StopIteration deep inside inference
    text = ("Rank,Processor,Total Cores,Interconnect,Rmax,Rpeak\n"
            "1,Xeon Gold 6148 20C 2.4GHz,1000,,10,20\n"
            "2,,1000,EDR,10,20\n"
            "3,Xeon Gold 6148 20C 2.4GHz,1000,EDR,10,20\n")
    report = parse_top500(text)
    assert [r.rank for r in report.rows] == [3]
    assert len(report.skipped) == 2
    # and a row forced past the parser still fails with a clear error
    with pytest.raises(ValueError, match="no fabric family rule"):
        infer_platform(_row(interconnect=""))
    with pytest.raises(ValueError, match="no CPU family rule"):
        infer_platform(_row(processor=""))


def test_parse_missing_required_column_always_raises():
    with pytest.raises(ValueError, match="interconnect"):
        parse_top500("Rank,Processor,Total Cores,Rmax,Rpeak\n"
                     "1,Xeon 20C 2GHz,100,1,2\n")


# ---------------------------------------------------------- inference

def test_infer_frontera_like_row_matches_hand_spec():
    plat = infer_platform(_row())
    prov = plat.provenance_dict
    assert plat.scale.n_nodes == 8008
    assert plat.node.cores == 56
    assert prov["cpu_family"] == "xeon-avx512"
    assert prov["peak_source"] == "processor-heuristic"
    # nominal 56 * 32 * 2.7e9 with the AVX-512 sustained derate
    assert plat.node.peak_flops == pytest.approx(
        56 * 32 * 2.7e9 * 0.70, rel=1e-6)
    assert plat.fabric.kind == "fat-tree"
    assert plat.fabric.link_bw == pytest.approx(200e9 / 8)
    assert fabric_group(plat) == "infiniband"
    assert plat.scale.reported_tflops == pytest.approx(23516.4)


def test_infer_fabric_kinds_from_interconnect_strings():
    cases = {"Aries interconnect": ("dragonfly", "aries"),
             "Slingshot-10": ("dragonfly", "slingshot"),
             "Tofu interconnect D": ("torus", "tofu"),
             "Custom 5D Torus": ("torus", "bluegene"),
             "Intel Omni-Path": ("fat-tree", "omnipath"),
             "25G Ethernet": ("fat-tree", "ethernet"),
             "Mystery Fabric 3000": ("fat-tree", "custom")}
    for text, (kind, family) in cases.items():
        plat = infer_platform(_row(interconnect=text))
        assert plat.fabric.kind == kind, text
        assert fabric_group(plat) == family, text


def test_infer_rpeak_reconciliation_rescales_bad_guess():
    # ThunderX2 hits the generic rule (16 flops/cyc guess vs true 8):
    # derived nominal misses listed Rpeak by ~2x -> rescale + provenance
    plat = infer_platform(_row(
        processor="Marvell ThunderX2 28C 2.0GHz", cores=145152,
        rmax_tflops=1529.0, rpeak_tflops=2322.4))
    prov = plat.provenance_dict
    assert prov["peak_source"].startswith("rpeak-rescaled")
    n_nodes = plat.scale.n_nodes
    assert plat.node.peak_flops == pytest.approx(
        2322.4e12 / n_nodes * 0.80, rel=1e-6)  # generic sustained 0.8


def test_infer_accelerated_row_gets_accel_section():
    plat = infer_platform(_row(
        processor="IBM POWER9 22C 3.07GHz", cores=2414592,
        accel_cores=2211840, accelerator="NVIDIA Volta GV100",
        rmax_tflops=148600.0, rpeak_tflops=200794.9))
    assert plat.scale.n_nodes == 4608      # (total - accel) / 44
    assert plat.node.accel_peak_flops > 0.5 * plat.node.peak_flops
    assert plat.provenance_dict["accelerator"] == "NVIDIA Volta GV100"


def test_infer_overrides_and_custom_tables_apply():
    plat = infer_platform(_row(), overrides={"n_nodes": 100,
                                             "hbm_bytes": 64e9})
    assert plat.scale.n_nodes == 100
    assert plat.node.hbm_bytes == pytest.approx(64e9)
    assert "override 100" in plat.provenance_dict["n_nodes"]
    # a replacement CPU table is honored (first match wins)
    rule = CPUFamilyRule("my-chip", r".", 8, 1.0, 1, 1.0, 1.0, 4, 1.0)
    plat2 = infer_platform(_row(rpeak_tflops=448448 * 8 * 2.7 / 1e3),
                           cpu_families=(rule,))
    assert plat2.provenance_dict["cpu_family"] == "my-chip"
    assert plat2.node.cores == 28          # 1 socket x parsed 28C


@pytest.mark.parametrize("idx", [0, 1, 4, 10, 22])
def test_inferred_platforms_build_both_backends(idx):
    plat = infer_platforms([load_sample()[idx]])[0]
    stack = plat.des()
    assert stack.topology.n_links > 0
    prm = plat.fastsim()
    assert prm.peak_flops > 0 and prm.link_bw > 0
    assert Platform.from_json(plat.to_json()) == plat


# ------------------------------------------------- registry satellites

def test_bulk_register_namespaces_and_rolls_back_on_collision():
    plats = infer_platforms(load_sample()[:3])
    names = [f"t500test/{p.name}" for p in plats]
    unregister(names)
    try:
        before = set(list_platforms())
        out = bulk_register(plats, namespace="t500test")
        assert [p.name for p in out] == names
        assert get_platform(names[0]).scale.reported_tflops > 0
        # built-ins untouched, originals not registered bare
        assert "frontera" in list_platforms()
        assert plats[0].name not in list_platforms()
        # a second bulk register collides atomically: nothing new lands
        with pytest.raises(ValueError, match="already registered"):
            bulk_register(plats[:1] + infer_platforms(load_sample()[3:4]),
                          namespace="t500test")
        assert set(list_platforms()) - before == set(names)
        # duplicate inside one batch is rejected up front
        with pytest.raises(ValueError, match="duplicate"):
            bulk_register([plats[0], plats[0]], namespace="t500test2")
        assert not [n for n in list_platforms()
                    if n.startswith("t500test2/")]
    finally:
        unregister(names)


def test_bulk_register_rejects_bad_namespace():
    with pytest.raises(ValueError, match="namespace"):
        bulk_register([], namespace="a/b")


def test_get_platform_suggests_close_matches():
    with pytest.raises(KeyError) as ei:
        get_platform("fronterra")
    msg = str(ei.value)
    assert "did you mean" in msg and "frontera" in msg
    # no close match -> counts the registry instead of dumping it
    with pytest.raises(KeyError, match="platforms registered"):
        get_platform("zzzzzzz")


# ------------------------------------------------------ fleet + tuning

def test_tune_scenario_memory_rule_and_proxy_invariance():
    plat = infer_platform(_row())
    cfg, scale = tune_scenario(plat, SMOKE_TUNING)
    # proxy grid respects the cap; memory rule fills <= 75% of proxy mem
    assert cfg.P * cfg.Q <= SMOKE_TUNING.max_ranks
    proxy_nodes = cfg.P * cfg.Q
    assert 8 * cfg.N ** 2 <= 0.75 * proxy_nodes * plat.node.hbm_bytes
    assert scale == pytest.approx(plat.scale.n_nodes / proxy_nodes)
    assert cfg.n_panels <= SMOKE_TUNING.panels_cap
    # a machine smaller than the cap simulates at full size
    small = infer_platform(_row(cores=56 * 100,
                                rmax_tflops=100.0, rpeak_tflops=483.8))
    cfg_s, scale_s = tune_scenario(small, SMOKE_TUNING)
    assert scale_s == pytest.approx(1.0)
    assert cfg_s.P * cfg_s.Q == pytest.approx(100)


@pytest.fixture(scope="module")
def fleet_report():
    from repro.core.fastsim import trace_count
    rows = load_sample()
    t0 = trace_count()
    report = predict_fleet(rows, tuning=SMOKE_TUNING)
    report.new_compiles = trace_count() - t0
    return report


def test_fleet_runs_as_single_batched_sweep(fleet_report):
    # one forced bucket, at most one fresh compile for 51 machines
    # (0 when an earlier test already traced the same bucket)
    assert fleet_report.new_compiles <= 1
    assert fleet_report.compiles == fleet_report.new_compiles
    assert len(fleet_report.entries) >= 50
    for e in fleet_report.entries:
        assert e.cfg.n_panels <= fleet_report.bucket[0]
        assert e.cfg.P <= fleet_report.bucket[1]
        assert e.cfg.Q <= fleet_report.bucket[2]


def test_fleet_report_is_ranked_and_jsonable(fleet_report):
    ranked = fleet_report.ranked()
    preds = [e.calibrated_tflops or e.predicted_tflops for e in ranked]
    assert preds == sorted(preds, reverse=True)
    assert all(p > 0 for p in preds)
    d = fleet_report.to_dict()
    assert d["machines"][0]["predicted_rank"] == 1
    assert d["machines"][0]["provenance"]
    json.dumps(d)    # fully serializable

def test_fleet_acceptance_heldout_median_error(fleet_report):
    """Acceptance: held-out median relative error after fabric-family
    calibration <= 15% on the vendored sample (paper: 4% on Frontera
    hand-built; the heuristic-inferred fleet gets the looser bound)."""
    cal = fleet_report.calibration
    assert cal.n_train >= 20 and cal.n_test >= 15
    assert cal.heldout_median_abs_err <= 0.15, cal.to_dict()
    # calibration factors are sane multiplicative efficiencies
    for fam, f in cal.factors.items():
        assert 0.3 < f < 2.0, (fam, f)
    # raw (uncalibrated) predictions were already the right magnitude
    assert fleet_report.median_abs_err() <= 0.25


def test_fleet_split_is_deterministic_and_stratified(fleet_report):
    by_family = {}
    for e in fleet_report.entries:
        by_family.setdefault(e.family, []).append(e)
    for fam, group in by_family.items():
        marks = {e.split for e in group}
        assert marks <= {"train", "test"}
        if len(group) == 1:
            assert marks == {"train"}, fam
        else:
            assert "train" in marks, fam


def test_fleet_handles_platforms_without_published_rmax():
    # registry built-ins (reported_tflops=0) predict fine: no published
    # number means NaN rel_err (excluded from medians), not a crash
    plats = [get_platform("bdw-local"), get_platform("frontera")]
    report = predict_fleet(plats, tuning=SMOKE_TUNING)
    by_name = {e.platform.name: e for e in report.entries}
    assert by_name["bdw-local"].predicted_tflops > 0
    assert by_name["bdw-local"].rel_err != by_name["bdw-local"].rel_err
    assert by_name["bdw-local"].split == ""       # never trains/scores
    assert by_name["frontera"].split == "train"   # singleton family
    d = report.to_dict()
    assert json.loads(json.dumps(d))  # NaN-free JSON
    row = next(m for m in d["machines"] if m["name"] == "bdw-local")
    assert row["rel_err"] is None


def test_predict_fleet_empty_source_raises():
    with pytest.raises(ValueError, match="no machines"):
        predict_fleet([])


# ------------------------------------------------------------ serving

def test_serve_predict_top500_from_csv():
    from repro.serve import predict_top500
    report = predict_top500(sample_list_path(), tuning=SMOKE_TUNING)
    assert len(report.entries) >= 50
    assert report.compiles <= 1
    # namespace registration exposes machines to the name-based API
    ns = "t500srv"
    report2 = predict_top500(sample_list_path(), namespace=ns,
                             tuning=SMOKE_TUNING, calibrate=False)
    try:
        reg_names = [e.platform.name for e in report2.entries]
        assert all(n.startswith(ns + "/") for n in reg_names)
        assert get_platform(reg_names[0]) is not None
        # re-ingesting the same list is an error unless overwrite=True
        with pytest.raises(ValueError, match="already registered"):
            predict_top500(sample_list_path(), namespace=ns,
                           tuning=SMOKE_TUNING, calibrate=False)
        report3 = predict_top500(sample_list_path(), namespace=ns,
                                 overwrite=True, tuning=SMOKE_TUNING,
                                 calibrate=False)
        assert len(report3.entries) == len(report2.entries)
    finally:
        unregister([e.platform.name for e in report2.entries])


def test_serve_predict_top500_surfaces_skipped_and_empty(tmp_path):
    from repro.serve import predict_top500
    good = tmp_path / "one_bad.csv"
    good.write_text(
        "Rank,Processor,Total Cores,Interconnect,Rmax,Rpeak\n"
        "1,Xeon Gold 6148 20C 2.4GHz,40000,EDR,500,768\n"
        "2,Xeon Gold 6148 20C 2.4GHz,bogus,EDR,500,768\n",
        encoding="utf-8")
    report = predict_top500(str(good), tuning=SMOKE_TUNING,
                            calibrate=False)
    assert len(report.entries) == 1
    assert [line for line, _ in report.skipped_rows] == [2]
    assert report.to_dict()["skipped_rows"]
    bad = tmp_path / "all_bad.csv"
    bad.write_text(
        "Rank,Processor,Total Cores,Interconnect,Rmax,Rpeak\n"
        "1,Xeon Gold 6148 20C 2.4GHz,bogus,EDR,500,768\n",
        encoding="utf-8")
    with pytest.raises(ValueError, match="no parseable rows"):
        predict_top500(str(bad), tuning=SMOKE_TUNING)


def test_service_predict_top500_method_updates_stats():
    from repro.serve import HPLPredictionService
    from repro.top500 import sample_list_path
    svc = HPLPredictionService()
    out = svc.predict_top500(sample_list_path(), tuning=SMOKE_TUNING)
    assert out["machines"] and out["compiles"] <= 1
    assert svc.stats["scenarios"] >= 50


# ------------------------- predict_platforms error paths (satellite)

def test_predict_platforms_unknown_name_mid_batch_leaves_queue_clean():
    from repro.core.apps.hpl import HPLConfig
    from repro.serve import HPLPredictionService
    svc = HPLPredictionService()
    cfg = HPLConfig(N=1024, nb=128, P=2, Q=2)
    with pytest.raises(KeyError, match="no-such"):
        svc.predict_platforms(["frontera", "no-such-machine"], cfg=cfg)
    # the bad batch enqueued nothing and counted nothing
    assert svc.stats["requests"] == 0
    assert not svc._queue
    # the service still serves a clean follow-up batch
    out = svc.predict_platforms(["frontera", "pupmaya"], cfg=cfg)
    assert set(out) == {"frontera", "pupmaya"}
    assert svc.stats["requests"] == 2
    assert svc.stats["scenarios"] == 2


def test_predict_platforms_empty_sequence_is_a_noop():
    from repro.serve import HPLPredictionService
    svc = HPLPredictionService()
    assert svc.predict_platforms([]) == {}
    assert svc.stats == {"requests": 0, "batches": 0, "scenarios": 0,
                         "traces": 0, "des_breakdowns": 0}


# ----------------------- vendored edition set (campaign satellite)

def test_second_vendored_edition_parses_clean():
    from repro.top500 import list_sample_editions
    assert list_sample_editions() == ["2020_06", "2020_11"]
    rows = load_sample(edition="2020_11")     # strict: must be clean
    assert len(rows) >= 40
    ranks = [r.rank for r in rows]
    assert ranks == sorted(ranks) and len(set(ranks)) == len(ranks)
    for r in rows:
        assert 0 < r.rmax_tflops <= r.rpeak_tflops
        assert r.cpu_cores > 0 and r.processor and r.interconnect


def test_editions_share_machines_and_record_upgrades():
    june = {r.system: r for r in load_sample(edition="2020_06")}
    nov = {r.system: r for r in load_sample(edition="2020_11")}
    common = set(june) & set(nov)
    assert len(common) >= 30                  # slug-matched drift basis
    # the Nov list records Fugaku's expansion and Selene's doubling
    assert nov["Fugaku"].rmax_tflops > june["Fugaku"].rmax_tflops
    assert nov["Selene"].cores == 2 * june["Selene"].cores
    assert "JUWELS Booster Module" in set(nov) - set(june)
    assert "K computer" in set(june) - set(nov)
    # every Nov row infers a platform (no new vocab fell outside the
    # CPU/fabric family rules)
    plats = infer_platforms(nov.values())
    assert len(plats) == len(nov)


def test_unknown_sample_edition_hints_close_match():
    with pytest.raises(ValueError,
                       match=r"unknown sample edition '2020_12'; did "
                             r"you mean: 2020_11"):
        sample_list_path("2020_12")
    with pytest.raises(ValueError, match=r"vendored: 2020_06, 2020_11"):
        sample_list_path("1993")
