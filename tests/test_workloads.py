"""Workload layer: registry + spec round trips, HPL/transformer parity
with the pre-layer plumbing, DES-vs-stepsim cross-validation on registry
platforms (the transformer mirror of test_platforms' HPL bound),
compile-once sweeps, the generic what-if grid, the workload-routing
prediction service, and the TOP500 DES-bridge calibration path."""
import dataclasses

import pytest

from repro.core.apps.hpl import HPLConfig, HPLSim
from repro.core.fastsim import simulate_hpl_fast
from repro.platforms import Platform, get_platform
from repro.workloads import (HPLWorkload, StepParams, TransformerWorkload,
                             Workload, WorkloadSpec, get_workload,
                             list_workloads, sweep_step, trace_count,
                             workload_from_spec)

TORUS_PLATFORMS = ("tpu-v5e-pod", "syn-torus-fugaku-4k", "syn-torus-bgq-8k")
SMALL = dict(mesh=(2, 4), num_layers=3)     # 8-rank DES probes


# ---------------------------------------------------------------- registry

def test_registry_lists_both_workloads():
    assert {"hpl", "transformer"} <= set(list_workloads())
    assert isinstance(get_workload("hpl"), HPLWorkload)
    assert isinstance(get_workload("transformer"), TransformerWorkload)


def test_registry_unknown_name_suggests_close_matches():
    with pytest.raises(KeyError, match="transformer"):
        get_workload("transformre")
    with pytest.raises(KeyError, match="registered"):
        get_workload("stencil")


def test_workload_from_spec_and_param_overrides():
    spec = WorkloadSpec.make("hpl", N=2048, nb=128, P=2, Q=4)
    wl = workload_from_spec(spec)
    assert isinstance(wl, HPLWorkload)
    assert wl.config(get_platform("bdw-local")) == HPLConfig(
        N=2048, nb=128, P=2, Q=4, bcast="1ring")
    wl2 = get_workload("hpl", spec=spec, Q=2)
    assert wl2.spec.get("Q") == 2 and wl2.spec.get("N") == 2048
    with pytest.raises(ValueError, match="kind"):
        TransformerWorkload(spec=spec)


# ------------------------------------------------------------ spec as data

def test_workload_spec_round_trip_and_normalization():
    s = WorkloadSpec.make("transformer", mesh=[4, 8], num_layers=6)
    assert s == WorkloadSpec.from_json(s.to_json())
    assert s == WorkloadSpec.from_dict(s.to_dict())
    # list/tuple params normalize equal, and specs hash
    assert s == WorkloadSpec.make("transformer", num_layers=6, mesh=(4, 8))
    assert hash(s) == hash(WorkloadSpec.from_json(s.to_json()))
    with pytest.raises(TypeError, match="JSON-safe"):
        WorkloadSpec.make("hpl", bad=object())


def test_workload_spec_hypothesis_round_trip():
    hypothesis = pytest.importorskip("hypothesis")
    import hypothesis.strategies as st
    from hypothesis import given, settings

    scalars = st.one_of(
        st.none(), st.booleans(), st.integers(-2**40, 2**40),
        st.floats(allow_nan=False, allow_infinity=False), st.text())
    values = st.one_of(scalars, st.lists(scalars, max_size=4))

    @settings(max_examples=50, deadline=None)
    @given(kind=st.text(min_size=1), name=st.text(),
           params=st.dictionaries(st.text(), values, max_size=6))
    def inner(kind, name, params):
        spec = WorkloadSpec.make(kind, name=name, **params)
        assert WorkloadSpec.from_json(spec.to_json()) == spec

    inner()


# ----------------------------------------------------- HPL extraction

def test_hpl_workload_matches_platform_plumbing():
    """The extracted workload must serve exactly what the HPL-specific
    path served: published run, spec-calibrated params."""
    plat = get_platform("tpu-v5e-pod")
    model = get_workload("hpl").fastsim_model(plat)
    direct = simulate_hpl_fast(plat.hpl_config(), plat.fastsim())
    assert model.predict()["time_s"] == pytest.approx(direct["time_s"],
                                                      rel=1e-9)
    res = get_workload("hpl").predict(plat)
    assert res["gflops"] == pytest.approx(direct["gflops"], rel=1e-9)


def test_hpl_workload_des_matches_hplsim():
    plat = get_platform("bdw-local")
    wl = get_workload("hpl", N=1536, nb=128, P=2, Q=4, lookahead=0)
    res = wl.predict_des(plat)
    direct = HPLSim(HPLConfig(N=1536, nb=128, P=2, Q=4, lookahead=0),
                    plat).run()
    assert res["time_s"] == pytest.approx(direct.time_s, rel=1e-12)


def test_hpl_workload_validates_capacity():
    wl = get_workload("hpl", N=4096, nb=128, P=64, Q=64)
    with pytest.raises(ValueError, match="ranks"):
        wl.validate(get_platform("bdw-local"))


# ------------------------------------------- transformer over platforms

def test_transformer_geometry_from_fabric():
    wl = get_workload("transformer")
    assert wl.geometry(get_platform("tpu-v5e-pod")) == ((16, 16), 1)
    assert wl.geometry(get_platform("syn-torus-fugaku-4k")) == ((256, 16), 1)
    assert wl.geometry(get_platform("syn-mp-2pod-v5e")) == ((16, 16), 2)
    with pytest.raises(ValueError, match="fat-tree"):
        wl.geometry(get_platform("frontera"))
    with pytest.raises(ValueError, match="rows, cols"):
        get_workload("transformer", mesh=[2, 4, 4]).geometry(
            get_platform("tpu-v5e-pod"))
    with pytest.raises(ValueError, match="chips"):
        get_workload("transformer", mesh=[64, 64]).validate(
            get_platform("tpu-v5e-pod"))


@pytest.mark.parametrize("name", TORUS_PLATFORMS)
def test_cross_validation_des_vs_stepsim(name):
    """Both transformer backends built from one spec must tell the same
    story — the workload mirror of the <15% HPL bound."""
    plat = get_platform(name)
    wl = get_workload("transformer", **SMALL)
    des = wl.predict_des(plat)
    fast = wl.predict(plat)
    rel = abs(des["step_s"] - fast["step_s"]) / des["step_s"]
    assert rel < 0.15, (name, des["step_s"], fast["step_s"], rel)


def test_cross_validation_multipod_gateway_model():
    """Cross-pod rings funnel through the pod gateway; the analytic
    contention model is approximate — hold it to 30% and to the right
    side (a second pod must cost time in both backends)."""
    plat = get_platform("syn-mp-2pod-v5e")
    wl = get_workload("transformer", **SMALL)
    des = wl.predict_des(plat)
    fast = wl.predict(plat)
    rel = abs(des["step_s"] - fast["step_s"]) / des["step_s"]
    assert rel < 0.30, (des["step_s"], fast["step_s"], rel)
    single = get_workload("transformer", pods=1, **SMALL).predict(plat)
    assert fast["step_s"] > single["step_s"]
    assert des["step_s"] > single["step_s"]


def test_transformer_end_to_end_acceptance():
    """ISSUE acceptance: the one-liner must run end to end."""
    model = get_workload("transformer").fastsim_model(
        get_platform("tpu-v5e-pod"))
    out = model.predict()
    assert out["step_s"] > 0 and 0 < out["mfu"] < 1
    assert out["tokens_per_s"] > 0


# ------------------------------------------------------ batched stepsim

def test_step_sweep_compiles_once_for_16_scenarios():
    """ISSUE acceptance: a single what-if sweep over the transformer
    workload compiles once across >= 16 scenarios."""
    model = get_workload("transformer").fastsim_model(
        get_platform("tpu-v5e-pod"))
    base = model.params
    grid = [dataclasses.replace(base,
                                link_bw=base.link_bw * (1 + 0.1 * i),
                                n_layers=float(2 + i),
                                flops_per_layer=base.flops_per_layer
                                * (1 + 0.05 * i))
            for i in range(18)]
    model.sweep(grid[:18])               # warm the (32,)-lane program
    c0 = trace_count()
    res = model.sweep(grid)
    assert trace_count() - c0 == 0       # fully cached
    assert len(res) == 18
    # cold-cache single compile for a fresh lane count
    c0 = trace_count()
    res2 = model.sweep([dataclasses.replace(g, mem_bw=g.mem_bw * 1.25)
                        for g in grid])
    assert trace_count() - c0 <= 1
    for r, r2 in zip(res, res2):
        assert r2["time_s"] <= r["time_s"] + 1e-12


def test_step_sweep_matches_singles():
    plat = get_platform("syn-torus-fugaku-4k")
    model = get_workload("transformer").fastsim_model(plat)
    base = model.params
    grid = [dataclasses.replace(base, link_bw=base.link_bw * s)
            for s in (0.5, 1.0, 2.0, 4.0)]
    batched = sweep_step(grid)
    for p, b in zip(grid, batched):
        single = sweep_step([p])[0]
        assert b["time_s"] == pytest.approx(single["time_s"], rel=1e-12)
    # monotone: more bandwidth never slows the step
    times = [b["time_s"] for b in batched]
    assert times == sorted(times, reverse=True)


def test_step_params_gradient_flows():
    jax = pytest.importorskip("jax")
    from jax.experimental import enable_x64
    from repro.workloads import step_time_traced

    model = get_workload("transformer").fastsim_model(
        get_platform("tpu-v5e-pod"))

    def loss(scale):
        p = dataclasses.replace(model.params,
                                link_bw=model.params.link_bw * scale)
        return step_time_traced(p)

    with enable_x64(True):
        g = jax.grad(loss)(1.0)
    assert g < 0                 # faster links -> shorter step


# ------------------------------------------------------ generic what-if

def test_whatif_grid_accepts_workloads_and_legacy_config():
    from repro.core.predict import whatif_grid
    plat = get_platform("tpu-v5e-pod")
    rows = whatif_grid(get_workload("transformer"), plat,
                       {"link_bw": [1.0, 2.0], "mem_bw": [1.0, 1.5]})
    assert len(rows) == 4
    assert rows[0]["speedup"] == pytest.approx(1.0, rel=1e-9)
    assert all(r["speedup"] >= 0.999 for r in rows)
    hrows = whatif_grid(get_workload("hpl"), plat, {"link_bw": [1.0, 2.0]})
    assert hrows[0]["speedup"] == pytest.approx(1.0, rel=1e-9)
    assert "gflops" in hrows[0]
    # legacy (cfg, params) form must behave identically to before
    cfg = plat.hpl_config()
    lrows = whatif_grid(cfg, plat.fastsim(), {"link_bw": [1.0, 2.0]})
    assert lrows[1]["time_s"] == pytest.approx(hrows[1]["time_s"], rel=1e-9)
    with pytest.raises(ValueError, match="platform"):
        whatif_grid(get_workload("hpl"), None, {"link_bw": [1.0]})


# -------------------------------------------------------------- serving

def test_prediction_service_routes_mixed_workloads():
    from repro.serve import PredictionService, WorkloadRequest
    svc = PredictionService()
    out = svc.predict_batch([
        WorkloadRequest(rid=0, workload="hpl", platform="tpu-v5e-pod"),
        WorkloadRequest(rid=1, workload="transformer",
                        platform="tpu-v5e-pod"),
        WorkloadRequest(rid=2, workload="hpl", platform="frontera"),
    ])
    assert set(out) == {0, 1, 2}
    plat = get_platform("tpu-v5e-pod")
    assert out[0]["time_s"] == pytest.approx(
        get_workload("hpl").predict(plat)["time_s"], rel=1e-9)
    assert out[1]["step_s"] == pytest.approx(
        get_workload("transformer").predict(plat)["step_s"], rel=1e-9)
    # one wave, one sweep per workload family
    assert svc.stats["batches"] == 1 and svc.stats["sweeps"] == 2


def test_prediction_service_all_or_nothing_and_breakdown_guard():
    from repro.serve import PredictionService, WorkloadRequest
    svc = PredictionService()
    with pytest.raises(KeyError, match="unknown platform"):
        svc.predict_batch([
            WorkloadRequest(rid=0, workload="hpl", platform="tpu-v5e-pod"),
            WorkloadRequest(rid=1, workload="hpl", platform="nope"),
        ])
    assert not svc._queue and svc.stats["requests"] == 0
    with pytest.raises(ValueError, match="max_des_ranks"):
        svc.predict_batch([WorkloadRequest(
            rid=0, workload="transformer", platform="syn-torus-fugaku-4k",
            breakdown=True)])        # default mesh = 4096 DES ranks
    out = svc.predict_batch([WorkloadRequest(
        rid=7, workload="transformer", platform="tpu-v5e-pod",
        params={"mesh": [2, 4], "num_layers": 2}, breakdown=True)])
    assert out[7]["breakdown"]["n_ranks"] == 8   # trace summary attached
    assert svc.predict_batch([]) == {}


# ------------------------------------------- TOP500 DES-bridge path

def test_calibrate_against_des_records_provenance():
    from repro.top500 import (calibrate_against_des, infer_platforms,
                              load_sample, predict_fleet)
    rows = load_sample()[:3]
    plats = infer_platforms(rows)
    res = calibrate_against_des(plats, steps=6)
    assert len(res.platforms) == len(plats)
    for plat in res.platforms:
        cal = plat.calibration_dict
        # the audit trail's applied table matches what was baked in
        fam = next(f for f, t in res.tables.items() if t == cal)
        assert res.donors[fam] and res.fits[fam]
        assert {"bcast_bw_scale", "swap_bw_scale"} <= set(cal)
        assert all(0.01 < v < 50.0 for v in cal.values())
        prov = plat.provenance_dict["calibration"]
        assert prov.startswith("des-bridge:")
        # calibrated spec stays serializable data
        assert Platform.from_dict(plat.to_dict()) == plat
    # the DES-bridge record survives a later residual pass
    report = predict_fleet(res.platforms, calibrate=True)
    for e in report.entries:
        assert e.platform.provenance_dict["calibration"].startswith(
            "des-bridge:")


def test_family_factor_path_records_provenance():
    from repro.top500 import infer_platforms, load_sample, predict_fleet
    rows = load_sample()[:6]
    report = predict_fleet(infer_platforms(rows), calibrate=True)
    for e in report.entries:
        assert e.platform.provenance_dict["calibration"] == "family-factor"
