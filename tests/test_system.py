"""End-to-end behaviour tests for the full system (paper + substrate)."""
import dataclasses
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, reduced


def test_train_resume_is_bit_deterministic(tmp_path):
    """Train 6 steps; train 3 + restart + 3 — identical final params
    (checkpoint/restart correctness, the FT cornerstone)."""
    from repro.train.loop import train
    cfg = reduced(get_config("qwen2-0.5b"))
    r_straight = train(cfg, steps=6, global_batch=2, seq_len=32,
                       log_every=100, log_fn=lambda s: None)
    d1 = tmp_path / "ck"
    train(cfg, steps=3, global_batch=2, seq_len=32, ckpt_dir=d1,
          ckpt_every=3, log_every=100, log_fn=lambda s: None)
    r_resumed = train(cfg, steps=6, global_batch=2, seq_len=32, ckpt_dir=d1,
                      ckpt_every=100, log_every=100, log_fn=lambda s: None)
    a = jax.tree.leaves(r_straight["state"].params)
    b = jax.tree.leaves(r_resumed["state"].params)
    for x, y in zip(a, b):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


def test_train_loss_decreases_meaningfully():
    from repro.train.loop import train
    cfg = reduced(get_config("qwen2-0.5b"))
    res = train(cfg, steps=25, global_batch=4, seq_len=64, lr=1e-3,
                log_every=100, log_fn=lambda s: None)
    assert res["final_loss"] < res["first_loss"] - 0.2


def test_serve_engine_greedy_matches_manual_decode(rng):
    from repro.models import build_model
    from repro.serve.engine import Request, ServeEngine
    cfg = dataclasses.replace(reduced(get_config("qwen2-0.5b")),
                              dtype="float32")
    model = build_model(cfg)
    params = model.init(rng)
    prompt = np.asarray(
        jax.random.randint(rng, (16,), 0, cfg.vocab_size), np.int32)
    eng = ServeEngine(cfg, params, batch_slots=1, max_len=32)
    out = eng.run([Request(rid=0, prompt=prompt, max_new_tokens=6)])[0]
    # manual: prefill + greedy loop
    cache, lg = jax.jit(lambda p, b: model.prefill(p, b, max_len=32))(
        params, {"tokens": jnp.asarray(prompt)[None]})
    toks = [int(jnp.argmax(lg[0, :cfg.vocab_size]))]
    for _ in range(5):
        cache, lg = jax.jit(model.decode)(
            params, cache, jnp.asarray([[toks[-1]]], jnp.int32))
        toks.append(int(jnp.argmax(lg[0, :cfg.vocab_size])))
    assert out == toks


def test_straggler_monitor_flags():
    from repro.ft import StepTimeMonitor
    mon = StepTimeMonitor(threshold=1.5, warmup=3)
    for _ in range(10):
        assert not mon.record(0.1)
    assert mon.record(0.5)
    assert mon.flags


def test_grad_compression_trains():
    from repro.train.step import make_train_state, make_train_step
    cfg = reduced(get_config("qwen2-0.5b"))
    state = make_train_state(cfg, jax.random.PRNGKey(0))
    step_fn, _ = make_train_step(cfg, lr=1e-3, grad_compression=True)
    batch = {"tokens": jax.random.randint(jax.random.PRNGKey(1), (2, 32),
                                          0, cfg.vocab_size)}
    step = jax.jit(step_fn)
    state, m1 = step(state, batch)
    state, m2 = step(state, batch)
    assert float(m2["loss"]) < float(m1["loss"])


def test_microbatched_grad_accumulation_matches_full():
    from repro.train.step import make_train_state, make_train_step
    cfg = dataclasses.replace(reduced(get_config("qwen2-0.5b")),
                              dtype="float32")
    s0 = make_train_state(cfg, jax.random.PRNGKey(0))
    batch = {"tokens": jax.random.randint(jax.random.PRNGKey(1), (4, 32),
                                          0, cfg.vocab_size)}
    f1, _ = make_train_step(cfg, lr=1e-3, microbatches=1)
    f2, _ = make_train_step(cfg, lr=1e-3, microbatches=2)
    s1, m1 = jax.jit(f1)(s0, batch)
    s2, m2 = jax.jit(f2)(s0, batch)
    for a, b in zip(jax.tree.leaves(s1.params), jax.tree.leaves(s2.params)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=2e-3)


@pytest.mark.slow
def test_pipeline_parallel_subprocess():
    code = r"""
import os
os.environ['XLA_FLAGS']='--xla_force_host_platform_device_count=2'
import jax, jax.numpy as jnp, numpy as np
from repro.sharding.pipeline import pipeline_forward
mesh = jax.make_mesh((2,), ('pod',))
W = jax.random.normal(jax.random.PRNGKey(0), (2, 16, 16)) * 0.3
x = jax.random.normal(jax.random.PRNGKey(1), (8, 16))
y = pipeline_forward(lambda w, xm: jnp.tanh(xm @ w), W, x, mesh=mesh, n_micro=4)
ref = jnp.tanh(jnp.tanh(x @ W[0]) @ W[1])
np.testing.assert_allclose(np.asarray(y), np.asarray(ref), atol=1e-5)
print('OK')
"""
    res = subprocess.run([sys.executable, "-c", code], capture_output=True,
                         text=True, timeout=600,
                         env={**__import__("os").environ,
                              "PYTHONPATH": "src"})
    assert res.returncode == 0 and "OK" in res.stdout, res.stderr[-2000:]


def test_simulator_predicts_from_record(tmp_path):
    """SimXLA prediction from a synthetic dry-run record."""
    import json
    from repro.core.predict import predict_cell
    rec = {"arch": "x", "shape": "train_4k", "mesh": "16x16", "chips": 256,
           "kind": "train",
           "roofline": {"hlo_flops_total": 2.56e17,
                        "hlo_bytes_total": 2.56e14},
           "collectives": {"all-reduce": {"count": 10,
                                          "wire_bytes": 1e9}}}
    (tmp_path / "x__train_4k__16x16.json").write_text(json.dumps(rec))
    p = predict_cell("x", "train_4k", dryrun_dir=tmp_path)
    assert p.step_s > 0
    assert p.compute_s == pytest.approx(1e15 / (197e12 * 0.9), rel=1e-6)


def test_straggler_des_whatif_blowup():
    """A 4x-slow chip must blow up the synchronous step time (DES)."""
    from repro.core.apps.transformer import LayerWork, StepWorkload, \
        TransformerStepSim
    wl = StepWorkload(layers=[LayerWork(1e-3, [("all-reduce", 1e6, "model")])
                              for _ in range(4)],
                      tail_collectives=[("all-reduce", 1e7, "data")])
    base = TransformerStepSim(wl, mesh=(4, 4)).run()
    slow = TransformerStepSim(wl, mesh=(4, 4), straggler=(5, 4.0)).run()
    assert slow["step_s"] > 2.0 * base["step_s"]
