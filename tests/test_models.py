"""Per-arch smoke tests (reduced configs, one forward/train step on CPU,
shape + finiteness assertions) and decode consistency."""
import dataclasses
import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, get_config, reduced
from repro.models import build_model
from repro.models.api import make_batch
from repro.configs.base import ShapeConfig
from repro.train.step import make_train_state, make_train_step

ALL_ARCHS = sorted(ARCHS)


def _smoke_batch(cfg, key, b=2, s=64):
    batch = {"tokens": jax.random.randint(key, (b, s), 0, cfg.vocab_size)}
    if cfg.family == "encdec":
        batch["encoder_embeds"] = jax.random.normal(
            key, (b, cfg.encoder_seq, cfg.d_model)) * 0.1
    if cfg.family == "vlm":
        batch["image_embeds"] = jax.random.normal(
            key, (b, cfg.n_image_tokens, cfg.d_model)) * 0.1
    return batch


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_smoke_forward_shapes(arch, rng):
    cfg = reduced(get_config(arch))
    model = build_model(cfg)
    params = model.init(rng)
    batch = _smoke_batch(cfg, rng)
    logits, _ = jax.jit(model.forward)(params, batch)
    b = batch["tokens"].shape[0]
    s = batch["tokens"].shape[1] + (cfg.n_image_tokens or 0)
    assert logits.shape == (b, s, cfg.vocab_padded)
    assert bool(jnp.isfinite(logits).all()), arch


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_smoke_train_step(arch, rng):
    cfg = reduced(get_config(arch))
    state = make_train_state(cfg, rng)
    step_fn, _ = make_train_step(cfg, lr=1e-3)
    batch = _smoke_batch(cfg, rng)
    step = jax.jit(step_fn)
    state, m1 = step(state, batch)
    state, m2 = step(state, batch)
    assert math.isfinite(float(m1["loss"]))
    assert float(m2["loss"]) < float(m1["loss"]), \
        f"{arch}: loss did not decrease {m1['loss']} -> {m2['loss']}"


@pytest.mark.parametrize("arch", ["qwen2-0.5b", "mamba2-780m",
                                  "zamba2-2.7b", "whisper-medium",
                                  "granite-34b", "llava-next-mistral-7b"])
def test_decode_matches_teacher_forcing(arch, rng):
    cfg = dataclasses.replace(reduced(get_config(arch)), dtype="float32")
    model = build_model(cfg)
    params = model.init(rng)
    S = 32
    batch = _smoke_batch(cfg, rng, b=2, s=S)
    logits, _ = jax.jit(model.forward)(params, batch)
    pre = dict(batch, tokens=batch["tokens"][:, :S - 1])
    n_img = cfg.n_image_tokens or 0
    cache, lg_pre = jax.jit(
        lambda p, b: model.prefill(p, b, max_len=n_img + S))(params, pre)
    scale = float(jnp.max(jnp.abs(logits)))
    np.testing.assert_allclose(np.asarray(lg_pre),
                               np.asarray(logits[:, n_img + S - 2]),
                               atol=2e-3 * scale)
    cache, lg_dec = jax.jit(model.decode)(params, cache,
                                          batch["tokens"][:, S - 1:S])
    np.testing.assert_allclose(np.asarray(lg_dec),
                               np.asarray(logits[:, n_img + S - 1]),
                               atol=2e-3 * scale)


@pytest.mark.parametrize("arch", ["phi3.5-moe-42b-a6.6b",
                                  "qwen3-moe-235b-a22b"])
def test_moe_decode_consistency_no_drops(arch, rng):
    cfg0 = reduced(get_config(arch))
    cfg = dataclasses.replace(
        cfg0, dtype="float32",
        moe=dataclasses.replace(cfg0.moe, capacity_factor=8.0))
    model = build_model(cfg)
    params = model.init(rng)
    S = 32
    batch = {"tokens": jax.random.randint(rng, (2, S), 0, cfg.vocab_size)}
    logits, _ = jax.jit(model.forward)(params, batch)
    cache, lg_pre = jax.jit(
        lambda p, b: model.prefill(p, b, max_len=S))(
            params, {"tokens": batch["tokens"][:, :S - 1]})
    np.testing.assert_allclose(np.asarray(lg_pre),
                               np.asarray(logits[:, S - 2]), atol=1e-3)


def test_vocab_padding_masked(rng):
    cfg = reduced(get_config("mamba2-780m"))  # vocab 512 pads cleanly? force odd
    cfg = dataclasses.replace(cfg, vocab_size=500)
    model = build_model(cfg)
    params = model.init(rng)
    batch = {"tokens": jax.random.randint(rng, (1, 16), 0, 500)}
    logits, _ = jax.jit(model.forward)(params, batch)
    assert logits.shape[-1] == cfg.vocab_padded == 512
    pad = logits[..., 500:]
    assert bool((pad <= -1e29).all()), "pad logits must be -inf-masked"


def test_param_count_analytical_close(rng):
    """cfg.n_params() (used for 6ND roofline) tracks actual init counts."""
    for arch in ["qwen2-0.5b", "mamba2-780m", "phi3.5-moe-42b-a6.6b"]:
        cfg = reduced(get_config(arch))
        model = build_model(cfg)
        actual = sum(np.prod(x.shape) for x in
                     jax.tree.leaves(jax.eval_shape(model.init, rng)))
        est = cfg.n_params()
        assert abs(actual - est) / actual < 0.30, \
            (arch, actual, est)
