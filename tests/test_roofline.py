"""HLO analyzer tests on synthetic programs with known costs."""
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.roofline.hlo_parse import analyze, parse_hlo_module
from repro.roofline.analysis import roofline_terms, model_flops
from repro.configs import get_config, get_shape


def _compile_text(fn, *args):
    return jax.jit(fn).lower(*args).compile().as_text()


def test_single_matmul_flops():
    a = jax.ShapeDtypeStruct((512, 512), jnp.float32)
    text = _compile_text(lambda x, y: x @ y, a, a)
    r = analyze(text)
    expect = 2 * 512 ** 3
    assert abs(r["flops"] - expect) / expect < 0.05, r["flops"]


def test_scan_trip_count_multiplies():
    a = jax.ShapeDtypeStruct((256, 256), jnp.float32)

    def g(x, y):
        def body(c, _):
            return c @ y, ()
        out, _ = jax.lax.scan(body, x, None, length=12)
        return out
    r = analyze(_compile_text(g, a, a))
    expect = 12 * 2 * 256 ** 3
    assert abs(r["flops"] - expect) / expect < 0.05, r["flops"]


def test_nested_scan_multiplies():
    a = jax.ShapeDtypeStruct((128, 128), jnp.float32)

    def g(x, y):
        def outer(c, _):
            def inner(c2, _):
                return c2 @ y, ()
            c2, _ = jax.lax.scan(inner, c, None, length=3)
            return c2, ()
        out, _ = jax.lax.scan(outer, x, None, length=5)
        return out
    r = analyze(_compile_text(g, a, a))
    expect = 15 * 2 * 128 ** 3
    assert abs(r["flops"] - expect) / expect < 0.05, r["flops"]


def test_bytes_reasonable_for_elementwise():
    a = jax.ShapeDtypeStruct((1 << 20,), jnp.float32)
    r = analyze(_compile_text(lambda x: x * 2 + 1, a))
    # read + write of 4 MiB within 4x (fusion boundaries)
    assert 4e6 <= r["bytes"] <= 64e6, r["bytes"]


def test_roofline_terms_dominance():
    t = roofline_terms(per_device_flops=1e12, per_device_bytes=1e9,
                       per_device_coll_bytes=1e6, chips=256)
    assert t["dominant"] == "compute"
    t2 = roofline_terms(per_device_flops=1e9, per_device_bytes=1e12,
                        per_device_coll_bytes=1e6, chips=256)
    assert t2["dominant"] == "memory"


def test_model_flops_6nd():
    cfg = get_config("qwen2-0.5b")
    shape = get_shape("train_4k")
    mf = model_flops(cfg, shape)
    n = cfg.n_active_params()
    assert mf == pytest.approx(6.0 * n * shape.tokens)


@pytest.mark.slow
def test_collective_parse_on_sharded_program():
    """Run a tiny sharded program in a subprocess (needs >1 device) and
    check all-reduce wire bytes."""
    code = r"""
import os
os.environ['XLA_FLAGS']='--xla_force_host_platform_device_count=8'
import jax, jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.roofline.hlo_parse import analyze
mesh = jax.make_mesh((8,), ('x',))
a = jax.ShapeDtypeStruct((1024, 1024), jnp.float32)
f = jax.jit(lambda a, b: a @ b,
            in_shardings=(NamedSharding(mesh, P(None, 'x')),
                          NamedSharding(mesh, P('x', None))),
            out_shardings=NamedSharding(mesh, P(None, None)))
r = analyze(f.lower(a, a).compile().as_text())
ar = r['collectives'].get('all-reduce', {'wire_bytes': 0})
expect = 2 * 7 / 8 * 1024 * 1024 * 4
assert abs(ar['wire_bytes'] - expect) / expect < 0.05, ar
print('OK')
"""
    res = subprocess.run([sys.executable, "-c", code], capture_output=True,
                         text=True, timeout=300)
    assert res.returncode == 0 and "OK" in res.stdout, res.stderr[-2000:]
