"""Shared fixtures.  NOTE: no XLA_FLAGS here — smoke tests and benches see
the real single CPU device; only launch/dryrun.py (a subprocess) forces
512 host devices."""
import dataclasses

import jax
import pytest

from repro.configs import get_config, reduced


def pytest_configure(config):
    config.addinivalue_line(
        "markers", "slow: paper-scale simulations (minutes, not seconds)")


@pytest.fixture(scope="session")
def rng():
    return jax.random.PRNGKey(0)


def tiny(name: str, **over):
    cfg = reduced(get_config(name))
    if over:
        cfg = dataclasses.replace(cfg, **over)
    return cfg
