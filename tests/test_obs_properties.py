"""Property tests for the metrics merge algebra (repro.obs).

The fleet/shard aggregation story rests on snapshot merge being a
commutative monoid (empty registry as identity): CI shards, serving
replicas and fleet runs can be folded in any order, any grouping, and
the dashboard sees one truth.  Hypothesis drives random instrument
histories through snapshot -> JSON -> merge and checks:

  * JSON round-trip is lossless (snapshot == from_json(to_json));
  * merge is commutative and associative on snapshots;
  * the empty snapshot is the merge identity;
  * merged counters/histogram counts equal the sums of their parts.

Runs under CI's hypothesis install; skipped locally when hypothesis is
absent (the container does not ship it).
"""
import json

import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.obs import MetricsRegistry, merge_snapshots  # noqa: E402

NAMES = ["serve.requests", "engine.events", "fleet.machines", "lat"]
LABELS = [{}, {"kind": "hpl"}, {"kind": "tf", "zone": "a"}]
# one bounds tuple per histogram name so any two histories merge
BOUNDS = {"lat": (0.001, 0.1, 1.0), "engine.events": (10.0, 100.0)}

# integer-valued floats: exact in IEEE754, so float sums stay
# associative and snapshot equality is exact (real metric values are
# approximately-merged the same way, just without bit-exact equality)
finite = st.integers(min_value=0, max_value=10**6).map(float)

op = st.one_of(
    st.tuples(st.just("counter"), st.sampled_from(NAMES),
              st.sampled_from(LABELS), finite),
    st.tuples(st.just("gauge"), st.sampled_from(NAMES),
              st.sampled_from(LABELS), finite),
    st.tuples(st.just("hist"), st.sampled_from(sorted(BOUNDS)),
              st.sampled_from(LABELS), finite),
)


def build(ops):
    m = MetricsRegistry()
    for kind, name, labels, v in ops:
        if kind == "counter":
            m.counter(name, **labels).inc(v)
        elif kind == "gauge":
            m.gauge(name, **labels).set(v)
        else:
            m.histogram(name, buckets=BOUNDS[name], **labels).observe(v)
    return m


history = st.lists(op, max_size=30)


@settings(max_examples=60, deadline=None)
@given(history)
def test_json_round_trip_is_lossless(ops):
    m = build(ops)
    back = MetricsRegistry.from_json(m.to_json())
    assert back.snapshot() == m.snapshot()
    assert json.loads(m.to_json()) == m.snapshot()


@settings(max_examples=60, deadline=None)
@given(history, history)
def test_merge_commutes(ops_a, ops_b):
    a, b = build(ops_a).snapshot(), build(ops_b).snapshot()
    assert merge_snapshots(a, b) == merge_snapshots(b, a)


@settings(max_examples=60, deadline=None)
@given(history, history, history)
def test_merge_is_associative(ops_a, ops_b, ops_c):
    a, b, c = (build(o).snapshot() for o in (ops_a, ops_b, ops_c))
    assert merge_snapshots(merge_snapshots(a, b), c) == \
        merge_snapshots(a, merge_snapshots(b, c))


@settings(max_examples=60, deadline=None)
@given(history)
def test_empty_snapshot_is_identity(ops):
    a = build(ops).snapshot()
    empty = MetricsRegistry().snapshot()
    assert merge_snapshots(a, empty) == a
    assert merge_snapshots(empty, a) == a


@settings(max_examples=60, deadline=None)
@given(history, history)
def test_merged_totals_are_sums(ops_a, ops_b):
    a, b = build(ops_a).snapshot(), build(ops_b).snapshot()
    m = merge_snapshots(a, b)
    for key, v in m["counters"].items():
        assert v == pytest.approx(a["counters"].get(key, 0.0)
                                  + b["counters"].get(key, 0.0))
    for key, hv in m["histograms"].items():
        ca = a["histograms"].get(key, {}).get("count", 0)
        cb = b["histograms"].get(key, {}).get("count", 0)
        assert hv["count"] == ca + cb
