"""Sharding rules: resolution, legalization, scheme selection — and one
real (reduced-mesh) dry-run through a subprocess."""
import subprocess
import sys

import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import get_config
from repro.sharding.specs import make_rules, resolve, scheme_for


def test_scheme_selection():
    assert scheme_for(get_config("granite-34b"), 16) == "tp"      # R=48
    assert scheme_for(get_config("stablelm-3b"), 16) == "tp"      # G=32
    assert scheme_for(get_config("qwen3-moe-235b-a22b"), 16) == "tp"  # R=16
    assert scheme_for(get_config("qwen2-0.5b"), 16) == "sp"       # G=2,R=7
    assert scheme_for(get_config("minitron-8b"), 16) == "sp"      # G=8,R=4
    assert scheme_for(get_config("mamba2-780m"), 16) == "tp"      # ssm


def test_resolve_dedups_axes():
    rules = {"a": ("model",), "b": ("model",), "c": ("data", "model")}
    spec = resolve(("a", "b"), rules)
    assert spec == P("model", None)
    spec2 = resolve(("c", None), rules)
    assert spec2 == P(("data", "model"), None)


def test_rules_decode_small_batch_replicates_dp():
    cfg = get_config("zamba2-2.7b")
    rules = make_rules(cfg, mode="serve", global_batch=1)
    assert rules["dp"] == ()
    rules2 = make_rules(cfg, mode="serve", global_batch=128)
    assert rules2["dp"] == ("data",)


def test_legalize_drops_nondivisible_axes():
    import jax
    from repro.sharding.specs import legalize
    mesh = jax.make_mesh((1, 1), ("data", "model"))

    class FakeMesh:
        shape = {"data": 16, "model": 16}
    ps = legalize(P(("data", "model"), None), (896, 7), FakeMesh())
    assert ps == P("data", None)       # 896 % 256 != 0 but % 16 == 0
    ps2 = legalize(P("model",), (50280,), FakeMesh())
    assert ps2 == P(None)              # 50280 % 16 != 0


@pytest.mark.slow
def test_dryrun_cell_compiles_end_to_end(tmp_path):
    """The real deliverable-(e) path on the production 16x16 mesh."""
    res = subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun", "--arch",
         "mamba2-780m", "--shape", "decode_32k", "--out", str(tmp_path)],
        capture_output=True, text=True, timeout=1200,
        env={**__import__("os").environ, "PYTHONPATH": "src"})
    assert res.returncode == 0, res.stderr[-3000:]
    out = list(tmp_path.glob("*.json"))
    assert out, "no dry-run record written"
