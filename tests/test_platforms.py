"""Platform layer: registry integrity, both-backend builds, DES vs
fastsim cross-validation on every registry machine, and the DES->fastsim
calibration bridge (Table II acceptance band)."""
import dataclasses

import pytest

from repro.core.apps.hpl import HPLConfig, HPLSim
from repro.core.fastsim import FastSimParams, simulate_hpl_fast
from repro.core.hardware.node import NodeModel
from repro.core.hardware.topology import Topology
from repro.platforms import (Platform, get_platform, list_platforms)

ALL_NAMES = list_platforms()

# Expected registry backbone (the paper's machines + fabric diversity).
PAPER_NAMES = {"bdw-local", "frontera", "pupmaya", "paper-fat-tree-10008",
               "tpu-v5e-pod"}


def _small_cfg(plat: Platform) -> HPLConfig:
    """N~2k probe sized to the platform: 8 ranks spread over >= 2 nodes."""
    rpn = plat.scale.ranks_per_node
    P, Q = 2, 4
    assert P * Q <= plat.scale.n_ranks
    assert P * Q > rpn or rpn == 1      # spans nodes, not one self-send box
    return HPLConfig(N=2048, nb=128, P=P, Q=Q, lookahead=0,
                     bcast=plat.mpi.bcast)


# ---------------------------------------------------------------- registry

def test_registry_contains_paper_machines_and_fabric_diversity():
    assert PAPER_NAMES <= set(ALL_NAMES)
    assert len(ALL_NAMES) >= 13
    kinds = {get_platform(n).fabric.kind for n in ALL_NAMES}
    assert {"fat-tree", "dragonfly", "torus", "multipod"} <= kinds


def test_specs_are_frozen():
    plat = get_platform("frontera")
    with pytest.raises(dataclasses.FrozenInstanceError):
        plat.name = "x"
    with pytest.raises(dataclasses.FrozenInstanceError):
        plat.node.peak_flops = 1.0
    with pytest.raises(dataclasses.FrozenInstanceError):
        plat.scale.n_nodes = 2


@pytest.mark.parametrize("name", ALL_NAMES)
def test_spec_serialization_round_trip(name):
    plat = get_platform(name)
    assert Platform.from_dict(plat.to_dict()) == plat
    assert Platform.from_json(plat.to_json()) == plat


@pytest.mark.parametrize("name", ALL_NAMES)
def test_platform_builds_both_backends(name):
    plat = get_platform(name)
    stack = plat.des()
    assert isinstance(stack.node, NodeModel)
    assert isinstance(stack.topology, Topology)
    assert stack.topology.n_links > 0
    assert stack.ranks_per_node >= 1
    # grid fits the machine
    P, Q = plat.scale.grid
    assert 0 < P * Q <= plat.scale.n_ranks
    prm = plat.fastsim()
    assert isinstance(prm, FastSimParams)
    for field in ("peak_flops", "mem_bw", "link_bw", "gemm_eff"):
        assert getattr(prm, field) > 0, field
    cfg = plat.hpl_config()
    assert cfg.n_ranks == P * Q


def test_registry_unknown_name_suggests_close_matches():
    # a near-miss gets a difflib suggestion, not a registry dump
    with pytest.raises(KeyError, match="frontera"):
        get_platform("fronterra")
    with pytest.raises(KeyError, match="platforms registered"):
        get_platform("no-such-machine")


def test_hplsim_accepts_platform_and_matches_explicit_build():
    plat = get_platform("bdw-local")
    cfg = _small_cfg(plat)
    via_platform = HPLSim(cfg, plat).run()
    stack = plat.des()
    explicit = HPLSim(cfg, stack.node, stack.topology,
                      ranks_per_node=stack.ranks_per_node,
                      mpi_overhead=stack.mpi_overhead).run()
    assert via_platform.time_s == pytest.approx(explicit.time_s, rel=1e-12)


def test_hplsim_rejects_overcommitted_platform():
    plat = get_platform("bdw-local")        # 16 nodes
    cfg = HPLConfig(N=4096, nb=128, P=8, Q=8)
    with pytest.raises(ValueError, match="ranks"):
        HPLSim(cfg, plat)


def test_with_calibration_merges_and_applies():
    plat = get_platform("frontera")
    cal = plat.with_calibration({"bcast_bw_scale": 0.5})
    assert cal.fastsim().bcast_bw_scale == pytest.approx(0.5)
    assert cal.fastsim(calibrated=False).bcast_bw_scale == \
        plat.fastsim(calibrated=False).bcast_bw_scale
    # original spec untouched; round trip preserves the table
    assert plat.fastsim().bcast_bw_scale != pytest.approx(0.5) or \
        not plat.calibration
    assert Platform.from_dict(cal.to_dict()) == cal


# ------------------------------------------------- DES/fastsim agreement

@pytest.mark.parametrize("name", ALL_NAMES)
def test_cross_validation_des_vs_fastsim(name):
    """Both backends built from one spec must tell the same story:
    GFLOPS within 15% on a small config for every registry machine."""
    plat = get_platform(name)
    cfg = _small_cfg(plat)
    des = HPLSim(cfg, plat).run()
    prm = dataclasses.replace(plat.fastsim(), lookahead=0.0)
    fast = simulate_hpl_fast(cfg, prm)
    rel = abs(des.gflops - fast["gflops"]) / des.gflops
    assert rel < 0.15, (name, des.gflops, fast["gflops"], rel)


# -------------------------------------------------- calibration bridge

@pytest.mark.slow
def test_bridge_fits_contention_scales_to_des():
    from repro.platforms import fit_fastsim_to_des
    plat = get_platform("bdw-local")
    bridge = fit_fastsim_to_des(plat, steps=40)
    assert bridge.fit.loss <= bridge.fit.loss0 * 1.001
    cal = bridge.platform.calibration_dict
    assert set(cal) == {"bcast_bw_scale", "swap_bw_scale"}
    for v in cal.values():
        assert 0.05 < v < 20.0          # sane contention scales
    # the calibrated spec is serializable with its fitted table
    assert Platform.from_dict(bridge.platform.to_dict()) == bridge.platform


@pytest.mark.slow
def test_bridge_frontera_reproduces_table2_within_5pct():
    """Acceptance: fit_fastsim_to_des on Frontera's spec must reproduce
    Table 2's predicted GFLOPS within 5% of the uncalibrated path."""
    from repro.platforms import fit_fastsim_to_des
    plat = get_platform("frontera")
    cfg = plat.hpl_config()
    baseline = simulate_hpl_fast(cfg, plat.fastsim(calibrated=False))
    bridged = fit_fastsim_to_des(plat, steps=40)
    calibrated = simulate_hpl_fast(cfg, bridged.platform.fastsim())
    rel = abs(calibrated["gflops"] - baseline["gflops"]) \
        / baseline["gflops"]
    assert rel < 0.05, (baseline["gflops"], calibrated["gflops"], rel)


# ------------------------------------------------------ serving by name

def test_service_serves_platform_names():
    from repro.serve import HPLPredictionService, PredictRequest
    svc = HPLPredictionService()
    cfg = HPLConfig(N=2048, nb=128, P=2, Q=4)
    out = svc.predict_platforms(["frontera", "pupmaya", "tpu-v5e-pod"],
                                cfg=cfg)
    assert set(out) == {"frontera", "pupmaya", "tpu-v5e-pod"}
    for name in out:
        expect = simulate_hpl_fast(cfg, get_platform(name).fastsim())
        assert out[name]["time_s"] == pytest.approx(expect["time_s"],
                                                    rel=1e-6)
    # a platform-name request with no cfg serves the published run shape
    req = PredictRequest(rid=7, platform="bdw-local")
    res = svc.predict_batch([req])
    plat = get_platform("bdw-local")
    expect = simulate_hpl_fast(plat.hpl_config(), plat.fastsim())
    assert res[7]["time_s"] == pytest.approx(expect["time_s"], rel=1e-6)


def test_service_rejects_unresolvable_request():
    from repro.serve import HPLPredictionService, PredictRequest
    svc = HPLPredictionService()
    with pytest.raises(ValueError, match="platform"):
        svc.submit(PredictRequest(rid=0, cfg=HPLConfig(N=512, nb=128,
                                                       P=1, Q=1)))
