"""Fault-injection subsystem: spec round-trips, seeded bit-identical
replay, faults=None purity, DES injection through both workloads,
DES-vs-fastsim cross-validation, service hardening, and the ft layer's
thin-consumer rewiring (ISSUE 6 acceptance scenarios)."""
import dataclasses
import json
import random

import pytest

from repro.faults import (FASTSIM_KINDS, FAULT_KINDS, Fault, FaultSpec,
                          NO_FAULTS, as_fault_spec)
from repro.platforms import get_platform
from repro.workloads import get_workload

HPL_SMALL = dict(N=1536, nb=128, P=2, Q=4, lookahead=0)
TF_SMALL = dict(mesh=(2, 4), num_layers=3)

# ISSUE 6 acceptance scenario: one straggler chip at 0.5x speed plus
# two-ish degraded links (seeded 5% of the fabric at half bandwidth)
ACCEPTANCE = (FaultSpec.straggler(rank=1, slowdown=2.0, seed=7)
              + FaultSpec.degraded_links(0.05, factor=0.5, seed=7))


# ------------------------------------------------------------- spec data

def test_fault_spec_json_roundtrip():
    spec = FaultSpec(
        faults=(Fault("straggler", rank=3, factor=2.5, start=0.1),
                Fault("fail_stop", node=2),
                Fault("link_degrade", link_frac=0.1, factor=0.25),
                Fault("link_flap", node=1, factor=0.5, period=0.01,
                      duty=0.3, cycles=5),
                Fault("latency_jitter", sigma=0.4)),
        seed=42, name="kitchen-sink")
    assert FaultSpec.from_json(spec.to_json()) == spec
    assert FaultSpec.from_dict(json.loads(spec.to_json())) == spec
    # dict / JSON-string forms normalize through as_fault_spec
    assert as_fault_spec(spec.to_dict()) == spec
    assert as_fault_spec(spec.to_json()) == spec
    # hashable, like every other spec in the repo
    assert hash(spec) == hash(FaultSpec.from_json(spec.to_json()))


def test_fault_spec_fuzzed_roundtrip():
    """Seeded-random fuzz of the JSON round-trip (stdlib stand-in for
    the hypothesis property in test_faults_properties.py)."""
    rng = random.Random(1234)
    for _ in range(200):
        kind = rng.choice(FAULT_KINDS)
        kw = dict(start=rng.uniform(0, 10), duration=rng.uniform(0, 5))
        if kind == "straggler":
            kw.update(rank=rng.randrange(64), factor=rng.uniform(0.1, 8))
        elif kind == "fail_stop":
            kw.update(rank=rng.randrange(64))
        elif kind in ("link_degrade", "link_flap"):
            kw.update(link_frac=rng.uniform(0.01, 1.0),
                      factor=rng.uniform(0.05, 1.0))
            if kind == "link_flap":
                kw.update(period=rng.uniform(1e-4, 1.0),
                          duty=rng.uniform(0.05, 0.95),
                          cycles=rng.randrange(1, 20))
        else:
            kw.update(sigma=rng.uniform(0.01, 0.99))
        spec = FaultSpec(faults=(Fault(kind, **kw),),
                         seed=rng.randrange(1 << 31))
        assert FaultSpec.from_json(spec.to_json()) == spec


def test_fault_validation_rejects_bad_records():
    with pytest.raises(ValueError, match="kind"):
        Fault("meteor_strike")
    with pytest.raises(ValueError, match="rank"):
        Fault("straggler")
    with pytest.raises(ValueError, match="factor"):
        Fault("straggler", rank=0, factor=0.0)
    with pytest.raises(ValueError, match="rank or a node"):
        Fault("fail_stop")
    with pytest.raises(ValueError, match="link_frac"):
        Fault("link_degrade", factor=0.5)
    with pytest.raises(ValueError, match="capacity"):
        Fault("link_degrade", link_frac=0.5, factor=2.0)
    with pytest.raises(ValueError, match="finite"):
        Fault("link_flap", link_frac=0.5, factor=0.5, period=0.1, cycles=0)
    with pytest.raises(ValueError, match="sigma"):
        Fault("latency_jitter", sigma=0.0)


def test_as_fault_spec_normalization():
    assert as_fault_spec(None) is None
    assert as_fault_spec(NO_FAULTS) is None        # empty spec == no faults
    spec = FaultSpec.straggler(rank=0)
    assert as_fault_spec(spec) is spec
    with pytest.raises(TypeError, match="faults must be"):
        as_fault_spec(42)


def test_fault_spec_combinators():
    spec = ACCEPTANCE
    assert len(spec.faults) == 2
    assert spec.seed == 7
    assert [f.kind for f in spec.faults] == ["straggler", "link_degrade"]
    assert spec.fastsim_supported()
    assert not (spec + FaultSpec.fail_stop(rank=0)).fastsim_supported()
    assert set(FASTSIM_KINDS) < set(FAULT_KINDS)


# ------------------------------------------------- DES purity and replay

def test_faults_none_bit_identical_hpl():
    wl = get_workload("hpl", **HPL_SMALL)
    plat = get_platform("bdw-local")
    base = wl.predict_des(plat)
    for faults in (None, NO_FAULTS, FaultSpec()):
        again = wl.predict_des(plat, faults=faults)
        assert again["time_s"] == base["time_s"]       # bit-identical
        assert again["events"] == base["events"]


def test_faults_none_bit_identical_transformer():
    wl = get_workload("transformer", **TF_SMALL)
    plat = get_platform("tpu-v5e-pod")
    base = wl.predict_des(plat)
    again = wl.predict_des(plat, faults=None)
    assert again["time_s"] == base["time_s"]
    assert again["events"] == base["events"]


def test_seeded_replay_bit_identical():
    """The same seeded spec — link sampling AND jitter draws — replays
    to the exact same simulated history, twice."""
    spec = (FaultSpec.degraded_links(0.2, factor=0.4, seed=99)
            + FaultSpec(faults=(Fault("latency_jitter", sigma=0.3),))
            + FaultSpec(faults=(Fault("link_flap", link_frac=0.1,
                                      factor=0.5, period=1e-3,
                                      duty=0.5, cycles=3),)))
    wl = get_workload("hpl", **HPL_SMALL)
    plat = get_platform("bdw-local")
    a = wl.predict_des(plat, faults=spec)
    b = wl.predict_des(plat, faults=spec)
    assert a["time_s"] == b["time_s"]
    assert a["events"] == b["events"]
    # and a different seed gives a different degraded platform
    other = dataclasses.replace(spec, seed=100)
    c = wl.predict_des(plat, faults=other)
    assert c["time_s"] != a["time_s"]


# ------------------------------------- acceptance scenario, both workloads

@pytest.mark.parametrize("kind,plat_name,params", [
    ("hpl", "bdw-local", HPL_SMALL),
    ("transformer", "tpu-v5e-pod", TF_SMALL),
])
def test_acceptance_scenario_des_with_trace_markers(kind, plat_name, params):
    from repro.trace import to_chrome_json, validate_chrome_events
    wl = get_workload(kind, **params)
    plat = get_platform(plat_name)
    healthy = wl.predict_des(plat)
    app = wl.des_app(plat, trace=True, faults=ACCEPTANCE)
    app.run()
    trace = app.engine.trace
    assert app.engine.now > healthy["time_s"]        # faults cost time
    # fault spans on the dedicated track, excluded from breakdowns
    summ = trace.summary()
    names = {f["name"] for f in summ["faults"]}
    assert {"straggler", "link_degrade"} <= names
    doc = to_chrome_json(trace)
    validate_chrome_events(doc)
    tids = {e["args"]["name"] for e in doc["traceEvents"]
            if e.get("ph") == "M" and e.get("name") == "thread_name"}
    assert "faults" in tids


def test_straggler_cross_validation_des_vs_fastsim():
    """The fastsim straggler mapping tracks the DES within the repo's
    15% cross-validation band (gate calibrated across geometries)."""
    plat = get_platform("bdw-local")
    for (P, Q) in [(2, 4), (4, 4)]:
        wl = get_workload("hpl", N=1536, nb=128, P=P, Q=Q, lookahead=0)
        spec = FaultSpec.straggler(rank=1, slowdown=2.0)
        des = wl.predict_des(plat, faults=spec)
        fast = wl.predict(plat, faults=spec)
        rel = abs(des["time_s"] - fast["time_s"]) / des["time_s"]
        assert rel < 0.15, (P, Q, des["time_s"], fast["time_s"])


def test_transformer_straggler_fastsim_near_exact():
    """Symmetric mesh + ring syncs: the step time IS the straggler's
    chain, so the stepsim mapping is essentially exact."""
    wl = get_workload("transformer", **TF_SMALL)
    plat = get_platform("tpu-v5e-pod")
    spec = FaultSpec.straggler(rank=3, slowdown=3.0)
    des = wl.predict_des(plat, faults=spec)
    fast = wl.predict(plat, faults=spec)
    rel = abs(des["time_s"] - fast["time_s"]) / des["time_s"]
    assert rel < 0.05, (des["time_s"], fast["time_s"])


def test_acceptance_scenario_crossvalidates():
    wl = get_workload("hpl", **HPL_SMALL)
    plat = get_platform("bdw-local")
    des = wl.predict_des(plat, faults=ACCEPTANCE)
    fast = wl.predict(plat, faults=ACCEPTANCE)
    rel = abs(des["time_s"] - fast["time_s"]) / des["time_s"]
    assert rel < 0.15, (des["time_s"], fast["time_s"])


# ------------------------------------------------------------ fail-stop

def test_fail_stop_hpl_reports_partial_run():
    wl = get_workload("hpl", **HPL_SMALL)
    plat = get_platform("bdw-local")
    out = wl.predict_des(plat, faults=FaultSpec.fail_stop(rank=2, at=1e-4))
    assert out["failed"] and out["gflops"] == 0.0
    assert 0 <= out["n_finished"] < 8


def test_fail_stop_transformer_reports_partial_run():
    wl = get_workload("transformer", **TF_SMALL)
    plat = get_platform("tpu-v5e-pod")
    out = wl.predict_des(plat, faults=FaultSpec.fail_stop(rank=0))
    assert out["failed"] and out["n_finished"] < 8


def test_fastsim_rejects_des_only_kinds():
    from repro.faults.fastsim import apply_faults
    wl = get_workload("hpl", **HPL_SMALL)
    params = get_platform("bdw-local").fastsim()
    with pytest.raises(ValueError, match="fail_stop"):
        apply_faults(params, FaultSpec.fail_stop(rank=0))
    with pytest.raises(ValueError, match="DES-only"):
        apply_faults(params, FaultSpec(faults=(
            Fault("link_degrade", node=3, factor=0.5),)))
    with pytest.raises(ValueError, match="fail_stop"):
        wl.predict(get_platform("bdw-local"),
                   faults=FaultSpec.fail_stop(rank=0))


# ------------------------------------------------------ batched sweeps

def test_sweep_faults_one_compile_fault_grid():
    from repro.core.fastsim import trace_count
    from repro.faults.fastsim import sweep_faults
    wl = get_workload("hpl", **HPL_SMALL)
    plat = get_platform("bdw-local")
    specs = [FaultSpec.straggler(rank=1, slowdown=s)
             for s in (1.5, 2.0, 4.0)]
    t0 = trace_count()
    out = sweep_faults(wl, plat, specs)
    assert trace_count() - t0 <= 1          # whole fault grid, one trace
    assert len(out) == 4                    # healthy lane prepended
    assert out[0]["slowdown_vs_healthy"] == pytest.approx(1.0)
    slows = [r["slowdown_vs_healthy"] for r in out[1:]]
    assert all(s >= 1.0 for s in slows)
    assert slows == sorted(slows)           # worse straggler, worse run


# ------------------------------------------------------ serving hardening

def test_service_requests_carry_faults():
    from repro.serve import PredictionService, WorkloadRequest
    svc = PredictionService()
    out = svc.predict_batch([
        WorkloadRequest(rid=0, workload="hpl", platform="bdw-local",
                        params=dict(HPL_SMALL)),
        WorkloadRequest(rid=1, workload="hpl", platform="bdw-local",
                        params=dict(HPL_SMALL), faults=ACCEPTANCE),
    ])
    assert out[1]["time_s"] > out[0]["time_s"]


def test_service_deadline_falls_back_to_fastsim():
    from repro.serve import PredictionService, WorkloadRequest
    svc = PredictionService()
    out = svc.predict_batch([WorkloadRequest(
        rid=0, workload="transformer", platform="tpu-v5e-pod",
        params={"mesh": [4, 8], "num_layers": 8},
        breakdown=True, timeout_s=1e-9)])
    r = out[0]
    assert r["degraded"] and "breakdown" not in r
    assert r["fallback_reason"].startswith(("deadline_exceeded",
                                            "wall_deadline"))
    assert "time_s" in r                     # the fastsim answer stands
    assert svc.stats["fallbacks"] == 1


def test_service_rank_guard_fallback_only_with_timeout():
    from repro.serve import PredictionService, WorkloadRequest
    svc = PredictionService()
    # strict default: reject (PR 5 contract, unchanged)
    with pytest.raises(ValueError, match="max_des_ranks"):
        svc.predict_batch([WorkloadRequest(
            rid=0, workload="transformer", platform="syn-torus-fugaku-4k",
            breakdown=True)])
    assert not svc._queue and svc.stats["requests"] == 0
    # budgeted request: degrade to the fastsim answer instead
    out = svc.predict_batch([WorkloadRequest(
        rid=1, workload="transformer", platform="syn-torus-fugaku-4k",
        breakdown=True, timeout_s=60.0)])
    assert out[1]["degraded"]
    assert out[1]["fallback_reason"].startswith("max_des_ranks")
    assert "time_s" in out[1]


def test_service_isolates_per_request_errors():
    from repro.serve import PredictionService, WorkloadRequest
    svc = PredictionService()
    # default stays all-or-nothing (PR 4/5 contract)
    with pytest.raises(KeyError, match="unknown platform"):
        svc.predict_batch([
            WorkloadRequest(rid=0, workload="hpl", platform="tpu-v5e-pod"),
            WorkloadRequest(rid=1, workload="hpl", platform="nope"),
        ])
    assert not svc._queue and svc.stats["requests"] == 0
    # isolation: bad rids error out, good rids serve
    out = svc.predict_batch([
        WorkloadRequest(rid=0, workload="hpl", platform="tpu-v5e-pod"),
        WorkloadRequest(rid=1, workload="hpl", platform="nope"),
        WorkloadRequest(rid=2, workload="transformer",
                        platform="tpu-v5e-pod"),
    ], isolate_errors=True)
    assert out[1]["status"] == "error"
    assert out[1]["error_type"] == "KeyError"
    assert "unknown platform" in out[1]["error"]
    assert out[0]["status"] == "ok" and "time_s" in out[0]
    assert out[2]["status"] == "ok"
    assert not svc._queue and svc.stats["errors"] == 1
    # an all-failed (then empty) wave leaves the queue clean
    out = svc.predict_batch(
        [WorkloadRequest(rid=9, workload="hpl", platform="nope")],
        isolate_errors=True)
    assert out[9]["status"] == "error" and not svc._queue
    assert svc.predict_batch([], isolate_errors=True) == {}
    assert svc.predict_batch([]) == {}


def test_service_retries_transient_backend_errors():
    from repro.serve import PredictionService, WorkloadRequest
    from repro.workloads.hpl import HPLFastModel
    orig = HPLFastModel.sweep_models.__func__
    calls = {"n": 0}

    def flaky(cls, models):
        calls["n"] += 1
        if calls["n"] < 3:
            raise RuntimeError("transient backend glitch")
        return orig(cls, models)

    HPLFastModel.sweep_models = classmethod(flaky)
    try:
        svc = PredictionService(backoff_s=1e-4)
        out = svc.predict_batch([WorkloadRequest(
            rid=0, workload="hpl", platform="tpu-v5e-pod")])
        assert "time_s" in out[0]
        assert calls["n"] == 3 and svc.stats["retries"] == 2
        # exhausted retries surface the error (bounded, not infinite)
        calls["n"] = -100
        with pytest.raises(RuntimeError, match="transient"):
            svc.predict_batch([WorkloadRequest(
                rid=1, workload="hpl", platform="tpu-v5e-pod")])
    finally:
        HPLFastModel.sweep_models = classmethod(orig)
    # scenario errors are never retried
    svc2 = PredictionService()
    with pytest.raises(KeyError):
        svc2.predict_batch([WorkloadRequest(rid=0, workload="hpl",
                                            platform="nope")])
    assert svc2.stats["retries"] == 0


# ------------------------------------------------------------ ft layer

def test_simulate_fault_impact_generic():
    from repro.ft import simulate_fault_impact
    out = simulate_fault_impact("transformer", "tpu-v5e-pod",
                                FaultSpec.straggler(rank=0, slowdown=3.0))
    assert out["backend"] == "fastsim"
    assert out["blowup"] > 1.0
    assert out["verdict"] in ("evict", "tolerate")
    des = simulate_fault_impact(
        get_workload("transformer", **TF_SMALL), "tpu-v5e-pod",
        FaultSpec.fail_stop(rank=3), des=True)
    assert des["failed"] and des["verdict"] == "restart"
    assert des["blowup"] == float("inf")


def test_restart_plan_for_faults():
    from repro.ft import restart_plan_for_faults
    spec = FaultSpec.fail_stop(rank=18) + FaultSpec.fail_stop(node=1)
    plan = restart_plan_for_faults(spec, global_batch=1792, resume_step=500,
                                   old_mesh=(16, 16), ranks_per_node=4)
    assert plan.new_mesh == (14, 16)         # rows 0 (node 1) and 1 (rank 18)
    assert plan.per_device_batch_new == 128
    assert "evicted dp rows [0, 1]" in plan.notes
    with pytest.raises(ValueError, match="no.*fail_stop|fail_stop"):
        restart_plan_for_faults(FaultSpec.straggler(rank=0), global_batch=8,
                                resume_step=0, old_mesh=(4, 4))
    with pytest.raises(ValueError, match="surviving"):
        restart_plan_for_faults(FaultSpec.fail_stop(rank=0), global_batch=8,
                                resume_step=0, old_mesh=(1, 4))


def test_engine_wall_deadline():
    from repro.core.engine import Engine, SimWallDeadline

    def ticker(eng):
        while True:
            yield 1e-6

    eng = Engine()
    eng.spawn(ticker(eng))
    eng.set_wall_deadline(0.05)
    with pytest.raises(SimWallDeadline, match="wall"):
        eng.run_all()
    # and without a deadline the same engine construct runs fine
    eng2 = Engine()

    def finite():
        for _ in range(10):
            yield 1e-6
    eng2.spawn(finite())
    eng2.run_all()
    assert eng2.now == pytest.approx(1e-5)


def test_process_error_context():
    from repro.core.engine import Engine, ProcessError

    def boom():
        yield 1e-3
        raise KeyError("lost rendezvous")

    eng = Engine()
    eng.spawn(boom(), name="rank 7")
    with pytest.raises(ProcessError, match="rank 7") as ei:
        eng.run_all()
    assert ei.value.sim_time == pytest.approx(1e-3)
    assert isinstance(ei.value.__cause__, KeyError)
