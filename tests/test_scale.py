"""Representative-region simulation + per-scale contention calibration
(repro.scale, DESIGN.md §17).

The region contract: ``des_app(platform, regions=R)`` simulates one
representative prefix of the iteration space on the exact DES and
prices the rest with the region-calibrated closed form, stamped
``region_approx`` — within 10% of exact DES on every geometry small
enough to check here (the acceptance sweep in DESIGN.md §17 covers
10^4 ranks).
"""
import dataclasses
import math

import pytest

from repro.core.apps.hpl import HPLConfig, HPLSim
from repro.platforms import get_platform
from repro.scale import (RegionHPLSim, RegionSpec, as_region,
                         fit_contention_at_scale, scaled_probe_configs,
                         square_grid)


# ------------------------------------------------------------ RegionSpec
def test_as_region_normalization():
    assert as_region(None) == RegionSpec()
    assert as_region(16) == RegionSpec(panels=16)
    spec = RegionSpec(panels=20, warmup=4)
    assert as_region(spec) is spec
    with pytest.raises(TypeError):
        as_region(True)
    with pytest.raises(TypeError):
        as_region("12")
    with pytest.raises(ValueError):
        RegionSpec(panels=4, warmup=2)       # no usable fit window
    with pytest.raises(ValueError):
        RegionSpec(panels=12, warmup=0)


def test_square_grid():
    assert square_grid(16) == (4, 4)
    assert square_grid(12) == (3, 4)
    assert square_grid(10000) == (100, 100)
    assert square_grid(7) == (1, 7)
    with pytest.raises(ValueError):
        square_grid(0)


# ------------------------------------------------------------ HPL region
@pytest.mark.parametrize("cfg_kw", [
    dict(N=4096, nb=128, P=2, Q=4),
    dict(N=6144, nb=128, P=4, Q=4),
    dict(N=4096, nb=128, P=2, Q=8),
])
def test_region_hpl_within_10pct_of_exact(cfg_kw):
    plat = get_platform("frontera")
    cfg = HPLConfig(lookahead=0, bcast=plat.mpi.bcast, **cfg_kw)
    exact = HPLSim(cfg, plat).run()
    res = RegionHPLSim(cfg, plat, region=12).run()
    assert res.region_approx and res.region_panels == 12
    assert res.events < exact.events          # strictly fewer DES events
    err = abs(res.time_s - exact.time_s) / exact.time_s
    assert err < 0.10, f"region error {err:.1%} on {cfg_kw}"
    # gflops is recomputed from the extrapolated time
    assert res.gflops == pytest.approx(cfg.flops() / res.time_s / 1e9)


def test_region_hpl_exact_when_config_fits_region():
    plat = get_platform("frontera")
    cfg = HPLConfig(N=1024, nb=128, P=2, Q=2, lookahead=0,
                    bcast=plat.mpi.bcast)
    assert cfg.n_panels <= 12
    exact = HPLSim(cfg, plat).run()
    res = RegionHPLSim(cfg, plat, region=12).run()
    assert not res.region_approx and res.region_panels == 0
    assert res.time_s == exact.time_s and res.events == exact.events


def test_region_hpl_feature_fit_fallback_without_platform():
    # raw (node, topology) construction has no fastsim surface: the
    # sign-constrained feature fit takes over
    plat = get_platform("frontera")
    stack = plat.des()
    cfg = HPLConfig(N=4096, nb=128, P=2, Q=4, lookahead=0,
                    bcast=plat.mpi.bcast)
    exact = HPLSim(cfg, stack.node, stack.topology,
                   ranks_per_node=stack.ranks_per_node,
                   mpi_overhead=stack.mpi_overhead).run()
    sim = RegionHPLSim(cfg, stack.node, stack.topology, region=12,
                       ranks_per_node=stack.ranks_per_node,
                       mpi_overhead=stack.mpi_overhead)
    assert sim._platform is None
    res = sim.run()
    assert res.region_approx
    err = abs(res.time_s - exact.time_s) / exact.time_s
    assert err < 0.15, f"feature-fit fallback error {err:.1%}"


def test_region_hpl_through_workload_protocol():
    from repro.workloads import get_workload
    plat = get_platform("frontera")
    wl = get_workload("hpl", N=4096, nb=128, P=2, Q=4, lookahead=0)
    exact = wl.predict_des(plat)
    out = wl.predict_des(plat, regions=12, trace=True)
    assert out["region_approx"] and out["panels_simulated"] == 12
    assert out["breakdown"]["region_approx"]
    assert abs(out["time_s"] - exact["time_s"]) / exact["time_s"] < 0.10
    # exact runs carry no region stamp at all
    assert "region_approx" not in exact


# ---------------------------------------------------- transformer region
def test_region_transformer_through_workload_protocol():
    from repro.workloads import get_workload
    plat = get_platform("tpu-v5e-pod")
    wl = get_workload("transformer", mesh=(4, 8), num_layers=12)
    exact = wl.predict_des(plat)
    out = wl.predict_des(plat, regions=RegionSpec(panels=6, warmup=2))
    assert out["region_approx"] and out["layers_simulated"] == 6
    assert abs(out["time_s"] - exact["time_s"]) / exact["time_s"] < 0.10

    # a model that fits inside the region runs exactly
    small = get_workload("transformer", mesh=(4, 8), num_layers=4)
    assert "region_approx" not in small.predict_des(plat, regions=6)


# --------------------------------------------- per-scale contention table
def test_with_contention_round_trip_and_provenance():
    from repro.platforms.spec import Platform
    plat = get_platform("frontera")
    p2 = plat.with_contention(10_000, {"bcast_bw_scale": 1.7},
                              note="region-fit test")
    assert plat.contention == ()             # original untouched
    assert p2.contention_dict == {10_000: {"bcast_bw_scale": 1.7}}
    assert dict(p2.provenance)["contention@10000"] == "region-fit test"
    # JSON round trip preserves the table
    p3 = Platform.from_dict(p2.to_dict())
    assert p3.contention_dict == p2.contention_dict
    # re-fitting the same scale replaces the entry, not duplicates it
    p4 = p2.with_contention(10_000, {"bcast_bw_scale": 2.1})
    assert p4.contention_dict == {10_000: {"bcast_bw_scale": 2.1}}


def test_fastsim_at_ranks_applies_nearest_log_space_entry():
    plat = (get_platform("frontera")
            .with_contention(100, {"bcast_bw_scale": 1.5})
            .with_contention(10_000, {"bcast_bw_scale": 3.0}))
    base = plat.fastsim()
    # 500 is nearer 100 in log space; 5000 nearer 10000
    assert plat.fastsim(at_ranks=500).bcast_bw_scale == 1.5
    assert plat.fastsim(at_ranks=5000).bcast_bw_scale == 3.0
    assert plat.contention_for(3000) == {"bcast_bw_scale": 3.0}
    # fields outside the entry stay at base calibration
    assert plat.fastsim(at_ranks=500).swap_bw_scale == base.swap_bw_scale
    # no at_ranks -> base params, table ignored
    assert plat.fastsim().bcast_bw_scale == base.bcast_bw_scale


def test_scaled_probe_configs_geometry():
    plat = get_platform("frontera")
    cfgs = scaled_probe_configs(plat, 64, region=RegionSpec(panels=12))
    assert all(c.P * c.Q == 64 and c.lookahead == 0 for c in cfgs)
    assert [c.n_panels for c in cfgs] == [36, 48]
    with pytest.raises(ValueError, match="capacity"):
        scaled_probe_configs(plat, 10**6)


def test_fit_contention_at_scale_smoke():
    plat = get_platform("frontera")
    sf = fit_contention_at_scale(
        plat, 16, region=RegionSpec(panels=8, warmup=2),
        probe_configs=[HPLConfig(N=3072, nb=128, P=4, Q=4, lookahead=0,
                                 bcast=plat.mpi.bcast)],
        steps=12)
    assert sf.at_ranks == 16
    assert set(sf.overrides) == {"bcast_bw_scale", "swap_bw_scale"}
    assert all(v > 0 for v in sf.overrides.values())
    assert sf.platform.contention_dict[16] == sf.overrides
    note = dict(sf.platform.provenance)["contention@16"]
    assert "region-fit" in note and "panels=8" in note
    # the per-scale entry feeds fastsim(at_ranks=...)
    prm = sf.platform.fastsim(at_ranks=16)
    assert prm.bcast_bw_scale == pytest.approx(
        sf.overrides["bcast_bw_scale"])


# ------------------------------------------------------------- serving
def test_serve_region_breakdown_stamps_region_approx():
    from repro.serve import PredictionService, WorkloadRequest
    svc = PredictionService()
    out = svc.predict_batch([WorkloadRequest(
        rid=0, workload="hpl", platform="frontera",
        params={"N": 4096, "nb": 128, "P": 2, "Q": 4, "lookahead": 0},
        breakdown=True, regions=12)])
    r = out[0]
    assert r["region_approx"]
    assert r["breakdown"]["region_approx"]


def test_serve_region_guard_uses_max_region_ranks():
    from repro.serve import PredictionService, WorkloadRequest
    svc = PredictionService(max_region_ranks=8)
    with pytest.raises(ValueError, match="max_region_ranks"):
        svc.predict_batch([WorkloadRequest(
            rid=0, workload="hpl", platform="frontera",
            params={"N": 4096, "nb": 128, "P": 4, "Q": 4, "lookahead": 0},
            breakdown=True, regions=12)])
    # non-region breakdowns still answer to max_des_ranks (the error
    # suggests the regions= escape hatch)
    with pytest.raises(ValueError, match="max_des_ranks"):
        PredictionService(max_des_ranks=8).predict_batch([WorkloadRequest(
            rid=0, workload="hpl", platform="frontera",
            params={"N": 4096, "nb": 128, "P": 4, "Q": 4, "lookahead": 0},
            breakdown=True)])
