"""Trace subsystem benchmark: trace-derived comm/compute breakdown of a
scaled-down Frontera DES run, plus the tracing overhead contract.

Emits (under ``benchmarks.run --json``) the trace-derived fields
``compute_frac`` / ``comm_frac`` / ``idle_frac`` / ``critical_path_s``
so trajectory runs can watch where simulated time goes as the platform
models evolve, and a ``trace.overhead`` row asserting the recorder stays
out of the untraced hot path (identical simulated results, bounded wall
slowdown when on).
"""
from __future__ import annotations

import time


def _des(cfg, plat, trace, reps=2):
    """Best-of-N wall time (container timing is noisy; single-shot
    comparisons routinely invert)."""
    from repro.core.apps.hpl import HPLSim
    best = None
    for _ in range(reps):
        t0 = time.perf_counter()
        res = HPLSim(cfg, plat, trace=trace).run()
        wall = time.perf_counter() - t0
        best = wall if best is None else min(best, wall)
    return res, best


def run(quick: bool = True):
    from repro.platforms import get_platform

    plat = get_platform("frontera")
    cfg = plat.hpl_config(N=2048 if quick else 8192, nb=128,
                          P=2 if quick else 4, Q=4 if quick else 8)

    res_off, wall_off = _des(cfg, plat, trace=False)
    res_on, wall_on = _des(cfg, plat, trace=True)
    s = res_on.trace.summary()

    rows = [{
        "name": "trace.breakdown_frontera",
        "us_per_call": wall_on * 1e6,
        "derived": f"comm={s['comm_frac']*100:.0f}%;"
                   f"compute={s['compute_frac']*100:.0f}%;"
                   f"idle={s['idle_frac']*100:.0f}%;"
                   f"cp_cov={s['critical_path_coverage']*100:.0f}%",
        "compute_frac": s["compute_frac"],
        "comm_frac": s["comm_frac"],
        "idle_frac": s["idle_frac"],
        "critical_path_s": s["critical_path_s"],
        "critical_path_coverage": s["critical_path_coverage"],
        "makespan_s": s["makespan_s"],
        "n_spans": s["n_spans"],
        "n_msgs": s["n_msgs"],
    }, {
        "name": "trace.overhead",
        "us_per_call": (wall_on - wall_off) * 1e6,
        "derived": f"off={wall_off*1e3:.0f}ms;on={wall_on*1e3:.0f}ms;"
                   f"x{wall_on / max(wall_off, 1e-9):.2f};"
                   f"bit_identical={res_on.time_s == res_off.time_s}",
        "wall_off_s": wall_off,
        "wall_on_s": wall_on,
        "bit_identical": res_on.time_s == res_off.time_s,
    }]
    return rows


if __name__ == "__main__":
    for r in run():
        print(r)
