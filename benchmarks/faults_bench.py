"""Fault-injection benchmarks: degraded-fleet sweep cost + hardened
service latency under deadlines (DESIGN.md §16).

Two claims kept honest here:

  * a degraded-platform grid is an ordinary sweep axis — stragglers x
    link-degradations on one HPL scenario cost ONE compile and
    microseconds per lane (``sweep_faults``), not one DES run each;
  * the hardened ``PredictionService`` keeps its tail latency bounded:
    budgeted breakdown requests that would blow their deadline degrade
    to the fastsim answer (stamped ``fallback_reason``) instead of
    stalling the wave, so p99 stays near the fastsim cost.

Standalone use writes the NDJSON trajectory file CI uploads::

    PYTHONPATH=src python benchmarks/faults_bench.py --json \
        --out BENCH_faults.json
"""
from __future__ import annotations

import argparse
import json
import time


def _percentile(xs, p):
    xs = sorted(xs)
    i = min(len(xs) - 1, int(round(p / 100.0 * (len(xs) - 1))))
    return xs[i]


def run(quick: bool = True):
    from repro.core.fastsim import trace_count
    from repro.faults import FaultSpec
    from repro.faults.fastsim import sweep_faults
    from repro.platforms import get_platform
    from repro.serve import PredictionService, WorkloadRequest
    from repro.workloads import get_workload

    rows = []

    # ---------------------------------------- degraded-fleet fault grid
    plat = get_platform("frontera")
    wl = get_workload("hpl", N=32768 if quick else 65536, nb=128, P=2, Q=4)
    stragglers = [1.25, 1.5, 2.0, 3.0] if quick else \
        [1.1, 1.25, 1.5, 2.0, 3.0, 4.0, 6.0, 8.0]
    link_degs = [0.75, 0.5, 0.25]
    specs = ([FaultSpec.straggler(rank=1, slowdown=s) for s in stragglers]
             + [FaultSpec.degraded_links(0.05, factor=f, seed=11)
                for f in link_degs]
             + [FaultSpec.straggler(rank=1, slowdown=s, seed=11)
                + FaultSpec.degraded_links(0.05, factor=f, seed=11)
                for s in stragglers for f in link_degs])
    sweep_faults(wl, plat, specs)              # warm the bucket
    t_warm = trace_count()
    t0 = time.perf_counter()
    out = sweep_faults(wl, plat, specs)
    dt = time.perf_counter() - t0
    worst = max(r["slowdown_vs_healthy"] for r in out)
    rows.append({
        "name": "faults.sweep_grid",
        "us_per_call": dt / (len(specs) + 1) * 1e6,
        "derived": f"n={len(specs) + 1};wall_ms={dt * 1e3:.1f};"
                   f"retraces_after_warmup={trace_count() - t_warm};"
                   f"worst_slowdown={worst:.2f}x"})

    # --------------------------- service deadline/fallback tail latency
    svc = PredictionService()
    n_req = 8 if quick else 32
    reqs = []
    for i in range(n_req):
        # even rids: DES breakdown fits the budget; odd rids: a budget
        # the DES cannot meet -> fastsim fallback
        reqs.append(WorkloadRequest(
            rid=i, workload="transformer", platform="tpu-v5e-pod",
            params={"mesh": [2, 4], "num_layers": 2},
            breakdown=True,
            timeout_s=(60.0 if i % 2 == 0 else 1e-6)))
    lat = []
    results = {}
    for req in reqs:                    # per-request latency, not wave
        t0 = time.perf_counter()
        results.update(svc.predict_batch([req]))
        lat.append(time.perf_counter() - t0)
    fallbacks = sum(1 for r in results.values() if r.get("degraded"))
    served = sum(1 for r in results.values() if "breakdown" in r)
    assert fallbacks == n_req // 2 and served == n_req - fallbacks
    rows.append({
        "name": "serve.deadline_fallback",
        "us_per_call": sum(lat) / len(lat) * 1e6,
        "derived": f"n={n_req};fallbacks={fallbacks};"
                   f"p50_ms={_percentile(lat, 50) * 1e3:.2f};"
                   f"p99_ms={_percentile(lat, 99) * 1e3:.2f};"
                   f"fallback_p99_ms="
                   f"{_percentile(lat[1::2], 99) * 1e3:.2f}"})

    # ------------------------------------ isolation overhead on a wave
    svc2 = PredictionService()
    hpl_kw = dict(N=32768 if quick else 65536, nb=128, P=2, Q=4)
    wave = [WorkloadRequest(rid=i, workload="hpl", platform="frontera",
                            params=dict(hpl_kw))
            for i in range(n_req)]
    wave[1] = WorkloadRequest(rid=1, workload="hpl", platform="nope")
    t0 = time.perf_counter()
    out2 = svc2.predict_batch(wave, isolate_errors=True)
    dt = time.perf_counter() - t0
    errs = sum(1 for r in out2.values() if r.get("status") == "error")
    assert errs == 1 and len(out2) == n_req
    rows.append({
        "name": "serve.isolated_wave",
        "us_per_call": dt / n_req * 1e6,
        "derived": f"n={n_req};errors={errs};wall_ms={dt * 1e3:.1f};"
                   f"queue_clean={not svc2._queue}"})
    return rows


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--json", action="store_true")
    ap.add_argument("--out", default=None,
                    help="also write rows as NDJSON to this path")
    args = ap.parse_args()
    rows = run(quick=not args.full)
    lines = [json.dumps(r) for r in rows]
    if args.json:
        print("\n".join(lines))
    else:
        print("name,us_per_call,derived")
        for r in rows:
            print(f"{r['name']},{r['us_per_call']:.2f},\"{r['derived']}\"")
    if args.out:
        with open(args.out, "w") as f:
            f.write("\n".join(lines) + "\n")


if __name__ == "__main__":
    main()
