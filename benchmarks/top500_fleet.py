"""TOP500 fleet prediction: the whole vendored sample list (51 systems,
June-2020 era) ingested, spec-inferred, and predicted as ONE batched
sweep — the paper's Table II workflow scaled from 2 hand-built machines
to a list, in seconds of wall time.

    PYTHONPATH=src python benchmarks/top500_fleet.py [--json] [--smoke]
        [--full] [--csv PATH] [--out REPORT.json]

``--out`` writes the full ranked predicted-vs-published report (per
machine: raw + calibrated prediction, relative error, proxy scaling,
inference provenance) — CI uploads it as an artifact.
"""
from __future__ import annotations

import argparse
import json
import time


def run(quick: bool = True, csv_path: str = None, out: str = None):
    from repro.top500 import (FleetTuning, parse_top500, predict_fleet,
                              sample_list_path)

    path = csv_path or sample_list_path()
    rows = parse_top500(path).rows
    tuning = FleetTuning(max_ranks=256, panels_cap=2048) if quick \
        else FleetTuning(max_ranks=1024, panels_cap=4096)

    t0 = time.perf_counter()
    report = predict_fleet(rows, tuning=tuning)
    wall = time.perf_counter() - t0

    if out:
        with open(out, "w", encoding="utf-8") as fh:
            json.dump(report.to_dict(), fh, indent=1)

    cal = report.calibration
    best = report.ranked()[0]
    rows_out = [{
        "name": "top500_fleet.sweep",
        "us_per_call": wall / max(len(rows), 1) * 1e6,
        "derived": f"machines={len(rows)};compiles={report.compiles};"
                   f"bucket={report.bucket};wall_s={wall:.1f};"
                   f"median_err={report.median_abs_err():.3f};"
                   f"heldout_err={cal.heldout_median_abs_err:.3f};"
                   f"top={best.platform.name}"
                   f"@{best.calibrated_tflops:.0f}tf",
    }]
    return rows_out


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--full", action="store_true",
                    help="bigger proxy grids (max_ranks=1024)")
    ap.add_argument("--smoke", action="store_true",
                    help="quick CI configs (alias of the default)")
    ap.add_argument("--json", action="store_true",
                    help="emit NDJSON rows instead of CSV")
    ap.add_argument("--csv", default=None,
                    help="a TOP500 list export to predict instead of "
                         "the vendored sample")
    ap.add_argument("--out", default=None,
                    help="write the ranked report JSON here")
    args = ap.parse_args()
    if args.smoke and args.full:
        ap.error("--smoke and --full are mutually exclusive")
    rows = run(quick=not args.full, csv_path=args.csv, out=args.out)
    if not args.json:
        print("name,us_per_call,derived")
    for r in rows:
        if args.json:
            print(json.dumps(r), flush=True)
        else:
            print(f"{r['name']},{r['us_per_call']:.2f},"
                  f"\"{r['derived']}\"", flush=True)


if __name__ == "__main__":
    main()
