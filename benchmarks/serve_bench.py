"""Serving-throughput benchmark: mixed waves through PredictionService.

Drives mixed (workload, platform, faults) request waves through the
micro-batching front end and reports the serving numbers that matter
for the paper's simulation-as-a-service claim: predictions/s and the
per-request latency distribution (p50/p95/p99 from the service's own
``serve.request_latency_s`` histogram — the metrics subsystem measuring
the service that carries it).  A sequential reference (same requests,
one ``predict()`` call each) runs in the same process, so the
batched/sequential throughput ratio is a machine-speed-normalized
number CI can gate on.

Also measured every run:

  * warm pool — cold first wave (every compile) vs a warmed service's
    first wave, which must pay ZERO sweep compiles (gated via the §18
    trace counters after force-cooling the compile caches);
  * result cache — the same repeated-cell mixed wave with the
    content-addressed cache on vs off: predictions/s both ways,
    hit-rate, and the cached/uncached speedup (gated: >=10x absolute
    and within tolerance of the committed baseline);
  * the cost of the metrics subsystem itself — the same wave with
    ``metrics=NULL_METRICS`` vs an enabled registry (acceptance:
    metrics-on overhead stays within noise, target <=2%).

Standalone use writes the NDJSON trajectory file CI gates on::

    PYTHONPATH=src python benchmarks/serve_bench.py --json \
        --out BENCH_serve.json

    # CI regression gate: fail if the machine-normalized throughput
    # (batched/sequential ratio) drops >20% vs the committed baseline
    PYTHONPATH=src python benchmarks/serve_bench.py --check BENCH_serve.json
"""
from __future__ import annotations

import argparse
import json
import sys
import time

# normalized-throughput regression tolerance for --check (CI smoke gate)
CHECK_TOLERANCE = 0.20
# the acceptance floor for the cached/uncached throughput ratio on a
# repeated-cell mixed wave (ISSUE 10: >= 10x with the cache on)
MIN_CACHE_SPEEDUP = 10.0


def _requests(n_hpl, n_tf, n_faulted, n_breakdown):
    """The mixed scenario list: HPL + transformer + faulted HPL +
    breakdown-DES HPL.  Sweep shapes stay inside one compile bucket per
    family; the breakdown requests add real DES wall so waves carry a
    production-shaped mix of sub-ms sweeps and multi-ms simulations."""
    from repro.faults import FaultSpec
    from repro.serve import WorkloadRequest

    reqs = []
    rid = 0
    for i in range(n_hpl):
        reqs.append(WorkloadRequest(
            rid=rid, workload="hpl", platform="frontera",
            params=dict(N=1536 + 128 * (i % 4), nb=128, P=2, Q=4,
                        lookahead=0)))
        rid += 1
    for i in range(n_tf):
        reqs.append(WorkloadRequest(
            rid=rid, workload="transformer", platform="tpu-v5e-pod",
            params={"mesh": (2, 4), "num_layers": 2 + (i % 3)}))
        rid += 1
    spec = FaultSpec.straggler(rank=1, slowdown=2.0, seed=7)
    for i in range(n_faulted):
        reqs.append(WorkloadRequest(
            rid=rid, workload="hpl", platform="frontera",
            params=dict(N=1536, nb=128, P=2, Q=4, lookahead=0),
            faults=spec))
        rid += 1
    for i in range(n_breakdown):
        reqs.append(WorkloadRequest(
            rid=rid, workload="hpl", platform="bdw-local",
            params=dict(N=1536, nb=128, P=2, Q=2, lookahead=0),
            breakdown=True))
        rid += 1
    return reqs


def _wave_once(metrics=None):
    """One batched wave through a fresh service; returns (wall, svc)."""
    from repro.serve import PredictionService

    reqs = _requests(*_MIX)
    svc = (PredictionService() if metrics is None
           else PredictionService(metrics=metrics))
    t0 = time.perf_counter()
    svc.predict_batch(reqs)
    return time.perf_counter() - t0, svc


_MIX = (16, 16, 8, 4)       # hpl, transformer, faulted, breakdown / wave


def run(quick: bool = True):
    from repro.core import fastsim
    from repro.obs import NULL_METRICS
    from repro.serve import PredictionService
    from repro.workloads import stepsim

    global _MIX
    _MIX = (16, 16, 8, 4) if quick else (64, 64, 32, 8)
    n_req = sum(_MIX)
    rows = []

    # ------------------------------- warm pool: cold vs warm first wave
    # This section MUST run first: it measures the compile bill of a
    # pristine process.  Cold = first wave eats every sweep compile.
    # Then the compile caches are force-cooled (cache_clear) and a
    # fresh service warms from a representative traffic sample — its
    # first real wave must pay ZERO compiles (gated in --check via the
    # trace counters, the §18 ground truth).
    def _traces():
        return fastsim.trace_count() + stepsim.trace_count()

    pre = _traces()
    wall_cold, _ = _wave_once()
    cold_compiles = _traces() - pre

    fastsim._compiled.cache_clear()            # re-cool the process
    stepsim._compiled.cache_clear()
    svc_w = PredictionService()
    warm_report = svc_w.warm(requests=_requests(*_MIX))
    pre = _traces()
    t0 = time.perf_counter()
    svc_w.predict_batch(_requests(*_MIX))
    wall_warm = time.perf_counter() - t0
    first_wave_compiles = _traces() - pre
    rows.append({
        "name": "serve.warm_first_wave",
        "us_per_call": wall_warm / n_req * 1e6,
        "cold_first_wave_s": wall_cold,
        "warm_first_wave_s": wall_warm,
        "first_wave_compiles": first_wave_compiles,
        "warm_compiles": warm_report["compiles"],
        "derived": f"cold={wall_cold * 1e3:.0f}ms;"
                   f"warm={wall_warm * 1e3:.0f}ms;"
                   f"speedup={wall_cold / wall_warm:.1f}x;"
                   f"warm_compiles={warm_report['compiles']};"
                   f"first_wave_compiles={first_wave_compiles}"})

    # ------------------------------------------- batched mixed wave
    _wave_once()                               # warm the compile caches
    best_wall, best_svc = None, None
    for _ in range(5):
        wall, svc = _wave_once()
        if best_wall is None or wall < best_wall:
            best_wall, best_svc = wall, svc
    h = best_svc.metrics.histogram("serve.request_latency_s")
    p50, p95, p99 = (h.quantile(q) for q in (0.50, 0.95, 0.99))
    pps = n_req / best_wall

    # ------------------------------- sequential reference (same work)
    # a stratified every-4th subset (so it includes breakdown requests
    # in proportion), served one single-request wave at a time; its own
    # warm pass first — single-lane sweeps compile separately — then
    # best-of-3 timed passes (min, same estimator as the batched side,
    # so the gate ratio is min/min and stays stable under load noise)
    svc_seq = PredictionService()
    for r in _requests(*_MIX)[::4]:
        svc_seq.predict_batch([r])             # warm the 1-lane caches
    seq_wall, seq_n = None, len(_requests(*_MIX)[::4])
    for _ in range(3):
        seq_reqs = _requests(*_MIX)[::4]
        t0 = time.perf_counter()
        for r in seq_reqs:
            svc_seq.predict_batch([r])
        w = time.perf_counter() - t0
        seq_wall = w if seq_wall is None else min(seq_wall, w)
    seq_pps = seq_n / seq_wall

    rows.append({
        "name": "serve.mixed_wave",
        "us_per_call": best_wall / n_req * 1e6,
        "predictions_per_s": pps,
        "seq_predictions_per_s": seq_pps,
        "p50_s": p50, "p95_s": p95, "p99_s": p99,
        "derived": f"requests={n_req};predictions_per_s={pps:.0f};"
                   f"seq={seq_pps:.0f}/s;"
                   f"norm_ratio={pps / seq_pps:.2f}x;"
                   f"p50={p50 * 1e3:.2f}ms;p95={p95 * 1e3:.2f}ms;"
                   f"p99={p99 * 1e3:.2f}ms"})

    # ----------------------- result cache: repeated-cell wave, on vs off
    # Fleet traffic is mostly duplicate cells (the campaign layer asks
    # the same matrix across editions/users).  Serve the SAME mixed wave
    # repeatedly: cache-off recomputes every sweep + breakdown DES;
    # cache-on answers from content-addressed hits.  Both sides use the
    # best-of-5 min estimator on a service that has already seen the
    # traffic once (steady state), so the ratio is machine-normalized.
    svc_u = PredictionService()
    svc_u.predict_batch(_requests(*_MIX))      # steady-state entry
    wall_u = None
    for _ in range(5):
        reqs = _requests(*_MIX)
        t0 = time.perf_counter()
        svc_u.predict_batch(reqs)
        w = time.perf_counter() - t0
        wall_u = w if wall_u is None else min(wall_u, w)
    uncached_pps = n_req / wall_u

    svc_c = PredictionService(cache=True)
    svc_c.predict_batch(_requests(*_MIX))      # populate pass (misses)
    wall_c = None
    for _ in range(5):
        reqs = _requests(*_MIX)
        t0 = time.perf_counter()
        svc_c.predict_batch(reqs)
        w = time.perf_counter() - t0
        wall_c = w if wall_c is None else min(wall_c, w)
    cached_pps = n_req / wall_c
    hits = svc_c.stats["cache_hits"]
    misses = svc_c.stats["cache_misses"]
    hit_rate = hits / max(hits + misses, 1)
    ratio = cached_pps / uncached_pps
    rows.append({
        "name": "serve.cached_wave",
        "us_per_call": wall_c / n_req * 1e6,
        "predictions_per_s": cached_pps,
        "uncached_predictions_per_s": uncached_pps,
        "cache_speedup": ratio,
        "hit_rate": hit_rate,
        "derived": f"cached={cached_pps:.0f}/s;uncached={uncached_pps:.0f}/s;"
                   f"speedup={ratio:.1f}x;hit_rate={hit_rate:.2f};"
                   f"coalesced={svc_c.stats['coalesced']}"})

    # ------------------------------------- metrics-subsystem overhead
    # interleaved, order-alternating best-of-8 (noise on a ~30ms wave
    # swamps a one-shot comparison); min-vs-min isolates the
    # systematic cost from scheduler/GC jitter
    walls_off, walls_on = [], []
    for i in range(8):
        if i % 2 == 0:
            walls_off.append(_wave_once(metrics=NULL_METRICS)[0])
            walls_on.append(_wave_once()[0])
        else:
            walls_on.append(_wave_once()[0])
            walls_off.append(_wave_once(metrics=NULL_METRICS)[0])
    wall_off, wall_on = min(walls_off), min(walls_on)
    overhead = wall_on / wall_off - 1.0
    rows.append({
        "name": "serve.metrics_overhead",
        "us_per_call": (wall_on - wall_off) / n_req * 1e6,
        "overhead_frac": overhead,
        "derived": f"metrics_on={wall_on * 1e3:.1f}ms;"
                   f"metrics_off={wall_off * 1e3:.1f}ms;"
                   f"overhead={overhead * 100:+.1f}%"})

    # --------------------------- hardened wave: every counter nonzero
    # (retry + deadline fallback + isolated error in ONE wave; the
    # bench asserts the telemetry the acceptance scenario relies on)
    from repro.serve import WorkloadRequest
    from repro.workloads import HPLFastModel

    svc = PredictionService(backoff_s=0.001)
    orig = HPLFastModel.sweep_models.__func__
    state = {"n": 0}

    def flaky(cls, models):
        state["n"] += 1
        if state["n"] == 1:
            raise RuntimeError("transient backend hiccup")
        return orig(cls, models)

    HPLFastModel.sweep_models = classmethod(flaky)
    try:
        t0 = time.perf_counter()
        out = svc.predict_batch(
            [WorkloadRequest(rid=0, workload="hpl", platform="frontera"),
             WorkloadRequest(rid=1, workload="transformer",
                             platform="tpu-v5e-pod",
                             params={"mesh": (2, 4), "num_layers": 2},
                             breakdown=True, timeout_s=1e-9),
             WorkloadRequest(rid=2, workload="hpl", platform="nope")],
            isolate_errors=True)
        wall = time.perf_counter() - t0
    finally:
        HPLFastModel.sweep_models = classmethod(orig)
    c = svc.metrics.snapshot()["counters"]
    assert out[2]["status"] == "error" and out[1]["degraded"]
    for key in ("serve.retries", "serve.deadline_fallbacks",
                "serve.errors_isolated"):
        assert c.get(key, 0) > 0, f"{key} stayed zero"
    rows.append({
        "name": "serve.hardened_wave",
        "us_per_call": wall / 3 * 1e6,
        "derived": f"retries={c['serve.retries']:.0f};"
                   f"deadline_fallbacks={c['serve.deadline_fallbacks']:.0f};"
                   f"errors_isolated={c['serve.errors_isolated']:.0f};"
                   f"wall={wall * 1e3:.1f}ms"})
    return rows


def check(rows, baseline_path: str) -> int:
    """CI gate: fail if (a) machine-normalized serving throughput
    (batched predictions/s over the in-process sequential reference)
    regressed >CHECK_TOLERANCE vs the committed baseline, (b) the
    cached/uncached throughput ratio on the repeated-cell wave dropped
    below MIN_CACHE_SPEEDUP or regressed >CHECK_TOLERANCE normalized
    vs baseline, or (c) the warm-pool first wave paid any sweep
    compiles.  Rows without a gate are informational."""
    base = {}
    with open(baseline_path) as fh:
        for line in fh:
            line = line.strip()
            if line:
                r = json.loads(line)
                base[r["name"]] = r
    failures, gated = [], 0
    for r in rows:
        name = r["name"]
        b = base.get(name)
        if "first_wave_compiles" in r:
            gated += 1
            ok = r["first_wave_compiles"] == 0
            print(f"{name}: first wave after warm paid "
                  f"{r['first_wave_compiles']} compiles "
                  f"({'OK' if ok else 'REGRESSED'})")
            if not ok:
                failures.append(name)
            continue
        if "cache_speedup" in r:
            gated += 1
            ok = r["cache_speedup"] >= MIN_CACHE_SPEEDUP
            rel_txt = ""
            if b is not None and "cache_speedup" in b:
                rel = r["cache_speedup"] / b["cache_speedup"]
                ok = ok and rel >= 1.0 - CHECK_TOLERANCE
                rel_txt = f" vs baseline {b['cache_speedup']:.1f}x " \
                          f"({rel:.2f} relative)"
            print(f"{name}: cached/uncached {r['cache_speedup']:.1f}x"
                  f"{rel_txt} (floor {MIN_CACHE_SPEEDUP:.0f}x) "
                  f"{'OK' if ok else 'REGRESSED'}")
            if not ok:
                failures.append(name)
            continue
        if b is None:
            continue
        if "seq_predictions_per_s" in r and "seq_predictions_per_s" in b:
            now = r["predictions_per_s"] / r["seq_predictions_per_s"]
            ref = b["predictions_per_s"] / b["seq_predictions_per_s"]
            rel = now / ref
            gated += 1
            status = "OK" if rel >= 1.0 - CHECK_TOLERANCE else "REGRESSED"
            print(f"{name}: batched/sequential {now:.2f}x vs baseline "
                  f"{ref:.2f}x ({rel:.2f} relative) {status}")
            if status == "REGRESSED":
                failures.append(name)
        elif "overhead_frac" in r:
            print(f"{name}: metrics overhead "
                  f"{r['overhead_frac'] * 100:+.1f}% info-only")
    if failures:
        print(f"FAIL: normalized serving throughput regressed "
              f">{CHECK_TOLERANCE:.0%} vs {baseline_path} on: "
              f"{', '.join(failures)}")
        return 1
    print(f"serve bench within {CHECK_TOLERANCE:.0%} of baseline "
          f"({gated} gated scenarios)")
    return 0


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--json", action="store_true")
    ap.add_argument("--out", default=None,
                    help="also write NDJSON rows to this file")
    ap.add_argument("--check", default=None, metavar="BASELINE",
                    help="exit nonzero if normalized throughput regressed "
                         f">{CHECK_TOLERANCE:.0%} vs this NDJSON baseline")
    args = ap.parse_args()
    rows = run(quick=not args.full)
    lines = [json.dumps(r) for r in rows]
    if args.json:
        print("\n".join(lines))
    else:
        print("name,us_per_call,derived")
        for r in rows:
            print(f"{r['name']},{r['us_per_call']:.2f},\"{r['derived']}\"")
    if args.out:
        with open(args.out, "w") as fh:
            fh.write("\n".join(lines) + "\n")
    if args.check:
        sys.exit(check(rows, args.check))


if __name__ == "__main__":
    main()
