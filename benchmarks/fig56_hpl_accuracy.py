"""Paper Fig 5/6: simulated vs measured HPL performance.

Two validations, scaled to this container:
  (a) REAL blocked right-looking LU (numpy, single rank) instrumented and
      compared against the SimBLAS prediction built from the *calibrated*
      mu/theta/bandwidth — the paper's "simulated vs measured" axis;
  (b) DES vs fastsim cross-validation over several (N, nb, P, Q) grids —
      internal consistency of the two simulator fidelities.
"""
from __future__ import annotations

import time

import numpy as np


def _real_blocked_lu(N: int, nb: int):
    """Measured phase times of an actual numpy blocked LU (no pivot swaps
    across panels — timing-faithful, numerically naive)."""
    rng = np.random.default_rng(0)
    A = rng.standard_normal((N, N)) + N * np.eye(N)
    t_panel = t_trsm = t_gemm = 0.0
    for k in range(0, N - nb, nb):
        t0 = time.perf_counter()
        # unblocked panel factorization (dger-style)
        P = A[k:, k:k + nb]
        for j in range(nb):
            P[j + 1:, j] /= P[j, j]
            P[j + 1:, j + 1:] -= np.outer(P[j + 1:, j], P[j, j + 1:])
        t1 = time.perf_counter()
        L11 = np.tril(P[:nb], -1) + np.eye(nb)
        U12 = np.linalg.solve(L11, A[k:k + nb, k + nb:])
        A[k:k + nb, k + nb:] = U12
        t2 = time.perf_counter()
        A[k + nb:, k + nb:] -= P[nb:, :nb] @ U12
        t3 = time.perf_counter()
        t_panel += t1 - t0
        t_trsm += t2 - t1
        t_gemm += t3 - t2
    return {"panel": t_panel, "trsm": t_trsm, "gemm": t_gemm,
            "total": t_panel + t_trsm + t_gemm}


def _simblas_prediction(N: int, nb: int, profile):
    """SimBLAS model of the same loop, using the measured calibration.
    Panel Level-1/2 ops use the panel-sized dger bandwidth (paper §III-B1:
    per-kernel efficiencies are measured, not derived)."""
    from repro.core.simblas import SimBLAS
    from repro.core.hardware.node import NodeModel
    node = NodeModel(name="local-calibrated",
                     peak_flops=profile.dgemm.eff_flops,
                     mem_bw=profile.panel_bw or profile.mem_bw, cores=1,
                     gemm_efficiency=1.0, mem_efficiency=1.0,
                     blas_latency=profile.dgemm.theta)
    blas = SimBLAS(node, theta_mem=profile.theta_mem)
    t_panel = t_trsm = t_gemm = 0.0
    for k in range(0, N - nb, nb):
        m = N - k
        for j in range(nb):
            t_panel += blas.dscal(m - j - 1) + blas.dger(m - j - 1,
                                                         nb - j - 1)
        t_trsm += blas.dtrsm(nb, N - k - nb)
        t_gemm += blas.dgemm(m - nb, N - k - nb, nb)
    return {"panel": t_panel, "trsm": t_trsm, "gemm": t_gemm,
            "total": t_panel + t_trsm + t_gemm}


def run(quick: bool = True):
    from repro.core.calibrate import calibrate
    from repro.core.apps.hpl import HPLConfig, HPLSim
    from repro.core.fastsim import simulate_hpl_fast
    from repro.platforms import get_platform
    import dataclasses

    rows = []
    # (a) real vs simulated single-rank blocked LU
    profile = calibrate(quick=True)
    N, nb = (768, 64) if quick else (2048, 128)
    measured = _real_blocked_lu(N, nb)
    predicted = _simblas_prediction(N, nb, profile)
    err = abs(predicted["total"] - measured["total"]) / measured["total"]
    rows.append({
        "name": "fig56.real_vs_sim_lu",
        "us_per_call": measured["total"] * 1e6,
        "derived": f"measured_s={measured['total']:.3f};"
                   f"sim_s={predicted['total']:.3f};err={err*100:.1f}%;"
                   f"gemm_meas={measured['gemm']:.3f};"
                   f"gemm_sim={predicted['gemm']:.3f}",
    })
    # (b) DES vs fastsim on the local-machine platform
    plat = get_platform("bdw-local")
    prm = dataclasses.replace(plat.fastsim(), lookahead=0.0)
    for (n, b, p, q) in [(2048, 128, 4, 4), (4096, 128, 2, 8)]:
        cfg = HPLConfig(N=n, nb=b, P=p, Q=q)
        des = HPLSim(cfg, plat).run()
        fast = simulate_hpl_fast(cfg, prm)
        rel = abs(des.time_s - fast["time_s"]) / des.time_s
        rows.append({
            "name": f"fig56.des_vs_fast_N{n}_{p}x{q}",
            "us_per_call": des.time_s * 1e6,
            "derived": f"des_gf={des.gflops:.0f};fast_gf={fast['gflops']:.0f};"
                       f"rel={rel*100:.1f}%;events={des.events}",
        })
    return rows


if __name__ == "__main__":
    for r in run():
        print(r)
