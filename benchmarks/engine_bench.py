"""DES engine hot-loop benchmarks: rewritten loop vs the frozen
pre-rewrite stack (core/_legacy_engine.py), measured on every run.

Per-scenario events/s for the rewritten engine and the legacy one
(interleaved, same process, same configs — results are bit-identical by
contract, so the ratio isolates loop cost), peak event-queue depth, and
the ranks-vs-wall scaling of both DES workloads.  Region-mode cost at
10^4 ranks rides along in ``--full`` runs.

Standalone use writes the NDJSON trajectory file CI gates on::

    PYTHONPATH=src python benchmarks/engine_bench.py --json \
        --out BENCH_engine.json

    # CI regression gate: fail if events/s drops >20% vs the committed
    # baseline on any engine.* scenario
    PYTHONPATH=src python benchmarks/engine_bench.py --check BENCH_engine.json

The gate is machine-normalized: the frozen legacy loop runs in the same
process on the same machine, so its events/s is the machine-speed
reference, and the check compares the *new/legacy ratio* against the
baseline's ratio (a raw events/s comparison would trip whenever CI
lands on a slower runner).  Scenarios without a legacy counterpart are
reported but not gated.
"""
from __future__ import annotations

import argparse
import json
import sys
import time

# events/s regression tolerance for --check (CI smoke gate)
CHECK_TOLERANCE = 0.20


def _best_of(once, repeats):
    """Best wall time over ``repeats`` fresh runs (standard bench
    hygiene: the minimum is the least-noisy estimator of loop cost)."""
    best = None
    for _ in range(repeats):
        r = once()
        if best is None or r[0] < best[0]:
            best = r
    return best


def _time_hpl(cfg_kw, platform, *, legacy=False, repeats=3):
    from repro.core._legacy_engine import legacy_des
    from repro.core.apps.hpl import HPLConfig, HPLSim

    cfg = HPLConfig(**cfg_kw)

    def once():
        sim = HPLSim(cfg, platform)
        t0 = time.perf_counter()
        res = sim.run()
        return time.perf_counter() - t0, res.events, res.time_s

    if legacy:
        with legacy_des():
            return _best_of(once, repeats)
    return _best_of(once, repeats)


def _time_transformer(platform, wl_kw, *, legacy=False, repeats=3):
    from repro.core._legacy_engine import legacy_des
    from repro.workloads import get_workload

    wl = get_workload("transformer", **wl_kw)

    def once():
        app = wl.des_app(platform)
        t0 = time.perf_counter()
        res = app.run()
        return time.perf_counter() - t0, res["events"], res["step_s"]

    if legacy:
        with legacy_des():
            return _best_of(once, repeats)
    return _best_of(once, repeats)


def _peak_depth(build_app, t_sim: float):
    """Max queue depth over a run, sampled by a piggyback process at
    1000 points across the known sim duration (perturbs event count,
    not results — used in a separate run from the timing pass; the
    sampler must terminate or run_all() never drains)."""
    app = build_app()
    eng = app.engine
    peak = [0]
    dt = t_sim / 1000.0

    def sampler():
        for _ in range(1000):
            peak[0] = max(peak[0], eng.queue_depth())
            yield dt

    eng.spawn(sampler())
    app.run()
    return peak[0]


def run(quick: bool = True):
    from repro.core.apps.hpl import HPLConfig, HPLSim
    from repro.platforms import get_platform
    from repro.scale import RegionHPLSim

    rows = []
    plat = get_platform("frontera")

    # ------------------------- events/s, new vs legacy, per scenario
    # (legacy interleaved in the same process; ratios are honest
    # per-scenario measurements, not a single cherry-picked case)
    hpl_cases = [
        ("hpl_2x4", dict(N=4096, nb=128, P=2, Q=4, lookahead=0,
                         bcast=plat.mpi.bcast)),
        ("hpl_8x8", dict(N=6144 if quick else 16384, nb=128, P=8, Q=8,
                         lookahead=0, bcast=plat.mpi.bcast)),
    ]
    for name, cfg_kw in hpl_cases:
        wall_n, ev_n, t_sim = _time_hpl(cfg_kw, plat)
        wall_l, ev_l, t_sim_l = _time_hpl(cfg_kw, plat, legacy=True)
        assert t_sim == t_sim_l and ev_n == ev_l, \
            f"{name}: legacy stack diverged (bit-identity broken)"
        eps_new, eps_old = ev_n / wall_n, ev_l / wall_l
        depth = _peak_depth(
            lambda: HPLSim(HPLConfig(**cfg_kw), plat), t_sim)
        rows.append({
            "name": f"engine.{name}",
            "us_per_call": wall_n / ev_n * 1e6,
            "events_per_s": eps_new,
            "legacy_events_per_s": eps_old,
            "derived": f"events={ev_n};events_per_s={eps_new:.0f};"
                       f"legacy={eps_old:.0f};"
                       f"ratio={eps_new / eps_old:.2f}x;"
                       f"peak_depth={depth}"})

    tr_kw = dict(mesh=(4, 8), num_layers=4 if quick else 16)
    tpu = get_platform("tpu-v5e-pod")
    wall_n, ev_n, t_sim = _time_transformer(tpu, tr_kw)
    wall_l, ev_l, t_sim_l = _time_transformer(tpu, tr_kw, legacy=True)
    assert t_sim == t_sim_l and ev_n == ev_l
    eps_new, eps_old = ev_n / wall_n, ev_l / wall_l
    rows.append({
        "name": "engine.transformer_4x8",
        "us_per_call": wall_n / ev_n * 1e6,
        "events_per_s": eps_new,
        "legacy_events_per_s": eps_old,
        "derived": f"events={ev_n};events_per_s={eps_new:.0f};"
                   f"legacy={eps_old:.0f};ratio={eps_new / eps_old:.2f}x"})

    # ----------------------------------- ranks vs wall, both workloads
    scaling = []
    for ranks, (P, Q) in ([(16, (4, 4)), (64, (8, 8))] if quick else
                          [(64, (8, 8)), (256, (16, 16)),
                           (1024, (32, 32))]):
        cfg_kw = dict(N=128 * 24, nb=128, P=P, Q=Q, lookahead=0,
                      bcast=plat.mpi.bcast)
        wall, ev, _ = _time_hpl(cfg_kw, plat)
        scaling.append(f"{ranks}r={wall * 1e3:.0f}ms")
    rows.append({
        "name": "engine.hpl_ranks_vs_wall",
        "us_per_call": wall / ev * 1e6,
        "events_per_s": ev / wall,
        "derived": ";".join(scaling) + f";events_per_s={ev / wall:.0f}"})

    scaling = []
    for mesh in ([(2, 8), (4, 8)] if quick else [(4, 8), (8, 16), (16, 16)]):
        wall, ev, _ = _time_transformer(tpu, dict(mesh=mesh, num_layers=4))
        scaling.append(f"{mesh[0]}x{mesh[1]}={wall * 1e3:.0f}ms")
    rows.append({
        "name": "engine.transformer_ranks_vs_wall",
        "us_per_call": wall / ev * 1e6,
        "events_per_s": ev / wall,
        "derived": ";".join(scaling) + f";events_per_s={ev / wall:.0f}"})

    # -------------------------- region mode at scale (full runs only)
    if not quick:
        big = get_platform("paper-fat-tree-10008")
        cfg = HPLConfig(N=7680, nb=128, P=100, Q=100, lookahead=0,
                        bcast=big.mpi.bcast)
        sim = RegionHPLSim(cfg, big, region=12)
        t0 = time.perf_counter()
        res = sim.run()
        wall = time.perf_counter() - t0
        rows.append({
            "name": "engine.region_hpl_10k_ranks",
            "us_per_call": wall / res.events * 1e6,
            "events_per_s": res.events / wall,
            "derived": f"ranks={cfg.n_ranks};panels={cfg.n_panels};"
                       f"region=12;wall_s={wall:.1f};"
                       f"events={res.events};t_sim={res.time_s:.4f}"})
    return rows


def check(rows, baseline_path: str) -> int:
    """CI gate: fail if events/s regressed >CHECK_TOLERANCE vs the
    committed baseline.  Machine-normalized — the comparison is the
    new/legacy ratio (legacy runs in the same process, so it cancels
    runner speed); scenarios without a legacy run are informational."""
    base = {}
    with open(baseline_path) as fh:
        for line in fh:
            line = line.strip()
            if line:
                r = json.loads(line)
                base[r["name"]] = r
    failures, gated = [], 0
    for r in rows:
        name = r["name"]
        b = base.get(name)
        if b is None or "events_per_s" not in r:
            continue
        if "legacy_events_per_s" in r and "legacy_events_per_s" in b:
            now = r["events_per_s"] / r["legacy_events_per_s"]
            ref = b["events_per_s"] / b["legacy_events_per_s"]
            rel = now / ref
            gated += 1
            status = ("OK" if rel >= 1.0 - CHECK_TOLERANCE
                      else "REGRESSED")
            print(f"{name}: new/legacy ratio {now:.2f}x vs baseline "
                  f"{ref:.2f}x ({rel:.2f} relative) {status}")
            if status == "REGRESSED":
                failures.append(name)
        else:
            rel = r["events_per_s"] / float(b["events_per_s"])
            print(f"{name}: {r['events_per_s']:.0f} ev/s vs baseline "
                  f"{float(b['events_per_s']):.0f} ({rel:.2f}x) "
                  "info-only")
    if failures:
        print(f"FAIL: events/s regressed >{CHECK_TOLERANCE:.0%} vs "
              f"{baseline_path} on: {', '.join(failures)}")
        return 1
    print(f"engine bench within {CHECK_TOLERANCE:.0%} of baseline "
          f"({gated} gated scenarios)")
    return 0


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--json", action="store_true")
    ap.add_argument("--out", default=None,
                    help="also write NDJSON rows to this file")
    ap.add_argument("--check", default=None, metavar="BASELINE",
                    help="exit nonzero if events/s regressed "
                         f">{CHECK_TOLERANCE:.0%} vs this NDJSON baseline")
    args = ap.parse_args()
    rows = run(quick=not args.full)
    lines = [json.dumps(r) for r in rows]
    if args.json:
        print("\n".join(lines))
    else:
        print("name,us_per_call,derived")
        for r in rows:
            print(f"{r['name']},{r['us_per_call']:.2f},\"{r['derived']}\"")
    if args.out:
        with open(args.out, "w") as fh:
            fh.write("\n".join(lines) + "\n")
    if args.check:
        sys.exit(check(rows, args.check))


if __name__ == "__main__":
    main()
