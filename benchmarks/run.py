"""Benchmark harness — one module per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run [--full] [--json]

Default output is ``name,us_per_call,derived`` CSV (one row per
measurement); ``--json`` emits the same rows as NDJSON — one JSON object
per line — for machine consumption (BENCH_*.json trajectory tracking).
"""
from __future__ import annotations

import argparse
import json
import sys
import time
import traceback

MODULES = [
    "benchmarks.fig2_dgemm_model",      # Fig 2: DGEMM model fit, R^2
    "benchmarks.fig56_hpl_accuracy",    # Fig 5/6: measured vs simulated
    "benchmarks.fig7_scalability",      # Fig 7: sim cost vs rank count
    "benchmarks.table2_top500",         # Table II: Frontera / PupMaya
    "benchmarks.sec5_whatif",           # §V: what-if analyses
    "benchmarks.sweep_bench",           # batched sweep engine vs loop
    "benchmarks.tpu_predict",           # TPU adaptation table
    "benchmarks.train_step",            # transformer workload sweep
    "benchmarks.top500_fleet",          # TOP500 list fleet prediction
    "benchmarks.trace_breakdown",       # trace-derived comm/compute split
    "benchmarks.kernels_bench",         # Pallas kernels
    "benchmarks.faults_bench",          # degraded fleet + hardened serve
    "benchmarks.engine_bench",          # DES hot loop vs frozen legacy
    "benchmarks.serve_bench",           # serving throughput + latency
    "benchmarks.campaign_bench",        # campaign matrix + edition study
]

# --smoke: the fast subset CI runs on every push so benchmark entry
# points can't silently rot (fig56/fig7 drive multi-minute DES runs and
# stay out; they are exercised by --full trajectory runs).
SMOKE_MODULES = [
    "benchmarks.fig2_dgemm_model",
    "benchmarks.table2_top500",
    "benchmarks.sec5_whatif",
    "benchmarks.sweep_bench",
    "benchmarks.tpu_predict",
    "benchmarks.train_step",
    "benchmarks.top500_fleet",
    "benchmarks.trace_breakdown",
    "benchmarks.faults_bench",
    "benchmarks.engine_bench",
    "benchmarks.serve_bench",
    "benchmarks.campaign_bench",
]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true",
                    help="full-size benchmark configs (slow)")
    ap.add_argument("--only", default=None,
                    help="comma-separated module suffixes")
    ap.add_argument("--json", action="store_true",
                    help="emit NDJSON rows instead of CSV")
    ap.add_argument("--smoke", action="store_true",
                    help="fast CI subset (quick configs, no DES-heavy "
                         "modules)")
    args = ap.parse_args()

    if args.smoke and args.full:
        ap.error("--smoke and --full are mutually exclusive")
    modules = SMOKE_MODULES if args.smoke else MODULES
    if not args.json:
        print("name,us_per_call,derived")
    failed = 0
    for mod_name in modules:
        if args.only and not any(mod_name.endswith(o)
                                 for o in args.only.split(",")):
            continue
        try:
            mod = __import__(mod_name, fromlist=["run"])
            rows = mod.run(quick=not args.full)
            for r in rows:
                if args.json:
                    print(json.dumps(r), flush=True)
                else:
                    print(f"{r['name']},{r['us_per_call']:.2f},"
                          f"\"{r['derived']}\"", flush=True)
        except Exception as exc:
            failed += 1
            if args.json:
                print(json.dumps({"name": mod_name, "us_per_call": None,
                                  "derived": "ERROR",
                                  "error": f"{type(exc).__name__}: {exc}"}),
                      flush=True)
            else:
                print(f"{mod_name},NaN,\"ERROR\"", flush=True)
            traceback.print_exc(file=sys.stderr)
    if failed:
        sys.exit(1)


if __name__ == "__main__":
    main()
