"""Paper Fig 7: simulator cost vs MPI rank count on the 10,008-node
two-level fat-tree (556 edge x 18 core switches).

Paper: 2,000..10,000 ranks at N = 2e7; 21.8 h / 720 MB at the top end
(SystemC).  Here:
  * DES path — reduced N (quick mode) showing the same near-linear
    wall-time and linear memory scaling in rank count;
  * fastsim path — the FULL paper N=2e7 at every rank count, in seconds
    (the beyond-paper result).
"""
from __future__ import annotations

import gc
import time
import tracemalloc


def run(quick: bool = True):
    from repro.core.apps.hpl import HPLConfig, HPLSim
    from repro.core.fastsim import simulate_hpl_fast
    from repro.platforms import get_platform

    rows = []
    plat = get_platform("paper-fat-tree-10008")
    ranks_list = [512, 1152, 2048] if quick else [2048, 4608, 10000]
    N_des = 49152 if quick else 98304
    for ranks in ranks_list:
        P = int(ranks ** 0.5)
        while ranks % P:
            P -= 1
        Q = ranks // P
        cfg = HPLConfig(N=N_des, nb=192, P=P, Q=Q)
        gc.collect()
        tracemalloc.start()
        t0 = time.perf_counter()
        sim = HPLSim(cfg, plat)        # builds a fresh topology each run
        n_links = sim.net.topo.n_links
        res = sim.run()
        wall = time.perf_counter() - t0
        _, peak_mem = tracemalloc.get_traced_memory()
        tracemalloc.stop()
        rows.append({
            "name": f"fig7.des_ranks{ranks}",
            "us_per_call": wall * 1e6,
            "derived": f"events={res.events};mem_mb={peak_mem/1e6:.0f};"
                       f"links={n_links};simT={res.time_s:.2f}s;N={N_des}",
        })
    # fastsim at the paper's full matrix size
    prm = plat.fastsim()
    for ranks in ([2048, 10000] if quick else [2048, 4608, 10000]):
        P = int(ranks ** 0.5)
        while ranks % P:
            P -= 1
        Q = ranks // P
        cfg = plat.hpl_config(P=P, Q=Q)
        t0 = time.perf_counter()
        res = simulate_hpl_fast(cfg, prm)
        wall = time.perf_counter() - t0
        rows.append({
            "name": f"fig7.fastsim_ranks{ranks}_N2e7",
            "us_per_call": wall * 1e6,
            "derived": f"simT={res['time_s']/3600:.2f}h;"
                       f"tflops={res['tflops']:.0f};"
                       f"paper_systemc=21.8h_sim_wall",
        })
    return rows


if __name__ == "__main__":
    for r in run():
        print(r)
