"""Paper Table II: TOP500 systems (Frontera #5, PupMaya #25) Rmax
prediction from public configs.  Paper: Frontera 22,566 TF predicted vs
23,516 reported (-4.0%); PupMaya 7,558 vs 7,484 (+1.0%); paper sim wall
times 4.8 h / 1.7 h — ours are seconds (fastsim), and both systems run
through one sweep_hpl call (batched sweep engine)."""
from __future__ import annotations

import time

SYSTEMS = [
    # name, node_fn, nodes, Nmax, (P, Q), reported_tflops, paper_pred
    ("frontera", "frontera_node", 8008, 9_282_848, (88, 91), 23516, 22566),
    ("pupmaya", "pupmaya_node", 4248, 4_748_928, (59, 72), 7484, 7558),
]


def run(quick: bool = True):
    from repro.core.apps.hpl import HPLConfig
    from repro.core import fastsim
    from repro.core.hardware import node as node_mod

    cfgs, prms = [], []
    for name, node_fn, nodes, N, (P, Q), reported, paper_pred in SYSTEMS:
        node = getattr(node_mod, node_fn)()
        cfgs.append(HPLConfig(N=N, nb=384, P=P, Q=Q))
        prms.append(fastsim.FastSimParams.from_node(node, link_bw=100e9 / 8))
    t0 = time.perf_counter()
    results = fastsim.sweep_hpl(cfgs, prms)
    wall = time.perf_counter() - t0

    rows = []
    for (name, _, _, _, _, reported, paper_pred), res in zip(SYSTEMS,
                                                             results):
        err = (res["tflops"] - reported) / reported * 100
        err_paper = (paper_pred - reported) / reported * 100
        rows.append({
            "name": f"table2.{name}",
            "us_per_call": wall / len(SYSTEMS) * 1e6,
            "derived": f"pred_tf={res['tflops']:.0f};reported={reported};"
                       f"err={err:+.1f}%;paper_err={err_paper:+.1f}%;"
                       f"exec_h={res['time_s']/3600:.2f};"
                       f"sweep_wall_s={wall:.1f}",
        })
    return rows


if __name__ == "__main__":
    for r in run():
        print(r)
