"""Paper Table II: TOP500 systems (Frontera #5, PupMaya #25) Rmax
prediction from public configs.  Paper: Frontera 22,566 TF predicted vs
23,516 reported (-4.0%); PupMaya 7,558 vs 7,484 (+1.0%); paper sim wall
times 4.8 h / 1.7 h — ours are seconds (fastsim), and both systems run
through one sweep_hpl call (batched sweep engine).

Machine constants (grids, Nmax, reported Rmax) come from the platform
registry — this module holds no hardware numbers.
"""
from __future__ import annotations

import time

SYSTEMS = ["frontera", "pupmaya"]


def run(quick: bool = True):
    from repro.core import fastsim
    from repro.platforms import get_platform

    plats = [get_platform(name) for name in SYSTEMS]
    cfgs = [p.hpl_config() for p in plats]
    prms = [p.fastsim() for p in plats]
    t0 = time.perf_counter()
    results = fastsim.sweep_hpl(cfgs, prms)
    wall = time.perf_counter() - t0

    rows = []
    for plat, res in zip(plats, results):
        reported = plat.scale.reported_tflops
        paper_pred = plat.scale.paper_pred_tflops
        err = (res["tflops"] - reported) / reported * 100
        err_paper = (paper_pred - reported) / reported * 100
        rows.append({
            "name": f"table2.{plat.name}",
            "us_per_call": wall / len(SYSTEMS) * 1e6,
            "derived": f"pred_tf={res['tflops']:.0f};reported={reported:.0f};"
                       f"err={err:+.1f}%;paper_err={err_paper:+.1f}%;"
                       f"exec_h={res['time_s']/3600:.2f};"
                       f"sweep_wall_s={wall:.1f}",
        })
    return rows


if __name__ == "__main__":
    for r in run():
        print(r)
