"""Train-step prediction benchmark: the transformer workload over
registry platforms, at sweep scale.

Covers the second application of the workload layer the way
``table2_top500``/``sweep_bench`` cover HPL: per-platform step-time
predictions (DES-cross-validated elsewhere), plus a model-size x mesh x
hardware what-if grid served by the batched stepsim path — ≥16 scenarios
through ONE compiled program (the ``compiles=`` field in ``derived`` is
asserted by tests and tracked by CI artifacts).
"""
from __future__ import annotations

import dataclasses
import time

PLATFORMS = ("tpu-v5e-pod", "syn-torus-fugaku-4k", "syn-mp-2pod-v5e")


def run(quick: bool = True):
    from repro.platforms import get_platform
    from repro.workloads import get_workload, trace_count

    rows = []
    wl = get_workload("transformer")
    for name in PLATFORMS:
        plat = get_platform(name)
        t0 = time.perf_counter()
        pred = wl.predict(plat)
        wall = time.perf_counter() - t0
        rows.append({
            "name": f"train_step.predict_{name}",
            "us_per_call": wall * 1e6,
            "derived": f"step={pred['step_s']*1e3:.3f}ms;"
                       f"mfu={pred['mfu']:.3f};"
                       f"tok_s={pred['tokens_per_s']:.3g}",
        })

    # what-if grid: model size x link bandwidth x layer count, one
    # compile for the whole padded scenario batch
    plat = get_platform("tpu-v5e-pod")
    model = wl.fastsim_model(plat)
    base = model.params
    grid = []
    sizes = (1.0, 2.0, 4.0) if quick else (1.0, 1.5, 2.0, 3.0, 4.0)
    for fscale in sizes:                 # model width
        for lscale in (1.0, 2.0):        # link bandwidth
            for layers in (8.0, 16.0, 32.0):
                grid.append(dataclasses.replace(
                    base,
                    flops_per_layer=base.flops_per_layer * fscale,
                    bytes_per_layer=base.bytes_per_layer * fscale,
                    coll_model_bytes=base.coll_model_bytes * fscale,
                    link_bw=base.link_bw * lscale,
                    n_layers=layers))
    c0 = trace_count()
    t0 = time.perf_counter()
    res = model.sweep(grid)
    wall = time.perf_counter() - t0
    compiles = trace_count() - c0
    best = min(res, key=lambda r: r["time_s"])
    rows.append({
        "name": "train_step.whatif_sweep",
        "us_per_call": wall / len(grid) * 1e6,
        "derived": f"scenarios={len(grid)};compiles={compiles};"
                   f"wall_s={wall:.2f};best_step={best['step_s']*1e3:.2f}ms",
    })
    return rows


if __name__ == "__main__":
    for r in run():
        print(r)
