"""TPU adaptation table: SimXLA-predicted step time per (arch x shape x
mesh) vs the three-term roofline bound from the compiled dry-run —
the transformer-era Table II — plus the HPL-on-TPU sweep: Table II
recast for v5e pods, every mesh size predicted by one batched
``sweep_hpl`` program."""
from __future__ import annotations

import json
import math
import time
from pathlib import Path


def _hpl_on_tpu_rows():
    """Predict HPL Rmax on v5e meshes via the batched sweep engine.

    N is sized to ~75% of pod HBM (8 bytes per matrix element); chip
    peak, HBM capacity, and ICI numbers come from the tpu-v5e-pod
    registry entry."""
    from repro.core.fastsim import sweep_hpl
    from repro.platforms import get_platform

    plat = get_platform("tpu-v5e-pod")
    nb = plat.scale.hpl_nb
    meshes = [(4, 4), (8, 8), (16, 16)]
    cfgs = []
    for p, q in meshes:
        n_max = math.sqrt(0.75 * plat.node.hbm_bytes / 8 * p * q)
        cfgs.append(plat.hpl_config(N=int(n_max) // nb * nb, P=p, Q=q))
    prm = plat.fastsim()
    t0 = time.perf_counter()
    res = sweep_hpl(cfgs, prm)          # one sweep over all mesh sizes
    wall = time.perf_counter() - t0
    rows = []
    for (p, q), cfg, r in zip(meshes, cfgs, res):
        peak_tf = p * q * plat.node.peak_flops / 1e12
        rows.append({
            "name": f"tpu.hpl_v5e_{p}x{q}",
            "us_per_call": wall / len(meshes) * 1e6,
            "derived": f"N={cfg.N};pred_tf={r['tflops']:.0f};"
                       f"peak_tf={peak_tf:.0f};"
                       f"eff={r['tflops']/peak_tf:.2f};"
                       f"exec_s={r['time_s']:.1f}",
        })
    return rows


def run(quick: bool = True):
    # every chip/ICI number below comes from the tpu-v5e-pod registry
    # entry; fail loudly if the legacy constants ever drift from the spec
    from repro.core.simxla import SimXLA, assert_registry_consistent
    from repro.platforms import get_platform

    plat = get_platform("tpu-v5e-pod")
    assert_registry_consistent(plat)

    rows = _hpl_on_tpu_rows()
    rec_dir = Path("experiments/dryrun")
    if not rec_dir.exists():
        rows.append({"name": "tpu_predict.skipped", "us_per_call": 0,
                     "derived": "no dry-run records; run "
                                "repro.launch.dryrun --all"})
        return rows
    sim = SimXLA.for_platform(plat)
    files = sorted(rec_dir.glob("*__16x16.json"))
    if quick:
        keep = {"qwen3-moe-235b-a22b", "granite-34b", "mamba2-780m",
                "qwen2-0.5b"}
        files = [f for f in files if f.name.split("__")[0] in keep]
    for f in files:
        rec = json.loads(f.read_text())
        if not rec.get("ok"):
            continue
        p = sim.predict(rec)
        bound = rec["roofline"]["bound_s"]
        mf = rec["roofline"].get("model_flops", 0)
        mfu = (mf / max(p.step_s, 1e-12)) \
            / (rec["chips"] * plat.node.peak_flops)
        rows.append({
            "name": f"tpu.{rec['arch']}.{rec['shape']}",
            "us_per_call": p.step_s * 1e6,
            "derived": f"pred={p.step_s:.3g}s;comp={p.compute_s:.3g};"
                       f"mem={p.memory_s:.3g};coll={p.collective_s:.3g};"
                       f"mfu={mfu:.3f}",
        })
    return rows


if __name__ == "__main__":
    for r in run():
        print(r)
