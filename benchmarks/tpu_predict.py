"""TPU adaptation table: SimXLA-predicted step time per (arch x shape x
mesh) vs the three-term roofline bound from the compiled dry-run —
the transformer-era Table II."""
from __future__ import annotations

import json
from pathlib import Path


def run(quick: bool = True):
    rec_dir = Path("experiments/dryrun")
    rows = []
    if not rec_dir.exists():
        return [{"name": "tpu_predict.skipped", "us_per_call": 0,
                 "derived": "no dry-run records; run repro.launch.dryrun --all"}]
    from repro.core.simxla import SimXLA
    sim = SimXLA()
    files = sorted(rec_dir.glob("*__16x16.json"))
    if quick:
        keep = {"qwen3-moe-235b-a22b", "granite-34b", "mamba2-780m",
                "qwen2-0.5b"}
        files = [f for f in files if f.name.split("__")[0] in keep]
    for f in files:
        rec = json.loads(f.read_text())
        if not rec.get("ok"):
            continue
        p = sim.predict(rec)
        bound = rec["roofline"]["bound_s"]
        mf = rec["roofline"].get("model_flops", 0)
        mfu = (mf / max(p.step_s, 1e-12)) / (rec["chips"] * 197e12)
        rows.append({
            "name": f"tpu.{rec['arch']}.{rec['shape']}",
            "us_per_call": p.step_s * 1e6,
            "derived": f"pred={p.step_s:.3g}s;comp={p.compute_s:.3g};"
                       f"mem={p.memory_s:.3g};coll={p.collective_s:.3g};"
                       f"mfu={mfu:.3f}",
        })
    return rows


if __name__ == "__main__":
    for r in run():
        print(r)
