"""Paper §V what-if analyses.

  (1) HPL on a 200 Gb/s fabric (paper: +2.6% Frontera, +3.9% PupMaya —
      conclusion: not worth the upgrade);
  (2) TPU edition: ICI/HBM/peak what-ifs for a representative train cell;
  (3) straggler what-if via the DES transformer app.
"""
from __future__ import annotations

import time
from pathlib import Path


def run(quick: bool = True):
    import dataclasses

    from repro.core.fastsim import sweep_hpl
    from repro.platforms import get_platform

    systems = [get_platform("frontera"), get_platform("pupmaya")]
    cfgs, prms = [], []
    for plat in systems:
        base = plat.fastsim()
        for scale in (1.0, 2.0):        # 100 vs 200 Gb/s fabric
            cfgs.append(plat.hpl_config())
            prms.append(dataclasses.replace(
                base, link_bw=base.link_bw * scale))
    # both systems x both fabrics: one sweep, one compile per bucket
    res = sweep_hpl(cfgs, prms)

    rows = []
    for i, plat in enumerate(systems):
        r100, r200 = res[2 * i], res[2 * i + 1]
        gain = (r200["tflops"] / r100["tflops"] - 1) * 100
        rows.append({
            "name": f"sec5.hpl_200g_{plat.name}",
            "us_per_call": 0.0,
            "derived": f"tf100={r100['tflops']:.0f};tf200={r200['tflops']:.0f};"
                       f"gain={gain:+.1f}%;paper=+2.6%/+3.9%",
        })

    # TPU what-ifs need dry-run records
    rec_dir = Path("experiments/dryrun")
    if (rec_dir / "qwen3-moe-235b-a22b__train_4k__16x16.json").exists():
        from repro.core.predict import whatif, predict_cell_des
        for scale_name, kw in [("ici_x2", dict(link_bw_scale=2.0)),
                               ("hbm_x2", dict(hbm_bw_scale=2.0)),
                               ("peak_x2", dict(peak_scale=2.0))]:
            w = whatif("qwen3-moe-235b-a22b", "train_4k", **kw)
            rows.append({
                "name": f"sec5.tpu_{scale_name}_qwen3moe",
                "us_per_call": w["baseline_s"] * 1e6,
                "derived": f"base={w['baseline_s']:.2f}s;"
                           f"whatif={w['whatif_s']:.2f}s;"
                           f"speedup={w['speedup']:.2f}x",
            })
        t0 = time.perf_counter()
        from repro.ft.straggler import simulate_straggler_impact
        s = simulate_straggler_impact("qwen2-0.5b", "train_4k",
                                      slowdown=3.0)
        rows.append({
            "name": "sec5.straggler_3x_qwen2",
            "us_per_call": (time.perf_counter() - t0) * 1e6,
            "derived": f"base={s['baseline_s']:.3f}s;"
                       f"slow={s['straggler_s']:.3f}s;"
                       f"blowup={s['blowup']:.2f}x;verdict={s['verdict']}",
        })
    return rows


if __name__ == "__main__":
    for r in run():
        print(r)
