"""Paper Fig 2: DGEMM analytical model `E = mu*ops + theta` fitted to real
measurements on this container's CPU; reports R^2 (paper: 0.9998)."""
from __future__ import annotations

import time


def run(quick: bool = True):
    from repro.core.calibrate import measure_dgemm
    t0 = time.perf_counter()
    fit = measure_dgemm(sizes=[128, 256, 384, 512, 768, 1024]
                        if quick else None,
                        min_time=0.03 if quick else 0.1)
    wall = time.perf_counter() - t0
    rows = [{
        "name": "fig2.dgemm_fit",
        "us_per_call": fit.theta * 1e6,
        "derived": f"R2={fit.r2:.5f};eff_gflops={fit.eff_flops/1e9:.1f};"
                   f"mu={fit.mu:.3e};points={len(fit.points)};"
                   f"wall_s={wall:.1f}",
    }]
    return rows


if __name__ == "__main__":
    for r in run():
        print(r)
