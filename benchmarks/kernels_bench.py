"""Per-Pallas-kernel microbench: interpret-mode correctness deltas vs ref
+ analytic TPU-roofline timings for the production block shapes."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np


def _maxerr(a, b):
    return float(jnp.max(jnp.abs(a.astype(jnp.float32)
                                 - b.astype(jnp.float32))))


def run(quick: bool = True):
    rows = []
    key = jax.random.PRNGKey(0)

    # flash attention
    from repro.kernels.flash_attention.kernel import flash_attention_fwd
    from repro.kernels.flash_attention.ref import attention_ref
    b, s, g, r, hd = (1, 256, 1, 4, 64) if quick else (2, 1024, 2, 4, 128)
    q = jax.random.normal(key, (b, s, g, r, hd))
    k = jax.random.normal(key, (b, s, g, hd))
    v = jax.random.normal(key, (b, s, g, hd))
    t0 = time.perf_counter()
    out = flash_attention_fwd(q, k, v, causal=True, interpret=True)
    wall = time.perf_counter() - t0
    err = _maxerr(out, attention_ref(q, k, v))
    # analytic TPU time at roofline: 2*2*B*S^2*G*R*hd flops (causal /2)
    flops = 2 * 2 * b * s * s * g * r * hd / 2
    rows.append({"name": "kern.flash_attention",
                 "us_per_call": wall * 1e6,
                 "derived": f"err={err:.2e};tpu_roofline_us="
                            f"{flops/197e12*1e6:.2f}"})

    # ssd
    from repro.kernels.ssd_scan.kernel import ssd_scan
    from repro.kernels.ssd_scan.ref import ssd_ref_sequential
    bs, ss, hh, pp, nn = (1, 128, 2, 16, 8) if quick else (2, 512, 4, 64, 128)
    xh = jax.random.normal(key, (bs, ss, hh, pp))
    dt = jax.nn.softplus(jax.random.normal(key, (bs, ss, hh)))
    A = -jnp.exp(jax.random.normal(key, (hh,)))
    Bh = jax.random.normal(key, (bs, ss, hh, nn))
    Ch = jax.random.normal(key, (bs, ss, hh, nn))
    t0 = time.perf_counter()
    y = ssd_scan(xh, dt, A, Bh, Ch, 32 if quick else 128, interpret=True)
    wall = time.perf_counter() - t0
    err = _maxerr(y, ssd_ref_sequential(xh, dt, A, Bh, Ch))
    rows.append({"name": "kern.ssd_scan", "us_per_call": wall * 1e6,
                 "derived": f"err={err:.2e}"})

    # maxmin
    from repro.kernels.maxmin_fair.ops import waterfill
    from repro.kernels.maxmin_fair.ref import waterfill_ref
    F, L = (128, 128) if quick else (1024, 1024)
    adj = (jax.random.uniform(key, (F, L)) < 0.05).astype(jnp.int8)
    caps = jax.random.uniform(key, (L,)) * 1e9 + 1e8
    t0 = time.perf_counter()
    rk = waterfill(adj, caps, use_kernel=True)
    wall = time.perf_counter() - t0
    err = _maxerr(jnp.minimum(rk, 1e30),
                  jnp.minimum(waterfill_ref(adj, caps), 1e30))
    rows.append({"name": "kern.maxmin_waterfill",
                 "us_per_call": wall * 1e6,
                 "derived": f"err={err:.2e};F={F};L={L}"})
    return rows


if __name__ == "__main__":
    for r in run():
        print(r)
