"""Campaign-layer benchmark: spec -> matrix -> batched execution.

Three measurements:

  * ``campaign.expand`` — pure planning throughput: a four-axis grid
    spec expanded to its run matrix (no compiles, no simulation), in
    cases/s.  Expansion must stay trivially cheap next to execution.
  * ``campaign.grid_wave`` — the ISSUE's acceptance matrix (2 workload
    families x 3 heterogeneous platforms x axes x faults x seeds)
    through ``run_campaign``: runs/s plus the dispatch economy the
    layer exists for, read off the obs compile counters.
  * ``campaign.edition_study`` — the longitudinal TOP500 study (two
    vendored editions, proxy-scaled fleet sweeps, per-fabric
    calibration, drift report), end to end in machines/s.

The CI gate (``--check``) is machine-speed independent: it fails when
the *dispatch counts* drift from the committed baseline — if the grid
wave ever stops costing one compiled sweep per model family, or the
edition study stops costing one forced-bucket compile per cold edition,
that is a batching regression no wall-clock tolerance should absorb.

    PYTHONPATH=src python benchmarks/campaign_bench.py --json \
        --out BENCH_campaign.json
    PYTHONPATH=src python benchmarks/campaign_bench.py --check \
        BENCH_campaign.json
"""
from __future__ import annotations

import argparse
import json
import sys
import time

#: dispatch-count keys the --check gate compares exactly
GATED_KEYS = ("fastsim_dispatches", "stepsim_dispatches", "serve_sweeps")


def _grid_spec(n_seeds):
    from repro.campaign import CampaignSpec
    from repro.faults import FaultSpec
    return CampaignSpec.make(
        "bench-grid",
        workloads=["hpl", "transformer"],
        platforms=["tpu-v5e-pod", "syn-torus-fugaku-4k",
                   "syn-torus-bgq-8k"],
        axes={"N": [1536, 1920]},
        faults=[None, FaultSpec.straggler(rank=0, slowdown=1.5)],
        seeds=list(range(n_seeds)))


def _expand_spec():
    """A wide planning-only spec (validated against the registry, never
    executed): 4 axes x 3 platforms x faults x seeds."""
    from repro.campaign import CampaignSpec
    from repro.faults import FaultSpec
    return CampaignSpec.make(
        "bench-expand",
        workloads=["hpl"],
        platforms=["tpu-v5e-pod", "syn-torus-fugaku-4k",
                   "syn-torus-bgq-8k"],
        axes={"N": [1536, 1920, 2304], "nb": [128, 192],
              "lookahead": [0, 1]},
        faults=[None, FaultSpec.straggler(rank=0, slowdown=2.0)],
        seeds=list(range(8)),
        max_runs=10_000)


def run(quick: bool = True):
    from repro.campaign import expand, run_campaign
    from repro.top500 import FleetTuning

    rows = []

    # ------------------------------------------------- pure expansion
    spec = _expand_spec()
    expand(spec)                                   # warm imports
    reps = 5 if quick else 20
    best = min(_timed(lambda: expand(spec)) for _ in range(reps))
    n_cases = len(expand(spec).cases)
    rows.append({
        "name": "campaign.expand",
        "us_per_call": best / n_cases * 1e6,
        "cases_per_s": n_cases / best,
        "derived": f"cases={n_cases};cases_per_s={n_cases / best:.0f}"})

    # ---------------------------------------------- grid execution
    grid = _grid_spec(2 if quick else 8)
    run_campaign(grid)                             # warm compile caches
    best_wall, best_res = None, None
    for _ in range(3 if quick else 5):
        t0 = time.perf_counter()
        res = run_campaign(grid)
        wall = time.perf_counter() - t0
        if best_wall is None or wall < best_wall:
            best_wall, best_res = wall, res
    d = best_res.summary["meta"]["dispatches"]
    n_runs = best_res.summary["meta"]["runs"]
    rows.append({
        "name": "campaign.grid_wave",
        "us_per_call": best_wall / n_runs * 1e6,
        "runs_per_s": n_runs / best_wall,
        "dispatches": d,
        "derived": f"runs={n_runs};runs_per_s={n_runs / best_wall:.0f};"
                   f"fastsim={d['fastsim_dispatches']};"
                   f"stepsim={d['stepsim_dispatches']};"
                   f"sweeps={d['serve_sweeps']}"})

    # ------------------------------------------------ edition study
    from repro.campaign import edition_study_spec
    study = edition_study_spec(["2020_06", "2020_11"],
                               limit=10 if quick else 0)
    tuning = FleetTuning(max_ranks=256, panels_cap=2048)
    run_campaign(study, tuning=tuning)             # warm fleet bucket
    t0 = time.perf_counter()
    res = run_campaign(study, tuning=tuning)
    wall = time.perf_counter() - t0
    meta = res.summary["meta"]
    n_machines = meta["fleet_runs"]
    from repro.campaign import campaign_report
    drift = campaign_report(res.records)["drift"]["common_machines"]
    rows.append({
        "name": "campaign.edition_study",
        "us_per_call": wall / n_machines * 1e6,
        "machines_per_s": n_machines / wall,
        "dispatches": meta["dispatches"],
        "derived": f"machines={n_machines};editions=2;"
                   f"common={drift};"
                   f"machines_per_s={n_machines / wall:.0f};"
                   f"fastsim={meta['dispatches']['fastsim_dispatches']}"})
    return rows


def _timed(fn):
    t0 = time.perf_counter()
    fn()
    return time.perf_counter() - t0


def check(rows, baseline_path: str) -> int:
    """CI gate: dispatch counts must match the committed baseline
    exactly (batching economy is not allowed to drift); wall-clock
    numbers are informational."""
    base = {}
    with open(baseline_path) as fh:
        for line in fh:
            line = line.strip()
            if line:
                r = json.loads(line)
                base[r["name"]] = r
    failures, gated = [], 0
    for r in rows:
        b = base.get(r["name"])
        if b is None or "dispatches" not in r:
            continue
        gated += 1
        now = {k: r["dispatches"].get(k) for k in GATED_KEYS}
        ref = {k: b["dispatches"].get(k) for k in GATED_KEYS}
        status = "OK" if now == ref else "REGRESSED"
        print(f"{r['name']}: dispatches {now} vs baseline {ref} {status}")
        if status == "REGRESSED":
            failures.append(r["name"])
    if failures:
        print(f"FAIL: campaign dispatch economy drifted vs "
              f"{baseline_path} on: {', '.join(failures)}")
        return 1
    print(f"campaign bench dispatch counts match baseline "
          f"({gated} gated scenarios)")
    return 0


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--json", action="store_true")
    ap.add_argument("--out", default=None,
                    help="also write NDJSON rows to this file")
    ap.add_argument("--check", default=None, metavar="BASELINE",
                    help="exit nonzero if dispatch counts drifted vs "
                         "this NDJSON baseline")
    args = ap.parse_args()
    rows = run(quick=not args.full)
    lines = [json.dumps(r) for r in rows]
    if args.json:
        print("\n".join(lines))
    else:
        print("name,us_per_call,derived")
        for r in rows:
            print(f"{r['name']},{r['us_per_call']:.2f},\"{r['derived']}\"")
    if args.out:
        with open(args.out, "w") as fh:
            fh.write("\n".join(lines) + "\n")
    if args.check:
        sys.exit(check(rows, args.check))


if __name__ == "__main__":
    main()
