"""Batched sweep engine vs per-config loop (DESIGN.md §11).

A 64-scenario hardware what-if grid (8 link bandwidths x 8 GEMM
efficiencies) on a small-cluster HPL config: the loop path dispatches 64
single-scenario programs (all warm — params are traced, so they share
one compile); the batched path serves the whole grid as one program with
a trailing scenario axis.  Target: >= 10x wall-time win, results
matching to 1e-6."""
from __future__ import annotations

import dataclasses
import itertools
import time


def _best(fn, reps: int = 3) -> float:
    ts = []
    for _ in range(reps):
        t0 = time.perf_counter()
        fn()
        ts.append(time.perf_counter() - t0)
    return min(ts)


def run(quick: bool = True):
    from repro.core.fastsim import (simulate_hpl_fast, sweep_hpl,
                                    trace_count)
    from repro.platforms import get_platform

    plat = get_platform("frontera")
    cfg = plat.hpl_config(N=32768 if quick else 65536, nb=128, P=2, Q=4)
    base = plat.fastsim()
    grid = [dataclasses.replace(base, link_bw=base.link_bw * s,
                                gemm_eff=base.gemm_eff * e)
            for s, e in itertools.product(
                [0.5, 0.75, 1.0, 1.5, 2.0, 3.0, 4.0, 8.0],
                [0.90, 0.95, 0.97, 1.0, 1.02, 1.05, 1.07, 1.10])]

    # warm both paths (one compile each: single-lane and batched bucket)
    simulate_hpl_fast(cfg, grid[0])
    sweep_hpl(cfg, grid)
    traces_warm = trace_count()

    loop = [simulate_hpl_fast(cfg, p) for p in grid]
    t_loop = _best(lambda: [simulate_hpl_fast(cfg, p) for p in grid])
    batched = sweep_hpl(cfg, grid)
    t_batch = _best(lambda: sweep_hpl(cfg, grid))

    max_rel = max(abs(a["time_s"] - b["time_s"]) / b["time_s"]
                  for a, b in zip(batched, loop))
    speedup = t_loop / t_batch
    retraces = trace_count() - traces_warm
    return [
        {"name": "sweep.loop64",
         "us_per_call": t_loop / len(grid) * 1e6,
         "derived": f"wall_ms={t_loop*1e3:.1f};n={len(grid)}"},
        {"name": "sweep.batched64",
         "us_per_call": t_batch / len(grid) * 1e6,
         "derived": f"wall_ms={t_batch*1e3:.1f};speedup={speedup:.1f}x;"
                    f"max_rel={max_rel:.1e};retraces_after_warmup="
                    f"{retraces}"},
    ]


if __name__ == "__main__":
    for r in run():
        print(r)
