"""Mamba-2 (SSD — state-space duality, arXiv:2405.21060) layer.

Training/prefill uses the chunked SSD algorithm as a ``lax.scan`` over
chunks (O(S·Q) memory); decode is the O(1) state recurrence.  The
perf-critical chunk kernel has a Pallas TPU implementation in
``repro.kernels.ssd_scan`` (selected with ``use_kernel=True``); this module
is the pure-XLA baseline and the decode path.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax

from .layers import _dense_init, cast


def dims(cfg):
    s = cfg.ssm
    d = cfg.d_model
    din = s.d_inner(d)
    nh = s.n_heads(d)
    return din, nh, s.d_state, s.n_groups, s.head_dim, s.d_conv, s.chunk_size


def init_ssm(key, cfg):
    din, nh, ns, ng, hp, dc, _ = dims(cfg)
    d = cfg.d_model
    ks = jax.random.split(key, 8)
    p = {
        "in_z": _dense_init(ks[0], (d, din)),
        "in_x": _dense_init(ks[1], (d, din)),
        "in_B": _dense_init(ks[2], (d, ng * ns)),
        "in_C": _dense_init(ks[3], (d, ng * ns)),
        "in_dt": _dense_init(ks[4], (d, nh)),
        "conv_x": jax.random.normal(ks[5], (dc, din), jnp.float32) * 0.1,
        "conv_B": jax.random.normal(ks[5], (dc, ng * ns), jnp.float32) * 0.1,
        "conv_C": jax.random.normal(ks[5], (dc, ng * ns), jnp.float32) * 0.1,
        "conv_bias": jnp.zeros((din + 2 * ng * ns,), jnp.float32),
        "A_log": jnp.log(jnp.linspace(1.0, 16.0, nh, dtype=jnp.float32)),
        "D": jnp.ones((nh,), jnp.float32),
        "dt_bias": jnp.log(jnp.expm1(jnp.full((nh,), 1e-2, jnp.float32))),
        "norm_scale": jnp.ones((din,), jnp.float32),
        "out": _dense_init(ks[6], (din, d)),
    }
    return p


def spec_ssm(cfg):
    return {
        "in_z": ("fsdp", "tp"), "in_x": ("fsdp", "tp"),
        "in_B": ("fsdp", None), "in_C": ("fsdp", None),
        "in_dt": ("fsdp", None),
        "conv_x": (None, "tp"), "conv_B": (None, None), "conv_C": (None, None),
        "conv_bias": (None,),
        "A_log": (None,), "D": (None,), "dt_bias": (None,),
        "norm_scale": ("tp",),
        "out": ("tp", "fsdp"),
    }


def _causal_conv(x, w, b):
    """x: (B, S, C); w: (K, C); depthwise causal conv + bias."""
    k = w.shape[0]
    xp = jnp.pad(x, ((0, 0), (k - 1, 0), (0, 0)))
    y = sum(xp[:, i:i + x.shape[1], :] * cast(w[i], x.dtype) for i in range(k))
    return y + cast(b, x.dtype)


def _gated_norm(y, z, scale, eps=1e-5):
    y = y * jax.nn.silu(z)
    yf = y.astype(jnp.float32)
    var = jnp.mean(jnp.square(yf), axis=-1, keepdims=True)
    return (yf * lax.rsqrt(var + eps) * scale).astype(y.dtype)


def _heads_bc(t, nh, ng):
    """(B,S,G,N) -> broadcast groups to heads -> (B,S,H,N)."""
    if ng == nh:
        return t
    rep = nh // ng
    b, s, g, n = t.shape
    return jnp.broadcast_to(t[:, :, :, None, :], (b, s, g, rep, n)) \
              .reshape(b, s, nh, n)


def ssd_chunked(xh, dt, A, Bh, Ch, chunk):
    """Chunked SSD scan (pure XLA baseline).

    xh: (B,S,H,P); dt: (B,S,H) f32 (post-softplus); A: (H,) f32 (negative);
    Bh, Ch: (B,S,H,N).  Returns y: (B,S,H,P) and final state (B,H,P,N).
    """
    b, s0, h, p = xh.shape
    n = Bh.shape[-1]
    # pad S to a chunk multiple with dt=0 (identity state transition: the
    # padded steps neither decay the state nor inject input)
    s = ((s0 + chunk - 1) // chunk) * chunk
    if s != s0:
        pad = ((0, 0), (0, s - s0), (0, 0), (0, 0))
        xh = jnp.pad(xh, pad)
        Bh = jnp.pad(Bh, pad)
        Ch = jnp.pad(Ch, pad)
        dt = jnp.pad(dt, ((0, 0), (0, s - s0), (0, 0)))
    nc = s // chunk
    dtype = xh.dtype

    def reshape_c(t):
        return t.reshape(b, nc, chunk, *t.shape[2:])

    xc, dtc = reshape_c(xh), reshape_c(dt)
    Bc, Cc = reshape_c(Bh), reshape_c(Ch)
    Adt = dtc * A[None, None, None, :]                     # (B,nc,Q,H) ≤ 0

    def body(hstate, inp):
        xq, dtq, Aq, Bq, Cq = inp                          # (B,Q,...)
        cum = jnp.cumsum(Aq, axis=1)                       # (B,Q,H)
        # intra-chunk (dual / attention-like form).  The (Q,Q,H) tiles are
        # kept in the compute dtype (bf16 in training): decays are <= 1 so
        # bf16 is safe, and these tiles never leave VMEM in the Pallas
        # kernel — f32 here would double their HBM traffic in the XLA path
        # (EXPERIMENTS.md §Perf, mamba2 iteration 3).
        L = cum[:, :, None, :] - cum[:, None, :, :]        # (B,Q,Q,H) i from j
        iq = jnp.arange(chunk)
        causal = (iq[:, None] >= iq[None, :])[None, :, :, None]
        L = jnp.where(causal, jnp.exp(L), 0.0).astype(dtype)
        CB = jnp.einsum("bihn,bjhn->bijh", Cq, Bq)         # compute dtype
        M = CB * L * dtq[:, None, :, :].astype(dtype)      # (B,Q,Q,H)
        y_intra = jnp.einsum("bijh,bjhp->bihp", M, xq)
        # inter-chunk: contribution of carried state
        y_inter = jnp.einsum("bqhn,bhpn->bqhp",
                             (Cq.astype(jnp.float32)
                              * jnp.exp(cum)[..., None]).astype(dtype),
                             hstate.astype(dtype))
        # new chunk state
        last = cum[:, -1:, :]                              # (B,1,H)
        decay = jnp.exp(last - cum)                        # (B,Q,H)
        Sc = jnp.einsum("bqhn,bqhp->bhpn",
                        (Bq.astype(jnp.float32) * (decay * dtq)[..., None]
                         ).astype(dtype), xq)
        h_new = (jnp.exp(last[:, 0, :])[:, :, None, None]
                 * hstate + Sc.astype(jnp.float32))
        return h_new, y_intra + y_inter

    h0 = jnp.zeros((b, h, p, n), jnp.float32)
    # remat the chunk body: without it the scan linearization stacks every
    # per-chunk (Q,Q,H) tile for the backward pass — the dominant HBM
    # traffic of the dp-sharded mamba2 cell (EXPERIMENTS.md §Perf, iter 4)
    hT, yc = lax.scan(jax.checkpoint(body), h0,
                      (jnp.moveaxis(xc, 1, 0), jnp.moveaxis(dtc, 1, 0),
                       jnp.moveaxis(Adt, 1, 0), jnp.moveaxis(Bc, 1, 0),
                       jnp.moveaxis(Cc, 1, 0)))
    y = jnp.moveaxis(yc, 0, 1).reshape(b, s, h, p)
    return y[:, :s0], hT


def _ssm_fwd(p, x, cfg, use_kernel=False, want_state=False):
    din, nh, ns, ng, hp, dc, chunk = dims(cfg)
    b, s, d = x.shape
    dtype = x.dtype
    z = jnp.einsum("bsd,de->bse", x, cast(p["in_z"], dtype))
    xi = jnp.einsum("bsd,de->bse", x, cast(p["in_x"], dtype))
    Bi = jnp.einsum("bsd,de->bse", x, cast(p["in_B"], dtype))
    Ci = jnp.einsum("bsd,de->bse", x, cast(p["in_C"], dtype))
    dt = jnp.einsum("bsd,dh->bsh", x, cast(p["in_dt"], dtype))

    conv_tail = None
    if want_state:  # pre-conv tail feeds the decode-time conv window
        conv_tail = jnp.concatenate([xi, Bi, Ci], axis=-1)[:, -(dc - 1):, :]

    cb = p["conv_bias"]
    xi = jax.nn.silu(_causal_conv(xi, p["conv_x"], cb[:din]))
    Bi = jax.nn.silu(_causal_conv(Bi, p["conv_B"], cb[din:din + ng * ns]))
    Ci = jax.nn.silu(_causal_conv(Ci, p["conv_C"], cb[din + ng * ns:]))

    A = -jnp.exp(p["A_log"])                                # (H,) < 0
    dtf = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])
    xh = xi.reshape(b, s, nh, hp)
    Bh = _heads_bc(Bi.reshape(b, s, ng, ns), nh, ng)
    Ch = _heads_bc(Ci.reshape(b, s, ng, ns), nh, ng)

    if use_kernel and not want_state:
        from repro.kernels.ssd_scan import ops as ssd_ops
        y = ssd_ops.ssd(xh, dtf, A, Bh, Ch, chunk)
        hT = None
    else:
        y, hT = ssd_chunked(xh, dtf, A, Bh, Ch, chunk)
    y = y + xh * cast(p["D"], dtype)[None, None, :, None]
    y = y.reshape(b, s, din)
    y = _gated_norm(y, z, p["norm_scale"])
    out = jnp.einsum("bse,ed->bsd", y, cast(p["out"], dtype))
    if want_state:
        return out, {"conv": conv_tail.astype(jnp.float32), "state": hT}
    return out


def apply_ssm(p, x, cfg, use_kernel=False):
    """Full-sequence Mamba-2 mixer.  x: (B,S,D) -> (B,S,D)."""
    return _ssm_fwd(p, x, cfg, use_kernel=use_kernel, want_state=False)


def apply_ssm_prefill(p, x, cfg):
    """Like apply_ssm but also returns the decode cache {'conv','state'}."""
    return _ssm_fwd(p, x, cfg, want_state=True)


# ---------------------------------------------------------------------------
# decode


def init_ssm_cache(cfg, batch, dtype=jnp.float32):
    din, nh, ns, ng, hp, dc, _ = dims(cfg)
    return {
        "conv": jnp.zeros((batch, dc - 1, din + 2 * ng * ns), dtype),
        "state": jnp.zeros((batch, nh, hp, ns), jnp.float32),
    }


def spec_ssm_cache(cfg):
    return {"conv": ("dp", None, None), "state": ("dp", "tp", None, None)}


def apply_ssm_decode(p, x, cfg, cache):
    """x: (B,1,D); cache: {'conv': (B,K-1,C), 'state': (B,H,P,N)}."""
    din, nh, ns, ng, hp, dc, _ = dims(cfg)
    b = x.shape[0]
    dtype = x.dtype
    z = jnp.einsum("bsd,de->bse", x, cast(p["in_z"], dtype))
    xi = jnp.einsum("bsd,de->bse", x, cast(p["in_x"], dtype))
    Bi = jnp.einsum("bsd,de->bse", x, cast(p["in_B"], dtype))
    Ci = jnp.einsum("bsd,de->bse", x, cast(p["in_C"], dtype))
    dt = jnp.einsum("bsd,dh->bsh", x, cast(p["in_dt"], dtype))

    new_col = jnp.concatenate([xi, Bi, Ci], axis=-1)        # (B,1,C)
    window = jnp.concatenate([cache["conv"].astype(dtype), new_col], axis=1)
    conv_w = jnp.concatenate([p["conv_x"], p["conv_B"], p["conv_C"]], axis=1)
    conv = jnp.einsum("bkc,kc->bc", window, cast(conv_w, dtype)) \
        + cast(p["conv_bias"], dtype)
    conv = jax.nn.silu(conv)
    xi = conv[:, :din]
    Bi = conv[:, din:din + ng * ns]
    Ci = conv[:, din + ng * ns:]
    new_conv_cache = window[:, 1:, :].astype(cache["conv"].dtype)

    A = -jnp.exp(p["A_log"])
    dtf = jax.nn.softplus(dt[:, 0, :].astype(jnp.float32) + p["dt_bias"])
    xh = xi.reshape(b, nh, hp).astype(jnp.float32)
    Bh = _heads_bc(Bi.reshape(b, 1, ng, ns), nh, ng)[:, 0].astype(jnp.float32)
    Ch = _heads_bc(Ci.reshape(b, 1, ng, ns), nh, ng)[:, 0].astype(jnp.float32)

    decay = jnp.exp(dtf * A[None, :])                       # (B,H)
    h_new = (cache["state"] * decay[:, :, None, None]
             + jnp.einsum("bhn,bhp->bhpn", Bh * dtf[..., None], xh))
    y = jnp.einsum("bhn,bhpn->bhp", Ch, h_new)
    y = y + xh * p["D"][None, :, None]
    y = y.reshape(b, 1, din).astype(dtype)
    y = _gated_norm(y, z, p["norm_scale"])
    out = jnp.einsum("bse,ed->bsd", y, cast(p["out"], dtype))
    return out, {"conv": new_conv_cache, "state": h_new}
