"""Core transformer building blocks (functional, pytree params).

Every ``init_*`` has a sibling ``spec_*`` returning an identical tree of
*logical* partition specs (tuples of logical axis names / None).  Logical
axes: ``dp`` (batch), ``fsdp`` (ZeRO weight shard), ``tp`` (tensor
parallel), ``sp`` (sequence).  ``sharding/specs.py`` resolves them onto the
physical mesh.
"""
from __future__ import annotations

import math
from functools import partial
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
from jax import lax

Params = Dict[str, Any]

# ---------------------------------------------------------------------------
# helpers


def _dense_init(key, shape, in_axis=-2, dtype=jnp.float32):
    fan_in = shape[in_axis] if len(shape) > 1 else shape[0]
    if len(shape) > 2:  # (D, H, hd) style: fan-in is the leading dim
        fan_in = shape[0]
    scale = 1.0 / math.sqrt(max(fan_in, 1))
    return jax.random.normal(key, shape, dtype) * scale


def cast(x, dtype):
    return x.astype(dtype) if x.dtype != dtype else x


# ---------------------------------------------------------------------------
# norms


def init_norm(key, d, norm="rms"):
    p = {"scale": jnp.ones((d,), jnp.float32)}
    if norm == "ln":
        p["bias"] = jnp.zeros((d,), jnp.float32)
    return p


def spec_norm(norm="rms"):
    p = {"scale": (None,)}
    if norm == "ln":
        p["bias"] = (None,)
    return p


def apply_norm(p, x, norm="rms", eps=1e-5):
    xf = x.astype(jnp.float32)
    if norm == "rms":
        var = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
        y = xf * jax.lax.rsqrt(var + eps) * p["scale"]
    else:
        mu = jnp.mean(xf, axis=-1, keepdims=True)
        var = jnp.var(xf, axis=-1, keepdims=True)
        y = (xf - mu) * jax.lax.rsqrt(var + eps) * p["scale"] + p["bias"]
    return y.astype(x.dtype)


# ---------------------------------------------------------------------------
# rotary embedding


def rope(x, positions, theta=10000.0):
    """x: (B, S, ..., hd), positions: (B, S) int32. Works for any rank."""
    hd = x.shape[-1]
    half = hd // 2
    freqs = jnp.exp(-math.log(theta) * jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = positions[..., None].astype(jnp.float32) * freqs      # (B, S, half)
    shape = ang.shape[:2] + (1,) * (x.ndim - 3) + (half,)
    cos = jnp.cos(ang).reshape(shape)
    sin = jnp.sin(ang).reshape(shape)
    x1, x2 = x[..., :half], x[..., half:]
    y1 = x1 * cos - x2 * sin
    y2 = x2 * cos + x1 * sin
    return jnp.concatenate([y1, y2], axis=-1).astype(x.dtype)


def sinusoidal_positions(seq_len, d_model):
    pos = jnp.arange(seq_len, dtype=jnp.float32)[:, None]
    dim = jnp.arange(0, d_model, 2, dtype=jnp.float32)[None, :]
    ang = pos / jnp.power(10000.0, dim / d_model)
    pe = jnp.zeros((seq_len, d_model), jnp.float32)
    pe = pe.at[:, 0::2].set(jnp.sin(ang))
    pe = pe.at[:, 1::2].set(jnp.cos(ang))
    return pe


# ---------------------------------------------------------------------------
# attention


def init_attention(key, cfg):
    """Grouped layout: wq (D, G, R, hd) where G = kv groups, R = H/G reps.

    No (G·R)↔H reshapes ever touch a sharded dim, so GSPMD propagation is
    exact whichever of G / R the mesh's `model` axis shards.
    """
    d, h, kv, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.resolved_head_dim
    r = h // kv
    ks = jax.random.split(key, 4)
    p = {
        "wq": _dense_init(ks[0], (d, kv, r, hd)),
        "wk": _dense_init(ks[1], (d, kv, hd)),
        "wv": _dense_init(ks[2], (d, kv, hd)),
        "wo": _dense_init(ks[3], (kv, r, hd, d)),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((kv, r, hd), jnp.float32)
        p["bk"] = jnp.zeros((kv, hd), jnp.float32)
        p["bv"] = jnp.zeros((kv, hd), jnp.float32)
    return p


def spec_attention(cfg):
    p = {
        "wq": ("fsdp", "tp_kv", "tp_rep", None),
        "wk": ("fsdp", "tp_kv", None),
        "wv": ("fsdp", "tp_kv", None),
        "wo": ("tp_kv", "tp_rep", None, "fsdp"),
    }
    if cfg.qkv_bias:
        p["bq"] = ("tp_kv", "tp_rep", None)
        p["bk"] = ("tp_kv", None)
        p["bv"] = ("tp_kv", None)
    return p


def _qkv(p, x, cfg, positions):
    """Returns q: (B,S,G,R,hd); k, v: (B,S,G,hd)."""
    dtype = x.dtype
    q = jnp.einsum("bsd,dgrk->bsgrk", x, cast(p["wq"], dtype))
    k = jnp.einsum("bsd,dgk->bsgk", x, cast(p["wk"], dtype))
    v = jnp.einsum("bsd,dgk->bsgk", x, cast(p["wv"], dtype))
    if cfg.qkv_bias:
        q = q + cast(p["bq"], dtype)
        k = k + cast(p["bk"], dtype)
        v = v + cast(p["bv"], dtype)
    if not cfg.attention_free and cfg.rope_theta > 0:
        q = rope(q, positions, cfg.rope_theta)
        k = rope(k, positions, cfg.rope_theta)
    return q, k, v


def mha(q, k, v, causal=True, q_offset=0, kv_len=None, block_size=1024):
    """Blockwise (online-softmax) attention: O(S·block) memory, XLA-only.

    Grouped layout throughout (no KV-head expansion, no reshapes of
    potentially-sharded dims).  q: (B, Sq, G, R, hd); k, v: (B, Sk, G, hd).
    kv_len: optional scalar — positions >= kv_len are masked (decode cache).
    Returns (B, Sq, G, R, hd).
    """
    b, sq, g, r, hd = q.shape
    sk = k.shape[1]
    scale = 1.0 / math.sqrt(hd)
    qg = (q * scale).astype(q.dtype)

    if sk <= block_size or sk % block_size != 0:
        # direct path (small S / decode / non-divisible enc lengths)
        scores = jnp.einsum("bqgrk,bsgk->bgrqs", qg, k).astype(jnp.float32)
        if causal or kv_len is not None:
            mask = _attn_mask(sq, sk, causal, q_offset, kv_len)  # (1,1,sq,sk)
            bias = jnp.where(mask[0, 0], 0.0, -1e30)             # f32 (sq,sk)
            scores = scores + bias
        probs = jax.nn.softmax(scores, axis=-1).astype(q.dtype)
        return jnp.einsum("bgrqs,bsgk->bqgrk", probs, v)

    nb = sk // block_size
    kb = jnp.moveaxis(k.reshape(b, nb, block_size, g, hd), 1, 0)
    vb = jnp.moveaxis(v.reshape(b, nb, block_size, g, hd), 1, 0)

    def body(carry, inp):
        m, l, acc = carry
        kblk, vblk, bi = inp
        s = jnp.einsum("bqgrk,bsgk->bgrqs", qg, kblk).astype(jnp.float32)
        kpos = bi * block_size + jnp.arange(block_size)
        qpos = q_offset + jnp.arange(sq)
        # additive f32 bias of shape (sq, blk): tiny, fuses into the einsum
        # epilogue; a boolean `where` mask at score shape gets hoisted by XLA
        # into a (nb, B, G, R, sq, blk) pred tensor — GBs per layer.
        bias = jnp.zeros((sq, block_size), jnp.float32)
        if causal:
            bias = jnp.where(kpos[None, :] <= qpos[:, None], bias, -1e30)
        if kv_len is not None:
            bias = jnp.where(kpos[None, :] < kv_len, bias, -1e30)
        s = s + bias[None, None, None]
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[..., None])
        corr = jnp.exp(m - m_new)
        l_new = l * corr + jnp.sum(p, axis=-1)
        acc_new = acc * corr[..., None] + jnp.einsum(
            "bgrqs,bsgk->bgrqk", p.astype(q.dtype), vblk).astype(jnp.float32)
        return (m_new, l_new, acc_new), None

    m0 = jnp.full((b, g, r, sq), -jnp.inf, jnp.float32)
    l0 = jnp.zeros((b, g, r, sq), jnp.float32)
    a0 = jnp.zeros((b, g, r, sq, hd), jnp.float32)
    (m, l, acc), _ = lax.scan(body, (m0, l0, a0), (kb, vb, jnp.arange(nb)))
    out = acc / jnp.maximum(l, 1e-30)[..., None]       # (b,g,r,sq,hd)
    return jnp.moveaxis(out, 3, 1).astype(q.dtype)     # (b,sq,g,r,hd)


def _attn_mask(sq, sk, causal, q_offset, kv_len):
    qpos = q_offset + jnp.arange(sq)
    kpos = jnp.arange(sk)
    mask = jnp.ones((sq, sk), bool)
    if causal:
        mask = mask & (kpos[None, :] <= qpos[:, None])
    if kv_len is not None:
        mask = mask & (kpos[None, :] < kv_len)
    return mask[None, None]


def apply_attention(p, x, cfg, positions, causal=True, use_kernel=False):
    """Full-sequence (train / prefill) self-attention. Returns (out, (k, v))."""
    q, k, v = _qkv(p, x, cfg, positions)
    if use_kernel:
        from repro.kernels.flash_attention import ops as fa_ops
        out = fa_ops.flash_attention(q, k, v, causal=causal)
    else:
        out = mha(q, k, v, causal=causal,
                  block_size=getattr(cfg, "attn_block", 1024))
    y = jnp.einsum("bsgrk,grkd->bsd", out, cast(p["wo"], x.dtype))
    return y, (k, v)


def apply_attention_decode(p, x, cfg, k_cache, v_cache, cache_len):
    """One-token decode: x (B, 1, D); caches (B, Smax, G, hd)."""
    positions = jnp.full((x.shape[0], 1), cache_len, jnp.int32)
    q, k, v = _qkv(p, x, cfg, positions)
    k_cache = lax.dynamic_update_slice_in_dim(k_cache, k.astype(k_cache.dtype),
                                              cache_len, axis=1)
    v_cache = lax.dynamic_update_slice_in_dim(v_cache, v.astype(v_cache.dtype),
                                              cache_len, axis=1)
    out = mha(q, k_cache.astype(q.dtype), v_cache.astype(q.dtype),
              causal=False, kv_len=cache_len + 1,
              block_size=1 << 62)  # direct path; masking handles validity
    y = jnp.einsum("bsgrk,grkd->bsd", out, cast(p["wo"], x.dtype))
    return y, (k_cache, v_cache)


# cross-attention (enc-dec) -------------------------------------------------


def init_cross_attention(key, cfg):
    return init_attention(key, cfg)


def apply_cross_attention(p, x, cfg, enc_k, enc_v):
    dtype = x.dtype
    q = jnp.einsum("bsd,dgrk->bsgrk", x, cast(p["wq"], dtype))
    out = mha(q, enc_k, enc_v, causal=False)
    return jnp.einsum("bsgrk,grkd->bsd", out, cast(p["wo"], dtype))


def cross_kv(p, enc_out, cfg):
    dtype = enc_out.dtype
    k = jnp.einsum("bsd,dgk->bsgk", enc_out, cast(p["wk"], dtype))
    v = jnp.einsum("bsd,dgk->bsgk", enc_out, cast(p["wv"], dtype))
    return k, v


# ---------------------------------------------------------------------------
# MLP


def init_mlp(key, cfg, d_ff=None):
    d = cfg.d_model
    f = d_ff or cfg.d_ff
    ks = jax.random.split(key, 3)
    if cfg.act == "swiglu":
        return {"wi": _dense_init(ks[0], (d, f)), "wg": _dense_init(ks[1], (d, f)),
                "wo": _dense_init(ks[2], (f, d))}
    return {"wi": _dense_init(ks[0], (d, f)), "wo": _dense_init(ks[2], (f, d))}


def spec_mlp(cfg):
    if cfg.act == "swiglu":
        return {"wi": ("fsdp", "tp"), "wg": ("fsdp", "tp"), "wo": ("tp", "fsdp")}
    return {"wi": ("fsdp", "tp"), "wo": ("tp", "fsdp")}


def apply_mlp(p, x, cfg):
    dtype = x.dtype
    h = jnp.einsum("bsd,df->bsf", x, cast(p["wi"], dtype))
    if cfg.act == "swiglu":
        g = jnp.einsum("bsd,df->bsf", x, cast(p["wg"], dtype))
        h = jax.nn.silu(g) * h
    else:
        h = jax.nn.gelu(h)
    return jnp.einsum("bsf,fd->bsd", h, cast(p["wo"], dtype))


# ---------------------------------------------------------------------------
# embedding / unembedding


def init_embed(key, cfg):
    ks = jax.random.split(key, 2)
    p = {"tok": jax.random.normal(ks[0], (cfg.vocab_padded, cfg.d_model),
                                  jnp.float32) * 0.02}
    if not cfg.tie_embeddings:
        p["unembed"] = _dense_init(ks[1], (cfg.d_model, cfg.vocab_padded))
    return p


def spec_embed(cfg):
    p = {"tok": ("vocab", "fsdp")}
    if not cfg.tie_embeddings:
        p["unembed"] = ("fsdp", "vocab")
    return p


def apply_embed(p, tokens, cfg):
    emb = cast(p["tok"], jnp.dtype(cfg.dtype))
    return jnp.take(emb, tokens, axis=0)


def apply_unembed(p, x, cfg):
    """Logits over the padded vocab; the pad region is masked to -inf."""
    w = p["unembed"] if not cfg.tie_embeddings else p["tok"].T
    logits = jnp.einsum("bsd,dv->bsv", x, cast(w, x.dtype))
    if cfg.vocab_padded != cfg.vocab_size:
        pad_mask = jnp.arange(cfg.vocab_padded) >= cfg.vocab_size
        logits = jnp.where(pad_mask, jnp.asarray(-1e30, logits.dtype), logits)
    return logits
