"""Abstract (ShapeDtypeStruct) model inputs/state for AOT lowering.

This is the paper's "matrix A is never allocated" insight applied to the
TPU world: the dry-run and the simulator only ever see shape/dtype
descriptors — no weights, activations or caches are materialized.
"""
from __future__ import annotations

from typing import Any, Dict

import jax
import jax.numpy as jnp

from repro.configs import ModelConfig, ShapeConfig
from repro.models import build_model
from repro.train.optimizer import opt_init


def input_specs(cfg: ModelConfig, shape: ShapeConfig) -> Dict[str, Any]:
    """ShapeDtypeStruct stand-ins for every model input of this cell."""
    b, s = shape.global_batch, shape.seq_len
    f32 = jnp.float32
    if shape.kind == "decode":
        return {"tokens": jax.ShapeDtypeStruct((b, 1), jnp.int32)}
    out: Dict[str, Any] = {}
    if cfg.family == "vlm":
        n_img = cfg.n_image_tokens
        out["tokens"] = jax.ShapeDtypeStruct((b, s - n_img), jnp.int32)
        out["image_embeds"] = jax.ShapeDtypeStruct((b, n_img, cfg.d_model), f32)
    elif cfg.family == "encdec":
        out["tokens"] = jax.ShapeDtypeStruct((b, s), jnp.int32)
        out["encoder_embeds"] = jax.ShapeDtypeStruct(
            (b, cfg.encoder_seq, cfg.d_model), f32)
    else:
        out["tokens"] = jax.ShapeDtypeStruct((b, s), jnp.int32)
    return out


def input_logical_specs(cfg: ModelConfig, shape: ShapeConfig):
    if shape.kind == "decode":
        return {"tokens": ("dp", None)}
    out = {"tokens": ("dp", "sp")}
    if cfg.family == "vlm":
        out["image_embeds"] = ("dp", "sp", None)
    elif cfg.family == "encdec":
        out["encoder_embeds"] = ("dp", "sp", None)
    return out


def abstract_params(cfg: ModelConfig):
    model = build_model(cfg)
    return jax.eval_shape(model.init, jax.random.PRNGKey(0))


def abstract_state(cfg: ModelConfig):
    from repro.train.step import TrainState
    params = abstract_params(cfg)
    opt = jax.eval_shape(opt_init(cfg.optimizer), params)
    return TrainState(params=params, opt=opt,
                      step=jax.ShapeDtypeStruct((), jnp.int32))


def abstract_cache(cfg: ModelConfig, shape: ShapeConfig):
    model = build_model(cfg)
    return jax.eval_shape(
        lambda: model.init_cache(shape.global_batch, shape.seq_len,
                                 enc_len=cfg.encoder_seq or 0))


def make_batch(cfg: ModelConfig, shape: ShapeConfig, key=None, scale=0.02):
    """Concrete synthetic batch matching input_specs (smoke tests/examples)."""
    key = key if key is not None else jax.random.PRNGKey(0)
    specs = input_specs(cfg, shape)
    out = {}
    for name, sds in specs.items():
        key, sub = jax.random.split(key)
        if jnp.issubdtype(sds.dtype, jnp.integer):
            out[name] = jax.random.randint(sub, sds.shape, 0, cfg.vocab_size,
                                           dtype=sds.dtype)
        else:
            out[name] = jax.random.normal(sub, sds.shape, sds.dtype) * scale
    return out
