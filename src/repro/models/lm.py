"""Model assembly for every assigned architecture family.

One ``Model`` object per ``ModelConfig``; functional methods:
  init(key) -> params                       param_specs() -> logical specs
  forward(params, batch) -> (logits, aux)   loss(params, batch) -> (loss, metrics)
  init_cache(batch) -> cache                cache_specs() -> logical specs
  prefill(params, batch) -> (cache, logits) decode(params, cache, tok) -> (cache, logits)

Layer stacks are ``lax.scan`` over stacked params (compile time independent
of depth); remat policy from ``cfg.remat``.
"""
from __future__ import annotations

import math
from functools import partial
from typing import Any, Dict

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax

from repro.sharding.specs import constrain
from . import layers as L
from . import mamba2 as M
from . import moe as MOE

Params = Dict[str, Any]


def _stack_init(init_fn, key, n):
    """vmap an init over layer keys -> params stacked on axis 0."""
    return jax.vmap(init_fn)(jax.random.split(key, n))


def _stacked(spec):
    """Prepend a None (layer) dim to every leaf of a logical spec tree."""
    return jax.tree.map(lambda s: (None,) + tuple(s), spec,
                        is_leaf=lambda x: type(x) is tuple)


def _maybe_remat(fn, cfg):
    if cfg.remat == "none":
        return fn
    if cfg.remat == "dots":
        pol = jax.checkpoint_policies.checkpoint_dots
        return jax.checkpoint(fn, policy=pol)
    if cfg.remat == "dots_nb":
        # save projection dots, recompute attention-score dots (they carry
        # batch dims) — the flash-attention memory/compute tradeoff
        pol = jax.checkpoint_policies.checkpoint_dots_with_no_batch_dims
        return jax.checkpoint(fn, policy=pol)
    return jax.checkpoint(fn)


class Model:
    def __init__(self, cfg, use_kernel: bool = False):
        self.cfg = cfg
        self.use_kernel = use_kernel
        self.dtype = jnp.dtype(cfg.dtype)

    # ------------------------------------------------------------- params
    def init(self, key) -> Params:
        cfg = self.cfg
        keys = jax.random.split(key, 8)
        p: Params = {"embed": L.init_embed(keys[0], cfg),
                     "final_norm": L.init_norm(keys[1], cfg.d_model, cfg.norm)}
        if cfg.family in ("dense", "moe", "vlm"):
            p["layers"] = _stack_init(partial(self._init_layer, cfg=cfg),
                                      keys[2], cfg.num_layers)
        elif cfg.family == "ssm":
            p["layers"] = _stack_init(partial(self._init_ssm_layer, cfg=cfg),
                                      keys[2], cfg.num_layers)
        elif cfg.family == "hybrid":
            p["layers"] = _stack_init(partial(self._init_ssm_layer, cfg=cfg),
                                      keys[2], cfg.num_layers)
            p["shared"] = self._init_layer(keys[3], cfg=cfg)
        elif cfg.family == "encdec":
            p["enc_layers"] = _stack_init(
                partial(self._init_layer, cfg=cfg), keys[2],
                cfg.num_encoder_layers)
            p["enc_norm"] = L.init_norm(keys[4], cfg.d_model, cfg.norm)
            p["layers"] = _stack_init(
                partial(self._init_decdec_layer, cfg=cfg), keys[3],
                cfg.num_layers)
        else:
            raise ValueError(cfg.family)
        return p

    @staticmethod
    def _init_layer(key, cfg):
        ks = jax.random.split(key, 4)
        p = {"ln1": L.init_norm(ks[0], cfg.d_model, cfg.norm),
             "attn": L.init_attention(ks[1], cfg),
             "ln2": L.init_norm(ks[2], cfg.d_model, cfg.norm)}
        if cfg.moe is not None and cfg.family == "moe":
            p["moe"] = MOE.init_moe(ks[3], cfg)
        else:
            p["mlp"] = L.init_mlp(ks[3], cfg)
        return p

    @staticmethod
    def _init_ssm_layer(key, cfg):
        ks = jax.random.split(key, 2)
        return {"ln": L.init_norm(ks[0], cfg.d_model, cfg.norm),
                "ssm": M.init_ssm(ks[1], cfg)}

    @staticmethod
    def _init_decdec_layer(key, cfg):
        ks = jax.random.split(key, 6)
        return {"ln1": L.init_norm(ks[0], cfg.d_model, cfg.norm),
                "attn": L.init_attention(ks[1], cfg),
                "lnx": L.init_norm(ks[2], cfg.d_model, cfg.norm),
                "cross": L.init_cross_attention(ks[3], cfg),
                "ln2": L.init_norm(ks[4], cfg.d_model, cfg.norm),
                "mlp": L.init_mlp(ks[5], cfg)}

    def param_specs(self):
        cfg = self.cfg
        sp: Params = {"embed": L.spec_embed(cfg),
                      "final_norm": L.spec_norm(cfg.norm)}
        if cfg.family in ("dense", "moe", "vlm"):
            sp["layers"] = _stacked(self._spec_layer(cfg))
        elif cfg.family == "ssm":
            sp["layers"] = _stacked(self._spec_ssm_layer(cfg))
        elif cfg.family == "hybrid":
            sp["layers"] = _stacked(self._spec_ssm_layer(cfg))
            sp["shared"] = self._spec_layer(cfg)
        elif cfg.family == "encdec":
            sp["enc_layers"] = _stacked(self._spec_layer(cfg))
            sp["enc_norm"] = L.spec_norm(cfg.norm)
            sp["layers"] = _stacked(self._spec_decdec_layer(cfg))
        return sp

    @staticmethod
    def _spec_layer(cfg):
        p = {"ln1": L.spec_norm(cfg.norm), "attn": L.spec_attention(cfg),
             "ln2": L.spec_norm(cfg.norm)}
        if cfg.moe is not None and cfg.family == "moe":
            p["moe"] = MOE.spec_moe(cfg)
        else:
            p["mlp"] = L.spec_mlp(cfg)
        return p

    @staticmethod
    def _spec_ssm_layer(cfg):
        return {"ln": L.spec_norm(cfg.norm), "ssm": M.spec_ssm(cfg)}

    @staticmethod
    def _spec_decdec_layer(cfg):
        return {"ln1": L.spec_norm(cfg.norm), "attn": L.spec_attention(cfg),
                "lnx": L.spec_norm(cfg.norm),
                "cross": L.spec_attention(cfg),
                "ln2": L.spec_norm(cfg.norm), "mlp": L.spec_mlp(cfg)}

    # ------------------------------------------------------------ forward
    def _embed_inputs(self, params, batch):
        """Returns (x, positions, loss_mask, labels)."""
        cfg = self.cfg
        tokens = batch["tokens"]
        b = tokens.shape[0]
        x = L.apply_embed(params["embed"], tokens, cfg)
        if cfg.family == "vlm":
            img = batch["image_embeds"].astype(self.dtype)   # (B, Nimg, D)
            x = jnp.concatenate([img, x], axis=1)
            n_img, s = img.shape[1], x.shape[1]
            labels = jnp.concatenate(
                [jnp.zeros((b, n_img), tokens.dtype), tokens], axis=1)
            mask = (jnp.arange(s) >= n_img)[None, :].astype(jnp.float32)
            mask = mask * (jnp.arange(s) < s - 1)[None, :]
            labels = jnp.roll(labels, -1, axis=1)
        else:
            s = tokens.shape[1]
            labels = jnp.roll(tokens, -1, axis=1)
            mask = (jnp.arange(s) < s - 1)[None, :].astype(jnp.float32)
            mask = jnp.broadcast_to(mask, (b, s))
        if cfg.family == "encdec":
            pe = L.sinusoidal_positions(s, cfg.d_model).astype(self.dtype)
            x = x + pe[None]
        positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32)[None],
                                     (b, s))
        x = constrain(x, ("dp", "sp", None))
        return x, positions, mask, labels

    def _dense_layer_fwd(self, p_l, x, positions, *, shared_cfg=None):
        cfg = self.cfg
        h = L.apply_norm(p_l["ln1"], x, cfg.norm)
        a, _ = L.apply_attention(p_l["attn"], h, cfg, positions,
                                 use_kernel=self.use_kernel)
        x = x + a
        h = L.apply_norm(p_l["ln2"], x, cfg.norm)
        if "moe" in p_l:
            m, aux = MOE.apply_moe(p_l["moe"], h, cfg)
        else:
            m, aux = L.apply_mlp(p_l["mlp"], h, cfg), jnp.zeros((), jnp.float32)
        x = x + m
        x = constrain(x, ("dp", "sp", None))
        return x, aux

    def _ssm_layer_fwd(self, p_l, x):
        cfg = self.cfg
        h = L.apply_norm(p_l["ln"], x, cfg.norm)
        y = M.apply_ssm(p_l["ssm"], h, cfg, use_kernel=self.use_kernel)
        x = x + y
        x = constrain(x, ("dp", "sp", None))
        return x

    def _encoder(self, params, enc_embeds):
        cfg = self.cfg
        x = enc_embeds.astype(self.dtype)
        pe = L.sinusoidal_positions(x.shape[1], cfg.d_model).astype(self.dtype)
        x = x + pe[None]
        positions = jnp.broadcast_to(
            jnp.arange(x.shape[1], dtype=jnp.int32)[None], x.shape[:2])

        def layer(x, p_l):
            h = L.apply_norm(p_l["ln1"], x, cfg.norm)
            a, _ = L.apply_attention(p_l["attn"], h, cfg, positions,
                                     causal=False)
            x = x + a
            h = L.apply_norm(p_l["ln2"], x, cfg.norm)
            x = x + L.apply_mlp(p_l["mlp"], h, cfg)
            return constrain(x, ("dp", "sp", None)), None

        x, _ = lax.scan(_maybe_remat(layer, cfg), x, params["enc_layers"])
        return L.apply_norm(params["enc_norm"], x, cfg.norm)

    def forward(self, params, batch):
        """Training/teacher-forcing forward.  Returns (logits, aux_loss)."""
        cfg = self.cfg
        x, positions, mask, labels = self._embed_inputs(params, batch)

        if cfg.family in ("dense", "moe", "vlm"):
            def layer(x, p_l):
                x, aux = self._dense_layer_fwd(p_l, x, positions)
                return x, aux
            x, auxs = lax.scan(_maybe_remat(layer, cfg), x, params["layers"])
            aux = jnp.sum(auxs)
        elif cfg.family == "ssm":
            def layer(x, p_l):
                return self._ssm_layer_fwd(p_l, x), None
            x, _ = lax.scan(_maybe_remat(layer, cfg), x, params["layers"])
            aux = jnp.zeros((), jnp.float32)
        elif cfg.family == "hybrid":
            period = cfg.hybrid_period
            n_groups = cfg.num_layers // period
            grouped = jax.tree.map(
                lambda a: a.reshape((n_groups, period) + a.shape[1:]),
                params["layers"])

            def group(x, p_g):
                def inner(x, p_l):
                    return self._ssm_layer_fwd(p_l, x), None
                x, _ = lax.scan(inner, x, p_g)
                x, _ = self._dense_layer_fwd(params["shared"], x, positions)
                return x, None
            x, _ = lax.scan(_maybe_remat(group, cfg), x, grouped)
            aux = jnp.zeros((), jnp.float32)
        elif cfg.family == "encdec":
            enc_out = self._encoder(params, batch["encoder_embeds"])
            def layer(x, p_l):
                h = L.apply_norm(p_l["ln1"], x, cfg.norm)
                a, _ = L.apply_attention(p_l["attn"], h, cfg, positions)
                x = x + a
                h = L.apply_norm(p_l["lnx"], x, cfg.norm)
                ck, cv = L.cross_kv(p_l["cross"], enc_out, cfg)
                x = x + L.apply_cross_attention(p_l["cross"], h, cfg, ck, cv)
                h = L.apply_norm(p_l["ln2"], x, cfg.norm)
                x = x + L.apply_mlp(p_l["mlp"], h, cfg)
                return constrain(x, ("dp", "sp", None)), None
            x, _ = lax.scan(_maybe_remat(layer, cfg), x, params["layers"])
            aux = jnp.zeros((), jnp.float32)
        else:
            raise ValueError(cfg.family)

        x = L.apply_norm(params["final_norm"], x, cfg.norm)
        logits = L.apply_unembed(params["embed"], x, cfg)
        logits = constrain(logits, ("dp", "sp", "vocab"))
        return logits, (aux, mask, labels)

    def loss(self, params, batch):
        logits, (aux, mask, labels) = self.forward(params, batch)
        lf = logits.astype(jnp.float32)
        lse = jax.nn.logsumexp(lf, axis=-1)
        ll = jnp.take_along_axis(lf, labels[..., None], axis=-1)[..., 0]
        nll = (lse - ll) * mask
        denom = jnp.maximum(jnp.sum(mask), 1.0)
        ce = jnp.sum(nll) / denom
        total = ce + 0.01 * aux
        return total, {"ce": ce, "aux": aux, "tokens": denom}

    # -------------------------------------------------------------- cache
    def init_cache(self, batch_size: int, max_len: int, enc_len: int = 0):
        cfg = self.cfg
        g, hd = cfg.n_kv_heads, cfg.resolved_head_dim
        dt = self.dtype
        c: Params = {"len": jnp.zeros((), jnp.int32)}
        if cfg.family in ("dense", "moe", "vlm"):
            c["k"] = jnp.zeros((cfg.num_layers, batch_size, max_len, g, hd), dt)
            c["v"] = jnp.zeros_like(c["k"])
        elif cfg.family == "ssm":
            sc = M.init_ssm_cache(cfg, batch_size)
            c["ssm"] = jax.tree.map(
                lambda a: jnp.zeros((cfg.num_layers,) + a.shape, a.dtype), sc)
        elif cfg.family == "hybrid":
            sc = M.init_ssm_cache(cfg, batch_size)
            c["ssm"] = jax.tree.map(
                lambda a: jnp.zeros((cfg.num_layers,) + a.shape, a.dtype), sc)
            n_apps = cfg.num_layers // cfg.hybrid_period
            c["k"] = jnp.zeros((n_apps, batch_size, max_len, g, hd), dt)
            c["v"] = jnp.zeros_like(c["k"])
        elif cfg.family == "encdec":
            c["k"] = jnp.zeros((cfg.num_layers, batch_size, max_len, g, hd), dt)
            c["v"] = jnp.zeros_like(c["k"])
            c["ck"] = jnp.zeros((cfg.num_layers, batch_size,
                                 enc_len or cfg.encoder_seq, g, hd), dt)
            c["cv"] = jnp.zeros_like(c["ck"])
        return c

    def cache_specs(self):
        cfg = self.cfg
        kv = (None, "dp", "kv_seq", "tp_kv", None)
        c: Params = {"len": None}
        if cfg.family in ("dense", "moe", "vlm", "encdec"):
            c["k"] = kv
            c["v"] = kv
        if cfg.family == "encdec":
            c["ck"] = kv
            c["cv"] = kv
        if cfg.family in ("ssm", "hybrid"):
            sc = M.spec_ssm_cache(cfg)
            c["ssm"] = jax.tree.map(lambda s: (None,) + tuple(s), sc,
                                    is_leaf=lambda x: type(x) is tuple)
        if cfg.family == "hybrid":
            c["k"] = kv
            c["v"] = kv
        return c

    # ------------------------------------------------------------ prefill
    def prefill(self, params, batch, max_len: int):
        """Process the full prompt; returns (cache, last-token logits)."""
        cfg = self.cfg
        tokens = batch["tokens"]
        b = tokens.shape[0]
        x, positions, _, _ = self._embed_inputs(params, batch)
        s = x.shape[1]
        cache = self.init_cache(b, max_len,
                                enc_len=cfg.encoder_seq or 0)

        def pad_kv(k):  # (B,S,G,hd) -> (B,max_len,G,hd)
            pad = max_len - s
            return jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0))) if pad else k

        if cfg.family in ("dense", "moe", "vlm"):
            def layer(x, p_l):
                h = L.apply_norm(p_l["ln1"], x, cfg.norm)
                a, (k, v) = L.apply_attention(p_l["attn"], h, cfg, positions,
                                              use_kernel=self.use_kernel)
                x = x + a
                h = L.apply_norm(p_l["ln2"], x, cfg.norm)
                if "moe" in p_l:
                    m, _ = MOE.apply_moe(p_l["moe"], h, cfg)
                else:
                    m = L.apply_mlp(p_l["mlp"], h, cfg)
                x = constrain(x + m, ("dp", "sp", None))
                return x, (pad_kv(k.astype(self.dtype)),
                           pad_kv(v.astype(self.dtype)))
            x, (ks, vs) = lax.scan(_maybe_remat(layer, cfg), x,
                                   params["layers"])
            cache["k"], cache["v"] = ks, vs
        elif cfg.family == "ssm":
            def layer(x, p_l):
                h = L.apply_norm(p_l["ln"], x, cfg.norm)
                y, st = M.apply_ssm_prefill(p_l["ssm"], h, cfg)
                return constrain(x + y, ("dp", "sp", None)), st
            x, sts = lax.scan(_maybe_remat(layer, cfg), x, params["layers"])
            cache["ssm"] = sts
        elif cfg.family == "hybrid":
            period = cfg.hybrid_period
            n_groups = cfg.num_layers // period
            grouped = jax.tree.map(
                lambda a: a.reshape((n_groups, period) + a.shape[1:]),
                params["layers"])

            def group(x, p_g):
                def inner(x, p_l):
                    h = L.apply_norm(p_l["ln"], x, cfg.norm)
                    y, st = M.apply_ssm_prefill(p_l["ssm"], h, cfg)
                    return constrain(x + y, ("dp", "sp", None)), st
                x, sts = lax.scan(inner, x, p_g)
                sh = params["shared"]
                h = L.apply_norm(sh["ln1"], x, cfg.norm)
                a, (k, v) = L.apply_attention(sh["attn"], h, cfg, positions)
                x = x + a
                h = L.apply_norm(sh["ln2"], x, cfg.norm)
                x = constrain(x + L.apply_mlp(sh["mlp"], h, cfg),
                              ("dp", "sp", None))
                return x, (sts, pad_kv(k.astype(self.dtype)),
                           pad_kv(v.astype(self.dtype)))
            x, (sts, ks, vs) = lax.scan(_maybe_remat(group, cfg), x, grouped)
            cache["ssm"] = jax.tree.map(
                lambda a: a.reshape((cfg.num_layers,) + a.shape[2:]), sts)
            cache["k"], cache["v"] = ks, vs
        elif cfg.family == "encdec":
            enc_out = self._encoder(params, batch["encoder_embeds"])
            def layer(x, p_l):
                h = L.apply_norm(p_l["ln1"], x, cfg.norm)
                a, (k, v) = L.apply_attention(p_l["attn"], h, cfg, positions)
                x = x + a
                h = L.apply_norm(p_l["lnx"], x, cfg.norm)
                ck, cv = L.cross_kv(p_l["cross"], enc_out, cfg)
                x = x + L.apply_cross_attention(p_l["cross"], h, cfg, ck, cv)
                h = L.apply_norm(p_l["ln2"], x, cfg.norm)
                x = constrain(x + L.apply_mlp(p_l["mlp"], h, cfg),
                              ("dp", "sp", None))
                return x, (pad_kv(k.astype(self.dtype)),
                           pad_kv(v.astype(self.dtype)),
                           ck.astype(self.dtype), cv.astype(self.dtype))
            x, (ks, vs, cks, cvs) = lax.scan(_maybe_remat(layer, cfg), x,
                                             params["layers"])
            cache["k"], cache["v"] = ks, vs
            cache["ck"], cache["cv"] = cks, cvs

        cache["len"] = jnp.asarray(s, jnp.int32)
        x = L.apply_norm(params["final_norm"], x, cfg.norm)
        last = x[:, -1:, :]
        logits = L.apply_unembed(params["embed"], last, cfg)
        return cache, logits[:, 0, :]

    # ------------------------------------------------------------- decode
    def decode(self, params, cache, tokens):
        """One decode step. tokens: (B, 1) -> (new_cache, logits (B, V))."""
        cfg = self.cfg
        pos = cache["len"]
        x = L.apply_embed(params["embed"], tokens, cfg)
        if cfg.family == "encdec":
            pe = L.sinusoidal_positions(8192, cfg.d_model).astype(self.dtype)
            x = x + lax.dynamic_slice_in_dim(pe, pos, 1, axis=0)[None]
        x = constrain(x, ("dp", None, None))

        if cfg.family in ("dense", "moe", "vlm"):
            def layer(x, inp):
                p_l, kc, vc = inp
                h = L.apply_norm(p_l["ln1"], x, cfg.norm)
                a, (kc, vc) = L.apply_attention_decode(p_l["attn"], h, cfg,
                                                       kc, vc, pos)
                x = x + a
                h = L.apply_norm(p_l["ln2"], x, cfg.norm)
                if "moe" in p_l:
                    m, _ = MOE.apply_moe(p_l["moe"], h, cfg)
                else:
                    m = L.apply_mlp(p_l["mlp"], h, cfg)
                return x + m, (kc, vc)
            x, (ks, vs) = lax.scan(layer, x,
                                   (params["layers"], cache["k"], cache["v"]))
            cache = dict(cache, k=ks, v=vs)
        elif cfg.family == "ssm":
            def layer(x, inp):
                p_l, sc = inp
                h = L.apply_norm(p_l["ln"], x, cfg.norm)
                y, sc = M.apply_ssm_decode(p_l["ssm"], h, cfg, sc)
                return x + y, sc
            x, sts = lax.scan(layer, x, (params["layers"], cache["ssm"]))
            cache = dict(cache, ssm=sts)
        elif cfg.family == "hybrid":
            period = cfg.hybrid_period
            n_groups = cfg.num_layers // period
            grouped = jax.tree.map(
                lambda a: a.reshape((n_groups, period) + a.shape[1:]),
                params["layers"])
            g_ssm = jax.tree.map(
                lambda a: a.reshape((n_groups, period) + a.shape[1:]),
                cache["ssm"])

            def group(x, inp):
                p_g, sc_g, kc, vc = inp
                def inner(x, inp2):
                    p_l, sc = inp2
                    h = L.apply_norm(p_l["ln"], x, cfg.norm)
                    y, sc = M.apply_ssm_decode(p_l["ssm"], h, cfg, sc)
                    return x + y, sc
                x, sc_g = lax.scan(inner, x, (p_g, sc_g))
                sh = params["shared"]
                h = L.apply_norm(sh["ln1"], x, cfg.norm)
                a, (kc, vc) = L.apply_attention_decode(sh["attn"], h, cfg,
                                                       kc, vc, pos)
                x = x + a
                h = L.apply_norm(sh["ln2"], x, cfg.norm)
                x = x + L.apply_mlp(sh["mlp"], h, cfg)
                return x, (sc_g, kc, vc)
            x, (sts, ks, vs) = lax.scan(group, x,
                                        (grouped, g_ssm, cache["k"],
                                         cache["v"]))
            cache = dict(cache,
                         ssm=jax.tree.map(
                             lambda a: a.reshape((cfg.num_layers,)
                                                 + a.shape[2:]), sts),
                         k=ks, v=vs)
        elif cfg.family == "encdec":
            def layer(x, inp):
                p_l, kc, vc, ck, cv = inp
                h = L.apply_norm(p_l["ln1"], x, cfg.norm)
                a, (kc, vc) = L.apply_attention_decode(p_l["attn"], h, cfg,
                                                       kc, vc, pos)
                x = x + a
                h = L.apply_norm(p_l["lnx"], x, cfg.norm)
                x = x + L.apply_cross_attention(
                    p_l["cross"], h, cfg, ck.astype(x.dtype),
                    cv.astype(x.dtype))
                h = L.apply_norm(p_l["ln2"], x, cfg.norm)
                x = x + L.apply_mlp(p_l["mlp"], h, cfg)
                return x, (kc, vc)
            x, (ks, vs) = lax.scan(layer, x,
                                   (params["layers"], cache["k"], cache["v"],
                                    cache["ck"], cache["cv"]))
            cache = dict(cache, k=ks, v=vs)

        cache["len"] = pos + 1
        x = L.apply_norm(params["final_norm"], x, cfg.norm)
        logits = L.apply_unembed(params["embed"], x, cfg)
        return cache, logits[:, 0, :]


def build_model(cfg, use_kernel: bool = False) -> Model:
    return Model(cfg, use_kernel=use_kernel)
