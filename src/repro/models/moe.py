"""Mixture-of-Experts layer (GShard-style einsum dispatch, EP over `tp`).

Baseline path: capacity-bounded one-hot dispatch/combine einsums — fully
pjit-shardable (experts over the `tp` axis, token groups over `dp`).  The
beyond-paper optimized path (sorted grouped-GEMM dispatch) lives in
``moe_grouped.py`` and is selected by ``dispatch="grouped"``.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from .layers import _dense_init, cast


def init_moe(key, cfg):
    e = cfg.moe
    d, f, E = cfg.d_model, e.d_ff_expert, e.num_experts
    ks = jax.random.split(key, 5)
    p = {"router": _dense_init(ks[0], (d, E))}
    if cfg.act == "swiglu":
        p["wi"] = _dense_init(ks[1], (E, d, f))
        p["wg"] = _dense_init(ks[2], (E, d, f))
        p["wo"] = _dense_init(ks[3], (E, f, d))
    else:
        p["wi"] = _dense_init(ks[1], (E, d, f))
        p["wo"] = _dense_init(ks[3], (E, f, d))
    if e.n_shared_experts:
        fs = e.n_shared_experts * f
        p["shared"] = {"wi": _dense_init(ks[4], (d, fs)),
                       "wg": _dense_init(ks[4], (d, fs)),
                       "wo": _dense_init(ks[4], (fs, d))}
    return p


def spec_moe(cfg):
    e = cfg.moe
    p = {"router": (None, None)}
    if cfg.act == "swiglu":
        p["wi"] = ("ep", "fsdp", None)
        p["wg"] = ("ep", "fsdp", None)
        p["wo"] = ("ep", None, "fsdp")
    else:
        p["wi"] = ("ep", "fsdp", None)
        p["wo"] = ("ep", None, "fsdp")
    if e.n_shared_experts:
        p["shared"] = {"wi": ("fsdp", "tp"), "wg": ("fsdp", "tp"),
                       "wo": ("tp", "fsdp")}
    return p


def capacity(cfg, tokens_per_group: int) -> int:
    e = cfg.moe
    c = int(math.ceil(tokens_per_group * e.top_k * e.capacity_factor
                      / e.num_experts))
    return max(c, 1)


def _topk_dispatch(gates, top_k, cap):
    """gates: (G, S, E) f32.  Returns dispatch (G,S,E,C) bool-ish bf16 and
    combine (G,S,E,C) f32 plus aux losses."""
    g, s, e = gates.shape
    probs = jax.nn.softmax(gates, axis=-1)
    # iterative top-k with capacity accounting (GShard style)
    remaining = probs
    dispatch = jnp.zeros((g, s, e, cap), jnp.bool_)
    combine = jnp.zeros((g, s, e, cap), jnp.float32)
    # position counters via cumulative sum of selections, built per k
    sel_so_far = jnp.zeros((g, s, e), jnp.int32)  # 1 if token->expert chosen
    for _ in range(top_k):
        idx = jnp.argmax(remaining, axis=-1)                     # (G,S)
        onehot = jax.nn.one_hot(idx, e, dtype=jnp.int32)          # (G,S,E)
        # position of each token within its expert queue: all slots consumed
        # by earlier k-iterations (over *all* tokens) come first, then tokens
        # before s within this iteration.
        count_prev = jnp.sum(sel_so_far, axis=1, keepdims=True)  # (G,1,E)
        pos = jnp.cumsum(onehot, axis=1) - 1 + count_prev         # (G,S,E)
        pos = jnp.sum(pos * onehot, axis=-1)                      # (G,S)
        keep = pos < cap
        w = jnp.sum(probs * onehot, axis=-1) * keep               # (G,S)
        poh = jax.nn.one_hot(pos, cap, dtype=jnp.float32) * keep[..., None]
        d_k = (onehot[..., None].astype(jnp.float32)
               * poh[:, :, None, :])                              # (G,S,E,C)
        dispatch = jnp.logical_or(dispatch, d_k > 0)
        combine = combine + d_k * w[..., None, None]
        sel_so_far = sel_so_far + onehot
        remaining = remaining * (1.0 - onehot.astype(remaining.dtype))
    # load-balancing aux loss (Switch-style)
    me = jnp.mean(probs, axis=1)                                  # (G,E)
    ce = jnp.mean(sel_so_far.astype(jnp.float32) / max(1, top_k), axis=1)
    aux = jnp.mean(jnp.sum(me * ce, axis=-1)) * e
    return dispatch, combine, aux


def apply_moe(p, x, cfg):
    """x: (B, S, D) -> (B, S, D). Groups = batch dim."""
    if getattr(cfg, "moe_impl", "einsum") == "scatter":
        return apply_moe_scatter(p, x, cfg)
    e = cfg.moe
    b, s, d = x.shape
    dtype = x.dtype
    cap = capacity(cfg, s)
    gates = jnp.einsum("gsd,de->gse", x, cast(p["router"], dtype)
                       ).astype(jnp.float32)
    dispatch, combine, aux = _topk_dispatch(gates, e.top_k, cap)
    disp = dispatch.astype(dtype)
    xe = jnp.einsum("gsec,gsd->gecd", disp, x)                   # (G,E,C,D)
    h = jnp.einsum("gecd,edf->gecf", xe, cast(p["wi"], dtype))
    if cfg.act == "swiglu":
        gg = jnp.einsum("gecd,edf->gecf", xe, cast(p["wg"], dtype))
        h = jax.nn.silu(gg) * h
    else:
        h = jax.nn.gelu(h)
    ye = jnp.einsum("gecf,efd->gecd", h, cast(p["wo"], dtype))
    y = jnp.einsum("gsec,gecd->gsd", combine.astype(dtype), ye)
    if e.n_shared_experts:
        sp = p["shared"]
        hs = jnp.einsum("bsd,df->bsf", x, cast(sp["wi"], dtype))
        gs = jnp.einsum("bsd,df->bsf", x, cast(sp["wg"], dtype))
        y = y + jnp.einsum("bsf,fd->bsd", jax.nn.silu(gs) * hs,
                           cast(sp["wo"], dtype))
    return y, aux


def apply_moe_scatter(p, x, cfg):
    """Sorted grouped-GEMM dispatch (beyond-paper perf path, §Perf).

    The einsum path pays 2·S·(E_loc·C)·D dispatch+combine dot flops per
    group per layer — ~64% of qwen3-moe's total HLO flops.  Here routing
    is argsort + gather/scatter (O(S·k·D) data movement, no dot flops);
    expert GEMMs are unchanged.  Token order within an expert differs from
    the einsum path (sort order vs. GShard k-round priority), so capacity
    drops may differ at the margin — both are valid MoE semantics.
    """
    e = cfg.moe
    b, s, d = x.shape
    dtype = x.dtype
    k = e.top_k
    cap = capacity(cfg, s)
    E = e.num_experts
    gates = jnp.einsum("gsd,de->gse", x, cast(p["router"], dtype)
                       ).astype(jnp.float32)
    probs = jax.nn.softmax(gates, axis=-1)                  # (B,S,E)
    w, idx = jax.lax.top_k(probs, k)                        # (B,S,k)
    sk = s * k
    eid = idx.reshape(b, sk)                                # expert per slot
    wgt = w.reshape(b, sk)
    tok = jnp.broadcast_to((jnp.arange(sk) // k)[None], (b, sk))  # token ix

    order = jnp.argsort(eid, axis=1, stable=True)           # (B,S*k)
    eid_s = jnp.take_along_axis(eid, order, axis=1)
    tok_s = jnp.take_along_axis(tok, order, axis=1)
    # position within expert: arange - start offset of the expert
    counts = jnp.sum(jax.nn.one_hot(eid, E, dtype=jnp.int32), axis=1)
    starts = jnp.cumsum(counts, axis=1) - counts             # (B,E) exclusive
    pos = jnp.arange(sk)[None] - jnp.take_along_axis(starts, eid_s, axis=1)
    keep = pos < cap
    dst = jnp.where(keep, eid_s * cap + pos, E * cap)        # overflow slot

    from repro.sharding.specs import constrain
    # row-wise gather/scatter via vmap: indices stay (slots,) per batch —
    # take_along_axis would broadcast u32 indices to (B, slots, D) (45 TB
    # of index traffic per layer at qwen3 scale; see EXPERIMENTS.md §Perf)
    x_s = jax.vmap(lambda xb, ib: jnp.take(xb, ib, axis=0))(x, tok_s)
    x_s = constrain(x_s, ("dp", None, None))
    buf = jnp.zeros((b, E * cap + 1, d), dtype)
    buf = jax.vmap(lambda bb, db, vb: bb.at[db].set(vb))(buf, dst, x_s)
    buf = constrain(buf, ("dp", None, None))  # scatter stays batch-sharded
    xe = buf[:, :E * cap].reshape(b, E, cap, d)              # (B,E,C,D)
    xe = constrain(xe, ("dp", "ep", None, None))

    h = jnp.einsum("gecd,edf->gecf", xe, cast(p["wi"], dtype))
    if cfg.act == "swiglu":
        gg = jnp.einsum("gecd,edf->gecf", xe, cast(p["wg"], dtype))
        h = jax.nn.silu(gg) * h
    else:
        h = jax.nn.gelu(h)
    ye = jnp.einsum("gecf,efd->gecd", h, cast(p["wo"], dtype))
    ye = constrain(ye, ("dp", "ep", None, None))
    ye_flat = jnp.concatenate(
        [ye.reshape(b, E * cap, d),
         jnp.zeros((b, 1, d), ye.dtype)], axis=1)            # overflow = 0
    ye_flat = constrain(ye_flat, ("dp", None, None))
    out_s = jax.vmap(lambda yb, ib: jnp.take(yb, ib, axis=0))(ye_flat, dst)
    w_s = jnp.take_along_axis(wgt, order, axis=1) * keep
    out_s = out_s * w_s[..., None].astype(dtype)
    # un-sort and reduce the k slots per token
    y_slots = jnp.zeros((b, sk, d), dtype)
    y_slots = jax.vmap(lambda yb, ob, vb: yb.at[ob].set(vb))(
        y_slots, order, out_s)
    y = jnp.sum(y_slots.reshape(b, s, k, d), axis=2)
    y = constrain(y, ("dp", "sp", None))

    me = jnp.mean(probs, axis=1)
    ce = jnp.mean(jnp.sum(jax.nn.one_hot(idx, E, dtype=jnp.float32),
                          axis=2), axis=1) / k
    aux = jnp.mean(jnp.sum(me * ce, axis=-1)) * E
    if e.n_shared_experts:
        sp = p["shared"]
        hs = jnp.einsum("bsd,df->bsf", x, cast(sp["wi"], dtype))
        gs = jnp.einsum("bsd,df->bsf", x, cast(sp["wg"], dtype))
        y = y + jnp.einsum("bsf,fd->bsd", jax.nn.silu(gs) * hs,
                           cast(sp["wo"], dtype))
    return y, aux
