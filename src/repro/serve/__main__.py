"""``python -m repro.serve`` — serving-side operational commands.

``warm`` precompiles the sweep buckets a (workload, platform) traffic
mix will need, so the first real wave served by a fresh process pays
zero compiles::

    python -m repro.serve warm --workloads hpl,transformer \\
        --platforms frontera,pupmaya --count 32 --json

``--count`` replicates each (workload, platform) cell so the warm
dispatch is padded to the same power-of-two lane count the real waves
will use (the jit cache is keyed on the padded batch shape — warm with
the wave size you expect to serve).
"""
from __future__ import annotations

import argparse
import json
import sys


def _csv(text: str):
    return [t for t in (s.strip() for s in text.split(",")) if t]


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(prog="python -m repro.serve",
                                 description=__doc__)
    sub = ap.add_subparsers(dest="cmd", required=True)
    w = sub.add_parser("warm", help="precompile sweep buckets for a "
                                    "(workload, platform) grid")
    w.add_argument("--workloads", default="hpl",
                   help="comma-separated workload kind names (default hpl)")
    w.add_argument("--platforms", required=True,
                   help="comma-separated registered platform names")
    w.add_argument("--count", type=int, default=1,
                   help="scenarios per (workload, platform) cell — match "
                        "the wave size you expect to serve")
    w.add_argument("--shard", action="store_true",
                   help="warm the device-sharded dispatch path")
    w.add_argument("--json", action="store_true", dest="as_json",
                   help="emit the warm report as one JSON line")
    args = ap.parse_args(argv)

    if args.cmd == "warm":
        from repro.serve import warm
        report = warm(_csv(args.workloads), _csv(args.platforms),
                      count=args.count, shard=args.shard)
        if args.as_json:
            print(json.dumps(report, sort_keys=True))
        else:
            print(f"warmed {report['scenarios']} scenarios in "
                  f"{report['dispatches']} dispatches "
                  f"({report['compiles']} compiles)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
