from .cache import ResultCache, as_result_cache, request_key
from .engine import ServeEngine, Request
from .predict import (HPLPredictionService, PredictRequest,
                      PredictionService, WorkloadRequest, predict_top500,
                      warm)

__all__ = ["ServeEngine", "Request", "HPLPredictionService",
           "PredictRequest", "PredictionService", "WorkloadRequest",
           "ResultCache", "as_result_cache", "request_key",
           "predict_top500", "warm"]
