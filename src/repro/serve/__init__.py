from .engine import ServeEngine, Request
from .predict import (HPLPredictionService, PredictRequest,
                      predict_top500)

__all__ = ["ServeEngine", "Request", "HPLPredictionService",
           "PredictRequest", "predict_top500"]
