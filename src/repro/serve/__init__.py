from .engine import ServeEngine, Request
from .predict import (HPLPredictionService, PredictRequest,
                      PredictionService, WorkloadRequest, predict_top500)

__all__ = ["ServeEngine", "Request", "HPLPredictionService",
           "PredictRequest", "PredictionService", "WorkloadRequest",
           "predict_top500"]
