from .engine import ServeEngine, Request
from .predict import HPLPredictionService, PredictRequest

__all__ = ["ServeEngine", "Request", "HPLPredictionService",
           "PredictRequest"]
