"""Batch prediction services: simulation-as-a-service endpoints.

``PredictionService`` is the workload-generic front end: requests name a
``(workload, platform)`` pair (registry names, specs, or instances) and
``flush`` drains the queue in micro-batches, one batched sweep per
workload family per wave (``FastModel.sweep_models``) — HPL requests
share ``sweep_hpl`` programs, transformer requests share ``sweep_step``
programs, and a mixed burst costs one dispatch per family.

``HPLPredictionService`` is the original HPL-specialized endpoint, kept
as the back-compat surface for cfg/params-level requests (an
``HPLConfig`` plus a ``FastSimParams`` what-if).  A burst of thousands
of requests costs a handful of compiles (shape-bucket LRU cache) and one
vmapped dispatch per (bucket, wave) — the serving answer to the paper's
4.8-hour-per-scenario SystemC baseline.

Requests can name a registered platform instead of carrying explicit
params: ``PredictRequest(rid=1, platform="frontera")`` serves that
machine's published HPL run from its spec (DES-calibrated fastsim
params included), so the endpoint can predict any registry machine by
name.

Both services accept ``breakdown=True``: a traced DES of the same
scenario runs and ``result["breakdown"]`` carries per-phase times,
compute/comm/idle fractions and the critical path (see ``repro.trace``).
The DES costs real wall time per rank, so breakdown requests are capped
at ``max_des_ranks`` (reject, don't stall, the batch endpoint) — 1024
since the engine hot-loop rewrite.  ``WorkloadRequest.regions`` runs the
breakdown DES as a representative-region simulation (``repro.scale``):
only one region of the iteration space is simulated exactly, so the
guard rises to ``max_region_ranks`` and the result is stamped
``region_approx=True``.

Production hardening (all opt-in, so the strict all-or-nothing contract
above is the default):

  * ``WorkloadRequest.timeout_s`` sets a per-request wall-clock budget.
    The deadline is stamped at submit time and propagated into the
    breakdown DES (``Engine.set_wall_deadline``); a request whose DES
    would blow the budget — or whose scenario exceeds the rank guard —
    degrades gracefully to its fastsim-only answer, stamped with
    ``fallback_reason`` and ``degraded=True`` instead of timing out (or
    rejecting) the wave.
  * transient backend errors (``RuntimeError``/``OSError`` from a sweep
    dispatch) are retried with exponential backoff (``retries``,
    ``backoff_s``); scenario errors (``ValueError``/``KeyError``) never
    are.
  * ``predict_batch(reqs, isolate_errors=True)`` captures per-request
    resolution errors into ``{"status": "error", ...}`` response
    entries instead of rejecting the wave; failed requests are never
    enqueued, so an empty or all-failed wave leaves the queue clean.
  * ``WorkloadRequest.faults`` runs the scenario on a degraded platform
    (``repro.faults``): folded into the fast model's params and, for
    breakdown requests, injected into the DES.

Production throughput (all opt-in; DESIGN.md §20):

  * ``PredictionService(cache=True)`` attaches a content-addressed
    result cache (``repro.serve.cache``): repeat scenarios are served
    from the cache (stamped ``cached=True``) and duplicate in-flight
    keys within a wave coalesce onto one dispatched leader.  Budgeted
    (``timeout_s``) requests and error/degraded results are never
    cached.
  * ``PredictionService(shard=True)`` splits each family sweep's padded
    lane axis across local devices; with one device (or an indivisible
    batch) it falls back to the exact unsharded code path.
  * ``svc.warm(workloads, platforms, count=...)`` (or ``python -m
    repro.serve warm``) precompiles the sweep buckets a traffic mix
    will need, so the first real wave pays zero compiles — verified by
    the §18 compile hit/miss counters.

Observability (``repro.obs``, DESIGN.md §18): both services carry a
``MetricsRegistry`` (``svc.metrics``; pass ``metrics=NULL_METRICS`` to
switch it off, or share one registry across services/replicas — they
merge).  Counters back every hardening path (retries, deadline
fallbacks, degraded answers, isolated errors, rank-guard trips,
dispatch failures), per-request latency and wave size are recorded as
histograms (distributions, not point numbers), and the queue depth is a
gauge with a tracked peak.  ``svc.metrics.to_prometheus()`` is the
scrape surface; ``svc.manifest()`` emits one NDJSON run-manifest line.
Breakdown DES runs report engine telemetry into the same registry.

Dispatch is all-or-nothing per wave: every family's sweep runs before
any result is attached, and a dispatch that fails (after retries)
stamps every request in the wave with a ``{"status": "error", ...}``
result, re-raises, and leaves the queue holding only the requests
behind the wave — the service stays reusable and the queue clean (the
PR 4 resolve-all-before-enqueue guarantee, extended to dispatch time).
"""
from __future__ import annotations

import dataclasses
import time
import weakref
from typing import Any, Dict, List, Mapping, Optional, Sequence

from repro.core.apps.hpl import HPLConfig
from repro.core.engine import SimWallDeadline
from repro.core.fastsim import (FastSimParams, lane_sharding, sweep_hpl,
                                trace_count)
from repro.obs import COUNT_BUCKETS, MetricsRegistry, manifest_line
from repro.serve.cache import as_result_cache, copy_payload, request_key


@dataclasses.dataclass
class PredictRequest:
    rid: int
    cfg: Optional[HPLConfig] = None
    params: Optional[FastSimParams] = None
    platform: Optional[str] = None       # registry name; fills cfg/params
    breakdown: bool = False              # attach a DES phase breakdown
    result: Optional[dict] = None
    _t_submit: Optional[float] = dataclasses.field(default=None, repr=False)


@dataclasses.dataclass
class WorkloadRequest:
    """One (workload, platform) prediction request.  ``workload`` is a
    registry kind name, a ``WorkloadSpec``, or a ``Workload`` instance;
    ``platform`` a registry name or ``Platform`` spec; ``params`` are
    workload-spec overrides applied at resolution time."""
    rid: int
    workload: Any = "hpl"
    platform: Any = None
    params: Dict[str, Any] = dataclasses.field(default_factory=dict)
    breakdown: bool = False              # attach a DES phase breakdown
    faults: Any = None                   # FaultSpec / dict / JSON scenario
    regions: Any = None                  # int / RegionSpec: breakdown DES
    #        runs as a representative region (repro.scale), guarded by
    #        max_region_ranks instead of max_des_ranks and stamped
    #        region_approx=True
    timeout_s: Optional[float] = None    # wall budget; enables fallback
    result: Optional[dict] = None
    _bound: Any = dataclasses.field(default=None, repr=False)
    #        ^ (workload, platform, fastmodel), set by _resolve
    _ckey: Optional[str] = dataclasses.field(default=None, repr=False)
    #        ^ content-addressed cache key, set at flush time (None when
    #        the cache is off or the request is uncacheable)
    _deadline: Optional[float] = dataclasses.field(default=None, repr=False)
    _fallback: Optional[str] = dataclasses.field(default=None, repr=False)
    _t_submit: Optional[float] = dataclasses.field(default=None, repr=False)


#: live services, for registry-driven resolution-memo invalidation
_LIVE_SERVICES: "weakref.WeakSet" = weakref.WeakSet()
_RESOLUTION_HOOK_INSTALLED = False


def _install_resolution_hook() -> None:
    """Idempotently subscribe to platform re-registration so every live
    service forgets memoized resolutions of the re-registered name."""
    global _RESOLUTION_HOOK_INSTALLED
    if _RESOLUTION_HOOK_INSTALLED:
        return
    from repro.platforms.registry import add_invalidation_hook

    def _on_rebound(name: str) -> None:
        for svc in list(_LIVE_SERVICES):
            svc._drop_resolution_memo(name)

    add_invalidation_hook(_on_rebound)
    _RESOLUTION_HOOK_INSTALLED = True


class PredictionService:
    """Workload-generic micro-batching front end: routes ``(workload,
    platform)`` requests through the workload registry and drains the
    queue one batched sweep per workload family per wave."""

    #: exception types a sweep dispatch may raise transiently (backend
    #: hiccups); scenario errors (ValueError/KeyError) are never retried
    TRANSIENT = (RuntimeError, OSError)

    def __init__(self, max_batch: int = 256, max_des_ranks: int = 1024,
                 max_region_ranks: int = 16384,
                 retries: int = 2, backoff_s: float = 0.05,
                 metrics: Any = None, cache: Any = None,
                 shard: bool = False):
        self.max_batch = max_batch
        self.max_des_ranks = max_des_ranks
        self.max_region_ranks = max_region_ranks
        self.retries = retries
        self.backoff_s = backoff_s
        self._queue: List[WorkloadRequest] = []
        self.stats = {"requests": 0, "batches": 0, "scenarios": 0,
                      "sweeps": 0, "des_breakdowns": 0, "retries": 0,
                      "fallbacks": 0, "errors": 0, "cache_hits": 0,
                      "cache_misses": 0, "coalesced": 0}
        #: on by default (a fresh registry); pass NULL_METRICS to opt
        #: out or a shared registry to aggregate across services
        self.metrics = MetricsRegistry() if metrics is None else metrics
        #: off by default — the strict recompute-everything contract of
        #: PRs 4-8 is the default.  True / an int / a ResultCache turn
        #: on content-addressed result caching + request coalescing
        #: (share one ResultCache across services to share results).
        self.cache = as_result_cache(cache)
        #: off by default — True shards each family sweep's padded lane
        #: axis across local devices (single-device fallback is bitwise-
        #: identical to the unsharded path)
        self.shard = bool(shard)
        #: (workload, params, platform, faults) -> (wl, plat, model);
        #: name-level resolutions are pure, so repeat traffic skips the
        #: spec/model rebuild (the dominant per-request Python cost).
        #: Entries derived from a registry name are dropped when that
        #: name is re-registered (see _install_resolution_hook).
        self._resolve_memo: Dict[tuple, tuple] = {}
        _LIVE_SERVICES.add(self)
        _install_resolution_hook()

    def _drop_resolution_memo(self, name: str) -> None:
        """Registry rebinding event: forget memoized resolutions of
        platform ``name`` so the next request re-reads the registry."""
        self._resolve_memo = {k: v for k, v in self._resolve_memo.items()
                              if k[2] != name}

    @staticmethod
    def _memo_key(req: WorkloadRequest) -> Optional[tuple]:
        """Hashable identity of a name-level resolution, or None when
        the request carries instances/unhashables (resolved fresh)."""
        if not (isinstance(req.workload, str)
                and isinstance(req.platform, str)):
            return None
        try:
            key = (req.workload, tuple(sorted(req.params.items())),
                   req.platform, req.faults)
            hash(key)            # tuples build fine around list params;
            return key           # only hashing surfaces the TypeError
        except TypeError:        # unhashable param value / fault dict
            return None

    def _bind(self, req: WorkloadRequest) -> tuple:
        """Build (workload, platform, fastmodel) for one request."""
        from repro.workloads import (Workload, WorkloadSpec, get_workload,
                                     workload_from_spec)
        wl = req.workload
        if isinstance(wl, str):
            wl = get_workload(wl, **req.params)
        elif isinstance(wl, WorkloadSpec):
            wl = workload_from_spec(
                wl.replace(**req.params) if req.params else wl)
        elif isinstance(wl, Workload):
            if req.params:
                wl = workload_from_spec(wl.spec.replace(**req.params))
        else:
            raise ValueError(f"request {req.rid}: workload must be a kind "
                             f"name, WorkloadSpec, or Workload, got "
                             f"{type(wl).__name__}")
        if req.platform is None:
            raise ValueError(f"request {req.rid}: needs a platform")
        plat = req.platform
        if isinstance(plat, str):
            from repro.platforms import get_platform
            plat = get_platform(plat)
        wl.validate(plat)
        return (wl, plat, wl.fastsim_model(plat, faults=req.faults))

    def _resolve(self, req: WorkloadRequest) -> None:
        """Bind names to specs and build the fast model; idempotent, and
        every error surfaces here (before anything is enqueued)."""
        if req._bound is not None:
            return
        memo_key = self._memo_key(req)
        bound = (self._resolve_memo.get(memo_key)
                 if memo_key is not None else None)
        if bound is None:
            bound = self._bind(req)
            if memo_key is not None:
                if len(self._resolve_memo) >= 4096:
                    self._resolve_memo.clear()
                self._resolve_memo[memo_key] = bound
        wl, plat, _ = bound
        if req.breakdown:
            # region requests simulate only a representative slice of the
            # iteration space, so they get the (much higher) region guard
            guard, name = ((self.max_region_ranks, "max_region_ranks")
                           if req.regions is not None
                           else (self.max_des_ranks, "max_des_ranks"))
            if wl.des_ranks(plat) > guard:
                if req.timeout_s is not None:
                    # budgeted request: degrade to fastsim, don't reject
                    req._fallback = (f"{name}: breakdown DES at "
                                     f"{wl.des_ranks(plat)} ranks exceeds "
                                     f"{guard}")
                else:
                    raise ValueError(
                        f"request {req.rid}: breakdown DES at "
                        f"{wl.des_ranks(plat)} ranks exceeds {name}="
                        f"{guard}; pass a scaled-down scenario"
                        + ("" if req.regions is not None else
                           " or a regions= request"))
        req._bound = bound

    def submit(self, req: WorkloadRequest) -> None:
        self._resolve(req)
        if req.timeout_s is not None and req._deadline is None:
            req._deadline = time.monotonic() + req.timeout_s
        self.stats["requests"] += 1
        self._queue.append(req)
        if self.metrics.enabled:
            req._t_submit = time.perf_counter()
            self.metrics.counter("serve.requests").inc()
            self.metrics.gauge("serve.queue_depth").set(len(self._queue))

    def _cache_key(self, req: WorkloadRequest) -> Optional[str]:
        """Content-addressed key of a resolved request, or None when it
        is uncacheable.  Budgeted requests (``timeout_s``) can degrade
        nondeterministically under wall pressure, so they are never
        cached (which also keeps every rank-guard/deadline fallback out
        of the cache — degraded answers are always recomputed)."""
        if req.timeout_s is not None:
            return None
        wl, plat, _ = req._bound
        return request_key(wl.spec, plat, faults=req.faults,
                           regions=req.regions, breakdown=req.breakdown)

    def _dispatch(self, model_cls, reqs: List[WorkloadRequest]) -> List[dict]:
        """One batched sweep per family, with bounded retry + exponential
        backoff for transient backend errors.  With ``shard=True`` the
        sweep's padded lane axis is split across local devices."""
        models = [r._bound[2] for r in reqs]
        delay = self.backoff_s
        for attempt in range(self.retries + 1):
            try:
                if self.shard:
                    with lane_sharding(True):
                        return model_cls.sweep_models(models)
                return model_cls.sweep_models(models)
            except self.TRANSIENT:
                if attempt == self.retries:
                    raise
                self.stats["retries"] += 1
                self.metrics.counter("serve.retries").inc()
                time.sleep(delay)
                delay *= 2.0

    def _attach_breakdown(self, req: WorkloadRequest, out: dict) -> None:
        """Run the traced DES under the request's remaining wall budget;
        on budget exhaustion the fastsim answer stands, stamped with the
        fallback reason."""
        wl, plat, _ = req._bound
        budget = None
        if req._deadline is not None:
            budget = req._deadline - time.monotonic()
            if budget <= 0.0:
                self._degrade(out, "deadline_exceeded: wall budget spent "
                                   "before the breakdown DES started",
                              kind="deadline")
                return
        try:
            app = wl.des_app(plat, trace=True, faults=req.faults,
                             regions=req.regions)
            if budget is not None:
                app.engine.set_wall_deadline(budget)
            if self.metrics.enabled:
                # DES telemetry (events/s, heap depth, recycle rate)
                # lands in the service registry; engine.metrics only
                # observes, so the simulated clock is unchanged
                app.engine.metrics = self.metrics
                with self.metrics.timer("serve.des_wall_s"):
                    app.run()
            else:
                app.run()
            summary = app.engine.trace.summary()
            if req.regions is not None:
                # the trace covers only the simulated region
                summary["region_approx"] = True
                out["region_approx"] = True
            out["breakdown"] = summary
            self.stats["des_breakdowns"] += 1
            self.metrics.counter("serve.des_breakdowns").inc()
        except SimWallDeadline as exc:
            self._degrade(out, f"wall_deadline: {exc}", kind="deadline")

    def _degrade(self, out: dict, reason: str, *,
                 kind: str = "deadline") -> None:
        """Stamp a degraded (fastsim-only) answer.  ``kind`` routes the
        counter: "deadline" for wall-budget fallbacks, "rank_guard" for
        breakdown requests over the DES rank cap."""
        out["fallback_reason"] = reason
        out["degraded"] = True
        self.stats["fallbacks"] += 1
        if self.metrics.enabled:
            self.metrics.counter("serve.fallbacks").inc()
            self.metrics.counter(
                "serve.deadline_fallbacks" if kind == "deadline"
                else "serve.rank_guard_trips").inc()

    def _finish(self, req: WorkloadRequest, out: dict,
                results: Dict[int, dict]) -> None:
        """Attach one answered result to its request + the result map
        and record the request's latency."""
        req.result = out
        results[req.rid] = out
        m = self.metrics
        if m.enabled and req._t_submit is not None:
            m.histogram("serve.request_latency_s").observe(
                time.perf_counter() - req._t_submit)

    def flush(self) -> Dict[int, dict]:
        """Drain the queue in waves of up to ``max_batch`` scenarios;
        each wave groups requests by workload family and runs ONE
        ``sweep_models`` dispatch per family.  Returns {rid: result}.

        With a cache attached, each wave is first partitioned: requests
        whose content-addressed key is already cached are served
        immediately (stamped ``cached=True``); duplicate in-flight keys
        coalesce onto one *leader* per key (the only one dispatched) and
        the followers receive deep copies of the leader's result.
        Uncacheable requests (``timeout_s`` budgets, which can degrade
        nondeterministically) always take the dispatch path, and error
        results are never inserted into the cache.

        Dispatch is all-or-nothing per wave: every family's sweep runs
        before any result is attached.  If one family's dispatch fails
        (after retries), every not-yet-served request in the wave is
        stamped with a ``{"status": "error", ...}`` result, the
        exception re-raises, and the queue keeps only the requests
        behind the wave — the service stays reusable with a clean queue
        (cache hits served before the failure keep their good results)."""
        results: Dict[int, dict] = {}
        m = self.metrics
        cache = self.cache
        while self._queue:
            wave = self._queue[:self.max_batch]
            del self._queue[:self.max_batch]
            if m.enabled:
                m.histogram("serve.wave_size", COUNT_BUCKETS).observe(
                    len(wave))
                m.gauge("serve.queue_depth").set(len(self._queue))
            to_dispatch: List[WorkloadRequest] = []
            followers: Dict[str, List[WorkloadRequest]] = {}
            served_ids: set = set()
            if cache is None:
                to_dispatch = list(wave)
            else:
                leaders: Dict[str, WorkloadRequest] = {}
                for req in wave:
                    req._ckey = key = self._cache_key(req)
                    if key is None:               # uncacheable: dispatch
                        to_dispatch.append(req)
                        continue
                    hit = cache.get(key)
                    if hit is not None:
                        hit["cached"] = True      # provenance stamp; the
                        #   payload under it is bit-identical to a miss
                        self._finish(req, hit, results)
                        served_ids.add(id(req))
                        self.stats["cache_hits"] += 1
                        m.counter("serve.cache_hits").inc()
                        continue
                    self.stats["cache_misses"] += 1
                    m.counter("serve.cache_misses").inc()
                    if key in leaders:            # coalesce onto leader
                        followers.setdefault(key, []).append(req)
                    else:
                        leaders[key] = req
                        to_dispatch.append(req)
            by_family: Dict[type, List[WorkloadRequest]] = {}
            for req in to_dispatch:
                by_family.setdefault(type(req._bound[2]), []).append(req)
            dispatched: List[tuple] = []
            try:
                for model_cls, reqs in by_family.items():
                    dispatched.append((reqs, self._dispatch(model_cls, reqs)))
                    self.stats["sweeps"] += 1
                    m.counter("serve.sweeps").inc()
            except Exception as exc:
                # the wave is already off the queue; stamp every request
                # not already served from cache so callers holding the
                # objects see the failure, then surface it.  Nothing from
                # a failed wave is ever inserted into the cache.
                err = {"status": "error", "error": str(exc),
                       "error_type": type(exc).__name__}
                for req in wave:
                    if id(req) not in served_ids:
                        req.result = dict(err)
                self.stats["errors"] += 1
                m.counter("serve.dispatch_failures").inc()
                raise
            for reqs, res in dispatched:
                for req, out in zip(reqs, res):
                    out = dict(out)
                    if req._fallback is not None:    # rank-guard degrade
                        self._degrade(out, req._fallback, kind="rank_guard")
                    elif req.breakdown:
                        self._attach_breakdown(req, out)
                    if (cache is not None and req._ckey is not None
                            and not out.get("degraded")):
                        # inserts happen only here, after a successful
                        # non-degraded dispatch: errors raised above and
                        # degraded answers never enter the cache
                        cache.put(req._ckey, out,
                                  platform=req._bound[1].name)
                    self._finish(req, out, results)
                    for dup in (followers.get(req._ckey, ())
                                if req._ckey is not None else ()):
                        self._finish(dup, copy_payload(out), results)
                        self.stats["coalesced"] += 1
                        m.counter("serve.coalesced").inc()
            self.stats["batches"] += 1
            self.stats["scenarios"] += len(wave)
            if m.enabled:
                m.counter("serve.batches").inc()
                m.counter("serve.scenarios").inc(len(wave))
                if cache is not None:
                    m.gauge("serve.cache_entries").set(len(cache))
                    m.gauge("serve.cache_occupancy").set(
                        len(cache) / cache.max_entries)
        return results

    def predict_batch(self, requests: Sequence[WorkloadRequest], *,
                      isolate_errors: bool = False) -> Dict[int, dict]:
        """Submit + flush in one call.

        Default is all-or-nothing on resolution: a bad request (unknown
        workload or platform name) rejects the whole call and leaves the
        queue untouched.  With ``isolate_errors=True`` a bad request
        instead yields a ``{"status": "error", "error": ...,
        "error_type": ...}`` entry for its rid while the rest of the
        wave is served normally; failed requests are never enqueued, so
        an empty (or all-failed) wave leaves the queue clean."""
        requests = list(requests)
        if not isolate_errors:
            for req in requests:
                self._resolve(req)
            if not requests:
                return {}
            for req in requests:
                self.submit(req)        # _resolve is idempotent
            return self.flush()
        results: Dict[int, dict] = {}
        good: List[WorkloadRequest] = []
        for req in requests:
            try:
                self._resolve(req)
                good.append(req)
            except Exception as exc:
                err = {"status": "error", "error": str(exc),
                       "error_type": type(exc).__name__}
                req.result = err
                results[req.rid] = err
                self.stats["errors"] += 1
                self.metrics.counter("serve.errors_isolated").inc()
        for req in good:
            self.submit(req)
        if good:
            for rid, out in self.flush().items():
                out.setdefault("status", "ok")
                results[rid] = out
        return results

    def predict(self, workload, platform, *, faults=None,
                timeout_s=None, **params) -> dict:
        """Single-request convenience entry point."""
        return self.predict_batch(
            [WorkloadRequest(rid=0, workload=workload, platform=platform,
                             params=params, faults=faults,
                             timeout_s=timeout_s)])[0]

    # --------------------------------------------------------- warm pool
    def warm(self, workloads: Any = ("hpl",), platforms: Any = (), *,
             count: int = 1, prime_cache: bool = False,
             requests: Optional[Sequence[WorkloadRequest]] = None
             ) -> Dict[str, Any]:
        """Precompile the sweep buckets a (workload, platform) grid will
        need, so the first real wave pays zero compiles.

        ``workloads``/``platforms`` are names, specs, or instances (one
        or a sequence); ``count`` replicates each cell so the warm
        dispatch is padded to the same power-of-two lane count a real
        wave of that size will use (the jit cache is keyed on the padded
        batch shape — warm with the wave size you expect to serve).
        Alternatively ``requests=`` warms from a representative traffic
        sample: the sweep engine sees exactly the scenario/geometry mix
        (and therefore the compile buckets) those requests will need —
        breakdown/timeout stamps are dropped, only the sweep shapes
        matter.  With ``prime_cache=True`` (and a cache attached) the
        warm results are inserted too, so the first wave is all-hits,
        not just all-compile-hits.

        Compiles are measured via the §18 trace counters and recorded as
        ``serve.warm_compiles`` / ``serve.warm_dispatches``; the report
        dict carries ``compiles``/``dispatches``/``scenarios``.  A
        second identical ``warm()`` reporting ``compiles == 0`` is the
        warm-pool verification contract."""
        from repro.core import fastsim
        from repro.workloads import stepsim

        def _aslist(x):
            return list(x) if isinstance(x, (list, tuple)) else [x]

        reqs: List[WorkloadRequest] = []
        if requests is not None:
            reqs = [WorkloadRequest(rid=-1 - i, workload=r.workload,
                                    platform=r.platform,
                                    params=dict(r.params), faults=r.faults,
                                    regions=r.regions)
                    for i, r in enumerate(requests)]
        else:
            for wl in _aslist(workloads):
                for plat in _aslist(platforms):
                    for i in range(max(1, int(count))):
                        reqs.append(WorkloadRequest(rid=-1 - len(reqs),
                                                    workload=wl,
                                                    platform=plat))
        for req in reqs:
            self._resolve(req)
        by_family: Dict[type, List[WorkloadRequest]] = {}
        for req in reqs:
            by_family.setdefault(type(req._bound[2]), []).append(req)
        m = self.metrics
        pre = fastsim.trace_count() + stepsim.trace_count()
        for model_cls, group in by_family.items():
            res = self._dispatch(model_cls, group)
            if m.enabled:
                m.counter("serve.warm_dispatches").inc()
            if prime_cache and self.cache is not None:
                for req, out in zip(group, res):
                    key = self._cache_key(req)
                    if key is not None:
                        self.cache.put(key, dict(out),
                                       platform=req._bound[1].name)
        compiles = fastsim.trace_count() + stepsim.trace_count() - pre
        if m.enabled and compiles:
            m.counter("serve.warm_compiles").inc(compiles)
        return {"compiles": compiles, "dispatches": len(by_family),
                "scenarios": len(reqs)}

    # ------------------------------------------------------ observability
    def prometheus(self) -> str:
        """The service's metrics in Prometheus text exposition format."""
        return self.metrics.to_prometheus()

    def manifest(self, **meta) -> str:
        """One NDJSON run-manifest line: service config + lifetime stats
        as ``meta`` and the full metrics snapshot (see ``repro.obs``)."""
        base = {"service": type(self).__name__,
                "max_batch": self.max_batch, "stats": dict(self.stats)}
        base.update(meta)
        return manifest_line("serve_run", meta=base, metrics=self.metrics)


class HPLPredictionService:
    """Micro-batching front end over the batched sweep engine — the
    HPL-specialized back-compat surface (cfg/params-level requests);
    new call sites should prefer the workload-generic
    ``PredictionService``."""

    def __init__(self, max_batch: int = 256, max_des_ranks: int = 1024,
                 metrics: Any = None):
        self.max_batch = max_batch
        self.max_des_ranks = max_des_ranks
        self._queue: List[PredictRequest] = []
        self.stats = {"requests": 0, "batches": 0, "scenarios": 0,
                      "traces": 0, "des_breakdowns": 0}
        #: same metric names as PredictionService (serve.requests,
        #: serve.batches, serve.scenarios, serve.sweeps, ...), so the
        #: two endpoints are drop-in equivalents on a dashboard
        self.metrics = MetricsRegistry() if metrics is None else metrics

    def _resolve(self, req: PredictRequest) -> None:
        if req.params is None or req.cfg is None:
            if req.platform is None:
                raise ValueError(
                    f"request {req.rid}: needs (cfg, params) or a "
                    "platform name")
            from repro.platforms import get_platform
            plat = get_platform(req.platform)
            if req.params is None:
                req.params = plat.fastsim()
            if req.cfg is None:
                req.cfg = plat.hpl_config()
        if req.breakdown:
            if req.platform is None:
                raise ValueError(
                    f"request {req.rid}: breakdown=True needs a platform "
                    "name (the DES is built from the spec)")
            if req.cfg.n_ranks > self.max_des_ranks:
                raise ValueError(
                    f"request {req.rid}: breakdown DES at "
                    f"{req.cfg.n_ranks} ranks exceeds max_des_ranks="
                    f"{self.max_des_ranks}; pass a scaled-down cfg")

    def submit(self, req: PredictRequest) -> None:
        self._resolve(req)
        self.stats["requests"] += 1
        self._queue.append(req)
        if self.metrics.enabled:
            req._t_submit = time.perf_counter()
            self.metrics.counter("serve.requests").inc()
            self.metrics.gauge("serve.queue_depth").set(len(self._queue))

    def _des_breakdown(self, req: PredictRequest) -> dict:
        """Traced DES of the request scenario -> phase/category report."""
        from repro.core.apps.hpl import HPLSim
        from repro.platforms import get_platform
        sim = HPLSim(req.cfg, get_platform(req.platform), trace=True)
        if self.metrics.enabled:
            sim.engine.metrics = self.metrics
            with self.metrics.timer("serve.des_wall_s"):
                res = sim.run()
        else:
            res = sim.run()
        out = res.trace.summary()
        out["des_time_s"] = res.time_s
        out["des_gflops"] = res.gflops
        self.stats["des_breakdowns"] += 1
        self.metrics.counter("serve.des_breakdowns").inc()
        return out

    def flush(self) -> Dict[int, dict]:
        """Drain the queue in waves of up to ``max_batch`` scenarios.

        Each wave is one ``sweep_hpl`` call: scenarios sharing a shape
        bucket run as a single compiled vmapped program.  Returns
        {rid: result-dict} for everything served.
        """
        results: Dict[int, dict] = {}
        m = self.metrics
        t0 = trace_count()
        while self._queue:
            wave = self._queue[:self.max_batch]
            del self._queue[:self.max_batch]
            if m.enabled:
                m.histogram("serve.wave_size", COUNT_BUCKETS).observe(
                    len(wave))
                m.gauge("serve.queue_depth").set(len(self._queue))
            res = sweep_hpl([r.cfg for r in wave],
                            [r.params for r in wave])
            m.counter("serve.sweeps").inc()   # one sweep_hpl per wave
            for req, out in zip(wave, res):
                if req.breakdown:
                    out = dict(out)
                    out["breakdown"] = self._des_breakdown(req)
                req.result = out
                results[req.rid] = out
                if m.enabled and req._t_submit is not None:
                    m.histogram("serve.request_latency_s").observe(
                        time.perf_counter() - req._t_submit)
            self.stats["batches"] += 1
            self.stats["scenarios"] += len(wave)
            if m.enabled:
                m.counter("serve.batches").inc()
                m.counter("serve.scenarios").inc(len(wave))
        self.stats["traces"] += trace_count() - t0
        return results

    def predict_batch(self, scenarios: Sequence[PredictRequest]
                      ) -> Dict[int, dict]:
        """Submit + flush in one call — the RPC-handler entry point.

        All-or-nothing on resolution: every request is resolved before
        any is enqueued, so one bad request (unknown platform name
        mid-batch, missing cfg) rejects the whole call and leaves the
        queue exactly as it was.  An empty batch returns {} without
        dispatching anything.
        """
        scenarios = list(scenarios)
        for req in scenarios:
            self._resolve(req)
        if not scenarios:
            return {}
        for req in scenarios:
            self.submit(req)        # _resolve is idempotent
        return self.flush()

    def predict_platforms(self, names: Sequence[str],
                          cfg: Optional[HPLConfig] = None,
                          ) -> Mapping[str, dict]:
        """Predict a batch of registry machines by name (their published
        HPL runs, or a shared ``cfg`` override) in one sweep."""
        reqs = [PredictRequest(rid=i, cfg=cfg, platform=name)
                for i, name in enumerate(names)]
        out = self.predict_batch(reqs)
        return {name: out[i] for i, name in enumerate(names)}

    def predict_top500(self, csv_path, **kw) -> dict:
        """Serve a whole TOP500 list export: ranked predicted-vs-
        published Rmax report as a JSON-safe dict (delegates to
        ``repro.top500.predict_top500``; same keywords)."""
        report = predict_top500(csv_path, metrics=self.metrics, **kw)
        self.stats["requests"] += len(report.entries)
        self.stats["scenarios"] += len(report.entries)
        self.stats["batches"] += 1
        if self.metrics.enabled:
            self.metrics.counter("serve.requests").inc(len(report.entries))
            self.metrics.counter("serve.scenarios").inc(len(report.entries))
            self.metrics.counter("serve.batches").inc()
        return report.to_dict()

    # ------------------------------------------------------ observability
    def prometheus(self) -> str:
        """The service's metrics in Prometheus text exposition format."""
        return self.metrics.to_prometheus()

    def manifest(self, **meta) -> str:
        """One NDJSON run-manifest line (same shape as
        ``PredictionService.manifest``)."""
        base = {"service": type(self).__name__,
                "max_batch": self.max_batch, "stats": dict(self.stats)}
        base.update(meta)
        return manifest_line("serve_run", meta=base, metrics=self.metrics)


def warm(workloads: Any = ("hpl",), platforms: Any = (), *,
         count: int = 1, prime_cache: bool = False,
         service: Optional[PredictionService] = None,
         **service_kw) -> Dict[str, Any]:
    """Module-level warm-pool entry point (``python -m repro.serve warm``
    wraps this): precompile the sweep buckets for a (workload, platform)
    grid on ``service`` — or a fresh ``PredictionService(**service_kw)``
    — and return the warm report (see ``PredictionService.warm``)."""
    svc = service if service is not None else PredictionService(**service_kw)
    report = svc.warm(workloads, platforms, count=count,
                      prime_cache=prime_cache)
    report["service"] = type(svc).__name__
    return report


def predict_top500(csv_path, *, namespace: Optional[str] = None,
                   overwrite: bool = False, metrics: Any = None, **kw):
    """Parse a TOP500 list export, infer a Platform per row, and predict
    the whole fleet in one batched sweep — returns the ``FleetReport``
    (rows the lenient parser rejected surface in ``report.skipped_rows``;
    a list with *no* parseable rows raises with the reasons).

    ``namespace="top500"`` additionally registers every inferred spec as
    ``top500/<name>`` so individual machines can then be served by name
    through ``PredictRequest(platform=...)``; re-ingesting the same list
    needs ``overwrite=True`` (forwarded to ``bulk_register``).  Remaining
    keywords reach ``repro.top500.predict_fleet`` (``tuning=``,
    ``calibrate=``, ``infer_kw=``).
    """
    from repro.top500 import infer_platforms, parse_top500, predict_fleet
    parsed = parse_top500(csv_path)
    if metrics is not None and metrics.enabled:
        metrics.counter("fleet.rows_parsed").inc(len(parsed.rows))
        metrics.counter("fleet.rows_skipped").inc(len(parsed.skipped))
    if not parsed.rows:
        raise ValueError(
            f"predict_top500: no parseable rows in {csv_path!r}; "
            f"skipped: {parsed.skipped[:5]}"
            f"{'...' if len(parsed.skipped) > 5 else ''}")
    platforms = infer_platforms(parsed.rows,
                                **(kw.pop("infer_kw", None) or {}))
    if namespace is not None:
        from repro.platforms import bulk_register
        platforms = bulk_register(platforms, namespace=namespace,
                                  overwrite=overwrite)
    report = predict_fleet(platforms, metrics=metrics, **kw)
    report.skipped_rows = list(parsed.skipped)
    return report
