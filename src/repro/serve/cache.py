"""Content-addressed result cache for the prediction services.

The fleet traffic the campaign layer (and any what-if UI) generates is
mostly *duplicate cells*: the same (workload, platform, faults, regions)
tuple asked again and again across waves, editions, and users.  Every
spec in the stack is frozen, hashable, JSON-round-trip data, so a
scenario has a canonical serialized form — which means a prediction is
*content-addressable*: the cache key is a digest of the serialized
scenario tuple, never of object identity or registry names.

Key properties (DESIGN.md §20):

  * **Canonical** — ``request_key`` digests the resolved
    ``WorkloadSpec`` (params folded, so ``get_workload("hpl", N=4096)``
    and an equal explicit spec collide), the full ``Platform`` content
    (not its name — two registries disagreeing about "frontera" can
    never cross-serve), the normalized ``FaultSpec`` and region spec,
    and the breakdown flag.  Any field change anywhere in that tuple
    changes the key.
  * **Bounded** — LRU over ``max_entries``; hits refresh recency.
  * **Invalidation** — re-registering (or unregistering) a platform
    name drops every entry derived from that name via the registry
    hook below.  Content addressing already guarantees a *changed*
    platform can never serve stale payloads (its digest differs); the
    explicit invalidation is memory hygiene plus a hard guarantee for
    audit-style callers.
  * **Never caches failures** — the service only inserts successful,
    deadline-free payloads; error and degraded results are recomputed
    every time.

Payloads are stored and served as deep copies, so callers can mutate
their results freely without poisoning the cache.
"""
from __future__ import annotations

import functools
import hashlib
import threading
import weakref
from collections import OrderedDict
from typing import Any, Dict, List, Optional, Tuple

__all__ = ["ResultCache", "as_result_cache", "request_key",
           "platform_digest", "spec_digest", "fault_digest",
           "copy_payload"]


def copy_payload(x):
    """Deep copy of a JSON-shaped result payload (dict/list/tuple of
    scalars).  Payloads are journaling-safe plain data by contract, so
    this beats ``copy.deepcopy`` by ~10x on the cache hit path; scalars
    are immutable and shared as-is, so hits stay bit-identical."""
    if isinstance(x, dict):
        return {k: copy_payload(v) for k, v in x.items()}
    if isinstance(x, list):
        return [copy_payload(v) for v in x]
    if isinstance(x, tuple):
        return tuple(copy_payload(v) for v in x)
    return x


# --------------------------------------------------------------- digests
@functools.lru_cache(maxsize=4096)
def platform_digest(platform) -> str:
    """Stable content digest of a ``Platform`` (memoized per spec — the
    registry holds specs alive, so repeat requests pay a dict hash, not
    a JSON serialization)."""
    return hashlib.sha256(
        platform.to_json(sort_keys=True).encode()).hexdigest()


@functools.lru_cache(maxsize=4096)
def spec_digest(spec) -> str:
    """Stable content digest of a ``WorkloadSpec``."""
    return hashlib.sha256(spec.to_json(sort_keys=True).encode()).hexdigest()


@functools.lru_cache(maxsize=4096)
def fault_digest(fault_spec) -> str:
    """Stable content digest of a normalized ``FaultSpec`` (or None)."""
    if fault_spec is None:
        return ""
    return hashlib.sha256(
        fault_spec.to_json(sort_keys=True).encode()).hexdigest()


def _regions_token(regions) -> str:
    """Canonical token for the ``regions=`` axis: None (exact fastsim
    answer) stays distinct from every region request; an int and the
    equivalent ``RegionSpec`` collide (same semantics)."""
    if regions is None:
        return ""
    from repro.scale import as_region
    r = as_region(regions)
    return f"r{r.panels}w{r.warmup}"


def request_key(workload_spec, platform, *, faults=None, regions=None,
                breakdown: bool = False) -> str:
    """The content-addressed key of one prediction request.

    ``workload_spec`` is the *resolved* ``WorkloadSpec`` (request params
    already folded in), ``platform`` the resolved ``Platform``;
    ``faults`` may be a ``FaultSpec``, dict, or JSON string (normalized
    through ``as_fault_spec``, so equal scenarios in different notations
    collide).  Sensitivity is total: any field change in any component
    yields a different key.
    """
    from repro.faults import as_fault_spec
    parts = (spec_digest(workload_spec), platform_digest(platform),
             fault_digest(as_fault_spec(faults)), _regions_token(regions),
             "breakdown" if breakdown else "")
    return hashlib.sha256("|".join(parts).encode()).hexdigest()


# ----------------------------------------------------------------- cache
#: every live cache, for registry-driven invalidation fan-out
_LIVE_CACHES: "weakref.WeakSet[ResultCache]" = weakref.WeakSet()
_HOOK_INSTALLED = False
_HOOK_LOCK = threading.Lock()


def _install_registry_hook() -> None:
    """Idempotently subscribe to platform re-registration events so
    every live cache drops entries derived from the re-registered
    name (serve imports platforms, never the reverse)."""
    global _HOOK_INSTALLED
    with _HOOK_LOCK:
        if _HOOK_INSTALLED:
            return
        from repro.platforms.registry import add_invalidation_hook

        def _on_reregister(name: str) -> None:
            for cache in list(_LIVE_CACHES):
                cache.invalidate_platform(name)

        add_invalidation_hook(_on_reregister)
        _HOOK_INSTALLED = True


class ResultCache:
    """LRU result cache keyed by :func:`request_key` digests.

    Entries carry the platform *name* they were resolved from so
    registry re-registration can invalidate by name; correctness never
    depends on it (the key is content-addressed), it is hygiene.
    """

    def __init__(self, max_entries: int = 4096):
        if max_entries < 1:
            raise ValueError(f"ResultCache: max_entries={max_entries} "
                             "must be >= 1")
        self.max_entries = int(max_entries)
        #: key -> (payload, platform_name)
        self._data: "OrderedDict[str, Tuple[dict, Optional[str]]]" = \
            OrderedDict()
        self.hits = 0
        self.misses = 0
        self.insertions = 0
        self.evictions = 0
        self.invalidations = 0
        _LIVE_CACHES.add(self)
        _install_registry_hook()

    def __len__(self) -> int:
        return len(self._data)

    def get(self, key: str) -> Optional[dict]:
        """Deep copy of the payload under ``key`` (refreshes recency),
        or None.  Counts a hit or a miss."""
        entry = self._data.get(key)
        if entry is None:
            self.misses += 1
            return None
        self._data.move_to_end(key)
        self.hits += 1
        return copy_payload(entry[0])

    def put(self, key: str, payload: dict, *,
            platform: Optional[str] = None) -> None:
        """Insert (a deep copy of) ``payload``; evicts least-recently-
        used entries past ``max_entries``."""
        self._data[key] = (copy_payload(payload), platform)
        self._data.move_to_end(key)
        self.insertions += 1
        while len(self._data) > self.max_entries:
            self._data.popitem(last=False)
            self.evictions += 1

    def invalidate_platform(self, name: str) -> int:
        """Drop every entry resolved from platform ``name``; returns
        how many were dropped."""
        stale = [k for k, (_, pname) in self._data.items() if pname == name]
        for k in stale:
            del self._data[k]
        self.invalidations += len(stale)
        return len(stale)

    def clear(self) -> None:
        self._data.clear()

    def stats(self) -> Dict[str, Any]:
        return {"entries": len(self._data), "capacity": self.max_entries,
                "hits": self.hits, "misses": self.misses,
                "insertions": self.insertions, "evictions": self.evictions,
                "invalidations": self.invalidations}

    def keys(self) -> List[str]:
        """Keys in LRU order (oldest first) — eviction-order tests."""
        return list(self._data)

    def __repr__(self) -> str:
        return (f"ResultCache({len(self._data)}/{self.max_entries} "
                f"entries, {self.hits} hits, {self.misses} misses)")


def as_result_cache(cache) -> Optional[ResultCache]:
    """Normalize the service's ``cache=`` argument: None/False -> off,
    True -> a fresh default-sized cache, an int -> that capacity, a
    ``ResultCache`` -> itself (share one across services to share
    results)."""
    if cache is None or cache is False:
        return None
    if cache is True:
        return ResultCache()
    if isinstance(cache, int):
        return ResultCache(max_entries=cache)
    if isinstance(cache, ResultCache):
        return cache
    raise TypeError(f"cache must be None/bool/int/ResultCache, got "
                    f"{type(cache).__name__}")
