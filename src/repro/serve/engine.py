"""Batched serving engine: prefill + decode with a slotted KV cache.

Static-slot continuous batching: a fixed decode batch of B slots; finished
sequences free their slot and the next queued request is prefilled into
it.  Single jit'd decode step over the whole batch (cache is donated); the
per-slot length mask handles ragged progress.

This is the serving-side end-to-end driver (deliverable b): small models
run real batched generation on CPU; the production shapes lower the same
``decode`` function through launch/dryrun.py.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import build_model


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray                   # (S,) int32
    max_new_tokens: int = 16
    out_tokens: Optional[List[int]] = None


class ServeEngine:
    def __init__(self, cfg, params, *, batch_slots: int = 4,
                 max_len: int = 512, greedy: bool = True):
        self.cfg = cfg
        self.model = build_model(cfg)
        self.params = params
        self.B = batch_slots
        self.max_len = max_len
        self.greedy = greedy
        self._decode = jax.jit(self.model.decode, donate_argnums=(1,))
        # one persistent jit wrapper — the compile cache is keyed on the
        # function object, so wrapping per request would retrace every
        # prefill instead of only once per prompt-length bucket
        self._prefill = jax.jit(
            lambda p, b: self.model.prefill(p, b, max_len=self.max_len))
        self._queue: List[Request] = []
        self.stats = {"prefills": 0, "decode_steps": 0, "tokens_out": 0}

    def submit(self, req: Request):
        req.out_tokens = []
        self._queue.append(req)

    def _prefill_one(self, req: Request):
        self.stats["prefills"] += 1
        tokens = jnp.asarray(req.prompt, jnp.int32)[None, :]
        batch = {"tokens": tokens}
        if self.cfg.family == "encdec":
            batch["encoder_embeds"] = jnp.zeros(
                (1, self.cfg.encoder_seq, self.cfg.d_model), jnp.float32)
        if self.cfg.family == "vlm":
            batch["image_embeds"] = jnp.zeros(
                (1, self.cfg.n_image_tokens, self.cfg.d_model), jnp.float32)
        cache, logits = self._prefill(self.params, batch)
        first = int(jnp.argmax(logits[0, :self.cfg.vocab_size]))
        return cache, first

    def warm(self, prompt_lens) -> Dict[str, int]:
        """Precompile prefill + decode for each prompt-length bucket by
        running a tiny throwaway request through the real serving path
        (compile caches are keyed on shapes, so a later real request of
        the same length pays zero compiles).  Warm traffic is real
        traffic and counts in ``stats``."""
        if isinstance(prompt_lens, int):
            prompt_lens = [prompt_lens]
        lens = sorted({int(n) for n in prompt_lens})
        before = dict(self.stats)
        for i, n in enumerate(lens):
            self.run([Request(rid=-1 - i, prompt=np.zeros(n, np.int32),
                              max_new_tokens=2)])
        return {"buckets": len(lens),
                "prefills": self.stats["prefills"] - before["prefills"],
                "decode_steps": (self.stats["decode_steps"]
                                 - before["decode_steps"])}

    def run(self, requests: List[Request]) -> Dict[int, List[int]]:
        """Serve a list of requests to completion (batched decode).

        Simplification: slots run the decode loop in lockstep batches of
        up to B; each wave drains before the next fills (static batching —
        the DES serving model covers continuous batching analytically).
        """
        for r in requests:
            self.submit(r)
        results: Dict[int, List[int]] = {}
        while self._queue:
            wave = [self._queue.pop(0) for _ in range(min(self.B,
                                                          len(self._queue)))]
            self._run_wave(wave)
            for r in wave:
                results[r.rid] = r.out_tokens
                self.stats["tokens_out"] += len(r.out_tokens)
        return results

    def _run_wave(self, wave: List[Request]):
        lens = {len(r.prompt) for r in wave}
        assert len(lens) == 1, \
            "wave prompts must share a length (cache['len'] is per-wave); " \
            "the caller buckets by prompt length"
        caches, cur = [], []
        for r in wave:
            cache, first = self._prefill_one(r)
            r.out_tokens.append(first)
            caches.append(cache)
            cur.append(first)
        # stack caches along batch dim (axis differs per family leaf: the
        # batch axis of every cache leaf is 1 in our layouts)
        def stack(*leaves):
            if leaves[0].ndim == 0:
                return leaves[0]
            return jnp.concatenate(leaves, axis=1 if leaves[0].ndim > 1
                                   else 0)
        if len(caches) > 1:
            cache = jax.tree.map(lambda *ls: stack(*ls), *caches)
        else:
            cache = caches[0]
        steps = max(r.max_new_tokens for r in wave) - 1
        alive = np.ones(len(wave), bool)
        for _ in range(max(steps, 0)):
            toks = jnp.asarray(cur, jnp.int32)[:, None]
            cache, logits = self._decode(self.params, cache, toks)
            self.stats["decode_steps"] += 1
            nxt = np.asarray(jnp.argmax(
                logits[:, :self.cfg.vocab_size], axis=-1))
            for i, r in enumerate(wave):
                if alive[i] and len(r.out_tokens) < r.max_new_tokens:
                    r.out_tokens.append(int(nxt[i]))
                    cur[i] = int(nxt[i])
                else:
                    alive[i] = False
            if not alive.any():
                break
