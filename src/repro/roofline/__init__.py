from .analysis import (collective_bytes, roofline_terms, parse_hlo_collectives,
                       HW, model_flops)

__all__ = ["collective_bytes", "roofline_terms", "parse_hlo_collectives",
           "HW", "model_flops"]
