"""Three-term roofline analysis from a compiled dry-run artifact.

    compute term    = HLO_FLOPs   / (chips x peak_FLOP/s)
    memory term     = HLO_bytes   / (chips x HBM_bw)
    collective term = coll_bytes  / (chips x link_bw)

``cost_analysis()`` on the partitioned module reports *per-device* flops /
bytes; we multiply back to whole-program numbers so the formulas above can
be applied uniformly.  collective_bytes is parsed from the (partitioned)
HLO text: for each all-gather / all-reduce / reduce-scatter / all-to-all /
collective-permute op we derive ring-algorithm wire bytes from the result
shape and the replica-group size.

Hardware constants (TPU v5e-class, per assignment):
    197 TFLOP/s bf16 per chip; 819 GB/s HBM; ~50 GB/s/link ICI.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Optional


@dataclasses.dataclass(frozen=True)
class Hardware:
    peak_flops: float = 197e12       # bf16 FLOP/s per chip
    hbm_bw: float = 819e9            # B/s per chip
    link_bw: float = 50e9            # B/s per ICI link (one-link bound)
    ici_links: int = 4               # 2-D torus: +-x, +-y (alt. bound)
    dcn_bw: float = 25e9             # B/s per chip across pods (pod axis)
    hbm_per_chip: float = 16e9       # bytes
    # power model — the paper's stated future work (§VI), implemented:
    # P(t) = idle + dynamic * utilization; energy integrates over the step.
    idle_watts: float = 70.0         # per chip, host share included
    dynamic_watts: float = 130.0     # at full MXU utilization (~200 W TDP class)


HW = Hardware()

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16, "s4": 1, "u4": 1,
}

# e.g.  %all-gather.3 = bf16[16,2048,896]{2,1,0} all-gather(%x), ...
_COLL_RE = re.compile(
    r"=\s*(?:\(([^)]*)\)|(\w+)\[([\d,]*)\][^ ]*)\s+"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\(", )

_GROUPS_RE = re.compile(r"replica_groups=\{\{([\d,]+)\}")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")


def _shape_bytes(dtype: str, dims: str) -> int:
    n = 1
    if dims.strip():
        for d in dims.split(","):
            n *= int(d)
    return n * _DTYPE_BYTES.get(dtype, 4)


def _tuple_bytes(inner: str) -> int:
    total = 0
    for m in re.finditer(r"(\w+)\[([\d,]*)\]", inner):
        total += _shape_bytes(m.group(1), m.group(2))
    return total


def parse_hlo_collectives(hlo_text: str) -> List[Dict]:
    """Returns one record per collective: op, result_bytes, group_size,
    wire_bytes (ring-algorithm bytes per participating device)."""
    out = []
    for line in hlo_text.splitlines():
        m = _COLL_RE.search(line)
        if not m:
            continue
        tuple_inner, dtype, dims, op = m.groups()
        rbytes = _tuple_bytes(tuple_inner) if tuple_inner \
            else _shape_bytes(dtype, dims)
        gs = 1
        gm = _GROUPS_RE.search(line)
        if gm:
            gs = len(gm.group(1).split(","))
        else:
            gi = _GROUPS_IOTA_RE.search(line)
            if gi:
                gs = int(gi.group(2))  # [num_groups, group_size]
        if gs <= 1 and op != "collective-permute":
            wire = 0.0
        elif op == "all-reduce":
            wire = 2.0 * (gs - 1) / gs * rbytes
        elif op == "all-gather":
            wire = (gs - 1) / gs * rbytes          # result = gathered
        elif op == "reduce-scatter":
            wire = (gs - 1) * rbytes               # result = scattered shard
        elif op == "all-to-all":
            wire = (gs - 1) / gs * rbytes
        else:                                       # collective-permute
            wire = float(rbytes)
        out.append({"op": op, "result_bytes": rbytes, "group_size": gs,
                    "wire_bytes": wire})
    return out


def collective_bytes(hlo_text: str) -> float:
    """Per-device collective wire bytes for the whole program."""
    return float(sum(r["wire_bytes"] for r in parse_hlo_collectives(hlo_text)))


def model_flops(cfg, shape) -> float:
    """6·N·D (dense) / 6·N_active·D (MoE); decode counts one token/seq."""
    n = cfg.n_active_params()
    if shape.kind == "train":
        tokens = shape.tokens
        return 6.0 * n * tokens
    if shape.kind == "prefill":
        return 2.0 * n * shape.tokens
    return 2.0 * n * shape.global_batch  # decode: one new token per sequence


def roofline_terms(*, per_device_flops: float, per_device_bytes: float,
                   per_device_coll_bytes: float, chips: int,
                   cfg=None, shape=None, hw: Hardware = HW) -> Dict:
    compute_t = per_device_flops / hw.peak_flops
    memory_t = per_device_bytes / hw.hbm_bw
    coll_t = per_device_coll_bytes / hw.link_bw
    coll_t_multilink = per_device_coll_bytes / (hw.link_bw * hw.ici_links)
    dominant = max((("compute", compute_t), ("memory", memory_t),
                    ("collective", coll_t)), key=lambda kv: kv[1])[0]
    bound = max(compute_t, memory_t, coll_t)
    out = {
        "compute_s": compute_t, "memory_s": memory_t, "collective_s": coll_t,
        "collective_multilink_s": coll_t_multilink,
        "dominant": dominant, "bound_s": bound,
        "chips": chips,
        "hlo_flops_total": per_device_flops * chips,
        "hlo_bytes_total": per_device_bytes * chips,
        "coll_bytes_per_device": per_device_coll_bytes,
    }
    # energy model (paper §VI future work): utilization = compute term /
    # step bound; idle power burns for the whole step on every chip.
    util = compute_t / max(bound, 1e-12)
    energy_j = chips * bound * (hw.idle_watts + hw.dynamic_watts * util)
    out["energy_j"] = energy_j
    out["avg_watts_per_chip"] = hw.idle_watts + hw.dynamic_watts * util
    if cfg is not None and shape is not None:
        mf = model_flops(cfg, shape)
        out["model_flops"] = mf
        out["useful_flops_ratio"] = mf / max(per_device_flops * chips, 1.0)
        # roofline fraction: useful model flops per second at the bound vs peak
        out["mfu_at_bound"] = (mf / max(bound, 1e-12)) / (chips * hw.peak_flops)
        out["joules_per_token"] = energy_j / max(
            shape.tokens if shape.kind != "decode" else shape.global_batch, 1)
    return out
