"""Optimized-HLO text analyzer with while-loop trip-count multiplication.

``compiled.cost_analysis()`` counts each ``while`` (scan) body ONCE — for a
scan-over-layers model that undercounts flops/bytes/collectives by ~L×.
This module re-derives the three roofline inputs from the partitioned HLO
text itself:

  * flops            — 2 · |result| · |contraction| per dot (+conv), × trips
  * bytes accessed   — per top-level instruction: operands + result
                       (dynamic-slice/gather count slice bytes, not the full
                       operand), × trips.  Post-fusion instruction boundaries
                       approximate materialized HBM buffers.
  * collectives      — ring-algorithm wire bytes per op, × trips

The same per-instruction walk feeds the simulator's workload trace
(core/apps/transformer.py): this is SimBLAS's "operation count" input,
extracted from the compiled artifact instead of the BLAS call site.
"""
from __future__ import annotations

import dataclasses
import math
import re
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "f8e4m3": 1, "f8e5m2fnuz": 1, "f8e4m3fnuz": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16, "s4": 1, "u4": 1,
    "token": 0, "opaque": 0,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
# type group is lazy: tuple types may contain `/*index=N*/` comments (which
# include '='), so we find the earliest `<type> <opcode>(` split instead.
_INSTR_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(.+?)\s+([\w\-]+)\((.*)$")
# computation headers start at column 0: `%name (args) -> type {` / `ENTRY %...`
_COMP_START_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*\(.*->.*\{\s*$")
_TRIP_RE = re.compile(r'known_trip_count[\\\'":{ ]+n[\\\'": ]+(\d+)')
_CONST_RE = re.compile(r"constant\((\d+)\)")
_CALLED_RE = re.compile(r"(?:condition|body|to_apply|calls|branch_computations)="
                        r"\{?%?([\w.\-]+(?:,\s*%?[\w.\-]+)*)\}?")


def _shape_elems(dims: str) -> int:
    n = 1
    if dims.strip():
        for d in dims.split(","):
            n *= int(d)
    return n


def _type_bytes(type_str: str) -> int:
    """bytes of a result type string: 'bf16[4,8]{1,0}' or '(f32[2], s32[])'."""
    total = 0
    for m in _SHAPE_RE.finditer(type_str):
        total += _shape_elems(m.group(2)) * _DTYPE_BYTES.get(m.group(1), 4)
    return total


@dataclasses.dataclass
class Instr:
    name: str
    type_str: str
    opcode: str
    rest: str           # operand list + attrs (raw tail of the line)
    operands: List[str]


@dataclasses.dataclass
class Computation:
    name: str
    instrs: List[Instr]
    symbols: Dict[str, str]  # instr name -> type string


def _split_operands(rest: str) -> Tuple[List[str], str]:
    """Split the '(...), attrs' tail into operand names and the attr tail."""
    depth = 0
    end = len(rest)
    for i, ch in enumerate(rest):
        if ch == "(":
            depth += 1
        elif ch == ")":
            depth -= 1
            if depth < 0:
                end = i
                break
    inner = rest[:end]
    tail = rest[end + 1:]
    ops = []
    for tok in re.split(r",\s*(?![^(]*\))", inner):
        tok = tok.strip()
        m = re.match(r"^%?([\w.\-]+)$", tok)
        if m:
            ops.append(m.group(1))
        else:
            # typed operand like 'bf16[2,3]{1,0} %name'
            m2 = re.search(r"%([\w.\-]+)\s*$", tok)
            if m2:
                ops.append(m2.group(1))
    return ops, tail


def parse_hlo_module(text: str) -> Dict[str, Computation]:
    comps: Dict[str, Computation] = {}
    cur: Optional[Computation] = None
    for line in text.splitlines():
        stripped = line.rstrip()
        if cur is None:
            if stripped[:1].isspace() or not stripped:
                continue
            m = _COMP_START_RE.match(stripped)
            if m and stripped.endswith("{"):
                cur = Computation(m.group(1), [], {})
            continue
        if stripped.strip() == "}":
            comps[cur.name] = cur
            cur = None
            continue
        mi = _INSTR_RE.match(stripped)
        if not mi:
            continue
        name, type_str, opcode, rest = mi.groups()
        ops, _ = _split_operands(rest)
        ins = Instr(name, type_str, opcode, rest, ops)
        cur.instrs.append(ins)
        cur.symbols[name] = type_str
    return comps


def _dot_flops(ins: Instr, symbols: Dict[str, str]) -> float:
    out_elems = sum(_shape_elems(m.group(2))
                    for m in _SHAPE_RE.finditer(ins.type_str))
    mC = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", ins.rest)
    if not mC or not ins.operands:
        return 2.0 * out_elems  # degenerate
    lhs_type = symbols.get(ins.operands[0], "")
    ms = _SHAPE_RE.search(lhs_type)
    if not ms:
        return 2.0 * out_elems
    dims = [int(d) for d in ms.group(2).split(",")] if ms.group(2) else []
    k = 1
    for ci in mC.group(1).split(","):
        if ci != "" and int(ci) < len(dims):
            k *= dims[int(ci)]
    return 2.0 * out_elems * k


def _conv_flops(ins: Instr, symbols: Dict[str, str]) -> float:
    # rough: 2 * out_elems * (kernel_elems / out_channels)
    out_elems = sum(_shape_elems(m.group(2))
                    for m in _SHAPE_RE.finditer(ins.type_str))
    if len(ins.operands) >= 2:
        ktype = symbols.get(ins.operands[1], "")
        ms = _SHAPE_RE.search(ktype)
        if ms and ms.group(2):
            kd = [int(d) for d in ms.group(2).split(",")]
            return 2.0 * out_elems * max(1, math.prod(kd[:-1]))
    return 2.0 * out_elems


_GROUPS_RE = re.compile(r"replica_groups=\{\{([\d,]+)\}")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")


def _group_size(rest: str) -> int:
    gm = _GROUPS_RE.search(rest)
    if gm:
        return len(gm.group(1).split(","))
    gi = _GROUPS_IOTA_RE.search(rest)
    if gi:
        return int(gi.group(2))
    return 1


def _collective_wire(opcode: str, ins: Instr, symbols: Dict[str, str]) -> Tuple[float, int]:
    rbytes = _type_bytes(ins.type_str)
    if opcode.endswith("-start"):
        opcode = opcode[:-6]
    gs = _group_size(ins.rest)
    if gs <= 1 and opcode != "collective-permute":
        return 0.0, gs
    if opcode == "all-reduce":
        return 2.0 * (gs - 1) / gs * rbytes, gs
    if opcode == "all-gather":
        return (gs - 1) / gs * rbytes, gs
    if opcode == "reduce-scatter":
        return float((gs - 1)) * rbytes, gs
    if opcode == "all-to-all":
        return (gs - 1) / gs * rbytes, gs
    return float(rbytes), gs  # collective-permute


_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute")
_SLICE_LIKE = ("dynamic-slice", "gather")
_NO_BYTES = ("parameter", "constant", "tuple", "get-tuple-element", "bitcast",
             "after-all", "iota", "partition-id", "replica-id")


@dataclasses.dataclass
class Cost:
    flops: float = 0.0
    bytes: float = 0.0
    coll_wire: float = 0.0
    coll_by_op: Dict[str, Dict] = dataclasses.field(default_factory=dict)
    instr_count: float = 0.0

    def add(self, other: "Cost", mult: float = 1.0):
        self.flops += other.flops * mult
        self.bytes += other.bytes * mult
        self.coll_wire += other.coll_wire * mult
        self.instr_count += other.instr_count * mult
        for k, v in other.coll_by_op.items():
            agg = self.coll_by_op.setdefault(k, {"count": 0.0,
                                                 "wire_bytes": 0.0})
            agg["count"] += v["count"] * mult
            agg["wire_bytes"] += v["wire_bytes"] * mult


def _trip_count(cond: Optional[Computation], ins: Instr) -> int:
    m = _TRIP_RE.search(ins.rest)
    if m:
        return int(m.group(1))
    if cond is not None:
        consts = []
        for i2 in cond.instrs:
            if i2.opcode == "constant":
                mc = re.match(r"\s*(\d+)\s*\)", i2.rest)
                if mc:
                    consts.append(int(mc.group(1)))
            consts.extend(int(c) for c in _CONST_RE.findall(i2.rest))
        if consts:
            return max(consts)
    return 1


class HloAnalyzer:
    def __init__(self, text: str):
        self.comps = parse_hlo_module(text)
        self._memo: Dict[str, Cost] = {}
        entry = None
        for name in self.comps:
            if name.startswith("main") or ".main" in name:
                entry = name
        if entry is None and self.comps:
            # ENTRY is the last computation in XLA dumps
            entry = list(self.comps)[-1]
        self.entry = entry

    def cost(self, comp_name: Optional[str] = None) -> Cost:
        comp_name = comp_name or self.entry
        if comp_name in self._memo:
            return self._memo[comp_name]
        comp = self.comps.get(comp_name)
        total = Cost()
        if comp is None:
            return total
        self._memo[comp_name] = total  # guard (no real cycles in HLO)
        for ins in comp.instrs:
            op = ins.opcode
            if op == "while":
                body = cond = None
                mb = re.search(r"body=%?([\w.\-]+)", ins.rest)
                mc = re.search(r"condition=%?([\w.\-]+)", ins.rest)
                if mb:
                    body = mb.group(1)
                if mc:
                    cond = self.comps.get(mc.group(1))
                trips = _trip_count(cond, ins)
                if body:
                    total.add(self.cost(body), mult=trips)
                continue
            if op in ("call", "async-start"):
                mt = re.search(r"to_apply=%?([\w.\-]+)", ins.rest)
                if mt:
                    total.add(self.cost(mt.group(1)))
                continue
            if op == "conditional":
                mt = re.search(r"branch_computations=\{([^}]*)\}", ins.rest)
                if mt:
                    branches = [b.strip().lstrip("%")
                                for b in mt.group(1).split(",")]
                    costs = [self.cost(b) for b in branches]
                    if costs:
                        worst = max(costs, key=lambda c: c.flops + c.bytes)
                        total.add(worst)
                continue
            if op == "fusion":
                mt = re.search(r"calls=%?([\w.\-]+)", ins.rest)
                inner = self.cost(mt.group(1)) if mt else Cost()
                # fused dots still compute; bytes at the fusion boundary
                total.flops += inner.flops
                total.bytes += self._fusion_bytes(ins, comp.symbols,
                                                  mt.group(1) if mt else None)
                total.instr_count += 1
                continue
            total.instr_count += 1
            if op in ("dot",):
                total.flops += _dot_flops(ins, comp.symbols)
                total.bytes += self._io_bytes(ins, comp.symbols)
            elif op == "convolution":
                total.flops += _conv_flops(ins, comp.symbols)
                total.bytes += self._io_bytes(ins, comp.symbols)
            elif any(op.startswith(c) for c in _COLLECTIVES):
                wire, gs = _collective_wire(op, ins, comp.symbols)
                total.coll_wire += wire
                key = op[:-6] if op.endswith("-start") else op
                agg = total.coll_by_op.setdefault(
                    key, {"count": 0.0, "wire_bytes": 0.0})
                agg["count"] += 1
                agg["wire_bytes"] += wire
                total.bytes += self._io_bytes(ins, comp.symbols)
            elif op in _NO_BYTES or op.endswith("-done"):
                pass
            else:
                total.bytes += self._io_bytes(ins, comp.symbols)
        return total

    def _fusion_bytes(self, ins: Instr, symbols: Dict[str, str],
                      called: Optional[str]) -> float:
        """Fusion boundary bytes, aware of in-place dynamic-update-slice:
        a loop-carried stash updated through a DUS fusion costs 2x the
        update slice, not the whole buffer (XLA aliases it in place)."""
        comp = self.comps.get(called) if called else None
        if comp is None:
            return self._io_bytes(ins, symbols)
        dus = [i for i in comp.instrs if i.opcode == "dynamic-update-slice"]
        dsl = [i for i in comp.instrs
               if i.opcode in ("dynamic-slice", "gather")]
        if not dus and not dsl:
            return self._io_bytes(ins, symbols)
        defs = {i.name: i for i in comp.instrs}

        def trace_param(name):
            seen = 0
            while name in defs and seen < 20:
                d = defs[name]
                if d.opcode == "parameter":
                    m = re.match(r"\s*(\d+)\s*\)", d.rest)
                    return int(m.group(1)) if m else None
                if d.opcode in ("convert", "bitcast", "copy", "reshape"):
                    name = d.operands[0] if d.operands else None
                    seen += 1
                    continue
                return None
            return None

        skip_params = set()
        slice_bytes = 0.0
        dus_names = set()
        for d in dus:
            dus_names.add(d.name)
            if len(d.operands) > 1:
                slice_bytes += 2.0 * _type_bytes(
                    comp.symbols.get(d.operands[1], ""))
            pi = trace_param(d.operands[0]) if d.operands else None
            if pi is not None:
                skip_params.add(pi)
        for d in dsl:  # reads of one slice of a big (stacked) buffer
            slice_bytes += _type_bytes(d.type_str)
            pi = trace_param(d.operands[0]) if d.operands else None
            if pi is not None:
                skip_params.add(pi)
        # root derived from a DUS (possibly via convert/bitcast/tuple)?
        root = comp.instrs[-1] if comp.instrs else None
        out_bytes = _type_bytes(ins.type_str)

        def derives_from_dus(name, depth=0):
            if name in dus_names:
                return True
            d = defs.get(name)
            if d is None or depth > 20:
                return False
            if d.opcode in ("convert", "bitcast", "copy", "reshape", "tuple"):
                return any(derives_from_dus(o, depth + 1) for o in d.operands)
            return False

        if root is not None and derives_from_dus(root.name):
            out_bytes = 0.0
        op_bytes = 0.0
        for idx, o in enumerate(ins.operands):
            if idx in skip_params:
                continue
            op_bytes += _type_bytes(symbols.get(o, ""))
        return out_bytes + op_bytes + slice_bytes

    def _io_bytes(self, ins: Instr, symbols: Dict[str, str]) -> float:
        out_b = _type_bytes(ins.type_str)
        if ins.opcode in _SLICE_LIKE:
            return 2.0 * out_b              # read slice + write result
        if ins.opcode == "dynamic-update-slice":
            upd = symbols.get(ins.operands[1], "") if len(ins.operands) > 1 \
                else ""
            return 2.0 * _type_bytes(upd)   # read update + write region
        if ins.opcode == "scatter":
            upd = symbols.get(ins.operands[-1], "") if ins.operands else ""
            return 2.0 * _type_bytes(upd) + out_b
        op_b = sum(_type_bytes(symbols.get(o, "")) for o in ins.operands)
        return out_b + op_b


def analyze(text: str) -> Dict:
    an = HloAnalyzer(text)
    c = an.cost()
    return {
        "flops": c.flops,
        "bytes": c.bytes,
        "coll_wire_bytes": c.coll_wire,
        "collectives": c.coll_by_op,
        "instr_count": c.instr_count,
    }


def score_matcher(sq: int, blk: int, min_rank: int = 3):
    """Matches attention-score-shaped results: last two dims are
    (m·sq_shard, blk) or (blk, m·sq_shard) for any seq shard (sq or
    sq/2^i) possibly merged with head dims by XLA reshapes."""
    shards = {sq // (1 << i) for i in range(6) if sq % (1 << i) == 0}

    def is_seqish(d):
        return any(d % s == 0 for s in shards if s >= blk // 2 and s > 1)

    def match(dims):
        if len(dims) < min_rank:
            return False
        a, b = dims[-2], dims[-1]
        return ((b == blk and is_seqish(a))
                or (a == blk and is_seqish(b)))
    return match


def chunk_matcher(q: int, min_rank: int = 3):
    """Matches SSD (Q, Q) intra-chunk matrices in any layout: some
    adjacent dim pair is (Q, Q) or (Q, m·Q) — covers (..., Q, Q, H),
    (H, Q, Q) and head-merged (Q, H·Q) variants."""
    def match(dims):
        if len(dims) < min_rank:
            return False
        for a, b in zip(dims[:-1], dims[1:]):
            if (a == q and b % q == 0) or (b == q and a % q == 0):
                return True
        return False
    return match


def pattern_traffic(text: str, match_fn):
    """Measured bytes + dot-flops of instructions whose result shape
    satisfies ``match_fn(dims)``, with while-loop multipliers.

    Used by the kernel-adjusted roofline (§Perf): a Pallas flash/SSD
    kernel keeps these tiles in VMEM, so their HBM traffic is removed and
    causally-skippable score flops are halved.  The numbers subtracted are
    *measured from the same compiled HLO*, not estimated.
    """
    an = HloAnalyzer(text)
    mult = _loop_multipliers(an)
    bytes_total = 0.0
    dot_flops = 0.0
    for cname, m in mult.items():
        comp = an.comps.get(cname)
        if comp is None:
            continue
        for ins in comp.instrs:
            if ins.opcode in _NO_BYTES or ins.opcode == "while":
                continue
            ms = list(_SHAPE_RE.finditer(ins.type_str))
            if not ms:
                continue
            dims_s = ms[0].group(2)
            dims = [int(d) for d in dims_s.split(",")] if dims_s else []
            if not match_fn(dims):
                continue
            if ins.opcode == "fusion":
                mf = re.search(r"calls=%?([\w.\-]+)", ins.rest)
                b = an._fusion_bytes(ins, comp.symbols,
                                     mf.group(1) if mf else None)
            else:
                b = an._io_bytes(ins, comp.symbols)
            bytes_total += b * m
            if ins.opcode == "dot":
                dot_flops += _dot_flops(ins, comp.symbols) * m
    return {"bytes": bytes_total, "dot_flops": dot_flops}


def _loop_multipliers(an: "HloAnalyzer"):
    mult = {an.entry: 1.0}
    order = [an.entry]
    i = 0
    while i < len(order):
        cname = order[i]
        i += 1
        comp = an.comps.get(cname)
        if comp is None:
            continue
        m = mult[cname]
        for ins in comp.instrs:
            if ins.opcode != "while":
                continue
            mm = re.search(r"body=%?([\w.\-]+)", ins.rest)
            if not mm:
                continue
            mc = re.search(r"condition=%?([\w.\-]+)", ins.rest)
            cond = an.comps.get(mc.group(1)) if mc else None
            trips = _trip_count(cond, ins)
            cm = m * trips
            if mult.get(mm.group(1), 0) < cm:
                mult[mm.group(1)] = cm
                order.append(mm.group(1))
    return mult


def top_instructions(text: str, n: int = 25, key: str = "bytes"):
    """Profiler view: instructions ranked by bytes (or flops) including the
    loop multiplier of every enclosing while.  This is the dry-run analogue
    of a wall-clock profile (see system prompt: reason from lowered IR)."""
    an = HloAnalyzer(text)
    mult = _loop_multipliers(an)
    rows = []
    for cname, m in mult.items():
        comp = an.comps.get(cname)
        if comp is None:
            continue
        for ins in comp.instrs:
            if ins.opcode in _NO_BYTES or ins.opcode == "while":
                continue
            if ins.opcode == "fusion":
                mf = re.search(r"calls=%?([\w.\-]+)", ins.rest)
                b = an._fusion_bytes(ins, comp.symbols,
                                     mf.group(1) if mf else None)
            else:
                b = an._io_bytes(ins, comp.symbols)
            f = _dot_flops(ins, comp.symbols) if ins.opcode == "dot" else 0.0
            rows.append({"comp": cname, "instr": ins.name, "op": ins.opcode,
                         "mult": m, "bytes": b * m, "flops": f * m,
                         "type": ins.type_str[:80]})
    rows.sort(key=lambda r: -r[key])
    return rows[:n]
