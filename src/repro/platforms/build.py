"""Backend adapters: one Platform spec -> either simulation stack.

``build_des`` materializes the discrete-event stack (NodeModel +
Topology + SimMPI knobs); ``build_fastsim`` derives the vectorized
simulator's FastSimParams from the same spec, so the two fidelities are
guaranteed to describe the same machine.  fastsim (and therefore jax) is
imported lazily — the DES path stays importable without it.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Optional

from repro.core.hardware.node import NodeModel
from repro.core.hardware.topology import (Dragonfly, FatTreeTwoLevel,
                                          MultiPod, Topology, Torus)

from .spec import FabricSpec, NodeSpec, Platform


@dataclasses.dataclass(frozen=True)
class DESStack:
    """Everything HPLSim needs: the hardware pair plus MPI-stack knobs.
    ``trace`` asks the consuming sim to attach a TraceRecorder."""
    node: NodeModel
    topology: Topology
    ranks_per_node: int = 1
    mpi_overhead: float = 5e-7
    trace: bool = False

    def __iter__(self):
        return iter((self.node, self.topology, self.ranks_per_node,
                     self.mpi_overhead))


def build_node(spec: NodeSpec) -> NodeModel:
    from repro.core.hardware.node import node_from_spec
    return node_from_spec(spec)


def build_topology(fab: FabricSpec, n_nodes: int) -> Topology:
    if fab.kind == "fat-tree":
        if fab.nodes_per_edge <= 0 or fab.n_core <= 0:
            raise ValueError("fat-tree fabric needs nodes_per_edge and "
                             "n_core")
        return FatTreeTwoLevel(n_nodes, fab.nodes_per_edge, fab.n_core,
                               link_bw=fab.link_bw,
                               hop_latency=fab.hop_latency,
                               uplink_bw=fab.uplink_bw,
                               base_latency=fab.base_latency)
    if fab.kind == "dragonfly":
        cap = fab.n_groups * fab.routers_per_group * fab.nodes_per_router
        if cap < n_nodes:
            raise ValueError(f"dragonfly {fab.n_groups}x"
                             f"{fab.routers_per_group}x"
                             f"{fab.nodes_per_router} holds {cap} nodes "
                             f"< {n_nodes}")
        return Dragonfly(fab.n_groups, fab.routers_per_group,
                         fab.nodes_per_router, link_bw=fab.link_bw,
                         global_bw=fab.global_bw,
                         hop_latency=fab.hop_latency,
                         nonminimal=fab.nonminimal,
                         base_latency=fab.base_latency)
    if fab.kind == "torus":
        if math.prod(fab.dims) < n_nodes:
            raise ValueError(f"torus {fab.dims} holds {math.prod(fab.dims)} "
                             f"nodes < {n_nodes}")
        return Torus(fab.dims, link_bw=fab.link_bw,
                     hop_latency=fab.hop_latency,
                     base_latency=fab.base_latency)
    if fab.kind == "multipod":
        pod_size = math.prod(fab.dims)
        if fab.n_pods <= 0 or pod_size <= 0:
            raise ValueError("multipod fabric needs n_pods and pod dims")
        pods = [Torus(fab.dims, link_bw=fab.link_bw,
                      hop_latency=fab.hop_latency,
                      base_latency=fab.base_latency)
                for _ in range(fab.n_pods)]
        return MultiPod(pods, pod_size, dcn_bw_per_node=fab.dcn_bw_per_node,
                        dcn_latency=fab.dcn_latency)
    raise ValueError(f"unknown fabric kind {fab.kind!r}")


def build_des(platform: Platform, *, trace: bool = False) -> DESStack:
    return DESStack(node=build_node(platform.node),
                    topology=build_topology(platform.fabric,
                                            platform.scale.n_nodes),
                    ranks_per_node=platform.scale.ranks_per_node,
                    mpi_overhead=platform.mpi.overhead,
                    trace=trace)


def derived_net_latency(platform: Platform) -> float:
    """Effective small-message latency when the spec doesn't pin one:
    software overhead + fabric base latency + a typical 2-hop traversal
    (what a DES message actually pays end to end)."""
    fab = platform.fabric
    return platform.mpi.overhead + fab.base_latency + 2.0 * fab.hop_latency


def build_ici(platform: Platform, **overrides):
    """ICI parameters (the TPU-world analytic network section) derived
    from the same spec that builds the DES topology — the third backend
    adapter next to ``build_des``/``build_fastsim``.  Keyword overrides
    win over the spec-derived values."""
    # simxla is jax-free but lives in core; resolve lazily so this
    # module stays importable from either side of the package boundary
    from repro.core.simxla import ici_from_platform
    return ici_from_platform(platform, **overrides)


def build_fastsim(platform: Platform, *, calibrated: bool = True):
    from repro.core.fastsim import FastSimParams

    net_latency = platform.mpi.net_latency
    if net_latency is None:
        net_latency = derived_net_latency(platform)
    prm = FastSimParams.from_node(
        build_node(platform.node), link_bw=platform.fabric.link_bw,
        ranks_per_node=platform.scale.ranks_per_node,
        net_latency=net_latency, hop_latency=platform.fabric.hop_latency)
    if calibrated and platform.calibration:
        prm = dataclasses.replace(prm, **platform.calibration_dict)
    return prm
