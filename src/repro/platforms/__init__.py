"""Platform layer: declarative machine specs driving both simulation
backends (DESIGN.md §12).

    from repro.platforms import get_platform
    plat = get_platform("frontera")
    node, topo, rpn, overhead = plat.des()     # discrete-event stack
    prm = plat.fastsim()                       # vectorized fastsim params
    cfg = plat.hpl_config()                    # the machine's Rmax run

Bridge utilities (``fit_fastsim_to_des``) are exposed lazily so the DES
path never drags in jax through this package's import.
"""
from .spec import (FabricSpec, MPIStackSpec, NodeSpec, Platform,
                   ScaleSpec)
from .registry import (add_invalidation_hook, bulk_register,
                       get_platform, list_platforms, register, unregister)
from .build import DESStack, build_des, build_fastsim, build_ici, \
    build_node, build_topology

__all__ = ["FabricSpec", "MPIStackSpec", "NodeSpec", "Platform",
           "ScaleSpec", "get_platform", "list_platforms", "register",
           "bulk_register", "unregister", "add_invalidation_hook",
           "DESStack", "build_des", "build_fastsim", "build_ici",
           "build_node", "build_topology", "fit_fastsim_to_des", "des_probe_runs",
           "BridgeFit"]

_BRIDGE_NAMES = ("fit_fastsim_to_des", "des_probe_runs", "BridgeFit",
                 "DEFAULT_PROBES", "DEFAULT_FIT_FIELDS")


def __getattr__(name):
    # bridge imports apps.hpl + calibrate; resolve lazily to keep this
    # package importable from inside core's own import chain
    if name in _BRIDGE_NAMES:
        from . import bridge
        return getattr(bridge, name)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
