"""Declarative machine specifications — the single source of platform truth.

The paper predicts full-system performance from "an abstract yet
high-fidelity model" of the platform; Cornebize & Legrand (2102.07674)
show that *calibration quality* dominates prediction accuracy, and
Mohammed et al. (1910.06844) argue for one machine description driving
multiple simulation backends.  This module is that description: a
``Platform`` bundles four sections —

  * ``NodeSpec``   — the processing element (peak flops, memory system,
    BLAS dispatch overheads, optional accelerator section),
  * ``FabricSpec`` — the interconnect (fat-tree / dragonfly / torus /
    multipod geometry, link bandwidths, hop latencies),
  * ``MPIStackSpec`` — the software stack (per-call overhead, effective
    small-message latency, default HPL broadcast algorithm),
  * ``ScaleSpec``  — deployment scale (node count, ranks per node, the
    machine's published HPL run geometry and TOP500 numbers),

plus an optional ``calibration`` table of DES-fitted fastsim overrides
(see platforms/bridge.py).  Specs are frozen, hashable, and round-trip
through ``to_dict``/``from_dict`` (JSON-safe), so a registry machine can
be shipped, diffed, and versioned as data.

Backends are built lazily: ``platform.des()`` returns the discrete-event
stack (NodeModel, Topology, ranks-per-node, SimMPI knobs) and
``platform.fastsim()`` the vectorized simulator's ``FastSimParams`` —
both via platforms/build.py, so this module stays import-light.
"""
from __future__ import annotations

import dataclasses
import json
import math
from typing import Any, Dict, Optional, Tuple

FABRIC_KINDS = ("fat-tree", "dragonfly", "torus", "multipod")


@dataclasses.dataclass(frozen=True)
class NodeSpec:
    """One node: the paper's §III-A1 processing-element model as data."""
    name: str
    peak_flops: float            # node peak, FLOP/s (sustained AVX/MXU clock)
    mem_bw: float                # B/s
    cores: int = 1
    gemm_efficiency: float = 0.92
    mem_efficiency: float = 0.80
    blas_latency: float = 2e-7   # theta: per-BLAS-call overhead (s)
    hbm_bytes: float = 0.0       # per-node memory capacity (sizes HPL N)
    # accelerator section (paper's CPU-GPGPU heterogeneous extension)
    accel_peak_flops: float = 0.0
    accel_mem_bw: float = 0.0
    accel_efficiency: float = 0.75

    @classmethod
    def xeon(cls, name: str, sockets: int, cores_per_socket: int,
             sustained_clock_ghz: float, flops_per_cycle: int = 32,
             ddr_gbs: float = 100.0, **kw) -> "NodeSpec":
        """Xeon-style derivation: peak = cores x flops/cycle x clock."""
        cores = sockets * cores_per_socket
        return cls(name=name,
                   peak_flops=cores * flops_per_cycle
                   * sustained_clock_ghz * 1e9,
                   mem_bw=ddr_gbs * 1e9, cores=cores, **kw)


@dataclasses.dataclass(frozen=True)
class FabricSpec:
    """The interconnect: one of FABRIC_KINDS plus its geometry knobs.

    ``link_bw`` is the per-node injection bandwidth in B/s; geometry
    fields are kind-specific and ignored by the other kinds.
    """
    kind: str
    link_bw: float
    hop_latency: float = 90e-9
    base_latency: float = 1e-6
    # fat-tree (two-level, D-mod-K)
    nodes_per_edge: int = 0
    n_core: int = 0
    uplink_bw: Optional[float] = None
    # dragonfly (g groups x a routers x p nodes)
    n_groups: int = 0
    routers_per_group: int = 0
    nodes_per_router: int = 0
    global_bw: Optional[float] = None
    nonminimal: bool = False
    # torus (TPU ICI)
    dims: Tuple[int, ...] = ()
    # multipod (pods of `dims`-torus joined by a DCN)
    n_pods: int = 0
    dcn_bw_per_node: float = 25e9
    dcn_latency: float = 10e-6

    def __post_init__(self):
        if self.kind not in FABRIC_KINDS:
            raise ValueError(f"fabric kind {self.kind!r} not in "
                             f"{FABRIC_KINDS}")


@dataclasses.dataclass(frozen=True)
class MPIStackSpec:
    """MPI software stack: what SimMPI / fastsim need beyond the wire."""
    overhead: float = 5e-7           # per-call software overhead (s)
    net_latency: Optional[float] = None  # end-to-end small-msg latency;
    #                                  None -> derived from the fabric
    bcast: str = "1ring"             # default HPL panel-broadcast variant


@dataclasses.dataclass(frozen=True)
class ScaleSpec:
    """Deployment scale and the machine's published HPL geometry."""
    n_nodes: int
    ranks_per_node: int = 1
    grid: Tuple[int, int] = (0, 0)   # published / default (P, Q)
    hpl_n: int = 0                   # published / memory-sized Nmax
    hpl_nb: int = 384
    reported_tflops: float = 0.0     # TOP500 Rmax (0 = not a real entry)
    paper_pred_tflops: float = 0.0   # the paper's own prediction, if any

    @property
    def n_ranks(self) -> int:
        return self.n_nodes * self.ranks_per_node


@dataclasses.dataclass(frozen=True)
class Platform:
    """A complete machine description; the only place machine constants
    are allowed to live (everything else goes through the registry)."""
    name: str
    node: NodeSpec
    fabric: FabricSpec
    mpi: MPIStackSpec = MPIStackSpec()
    scale: ScaleSpec = ScaleSpec(n_nodes=1)
    # DES-fitted FastSimParams overrides, e.g. (("bcast_bw_scale", 0.9),)
    calibration: Tuple[Tuple[str, float], ...] = ()
    # per-scale contention overrides fitted from region-DES probes
    # (repro.scale): ((ranks, (("bcast_bw_scale", 0.8), ...)), ...);
    # ``fastsim(at_ranks=...)`` applies the nearest (log-space) entry on
    # top of ``calibration``
    contention: Tuple[Tuple[int, Tuple[Tuple[str, float], ...]], ...] = ()
    # inference audit trail for generated specs (top500 ingestion): each
    # entry is a (key, value) string pair, e.g. ("cpu_family", "xeon-avx512")
    # or ("peak_source", "rpeak-rescaled"); empty for hand-written specs
    provenance: Tuple[Tuple[str, str], ...] = ()
    notes: str = ""

    # ------------------------------------------------------ backends
    def des(self, trace: bool = False):
        """Build the discrete-event stack: a DESStack of
        (node, topology, ranks_per_node, mpi_overhead).  ``trace=True``
        marks the stack so HPLSim attaches a TraceRecorder."""
        from .build import build_des
        return build_des(self, trace=trace)

    def fastsim(self, *, calibrated: bool = True,
                at_ranks: Optional[int] = None):
        """Build FastSimParams (with ``calibration`` overrides applied
        unless ``calibrated=False``).  ``at_ranks`` additionally applies
        the nearest per-scale ``contention`` entry (log-space distance),
        so predictions at 10^4 ranks use scales fitted at 10^4 ranks."""
        from .build import build_fastsim
        params = build_fastsim(self, calibrated=calibrated)
        if at_ranks is not None and calibrated:
            over = self.contention_for(at_ranks)
            if over:
                params = dataclasses.replace(params, **over)
        return params

    def contention_for(self, at_ranks: int) -> Dict[str, float]:
        """The contention entry nearest ``at_ranks`` in log-space
        ({} when the table is empty)."""
        if not self.contention or at_ranks < 1:
            return {}
        ranks, over = min(
            self.contention,
            key=lambda e: abs(math.log(max(e[0], 1)) - math.log(at_ranks)))
        return dict(over)

    def node_model(self):
        from .build import build_node
        return build_node(self.node)

    def ici(self, **overrides):
        """ICI parameters (``repro.core.simxla.ICIParams``) derived from
        the fabric/MPI sections — the analytic-network backend adapter."""
        from .build import build_ici
        return build_ici(self, **overrides)

    def topology(self):
        from .build import build_topology
        return build_topology(self.fabric, self.scale.n_nodes)

    def hpl_config(self, N: Optional[int] = None, nb: Optional[int] = None,
                   P: Optional[int] = None, Q: Optional[int] = None, **kw):
        """The machine's published HPL run (overridable per field)."""
        from repro.core.apps.hpl import HPLConfig
        gp, gq = self.scale.grid
        P = P if P is not None else gp
        Q = Q if Q is not None else gq
        if P <= 0 or Q <= 0:
            raise ValueError(f"platform {self.name!r} has no default grid; "
                             "pass P and Q explicitly")
        N = N if N is not None else self.scale.hpl_n
        if N <= 0:
            raise ValueError(f"platform {self.name!r} has no default N; "
                             "pass N explicitly")
        kw.setdefault("bcast", self.mpi.bcast)
        return HPLConfig(N=N, nb=nb if nb is not None else self.scale.hpl_nb,
                         P=P, Q=Q, **kw)

    @property
    def calibration_dict(self) -> Dict[str, float]:
        return dict(self.calibration)

    @property
    def provenance_dict(self) -> Dict[str, str]:
        return dict(self.provenance)

    def with_calibration(self, overrides: Dict[str, float]) -> "Platform":
        """A copy with ``overrides`` merged into the calibration table."""
        merged = dict(self.calibration)
        merged.update(overrides)
        return dataclasses.replace(
            self, calibration=tuple(sorted(merged.items())))

    @property
    def contention_dict(self) -> Dict[int, Dict[str, float]]:
        return {r: dict(over) for r, over in self.contention}

    def with_contention(self, at_ranks: int, overrides: Dict[str, float],
                        note: str = "") -> "Platform":
        """A copy with ``overrides`` merged into the per-scale contention
        entry for ``at_ranks``; a non-empty ``note`` records the fit's
        provenance (region geometry, probe count) under
        ``contention@<ranks>``."""
        at_ranks = int(at_ranks)
        table = self.contention_dict
        entry = table.setdefault(at_ranks, {})
        entry.update(overrides)
        cont = tuple(sorted(
            (r, tuple(sorted(over.items()))) for r, over in table.items()))
        prov = self.provenance
        if note:
            key = f"contention@{at_ranks}"
            prov = tuple(kv for kv in prov if kv[0] != key) + ((key, note),)
        return dataclasses.replace(self, contention=cont, provenance=prov)

    # -------------------------------------------------- serialization
    def to_dict(self) -> Dict[str, Any]:
        d = dataclasses.asdict(self)
        d["fabric"]["dims"] = list(self.fabric.dims)
        d["scale"]["grid"] = list(self.scale.grid)
        d["calibration"] = [list(kv) for kv in self.calibration]
        d["contention"] = [[r, [list(kv) for kv in over]]
                           for r, over in self.contention]
        d["provenance"] = [list(kv) for kv in self.provenance]
        return d

    def to_json(self, **kw) -> str:
        return json.dumps(self.to_dict(), **kw)

    @classmethod
    def from_dict(cls, d: Dict[str, Any]) -> "Platform":
        fab = dict(d["fabric"])
        fab["dims"] = tuple(fab.get("dims") or ())
        sc = dict(d["scale"])
        sc["grid"] = tuple(sc.get("grid") or (0, 0))
        return cls(name=d["name"],
                   node=NodeSpec(**d["node"]),
                   fabric=FabricSpec(**fab),
                   mpi=MPIStackSpec(**d.get("mpi", {})),
                   scale=ScaleSpec(**sc),
                   calibration=tuple((k, float(v))
                                     for k, v in d.get("calibration", [])),
                   contention=tuple(
                       (int(r), tuple((k, float(v)) for k, v in over))
                       for r, over in d.get("contention", [])),
                   provenance=tuple((k, str(v))
                                    for k, v in d.get("provenance", [])),
                   notes=d.get("notes", ""))

    @classmethod
    def from_json(cls, s: str) -> "Platform":
        return cls.from_dict(json.loads(s))
