"""DES -> fastsim calibration bridge.

The two backends describe the same machine at different fidelities: the
DES resolves per-message contention on the real topology, while fastsim
folds contention into per-phase bandwidth scales (``bcast_bw_scale``,
``swap_bw_scale``).  This module closes the loop the way Cornebize &
Legrand close it against real machines — treat the higher-fidelity
simulator as the measurement, and gradient-fit the fast model to it:

    fit = fit_fastsim_to_des(get_platform("frontera"))
    fit.platform                 # spec with DES-consistent calibration

``fit_fastsim_params`` differentiates the entire HPL panel recurrence
with respect to the fitted fields (DESIGN.md §11), so a handful of small
DES probe runs is enough to pin the contention scales; the fitted values
are baked into the spec's ``calibration`` table so every registry
machine can ship DES-consistent fastsim params.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Sequence, Tuple

from .spec import Platform

# Small probe grids: big enough that broadcast/swap terms are visible,
# small enough that the DES runs in seconds.  (N, nb, P, Q).
DEFAULT_PROBES: Tuple[Tuple[int, int, int, int], ...] = (
    (1536, 128, 2, 2),
    (2048, 128, 2, 4),
    (2048, 128, 4, 4),
)

DEFAULT_FIT_FIELDS: Tuple[str, ...] = ("bcast_bw_scale", "swap_bw_scale")


@dataclasses.dataclass
class BridgeFit:
    platform: Platform               # spec with fitted calibration baked in
    fit: object                      # the underlying calibrate.FastSimFit
    probes: List[Tuple[object, float]]   # (HPLConfig, DES seconds)
    fields: Tuple[str, ...]

    @property
    def calibration(self) -> dict:
        return {f: float(getattr(self.fit.params, f)) for f in self.fields}


def des_probe_runs(platform: Platform,
                   probe_configs: Optional[Sequence] = None, *,
                   regions=None) -> List[Tuple[object, float]]:
    """Run the DES on small probe configs; returns (cfg, seconds) pairs.

    Probes use ``lookahead=0`` (the DES models the non-overlapped
    schedule) and are clipped to the platform's rank capacity.  With
    ``regions`` set (an int or ``repro.scale.RegionSpec``) each probe is
    a representative-region run — only the region's panels are simulated
    exactly — which is what makes 10^4+-rank probes affordable.
    """
    from repro.core.apps.hpl import HPLConfig, HPLSim

    if probe_configs is None:
        cap = platform.scale.n_ranks
        probe_configs = [HPLConfig(N=n, nb=nb, P=p, Q=q, lookahead=0,
                                   bcast=platform.mpi.bcast)
                         for n, nb, p, q in DEFAULT_PROBES if p * q <= cap]
    if not probe_configs:
        raise ValueError(f"platform {platform.name!r}: no probe config "
                         "fits its rank capacity")
    runs = []
    for cfg in probe_configs:
        if regions is None:
            res = HPLSim(cfg, platform).run()
        else:
            from repro.scale import RegionHPLSim
            res = RegionHPLSim(cfg, platform, region=regions).run()
        runs.append((cfg, res.time_s))
    return runs


def fit_fastsim_to_des(platform: Platform,
                       probe_configs: Optional[Sequence] = None,
                       fields: Sequence[str] = DEFAULT_FIT_FIELDS,
                       steps: int = 60, lr: float = 0.1,
                       regions=None) -> BridgeFit:
    """Gradient-fit fastsim's contention scales to DES probe runs.

    Returns a BridgeFit whose ``platform`` carries the fitted values in
    its calibration table — ``platform.fastsim()`` is then
    DES-consistent at probe scale while the compute side of the spec
    stays untouched (only ``fields`` move).  ``regions`` switches the
    probes to representative-region runs (``repro.scale``), unlocking
    probe grids at 10^4+ ranks; per-scale fits should go through
    ``repro.scale.fit_contention_at_scale``, which stores the result in
    the spec's ``contention`` table instead of the global calibration.
    """
    from repro.core.calibrate import fit_fastsim_params

    runs = des_probe_runs(platform, probe_configs, regions=regions)
    init = dataclasses.replace(platform.fastsim(calibrated=False),
                               lookahead=0.0)
    fit = fit_fastsim_params(runs, init, fields=tuple(fields),
                             steps=steps, lr=lr)
    calibration = {f: float(getattr(fit.params, f)) for f in fields}
    return BridgeFit(platform=platform.with_calibration(calibration),
                     fit=fit, probes=runs, fields=tuple(fields))
