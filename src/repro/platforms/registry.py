"""Named platform registry — every machine the framework can predict.

Real systems from the paper (Table I/II, Fig 7) and the TPU adaptation
target, plus synthetic TOP500-class entries spanning all three fabric
families (fat-tree / dragonfly / torus) so scenario sweeps have scale
diversity to chew on.  All machine constants — peaks, bandwidths, grid
shapes, published Rmax numbers — live HERE and nowhere else; call sites
go through ``get_platform(name)``.

Synthetic entries are loosely modeled on public TOP500-class systems
(Cascade Lake + EDR, Sapphire Rapids + HDR, Aries and Slingshot
dragonflies, A64FX and BG/Q tori, an A100 fat-tree, a 2-pod TPU DCN rig)
but are NOT measurements of those machines — they are plausible spec
points for what-if studies.
"""
from __future__ import annotations

import dataclasses
import difflib
from typing import Callable, Dict, Iterable, List, Sequence

from .spec import FabricSpec, MPIStackSpec, NodeSpec, Platform, ScaleSpec

_REGISTRY: Dict[str, Platform] = {}

#: callbacks fired with a platform *name* whenever that name's binding
#: changes (overwrite re-registration or unregistration) — the serving
#: layer's result caches subscribe here to drop entries derived from
#: the name (repro.serve.cache; layering stays serve -> platforms)
_INVALIDATION_HOOKS: List[Callable[[str], None]] = []


def add_invalidation_hook(fn: Callable[[str], None]) -> None:
    """Subscribe to name-rebinding events; ``fn(name)`` is called after
    an existing registration is overwritten or removed (idempotent —
    the same callable is only installed once)."""
    if fn not in _INVALIDATION_HOOKS:
        _INVALIDATION_HOOKS.append(fn)


def _notify_rebound(name: str) -> None:
    for fn in list(_INVALIDATION_HOOKS):
        fn(name)


def register(platform: Platform, *, overwrite: bool = False) -> Platform:
    if not overwrite and platform.name in _REGISTRY:
        raise ValueError(f"platform {platform.name!r} already registered")
    rebound = platform.name in _REGISTRY
    _REGISTRY[platform.name] = platform
    if rebound:
        _notify_rebound(platform.name)
    return platform


def bulk_register(platforms: Iterable[Platform], *, namespace: str,
                  overwrite: bool = False) -> List[Platform]:
    """Register a generated list under ``namespace/`` so ingested specs
    (e.g. a whole TOP500 list) can never collide with built-in names.

    Each platform is re-named ``f"{namespace}/{platform.name}"``.  The
    batch is validated up front — a duplicate inside the batch or a
    collision with an already-registered name raises before anything is
    registered (all-or-nothing), unless ``overwrite=True``.  Returns the
    renamed platforms in input order.
    """
    if not namespace or "/" in namespace:
        raise ValueError(f"bulk_register: namespace {namespace!r} must be "
                         "a non-empty string without '/'")
    renamed = [dataclasses.replace(p, name=f"{namespace}/{p.name}")
               for p in platforms]
    seen: Dict[str, int] = {}
    for p in renamed:
        if p.name in seen:
            raise ValueError(f"bulk_register: duplicate name {p.name!r} "
                             "inside the batch")
        seen[p.name] = 1
        if not overwrite and p.name in _REGISTRY:
            raise ValueError(f"bulk_register: {p.name!r} already "
                             "registered (pass overwrite=True to replace)")
    for p in renamed:
        rebound = p.name in _REGISTRY
        _REGISTRY[p.name] = p
        if rebound:
            _notify_rebound(p.name)
    return renamed


def unregister(names: Sequence[str]) -> None:
    """Remove registered names (missing ones are ignored) — the cleanup
    companion to ``bulk_register`` for tests and re-ingestion."""
    for name in names:
        if _REGISTRY.pop(name, None) is not None:
            _notify_rebound(name)


def get_platform(name: str) -> Platform:
    try:
        return _REGISTRY[name]
    except KeyError:
        close = difflib.get_close_matches(name, _REGISTRY, n=3, cutoff=0.5)
        hint = (f"did you mean: {', '.join(close)}?" if close
                else "no close match")
        raise KeyError(f"unknown platform {name!r}; {hint} "
                       f"({len(_REGISTRY)} platforms registered; "
                       "see list_platforms())") from None


def list_platforms() -> List[str]:
    return sorted(_REGISTRY)


# --------------------------------------------------------------- nodes

# Paper Table I: 2x Xeon E5-2699 v4 Broadwell 22c @2.2 GHz nominal;
# AVX2 (16 DP flops/cyc) sustains ~1.8 GHz; DDR4-2400 x 4ch x 2.
_BDW_NODE = NodeSpec.xeon("bdw-2699v4", 2, 22, 1.8, flops_per_cycle=16,
                          ddr_gbs=153.6, hbm_bytes=256e9)

# Frontera: 2x Xeon Platinum 8280 28c; AVX-512 sustains ~1.8 GHz (paper:
# the nominal 2.7 GHz cannot be held under AVX-512); DDR4-2933 x 6ch x 2.
_CLX_NODE = NodeSpec.xeon("clx-8280", 2, 28, 1.8, flops_per_cycle=32,
                          ddr_gbs=2 * 6 * 23.46, hbm_bytes=192e9)

# PupMaya: 2x Xeon Gold 6148 20c; AVX-512 sustains ~1.6 GHz; DDR4-2666.
_SKX_NODE = NodeSpec.xeon("skx-6148", 2, 20, 1.6, flops_per_cycle=32,
                          ddr_gbs=2 * 6 * 21.3, hbm_bytes=192e9)

# TPU v5e: 197 TF bf16, 819 GB/s HBM, 16 GB per chip; 2 us dispatch.
_V5E_NODE = NodeSpec(name="tpu-v5e", peak_flops=197e12, mem_bw=819e9,
                     cores=1, gemm_efficiency=0.90, mem_efficiency=0.85,
                     blas_latency=2e-6, hbm_bytes=16e9)


# ------------------------------------------------- paper / real systems

register(Platform(
    name="bdw-local",
    node=_BDW_NODE,
    fabric=FabricSpec(kind="fat-tree", link_bw=100e9 / 8, nodes_per_edge=4,
                      n_core=2),
    mpi=MPIStackSpec(net_latency=2e-6),
    scale=ScaleSpec(n_nodes=16, grid=(4, 4), hpl_n=4096, hpl_nb=128),
    notes="Paper Table I local validation machine, as a 16-node cell."))

register(Platform(
    name="frontera",
    node=_CLX_NODE,
    # 8,008 nodes on HDR100 (pairs into HDR200 leaf ports): ~182 leaf
    # switches x 44 nodes, 6 core switches, 18 HDR200 uplinks / 6 cores.
    fabric=FabricSpec(kind="fat-tree", link_bw=100e9 / 8, hop_latency=90e-9,
                      nodes_per_edge=44, n_core=6,
                      uplink_bw=200e9 / 8 * 3),
    mpi=MPIStackSpec(net_latency=2e-6),
    scale=ScaleSpec(n_nodes=8008, grid=(88, 91), hpl_n=9_282_848,
                    hpl_nb=384, reported_tflops=23516,
                    paper_pred_tflops=22566),
    notes="TOP500 #5 (paper Table II); paper SystemC sim wall 4.8 h."))

register(Platform(
    name="pupmaya",
    node=_SKX_NODE,
    fabric=FabricSpec(kind="fat-tree", link_bw=100e9 / 8, hop_latency=90e-9,
                      nodes_per_edge=32, n_core=8),
    mpi=MPIStackSpec(net_latency=2e-6),
    scale=ScaleSpec(n_nodes=4248, grid=(59, 72), hpl_n=4_748_928,
                    hpl_nb=384, reported_tflops=7484,
                    paper_pred_tflops=7558),
    notes="TOP500 #25 (paper Table II); paper SystemC sim wall 1.7 h."))

register(Platform(
    name="paper-fat-tree-10008",
    node=_CLX_NODE,
    # The paper's Fig 7 scalability rig: 10,008 nodes, 556 36-port edge
    # switches (18 down / 18 up), 18 core switches.
    fabric=FabricSpec(kind="fat-tree", link_bw=100e9 / 8,
                      nodes_per_edge=18, n_core=18),
    mpi=MPIStackSpec(net_latency=2e-6),
    scale=ScaleSpec(n_nodes=10008, grid=(72, 139), hpl_n=20_000_000),
    notes="Paper Fig 7 10,008-node scalability rig (21.8 h SystemC)."))

register(Platform(
    name="tpu-v5e-pod",
    node=_V5E_NODE,
    # one v5e pod: (16, 16) 2-D ICI torus, ~45 GB/s per link direction
    fabric=FabricSpec(kind="torus", link_bw=45e9, hop_latency=500e-9,
                      dims=(16, 16)),
    mpi=MPIStackSpec(net_latency=1e-6),
    scale=ScaleSpec(n_nodes=256, grid=(16, 16), hpl_n=619_520, hpl_nb=512),
    # DES-fitted (bridge.fit_fastsim_to_des, 3 small probes, 120 steps)
    calibration=(("bcast_bw_scale", 0.6641436081771985),
                 ("net_latency", 1.6478532495591818e-06),
                 ("swap_bw_scale", 1.3025717500119678)),
    notes="Hardware-adaptation target: HPL recast onto a v5e ICI torus."))


# ---------------------------------------------- synthetic TOP500 class

register(Platform(
    name="syn-ft-edr-1k",
    node=NodeSpec.xeon("syn-skl-6142", 2, 24, 2.0, flops_per_cycle=32,
                       ddr_gbs=230.4, hbm_bytes=192e9),
    fabric=FabricSpec(kind="fat-tree", link_bw=100e9 / 8,
                      nodes_per_edge=32, n_core=8),
    scale=ScaleSpec(n_nodes=1024, grid=(32, 32), hpl_n=4_294_912,
                    hpl_nb=256),
    notes="Mid-size Skylake + EDR fat-tree (departmental TOP500 entry)."))

register(Platform(
    name="syn-ft-hdr-32k",
    node=NodeSpec.xeon("syn-spr-8480", 2, 48, 2.4, flops_per_cycle=32,
                       ddr_gbs=614.4, hbm_bytes=512e9),
    fabric=FabricSpec(kind="fat-tree", link_bw=200e9 / 8,
                      nodes_per_edge=64, n_core=16,
                      uplink_bw=400e9 / 8),
    scale=ScaleSpec(n_nodes=32768, grid=(128, 256), hpl_n=39_650_304,
                    hpl_nb=512),
    notes="Leadership-class Sapphire Rapids + HDR200 fat-tree."))

register(Platform(
    name="syn-df-aries-8k",
    node=NodeSpec.xeon("syn-bdw-6148", 2, 18, 2.1, flops_per_cycle=32,
                       ddr_gbs=204.8, hbm_bytes=128e9),
    fabric=FabricSpec(kind="dragonfly", link_bw=14.6e9, hop_latency=100e-9,
                      n_groups=16, routers_per_group=16,
                      nodes_per_router=32, global_bw=18.75e9),
    scale=ScaleSpec(n_nodes=8192, grid=(64, 128), hpl_n=9_914_496,
                    hpl_nb=384),
    notes="Aries-era dragonfly (Cray XC-class), minimal routing."))

register(Platform(
    name="syn-df-ss-16k",
    node=NodeSpec.xeon("syn-amd-7763", 2, 64, 2.0, flops_per_cycle=16,
                       ddr_gbs=409.6, hbm_bytes=256e9),
    fabric=FabricSpec(kind="dragonfly", link_bw=25e9, hop_latency=100e-9,
                      n_groups=32, routers_per_group=16,
                      nodes_per_router=32, nonminimal=True),
    scale=ScaleSpec(n_nodes=16384, grid=(128, 128), hpl_n=19_826_176,
                    hpl_nb=512),
    notes="Slingshot-era dragonfly, Valiant non-minimal routing."))

register(Platform(
    name="syn-torus-fugaku-4k",
    node=NodeSpec(name="syn-a64fx", peak_flops=48 * 32 * 2.2e9,
                  mem_bw=1024e9, cores=48, gemm_efficiency=0.90,
                  mem_efficiency=0.80, blas_latency=2e-7,
                  hbm_bytes=32e9),
    fabric=FabricSpec(kind="torus", link_bw=6.8e9, hop_latency=200e-9,
                      dims=(16, 16, 16)),
    scale=ScaleSpec(n_nodes=4096, grid=(64, 64), hpl_n=3_506_496,
                    hpl_nb=192),
    # DES-fitted (bridge.fit_fastsim_to_des, 3 small probes, 120 steps)
    calibration=(("bcast_bw_scale", 0.5907666924636771),
                 ("net_latency", 2.29015778924287e-06),
                 ("swap_bw_scale", 10.155731492432405)),
    notes="A64FX + TofuD-style 3-D torus cell (Fugaku-like)."))

register(Platform(
    name="syn-torus-bgq-8k",
    node=NodeSpec(name="syn-bgq", peak_flops=16 * 8 * 1.6e9,
                  mem_bw=42.6e9, cores=16, gemm_efficiency=0.85,
                  mem_efficiency=0.80, blas_latency=2e-7, hbm_bytes=16e9),
    fabric=FabricSpec(kind="torus", link_bw=2e9, hop_latency=80e-9,
                      dims=(32, 16, 16)),
    scale=ScaleSpec(n_nodes=8192, grid=(64, 128), hpl_n=3_506_432,
                    hpl_nb=128),
    # DES-fitted (bridge.fit_fastsim_to_des, 3 small probes, 120 steps)
    calibration=(("bcast_bw_scale", 0.8759841926584423),
                 ("net_latency", 4.562412942707659e-06),
                 ("swap_bw_scale", 3.1254017822068474)),
    notes="BlueGene/Q-style low-power torus machine."))

register(Platform(
    name="syn-gpu-ft-2k",
    # HPL runs on the GPUs: node peak is 4x A100 DP (9.7 TF each); the
    # accelerator section documents the split.  One rank per GPU.
    node=NodeSpec(name="syn-4xa100", peak_flops=4 * 9.7e12,
                  mem_bw=4 * 1555e9, cores=4, gemm_efficiency=0.90,
                  mem_efficiency=0.80, blas_latency=2e-6,
                  hbm_bytes=4 * 80e9, accel_peak_flops=4 * 9.7e12,
                  accel_mem_bw=4 * 1555e9),
    fabric=FabricSpec(kind="fat-tree", link_bw=200e9 / 8,
                      nodes_per_edge=32, n_core=16),
    scale=ScaleSpec(n_nodes=2048, ranks_per_node=4, grid=(64, 128),
                    hpl_n=7_839_744, hpl_nb=384),
    notes="GPU-accelerated fat-tree (A100-class), 4 ranks/node."))

register(Platform(
    name="syn-mp-2pod-v5e",
    node=_V5E_NODE,
    fabric=FabricSpec(kind="multipod", link_bw=45e9, hop_latency=500e-9,
                      dims=(16, 16), n_pods=2, dcn_bw_per_node=25e9,
                      dcn_latency=10e-6),
    mpi=MPIStackSpec(net_latency=1e-6),
    scale=ScaleSpec(n_nodes=512, grid=(16, 32), hpl_n=876_032,
                    hpl_nb=512),
    # DES-fitted (bridge.fit_fastsim_to_des, 3 small probes, 120 steps)
    calibration=(("bcast_bw_scale", 0.6624194630769419),
                 ("net_latency", 1.647546832564056e-06),
                 ("swap_bw_scale", 1.301011940122499)),
    notes="Two v5e pods joined by a DCN (cross-pod HPL what-if rig)."))
