"""Transformer train/serve step as a DES application on the TPU torus.

This is the hardware-adaptation analogue of apps/hpl.py: instead of HPL's
panel/bcast/update flow over MPI on a fat-tree, the application is a
scan-over-layers train (or decode) step whose per-layer compute and
collective schedule comes from the compiled dry-run record.

What the DES adds over the analytic SimXLA model (both are paper-style
"library models"):
  * contention on shared links — cross-pod DCN traffic, multi-axis
    collectives sharing ring links;
  * straggler injection (slow chip / slow link) for the fault-tolerance
    what-if studies (ft/straggler.py consumes these results);
  * jitter — per-rank compute-time perturbation.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict, List, Optional, Tuple

from repro.core.engine import Engine
from repro.core.hardware.network import Network
from repro.core.hardware.node import NodeModel, TPU_V5E
from repro.core.hardware.topology import Torus, MultiPod
from repro.core.simmpi import SimMPI
from repro.core.simxla import ICIParams, default_ici, ici_from_platform


@dataclasses.dataclass
class LayerWork:
    compute_s: float
    # (op, wire_bytes, axis): axis 'model' | 'data' | 'pod'
    collectives: List[Tuple[str, float, str]]


@dataclasses.dataclass
class StepWorkload:
    """Per-device, per-layer workload; see from_dryrun_record."""
    layers: List[LayerWork]
    tail_collectives: List[Tuple[str, float, str]]   # e.g. grad all-reduce
    tail_compute_s: float = 0.0

    @staticmethod
    def from_dryrun_record(record: Dict, num_layers: int,
                           chip: NodeModel = TPU_V5E) -> "StepWorkload":
        r = record["roofline"]
        chips = record["chips"]
        flops = r["hlo_flops_total"] / chips
        nbytes = r["hlo_bytes_total"] / chips
        compute = max(flops / (chip.peak_flops * chip.gemm_efficiency),
                      nbytes / 3.0 / (chip.mem_bw * chip.mem_efficiency))
        per_layer = compute / max(num_layers, 1)
        colls = record.get("collectives", {})
        layer_colls: List[Tuple[str, float, str]] = []
        tail: List[Tuple[str, float, str]] = []
        for op, agg in colls.items():
            wire = agg["wire_bytes"]
            if op == "all-reduce" and record.get("kind") == "train":
                # gradient reduction: half at tail over 'data' (+pod), rest
                # per-layer over 'model'
                tail.append((op, wire * 0.5, "data"))
                layer_colls.append((op, wire * 0.5 / num_layers, "model"))
            else:
                layer_colls.append((op, wire / num_layers, "model"))
        return StepWorkload(
            layers=[LayerWork(per_layer, list(layer_colls))
                    for _ in range(num_layers)],
            tail_collectives=tail)


class TransformerStepSim:
    def __init__(self, workload: StepWorkload, *,
                 mesh: Tuple[int, int] = (16, 16), pods: int = 1,
                 chip: Optional[NodeModel] = None,
                 ici: Optional[ICIParams] = None,
                 mpi_overhead: float = 5e-7,
                 straggler: Optional[Tuple[int, float]] = None,
                 jitter: float = 0.0, seed: int = 0,
                 trace: bool = False, faults=None,
                 layer_marks: Optional[Dict[int, float]] = None):
        self.workload = workload
        self.mesh = mesh
        self.pods = pods
        self.chip = chip if chip is not None else TPU_V5E
        ici = ici or default_ici()
        self.n_per_pod = mesh[0] * mesh[1]
        self.n = self.n_per_pod * pods
        self.engine = Engine(trace=trace)
        if pods == 1:
            topo = Torus(mesh, link_bw=ici.link_bw,
                         hop_latency=ici.hop_latency,
                         base_latency=ici.base_latency)
        else:
            topo = MultiPod([Torus(mesh, link_bw=ici.link_bw,
                                   hop_latency=ici.hop_latency,
                                   base_latency=ici.base_latency)
                             for _ in range(pods)],
                            self.n_per_pod, dcn_bw_per_node=ici.dcn_bw,
                            dcn_latency=ici.dcn_latency)
        self.net = Network(self.engine, topo)
        self.mpi = SimMPI(self.engine, self.net, self.n,
                          overhead=mpi_overhead)
        self.straggler = straggler
        self.jitter = jitter
        self.seed = seed
        self.finish: Dict[int, float] = {}
        # region-simulation hook (src/repro/scale/): record per-layer
        # boundary times (max over ranks; no events scheduled)
        self.layer_marks = layer_marks
        if faults is not None:
            from repro.faults.inject import install_faults
            install_faults(faults, self.engine, network=self.net,
                           n_ranks=self.n)

    @classmethod
    def from_platform(cls, workload: StepWorkload, platform, *,
                      mesh: Optional[Tuple[int, int]] = None,
                      pods: Optional[int] = None,
                      **kw) -> "TransformerStepSim":
        """Build the DES from a ``repro.platforms.Platform`` spec: chip,
        ICI, and MPI-stack knobs all come from the spec; the (rows, cols)
        mesh defaults to the platform's torus dims (a k-D torus collapses
        to ``(prod(dims[:-1]), dims[-1])``) and ``pods`` to the fabric's
        pod count."""
        fab = platform.fabric
        if fab.kind not in ("torus", "multipod"):
            raise ValueError(
                f"platform {platform.name!r} has a {fab.kind!r} fabric; "
                "the transformer step DES needs torus or multipod")
        if mesh is None:
            mesh = (math.prod(fab.dims[:-1]), fab.dims[-1])
        if pods is None:
            pods = fab.n_pods if fab.kind == "multipod" else 1
        kw.setdefault("chip", platform.node_model())
        kw.setdefault("ici", ici_from_platform(platform))
        kw.setdefault("mpi_overhead", platform.mpi.overhead)
        return cls(workload, mesh=tuple(mesh), pods=pods, **kw)

    # mesh coordinate helpers (rank = pod*n_per_pod + row*cols + col)
    def _groups(self, rank: int) -> Dict[str, List[int]]:
        rows, cols = self.mesh
        pod = rank // self.n_per_pod
        local = rank % self.n_per_pod
        r, c = divmod(local, cols)
        base = pod * self.n_per_pod
        return {
            "model": [base + r * cols + cc for cc in range(cols)],
            "data": [base + rr * cols + c for rr in range(rows)],
            "pod": [p * self.n_per_pod + local for p in range(self.pods)],
        }

    def _compute_scale(self, rank: int) -> float:
        s = 1.0
        if self.straggler and rank == self.straggler[0]:
            s *= self.straggler[1]
        if self.jitter:
            # deterministic per-rank jitter (no RNG in sim time)
            h = (rank * 2654435761 + self.seed) & 0xffffffff
            s *= 1.0 + self.jitter * ((h / 0xffffffff) - 0.5) * 2.0
        return s

    def _rank_proc(self, rank: int):
        tr = self.engine.trace
        fa = self.engine.faults
        tren = tr.enabled
        faen = fa.enabled
        groups = self._groups(rank)
        # per-axis ring geometry computed once per rank, not per
        # collective call: (group, me, nxt, prv, prv_ring_index)
        rings = {}
        for axis, grp in groups.items():
            n = len(grp)
            me = grp.index(rank)
            rings[axis] = (grp, me, grp[(me + 1) % n], grp[(me - 1) % n],
                           (me - 1) % n)
        base_scale = self._compute_scale(rank)
        marks = self.layer_marks
        for li, layer in enumerate(self.workload.layers):
            ph0 = self.engine.now
            # fault scale is re-read per layer: stragglers can activate
            # and clear mid-step
            scale = base_scale * fa.compute_scale(rank) \
                if faen else base_scale
            if tren:
                tr.compute(rank, "layer_compute", layer.compute_s * scale,
                           args={"layer": li})
            yield layer.compute_s * scale
            for ci, (op, wire, axis) in enumerate(layer.collectives):
                if len(groups[axis]) <= 1:
                    continue
                yield from self._collective(rank, op, wire, rings[axis],
                                            op_id=("l", li, ci, axis))
            if tren:
                tr.complete(rank, "phase", f"layer{li}", ph0,
                            args={"layer": li})
            if marks is not None:
                # per-layer boundary on this rank; the region layer
                # replicates the steady-state delta of the max-over-ranks
                # boundary times (ordering untouched: no events scheduled)
                prev = marks.get(li, 0.0)
                if self.engine.now > prev:
                    marks[li] = self.engine.now
        ph0 = self.engine.now
        if self.workload.tail_compute_s:
            scale = base_scale * fa.compute_scale(rank) \
                if faen else base_scale
            if tren:
                tr.compute(rank, "tail_compute",
                           self.workload.tail_compute_s * scale)
            yield self.workload.tail_compute_s * scale
        for ci, (op, wire, axis) in enumerate(self.workload.tail_collectives):
            grp = groups[axis]
            if len(grp) > 1:
                yield from self._collective(rank, op, wire, rings[axis],
                                            op_id=("t", ci, axis))
            if axis == "data" and self.pods > 1:
                yield from self._collective(rank, op, wire / len(grp),
                                            rings["pod"], op_id=("tp", ci))
        if tren and self.engine.now > ph0:
            tr.complete(rank, "phase", "tail", ph0)
        self.finish[rank] = self.engine.now

    def _collective(self, rank, op, wire_bytes, ring, op_id):
        """Ring collectives as real flows; wire_bytes already follows the
        hlo_parse ring convention (bytes through one device).  ``ring``
        is the precomputed (group, me, nxt, prv, prv_index) tuple from
        _rank_proc — ring geometry is a pure function of (rank, axis)."""
        mpi = self.mpi
        tr = self.engine.trace
        group, me, nxt, prv, prv_i = ring
        tok = tr.coll_begin(rank, op, op_id, group, wire_bytes) \
            if tr.enabled else None
        n = len(group)
        if op == "all-reduce":
            rounds = 2 * (n - 1)
        elif op == "collective-permute":
            rounds = 1
        else:       # all-gather / reduce-scatter / all-to-all / default
            rounds = n - 1
        per_round = wire_bytes / max(rounds, 1)
        isend = mpi.isend
        eng = mpi.engine
        if tok is None and eng.pooling:
            # hot path: the blocking-recv body inlined (identical yield
            # sequence to mpi.recv, minus one generator frame per round;
            # traced and legacy runs keep the generator so span capture
            # and the pre-PR cost model stay exact)
            posted = mpi._posted
            recv_wait = mpi._recv_wait
            recycle = eng._recycle_event
            for k in range(rounds):
                ev = isend(rank, nxt, per_round, tag=(op_id, k, me))
                key = (prv, rank, (op_id, k, prv_i))
                box = posted.get(key)
                if box:
                    transfer, eager = box.pop(0)
                else:
                    w = eng.event()
                    wl = recv_wait.get(key)
                    if wl is None:
                        recv_wait[key] = [w]
                    else:
                        wl.append(w)
                    transfer, eager = yield w
                    recycle(w)
                yield transfer
                if eager:
                    recycle(transfer)
                yield ev
        else:
            recv = mpi.recv
            for k in range(rounds):
                ev = isend(rank, nxt, per_round, tag=(op_id, k, me))
                yield from recv(prv, rank, tag=(op_id, k, prv_i))
                yield ev
        if tok is not None:
            tr.coll_end(rank, tok)

    @property
    def trace(self):
        """The engine's TraceRecorder (NULL_RECORDER when tracing off)."""
        return self.engine.trace

    def run(self) -> Dict:
        fa = self.engine.faults
        for r in range(self.n):
            proc = self.engine.spawn(self._rank_proc(r), name=f"chip{r}")
            if fa.enabled:
                fa.register_rank(r, proc)
        self.engine.run_all()
        fa.finalize()
        if len(self.finish) < self.n:
            # fail-stop stranded the survivors; report a failed step
            return {"step_s": self.engine.now, "failed": True,
                    "n_finished": len(self.finish),
                    "events": self.engine.event_count,
                    "min_finish": min(self.finish.values())
                    if self.finish else 0.0}
        t = max(self.finish.values())
        return {"step_s": t, "events": self.engine.event_count,
                "min_finish": min(self.finish.values())}
