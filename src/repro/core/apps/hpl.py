"""HPL application model (paper §III-C) on the discrete-event simulator.

Right-looking LU with block size ``nb`` on a P x Q block-cyclic process
grid.  Per panel k:

  1. panel factorization (owning process column): per column j of the
     panel — idamax + pivot allreduce over the P column ranks + dscal +
     dger over the local rows; pivot exchange is aggregated into one
     column-group sync + analytic per-column latency (the paper models
     collectives with algorithm models, not per-packet events).
  2. panel broadcast along each process row (HPL '1ring' store-and-forward
     by default, 'long' = scatter+allgather variant available).
  3. trailing row swaps among the P column ranks (HPL_dlaswp*: modeled as
     log2(P) exchange rounds of the U strip — bandwidth-bound Level-1 ops
     per the paper).
  4. trailing update: dtrsm + dgemm on the local tile.

Matrix data is never allocated (paper: "the content of A is irrelevant
for the simulation") — only numroc-style shape arithmetic flows.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict, List, Optional

from repro.core.engine import Engine
from repro.core.hardware.network import Network
from repro.core.hardware.node import NodeModel
from repro.core.simblas import SimBLAS
from repro.core.simmpi import SimMPI


def numroc(n: int, nb: int, iproc: int, nprocs: int) -> int:
    """ScaLAPACK NUMROC: local rows/cols of an n-length dim distributed in
    nb blocks over nprocs, for process iproc (src proc 0)."""
    nblocks = n // nb
    base = (nblocks // nprocs) * nb
    extra = nblocks % nprocs
    if iproc < extra:
        base += nb
    elif iproc == extra:
        base += n % nb
    return base


@dataclasses.dataclass
class HPLConfig:
    N: int
    nb: int
    P: int
    Q: int
    bcast: str = "1ring"          # 1ring | long
    lookahead: int = 0            # modeled depth (0: panel on critical path)

    def __post_init__(self):
        if self.N < 1 or self.nb < 1:
            raise ValueError(f"HPLConfig: N={self.N}, nb={self.nb} must be "
                             ">= 1")
        if self.P < 1 or self.Q < 1:
            raise ValueError(f"HPLConfig: P={self.P}, Q={self.Q} must be "
                             ">= 1")
        if self.bcast not in ("1ring", "long"):
            raise ValueError(f"HPLConfig: bcast={self.bcast!r} not in "
                             "('1ring', 'long')")
        if self.lookahead not in (0, 1):
            raise ValueError(f"HPLConfig: lookahead={self.lookahead} must "
                             "be 0 or 1")
        # N % nb != 0 is legal: the trailing partial panel is modeled
        # (ceil(N/nb) panels, last one N % nb wide) — see n_panels.

    @property
    def n_ranks(self) -> int:
        return self.P * self.Q

    @property
    def n_panels(self) -> int:
        """ceil(N / nb): a trailing N % nb panel is simulated, not
        silently dropped."""
        return (self.N + self.nb - 1) // self.nb

    def flops(self) -> float:
        return (2.0 / 3.0) * self.N ** 3 + 1.5 * self.N ** 2


@dataclasses.dataclass
class HPLResult:
    time_s: float
    gflops: float
    events: int
    comm_time_est: float = 0.0
    trace: Optional[object] = None   # TraceRecorder when run with trace=True
    failed: bool = False             # a fault stopped ranks from finishing
    n_finished: int = -1             # ranks that completed (-1: all)
    # representative-region runs (repro.scale): only ``region_panels``
    # panels were simulated exactly; the rest are extrapolated
    region_approx: bool = False
    region_panels: int = 0


class HPLRank:
    """One MPI rank = one virtual thread."""

    def __init__(self, sim: "HPLSim", rank: int):
        self.sim = sim
        self.rank = rank
        self.p = rank % sim.cfg.P          # row coordinate (column-major grid)
        self.q = rank // sim.cfg.P

    def run(self):
        sim = self.sim
        cfg = sim.cfg
        mpi = sim.mpi
        eng = sim.engine
        tr = eng.trace
        fa = eng.faults
        tren = tr.enabled          # static for the whole run
        faen = fa.enabled
        blas = sim.blas[self.rank]
        P, Q, nb, N = cfg.P, cfg.Q, cfg.nb, cfg.N
        col_group = [self.q * P + pp for pp in range(P)]
        row_group = [qq * P + self.p for qq in range(Q)]
        n_panels = cfg.n_panels            # ceil: trailing partial panel
        if sim.max_panels is not None:     # region truncation (scale/)
            n_panels = min(n_panels, sim.max_panels)
        marks = sim.panel_marks

        for k in range(n_panels):
            rem = N - k * nb
            w = min(nb, rem)                # panel width (< nb on the last)
            qk = k % Q                      # owning process column
            pk = k % P                      # row owning the diagonal block
            mloc = numroc(rem, nb, (self.p - pk) % P, P)
            nloc = numroc(max(rem - w, 0), nb, (self.q - (k + 1) % Q) % Q, Q)
            panel_bytes = 8.0 * (mloc + w) * w

            if self.q == qk:
                # --- 1. panel factorization --------------------------------
                ph0 = eng.now
                t = blas.panel_fact(mloc, w)
                if faen:
                    t *= fa.compute_scale(self.rank)
                if tren:
                    tr.compute(self.rank, "panel_blas", t,
                               args={"panel": k, "w": w})
                yield t
                # pivot search allreduces: one aggregated column sync +
                # w analytic small allreduces (latency-bound)
                yield from mpi.barrier(self.rank, col_group, ("pf", k, self.q))
                ar_lat = 2 * math.ceil(math.log2(max(P, 2))) \
                    * (sim.net.topo.base_latency + mpi.overhead)
                if tren:
                    tr.complete(self.rank, "comm", "pivot_allreduce",
                                eng.now, t1=eng.now + w * ar_lat,
                                args={"panel": k})
                yield w * ar_lat
                if tren:
                    tr.complete(self.rank, "phase", "panel_fact", ph0,
                                args={"panel": k})
                # --- 2. broadcast along my row -----------------------------
                if Q > 1:
                    ph0 = eng.now
                    yield from self._bcast_panel(row_group, qk, panel_bytes, k)
                    if tren:
                        tr.complete(self.rank, "phase", "panel_bcast", ph0,
                                    args={"panel": k})
            else:
                if Q > 1:
                    ph0 = eng.now
                    yield from self._bcast_panel(row_group, qk, panel_bytes, k)
                    if tren:
                        tr.complete(self.rank, "phase", "panel_bcast", ph0,
                                    args={"panel": k})

            # --- 3. trailing row swaps (U strip) among column ranks --------
            u_bytes = 8.0 * w * max(nloc, 0)
            if P > 1 and u_bytes > 0:
                ph0 = eng.now
                rounds = math.ceil(math.log2(P))
                peer_up = col_group[(self.p + 1) % P]
                peer_dn = col_group[(self.p - 1) % P]
                for r in range(rounds):
                    ev = mpi.isend(self.rank, peer_up,
                                   u_bytes / max(rounds, 1),
                                   tag=("swap", k, r))
                    yield from mpi.recv(peer_dn, self.rank,
                                        tag=("swap", k, r))
                    yield ev
                t = blas.dlaswp(w, max(nloc, 1))
                if faen:
                    t *= fa.compute_scale(self.rank)
                if tren:
                    tr.compute(self.rank, "dlaswp", t, args={"panel": k})
                yield t
                if tren:
                    tr.complete(self.rank, "phase", "row_swap", ph0,
                                args={"panel": k})

            # --- 4. trailing update ---------------------------------------
            if nloc > 0:
                ph0 = eng.now
                t = blas.dtrsm(w, nloc)
                if faen:
                    t *= fa.compute_scale(self.rank)
                if tren:
                    tr.compute(self.rank, "dtrsm", t, args={"panel": k})
                yield t
                if mloc > 0:
                    t = blas.dgemm(mloc, nloc, w)
                    if faen:
                        t *= fa.compute_scale(self.rank)
                    if tren:
                        tr.compute(self.rank, "dgemm", t,
                                   args={"panel": k, "m": mloc, "n": nloc})
                    yield t
                if tren:
                    tr.complete(self.rank, "phase", "trailing_update", ph0,
                                args={"panel": k})

            if marks is not None:
                # per-panel boundary time on this rank; the region layer
                # fits its closed forms to the max over ranks (no events
                # scheduled — ordering is untouched)
                prev = marks.get(k, 0.0)
                if eng.now > prev:
                    marks[k] = eng.now

        sim.finish_times[self.rank] = sim.engine.now

    def _bcast_panel(self, row_group, root_q, nbytes, k):
        sim = self.sim
        cfg = sim.cfg
        mpi = sim.mpi
        Q = cfg.Q
        root_rank = row_group[root_q]
        if cfg.bcast == "long":
            yield from mpi.bcast(self.rank, root_rank, row_group, nbytes,
                                 op_id=("bc", k, self.p))
            return
        # HPL 1ring: store-and-forward pipeline around the row ring
        my_i = (self.q - root_q) % Q
        if my_i > 0:
            prev_rank = row_group[(self.q - 1) % Q]
            yield from mpi.recv(prev_rank, self.rank, tag=("bc1r", k))
        if my_i < Q - 1:
            nxt = row_group[(self.q + 1) % Q]
            ev = mpi.isend(self.rank, nxt, nbytes, tag=("bc1r", k))
            if cfg.lookahead == 0:
                yield ev


class HPLSim:
    """Full-DES HPL run.

    ``HPLSim(cfg, platform)`` builds the hardware pair from a
    ``repro.platforms.Platform`` spec (node model, topology, ranks per
    node, and MPI-stack knobs all come from the spec); the explicit
    ``HPLSim(cfg, node, topology)`` form stays for ad-hoc hardware, and
    ``HPLSim(cfg, platform.des(trace=True))`` accepts a prebuilt stack.

    ``trace=True`` attaches a ``repro.trace.TraceRecorder``: per-rank
    phase/compute/comm timelines, Chrome-trace export
    (``result.trace.to_chrome_json(path)``) and critical-path analysis
    (``result.trace.summary()``) at zero cost — and zero perturbation —
    when off.
    """

    def __init__(self, cfg: HPLConfig, node, topology=None,
                 ranks_per_node: Optional[int] = None,
                 mpi_overhead: Optional[float] = None,
                 trace: Optional[bool] = None,
                 faults=None,
                 max_panels: Optional[int] = None,
                 panel_marks: Optional[Dict[int, float]] = None):
        if topology is None and hasattr(node, "des"):   # a Platform spec
            platform = node
            stack = platform.des()
            node, topology = stack.node, stack.topology
            if ranks_per_node is None:
                ranks_per_node = stack.ranks_per_node
            if mpi_overhead is None:
                mpi_overhead = stack.mpi_overhead
            if trace is None:
                trace = stack.trace
            capacity = platform.scale.n_ranks
            if cfg.n_ranks > capacity:
                raise ValueError(
                    f"config needs {cfg.n_ranks} ranks but platform "
                    f"{platform.name!r} has {capacity}")
        elif topology is None and hasattr(node, "topology"):  # a DESStack
            stack = node
            node, topology = stack.node, stack.topology
            if ranks_per_node is None:
                ranks_per_node = stack.ranks_per_node
            if mpi_overhead is None:
                mpi_overhead = stack.mpi_overhead
            if trace is None:
                trace = stack.trace
        elif topology is None:
            raise TypeError("HPLSim needs a Platform, a DESStack, or "
                            "(node, topology)")
        ranks_per_node = 1 if ranks_per_node is None else ranks_per_node
        mpi_overhead = 5e-7 if mpi_overhead is None else mpi_overhead
        self.cfg = cfg
        self.node = node
        self.engine = Engine(trace=bool(trace))
        self.net = Network(self.engine, topology)
        self.mpi = SimMPI(self.engine, self.net, cfg.n_ranks,
                          rank_to_node=lambda r: r // ranks_per_node,
                          overhead=mpi_overhead)
        # per-rank BLAS: a rank uses its share of the node
        share = dataclasses.replace(
            node, peak_flops=node.peak_flops / ranks_per_node,
            mem_bw=node.mem_bw / ranks_per_node,
            cores=max(node.cores // ranks_per_node, 1))
        # every rank gets the same node share, and SimBLAS is a pure
        # function of shapes — one instance serves all ranks and its
        # panel_fact memo is shared across the whole grid (per-rank
        # instances under the legacy bench engine, as pre-rewrite)
        if self.engine.pooling:
            shared_blas = SimBLAS(share)
            self.blas = [shared_blas] * cfg.n_ranks
        else:
            self.blas = [SimBLAS(share) for _ in range(cfg.n_ranks)]
        self.finish_times: Dict[int, float] = {}
        # region-simulation hooks (src/repro/scale/): truncate the run
        # after max_panels panels and/or record per-panel boundary times
        self.max_panels = max_panels
        self.panel_marks = panel_marks
        if faults is not None:
            from repro.faults.inject import install_faults
            install_faults(faults, self.engine, network=self.net,
                           n_ranks=cfg.n_ranks,
                           rank_to_node=self.mpi.rank_to_node)

    @property
    def trace(self):
        """The engine's TraceRecorder (NULL_RECORDER when tracing off)."""
        return self.engine.trace

    def run(self) -> HPLResult:
        fa = self.engine.faults
        for r in range(self.cfg.n_ranks):
            proc = self.engine.spawn(HPLRank(self, r).run(),
                                     name=f"rank{r}")
            if fa.enabled:
                fa.register_rank(r, proc)
        self.engine.run_all()
        fa.finalize()
        trace = self.engine.trace if self.engine.trace.enabled else None
        n_done = len(self.finish_times)
        if n_done < self.cfg.n_ranks:
            # a fail-stop stranded the survivors at a rendezvous: the
            # heap drained without every rank finishing
            return HPLResult(time_s=self.engine.now, gflops=0.0,
                             events=self.engine.event_count, trace=trace,
                             failed=True, n_finished=n_done)
        t = max(self.finish_times.values())
        return HPLResult(time_s=t, gflops=self.cfg.flops() / t / 1e9,
                         events=self.engine.event_count, trace=trace)
