"""Discrete-event simulation engine (the paper's SystemC/CoFluent analog).

Sequential engine; every simulated MPI rank / virtual thread is a Python
generator ("CoFluent virtual thread").  Processes yield:

    float/int        — wait that many simulated seconds
    Event            — park until the event fires
    Process          — park until the child process terminates (join)
    ("spawn", gen)   — start a child process, continue immediately

The paper's "privatization of global variables" workaround (§III-C) is
unnecessary here: each generator closes over its own state — documented in
DESIGN.md §9.
"""
from __future__ import annotations

import heapq
import math
import time
from typing import Any, Callable, Generator, List, Optional

from repro.trace.recorder import NULL_RECORDER, TraceRecorder


class ProcessError(RuntimeError):
    """An exception escaped a DES process generator.  Carries the
    process identity and engine state at failure time so fault-run
    failures are debuggable (the original exception is ``__cause__``)."""

    def __init__(self, message: str, *, process: str = "", sim_time: float
                 = 0.0, pending_events: int = 0):
        super().__init__(message)
        self.process = process
        self.sim_time = sim_time
        self.pending_events = pending_events


class SimWallDeadline(RuntimeError):
    """The engine's *wall-clock* budget expired mid-run (the serving
    layer's per-request timeout; simulated time is unbounded)."""


class Event:
    __slots__ = ("engine", "_set", "waiters", "payload")

    def __init__(self, engine: "Engine"):
        self.engine = engine
        self._set = False
        self.waiters: List["Process"] = []
        self.payload: Any = None

    def set(self, payload: Any = None):
        if self._set:
            return
        self._set = True
        self.payload = payload
        for proc in self.waiters:
            self.engine._schedule(0.0, proc._step, payload)
        self.waiters.clear()

    @property
    def is_set(self) -> bool:
        return self._set


class Process:
    __slots__ = ("engine", "gen", "done", "_joiners", "name", "killed")

    def __init__(self, engine: "Engine", gen: Generator, name: str = ""):
        self.engine = engine
        self.gen = gen
        self.done = Event(engine)
        self.name = name
        self.killed = False          # fail-stop: stop dead, never join

    def kill(self):
        """Fail-stop this virtual thread: it takes no further steps and
        its ``done`` event never fires, so joiners and rendezvous peers
        block forever — exactly a real fail-stop process."""
        self.killed = True
        self.gen.close()

    def _step(self, send_value: Any = None):
        if self.killed:
            return
        eng = self.engine
        try:
            while True:
                cmd = self.gen.send(send_value)
                send_value = None
                if isinstance(cmd, (int, float)):
                    if cmd < 0:
                        raise ValueError(f"negative wait {cmd} in {self.name}")
                    eng._schedule(float(cmd), self._step, None)
                    return
                if isinstance(cmd, Event):
                    if cmd.is_set:
                        send_value = cmd.payload
                        continue
                    cmd.waiters.append(self)
                    return
                if isinstance(cmd, Process):
                    if cmd.done.is_set:
                        continue
                    cmd.done.waiters.append(self)
                    return
                if isinstance(cmd, tuple) and cmd and cmd[0] == "spawn":
                    eng.spawn(cmd[1])
                    continue
                raise TypeError(f"bad yield {cmd!r} from {self.name}")
        except StopIteration:
            self.done.set()
        except ProcessError:
            raise
        except Exception as exc:
            raise ProcessError(
                f"DES process {self.name or '<unnamed>'} failed at "
                f"t={eng.now:.9g}s ({len(eng._heap)} pending events): "
                f"{type(exc).__name__}: {exc}",
                process=self.name, sim_time=eng.now,
                pending_events=len(eng._heap)) from exc


class Engine:
    """Event loop.  Heap entries are ``(time, seq, fn, arg)``: ``seq`` is
    a monotonically increasing insertion number, so same-timestamp ties
    always fire in schedule order — event ordering (and therefore traces
    and results) is reproducible run-to-run.  Anything feeding the heap
    must iterate its own state deterministically too (see the ordered
    flow dicts in hardware/network.py).

    ``trace=True`` attaches a ``repro.trace.TraceRecorder``; off, the
    no-op NULL_RECORDER singleton sits there so instrumentation sites
    cost one attribute test and the loop itself is untouched.  The
    recorder never schedules events, so traced and untraced runs of the
    same scenario produce bit-identical simulated times.

    ``faults`` is the engine's fault clock — a
    ``repro.faults.inject.FaultRuntime`` attached by the application
    when a scenario carries a ``FaultSpec``, or the no-op NULL_FAULTS
    singleton.  A runtime drives degradation through ordinary
    ``call_at`` events (its schedule is finite by construction), so an
    unfaulted run schedules nothing extra and stays bit-identical to
    pre-fault builds.

    ``wall_deadline`` (a ``time.monotonic`` timestamp) bounds *wall
    clock*, not simulated time: the serving layer sets it so a DES that
    would blow a request deadline raises ``SimWallDeadline`` instead of
    stalling the wave.  Unset, the hot loop is untouched.
    """

    def __init__(self, trace: bool = False):
        self.now = 0.0
        self._heap: list = []
        self._seq = 0
        self.event_count = 0
        self.trace = TraceRecorder(self) if trace else NULL_RECORDER
        from repro.faults.inject import NULL_FAULTS
        self.faults = NULL_FAULTS
        self.wall_deadline: Optional[float] = None

    def event(self) -> Event:
        return Event(self)

    def _schedule(self, dt: float, fn: Callable, arg: Any):
        self._seq += 1
        heapq.heappush(self._heap, (self.now + dt, self._seq, fn, arg))

    def call_at(self, t: float, fn: Callable, arg: Any = None):
        self._seq += 1
        heapq.heappush(self._heap, (max(t, self.now), self._seq, fn, arg))

    def spawn(self, gen: Generator, name: str = "") -> Process:
        proc = Process(self, gen, name)
        self._schedule(0.0, proc._step, None)
        return proc

    def set_wall_deadline(self, timeout_s: Optional[float]):
        """Bound the *wall clock* a run may burn: ``run()`` raises
        ``SimWallDeadline`` once ``timeout_s`` real seconds elapse.
        None clears the bound."""
        self.wall_deadline = (None if timeout_s is None
                              else time.monotonic() + timeout_s)

    def run(self, until: float = math.inf) -> float:
        heap = self._heap
        if self.wall_deadline is not None:
            return self._run_deadline(until)
        while heap:
            t, _, fn, arg = heap[0]
            if t > until:
                break
            heapq.heappop(heap)
            self.now = t
            self.event_count += 1
            fn(arg)
        return self.now

    def _run_deadline(self, until: float) -> float:
        # separate loop so the unfaulted hot path above stays untouched;
        # the clock syscall is amortized over 1024-event slices
        heap = self._heap
        deadline = self.wall_deadline
        while heap:
            if time.monotonic() > deadline:
                raise SimWallDeadline(
                    f"wall-clock budget expired at sim t={self.now:.9g}s "
                    f"({self.event_count} events, {len(heap)} pending)")
            for _ in range(1024):
                if not heap:
                    break
                t, _, fn, arg = heap[0]
                if t > until:
                    return self.now
                heapq.heappop(heap)
                self.now = t
                self.event_count += 1
                fn(arg)
        return self.now

    def run_all(self) -> float:
        return self.run(math.inf)
