"""Discrete-event simulation engine (the paper's SystemC/CoFluent analog).

Sequential engine; every simulated MPI rank / virtual thread is a Python
generator ("CoFluent virtual thread").  Processes yield:

    float/int        — wait that many simulated seconds
    Event            — park until the event fires
    Process          — park until the child process terminates (join)
    ("spawn", gen)   — start a child process, continue immediately

The paper's "privatization of global variables" workaround (§III-C) is
unnecessary here: each generator closes over its own state — documented in
DESIGN.md §9.

Hot-loop layout (DESIGN.md §17).  The queue is split by delay class:

  * **same-timestamp FIFO** (``_nq_seq``/``_nq_fn``/``_nq_arg``) —
    events scheduled at the current instant (``dt == 0``: event
    wakeups, spawns, relays — the dominant class in collective-heavy
    runs) append to three parallel flat arrays consumed through a head
    cursor; they never touch the heap.  Parallel arrays instead of
    ``(seq, fn, arg)`` tuples is a *gc* decision, not a style one: an
    int/ref append creates no collector-tracked object, so the FIFO —
    unlike a tuple queue, whose retained entries push the gen-0
    counter over threshold every ~700 events — triggers no collections
    at all, matching the pre-rewrite loop's gc-neutral behavior
    (a tuple-queue variant measured 2x slower on zero-delay-heavy
    runs, with 100% of the difference inside ``gc.collect``).  The
    drained prefix is compacted every 8192 entries to bound memory.
    Wakeups batch per timestamp and are FIFO-stable by construction
    (satellite: the ``Event.set`` re-entrancy hazard).
  * **timed heap** (``_heap``) — future events live in a binary heap
    of ``(t, seq, fn, arg)`` tuples.  (A slot-reuse variant with
    mutable entries and a free list was measured ~40% *slower* than
    tuples — CPython's tuple free list beats manual recycling — so
    reuse is confined to the FIFO, events, and flows, where it wins.)
    ``seq`` is the monotonic insertion number: it tie-breaks equal
    timestamps (comparison never reaches the callables) and is what
    makes the FIFO/heap merge exact.

The merge rule is the old loop's total order, verbatim: dispatch in
``(time, seq)`` order, where FIFO entries carry ``t == now``.  The
rewritten loop is therefore *bit-identical* — same event order, same
finish times, same traces — to the frozen pre-rewrite loop kept in
``_legacy_engine.py``, and tests/test_engine_order.py holds it to that
on randomized programs.
"""
from __future__ import annotations

import heapq
import math
import time
from typing import Any, Callable, Generator, List, Optional

from repro.obs.metrics import NULL_METRICS
from repro.trace.recorder import NULL_RECORDER, TraceRecorder


# engine-telemetry histogram buckets (events/s spans interpreted-loop
# rates; recycle rate is a fraction of events)
_EVENTS_PER_S_BUCKETS = (1e3, 2.5e3, 5e3, 1e4, 2.5e4, 5e4, 1e5, 2e5,
                         3e5, 5e5, 1e6, 2e6, 5e6)
_RECYCLE_BUCKETS = (0.01, 0.025, 0.05, 0.1, 0.2, 0.3, 0.5, 0.75, 1.0)


class ProcessError(RuntimeError):
    """An exception escaped a DES process generator.  Carries the
    process identity and engine state at failure time so fault-run
    failures are debuggable (the original exception is ``__cause__``)."""

    def __init__(self, message: str, *, process: str = "", sim_time: float
                 = 0.0, pending_events: int = 0):
        super().__init__(message)
        self.process = process
        self.sim_time = sim_time
        self.pending_events = pending_events


class SimWallDeadline(RuntimeError):
    """The engine's *wall-clock* budget expired mid-run (the serving
    layer's per-request timeout; simulated time is unbounded)."""


class Event:
    # No cached bound method here: Events (and Processes) are *callable*
    # and the FIFO/heap store the object itself as the dispatch target.
    # An earlier variant cached ``self.step = self._step``, which is a
    # reference cycle (event -> bound method -> event) — every
    # non-recycled event became cyclic garbage only gc could free, and
    # zero-delay-heavy runs spent ~40% of wall time in collections.
    __slots__ = ("engine", "_set", "waiters", "payload")

    def __init__(self, engine: "Engine"):
        self.engine = engine
        self._set = False
        self.waiters: List["Process"] = []
        self.payload: Any = None

    def set(self, payload: Any = None):
        if self._set:
            return
        self._set = True
        self.payload = payload
        waiters = self.waiters
        if waiters:
            # zero-delay wakeups go straight onto the same-timestamp
            # FIFO in registration order (seq-numbered so the heap
            # merge stays exact)
            eng = self.engine
            seqs = eng._nq_seq
            fns = eng._nq_fn
            args = eng._nq_arg
            seq = eng._seq
            for proc in waiters:
                seq += 1
                seqs.append(seq)
                fns.append(proc)
                args.append(payload)
            eng._seq = seq
            waiters.clear()

    @property
    def is_set(self) -> bool:
        return self._set

    # an Event can sit directly in another event's waiters list and
    # relay the fire (SimMPI chains flow-completion -> transfer events
    # this way without a per-message adapter object); __call__ makes it
    # a dispatch target for the FIFO/heap without a bound-method alloc
    def _step(self, payload: Any = None):
        self.set(payload)

    __call__ = _step


class Process:
    __slots__ = ("engine", "gen", "done", "_joiners", "name", "killed")

    def __init__(self, engine: "Engine", gen: Generator, name: str = ""):
        self.engine = engine
        self.gen = gen
        self.done = Event(engine)
        self.name = name
        self.killed = False          # fail-stop: stop dead, never join

    def kill(self):
        """Fail-stop this virtual thread: it takes no further steps and
        its ``done`` event never fires, so joiners and rendezvous peers
        block forever — exactly a real fail-stop process."""
        self.killed = True
        self.gen.close()

    def _step(self, send_value: Any = None):
        if self.killed:
            return
        eng = self.engine
        send = self.gen.send
        try:
            while True:
                cmd = send(send_value)
                send_value = None
                # fast path: a bare float wait is the dominant yield
                # (PR 3's trace-overhead mapping) — dispatch on exact
                # type before the isinstance ladder
                tc = type(cmd)
                if tc is float:
                    if cmd < 0.0:
                        raise ValueError(f"negative wait {cmd} in {self.name}")
                    # inlined _schedule: one fewer call per wait, the
                    # single hottest line in the simulator
                    seq = eng._seq + 1
                    eng._seq = seq
                    if cmd == 0.0:
                        eng._nq_seq.append(seq)
                        eng._nq_fn.append(self)
                        eng._nq_arg.append(None)
                    else:
                        heapq.heappush(eng._heap,
                                       (eng.now + cmd, seq, self, None))
                    return
                if tc is Event:
                    if cmd._set:
                        send_value = cmd.payload
                        continue
                    cmd.waiters.append(self)
                    return
                # slow ladder, semantics identical to the legacy loop:
                # ints / numpy scalars / bools, Event subclasses, joins,
                # spawn tuples
                if isinstance(cmd, (int, float)):
                    if cmd < 0:
                        raise ValueError(f"negative wait {cmd} in {self.name}")
                    eng._schedule(float(cmd), self, None)
                    return
                if isinstance(cmd, Event):
                    if cmd.is_set:
                        send_value = cmd.payload
                        continue
                    cmd.waiters.append(self)
                    return
                if isinstance(cmd, Process):
                    if cmd.done.is_set:
                        continue
                    cmd.done.waiters.append(self)
                    return
                if isinstance(cmd, tuple) and cmd and cmd[0] == "spawn":
                    eng.spawn(cmd[1])
                    continue
                raise TypeError(f"bad yield {cmd!r} from {self.name}")
        except StopIteration:
            self.done.set()
        except ProcessError:
            raise
        except Exception as exc:
            raise ProcessError(
                f"DES process {self.name or '<unnamed>'} failed at "
                f"t={eng.now:.9g}s ({eng.pending()} pending events): "
                f"{type(exc).__name__}: {exc}",
                process=self.name, sim_time=eng.now,
                pending_events=eng.pending()) from exc

    __call__ = _step


class Engine:
    """Event loop.  Two queues (see module docstring): a FIFO for
    same-timestamp events and an array-backed slot-reuse heap for timed
    ones, merged in exact ``(time, seq)`` order so event ordering (and
    therefore traces and results) is reproducible run-to-run and
    bit-identical to the pre-rewrite loop.  Anything feeding the queues
    must iterate its own state deterministically too (see the ordered
    flow dicts in hardware/network.py).

    ``pooling`` marks this engine as supporting object recycling:
    SimMPI recycles its receive-wait events through ``_recycle_event``
    and Network recycles ``Flow`` objects when it is set (the legacy
    engine sets it False so benchmarks can reproduce pre-rewrite
    allocation behavior).

    ``trace=True`` attaches a ``repro.trace.TraceRecorder``; off, the
    no-op NULL_RECORDER singleton sits there so instrumentation sites
    cost one attribute test and the loop itself is untouched.  The
    recorder never schedules events, so traced and untraced runs of the
    same scenario produce bit-identical simulated times.

    ``faults`` is the engine's fault clock — a
    ``repro.faults.inject.FaultRuntime`` attached by the application
    when a scenario carries a ``FaultSpec``, or the no-op NULL_FAULTS
    singleton.  A runtime drives degradation through ordinary
    ``call_at`` events (its schedule is finite by construction), so an
    unfaulted run schedules nothing extra and stays bit-identical to
    pre-fault builds.

    ``wall_deadline`` (a ``time.monotonic`` timestamp) bounds *wall
    clock*, not simulated time: the serving layer sets it so a DES that
    would blow a request deadline raises ``SimWallDeadline`` instead of
    stalling the wave.  Unset, the hot loop is untouched.
    """

    pooling = True

    def __init__(self, trace: bool = False):
        self.now = 0.0
        self._heap: list = []        # (t, seq, fn, arg) tuples, heap order
        # same-instant FIFO as parallel arrays (gc-neutral; see module
        # docstring), consumed through the shared head cursor
        self._nq_seq: list = []
        self._nq_fn: list = []
        self._nq_arg: list = []
        self._nowq_head = 0
        self._seq = 0
        self._event_pool: list = []
        self.event_count = 0
        self.trace = TraceRecorder(self) if trace else NULL_RECORDER
        from repro.faults.inject import NULL_FAULTS
        self.faults = NULL_FAULTS
        self.wall_deadline: Optional[float] = None
        # metrics sink (repro.obs): NULL_METRICS unless a caller hangs a
        # registry here; run() then takes the metered mirror loop, so
        # the hot loop below never tests the flag per event.  Recycles
        # are counted unconditionally — one int add inside a function
        # call that already happened, invisible next to the event cost.
        self.metrics = NULL_METRICS
        self.recycles = 0
        self._recycles_seen = 0

    def event(self) -> Event:
        pool = self._event_pool
        if pool:
            return pool.pop()
        return Event(self)

    def _recycle_event(self, ev: Event) -> None:
        """Return an event to the pool.  Caller must guarantee no live
        references remain (SimMPI's receive-wait events qualify: they
        never escape the recv generator that made them)."""
        ev._set = False
        ev.payload = None
        # waiters is already empty after set(); a killed waiter path
        # never recycles, so no defensive clear needed — but it's cheap
        ev.waiters.clear()
        self._event_pool.append(ev)
        self.recycles += 1

    def pending(self) -> int:
        """Events scheduled but not yet dispatched (both queues)."""
        return len(self._heap) + len(self._nq_seq) - self._nowq_head

    def queue_depth(self) -> int:
        """Alias for ``pending()`` — the bench's peak-depth probe."""
        return self.pending()

    def _schedule(self, dt: float, fn: Callable, arg: Any):
        seq = self._seq + 1
        self._seq = seq
        if dt == 0.0:
            self._nq_seq.append(seq)
            self._nq_fn.append(fn)
            self._nq_arg.append(arg)
        else:
            heapq.heappush(self._heap, (self.now + dt, seq, fn, arg))

    def call_at(self, t: float, fn: Callable, arg: Any = None):
        seq = self._seq + 1
        self._seq = seq
        if t <= self.now:            # legacy max(t, now) clamp -> FIFO
            self._nq_seq.append(seq)
            self._nq_fn.append(fn)
            self._nq_arg.append(arg)
        else:
            heapq.heappush(self._heap, (t, seq, fn, arg))

    def spawn(self, gen: Generator, name: str = "") -> Process:
        proc = Process(self, gen, name)
        self._schedule(0.0, proc, None)
        return proc

    def set_wall_deadline(self, timeout_s: Optional[float]):
        """Bound the *wall clock* a run may burn: ``run()`` raises
        ``SimWallDeadline`` once ``timeout_s`` real seconds elapse.
        None clears the bound."""
        self.wall_deadline = (None if timeout_s is None
                              else time.monotonic() + timeout_s)

    def run(self, until: float = math.inf) -> float:
        if self.wall_deadline is not None:
            return self._run_deadline(until)
        if self.metrics.enabled:
            return self._run_metered(until)
        heap = self._heap
        seqs = self._nq_seq
        fns = self._nq_fn
        args = self._nq_arg
        pop = heapq.heappop
        head = self._nowq_head
        count = self.event_count
        now = self.now
        try:
            while True:
                if head < len(seqs):
                    # same-timestamp batch: drain FIFO entries at t ==
                    # now, yielding to the heap only when its top is an
                    # older (smaller-seq) event at the same instant
                    if now > until:
                        break
                    if heap:
                        s = heap[0]
                        if s[0] == now and s[1] < seqs[head]:
                            pop(heap)
                            count += 1
                            s[2](s[3])
                            continue
                    fn = fns[head]
                    arg = args[head]
                    head += 1
                    if head >= 8192:
                        # compact the drained prefix so long
                        # same-timestamp cascades don't grow the arrays
                        # (and pin payload refs) without bound
                        del seqs[:head]
                        del fns[:head]
                        del args[:head]
                        head = 0
                    count += 1
                    fn(arg)
                    continue
                if head:
                    seqs.clear()
                    fns.clear()
                    args.clear()
                    head = 0
                if not heap:
                    break
                s = heap[0]
                t = s[0]
                if t > until:
                    break
                pop(heap)
                self.now = now = t
                count += 1
                s[2](s[3])
        finally:
            self.event_count = count
            self._nowq_head = head
        return self.now

    def _run_metered(self, until: float) -> float:
        # metrics-on mirror of run(): same dispatch order (the registry
        # never schedules events, so simulated results stay
        # bit-identical — asserted in tests/test_obs.py), plus a
        # queue-depth high-water probe per dispatched event and a
        # metrics flush on exit.  Kept separate so the metrics-off hot
        # loop above never pays for either.
        heap = self._heap
        seqs = self._nq_seq
        fns = self._nq_fn
        args = self._nq_arg
        pop = heapq.heappop
        head = self._nowq_head
        count = self.event_count
        now = self.now
        ev0 = count
        hw = 0
        t0 = time.perf_counter()
        try:
            while True:
                depth = len(heap) + len(seqs) - head
                if depth > hw:
                    hw = depth
                if head < len(seqs):
                    if now > until:
                        break
                    if heap:
                        s = heap[0]
                        if s[0] == now and s[1] < seqs[head]:
                            pop(heap)
                            count += 1
                            s[2](s[3])
                            continue
                    fn = fns[head]
                    arg = args[head]
                    head += 1
                    if head >= 8192:
                        del seqs[:head]   # see run(): bound retention
                        del fns[:head]
                        del args[:head]
                        head = 0
                    count += 1
                    fn(arg)
                    continue
                if head:
                    seqs.clear()
                    fns.clear()
                    args.clear()
                    head = 0
                if not heap:
                    break
                s = heap[0]
                t = s[0]
                if t > until:
                    break
                pop(heap)
                self.now = now = t
                count += 1
                s[2](s[3])
        finally:
            self.event_count = count
            self._nowq_head = head
            self._flush_metrics(ev0, t0, high_water=hw)
        return self.now

    def _flush_metrics(self, ev0: int, t0: float,
                       high_water: Optional[int] = None) -> None:
        """Record one run()'s engine telemetry into ``self.metrics``
        (events, events/s distribution, queue-depth high-water via the
        ``queue_depth()`` probe, pool recycle rate)."""
        m = self.metrics
        ev = self.event_count - ev0
        dt = time.perf_counter() - t0
        m.counter("engine.runs").inc()
        m.counter("engine.events").inc(ev)
        rec = self.recycles - self._recycles_seen
        self._recycles_seen = self.recycles
        m.counter("engine.event_recycles").inc(rec)
        m.gauge("engine.event_pool").set(len(self._event_pool))
        if high_water is not None:
            m.gauge("engine.queue_depth_peak").set(high_water)
        if ev and dt > 0.0:
            m.histogram("engine.events_per_s",
                        buckets=_EVENTS_PER_S_BUCKETS).observe(ev / dt)
            m.histogram("engine.run_wall_s").observe(dt)
            m.histogram("engine.recycle_rate",
                        buckets=_RECYCLE_BUCKETS).observe(rec / ev)

    def _run_deadline(self, until: float) -> float:
        # separate loop so the unbudgeted hot path above stays
        # untouched; the clock syscall is amortized over 1024-event
        # slices.  Dispatch logic mirrors run() exactly (equivalence is
        # asserted under deadline in tests/test_engine_order.py).  With
        # a metrics registry attached, flush engine telemetry on the
        # way out (including the SimWallDeadline path).
        if self.metrics.enabled:
            ev0, t0 = self.event_count, time.perf_counter()
            try:
                return self._run_deadline_loop(until)
            finally:
                self._flush_metrics(ev0, t0)
        return self._run_deadline_loop(until)

    def _run_deadline_loop(self, until: float) -> float:
        heap = self._heap
        seqs = self._nq_seq
        fns = self._nq_fn
        args = self._nq_arg
        pop = heapq.heappop
        deadline = self.wall_deadline
        while True:
            if time.monotonic() > deadline:
                raise SimWallDeadline(
                    f"wall-clock budget expired at sim t={self.now:.9g}s "
                    f"({self.event_count} events, {self.pending()} pending)")
            head = self._nowq_head
            count = self.event_count
            budget = 1024
            try:
                while budget:
                    budget -= 1
                    if head < len(seqs):
                        if self.now > until:
                            return self.now
                        if heap:
                            s = heap[0]
                            if s[0] == self.now and s[1] < seqs[head]:
                                pop(heap)
                                count += 1
                                s[2](s[3])
                                continue
                        fn = fns[head]
                        arg = args[head]
                        head += 1
                        if head >= 8192:
                            del seqs[:head]   # see run(): bound retention
                            del fns[:head]
                            del args[:head]
                            head = 0
                        count += 1
                        fn(arg)
                        continue
                    if head:
                        seqs.clear()
                        fns.clear()
                        args.clear()
                        head = 0
                    if not heap:
                        return self.now
                    s = heap[0]
                    t = s[0]
                    if t > until:
                        return self.now
                    pop(heap)
                    self.now = t
                    count += 1
                    s[2](s[3])
            finally:
                self.event_count = count
                self._nowq_head = head

    def run_all(self) -> float:
        return self.run(math.inf)
