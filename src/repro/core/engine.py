"""Discrete-event simulation engine (the paper's SystemC/CoFluent analog).

Sequential engine; every simulated MPI rank / virtual thread is a Python
generator ("CoFluent virtual thread").  Processes yield:

    float/int        — wait that many simulated seconds
    Event            — park until the event fires
    Process          — park until the child process terminates (join)
    ("spawn", gen)   — start a child process, continue immediately

The paper's "privatization of global variables" workaround (§III-C) is
unnecessary here: each generator closes over its own state — documented in
DESIGN.md §9.
"""
from __future__ import annotations

import heapq
import math
from typing import Any, Callable, Generator, List, Optional

from repro.trace.recorder import NULL_RECORDER, TraceRecorder


class Event:
    __slots__ = ("engine", "_set", "waiters", "payload")

    def __init__(self, engine: "Engine"):
        self.engine = engine
        self._set = False
        self.waiters: List["Process"] = []
        self.payload: Any = None

    def set(self, payload: Any = None):
        if self._set:
            return
        self._set = True
        self.payload = payload
        for proc in self.waiters:
            self.engine._schedule(0.0, proc._step, payload)
        self.waiters.clear()

    @property
    def is_set(self) -> bool:
        return self._set


class Process:
    __slots__ = ("engine", "gen", "done", "_joiners", "name")

    def __init__(self, engine: "Engine", gen: Generator, name: str = ""):
        self.engine = engine
        self.gen = gen
        self.done = Event(engine)
        self.name = name

    def _step(self, send_value: Any = None):
        eng = self.engine
        try:
            while True:
                cmd = self.gen.send(send_value)
                send_value = None
                if isinstance(cmd, (int, float)):
                    if cmd < 0:
                        raise ValueError(f"negative wait {cmd} in {self.name}")
                    eng._schedule(float(cmd), self._step, None)
                    return
                if isinstance(cmd, Event):
                    if cmd.is_set:
                        send_value = cmd.payload
                        continue
                    cmd.waiters.append(self)
                    return
                if isinstance(cmd, Process):
                    if cmd.done.is_set:
                        continue
                    cmd.done.waiters.append(self)
                    return
                if isinstance(cmd, tuple) and cmd and cmd[0] == "spawn":
                    eng.spawn(cmd[1])
                    continue
                raise TypeError(f"bad yield {cmd!r} from {self.name}")
        except StopIteration:
            self.done.set()


class Engine:
    """Event loop.  Heap entries are ``(time, seq, fn, arg)``: ``seq`` is
    a monotonically increasing insertion number, so same-timestamp ties
    always fire in schedule order — event ordering (and therefore traces
    and results) is reproducible run-to-run.  Anything feeding the heap
    must iterate its own state deterministically too (see the ordered
    flow dicts in hardware/network.py).

    ``trace=True`` attaches a ``repro.trace.TraceRecorder``; off, the
    no-op NULL_RECORDER singleton sits there so instrumentation sites
    cost one attribute test and the loop itself is untouched.  The
    recorder never schedules events, so traced and untraced runs of the
    same scenario produce bit-identical simulated times.
    """

    def __init__(self, trace: bool = False):
        self.now = 0.0
        self._heap: list = []
        self._seq = 0
        self.event_count = 0
        self.trace = TraceRecorder(self) if trace else NULL_RECORDER

    def event(self) -> Event:
        return Event(self)

    def _schedule(self, dt: float, fn: Callable, arg: Any):
        self._seq += 1
        heapq.heappush(self._heap, (self.now + dt, self._seq, fn, arg))

    def call_at(self, t: float, fn: Callable, arg: Any = None):
        self._seq += 1
        heapq.heappush(self._heap, (max(t, self.now), self._seq, fn, arg))

    def spawn(self, gen: Generator, name: str = "") -> Process:
        proc = Process(self, gen, name)
        self._schedule(0.0, proc._step, None)
        return proc

    def run(self, until: float = math.inf) -> float:
        heap = self._heap
        while heap:
            t, _, fn, arg = heap[0]
            if t > until:
                break
            heapq.heappop(heap)
            self.now = t
            self.event_count += 1
            fn(arg)
        return self.now

    def run_all(self) -> float:
        return self.run(math.inf)
