"""Frozen pre-rewrite DES loop — the golden reference.

This is the per-event ``heapq``-of-tuples engine exactly as it stood
before the hot-loop rewrite (DESIGN.md §17).  It exists for two
reasons and must never be "improved":

  * **equivalence testing** — the rewritten engine must produce the
    *same event order and the same simulated times* as this loop on any
    program (tests/test_engine_order.py runs randomized spawn/wait/
    event/kill programs on both and diffs the sequences);
  * **benchmarking** — ``benchmarks/engine_bench.py`` reports the
    events/s ratio of the rewritten loop over this one (via
    ``legacy_des()``), so the speedup claim is measured on every run
    instead of asserted once.

Alongside the engine, ``LegacySimMPI`` and ``LegacyNetwork`` freeze the
pre-rewrite message layer (per-message closures, ``_Relay`` adapters,
per-send route computation, no Event/Flow recycling), and
``legacy_des()`` swaps the whole frozen stack into the app modules and
disables the SimBLAS panel-factorization cache — so a legacy run pays
the true pre-PR per-event cost, not a partially-optimized hybrid.
``LegacyEngine.pooling = False`` additionally tells the shared app code
(e.g. HPLSim's SimBLAS construction) to keep pre-rewrite behavior.
Results (event order, times, traces) are identical either way — the
frozen stack only changes speed.
"""
from __future__ import annotations

import contextlib
import heapq
import math
import time
from typing import Any, Callable, Dict, Generator, List, Optional, Sequence

from repro.core.engine import ProcessError, SimWallDeadline
from repro.core.hardware.network import Flow, Network
from repro.core.simmpi import RDV_HANDSHAKE, EAGER_LIMIT, SimMPI
from repro.trace.recorder import NULL_RECORDER, TraceRecorder


class LegacyEvent:
    __slots__ = ("engine", "_set", "waiters", "payload")

    def __init__(self, engine: "LegacyEngine"):
        self.engine = engine
        self._set = False
        self.waiters: List["LegacyProcess"] = []
        self.payload: Any = None

    def set(self, payload: Any = None):
        if self._set:
            return
        self._set = True
        self.payload = payload
        for proc in self.waiters:
            self.engine._schedule(0.0, proc._step, payload)
        self.waiters.clear()

    @property
    def is_set(self) -> bool:
        return self._set

    def _step(self, payload: Any = None):   # relay: see engine.Event._step
        self.set(payload)


class LegacyProcess:
    __slots__ = ("engine", "gen", "done", "_joiners", "name", "killed")

    def __init__(self, engine: "LegacyEngine", gen: Generator,
                 name: str = ""):
        self.engine = engine
        self.gen = gen
        self.done = LegacyEvent(engine)
        self.name = name
        self.killed = False

    def kill(self):
        self.killed = True
        self.gen.close()

    def _step(self, send_value: Any = None):
        if self.killed:
            return
        eng = self.engine
        try:
            while True:
                cmd = self.gen.send(send_value)
                send_value = None
                if isinstance(cmd, (int, float)):
                    if cmd < 0:
                        raise ValueError(f"negative wait {cmd} in {self.name}")
                    eng._schedule(float(cmd), self._step, None)
                    return
                if isinstance(cmd, LegacyEvent):
                    if cmd.is_set:
                        send_value = cmd.payload
                        continue
                    cmd.waiters.append(self)
                    return
                if isinstance(cmd, LegacyProcess):
                    if cmd.done.is_set:
                        continue
                    cmd.done.waiters.append(self)
                    return
                if isinstance(cmd, tuple) and cmd and cmd[0] == "spawn":
                    eng.spawn(cmd[1])
                    continue
                raise TypeError(f"bad yield {cmd!r} from {self.name}")
        except StopIteration:
            self.done.set()
        except ProcessError:
            raise
        except Exception as exc:
            raise ProcessError(
                f"DES process {self.name or '<unnamed>'} failed at "
                f"t={eng.now:.9g}s ({len(eng._heap)} pending events): "
                f"{type(exc).__name__}: {exc}",
                process=self.name, sim_time=eng.now,
                pending_events=len(eng._heap)) from exc


class LegacyEngine:
    """The pre-rewrite event loop: one ``(time, seq, fn, arg)`` tuple
    heap-pushed per event.  API-compatible with ``Engine`` so the whole
    application stack (SimMPI, Network, apps, faults, traces) runs on
    it unchanged."""

    pooling = False          # SimMPI/Network: no Event/Flow recycling

    def __init__(self, trace: bool = False):
        self.now = 0.0
        self._heap: list = []
        self._seq = 0
        self.event_count = 0
        self.trace = TraceRecorder(self) if trace else NULL_RECORDER
        from repro.faults.inject import NULL_FAULTS
        self.faults = NULL_FAULTS
        self.wall_deadline: Optional[float] = None

    def event(self) -> LegacyEvent:
        return LegacyEvent(self)

    def _recycle_event(self, ev) -> None:
        """No-op: the legacy loop never pools events."""

    def pending(self) -> int:
        return len(self._heap)

    def _schedule(self, dt: float, fn: Callable, arg: Any):
        self._seq += 1
        heapq.heappush(self._heap, (self.now + dt, self._seq, fn, arg))

    def call_at(self, t: float, fn: Callable, arg: Any = None):
        self._seq += 1
        heapq.heappush(self._heap, (max(t, self.now), self._seq, fn, arg))

    def spawn(self, gen: Generator, name: str = "") -> LegacyProcess:
        proc = LegacyProcess(self, gen, name)
        self._schedule(0.0, proc._step, None)
        return proc

    def set_wall_deadline(self, timeout_s: Optional[float]):
        self.wall_deadline = (None if timeout_s is None
                              else time.monotonic() + timeout_s)

    def run(self, until: float = math.inf) -> float:
        heap = self._heap
        if self.wall_deadline is not None:
            return self._run_deadline(until)
        while heap:
            t, _, fn, arg = heap[0]
            if t > until:
                break
            heapq.heappop(heap)
            self.now = t
            self.event_count += 1
            fn(arg)
        return self.now

    def _run_deadline(self, until: float) -> float:
        heap = self._heap
        deadline = self.wall_deadline
        while heap:
            if time.monotonic() > deadline:
                raise SimWallDeadline(
                    f"wall-clock budget expired at sim t={self.now:.9g}s "
                    f"({self.event_count} events, {len(heap)} pending)")
            for _ in range(1024):
                if not heap:
                    break
                t, _, fn, arg = heap[0]
                if t > until:
                    return self.now
                heapq.heappop(heap)
                self.now = t
                self.event_count += 1
                fn(arg)
        return self.now

    def run_all(self) -> float:
        return self.run(math.inf)


class _Relay:
    """Pre-rewrite adapter: lets a Network Event set another Event on
    fire (the live stack appends the target event directly instead)."""
    __slots__ = ("target",)

    def __init__(self, target):
        self.target = target

    def _step(self, payload=None):
        self.target.set(payload)


class LegacySimMPI(SimMPI):
    """SimMPI exactly as it stood before the hot-loop rewrite: one
    closure + one ``_Relay`` allocated per message, bare matchbox
    entries, no event recycling, and a wrapper generator frame around
    every untraced collective.  Collectives are inherited — they were
    not touched by the rewrite."""

    def isend(self, src: int, dst: int, nbytes: float, tag=0):
        # counter storage moved to attributes (SimMPI.counters is a
        # read-only property now); cost is equivalent to the pre-PR
        # dict increments
        self._p2p_msgs += 1
        self._p2p_bytes += nbytes
        eng = self.engine
        overhead = self.overhead * eng.faults.latency_factor(src) \
            if eng.faults.enabled else self.overhead
        eager = nbytes <= EAGER_LIMIT
        transfer_done = eng.event()
        if src == dst:
            eng.call_at(eng.now + overhead,
                        lambda _: transfer_done.set(), None)
            if eng.trace.enabled:
                eng.trace.msg_post(src, dst, nbytes, tag, transfer_done)
            return transfer_done
        lat_extra = 0.0 if eager \
            else RDV_HANDSHAKE * self.net.topo.base_latency

        def go(_):
            flow_done = self.net.send(self.rank_to_node(src),
                                      self.rank_to_node(dst), nbytes)
            flow_done.waiters.append(_Relay(transfer_done))
        eng.call_at(eng.now + overhead + lat_extra, go, None)
        if eng.trace.enabled:
            eng.trace.msg_post(src, dst, nbytes, tag, transfer_done)

        key = (src, dst, tag)
        waiters = self._recv_wait.get(key)
        if waiters:
            waiters.pop(0).set(transfer_done)
        else:
            self._posted.setdefault(key, []).append(transfer_done)
        if eager:
            send_done = eng.event()
            eng.call_at(eng.now + overhead,
                        lambda _: send_done.set(), None)
            return send_done
        return transfer_done

    def recv(self, src: int, dst: int, tag=0):
        tr = self.engine.trace
        t0 = self.engine.now if tr.enabled else 0.0
        key = (src, dst, tag)
        box = self._posted.get(key)
        if box:
            transfer = box.pop(0)
        else:
            w = self.engine.event()
            self._recv_wait.setdefault(key, []).append(w)
            transfer = yield w
        yield transfer
        if tr.enabled:
            tr.recv_done(dst, src, t0, transfer)

    def _traced(self, name: str, rank: int, group: List[int],
                nbytes: float, op_id, impl):
        tr = self.engine.trace
        if not tr.enabled:
            yield from impl
            return
        tok = tr.coll_begin(rank, name, op_id, group, nbytes)
        yield from impl
        tr.coll_end(rank, tok)


class LegacyNetwork(Network):
    """Network exactly as it stood before the hot-loop rewrite: a route
    computed per send, a closure per flow start, full progressive
    filling even for singleton components, and no Flow recycling."""

    def __init__(self, engine, topology, *, min_flow_time: float = 0.0):
        self.engine = engine
        self.topo = topology
        self.flows: Dict[Flow, None] = {}
        self.min_flow_time = min_flow_time

    def _component(self, seeds: Sequence[Flow]) -> List[Flow]:
        seen = set()
        out: List[Flow] = []
        stack = [f for f in seeds if f in self.flows]
        seen.update(id(f) for f in stack)
        seen_links: set = set()
        while stack:
            f = stack.pop()
            out.append(f)
            for l in f.links:
                if id(l) in seen_links:
                    continue
                seen_links.add(id(l))
                for g in l.flows:
                    if id(g) not in seen:
                        seen.add(id(g))
                        stack.append(g)
        return out

    def _reallocate(self, seeds: Optional[Sequence[Flow]] = None):
        now = self.engine.now
        comp = self._component(seeds) if seeds is not None \
            else list(self.flows)
        for f in comp:
            if f.rate > 0:
                f.remaining -= f.rate * (now - f._last_t)
                if f.remaining < 0:
                    f.remaining = 0.0
            f._last_t = now
        links: Dict[int, List[Flow]] = {}
        link_objs: Dict = {}
        for f in comp:
            f.rate = -1.0
            for l in f.links:
                links.setdefault(id(l), []).append(f)
                link_objs[id(l)] = l
        remaining_cap = {lid: link_objs[lid].capacity for lid in links}
        unassigned = dict(links)
        n_active = len(comp)
        while n_active > 0:
            best_lid, best_share = None, math.inf
            for lid, fl in unassigned.items():
                n = sum(1 for f in fl if f.rate < 0)
                if n == 0:
                    continue
                share = remaining_cap[lid] / n
                if share < best_share:
                    best_share, best_lid = share, lid
            if best_lid is None:
                for f in comp:
                    if f.rate < 0:
                        f.rate = math.inf
                        n_active -= 1
                break
            for f in unassigned[best_lid]:
                if f.rate < 0:
                    f.rate = best_share
                    n_active -= 1
                    for l in f.links:
                        remaining_cap[id(l)] -= best_share
            unassigned.pop(best_lid)
        for f in comp:
            f._version += 1
            if f.rate <= 0:
                continue
            t_done = now + (f.remaining / f.rate if f.rate < math.inf else 0.0)
            self.engine.call_at(t_done, self._maybe_complete,
                                (f, f._version))

    def _maybe_complete(self, arg):
        f, version = arg
        if f._version != version or f not in self.flows:
            return
        now = self.engine.now
        f.remaining -= f.rate * (now - f._last_t)
        f._last_t = now
        if f.remaining > 1e-9 * max(f.size, 1.0):
            return
        self.flows.pop(f, None)
        neighbors = [g for l in f.links for g in l.flows if g is not f]
        for l in f.links:
            l.flows.pop(f, None)
        if neighbors:
            self._reallocate(neighbors)
        f.done.set()

    def send(self, src: int, dst: int, size: float):
        done = self.engine.event()
        links = self.topo.route(src, dst)
        latency = sum(l.latency for l in links) + self.topo.base_latency
        if not links or size <= 0:
            self.engine.call_at(self.engine.now + latency,
                                lambda _: done.set(), None)
            return done
        f = Flow(size, links, done)

        def start(_):
            f._last_t = self.engine.now
            self.flows[f] = None
            for l in f.links:
                l.flows[f] = None
            self._reallocate([f])
        self.engine.call_at(self.engine.now + latency, start, None)
        return done


@contextlib.contextmanager
def legacy_des():
    """Run the DES application stack on the frozen pre-rewrite stack.

    Swaps ``LegacyEngine``, ``LegacySimMPI`` and ``LegacyNetwork`` into
    the app modules (they construct these from module-level names) and
    disables the SimBLAS panel-factorization cache, so runs inside the
    context pay the true pre-PR per-event and per-call costs.
    Test/bench instrumentation only — results are bit-identical to the
    rewritten path by contract (asserted in tests/test_engine_order.py)."""
    import repro.core.apps.hpl as hpl_mod
    import repro.core.apps.transformer as tr_mod
    import repro.core.simblas as simblas_mod

    saved = (hpl_mod.Engine, tr_mod.Engine, simblas_mod.PANEL_CACHE,
             hpl_mod.Network, tr_mod.Network, hpl_mod.SimMPI,
             tr_mod.SimMPI)
    hpl_mod.Engine = LegacyEngine
    tr_mod.Engine = LegacyEngine
    simblas_mod.PANEL_CACHE = False
    hpl_mod.Network = LegacyNetwork
    tr_mod.Network = LegacyNetwork
    hpl_mod.SimMPI = LegacySimMPI
    tr_mod.SimMPI = LegacySimMPI
    try:
        yield LegacyEngine
    finally:
        (hpl_mod.Engine, tr_mod.Engine, simblas_mod.PANEL_CACHE,
         hpl_mod.Network, tr_mod.Network, hpl_mod.SimMPI,
         tr_mod.SimMPI) = saved
