"""The paper's primary contribution: a full-system performance-prediction
simulator (hardware layer + library models + application layer on a
low-overhead DES), plus its JAX-vectorized exascale path and the TPU/XLA
adaptation.  See DESIGN.md §1-2."""
from .engine import Engine, Event, Process
from .simblas import SimBLAS
from .simmpi import SimMPI
from .calibrate import (calibrate, measure_dgemm, fit_linear,
                        fit_fastsim_params)
from .fastsim import (FastSimParams, simulate_hpl_fast, sweep_hpl,
                      simulate_time_traced)
from .simxla import SimXLA, ICIParams, ICI, collective_time
from .predict import (predict_cell, predict_cell_des, whatif, whatif_grid,
                      load_record)

__all__ = ["Engine", "Event", "Process", "SimBLAS", "SimMPI", "calibrate",
           "measure_dgemm", "fit_linear", "fit_fastsim_params",
           "FastSimParams", "simulate_hpl_fast", "sweep_hpl",
           "simulate_time_traced", "SimXLA", "ICIParams", "ICI",
           "collective_time", "predict_cell", "predict_cell_des", "whatif",
           "whatif_grid", "load_record"]
