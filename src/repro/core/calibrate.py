"""Microbenchmark calibration (paper §III-B1, Fig 2) — plus end-to-end
gradient calibration of the fastsim parameters.

Measures *real* BLAS performance on this host via numpy and fits the
SimBLAS analytical model ``E = mu * ops + theta`` by least squares,
reporting R^2 (the paper reports R^2 = 0.9998 for MKL DGEMM on a
Broadwell core; we run the same protocol on this container's CPU).
Memory-bound Level-1 ops calibrate the effective bandwidth the same way.

``fit_fastsim_params`` goes beyond the paper's per-kernel fits: because
the fast simulator traces its parameters (DESIGN.md §11),
``jax.value_and_grad`` differentiates the *entire* HPL panel recurrence
with respect to them, so measured full-application runtimes can be fit
directly — the simulation-based-optimization loop of Cornebize &
Legrand, with gradients instead of black-box search.
"""
from __future__ import annotations

import dataclasses
import math
import time
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np


@dataclasses.dataclass
class FitResult:
    mu: float                 # s per flop
    theta: float              # s per call
    r2: float
    points: List[Tuple[float, float]]   # (ops, seconds)

    @property
    def eff_flops(self) -> float:
        return 1.0 / self.mu


def _time_call(fn, min_time: float = 0.05, max_reps: int = 200) -> float:
    fn()  # warmup
    reps, total = 0, 0.0
    t0 = time.perf_counter()
    while total < min_time and reps < max_reps:
        fn()
        reps += 1
        total = time.perf_counter() - t0
    return total / reps


def fit_linear(points: Sequence[Tuple[float, float]]) -> FitResult:
    ops = np.array([p[0] for p in points])
    ts = np.array([p[1] for p in points])
    A = np.stack([ops, np.ones_like(ops)], axis=1)
    (mu, theta), *_ = np.linalg.lstsq(A, ts, rcond=None)
    pred = A @ np.array([mu, theta])
    ss_res = float(np.sum((ts - pred) ** 2))
    ss_tot = float(np.sum((ts - ts.mean()) ** 2))
    r2 = 1.0 - ss_res / max(ss_tot, 1e-30)
    return FitResult(mu=float(mu), theta=float(max(theta, 0.0)), r2=r2,
                     points=list(points))


def measure_dgemm(sizes: Optional[Sequence[int]] = None,
                  min_time: float = 0.05) -> FitResult:
    """Paper Fig 2 protocol: square-ish DGEMMs, m,n,k in [128, 2048]."""
    sizes = sizes or [128, 192, 256, 384, 512, 768, 1024, 1536]
    rng = np.random.default_rng(0)
    points = []
    for m in sizes:
        for k in (m // 2, m):
            a = rng.standard_normal((m, k))
            b = rng.standard_normal((k, m))
            t = _time_call(lambda: a @ b, min_time=min_time)
            ops = 2.0 * m * m * k + 2.0 * m * m
            points.append((ops, t))
    return fit_linear(points)


def measure_stream(n: int = 1 << 24, min_time: float = 0.1) -> float:
    """Effective memory bandwidth (B/s) via a daxpy-like triad."""
    rng = np.random.default_rng(0)
    x = rng.standard_normal(n)
    y = rng.standard_normal(n)

    def triad():
        y.__iadd__(0.5 * x)        # read x, read/write y
    t = _time_call(triad, min_time=min_time)
    return 8.0 * 3.0 * n / t


def measure_memop(op: str = "swap", n: int = 1 << 22,
                  min_time: float = 0.05) -> Tuple[float, float]:
    """Returns (bytes_touched, seconds) for a Level-1 style op."""
    rng = np.random.default_rng(0)
    x = rng.standard_normal(n)
    y = rng.standard_normal(n)
    if op == "swap":
        def fn():
            x[:], y[:] = y, np.array(x)
        nbytes = 8.0 * 4.0 * n
    elif op == "scal":
        def fn():
            x.__imul__(1.0000001)
        nbytes = 8.0 * 2.0 * n
    elif op == "copy":
        def fn():
            y[:] = x
        nbytes = 8.0 * 2.0 * n
    else:
        raise ValueError(op)
    t = _time_call(fn, min_time=min_time)
    return nbytes, t


def measure_dger(m: int = 1024, n: int = 128,
                 min_time: float = 0.05) -> float:
    """Effective bandwidth (B/s) of a dger-style rank-1 panel update at
    HPL-panel-like sizes.  Panels are often cache-resident, so this runs
    far above DRAM triad bandwidth — the paper calibrates *per kernel*
    efficiency for exactly this reason (§III-B1)."""
    rng = np.random.default_rng(0)
    A = rng.standard_normal((m, n))
    x = rng.standard_normal(m)
    y = rng.standard_normal(n)

    def fn():
        A.__isub__(np.outer(x, y))
    t = _time_call(fn, min_time=min_time)
    return 8.0 * (2.0 * m * n + m + n) / t


def measure_small_overhead(min_time: float = 0.05) -> float:
    """Per-call dispatch overhead of a tiny Level-1 op (numpy slicing +
    dispatch; a C BLAS would be ~10x lower — this calibrates OUR
    measurement substrate, exactly the paper's point that mu/theta are
    implementation-dependent)."""
    rng = np.random.default_rng(0)
    A = rng.standard_normal((256, 64))

    def fn():
        A[1:, 0] /= 1.0000001
        A[1:, 1:4] -= np.outer(A[1:, 0], A[0, 1:4])
    t = _time_call(fn, min_time=min_time)
    return t / 2.0          # two calls per fn


@dataclasses.dataclass
class CalibrationProfile:
    dgemm: FitResult
    mem_bw: float            # effective B/s (DRAM triad)
    panel_bw: float = 0.0    # effective B/s of panel-sized Level-1/2 ops
    theta_mem: float = 2e-6  # per-call overhead of Level-1/2 ops

    def as_dict(self) -> Dict:
        return {"mu": self.dgemm.mu, "theta": self.dgemm.theta,
                "r2": self.dgemm.r2, "eff_flops": self.dgemm.eff_flops,
                "mem_bw": self.mem_bw, "panel_bw": self.panel_bw,
                "theta_mem": self.theta_mem}


def calibrate(quick: bool = False) -> CalibrationProfile:
    sizes = [128, 256, 512, 1024] if quick else None
    return CalibrationProfile(
        dgemm=measure_dgemm(sizes=sizes,
                            min_time=0.02 if quick else 0.05),
        mem_bw=measure_stream(n=1 << 22 if quick else 1 << 24),
        panel_bw=measure_dger(),
        theta_mem=measure_small_overhead())


# ------------------------------------------------- gradient calibration

FASTSIM_FIT_FIELDS = ("gemm_eff", "mem_bw", "link_bw", "theta",
                      "net_latency")


@dataclasses.dataclass
class FastSimFit:
    params: "FastSimParams"          # calibrated parameters
    loss0: float                     # initial mean squared log-time error
    loss: float                      # final
    steps: int
    history: List[float]             # loss per step

    @property
    def improvement(self) -> float:
        return self.loss0 / max(self.loss, 1e-30)


def fit_fastsim_params(runs: Sequence[Tuple["HPLConfig", float]],
                       init: "FastSimParams",
                       fields: Sequence[str] = FASTSIM_FIT_FIELDS,
                       steps: int = 300, lr: float = 0.1) -> FastSimFit:
    """Fit ``fields`` of a FastSimParams to measured HPL runtimes.

    ``runs`` is a list of ``(HPLConfig, measured_seconds)``.  The loss is
    the mean squared log-time error; parameters are optimized in log
    space (positivity) with Adam, and the whole value-and-grad — every
    panel recurrence of every run — is one jitted program.
    """
    import jax
    import jax.numpy as jnp
    from jax.experimental import enable_x64
    from repro.train.optimizer import adamw_init, adamw_update
    from .fastsim import FastSimParams, _f64_params, simulate_time_traced

    runs = list(runs)
    fields = tuple(fields)
    base = dataclasses.asdict(_f64_params(init))
    logt_meas = [math.log(t) for _, t in runs]

    def loss_fn(theta):
        over = dict(base)
        for name, v in zip(fields, theta):
            over[name] = jnp.exp(v)
        prm = FastSimParams(**over)
        errs = [jnp.log(simulate_time_traced(cfg, prm)) - lm
                for (cfg, _), lm in zip(runs, logt_meas)]
        return sum(e * e for e in errs) / len(runs)

    with enable_x64(True):
        vg = jax.jit(jax.value_and_grad(loss_fn))
        theta = jnp.asarray([math.log(base[f]) for f in fields],
                            jnp.float64)
        state = adamw_init(theta)
        history: List[float] = []
        for _ in range(steps):
            val, g = vg(theta)
            history.append(float(val))
            theta, state, _ = adamw_update(theta, g, state, lr=lr,
                                           b2=0.999, weight_decay=0.0,
                                           max_grad_norm=1e9)
        final = float(vg(theta)[0])
        theta = np.asarray(theta)

    fitted = dict(base)
    for name, t in zip(fields, theta):
        fitted[name] = float(math.exp(t))
    return FastSimFit(params=FastSimParams(**fitted),
                      loss0=history[0] if history else final,
                      loss=final, steps=steps, history=history)
