"""Public prediction API: the paper's use-case surface.

``predict_cell(arch, shape, mesh)`` reads the dry-run record (lower+compile
already done by launch/dryrun.py) and returns SimXLA's analytic step-time
prediction; ``predict_cell_des`` runs the full DES with contention /
stragglers.  ``whatif`` re-predicts under hardware deltas (faster links,
more HBM bandwidth, straggler chips) — §V of the paper, TPU edition.
``whatif_grid`` is the HPL edition at sweep scale: a cartesian grid of
hardware deltas evaluated as one batched fastsim program.
"""
from __future__ import annotations

import dataclasses
import itertools
import json
from pathlib import Path
from typing import Dict, Mapping, Optional, Sequence

from repro.configs import get_config, get_shape
from .hardware.node import NodeModel, TPU_V5E
from .simxla import ICIParams, ICI, SimXLA, StepPrediction
from .apps.transformer import StepWorkload, TransformerStepSim

DRYRUN_DIR = Path("experiments/dryrun")


def load_record(arch: str, shape: str, mesh: str = "16x16",
                dryrun_dir: Path = DRYRUN_DIR) -> Dict:
    p = Path(dryrun_dir) / f"{arch}__{shape}__{mesh}.json"
    if not p.exists():
        raise FileNotFoundError(
            f"dry-run record {p} missing — run "
            f"`python -m repro.launch.dryrun --arch {arch} --shape {shape}`")
    return json.loads(p.read_text())


def predict_cell(arch: str, shape: str, mesh: str = "16x16",
                 chip: NodeModel = TPU_V5E, ici: ICIParams = ICI,
                 overlap: float = 0.7,
                 dryrun_dir: Path = DRYRUN_DIR) -> StepPrediction:
    rec = load_record(arch, shape, mesh, dryrun_dir)
    return SimXLA(chip=chip, ici=ici, overlap=overlap).predict(rec)


def predict_cell_des(arch: str, shape: str, mesh: str = "16x16",
                     straggler=None, jitter: float = 0.0,
                     dryrun_dir: Path = DRYRUN_DIR) -> Dict:
    rec = load_record(arch, shape, mesh, dryrun_dir)
    cfg = get_config(arch)
    wl = StepWorkload.from_dryrun_record(rec, cfg.num_layers)
    pods = 2 if mesh == "2x16x16" else 1
    sim = TransformerStepSim(wl, mesh=(16, 16), pods=pods,
                             straggler=straggler, jitter=jitter)
    return sim.run()


def whatif(arch: str, shape: str, mesh: str = "16x16", *,
           link_bw_scale: float = 1.0, hbm_bw_scale: float = 1.0,
           peak_scale: float = 1.0,
           dryrun_dir: Path = DRYRUN_DIR) -> Dict:
    """Paper §V for the TPU case study: predict the win from a hardware
    change without re-running anything on hardware."""
    base = predict_cell(arch, shape, mesh, dryrun_dir=dryrun_dir)
    chip = dataclasses.replace(TPU_V5E,
                               peak_flops=TPU_V5E.peak_flops * peak_scale,
                               mem_bw=TPU_V5E.mem_bw * hbm_bw_scale)
    ici = dataclasses.replace(ICI, link_bw=ICI.link_bw * link_bw_scale)
    new = predict_cell(arch, shape, mesh, chip=chip, ici=ici,
                       dryrun_dir=dryrun_dir)
    return {"baseline_s": base.step_s, "whatif_s": new.step_s,
            "speedup": base.step_s / max(new.step_s, 1e-12),
            "baseline": base, "whatif": new}


def whatif_grid(cfg, base_params, axes: Mapping[str, Sequence[float]], *,
                mode: str = "scale") -> list:
    """Paper §V at sweep scale: evaluate a cartesian grid of hardware
    what-ifs for one HPL config in a single batched fastsim program.

    ``axes`` maps FastSimParams field names to multipliers
    (``mode="scale"``, default) or absolute values (``mode="abs"``), e.g.
    ``{"link_bw": [1, 2, 4], "mem_bw": [1.0, 1.25]}`` — 6 scenarios plus
    the baseline, all served by one compile (bucketed sweep engine).

    Returns one dict per grid point, in ``itertools.product`` order, with
    the axis values, ``time_s``/``gflops``/``tflops``, and ``speedup``
    over the unmodified baseline.
    """
    from .fastsim import sweep_hpl

    if mode not in ("scale", "abs"):
        raise ValueError(f"whatif_grid: mode must be scale|abs, got {mode}")
    names = list(axes)
    combos = list(itertools.product(*[axes[n] for n in names]))
    grid = []
    for combo in combos:
        over = {n: (getattr(base_params, n) * v if mode == "scale" else v)
                for n, v in zip(names, combo)}
        grid.append(dataclasses.replace(base_params, **over))
    res = sweep_hpl(cfg, [base_params] + grid)   # lane 0 = baseline
    base_t = res[0]["time_s"]
    out = []
    for combo, r in zip(combos, res[1:]):
        row = dict(zip(names, combo))
        row.update(r)
        row["speedup"] = base_t / r["time_s"]
        out.append(row)
    return out
