"""Public prediction API: the paper's use-case surface.

``predict_cell(arch, shape, mesh)`` reads the dry-run record (lower+compile
already done by launch/dryrun.py) and returns SimXLA's analytic step-time
prediction; ``predict_cell_des`` runs the full DES with contention /
stragglers.  ``whatif`` re-predicts under hardware deltas (faster links,
more HBM bandwidth, straggler chips) — §V of the paper, TPU edition.
"""
from __future__ import annotations

import dataclasses
import json
from pathlib import Path
from typing import Dict, Optional

from repro.configs import get_config, get_shape
from .hardware.node import NodeModel, TPU_V5E
from .simxla import ICIParams, ICI, SimXLA, StepPrediction
from .apps.transformer import StepWorkload, TransformerStepSim

DRYRUN_DIR = Path("experiments/dryrun")


def load_record(arch: str, shape: str, mesh: str = "16x16",
                dryrun_dir: Path = DRYRUN_DIR) -> Dict:
    p = Path(dryrun_dir) / f"{arch}__{shape}__{mesh}.json"
    if not p.exists():
        raise FileNotFoundError(
            f"dry-run record {p} missing — run "
            f"`python -m repro.launch.dryrun --arch {arch} --shape {shape}`")
    return json.loads(p.read_text())


def predict_cell(arch: str, shape: str, mesh: str = "16x16",
                 chip: NodeModel = TPU_V5E, ici: ICIParams = ICI,
                 overlap: float = 0.7,
                 dryrun_dir: Path = DRYRUN_DIR) -> StepPrediction:
    rec = load_record(arch, shape, mesh, dryrun_dir)
    return SimXLA(chip=chip, ici=ici, overlap=overlap).predict(rec)


def predict_cell_des(arch: str, shape: str, mesh: str = "16x16",
                     straggler=None, jitter: float = 0.0,
                     dryrun_dir: Path = DRYRUN_DIR) -> Dict:
    rec = load_record(arch, shape, mesh, dryrun_dir)
    cfg = get_config(arch)
    wl = StepWorkload.from_dryrun_record(rec, cfg.num_layers)
    pods = 2 if mesh == "2x16x16" else 1
    sim = TransformerStepSim(wl, mesh=(16, 16), pods=pods,
                             straggler=straggler, jitter=jitter)
    return sim.run()


def whatif(arch: str, shape: str, mesh: str = "16x16", *,
           link_bw_scale: float = 1.0, hbm_bw_scale: float = 1.0,
           peak_scale: float = 1.0,
           dryrun_dir: Path = DRYRUN_DIR) -> Dict:
    """Paper §V for the TPU case study: predict the win from a hardware
    change without re-running anything on hardware."""
    base = predict_cell(arch, shape, mesh, dryrun_dir=dryrun_dir)
    chip = dataclasses.replace(TPU_V5E,
                               peak_flops=TPU_V5E.peak_flops * peak_scale,
                               mem_bw=TPU_V5E.mem_bw * hbm_bw_scale)
    ici = dataclasses.replace(ICI, link_bw=ICI.link_bw * link_bw_scale)
    new = predict_cell(arch, shape, mesh, chip=chip, ici=ici,
                       dryrun_dir=dryrun_dir)
    return {"baseline_s": base.step_s, "whatif_s": new.step_s,
            "speedup": base.step_s / max(new.step_s, 1e-12),
            "baseline": base, "whatif": new}
