"""Public prediction API: the paper's use-case surface.

``predict_cell(arch, shape, mesh)`` reads the dry-run record (lower+compile
already done by launch/dryrun.py) and returns SimXLA's analytic step-time
prediction; ``predict_cell_des`` runs the full DES with contention /
stragglers.  Chip and ICI parameters default to the ``tpu-v5e-pod``
registry spec and can be re-derived from any other platform via
``platform=``.  ``whatif`` re-predicts under hardware deltas (faster
links, more HBM bandwidth, straggler chips) — §V of the paper, TPU
edition.  ``whatif_grid`` is the sweep-scale edition: a cartesian grid of
hardware deltas evaluated as one batched program, for an ``HPLConfig``
(legacy form) or for any registered workload's fast model
(``whatif_grid(workload, platform, axes)``).
"""
from __future__ import annotations

import dataclasses
import itertools
import json
from pathlib import Path
from typing import Dict, Mapping, Optional, Sequence

from repro.configs import get_config, get_shape
from .hardware.node import NodeModel
from .simxla import ICIParams, SimXLA, StepPrediction, ici_from_platform
from .apps.transformer import StepWorkload, TransformerStepSim

DRYRUN_DIR = Path("experiments/dryrun")


def _resolve_platform(platform):
    if isinstance(platform, str):
        from repro.platforms import get_platform
        return get_platform(platform)
    return platform


def load_record(arch: str, shape: str, mesh: str = "16x16",
                dryrun_dir: Path = DRYRUN_DIR) -> Dict:
    p = Path(dryrun_dir) / f"{arch}__{shape}__{mesh}.json"
    if not p.exists():
        raise FileNotFoundError(
            f"dry-run record {p} missing — run "
            f"`python -m repro.launch.dryrun --arch {arch} --shape {shape}`")
    return json.loads(p.read_text())


def predict_cell(arch: str, shape: str, mesh: str = "16x16",
                 chip: Optional[NodeModel] = None,
                 ici: Optional[ICIParams] = None,
                 overlap: float = 0.7,
                 dryrun_dir: Path = DRYRUN_DIR,
                 platform="tpu-v5e-pod") -> StepPrediction:
    """Analytic step-time prediction for one compiled cell.  Hardware
    numbers come from ``platform`` (registry name or Platform spec);
    explicit ``chip``/``ici`` win over the spec-derived values."""
    rec = load_record(arch, shape, mesh, dryrun_dir)
    plat = _resolve_platform(platform)
    if chip is None:
        chip = plat.node_model()
    if ici is None:
        ici = ici_from_platform(plat)
    return SimXLA(chip=chip, ici=ici, overlap=overlap).predict(rec)


def predict_cell_des(arch: str, shape: str, mesh: str = "16x16",
                     straggler=None, jitter: float = 0.0,
                     dryrun_dir: Path = DRYRUN_DIR,
                     platform="tpu-v5e-pod", faults=None) -> Dict:
    rec = load_record(arch, shape, mesh, dryrun_dir)
    cfg = get_config(arch)
    plat = _resolve_platform(platform)
    wl = StepWorkload.from_dryrun_record(rec, cfg.num_layers,
                                         chip=plat.node_model())
    pods = 2 if mesh == "2x16x16" else 1
    sim = TransformerStepSim(wl, mesh=(16, 16), pods=pods,
                             chip=plat.node_model(),
                             ici=ici_from_platform(plat),
                             mpi_overhead=plat.mpi.overhead,
                             straggler=straggler, jitter=jitter,
                             faults=faults)
    return sim.run()


def whatif(arch: str, shape: str, mesh: str = "16x16", *,
           link_bw_scale: float = 1.0, hbm_bw_scale: float = 1.0,
           peak_scale: float = 1.0,
           dryrun_dir: Path = DRYRUN_DIR,
           platform="tpu-v5e-pod") -> Dict:
    """Paper §V for the TPU case study: predict the win from a hardware
    change without re-running anything on hardware."""
    plat = _resolve_platform(platform)
    base_chip = plat.node_model()
    base_ici = ici_from_platform(plat)
    base = predict_cell(arch, shape, mesh, chip=base_chip, ici=base_ici,
                        dryrun_dir=dryrun_dir)
    chip = dataclasses.replace(base_chip,
                               peak_flops=base_chip.peak_flops * peak_scale,
                               mem_bw=base_chip.mem_bw * hbm_bw_scale)
    ici = dataclasses.replace(base_ici,
                              link_bw=base_ici.link_bw * link_bw_scale)
    new = predict_cell(arch, shape, mesh, chip=chip, ici=ici,
                       dryrun_dir=dryrun_dir)
    return {"baseline_s": base.step_s, "whatif_s": new.step_s,
            "speedup": base.step_s / max(new.step_s, 1e-12),
            "baseline": base, "whatif": new}


def whatif_grid(scenario, base_params=None, axes: Mapping[str, Sequence[float]]
                = None, *, mode: str = "scale") -> list:
    """Paper §V at sweep scale: evaluate a cartesian grid of hardware
    what-ifs as one batched fastsim program.

    Two forms:

    * legacy HPL: ``whatif_grid(cfg, base_params, axes)`` with an
      ``HPLConfig`` and a ``FastSimParams`` baseline;
    * workload-generic: ``whatif_grid(workload, platform, axes)`` with
      any ``repro.workloads.Workload`` (the baseline params come from
      ``workload.fastsim_model(platform)``), or directly
      ``whatif_grid(model, None, axes)`` with a prebuilt ``FastModel``.

    ``axes`` maps params field names to multipliers (``mode="scale"``,
    default) or absolute values (``mode="abs"``), e.g.
    ``{"link_bw": [1, 2, 4], "mem_bw": [1.0, 1.25]}`` — 6 scenarios plus
    the baseline, all served by one compile (bucketed sweep engine).

    Returns one dict per grid point, in ``itertools.product`` order, with
    the axis values, the model's result fields (``time_s`` always), and
    ``speedup`` over the unmodified baseline.
    """
    if mode not in ("scale", "abs"):
        raise ValueError(f"whatif_grid: mode must be scale|abs, got {mode}")
    if hasattr(scenario, "fastsim_model"):          # a Workload
        if base_params is None:
            raise ValueError("whatif_grid(workload, platform, axes): the "
                             "second argument must be the platform")
        model = scenario.fastsim_model(_resolve_platform(base_params))
    elif hasattr(scenario, "sweep"):                # a prebuilt FastModel
        model = scenario
        if base_params is not None:
            model = dataclasses.replace(model, params=base_params)
    else:                                           # legacy HPLConfig form
        from repro.workloads.hpl import HPLFastModel
        model = HPLFastModel(cfg=scenario, params=base_params)

    base = model.params
    names = list(axes)
    combos = list(itertools.product(*[axes[n] for n in names]))
    grid = []
    for combo in combos:
        over = {n: (getattr(base, n) * v if mode == "scale" else v)
                for n, v in zip(names, combo)}
        grid.append(dataclasses.replace(base, **over))
    res = model.sweep([base] + grid)   # lane 0 = baseline
    base_t = res[0]["time_s"]
    out = []
    for combo, r in zip(combos, res[1:]):
        row = dict(zip(names, combo))
        row.update(r)
        row["speedup"] = base_t / r["time_s"]
        out.append(row)
    return out
