"""SimXLA — the SimBLAS/SimMPI idea adapted to the TPU/XLA world.

Where the paper models BLAS calls + MPI collectives on a fat-tree, the
TPU workload is XLA HLO ops + XLA collectives on an ICI torus.  The
library-layer models here consume the per-device (flops, bytes,
collective) trace extracted from the *compiled dry-run artifact*
(roofline/hlo_parse.py) — the exact analogue of substituting BLAS calls
with analytical models: data content never matters, only shapes.

Two fidelity levels (mirroring the paper's hybrid):
  * analytic (this module): closed-form ring/torus collective times +
    roofline op times + an overlap model;
  * DES (core/apps/transformer.py): per-rank virtual threads issuing
    flows on the Torus topology — contention is emergent.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict, List, Optional

from .hardware.node import NodeModel, TPU_V5E


@dataclasses.dataclass(frozen=True)
class ICIParams:
    link_bw: float = 50e9          # B/s per link per direction
    links_per_axis: int = 2        # bidirectional ring on each torus axis
    latency: float = 1e-6          # per collective-phase software latency
    dcn_bw: float = 25e9           # per-chip cross-pod bandwidth
    dcn_latency: float = 10e-6


ICI = ICIParams()


def ring_allreduce_time(nbytes: float, n: int, ici: ICIParams = ICI) -> float:
    """Bidirectional-ring all-reduce on one torus axis: reduce-scatter +
    all-gather, each moving (n-1)/n of the buffer over 2 links."""
    if n <= 1 or nbytes <= 0:
        return 0.0
    wire = 2.0 * (n - 1) / n * nbytes
    return wire / (ici.link_bw * ici.links_per_axis) \
        + 2.0 * (n - 1) * ici.latency


def ring_allgather_time(result_bytes: float, n: int,
                        ici: ICIParams = ICI) -> float:
    if n <= 1 or result_bytes <= 0:
        return 0.0
    wire = (n - 1) / n * result_bytes
    return wire / (ici.link_bw * ici.links_per_axis) + (n - 1) * ici.latency


def reduce_scatter_time(shard_bytes: float, n: int,
                        ici: ICIParams = ICI) -> float:
    if n <= 1 or shard_bytes <= 0:
        return 0.0
    wire = (n - 1) * shard_bytes
    return wire / (ici.link_bw * ici.links_per_axis) + (n - 1) * ici.latency


def all_to_all_time(nbytes: float, n: int, ici: ICIParams = ICI) -> float:
    """All-to-all on a ring: each chip sends (n-1)/n of its buffer; average
    hop distance n/4 on a bidirectional ring inflates wire occupancy."""
    if n <= 1 or nbytes <= 0:
        return 0.0
    wire = (n - 1) / n * nbytes * (n / 4.0) / max(n - 1, 1) * 2.0
    return wire / (ici.link_bw * ici.links_per_axis) + (n - 1) * ici.latency


def collective_permute_time(nbytes: float, ici: ICIParams = ICI) -> float:
    return nbytes / (ici.link_bw * ici.links_per_axis) + ici.latency


def collective_time(op: str, wire_bytes: float, group_size: int,
                    ici: ICIParams = ICI) -> float:
    """Time for one collective given the *ring wire bytes* already computed
    by the HLO analyzer (hlo_parse ring-algorithm convention)."""
    if wire_bytes <= 0:
        return 0.0
    n = max(group_size, 2)
    phases = {"all-reduce": 2 * (n - 1), "all-gather": n - 1,
              "reduce-scatter": n - 1, "all-to-all": n - 1,
              "collective-permute": 1}.get(op, n - 1)
    return wire_bytes / (ici.link_bw * ici.links_per_axis) \
        + phases * ici.latency


@dataclasses.dataclass
class StepPrediction:
    compute_s: float
    memory_s: float
    collective_s: float
    step_s: float
    bound_s: float
    breakdown: Dict[str, float]


class SimXLA:
    """Analytic step-time predictor for a compiled (arch x shape x mesh)
    cell, driven by the dry-run record."""

    def __init__(self, chip: NodeModel = TPU_V5E, ici: ICIParams = ICI,
                 overlap: float = 0.7, fusion_efficiency: float = 3.0):
        self.chip = chip
        self.ici = ici
        # fraction of collective time hidden under compute (XLA latency
        # hiding / async collectives)
        self.overlap = overlap
        # our HLO byte model counts op-boundary traffic on the *CPU*-
        # partitioned module; TPU fusion materializes ~1/fusion_efficiency
        # of those boundaries (calibratable; see EXPERIMENTS.md §Sim-accuracy)
        self.fusion_efficiency = fusion_efficiency

    def predict(self, record: Dict) -> StepPrediction:
        """record: one experiments/dryrun/*.json cell."""
        r = record["roofline"]
        flops = r["hlo_flops_total"] / record["chips"]
        nbytes = r["hlo_bytes_total"] / record["chips"]
        compute = flops / (self.chip.peak_flops * self.chip.gemm_efficiency)
        memory = (nbytes / self.fusion_efficiency
                  / (self.chip.mem_bw * self.chip.mem_efficiency))
        coll = 0.0
        per_op = {}
        for op, agg in record.get("collectives", {}).items():
            t = collective_time(op, agg["wire_bytes"],
                                group_size=16, ici=self.ici)
            per_op[op] = t
            coll += t
        onchip = max(compute, memory)
        step = max(onchip, coll) + (1.0 - self.overlap) * min(onchip, coll)
        return StepPrediction(
            compute_s=compute, memory_s=memory, collective_s=coll,
            step_s=step, bound_s=max(compute, memory, coll),
            breakdown=dict(per_op, compute=compute, memory=memory))
