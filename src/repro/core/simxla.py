"""SimXLA — the SimBLAS/SimMPI idea adapted to the TPU/XLA world.

Where the paper models BLAS calls + MPI collectives on a fat-tree, the
TPU workload is XLA HLO ops + XLA collectives on an ICI torus.  The
library-layer models here consume the per-device (flops, bytes,
collective) trace extracted from the *compiled dry-run artifact*
(roofline/hlo_parse.py) — the exact analogue of substituting BLAS calls
with analytical models: data content never matters, only shapes.

Two fidelity levels (mirroring the paper's hybrid):
  * analytic (this module): closed-form ring/torus collective times +
    roofline op times + an overlap model;
  * DES (core/apps/transformer.py): per-rank virtual threads issuing
    flows on the Torus topology — contention is emergent.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict, List, Optional

from .hardware.node import NodeModel, TPU_V5E


@dataclasses.dataclass(frozen=True)
class ICIParams:
    link_bw: float = 45e9          # B/s per link per direction
    links_per_axis: int = 2        # bidirectional ring on each torus axis
    latency: float = 1e-6          # per collective-phase software latency
    dcn_bw: float = 25e9           # per-chip cross-pod bandwidth
    dcn_latency: float = 10e-6
    hop_latency: float = 500e-9    # per-ICI-hop wire latency
    base_latency: float = 1e-6     # per-message software/NIC latency


def ici_from_platform(platform, **overrides) -> ICIParams:
    """Derive the ICI parameters from a ``repro.platforms.Platform`` spec
    (fabric + MPI-stack sections); keyword overrides win.  This is the
    single spec->ICI mapping — the legacy module constant ``ICI`` resolves
    through it from the ``tpu-v5e-pod`` registry entry."""
    fab, mpi = platform.fabric, platform.mpi
    latency = mpi.net_latency
    if latency is None:
        from repro.platforms.build import derived_net_latency
        latency = derived_net_latency(platform)
    kw = dict(link_bw=fab.link_bw, latency=latency,
              dcn_bw=fab.dcn_bw_per_node, dcn_latency=fab.dcn_latency,
              hop_latency=fab.hop_latency, base_latency=fab.base_latency)
    kw.update(overrides)
    return ICIParams(**kw)


def default_ici() -> ICIParams:
    """The TPU-v5e ICI constants, resolved from the platform registry
    (single source of machine truth) and cached."""
    global _DEFAULT_ICI
    if _DEFAULT_ICI is None:
        from repro.platforms.registry import get_platform
        _DEFAULT_ICI = ici_from_platform(get_platform("tpu-v5e-pod"))
    return _DEFAULT_ICI


_DEFAULT_ICI: Optional[ICIParams] = None


def assert_registry_consistent(platform=None) -> None:
    """Fail loudly if the legacy module constants (``ICI``, the node
    ``TPU_V5E``) have drifted from the registry spec they are supposed to
    mirror.  Benchmarks and examples that historically read hardcoded
    chip constants call this after routing through the registry, so a
    future re-hardcoding cannot silently diverge."""
    from repro.core.hardware.node import TPU_V5E
    if platform is None:
        from repro.platforms.registry import get_platform
        platform = get_platform("tpu-v5e-pod")
    spec_node = platform.node_model()
    if spec_node != TPU_V5E:
        raise RuntimeError(
            f"legacy TPU_V5E constant diverged from {platform.name!r} "
            f"spec: {TPU_V5E} != {spec_node}")
    spec_ici = ici_from_platform(platform)
    if spec_ici != default_ici():
        raise RuntimeError(
            f"legacy ICI constants diverged from {platform.name!r} "
            f"spec: {default_ici()} != {spec_ici}")


def __getattr__(name):
    # ICI stays importable as a constant; resolved (and cached) from the
    # registry on first access so the numbers live in one place.
    if name == "ICI":
        value = default_ici()
        globals()[name] = value
        return value
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")


def ring_allreduce_time(nbytes: float, n: int,
                        ici: Optional[ICIParams] = None) -> float:
    """Bidirectional-ring all-reduce on one torus axis: reduce-scatter +
    all-gather, each moving (n-1)/n of the buffer over 2 links."""
    ici = ici or default_ici()
    if n <= 1 or nbytes <= 0:
        return 0.0
    wire = 2.0 * (n - 1) / n * nbytes
    return wire / (ici.link_bw * ici.links_per_axis) \
        + 2.0 * (n - 1) * ici.latency


def ring_allgather_time(result_bytes: float, n: int,
                        ici: Optional[ICIParams] = None) -> float:
    ici = ici or default_ici()
    if n <= 1 or result_bytes <= 0:
        return 0.0
    wire = (n - 1) / n * result_bytes
    return wire / (ici.link_bw * ici.links_per_axis) + (n - 1) * ici.latency


def reduce_scatter_time(shard_bytes: float, n: int,
                        ici: Optional[ICIParams] = None) -> float:
    ici = ici or default_ici()
    if n <= 1 or shard_bytes <= 0:
        return 0.0
    wire = (n - 1) * shard_bytes
    return wire / (ici.link_bw * ici.links_per_axis) + (n - 1) * ici.latency


def all_to_all_time(nbytes: float, n: int,
                    ici: Optional[ICIParams] = None) -> float:
    """All-to-all on a ring: each chip sends (n-1)/n of its buffer; average
    hop distance n/4 on a bidirectional ring inflates wire occupancy."""
    ici = ici or default_ici()
    if n <= 1 or nbytes <= 0:
        return 0.0
    wire = (n - 1) / n * nbytes * (n / 4.0) / max(n - 1, 1) * 2.0
    return wire / (ici.link_bw * ici.links_per_axis) + (n - 1) * ici.latency


def collective_permute_time(nbytes: float,
                            ici: Optional[ICIParams] = None) -> float:
    ici = ici or default_ici()
    return nbytes / (ici.link_bw * ici.links_per_axis) + ici.latency


def collective_time(op: str, wire_bytes: float, group_size: int,
                    ici: Optional[ICIParams] = None) -> float:
    """Time for one collective given the *ring wire bytes* already computed
    by the HLO analyzer (hlo_parse ring-algorithm convention)."""
    ici = ici or default_ici()
    if wire_bytes <= 0:
        return 0.0
    n = max(group_size, 2)
    phases = {"all-reduce": 2 * (n - 1), "all-gather": n - 1,
              "reduce-scatter": n - 1, "all-to-all": n - 1,
              "collective-permute": 1}.get(op, n - 1)
    return wire_bytes / (ici.link_bw * ici.links_per_axis) \
        + phases * ici.latency


@dataclasses.dataclass
class StepPrediction:
    compute_s: float
    memory_s: float
    collective_s: float
    step_s: float
    bound_s: float
    breakdown: Dict[str, float]


class SimXLA:
    """Analytic step-time predictor for a compiled (arch x shape x mesh)
    cell, driven by the dry-run record.  Chip and ICI numbers default
    to the ``tpu-v5e-pod`` registry spec; ``SimXLA.for_platform`` derives
    them from any other ``Platform``."""

    def __init__(self, chip: Optional[NodeModel] = None,
                 ici: Optional[ICIParams] = None,
                 overlap: float = 0.7, fusion_efficiency: float = 3.0):
        self.chip = chip if chip is not None else TPU_V5E
        self.ici = ici or default_ici()
        # fraction of collective time hidden under compute (XLA latency
        # hiding / async collectives)
        self.overlap = overlap
        # our HLO byte model counts op-boundary traffic on the *CPU*-
        # partitioned module; TPU fusion materializes ~1/fusion_efficiency
        # of those boundaries (calibratable; see EXPERIMENTS.md §Sim-accuracy)
        self.fusion_efficiency = fusion_efficiency

    @classmethod
    def for_platform(cls, platform, **kw) -> "SimXLA":
        """A predictor whose chip and ICI sections come from a
        ``Platform`` spec instead of the legacy constants."""
        kw.setdefault("chip", platform.node_model())
        kw.setdefault("ici", ici_from_platform(platform))
        return cls(**kw)

    def predict(self, record: Dict) -> StepPrediction:
        """record: one experiments/dryrun/*.json cell."""
        r = record["roofline"]
        flops = r["hlo_flops_total"] / record["chips"]
        nbytes = r["hlo_bytes_total"] / record["chips"]
        compute = flops / (self.chip.peak_flops * self.chip.gemm_efficiency)
        memory = (nbytes / self.fusion_efficiency
                  / (self.chip.mem_bw * self.chip.mem_efficiency))
        coll = 0.0
        per_op = {}
        for op, agg in record.get("collectives", {}).items():
            t = collective_time(op, agg["wire_bytes"],
                                group_size=16, ici=self.ici)
            per_op[op] = t
            coll += t
        onchip = max(compute, memory)
        step = max(onchip, coll) + (1.0 - self.overlap) * min(onchip, coll)
        return StepPrediction(
            compute_s=compute, memory_s=memory, collective_s=coll,
            step_s=step, bound_s=max(compute, memory, coll),
            breakdown=dict(per_op, compute=compute, memory=memory))
