"""SimBLAS — analytical performance models of BLAS kernels (paper §III-B1).

Level-3 kernels are compute-bound: ``E = mu * ops + theta`` with
``mu = 1 / (peak * efficiency)``; Level-1/2 kernels are bandwidth-bound:
``E = bytes / (bw * eff) + theta``.  BLAS is data-independent, so only
shapes matter — no data is ever touched (this is what makes the matrix-A
elision sound).

``mu`` / ``theta`` come either from the node spec or from a measured
calibration (core/calibrate.py, reproducing the paper's Fig 2 microtest
with R^2 reported).
"""
from __future__ import annotations

import dataclasses
from typing import Optional

from .hardware.node import NodeModel

# Memoize whole panel-factorization accumulations (see ``panel_fact``).
# Values are bit-identical either way — the cached number is the same
# float the loop would produce — so this is purely a speed knob;
# ``repro.core._legacy_engine.legacy_des()`` clears it to reproduce the
# pre-rewrite per-call cost for benchmarking.
PANEL_CACHE = True


@dataclasses.dataclass
class BlasCounters:
    calls: int = 0
    flops: float = 0.0
    bytes: float = 0.0
    time: float = 0.0


class SimBLAS:
    def __init__(self, node: NodeModel, *, single_core: bool = False,
                 mu: Optional[float] = None, theta: Optional[float] = None,
                 theta_mem: Optional[float] = None):
        self.node = node
        self.single_core = single_core
        peak = node.core_peak if single_core else node.peak_flops
        self.mu = mu if mu is not None else 1.0 / (peak * node.gemm_efficiency)
        self.theta = theta if theta is not None else node.blas_latency
        # Level-1/2 calls have far smaller dispatch overhead than a GEMM
        # (no blocking/packing setup); calibrated separately.
        self.theta_mem = theta_mem if theta_mem is not None \
            else min(self.theta, 2e-6)
        self.counters = BlasCounters()
        self._panel_cache: dict = {}

    # -- helpers ----------------------------------------------------------
    def _compute(self, ops: float) -> float:
        t = self.mu * ops + self.theta
        c = self.counters
        c.calls += 1
        c.flops += ops
        c.time += t
        return t

    def _memory(self, nbytes: float) -> float:
        t = (nbytes / (self.node.mem_bw * self.node.mem_efficiency)
             + self.theta_mem)
        c = self.counters
        c.calls += 1
        c.bytes += nbytes
        c.time += t
        return t

    # -- Level 3 (compute-bound) ------------------------------------------
    def dgemm(self, m: int, n: int, k: int) -> float:
        return self._compute(2.0 * m * n * k + 2.0 * m * n)

    def dtrsm(self, m: int, n: int, side: str = "L") -> float:
        ops = float(m) * m * n if side == "L" else float(n) * n * m
        return self._compute(ops)

    # -- Level 2 (bandwidth-bound) ----------------------------------------
    def dgemv(self, m: int, n: int) -> float:
        return self._memory(8.0 * (m * n + m + n))

    def dger(self, m: int, n: int) -> float:
        # read A, x, y; write A
        return self._memory(8.0 * (2.0 * m * n + m + n))

    # -- Level 1 (bandwidth-bound) ----------------------------------------
    def dswap(self, n: int) -> float:
        return self._memory(8.0 * 4.0 * n)     # paper Fig 3: 4 accesses/elem

    def dscal(self, n: int) -> float:
        return self._memory(8.0 * 2.0 * n)

    def daxpy(self, n: int) -> float:
        return self._memory(8.0 * 3.0 * n)

    def dcopy(self, n: int) -> float:
        return self._memory(8.0 * 2.0 * n)

    def idamax(self, n: int) -> float:
        return self._memory(8.0 * n)

    # -- fused HPL panel factorization (paper §III-C inner loop) ------------
    def panel_fact(self, mloc: int, w: int) -> float:
        """Total BLAS time of one HPL panel factorization: per column j,
        idamax + dscal over the remaining rows and a rank-1 dger update.

        The accumulation order is exactly the unfused per-column loop, so
        the value is bit-identical to calling the three kernels w times —
        which is what lets the result be memoized per (mloc, w) shape
        (shapes repeat across process rows and panels).  When cached, the
        call counters reflect only the first computation of each shape.
        """
        if PANEL_CACHE:
            t = self._panel_cache.get((mloc, w))
            if t is not None:
                return t
        t = 0.0
        for j in range(w):
            mj = mloc - j
            if mj < 1:
                mj = 1
            t += self.idamax(mj)
            t += self.dscal(mj)
            t += self.dger(mj, w - j - 1)
        if PANEL_CACHE:
            self._panel_cache[(mloc, w)] = t
        return t

    # -- HPL auxiliary kernels (paper §III-C: HPL_dlaswp*) ------------------
    def dlaswp(self, rows: int, cols: int) -> float:
        return self._memory(8.0 * 4.0 * rows * cols)

    def dlacpy(self, rows: int, cols: int) -> float:
        return self._memory(8.0 * 2.0 * rows * cols)
