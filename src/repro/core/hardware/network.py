"""Stream-level (fluid) network model with max-min fair bandwidth sharing.

The paper's network layer: "a stream-level network model is implemented as
an alternative [to packet-level] that offers latency and bandwidth
restrictions ... we divide large messages into smaller chunks and calculate
the transmission time according to the currently allocated bandwidth".

We implement the continuous limit of that chunking: each message is a
*flow* over its route's links; whenever the flow set changes, bandwidth is
re-allocated max-min fairly (progressive filling) and every flow's
completion time is re-predicted.  Contention (the paper's §V finding that a
200 Gb/s upgrade buys almost nothing on a congested fat-tree) emerges from
the shared-link allocation.

The max-min allocation also exists as a vectorized JAX/Pallas kernel
(``repro.kernels.maxmin_fair``) used by the fast exascale path.
"""
from __future__ import annotations

import math
from typing import Callable, Dict, List, Optional, Sequence

from repro.core.engine import Engine, Event


class Link:
    __slots__ = ("capacity", "latency", "flows", "name", "_mark")

    def __init__(self, capacity: float, latency: float = 0.0, name: str = ""):
        self.capacity = capacity      # bytes / s
        self.latency = latency        # s per traversal
        # flow -> None: an *ordered* set.  Iteration order must be
        # insertion order, not id() order — component traversal feeds the
        # engine heap, and id()-ordered sets made same-timestamp event
        # ordering (and traces) vary run-to-run.
        self.flows: Dict["Flow", None] = {}
        self.name = name
        self._mark = 0      # visited stamp for Network._component


class Flow:
    __slots__ = ("size", "remaining", "links", "rate", "done", "_last_t",
                 "_version", "_mark", "_occ")

    def __init__(self, size: float, links: Sequence[Link], done: Event):
        self.size = float(size)
        self.remaining = float(size)
        self.links = list(links)
        self.rate = 0.0
        self.done = done
        self._last_t = 0.0
        self._version = 0
        self._mark = 0      # visited stamp for Network._component
        self._occ = 0       # occurrence count within one _reallocate


class Network:
    """Holds links + active flows; topology supplies routes."""

    def __init__(self, engine: Engine, topology, *,
                 min_flow_time: float = 0.0):
        self.engine = engine
        self.topo = topology
        self.flows: Dict[Flow, None] = {}   # ordered set (see Link.flows)
        self.min_flow_time = min_flow_time
        # route cache: topology routes are pure functions of (src, dst)
        # (even dragonfly Valiant is deterministic) and link latencies
        # never change mid-run, so (links, latency) can be memoized
        self._routes: Dict = {}
        # completed Flow shells for reuse (engine.pooling only); a
        # recycled flow keeps its monotonic _version so stale completion
        # predictions from its previous life can never fire (see
        # _maybe_complete's version check)
        self._flow_pool: List[Flow] = []
        # pre-bound callbacks: scheduled once per flow event, so the
        # binding cost is paid here instead of per call_at
        self._complete_cb = self._maybe_complete
        self._start_cb = self._start_flow
        self._stamp = 0     # _component's visited stamp

    # -- fluid max-min fairness ------------------------------------------
    #
    # Max-min allocation decomposes exactly over connected components of
    # the flow/link sharing graph, so a flow arrival/departure only
    # re-allocates its component — O(component) per event instead of
    # O(all flows).  This is what lets the Python DES reach 10^4 ranks
    # (paper Fig 7); the exascale path uses the vectorized kernel instead.
    def _component(self, seeds: Sequence[Flow]) -> List[Flow]:
        # visited tracking by stamping Flow/Link objects (monotonic
        # per-Network counter) instead of building id() sets per call.
        # NOTE: seed occurrences are deliberately preserved (a neighbor
        # sharing k links is seeded k times and _reallocate's shares
        # divide by occurrence count); only traversal-discovered flows
        # dedup, exactly like the id()-set version.
        stamp = self._stamp = self._stamp + 1
        out: List[Flow] = []
        stack = [f for f in seeds if f in self.flows]
        for f in stack:
            f._mark = stamp
        while stack:
            f = stack.pop()
            out.append(f)
            for l in f.links:
                if l._mark == stamp:
                    continue
                l._mark = stamp
                for g in l.flows:
                    if g._mark != stamp:
                        g._mark = stamp
                        stack.append(g)
        return out

    def _reallocate(self, seeds: Optional[Sequence[Flow]] = None):
        now = self.engine.now
        if seeds is not None and len(seeds) == 1:
            # fast path: a lone flow whose links carry nothing else gets
            # min-capacity — exactly what progressive filling computes
            # for a singleton component, without the id()-dict machinery
            f = seeds[0]
            if f in self.flows:
                alone = True
                for l in f.links:
                    if len(l.flows) > 1:
                        alone = False
                        break
                if alone:
                    if f.rate > 0:
                        f.remaining -= f.rate * (now - f._last_t)
                        if f.remaining < 0:
                            f.remaining = 0.0
                    f._last_t = now
                    rate = math.inf
                    for l in f.links:
                        if l.capacity < rate:
                            rate = l.capacity
                    f.rate = rate
                    f._version += 1
                    t_done = now + (f.remaining / rate
                                    if rate < math.inf else 0.0)
                    self.engine.call_at(t_done, self._complete_cb,
                                        (f, f._version))
                    return
        comp = self._component(seeds) if seeds is not None \
            else list(self.flows)
        # NOTE: ``comp`` may contain the same flow more than once
        # (neighbors sharing >= 2 links are seeded per shared link and
        # ``_component`` keeps the occurrences); shares deliberately
        # divide by *occurrence* counts — the reference semantics are
        # the quadratic per-round recount of unassigned occurrences.
        # Counting each flow's multiplicity up front (stamp pass) lets
        # the fill keep those counts incrementally — decrement by
        # ``_occ`` when a flow assigns — which is bit-identical to the
        # recount but O(rounds * links) instead of
        # O(rounds * links * flows).
        stamp = self._stamp = self._stamp + 1
        uniq: List[Flow] = []
        for f in comp:
            if f._mark == stamp:
                f._occ += 1
                continue
            f._mark = stamp
            f._occ = 1
            uniq.append(f)
            # progress accounting since last change (idempotent per
            # occurrence in the reference, so once per flow is exact)
            if f.rate > 0:
                f.remaining -= f.rate * (now - f._last_t)
                if f.remaining < 0:
                    f.remaining = 0.0
            f._last_t = now
        # progressive filling within the component.  One entry per link:
        # [remaining_capacity, flows, unassigned_occurrences].
        links: Dict[int, list] = {}
        for f in uniq:
            f.rate = -1.0  # unassigned
            occ = f._occ
            for l in f.links:
                e = links.get(id(l))
                if e is None:
                    links[id(l)] = e = [l.capacity, [], 0]
                e[1].append(f)
                e[2] += occ
        entries = list(links.values())
        n_active = len(comp)
        while n_active > 0:
            best, best_share = None, math.inf
            for e in entries:
                n = e[2]
                if n == 0:
                    continue
                share = e[0] / n
                if share < best_share:
                    best_share, best = share, e
            if best is None:
                for f in uniq:  # flows with no links (self-send)
                    if f.rate < 0:
                        f.rate = math.inf
                        n_active -= f._occ
                break
            for f in best[1]:
                if f.rate < 0:
                    f.rate = best_share
                    n_active -= f._occ
                    for l in f.links:
                        e2 = links[id(l)]
                        e2[0] -= best_share
                        e2[2] -= f._occ
        # re-predict completions
        for f in comp:
            f._version += 1
            if f.rate <= 0:
                continue
            t_done = now + (f.remaining / f.rate if f.rate < math.inf else 0.0)
            self.engine.call_at(t_done, self._complete_cb,
                                (f, f._version))

    def _maybe_complete(self, arg):
        f, version = arg
        if f._version != version or f not in self.flows:
            return
        now = self.engine.now
        f.remaining -= f.rate * (now - f._last_t)
        f._last_t = now
        if f.remaining > 1e-9 * max(f.size, 1.0):
            return  # superseded; a newer prediction exists
        self.flows.pop(f, None)
        # single pass: drop f from each link, then collect that link's
        # survivors — same neighbor list (and order) as collecting
        # before the pops, without the per-flow identity checks
        neighbors: List[Flow] = []
        for l in f.links:
            lf = l.flows
            lf.pop(f, None)
            if lf:
                neighbors.extend(lf)
        if neighbors:
            self._reallocate(neighbors)
        done = f.done
        if self.engine.pooling:
            # shell back to the pool; _version is NOT reset (monotonic
            # across lives), so leftover (f, old_version) predictions in
            # the heap stay stale forever
            f.done = None
            f.links = []
            self._flow_pool.append(f)
            # the flow-done event is internal to the network->SimMPI
            # edge: set() hands the wakeups to the engine FIFO, after
            # which nothing references it — recycle immediately
            done.set()
            self.engine._recycle_event(done)
        else:
            done.set()

    # -- public API -------------------------------------------------------
    def set_capacity(self, link: Link, capacity: float):
        """Change a link's capacity mid-run (the fault layer's
        time-varying bandwidth hook).  Flows crossing the link get their
        shares and completion predictions recomputed; with no flows the
        update is free.  Capacity must stay > 0 — fail-stop is modeled
        by killing processes, not by zero-bandwidth links."""
        if capacity <= 0:
            raise ValueError(f"link capacity must be > 0, got {capacity}")
        link.capacity = capacity
        if link.flows:
            self._reallocate(list(link.flows))

    def send(self, src: int, dst: int, size: float) -> Event:
        """Start a flow; returns Event set at completion (after path latency
        + bandwidth-shared transfer)."""
        done = self.engine.event()
        route = self._routes.get((src, dst))
        if route is None:
            links = self.topo.route(src, dst)
            route = (links, sum(l.latency for l in links)
                     + self.topo.base_latency)
            self._routes[(src, dst)] = route
        links, latency = route
        if not links or size <= 0:
            self.engine.call_at(self.engine.now + latency, done.set, None)
            return done
        pool = self._flow_pool
        if pool:
            f = pool.pop()
            f.size = float(size)
            f.remaining = f.size
            f.links = list(links)
            f.rate = 0.0
            f.done = done
            f._last_t = 0.0
        else:
            f = Flow(size, links, done)
        self.engine.call_at(self.engine.now + latency, self._start_cb, f)
        return done

    def _start_flow(self, f: Flow):
        f._last_t = self.engine.now
        self.flows[f] = None
        for l in f.links:
            l.flows[f] = None
        self._reallocate([f])
