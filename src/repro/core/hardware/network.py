"""Stream-level (fluid) network model with max-min fair bandwidth sharing.

The paper's network layer: "a stream-level network model is implemented as
an alternative [to packet-level] that offers latency and bandwidth
restrictions ... we divide large messages into smaller chunks and calculate
the transmission time according to the currently allocated bandwidth".

We implement the continuous limit of that chunking: each message is a
*flow* over its route's links; whenever the flow set changes, bandwidth is
re-allocated max-min fairly (progressive filling) and every flow's
completion time is re-predicted.  Contention (the paper's §V finding that a
200 Gb/s upgrade buys almost nothing on a congested fat-tree) emerges from
the shared-link allocation.

The max-min allocation also exists as a vectorized JAX/Pallas kernel
(``repro.kernels.maxmin_fair``) used by the fast exascale path.
"""
from __future__ import annotations

import math
from typing import Callable, Dict, List, Optional, Sequence

from repro.core.engine import Engine, Event


class Link:
    __slots__ = ("capacity", "latency", "flows", "name")

    def __init__(self, capacity: float, latency: float = 0.0, name: str = ""):
        self.capacity = capacity      # bytes / s
        self.latency = latency        # s per traversal
        # flow -> None: an *ordered* set.  Iteration order must be
        # insertion order, not id() order — component traversal feeds the
        # engine heap, and id()-ordered sets made same-timestamp event
        # ordering (and traces) vary run-to-run.
        self.flows: Dict["Flow", None] = {}
        self.name = name


class Flow:
    __slots__ = ("size", "remaining", "links", "rate", "done", "_last_t",
                 "_version")

    def __init__(self, size: float, links: Sequence[Link], done: Event):
        self.size = float(size)
        self.remaining = float(size)
        self.links = list(links)
        self.rate = 0.0
        self.done = done
        self._last_t = 0.0
        self._version = 0


class Network:
    """Holds links + active flows; topology supplies routes."""

    def __init__(self, engine: Engine, topology, *,
                 min_flow_time: float = 0.0):
        self.engine = engine
        self.topo = topology
        self.flows: Dict[Flow, None] = {}   # ordered set (see Link.flows)
        self.min_flow_time = min_flow_time

    # -- fluid max-min fairness ------------------------------------------
    #
    # Max-min allocation decomposes exactly over connected components of
    # the flow/link sharing graph, so a flow arrival/departure only
    # re-allocates its component — O(component) per event instead of
    # O(all flows).  This is what lets the Python DES reach 10^4 ranks
    # (paper Fig 7); the exascale path uses the vectorized kernel instead.
    def _component(self, seeds: Sequence[Flow]) -> List[Flow]:
        seen = set()
        out: List[Flow] = []
        stack = [f for f in seeds if f in self.flows]
        seen.update(id(f) for f in stack)
        seen_links: set = set()
        while stack:
            f = stack.pop()
            out.append(f)
            for l in f.links:
                if id(l) in seen_links:
                    continue
                seen_links.add(id(l))
                for g in l.flows:
                    if id(g) not in seen:
                        seen.add(id(g))
                        stack.append(g)
        return out

    def _reallocate(self, seeds: Optional[Sequence[Flow]] = None):
        now = self.engine.now
        comp = self._component(seeds) if seeds is not None \
            else list(self.flows)
        # progress accounting since last change
        for f in comp:
            if f.rate > 0:
                f.remaining -= f.rate * (now - f._last_t)
                if f.remaining < 0:
                    f.remaining = 0.0
            f._last_t = now
        # progressive filling within the component
        links: Dict[int, List[Flow]] = {}
        link_objs: Dict[int, Link] = {}
        for f in comp:
            f.rate = -1.0  # unassigned
            for l in f.links:
                links.setdefault(id(l), []).append(f)
                link_objs[id(l)] = l
        remaining_cap = {lid: link_objs[lid].capacity for lid in links}
        unassigned = dict(links)
        n_active = len(comp)
        while n_active > 0:
            best_lid, best_share = None, math.inf
            for lid, fl in unassigned.items():
                n = sum(1 for f in fl if f.rate < 0)
                if n == 0:
                    continue
                share = remaining_cap[lid] / n
                if share < best_share:
                    best_share, best_lid = share, lid
            if best_lid is None:
                for f in comp:  # flows with no links (self-send)
                    if f.rate < 0:
                        f.rate = math.inf
                        n_active -= 1
                break
            for f in unassigned[best_lid]:
                if f.rate < 0:
                    f.rate = best_share
                    n_active -= 1
                    for l in f.links:
                        remaining_cap[id(l)] -= best_share
            unassigned.pop(best_lid)
        # re-predict completions
        for f in comp:
            f._version += 1
            if f.rate <= 0:
                continue
            t_done = now + (f.remaining / f.rate if f.rate < math.inf else 0.0)
            self.engine.call_at(t_done, self._maybe_complete,
                                (f, f._version))

    def _maybe_complete(self, arg):
        f, version = arg
        if f._version != version or f not in self.flows:
            return
        now = self.engine.now
        f.remaining -= f.rate * (now - f._last_t)
        f._last_t = now
        if f.remaining > 1e-9 * max(f.size, 1.0):
            return  # superseded; a newer prediction exists
        self.flows.pop(f, None)
        neighbors = [g for l in f.links for g in l.flows if g is not f]
        for l in f.links:
            l.flows.pop(f, None)
        if neighbors:
            self._reallocate(neighbors)
        f.done.set()

    # -- public API -------------------------------------------------------
    def set_capacity(self, link: Link, capacity: float):
        """Change a link's capacity mid-run (the fault layer's
        time-varying bandwidth hook).  Flows crossing the link get their
        shares and completion predictions recomputed; with no flows the
        update is free.  Capacity must stay > 0 — fail-stop is modeled
        by killing processes, not by zero-bandwidth links."""
        if capacity <= 0:
            raise ValueError(f"link capacity must be > 0, got {capacity}")
        link.capacity = capacity
        if link.flows:
            self._reallocate(list(link.flows))

    def send(self, src: int, dst: int, size: float) -> Event:
        """Start a flow; returns Event set at completion (after path latency
        + bandwidth-shared transfer)."""
        done = self.engine.event()
        links = self.topo.route(src, dst)
        latency = sum(l.latency for l in links) + self.topo.base_latency
        if not links or size <= 0:
            self.engine.call_at(self.engine.now + latency,
                                lambda _: done.set(), None)
            return done
        f = Flow(size, links, done)

        def start(_):
            f._last_t = self.engine.now
            self.flows[f] = None
            for l in f.links:
                l.flows[f] = None
            self._reallocate([f])
        self.engine.call_at(self.engine.now + latency, start, None)
        return done
