from .node import NodeModel, frontera_node, pupmaya_node
from .network import Network, Flow
from . import topology

__all__ = ["NodeModel", "TPU_V5E", "frontera_node", "pupmaya_node",
           "Network", "Flow", "topology"]


def __getattr__(name):
    # lazy: TPU_V5E is built from the platform registry on first access
    if name == "TPU_V5E":
        from . import node
        return node.TPU_V5E
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
