from .node import NodeModel, TPU_V5E, frontera_node, pupmaya_node
from .network import Network, Flow
from . import topology

__all__ = ["NodeModel", "TPU_V5E", "frontera_node", "pupmaya_node",
           "Network", "Flow", "topology"]
