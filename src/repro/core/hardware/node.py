"""Node (processing element) analytical models.

Paper §III-A1: compute-bound ops cost ``ops / (peak x efficiency)``;
bandwidth-bound ops cost ``bytes / (bw x efficiency)``.  Peak numbers and
efficiencies are *inputs* measured by microbenchmark (core/calibrate.py)
or taken from public specs.  The same form covers CPU, GPU and TPU chips
(heterogeneous-architecture extension of CSMethod).

Machine constants live in ``repro.platforms.registry``; the named
factories below (``local_node``, ``frontera_node``, ...) are thin
compatibility shims over the registry.
"""
from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class NodeModel:
    name: str
    peak_flops: float            # node peak, FLOP/s (at sustained AVX/MXU clock)
    mem_bw: float                # B/s
    cores: int = 1
    gemm_efficiency: float = 0.92
    mem_efficiency: float = 0.80
    blas_latency: float = 2e-7   # theta: per-call overhead (s)
    # accelerator section (paper's CPU-GPGPU heterogeneous extension)
    accel_peak_flops: float = 0.0
    accel_mem_bw: float = 0.0
    accel_efficiency: float = 0.75

    @property
    def core_peak(self) -> float:
        return self.peak_flops / max(self.cores, 1)

    def gemm_time(self, ops: float, single_core: bool = False) -> float:
        peak = self.core_peak if single_core else self.peak_flops
        return ops / (peak * self.gemm_efficiency) + self.blas_latency

    def mem_time(self, nbytes: float) -> float:
        return nbytes / (self.mem_bw * self.mem_efficiency) + self.blas_latency


# --- registry-backed shims ---------------------------------------------------
# (Xeon-style peak derivation lives in platforms.spec.NodeSpec.xeon.)

def node_from_spec(spec) -> NodeModel:
    """NodeSpec -> NodeModel (platforms.build.build_node delegates here;
    living on this side of the package boundary keeps the spec->model
    mapping importable from either direction without a cycle)."""
    return NodeModel(name=spec.name, peak_flops=spec.peak_flops,
                     mem_bw=spec.mem_bw, cores=spec.cores,
                     gemm_efficiency=spec.gemm_efficiency,
                     mem_efficiency=spec.mem_efficiency,
                     blas_latency=spec.blas_latency,
                     accel_peak_flops=spec.accel_peak_flops,
                     accel_mem_bw=spec.accel_mem_bw,
                     accel_efficiency=spec.accel_efficiency)


def _registry_node(platform_name: str) -> NodeModel:
    # registry/spec only import platforms internals, so this stays safe
    # whichever of repro.core / repro.platforms gets imported first
    # (going through platforms.build here re-entered a half-initialized
    # module when repro.platforms was imported before repro.core).
    from repro.platforms.registry import get_platform
    return node_from_spec(get_platform(platform_name).node)


def local_node() -> NodeModel:
    """Paper Table I local Broadwell machine (registry: bdw-local)."""
    return _registry_node("bdw-local")


def frontera_node() -> NodeModel:
    """Frontera's CLX-8280 node (registry: frontera)."""
    return _registry_node("frontera")


def pupmaya_node() -> NodeModel:
    """PupMaya's SKX-6148 node (registry: pupmaya)."""
    return _registry_node("pupmaya")


def __getattr__(name):
    # TPU_V5E stays importable as a constant; resolved (and cached) from
    # the registry on first access so the numbers live in one place.
    if name == "TPU_V5E":
        value = _registry_node("tpu-v5e-pod")
        globals()[name] = value
        return value
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
