"""Node (processing element) analytical models.

Paper §III-A1: compute-bound ops cost ``ops / (peak x efficiency)``;
bandwidth-bound ops cost ``bytes / (bw x efficiency)``.  Peak numbers and
efficiencies are *inputs* measured by microbenchmark (core/calibrate.py)
or taken from public specs.  The same form covers CPU, GPU and TPU chips
(heterogeneous-architecture extension of CSMethod).
"""
from __future__ import annotations

import dataclasses
from typing import Optional


@dataclasses.dataclass(frozen=True)
class NodeModel:
    name: str
    peak_flops: float            # node peak, FLOP/s (at sustained AVX/MXU clock)
    mem_bw: float                # B/s
    cores: int = 1
    gemm_efficiency: float = 0.92
    mem_efficiency: float = 0.80
    blas_latency: float = 2e-7   # theta: per-call overhead (s)
    # accelerator section (paper's CPU-GPGPU heterogeneous extension)
    accel_peak_flops: float = 0.0
    accel_mem_bw: float = 0.0
    accel_efficiency: float = 0.75

    @property
    def core_peak(self) -> float:
        return self.peak_flops / max(self.cores, 1)

    def gemm_time(self, ops: float, single_core: bool = False) -> float:
        peak = self.core_peak if single_core else self.peak_flops
        return ops / (peak * self.gemm_efficiency) + self.blas_latency

    def mem_time(self, nbytes: float) -> float:
        return nbytes / (self.mem_bw * self.mem_efficiency) + self.blas_latency


def xeon_node(name: str, sockets: int, cores_per_socket: int,
              avx_clock_ghz: float, flops_per_cycle: int = 32,
              ddr_gbs: float = 100.0, **kw) -> NodeModel:
    cores = sockets * cores_per_socket
    return NodeModel(name=name,
                     peak_flops=cores * flops_per_cycle * avx_clock_ghz * 1e9,
                     mem_bw=ddr_gbs * 1e9, cores=cores, **kw)


# --- systems from the paper -------------------------------------------------

def local_node() -> NodeModel:
    """Paper Table I: 2x Xeon E5-2699 v4 Broadwell, 22c @2.2 GHz, DDR4-2400.
    Broadwell AVX2: 16 DP flops/cycle; AVX base ~1.8 GHz."""
    return xeon_node("bdw-2699v4", 2, 22, 1.8, flops_per_cycle=16,
                     ddr_gbs=153.6)


def frontera_node() -> NodeModel:
    """Frontera: 2x Xeon Platinum 8280 28c; AVX-512 sustained ~1.8 GHz
    (paper: nominal 2.7 GHz can't be held with AVX-512), 32 DP flops/cyc,
    DDR4-2933 x 6ch x 2."""
    return xeon_node("clx-8280", 2, 28, 1.8, flops_per_cycle=32,
                     ddr_gbs=2 * 6 * 23.46)


def pupmaya_node() -> NodeModel:
    """PupMaya: 2x Xeon Gold 6148 20c; AVX-512 sustained ~1.6 GHz,
    DDR4-2666."""
    return xeon_node("skx-6148", 2, 20, 1.6, flops_per_cycle=32,
                     ddr_gbs=2 * 6 * 21.3)


# --- TPU adaptation target ---------------------------------------------------

TPU_V5E = NodeModel(
    name="tpu-v5e",
    peak_flops=197e12,        # bf16
    mem_bw=819e9,
    cores=1,
    gemm_efficiency=0.90,     # large-matmul MXU efficiency (public MLPerf-ish)
    mem_efficiency=0.85,
    blas_latency=2e-6,        # per-op dispatch overhead
)
