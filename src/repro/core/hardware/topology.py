"""Network topologies with *dynamically computed* routing.

The paper (§III-A2): storing all routing paths at init costs O(nodes^2)
memory at scale; D-mod-K (fat-tree) and minimal/non-minimal (dragonfly)
routes can be computed on the fly instead.  Every topology below computes
``route(src, dst) -> [Link]`` arithmetically — no routing tables — which is
what keeps 10^4-rank simulations in a few hundred MB (paper Fig 7 / our
fig7 benchmark).

Topologies: two-level fat-tree (paper's 10,008-node scalability rig and
Frontera's 6-core/182-leaf HDR fabric), dragonfly, 2-D/3-D torus (TPU ICI
— the hardware-adaptation target), and a pod-of-pods DCN wrapper.
"""
from __future__ import annotations

import math
from typing import Dict, List, Optional, Tuple

from .network import Link


class Topology:
    base_latency: float = 0.0

    def route(self, src: int, dst: int) -> List[Link]:
        raise NotImplementedError

    @property
    def n_links(self) -> int:
        """True link count — the memory-scaling denominator of Fig 7.
        Subclasses that don't keep a flat ``links`` collection override
        this with their structural count."""
        return len(getattr(self, "links", []))

    def iter_links(self) -> List[Link]:
        """Every link, in a deterministic structural order — the fault
        layer's sampling universe (a seeded ``link_frac`` pick must hit
        the same links run-to-run)."""
        links = getattr(self, "links", None)
        if links is None:
            raise NotImplementedError(f"{type(self).__name__}.iter_links")
        return list(links.values()) if isinstance(links, dict) \
            else list(links)

    def node_links(self, node: int) -> List[Link]:
        """Links adjacent to ``node`` (for node-scoped link faults)."""
        raise NotImplementedError(f"{type(self).__name__}.node_links")


class FatTreeTwoLevel(Topology):
    """nodes -> edge switches -> core switches, D-mod-K up-routing.

    nodes_per_edge nodes attach to each edge switch; every edge switch has
    one uplink to each of n_core core switches.  The uplink for a packet is
    chosen as ``dst_node mod n_core`` (D-mod-K [Zahavi]) — deterministic,
    computed per-call, no tables.
    """

    def __init__(self, n_nodes: int, nodes_per_edge: int, n_core: int,
                 link_bw: float, hop_latency: float = 90e-9,
                 uplink_bw: Optional[float] = None,
                 base_latency: float = 1e-6):
        self.n_nodes = n_nodes
        self.nodes_per_edge = nodes_per_edge
        self.n_core = n_core
        self.n_edge = (n_nodes + nodes_per_edge - 1) // nodes_per_edge
        self.base_latency = base_latency
        ub = uplink_bw or link_bw
        # node<->edge links (one duplex pair per node, modeled per-direction)
        self.node_up = [Link(link_bw, hop_latency, f"n{i}-up")
                        for i in range(n_nodes)]
        self.node_down = [Link(link_bw, hop_latency, f"n{i}-dn")
                          for i in range(n_nodes)]
        # edge<->core per-direction links
        self.edge_up = [[Link(ub, hop_latency, f"e{e}-c{c}-up")
                         for c in range(n_core)] for e in range(self.n_edge)]
        self.edge_down = [[Link(ub, hop_latency, f"e{e}-c{c}-dn")
                           for c in range(n_core)] for e in range(self.n_edge)]

    def route(self, src: int, dst: int) -> List[Link]:
        if src == dst:
            return []
        se, de = src // self.nodes_per_edge, dst // self.nodes_per_edge
        if se == de:
            return [self.node_up[src], self.node_down[dst]]
        c = dst % self.n_core          # D-mod-K
        return [self.node_up[src], self.edge_up[se][c],
                self.edge_down[de][c], self.node_down[dst]]

    @property
    def n_links(self) -> int:
        return 2 * self.n_nodes + 2 * self.n_edge * self.n_core

    def iter_links(self) -> List[Link]:
        return (self.node_up + self.node_down
                + [l for row in self.edge_up for l in row]
                + [l for row in self.edge_down for l in row])

    def node_links(self, node: int) -> List[Link]:
        return [self.node_up[node], self.node_down[node]]


def _registry_topology(platform_name: str, n_nodes: Optional[int] = None,
                       **fabric_over):
    import dataclasses as _dc

    from repro.platforms.build import build_topology
    from repro.platforms.registry import get_platform
    plat = get_platform(platform_name)
    fab = _dc.replace(plat.fabric, **fabric_over) if fabric_over \
        else plat.fabric
    return build_topology(fab, plat.scale.n_nodes if n_nodes is None
                          else n_nodes)


def paper_fat_tree(link_bw: float = 100e9 / 8) -> FatTreeTwoLevel:
    """The paper's Fig 7 rig (registry: paper-fat-tree-10008)."""
    return _registry_topology("paper-fat-tree-10008", link_bw=link_bw)


def frontera_fat_tree(n_nodes: int = 8008,
                      link_bw: float = 100e9 / 8) -> FatTreeTwoLevel:
    """Frontera's HDR fat-tree (registry: frontera)."""
    return _registry_topology("frontera", n_nodes=n_nodes, link_bw=link_bw)


class Dragonfly(Topology):
    """Canonical dragonfly (Kim et al. 2008): g groups of a routers, p nodes
    per router, h global links per router.  Minimal routing (l-g-l) computed
    arithmetically; optional Valiant non-minimal via an intermediate group.
    """

    def __init__(self, n_groups: int, routers_per_group: int,
                 nodes_per_router: int, link_bw: float,
                 global_bw: Optional[float] = None,
                 hop_latency: float = 100e-9, nonminimal: bool = False,
                 base_latency: float = 1e-6):
        self.g, self.a, self.p = n_groups, routers_per_group, nodes_per_router
        self.nonminimal = nonminimal
        self.base_latency = base_latency
        gb = global_bw or link_bw
        n_routers = self.g * self.a
        self.n_nodes = n_routers * self.p
        self.node_up = [Link(link_bw, hop_latency) for _ in range(self.n_nodes)]
        self.node_down = [Link(link_bw, hop_latency) for _ in range(self.n_nodes)]
        # local all-to-all within group: per ordered router pair
        self.local = {}
        for grp in range(self.g):
            for i in range(self.a):
                for j in range(self.a):
                    if i != j:
                        self.local[(grp, i, j)] = Link(link_bw, hop_latency)
        # one global link per ordered group pair (aggregated)
        self.glob = {}
        for s in range(self.g):
            for d in range(self.g):
                if s != d:
                    self.glob[(s, d)] = Link(gb, hop_latency)

    def _locate(self, node: int) -> Tuple[int, int]:
        r = node // self.p
        return r // self.a, r % self.a

    def route(self, src: int, dst: int) -> List[Link]:
        if src == dst:
            return []
        sg, sr = self._locate(src)
        dg, dr = self._locate(dst)
        path = [self.node_up[src]]
        if sg == dg:
            if sr != dr:
                path.append(self.local[(sg, sr, dr)])
        else:
            groups = [sg, dg]
            if self.nonminimal:
                mid = (sg + dg) % self.g   # deterministic "random" Valiant
                if mid not in (sg, dg):
                    groups = [sg, mid, dg]
            # The aggregated (a, b) global link attaches to router
            # (b mod a_count) in group a — the egress — and lands on
            # router (a mod a_count) in group b — the ingress.
            cur_r = sr
            for a, b in zip(groups[:-1], groups[1:]):
                egress = b % self.a
                if cur_r != egress:
                    path.append(self.local[(a, cur_r, egress)])
                path.append(self.glob[(a, b)])
                cur_r = a % self.a
            if cur_r != dr:
                path.append(self.local[(dg, cur_r, dr)])
        path.append(self.node_down[dst])
        return path

    @property
    def n_links(self) -> int:
        return 2 * self.n_nodes + len(self.local) + len(self.glob)

    def iter_links(self) -> List[Link]:
        return (self.node_up + self.node_down + list(self.local.values())
                + list(self.glob.values()))

    def node_links(self, node: int) -> List[Link]:
        return [self.node_up[node], self.node_down[node]]


class Torus(Topology):
    """k-D torus with per-direction links — the TPU ICI fabric.

    Dimension-order routing, shortest wrap direction per dim.  A TPU v5e
    pod is a (16, 16) torus with ~50 GB/s per link per direction.
    """

    def __init__(self, dims: Tuple[int, ...], link_bw: float = 50e9,
                 hop_latency: float = 500e-9, base_latency: float = 1e-6):
        self.dims = tuple(dims)
        self.base_latency = base_latency
        self.n_nodes = math.prod(dims)
        # links[(node, dim, dir)] — dir in {+1, -1}
        self.links: Dict[Tuple[int, int, int], Link] = {}
        for n in range(self.n_nodes):
            for d in range(len(dims)):
                if dims[d] == 1:
                    continue
                self.links[(n, d, +1)] = Link(link_bw, hop_latency)
                self.links[(n, d, -1)] = Link(link_bw, hop_latency)

    def coords(self, node: int) -> Tuple[int, ...]:
        out = []
        for d in reversed(self.dims):
            out.append(node % d)
            node //= d
        return tuple(reversed(out))

    def node_at(self, coords) -> int:
        n = 0
        for c, d in zip(coords, self.dims):
            n = n * d + c
        return n

    def node_links(self, node: int) -> List[Link]:
        return [l for (n, _, _), l in self.links.items() if n == node]

    def route(self, src: int, dst: int) -> List[Link]:
        if src == dst:
            return []
        sc, dc = list(self.coords(src)), self.coords(dst)
        path: List[Link] = []
        cur = sc
        for d in range(len(self.dims)):
            size = self.dims[d]
            if size == 1:
                continue
            while cur[d] != dc[d]:
                fwd = (dc[d] - cur[d]) % size
                step = +1 if fwd <= size - fwd else -1
                node = self.node_at(cur)
                path.append(self.links[(node, d, step)])
                cur[d] = (cur[d] + step) % size
        return path


class MultiPod(Topology):
    """Pods (any intra-pod topology) joined by a DCN: per-pod up/down links
    through a non-blocking core (the cross-pod "pod" mesh axis)."""

    def __init__(self, pod_topos: List[Topology], pod_size: int,
                 dcn_bw_per_node: float = 25e9, dcn_latency: float = 10e-6):
        self.pods = pod_topos
        self.pod_size = pod_size
        self.base_latency = max(p.base_latency for p in pod_topos)
        self.dcn_latency = dcn_latency
        self.n_nodes = pod_size * len(pod_topos)
        self.dcn_up = [Link(dcn_bw_per_node * pod_size, dcn_latency)
                       for _ in pod_topos]
        self.dcn_down = [Link(dcn_bw_per_node * pod_size, dcn_latency)
                         for _ in pod_topos]

    def route(self, src: int, dst: int) -> List[Link]:
        sp, dp = src // self.pod_size, dst // self.pod_size
        sl, dl = src % self.pod_size, dst % self.pod_size
        if sp == dp:
            return self.pods[sp].route(sl, dl)
        # exit via pod gateway (node 0), cross DCN, enter at gateway
        return (self.pods[sp].route(sl, 0) + [self.dcn_up[sp],
                                              self.dcn_down[dp]]
                + self.pods[dp].route(0, dl))

    @property
    def n_links(self) -> int:
        return sum(p.n_links for p in self.pods) + 2 * len(self.pods)

    def iter_links(self) -> List[Link]:
        out: List[Link] = []
        for p in self.pods:
            out.extend(p.iter_links())
        return out + self.dcn_up + self.dcn_down

    def node_links(self, node: int) -> List[Link]:
        pod, local = node // self.pod_size, node % self.pod_size
        return self.pods[pod].node_links(local)
