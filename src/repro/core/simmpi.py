"""SimMPI — MPI library model on the stream-level network (paper §III-B2).

Peer-to-peer ops run as flows on the network model (so contention is
emergent); eager vs rendezvous protocol by message size.  Collectives are
decomposed into p2p rounds mimicking OpenMPI/IntelMPI algorithm selection
(binomial / ring / recursive-doubling / Rabenseifner / pairwise) with the
same size-based switch points.

Every rank is a DES virtual thread; ``yield from`` any op to advance
simulated time.

Message matching is exact: tags are arbitrary hashable values and the
collectives use structured ``(op_id, round, ...)`` tuples directly.  (An
earlier revision truncated tags to 16-bit hashes, which could cross-match
two overlapping collectives on the same group — op_id hygiene is now a
tested invariant, see tests/test_simmpi.py.)

Tracing: when ``engine.trace`` is enabled every collective emits one span
per member rank (tagged with group size / bytes / algorithm op key),
every p2p message an async record from isend-post to recv-completion,
and blocking recvs a span carrying the send->recv happens-before edge.
The recorder never schedules engine events, so tracing does not perturb
simulated time.
"""
from __future__ import annotations

import math
from typing import Dict, List, Optional, Tuple

from .engine import Engine, Event
from .hardware.network import Network

EAGER_LIMIT = 64 * 1024          # bytes: eager vs rendezvous
RDV_HANDSHAKE = 2                # extra half-RTTs for rendezvous


class SimMPI:
    def __init__(self, engine: Engine, network: Network, n_ranks: int,
                 rank_to_node=None, overhead: float = 5e-7):
        self.engine = engine
        self.net = network
        self.n = n_ranks
        self.rank_to_node = rank_to_node or (lambda r: r)
        self.overhead = overhead         # per-call software overhead (s)
        self._posted: Dict[Tuple[int, int, object], List[Event]] = {}
        self._recv_wait: Dict[Tuple[int, int, object], List[Event]] = {}
        self._coll_state: Dict = {}
        # rank -> node resolved once (the mapping is static by design);
        # isend is the hottest caller and skips the per-message calls
        self._node_of = [self.rank_to_node(r) for r in range(n_ranks)]
        # rendezvous handshake latency is a topology constant
        self._rdv_extra = RDV_HANDSHAKE * network.topo.base_latency
        self._p2p_msgs = 0
        self._p2p_bytes = 0.0
        self._colls = 0

    @property
    def counters(self) -> Dict:
        """Op counters as a dict (kept as plain attributes internally —
        attribute increments beat dict lookups in the isend hot path)."""
        return {"p2p_msgs": self._p2p_msgs, "p2p_bytes": self._p2p_bytes,
                "colls": self._colls}

    # ---------------------------------------------------------------- p2p
    def isend(self, src: int, dst: int, nbytes: float, tag=0) -> Event:
        """Post a send.  Returns the *sender-side* completion event:
        eager messages complete for the sender once buffered (overhead);
        rendezvous messages complete when the transfer finishes.  The
        receiver always waits for the transfer (see recv)."""
        self._p2p_msgs += 1
        self._p2p_bytes += nbytes
        eng = self.engine
        tren = eng.trace.enabled
        # fault hook: latency_jitter scales the per-message software
        # overhead (one attribute test when no faults are installed)
        overhead = self.overhead * eng.faults.latency_factor(src) \
            if eng.faults.enabled else self.overhead
        eager = nbytes <= EAGER_LIMIT
        transfer_done = eng.event()
        if src == dst:
            # schedule the bound set method — same dispatch, no
            # per-message closure allocation
            eng.call_at(eng.now + overhead, transfer_done.set, None)
            if tren:
                eng.trace.msg_post(src, dst, nbytes, tag, transfer_done)
            return transfer_done
        lat_extra = 0.0 if eager else self._rdv_extra
        node_of = self._node_of
        eng.call_at(eng.now + overhead + lat_extra, self._isend_go,
                    (node_of[src], node_of[dst], nbytes, transfer_done))
        if tren:
            eng.trace.msg_post(src, dst, nbytes, tag, transfer_done)

        # the matchbox entry carries the eager flag so recv knows the
        # sender kept no reference (eager senders get send_done instead)
        # and the transfer event can be recycled after delivery
        key = (src, dst, tag)
        entry = (transfer_done, eager)
        waiters = self._recv_wait.get(key)
        if waiters:
            waiters.pop(0).set(entry)
        else:
            self._posted.setdefault(key, []).append(entry)
        if eager:
            send_done = eng.event()
            eng.call_at(eng.now + overhead, send_done.set, None)
            return send_done
        return transfer_done

    def _isend_go(self, arg):
        """Deferred flow launch (fires after software overhead [+ rdv
        handshake]); the transfer event rides the flow-done event's
        waiters list directly — no per-message adapter."""
        src_node, dst_node, nbytes, transfer_done = arg
        flow_done = self.net.send(src_node, dst_node, nbytes)
        flow_done.waiters.append(transfer_done)

    def send(self, src: int, dst: int, nbytes: float, tag=0):
        """Generator: blocking send."""
        ev = self.isend(src, dst, nbytes, tag)
        yield ev

    def recv(self, src: int, dst: int, tag=0):
        """Generator: blocking receive — waits for the matching send's
        transfer to complete."""
        eng = self.engine
        tr = eng.trace
        t0 = eng.now if tr.enabled else 0.0
        key = (src, dst, tag)
        box = self._posted.get(key)
        if box:
            transfer, eager = box.pop(0)
        else:
            w = eng.event()
            self._recv_wait.setdefault(key, []).append(w)
            transfer, eager = yield w
            # w never escapes this generator (isend pops it from the
            # wait list before setting it), so it can go back to the
            # engine's event pool once we have resumed
            if eng.pooling:
                eng._recycle_event(w)
        yield transfer
        if tr.enabled:
            tr.recv_done(dst, src, t0, transfer)
        elif eager and eng.pooling:
            # eager transfers are invisible to the sender (it holds
            # send_done) and the recorder is off, so after delivery the
            # transfer event has no remaining references
            eng._recycle_event(transfer)

    def sendrecv(self, me: int, peer: int, nbytes: float, tag=0):
        ev = self.isend(me, peer, nbytes, tag)
        yield from self.recv(peer, me, tag)
        yield ev

    # --------------------------------------------------------- collectives
    # One generator per participating rank; all ranks call with the same
    # group and op_id (unique per call site x step — exact tuple tags mean
    # two in-flight collectives with different op_ids can never
    # cross-match).
    def _traced(self, name: str, rank: int, group: List[int], nbytes: float,
                op_id, impl):
        """Wrap a collective generator in a per-rank trace span; with
        tracing off the impl generator is returned bare (no wrapper
        frame on the resume path — yields are identical either way)."""
        tr = self.engine.trace
        if not tr.enabled:
            return impl
        return self._traced_span(name, rank, group, nbytes, op_id, impl, tr)

    def _traced_span(self, name, rank, group, nbytes, op_id, impl, tr):
        tok = tr.coll_begin(rank, name, op_id, group, nbytes)
        yield from impl
        tr.coll_end(rank, tok)

    def _gather_barrier(self, op_id, group: List[int], rank: int):
        """All ranks of `group` rendezvous; returns (event, is_root)."""
        st = self._coll_state.setdefault(op_id, {"arrived": 0,
                                                 "ev": self.engine.event()})
        st["arrived"] += 1
        if st["arrived"] == len(group):
            st["ev"].set()
            self._coll_state.pop(op_id, None)
        return st["ev"]

    def barrier(self, rank: int, group: List[int], op_id):
        return self._traced("barrier", rank, group, 0.0, op_id,
                            self._barrier_impl(rank, group, op_id))

    def _barrier_impl(self, rank: int, group: List[int], op_id):
        ev = self._gather_barrier(op_id, group, rank)
        yield ev
        # dissemination rounds: ceil(log2 n) latency exchanges
        n = len(group)
        rounds = max(1, math.ceil(math.log2(max(n, 2))))
        yield rounds * (self.net.topo.base_latency + self.overhead)

    def bcast(self, rank: int, root: int, group: List[int], nbytes: float,
              op_id):
        return self._traced("bcast", rank, group, nbytes, op_id,
                            self._bcast_impl(rank, root, group, nbytes,
                                             op_id))

    def _bcast_impl(self, rank: int, root: int, group: List[int],
                    nbytes: float, op_id):
        """Binomial tree for small msgs; scatter+ring-allgather for large
        (OpenMPI/van-de-Geijn switch at 512 KiB)."""
        self._colls += 1
        n = len(group)
        if n <= 1:
            return
        if nbytes < 512 * 1024:
            yield from self._bcast_binomial(rank, root, group, nbytes, op_id)
        else:
            # scatter (binomial, nbytes/n chunks) + ring allgather
            yield from self._bcast_binomial(rank, root, group, nbytes / n,
                                            (op_id, "scat"))
            yield from self.allgather(rank, group, nbytes / n,
                                      (op_id, "ag"))

    def _bcast_binomial(self, rank: int, root: int, group: List[int],
                        nbytes: float, op_id):
        n = len(group)
        idx = {r: i for i, r in enumerate(group)}
        me = (idx[rank] - idx[root]) % n
        rounds = math.ceil(math.log2(max(n, 2)))
        # virtual rank 0 is root; in round k, ranks < 2^k send to +2^k
        recv_round = None if me == 0 else int(math.floor(math.log2(me)))
        if recv_round is not None:
            src_v = me - (1 << recv_round)
            src = group[(src_v + idx[root]) % n]
            yield from self.recv(src, rank, tag=(op_id, me))
        start = 0 if me == 0 else recv_round + 1
        for k in range(start, rounds):
            dst_v = me + (1 << k)
            if dst_v < n:
                dst = group[(dst_v + idx[root]) % n]
                ev = self.isend(rank, dst, nbytes, tag=(op_id, dst_v))
                yield ev

    def allreduce(self, rank: int, group: List[int], nbytes: float, op_id):
        return self._traced("allreduce", rank, group, nbytes, op_id,
                            self._allreduce_impl(rank, group, nbytes,
                                                 op_id))

    def _allreduce_impl(self, rank: int, group: List[int], nbytes: float,
                        op_id):
        """Recursive doubling (small) / Rabenseifner reduce-scatter+allgather
        (large, switch 64 KiB)."""
        self._colls += 1
        n = len(group)
        if n <= 1:
            return
        idx = {r: i for i, r in enumerate(group)}
        me = idx[rank]
        if nbytes < 64 * 1024:
            rounds = math.ceil(math.log2(n))
            for k in range(rounds):
                peer_v = me ^ (1 << k)
                if peer_v < n:
                    peer = group[peer_v]
                    yield from self.sendrecv(rank, peer, nbytes,
                                             tag=(op_id, k))
        else:
            yield from self.reduce_scatter(rank, group, nbytes, (op_id, "rs"))
            yield from self.allgather(rank, group, nbytes / n, (op_id, "ag"))

    def reduce_scatter(self, rank: int, group: List[int], nbytes: float,
                       op_id):
        return self._traced("reduce_scatter", rank, group, nbytes, op_id,
                            self._reduce_scatter_impl(rank, group, nbytes,
                                                      op_id))

    def _reduce_scatter_impl(self, rank: int, group: List[int],
                             nbytes: float, op_id):
        """Ring reduce-scatter: n-1 rounds of nbytes/n to the neighbor."""
        n = len(group)
        if n <= 1:
            return
        idx = {r: i for i, r in enumerate(group)}
        me = idx[rank]
        nxt, prv = group[(me + 1) % n], group[(me - 1) % n]
        for k in range(n - 1):
            ev = self.isend(rank, nxt, nbytes / n, tag=(op_id, k, me))
            yield from self.recv(prv, rank, tag=(op_id, k, (me - 1) % n))
            yield ev

    def allgather(self, rank: int, group: List[int], nbytes_shard: float,
                  op_id):
        return self._traced("allgather", rank, group, nbytes_shard, op_id,
                            self._allgather_impl(rank, group, nbytes_shard,
                                                 op_id))

    def _allgather_impl(self, rank: int, group: List[int],
                        nbytes_shard: float, op_id):
        """Ring allgather: n-1 rounds forwarding shards."""
        n = len(group)
        if n <= 1:
            return
        idx = {r: i for i, r in enumerate(group)}
        me = idx[rank]
        nxt, prv = group[(me + 1) % n], group[(me - 1) % n]
        for k in range(n - 1):
            ev = self.isend(rank, nxt, nbytes_shard, tag=(op_id, k, me))
            yield from self.recv(prv, rank, tag=(op_id, k, (me - 1) % n))
            yield ev

    def alltoall(self, rank: int, group: List[int], nbytes_per_pair: float,
                 op_id):
        return self._traced("alltoall", rank, group, nbytes_per_pair, op_id,
                            self._alltoall_impl(rank, group,
                                                nbytes_per_pair, op_id))

    def _alltoall_impl(self, rank: int, group: List[int],
                       nbytes_per_pair: float, op_id):
        """Pairwise exchange, n-1 rounds: in round k send to (me+k) mod n
        and receive from (me-k) mod n, which covers every ordered pair for
        any group size (an XOR pairing silently skips rounds whenever
        me ^ k falls outside a non-power-of-two group)."""
        self._colls += 1
        n = len(group)
        idx = {r: i for i, r in enumerate(group)}
        me = idx[rank]
        for k in range(1, n):
            dst = group[(me + k) % n]
            src = group[(me - k) % n]
            ev = self.isend(rank, dst, nbytes_per_pair, tag=(op_id, k))
            yield from self.recv(src, rank, tag=(op_id, k))
            yield ev
