"""SimMPI — MPI library model on the stream-level network (paper §III-B2).

Peer-to-peer ops run as flows on the network model (so contention is
emergent); eager vs rendezvous protocol by message size.  Collectives are
decomposed into p2p rounds mimicking OpenMPI/IntelMPI algorithm selection
(binomial / ring / recursive-doubling / Rabenseifner / pairwise) with the
same size-based switch points.

Every rank is a DES virtual thread; ``yield from`` any op to advance
simulated time.

Message matching is exact: tags are arbitrary hashable values and the
collectives use structured ``(op_id, round, ...)`` tuples directly.  (An
earlier revision truncated tags to 16-bit hashes, which could cross-match
two overlapping collectives on the same group — op_id hygiene is now a
tested invariant, see tests/test_simmpi.py.)

Tracing: when ``engine.trace`` is enabled every collective emits one span
per member rank (tagged with group size / bytes / algorithm op key),
every p2p message an async record from isend-post to recv-completion,
and blocking recvs a span carrying the send->recv happens-before edge.
The recorder never schedules engine events, so tracing does not perturb
simulated time.
"""
from __future__ import annotations

import math
from typing import Dict, List, Optional, Tuple

from .engine import Engine, Event
from .hardware.network import Network

EAGER_LIMIT = 64 * 1024          # bytes: eager vs rendezvous
RDV_HANDSHAKE = 2                # extra half-RTTs for rendezvous


class SimMPI:
    def __init__(self, engine: Engine, network: Network, n_ranks: int,
                 rank_to_node=None, overhead: float = 5e-7):
        self.engine = engine
        self.net = network
        self.n = n_ranks
        self.rank_to_node = rank_to_node or (lambda r: r)
        self.overhead = overhead         # per-call software overhead (s)
        self._posted: Dict[Tuple[int, int, object], List[Event]] = {}
        self._recv_wait: Dict[Tuple[int, int, object], List[Event]] = {}
        self._coll_state: Dict = {}
        self.counters = {"p2p_msgs": 0, "p2p_bytes": 0.0, "colls": 0}

    # ---------------------------------------------------------------- p2p
    def isend(self, src: int, dst: int, nbytes: float, tag=0) -> Event:
        """Post a send.  Returns the *sender-side* completion event:
        eager messages complete for the sender once buffered (overhead);
        rendezvous messages complete when the transfer finishes.  The
        receiver always waits for the transfer (see recv)."""
        self.counters["p2p_msgs"] += 1
        self.counters["p2p_bytes"] += nbytes
        eng = self.engine
        # fault hook: latency_jitter scales the per-message software
        # overhead (one attribute test when no faults are installed)
        overhead = self.overhead * eng.faults.latency_factor(src) \
            if eng.faults.enabled else self.overhead
        eager = nbytes <= EAGER_LIMIT
        transfer_done = eng.event()
        if src == dst:
            eng.call_at(eng.now + overhead,
                        lambda _: transfer_done.set(), None)
            if eng.trace.enabled:
                eng.trace.msg_post(src, dst, nbytes, tag, transfer_done)
            return transfer_done
        lat_extra = 0.0 if eager \
            else RDV_HANDSHAKE * self.net.topo.base_latency

        def go(_):
            flow_done = self.net.send(self.rank_to_node(src),
                                      self.rank_to_node(dst), nbytes)
            flow_done.waiters.append(_Relay(transfer_done))
        eng.call_at(eng.now + overhead + lat_extra, go, None)
        if eng.trace.enabled:
            eng.trace.msg_post(src, dst, nbytes, tag, transfer_done)

        key = (src, dst, tag)
        waiters = self._recv_wait.get(key)
        if waiters:
            waiters.pop(0).set(transfer_done)
        else:
            self._posted.setdefault(key, []).append(transfer_done)
        if eager:
            send_done = eng.event()
            eng.call_at(eng.now + overhead,
                        lambda _: send_done.set(), None)
            return send_done
        return transfer_done

    def send(self, src: int, dst: int, nbytes: float, tag=0):
        """Generator: blocking send."""
        ev = self.isend(src, dst, nbytes, tag)
        yield ev

    def recv(self, src: int, dst: int, tag=0):
        """Generator: blocking receive — waits for the matching send's
        transfer to complete."""
        tr = self.engine.trace
        t0 = self.engine.now if tr.enabled else 0.0
        key = (src, dst, tag)
        box = self._posted.get(key)
        if box:
            transfer = box.pop(0)
        else:
            w = self.engine.event()
            self._recv_wait.setdefault(key, []).append(w)
            transfer = yield w
        yield transfer
        if tr.enabled:
            tr.recv_done(dst, src, t0, transfer)

    def sendrecv(self, me: int, peer: int, nbytes: float, tag=0):
        ev = self.isend(me, peer, nbytes, tag)
        yield from self.recv(peer, me, tag)
        yield ev

    # --------------------------------------------------------- collectives
    # One generator per participating rank; all ranks call with the same
    # group and op_id (unique per call site x step — exact tuple tags mean
    # two in-flight collectives with different op_ids can never
    # cross-match).
    def _traced(self, name: str, rank: int, group: List[int], nbytes: float,
                op_id, impl):
        """Wrap a collective generator in a per-rank trace span."""
        tr = self.engine.trace
        if not tr.enabled:
            yield from impl
            return
        tok = tr.coll_begin(rank, name, op_id, group, nbytes)
        yield from impl
        tr.coll_end(rank, tok)

    def _gather_barrier(self, op_id, group: List[int], rank: int):
        """All ranks of `group` rendezvous; returns (event, is_root)."""
        st = self._coll_state.setdefault(op_id, {"arrived": 0,
                                                 "ev": self.engine.event()})
        st["arrived"] += 1
        if st["arrived"] == len(group):
            st["ev"].set()
            self._coll_state.pop(op_id, None)
        return st["ev"]

    def barrier(self, rank: int, group: List[int], op_id):
        return self._traced("barrier", rank, group, 0.0, op_id,
                            self._barrier_impl(rank, group, op_id))

    def _barrier_impl(self, rank: int, group: List[int], op_id):
        ev = self._gather_barrier(op_id, group, rank)
        yield ev
        # dissemination rounds: ceil(log2 n) latency exchanges
        n = len(group)
        rounds = max(1, math.ceil(math.log2(max(n, 2))))
        yield rounds * (self.net.topo.base_latency + self.overhead)

    def bcast(self, rank: int, root: int, group: List[int], nbytes: float,
              op_id):
        return self._traced("bcast", rank, group, nbytes, op_id,
                            self._bcast_impl(rank, root, group, nbytes,
                                             op_id))

    def _bcast_impl(self, rank: int, root: int, group: List[int],
                    nbytes: float, op_id):
        """Binomial tree for small msgs; scatter+ring-allgather for large
        (OpenMPI/van-de-Geijn switch at 512 KiB)."""
        self.counters["colls"] += 1
        n = len(group)
        if n <= 1:
            return
        if nbytes < 512 * 1024:
            yield from self._bcast_binomial(rank, root, group, nbytes, op_id)
        else:
            # scatter (binomial, nbytes/n chunks) + ring allgather
            yield from self._bcast_binomial(rank, root, group, nbytes / n,
                                            (op_id, "scat"))
            yield from self.allgather(rank, group, nbytes / n,
                                      (op_id, "ag"))

    def _bcast_binomial(self, rank: int, root: int, group: List[int],
                        nbytes: float, op_id):
        n = len(group)
        idx = {r: i for i, r in enumerate(group)}
        me = (idx[rank] - idx[root]) % n
        rounds = math.ceil(math.log2(max(n, 2)))
        # virtual rank 0 is root; in round k, ranks < 2^k send to +2^k
        recv_round = None if me == 0 else int(math.floor(math.log2(me)))
        if recv_round is not None:
            src_v = me - (1 << recv_round)
            src = group[(src_v + idx[root]) % n]
            yield from self.recv(src, rank, tag=(op_id, me))
        start = 0 if me == 0 else recv_round + 1
        for k in range(start, rounds):
            dst_v = me + (1 << k)
            if dst_v < n:
                dst = group[(dst_v + idx[root]) % n]
                ev = self.isend(rank, dst, nbytes, tag=(op_id, dst_v))
                yield ev

    def allreduce(self, rank: int, group: List[int], nbytes: float, op_id):
        return self._traced("allreduce", rank, group, nbytes, op_id,
                            self._allreduce_impl(rank, group, nbytes,
                                                 op_id))

    def _allreduce_impl(self, rank: int, group: List[int], nbytes: float,
                        op_id):
        """Recursive doubling (small) / Rabenseifner reduce-scatter+allgather
        (large, switch 64 KiB)."""
        self.counters["colls"] += 1
        n = len(group)
        if n <= 1:
            return
        idx = {r: i for i, r in enumerate(group)}
        me = idx[rank]
        if nbytes < 64 * 1024:
            rounds = math.ceil(math.log2(n))
            for k in range(rounds):
                peer_v = me ^ (1 << k)
                if peer_v < n:
                    peer = group[peer_v]
                    yield from self.sendrecv(rank, peer, nbytes,
                                             tag=(op_id, k))
        else:
            yield from self.reduce_scatter(rank, group, nbytes, (op_id, "rs"))
            yield from self.allgather(rank, group, nbytes / n, (op_id, "ag"))

    def reduce_scatter(self, rank: int, group: List[int], nbytes: float,
                       op_id):
        return self._traced("reduce_scatter", rank, group, nbytes, op_id,
                            self._reduce_scatter_impl(rank, group, nbytes,
                                                      op_id))

    def _reduce_scatter_impl(self, rank: int, group: List[int],
                             nbytes: float, op_id):
        """Ring reduce-scatter: n-1 rounds of nbytes/n to the neighbor."""
        n = len(group)
        if n <= 1:
            return
        idx = {r: i for i, r in enumerate(group)}
        me = idx[rank]
        nxt, prv = group[(me + 1) % n], group[(me - 1) % n]
        for k in range(n - 1):
            ev = self.isend(rank, nxt, nbytes / n, tag=(op_id, k, me))
            yield from self.recv(prv, rank, tag=(op_id, k, (me - 1) % n))
            yield ev

    def allgather(self, rank: int, group: List[int], nbytes_shard: float,
                  op_id):
        return self._traced("allgather", rank, group, nbytes_shard, op_id,
                            self._allgather_impl(rank, group, nbytes_shard,
                                                 op_id))

    def _allgather_impl(self, rank: int, group: List[int],
                        nbytes_shard: float, op_id):
        """Ring allgather: n-1 rounds forwarding shards."""
        n = len(group)
        if n <= 1:
            return
        idx = {r: i for i, r in enumerate(group)}
        me = idx[rank]
        nxt, prv = group[(me + 1) % n], group[(me - 1) % n]
        for k in range(n - 1):
            ev = self.isend(rank, nxt, nbytes_shard, tag=(op_id, k, me))
            yield from self.recv(prv, rank, tag=(op_id, k, (me - 1) % n))
            yield ev

    def alltoall(self, rank: int, group: List[int], nbytes_per_pair: float,
                 op_id):
        return self._traced("alltoall", rank, group, nbytes_per_pair, op_id,
                            self._alltoall_impl(rank, group,
                                                nbytes_per_pair, op_id))

    def _alltoall_impl(self, rank: int, group: List[int],
                       nbytes_per_pair: float, op_id):
        """Pairwise exchange, n-1 rounds: in round k send to (me+k) mod n
        and receive from (me-k) mod n, which covers every ordered pair for
        any group size (an XOR pairing silently skips rounds whenever
        me ^ k falls outside a non-power-of-two group)."""
        self.counters["colls"] += 1
        n = len(group)
        idx = {r: i for i, r in enumerate(group)}
        me = idx[rank]
        for k in range(1, n):
            dst = group[(me + k) % n]
            src = group[(me - k) % n]
            ev = self.isend(rank, dst, nbytes_per_pair, tag=(op_id, k))
            yield from self.recv(src, rank, tag=(op_id, k))
            yield ev


class _Relay:
    """Adapter: lets a Network Event set another Event on fire."""
    __slots__ = ("target",)

    def __init__(self, target: Event):
        self.target = target

    def _step(self, payload=None):
        self.target.set(payload)
