"""fastsim — the HPL simulator itself as a JAX program (beyond-paper).

The paper's SystemC engine needs 4.8 h to simulate HPL on Frontera.  The
per-panel timing recurrence is a max-plus system over the P x Q grid:

  fact_k(p)        panel factorization on owning column (SimBLAS closed forms)
  arrival_k(p,q)   1-ring store&forward broadcast = prefix-max along the row
                   ring: a_i = hop*i + cummax_j<=i (d_j - hop*j)
  T_{k+1}(p,q)     = max(T_k, arrival, colmax(arrival)) + swap + update

Everything is vectorized over the grid and the panel loop is a
``lax.fori_loop`` — Frontera's 48k panels x 8,008 ranks simulate in
seconds on this laptop-class CPU (cross-validated against the DES path in
tests/test_hpl_sim.py).

Beyond single runs, this module is a *batched sweep engine* (DESIGN.md
§11): ``(N, nb, P, Q)`` and every ``FastSimParams`` field are traced
values, array shapes are padded to a small set of buckets with masking,
and compiled programs live in an LRU cache keyed on the bucket.  Hardware
what-ifs (link_bw, gemm_eff, mem_bw, lookahead, ...) therefore never
recompile, and ``sweep_hpl`` runs a whole scenario grid as one program
with a trailing scenario axis (``jax.vmap`` only for mixed-geometry
sweeps).  Because parameters are traced,
``jax.grad``/``jax.value_and_grad`` flow through the full recurrence —
see ``calibrate.fit_fastsim_params`` for gradient-based calibration.
"""
from __future__ import annotations

import contextlib
import dataclasses
import functools
import math
import time
from typing import Dict, List, Optional, Sequence, Tuple, Union

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import enable_x64

from repro.obs.metrics import RATIO_BUCKETS, get_global_metrics

from .apps.hpl import HPLConfig
from .hardware.node import NodeModel


@dataclasses.dataclass(frozen=True)
class FastSimParams:
    # node
    peak_flops: float            # per rank
    gemm_eff: float
    mem_bw: float                # per rank, effective
    theta: float                 # per-BLAS-call overhead
    # network
    link_bw: float               # per-NIC bytes/s
    net_latency: float           # per-message software+wire latency
    hop_latency: float = 90e-9
    bcast_bw_scale: float = 1.0  # contention scale on panel broadcast
    swap_bw_scale: float = 1.0   # contention scale on row swaps
    lookahead: float = 1.0       # HPL lookahead depth (1 = overlap panel)

    @staticmethod
    def from_node(node: NodeModel, *, link_bw: float,
                  ranks_per_node: int = 1, net_latency: float = 2e-6,
                  **kw) -> "FastSimParams":
        return FastSimParams(
            peak_flops=node.peak_flops / ranks_per_node,
            gemm_eff=node.gemm_efficiency,
            mem_bw=node.mem_bw * node.mem_efficiency / ranks_per_node,
            theta=node.blas_latency,
            link_bw=link_bw, net_latency=net_latency, **kw)


_PARAM_FIELDS = tuple(f.name for f in dataclasses.fields(FastSimParams))

# Registered as a pytree: a FastSimParams passed to jit is *traced*, so
# changing any value reuses the compiled program (the old code passed a
# dict of Python floats baked in at trace time).
jax.tree_util.register_dataclass(
    FastSimParams, data_fields=list(_PARAM_FIELDS), meta_fields=[])


def _f64_params(prm: FastSimParams) -> FastSimParams:
    """Normalize leaves to Python floats so the jit cache sees one dtype."""
    return FastSimParams(**{n: float(getattr(prm, n)) for n in _PARAM_FIELDS})


# ------------------------------------------------------------- bucketing
def _bucket(n: int) -> int:
    """Smallest b >= n of the form 2^k or 3*2^(k-1) (<= 1.5x padding)."""
    n = max(int(n), 1)
    p = 1 << (n - 1).bit_length()
    if p >= 4 and 3 * p // 4 >= n:
        return 3 * p // 4
    return p


def bucket_key(cfg: HPLConfig) -> Tuple[int, int, int]:
    """(n_panels_max, P_max, Q_max) compile-cache key for a config."""
    return (_bucket(cfg.n_panels), _bucket(cfg.P), _bucket(cfg.Q))


# ------------------------------------------------------------ traced core
def _sim_core(N, nb, P, Q, prm: FastSimParams,
              n_panels_max: int, P_max: int, Q_max: int):
    """HPL panel recurrence with *traced* (N, nb, P, Q, prm).

    Shapes are the static bucket (P_max, Q_max) and the loop runs
    n_panels_max iterations; rows p >= P, columns q >= Q and panels
    k >= ceil(N/nb) are padding, masked so they never touch live lanes (the
    ring-broadcast permutation maps padding columns to themselves, the
    column-sync max and the final max are mask-reduced, and the loop
    carry freezes once k reaches the live panel count).

    ``prm`` leaves are (B,)-vectors: the whole recurrence carries a
    *trailing* scenario-batch axis — grid state is (P_max, Q_max, B) —
    so a hardware what-if grid runs as one program whose gathers and
    ring permutations move contiguous B-sized blocks (a leading vmap
    axis would make every gather element-strided; measured ~4x slower).
    Geometry (N, nb, P, Q) is scalar per call; mixed-geometry sweeps
    vmap over this core with B=1 (see ``_compiled``).
    """
    f64 = jnp.float64
    N = jnp.asarray(N, jnp.int64)
    nb = jnp.asarray(nb, jnp.int64)
    P = jnp.asarray(P, jnp.int64)
    Q = jnp.asarray(Q, jnp.int64)
    B = jnp.shape(prm.peak_flops)[0]
    peak = prm.peak_flops * prm.gemm_eff                 # (B,)
    mem_bw = prm.mem_bw
    theta = prm.theta
    alpha = prm.net_latency
    bcast_bw = prm.link_bw * prm.bcast_bw_scale
    swap_bw = prm.link_bw * prm.swap_bw_scale
    lookahead = prm.lookahead

    # exact ceil-log2 via static lookup tables (float log2 can be off by
    # one ulp at powers of two, which would flip a whole latency round)
    ar2 = jnp.asarray([2.0 * math.ceil(math.log2(max(p, 2)))
                       for p in range(P_max + 1)], f64)
    swr = jnp.asarray([float(max(math.ceil(math.log2(p)), 1)) if p > 1
                       else 0.0 for p in range(P_max + 1)], f64)
    ar_lat = ar2[P] * alpha                              # (B,)
    sw_rounds = swr[P]

    row_on = jnp.arange(P_max) < P
    col_on = jnp.arange(Q_max) < Q
    active = row_on[:, None] & col_on[None, :]
    # ceil: a trailing N % nb panel is simulated at its true width
    n_panels = (N + nb - 1) // nb
    iq = jnp.arange(Q_max)

    def width(rem):
        """Panel width: nb except on the trailing partial panel (and 0 on
        padding iterations past the live panel count)."""
        return jnp.clip(jnp.minimum(nb, rem), 0)

    def numroc_vec(rem, shift, nprocs, size):
        """Vectorized NUMROC for procs 0..size-1 with owner shift."""
        ip = (jnp.arange(size) - shift) % nprocs
        nblocks = rem // nb
        base = (nblocks // nprocs) * nb
        extra = nblocks % nprocs
        return (base + jnp.where(ip < extra, nb,
                                 jnp.where(ip == extra, rem % nb, 0))
                ).astype(f64)

    def fact_time(k):
        """Panel-k factorization cost per row rank (SimBLAS closed forms):
        dger/dscal/idamax are Level-1/2 memory-bound.  Returns (P, B)."""
        rem = N - k * nb
        wf = width(rem).astype(f64)
        mloc = numroc_vec(rem, k % P, P, P_max)
        pf_bytes = 8.0 * (jnp.maximum(mloc * wf * wf - wf ** 3 / 3.0, 0.0)
                          + 3.0 * mloc * wf)
        return pf_bytes[:, None] / mem_bw + wf * (3 * theta) + wf * ar_lat

    # The T carry lives in *ring-order* space: stored column i holds the
    # absolute column (qk + i) % Q, so the broadcast root is always index
    # 0 and the prefix-max chain never gathers.  Each panel advances the
    # ring by exactly one column (qk = k % Q), so re-basing the carry for
    # the next panel is the static-roll-plus-select below — padding
    # columns (i >= Q) map to themselves throughout.  XLA CPU runs
    # dynamic gathers and cumulative scans orders of magnitude slower
    # than fusable elementwise chains on batched shapes, so both are
    # expressed with static slices + selects (bitwise-identical: max is
    # exact and the shifts are pure selection).
    #
    # ord-space NUMROC is panel-invariant: stored column i belongs to
    # proc (i - 1) % Q of the *next* panel's distribution, every panel.
    # bucket(1) == 1, so Q_max > 1 implies Q >= 2: the ord index of
    # column (k+1) % Q — i.e. 1 % Q — is static.
    idx1 = 1 if Q_max > 1 else 0

    def cummax_cols(x):
        """Inclusive prefix-max along axis 1 (Kogge-Stone shift-max)."""
        s = 1
        while s < Q_max:
            shifted = jnp.concatenate(
                [jnp.full_like(x[:, :s, :], -jnp.inf), x[:, :-s, :]],
                axis=1)
            x = jnp.maximum(x, shifted)
            s *= 2
        return x

    def ring_rebase(T):
        """Stored col i <- stored col (i+1)%Q on live cols, identity on
        padding: one static roll plus two selects."""
        if Q_max == 1:
            return T
        roll = jnp.concatenate([T[:, 1:, :], T[:, :1, :]], axis=1)
        qcol = iq[None, :, None]
        return jnp.where(
            qcol < Q - 1, roll,
            jnp.where(qcol == Q - 1,
                      jnp.broadcast_to(T[:, :1, :], T.shape), T))

    def step(k, T, fact_done):
        rem = N - k * nb
        wf = width(rem).astype(f64)                      # panel width
        mloc = numroc_vec(rem, k % P, P, P_max)                    # (P,)
        nloc = numroc_vec(jnp.maximum(rem - width(rem), 0), 1, Q,
                          Q_max)                                   # (Q,) ord

        # 2. 1-ring broadcast along each row: prefix-max recurrence.
        # fact_done was computed in the previous iteration (lookahead):
        # the owning column factored panel k right after updating the
        # panel-k columns of step k-1, overlapping the rest of the update.
        panel_bytes = 8.0 * (mloc + wf) * wf             # (P,)
        hop = alpha + panel_bytes[:, None] / bcast_bw    # (P, B)
        hi = hop[:, None, :] * iq.astype(f64)[None, :, None]
        d = (T - hi).at[:, 0, :].set(fact_done)          # chain readiness
        a = hi + cummax_cols(d)
        arrival = a.at[:, 0, :].set(fact_done)           # root holds panel

        # 3. row swaps: column ranks exchange the U strip (sync on colmax)
        # 4. update: dtrsm + dgemm on the local tile
        u_bytes = 8.0 * wf * nloc                        # (Q,)
        trsm = (wf * wf * nloc)[:, None] / peak + theta  # (Q, B)
        gemm = (2.0 * mloc[:, None, None] * nloc[None, :, None] * wf
                + 2.0 * mloc[:, None, None] * nloc[None, :, None]) \
            / peak + theta                               # (P, Q, B)
        if P_max > 1:                    # P > 1 exactly (bucket(1) == 1)
            swap = jnp.where(
                u_bytes[:, None] > 0,
                sw_rounds * (alpha + (u_bytes[:, None]
                                      / jnp.maximum(sw_rounds, 1.0))
                             / swap_bw)
                + (4.0 * 8.0 * wf * nloc)[:, None] / mem_bw,
                0.0)                                     # (Q, B)
            # column sync: every rank of a column proceeds from the
            # column max, so after_swap is row-independent — a (Q, B)
            # row vector instead of a (P, Q, B) grid.
            colmax = jnp.max(jnp.maximum(arrival, T), axis=0,
                             where=row_on[:, None, None],
                             initial=-jnp.inf)           # (Q, B)
            after_swap = colmax + swap                   # (Q, B)
            T_new = (after_swap + trsm)[None, :, :] + gemm
            as_next = after_swap[idx1]                   # (B,) static slice
        else:
            after_swap = jnp.maximum(arrival, T)         # (1, Q, B)
            T_new = after_swap + trsm[None, :, :] + gemm
            as_next = after_swap[:, idx1, :]             # (P=1, B)

        # 1'. (lookahead) factor panel k+1 on its owning column, anchored
        # right after that column updates just the next panel's columns.
        mloc_n = numroc_vec(jnp.maximum(rem - nb, 0), (k + 1) % P, P, P_max)
        w_next = width(rem - nb).astype(f64)
        gemm_nb = (2.0 * mloc_n[:, None] * w_next * wf) / peak \
            + theta                                                 # (P, B)
        ft = fact_time(k + 1)
        fact_next_overlap = as_next + gemm_nb + ft
        fact_next_serial = T_new[:, idx1, :] + ft
        fact_next = (lookahead * jnp.minimum(fact_next_overlap,
                                             fact_next_serial)
                     + (1.0 - lookahead) * fact_next_serial)
        # the panel column cannot broadcast before finishing its own step
        # only when overlapping is off; with lookahead the bcast may start
        # mid-update (HPL posts it asynchronously).
        return T_new, fact_next

    def body(k, carry):
        T, F = carry
        T2, F2 = step(k, T, F)
        live = k < n_panels
        # freeze once past the live panel count, then re-base the ring
        # (frozen values must keep rotating with qk to stay column-stable;
        # the final masked max is invariant under the live-column cycle)
        return ring_rebase(jnp.where(live, T2, T)), jnp.where(live, F2, F)

    T0 = jnp.zeros((P_max, Q_max, B), f64)
    F0 = fact_time(0)                    # panel 0: nothing to overlap with
    T, _ = jax.lax.fori_loop(0, n_panels_max, body, (T0, F0))
    total = jnp.max(jnp.where(active[:, :, None], T, -jnp.inf),
                    axis=(0, 1))                         # (B,)
    # back substitution: ~2 N^2 flops + N broadcasts (minor)
    total = total + 2.0 * N * N / (peak * P * Q) + N / nb * alpha
    return total


# ------------------------------------------------------- lane sharding
# Device-sharded batch dispatch (DESIGN.md §20): the sweep engine's
# trailing/leading scenario axis is embarrassingly parallel (every lane
# is an independent recurrence), so when more than one local device is
# available the padded lane axis can be split across them.  Off by
# default; the single-device (or indivisible-batch) fallback takes the
# exact pre-sharding code path, so results are bitwise-identical to an
# unsharded dispatch by construction.
_LANE_SHARDING = False


def set_lane_sharding(enabled: bool) -> bool:
    """Enable/disable device-sharded sweep dispatch; returns the
    previous setting (for restoration)."""
    global _LANE_SHARDING
    prev = _LANE_SHARDING
    _LANE_SHARDING = bool(enabled)
    return prev


@contextlib.contextmanager
def lane_sharding(enabled: bool = True):
    """Scoped ``set_lane_sharding`` — the serving layer wraps a wave's
    family dispatches in this context when ``shard=True``."""
    prev = set_lane_sharding(enabled)
    try:
        yield
    finally:
        set_lane_sharding(prev)


def shard_device_count() -> int:
    """How many local devices a sharded dispatch would split over."""
    return len(jax.devices())


def _shard_lanes(n_lanes: int, *trees):
    """Place ``(B,)``-leading pytrees across local devices along the
    lane axis.  Returns ``(trees, sharded)``; identity (and False) when
    sharding is off, only one device exists, or the padded batch does
    not divide the device count — the single-device fallback that keeps
    results bitwise-identical to the unsharded path."""
    if not _LANE_SHARDING:
        return trees, False
    devs = jax.devices()
    if len(devs) <= 1 or n_lanes % len(devs):
        return trees, False
    from jax.sharding import Mesh, NamedSharding, PartitionSpec
    mesh = Mesh(np.asarray(devs), ("lanes",))
    sharding = NamedSharding(mesh, PartitionSpec("lanes"))

    def put(x):
        return jax.device_put(jnp.asarray(x), sharding)

    return tuple(jax.tree_util.tree_map(put, t) for t in trees), True


def _record_shard(m, sharded: bool, prefix: str = "fastsim") -> None:
    if m.enabled and sharded:
        m.counter(f"{prefix}.sharded_dispatches").inc()
        m.gauge(f"{prefix}.shard_devices").set(shard_device_count())


# --------------------------------------------------------- compile cache
_TRACE_COUNT = 0


def trace_count() -> int:
    """How many times a simulator core has been (re)traced so far — a
    compile counter for cache-hit assertions in tests and benchmarks."""
    return _TRACE_COUNT


def _sim_core_scalar(N, nb, P, Q, prm: FastSimParams,
                     n_panels_max: int, P_max: int, Q_max: int):
    """Scalar-params entry over the trailing-batch core (B=1)."""
    prm1 = jax.tree_util.tree_map(
        lambda x: jnp.asarray(x, jnp.float64)[None], prm)
    return _sim_core(N, nb, P, Q, prm1, n_panels_max, P_max, Q_max)[0]


@functools.lru_cache(maxsize=128)
def _compiled(n_panels_max: int, P_max: int, Q_max: int, mode: str):
    """mode: 'single' (scalar in/out) | 'params' (shared geometry, (B,)
    params leaves — the trailing-batch fast path for what-if grids) |
    'batch' (vmap over geometry and params for mixed-config sweeps)."""
    core = _sim_core if mode == "params" else _sim_core_scalar

    def fn(N, nb, P, Q, prm):
        global _TRACE_COUNT
        _TRACE_COUNT += 1
        return core(N, nb, P, Q, prm, n_panels_max, P_max, Q_max)
    return jax.jit(jax.vmap(fn) if mode == "batch" else fn)


def _record_dispatch(m, key: Tuple[int, int, int], pre_traces: int,
                     dt: float, live: int, lanes: int) -> None:
    """One compiled-program dispatch into the global metrics registry:
    compile-cache hit/miss (and compile wall) per shape bucket, plus
    sweep-lane occupancy — padding lanes are pure waste, so the ratio
    is the sweep engine's utilization number."""
    bucket = "x".join(str(b) for b in key)
    misses = trace_count() - pre_traces
    if misses:
        m.counter("fastsim.compile_misses", bucket=bucket).inc(misses)
        m.histogram("fastsim.compile_wall_s", bucket=bucket).observe(dt)
    else:
        m.counter("fastsim.compile_hits", bucket=bucket).inc()
        m.histogram("fastsim.dispatch_wall_s").observe(dt)
    m.counter("fastsim.lanes_live").inc(live)
    m.counter("fastsim.lanes_padded").inc(lanes - live)
    m.histogram("fastsim.sweep_occupancy", RATIO_BUCKETS).observe(
        live / lanes)


def _run_single(cfg: HPLConfig, prm: FastSimParams) -> float:
    fn = _compiled(*bucket_key(cfg), "single")
    m = get_global_metrics()
    if not m.enabled:
        return float(fn(np.int64(cfg.N), np.int64(cfg.nb),
                        np.int64(cfg.P), np.int64(cfg.Q), _f64_params(prm)))
    pre, t0 = trace_count(), time.perf_counter()
    out = float(fn(np.int64(cfg.N), np.int64(cfg.nb),
                   np.int64(cfg.P), np.int64(cfg.Q), _f64_params(prm)))
    _record_dispatch(m, bucket_key(cfg), pre, time.perf_counter() - t0, 1, 1)
    return out


def _stack_params(prm_list: Sequence[FastSimParams],
                  lanes: Sequence[int]) -> FastSimParams:
    # numpy leaves: jit converts them on dispatch, ~10x cheaper than
    # building device arrays one field at a time
    return FastSimParams(**{
        n: np.asarray([float(getattr(prm_list[i], n)) for i in lanes],
                      np.float64)
        for n in _PARAM_FIELDS})


def _pad_pow2(idxs: List[int]) -> List[int]:
    pad = 1 << (len(idxs) - 1).bit_length()
    return idxs + [idxs[-1]] * (pad - len(idxs))


def simulate_time_traced(cfg: HPLConfig, prm: FastSimParams):
    """Differentiable scalar HPL time for traced ``prm`` leaves (call
    under ``jax.experimental.enable_x64``; config stays concrete).  This
    is the autodiff surface used by ``calibrate.fit_fastsim_params``."""
    return _sim_core_scalar(np.int64(cfg.N), np.int64(cfg.nb),
                            np.int64(cfg.P), np.int64(cfg.Q), prm,
                            *bucket_key(cfg))


def _result(cfg: HPLConfig, t: float) -> dict:
    return {"time_s": t, "gflops": cfg.flops() / t / 1e9,
            "tflops": cfg.flops() / t / 1e12}


def simulate_hpl_fast(cfg: HPLConfig, prm: FastSimParams) -> dict:
    with enable_x64(True):
        t = _run_single(cfg, prm)
    return _result(cfg, t)


# ---------------------------------------------------------- sweep engine
Configs = Union[HPLConfig, Sequence[HPLConfig]]
Params = Union[FastSimParams, Sequence[FastSimParams]]


def sweep_hpl(configs: Configs, params: Params, *,
              bucket: Optional[Tuple[int, int, int]] = None) -> List[dict]:
    """Run a scenario sweep in as few compiled programs as possible.

    ``configs`` and ``params`` are zipped; a single ``HPLConfig`` or
    ``FastSimParams`` on either side broadcasts against the other.
    Scenarios sharing an exact ``(N, nb, P, Q)`` run as one params-only
    vmap (geometry stays scalar — the fast path for hardware what-if
    grids); the remaining scenarios are grouped by shape bucket
    (``bucket_key``) and each bucket runs as one fully-vmapped call.
    Batches are padded to a power of two so repeat sweeps of any size
    reuse the compile cache.  Results come back as one
    ``simulate_hpl_fast``-style dict per scenario, in input order.

    ``bucket=(n_panels_max, P_max, Q_max)`` forces every scenario into
    ONE padded shape bucket: the whole sweep runs as a single compiled
    vmapped program regardless of geometry mix (the TOP500 fleet path —
    one compile for a whole list).  Each component is rounded up to a
    cache-friendly bucket size; a config that doesn't fit raises.
    """
    cfg_list = [configs] if isinstance(configs, HPLConfig) else list(configs)
    prm_list = [params] if isinstance(params, FastSimParams) else list(params)
    if len(cfg_list) == 1 and len(prm_list) > 1:
        cfg_list = cfg_list * len(prm_list)
    if len(prm_list) == 1 and len(cfg_list) > 1:
        prm_list = prm_list * len(cfg_list)
    if len(cfg_list) != len(prm_list):
        raise ValueError(
            f"sweep_hpl: {len(cfg_list)} configs vs {len(prm_list)} params "
            "(must match, or one side must be a single scenario)")
    if bucket is not None:
        return _sweep_forced_bucket(cfg_list, prm_list, bucket)

    by_cfg: Dict[Tuple[int, int, int, int], List[int]] = {}
    for idx, cfg in enumerate(cfg_list):
        by_cfg.setdefault((cfg.N, cfg.nb, cfg.P, cfg.Q), []).append(idx)

    times = np.empty(len(cfg_list), np.float64)
    mixed: Dict[Tuple[int, int, int], List[int]] = {}
    m = get_global_metrics()
    with enable_x64(True):
        for (N, nb, P, Q), idxs in by_cfg.items():
            key = bucket_key(cfg_list[idxs[0]])
            if len(idxs) == 1:
                mixed.setdefault(key, []).append(idxs[0])
                continue
            lanes = _pad_pow2(idxs)
            fn = _compiled(*key, "params")
            (stacked,), sharded = _shard_lanes(
                len(lanes), _stack_params(prm_list, lanes))
            if m.enabled:
                pre, t0 = trace_count(), time.perf_counter()
            out = np.asarray(fn(np.int64(N), np.int64(nb), np.int64(P),
                                np.int64(Q), stacked))
            if m.enabled:
                _record_dispatch(m, key, pre, time.perf_counter() - t0,
                                 len(idxs), len(lanes))
                _record_shard(m, sharded)
            times[idxs] = out[:len(idxs)]
        for key, idxs in mixed.items():
            if len(idxs) == 1:
                times[idxs[0]] = _run_single(cfg_list[idxs[0]],
                                             prm_list[idxs[0]])
                continue
            lanes = _pad_pow2(idxs)
            geom = np.asarray([[cfg_list[i].N, cfg_list[i].nb,
                                cfg_list[i].P, cfg_list[i].Q]
                               for i in lanes], np.int64)
            fn = _compiled(*key, "batch")
            args, sharded = _shard_lanes(
                len(lanes), geom[:, 0], geom[:, 1], geom[:, 2], geom[:, 3],
                _stack_params(prm_list, lanes))
            if m.enabled:
                pre, t0 = trace_count(), time.perf_counter()
            out = np.asarray(fn(*args))
            if m.enabled:
                _record_dispatch(m, key, pre, time.perf_counter() - t0,
                                 len(idxs), len(lanes))
                _record_shard(m, sharded)
            times[idxs] = out[:len(idxs)]
    return [_result(cfg, float(t)) for cfg, t in zip(cfg_list, times)]


def _sweep_forced_bucket(cfg_list: Sequence[HPLConfig],
                         prm_list: Sequence[FastSimParams],
                         bucket: Tuple[int, int, int]) -> List[dict]:
    """One 'batch'-mode dispatch for the whole sweep under a shared
    (rounded-up) bucket — exactly one traced program per distinct
    forced bucket, however many geometries are mixed in."""
    n_panels_max, P_max, Q_max = (_bucket(b) for b in bucket)
    for cfg in cfg_list:
        if (cfg.n_panels > n_panels_max or cfg.P > P_max
                or cfg.Q > Q_max):
            raise ValueError(
                f"sweep_hpl: config (N={cfg.N}, nb={cfg.nb}, P={cfg.P}, "
                f"Q={cfg.Q}) exceeds forced bucket "
                f"({n_panels_max}, {P_max}, {Q_max})")
    lanes = _pad_pow2(list(range(len(cfg_list))))
    geom = np.asarray([[cfg_list[i].N, cfg_list[i].nb,
                        cfg_list[i].P, cfg_list[i].Q]
                       for i in lanes], np.int64)
    m = get_global_metrics()
    with enable_x64(True):
        fn = _compiled(n_panels_max, P_max, Q_max, "batch")
        args, sharded = _shard_lanes(
            len(lanes), geom[:, 0], geom[:, 1], geom[:, 2], geom[:, 3],
            _stack_params(prm_list, lanes))
        if m.enabled:
            pre, t0 = trace_count(), time.perf_counter()
        out = np.asarray(fn(*args))
        if m.enabled:
            _record_dispatch(m, (n_panels_max, P_max, Q_max), pre,
                             time.perf_counter() - t0, len(cfg_list),
                             len(lanes))
            _record_shard(m, sharded)
    return [_result(cfg, float(t))
            for cfg, t in zip(cfg_list, out[:len(cfg_list)])]
