"""fastsim — the HPL simulator itself as a JAX program (beyond-paper).

The paper's SystemC engine needs 4.8 h to simulate HPL on Frontera.  The
per-panel timing recurrence is a max-plus system over the P x Q grid:

  fact_k(p)        panel factorization on owning column (SimBLAS closed forms)
  arrival_k(p,q)   1-ring store&forward broadcast = prefix-max along the row
                   ring: a_i = hop*i + cummax_j<=i (d_j - hop*j)
  T_{k+1}(p,q)     = max(T_k, arrival, colmax(arrival)) + swap + update

Everything is vectorized over the grid and the panel loop is a
``lax.fori_loop`` — Frontera's 48k panels x 8,008 ranks simulate in
seconds on this laptop-class CPU (cross-validated against the DES path in
tests/test_hpl_sim.py).  This is the TPU-era answer to the paper's
"simulation speed" axis: the simulator is itself a JAX program that could
run on the accelerator it models.
"""
from __future__ import annotations

import dataclasses
import math
from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from .apps.hpl import HPLConfig
from .hardware.node import NodeModel


@dataclasses.dataclass(frozen=True)
class FastSimParams:
    # node
    peak_flops: float            # per rank
    gemm_eff: float
    mem_bw: float                # per rank, effective
    theta: float                 # per-BLAS-call overhead
    # network
    link_bw: float               # per-NIC bytes/s
    net_latency: float           # per-message software+wire latency
    hop_latency: float = 90e-9
    bcast_bw_scale: float = 1.0  # contention scale on panel broadcast
    swap_bw_scale: float = 1.0   # contention scale on row swaps
    lookahead: float = 1.0       # HPL lookahead depth (1 = overlap panel)

    @staticmethod
    def from_node(node: NodeModel, *, link_bw: float,
                  ranks_per_node: int = 1, net_latency: float = 2e-6,
                  **kw) -> "FastSimParams":
        return FastSimParams(
            peak_flops=node.peak_flops / ranks_per_node,
            gemm_eff=node.gemm_efficiency,
            mem_bw=node.mem_bw * node.mem_efficiency / ranks_per_node,
            theta=node.blas_latency,
            link_bw=link_bw, net_latency=net_latency, **kw)


def _numroc_vec(rem, nb, shift, nprocs):
    """Vectorized NUMROC for all procs 0..nprocs-1 with owner shift."""
    ip = (jnp.arange(nprocs) - shift) % nprocs
    nblocks = rem // nb
    base = (nblocks // nprocs) * nb
    extra = nblocks % nprocs
    return base + jnp.where(ip < extra, nb,
                            jnp.where(ip == extra, rem % nb, 0))


@partial(jax.jit, static_argnums=(0, 1, 2, 3))
def _simulate(N: int, nb: int, P: int, Q: int, prm: dict):
    n_panels = N // nb
    peak = prm["peak_flops"] * prm["gemm_eff"]
    mem_bw = prm["mem_bw"]
    theta = prm["theta"]
    alpha = prm["net_latency"]
    bcast_bw = prm["link_bw"] * prm["bcast_bw_scale"]
    swap_bw = prm["link_bw"] * prm["swap_bw_scale"]
    ar_lat = 2.0 * math.ceil(math.log2(max(P, 2))) * alpha
    sw_rounds = max(math.ceil(math.log2(P)), 1) if P > 1 else 0

    lookahead = prm.get("lookahead", 1.0)

    def fact_time(k):
        """Panel-k factorization cost per row rank (SimBLAS closed forms):
        dger/dscal/idamax are Level-1/2 memory-bound."""
        rem = N - k * nb
        pk = k % P
        mloc = _numroc_vec(rem, nb, pk, P).astype(jnp.float64)
        pf_bytes = 8.0 * (jnp.maximum(mloc * nb * nb - nb ** 3 / 3.0, 0.0)
                          + 3.0 * mloc * nb)
        return pf_bytes / mem_bw + nb * (3 * theta) + nb * ar_lat

    def step(k, carry):
        T, fact_done = carry
        rem = N - k * nb
        qk = k % Q
        pk = k % P
        mloc = _numroc_vec(rem, nb, pk, P).astype(jnp.float64)       # (P,)
        nloc = _numroc_vec(jnp.maximum(rem - nb, 0), nb,
                           (k + 1) % Q, Q).astype(jnp.float64)       # (Q,)

        # 2. 1-ring broadcast along each row: prefix-max recurrence.
        # fact_done was computed in the previous iteration (lookahead):
        # the owning column factored panel k right after updating the
        # panel-k columns of step k-1, overlapping the rest of the update.
        panel_bytes = 8.0 * (mloc + nb) * nb             # (P,)
        hop = alpha + panel_bytes / bcast_bw             # (P,)
        order = (qk + jnp.arange(Q)) % Q                 # ring order, [qk,...]
        Tord = T[:, order]                               # (P, Q)
        d = Tord.at[:, 0].set(fact_done)                 # chain readiness
        i = jnp.arange(Q, dtype=jnp.float64)[None, :]
        a = hop[:, None] * i + jax.lax.cummax(d - hop[:, None] * i, axis=1)
        arrival_ord = a.at[:, 0].set(fact_done)          # root holds panel
        arrival = jnp.zeros_like(T).at[:, order].set(arrival_ord)

        # 3. row swaps: column ranks exchange the U strip (sync on colmax)
        u_bytes = 8.0 * nb * nloc                        # (Q,)
        swap = jnp.where(
            u_bytes > 0,
            sw_rounds * (alpha + (u_bytes / max(sw_rounds, 1)) / swap_bw)
            + (4.0 * 8.0 * nb * nloc) / mem_bw,
            0.0)[None, :] * (1.0 if P > 1 else 0.0)      # (1, Q)
        ready = jnp.maximum(arrival, T)
        if P > 1:
            ready = jnp.broadcast_to(jnp.max(ready, axis=0, keepdims=True),
                                     ready.shape)

        # 4. update: dtrsm + dgemm on the local tile
        trsm = (nb * nb * nloc)[None, :] / peak + theta
        gemm = (2.0 * mloc[:, None] * nloc[None, :] * nb
                + 2.0 * mloc[:, None] * nloc[None, :]) / peak + theta
        after_swap = ready + swap
        T_new = after_swap + trsm + gemm

        # 1'. (lookahead) factor panel k+1 on its owning column, anchored
        # right after that column updates just the next panel's nb columns.
        qn = (k + 1) % Q
        mloc_n = _numroc_vec(jnp.maximum(rem - nb, 0), nb, (k + 1) % P,
                             P).astype(jnp.float64)
        gemm_nb = (2.0 * mloc_n * nb * nb) / peak + theta            # (P,)
        fact_next_overlap = after_swap[:, qn] + gemm_nb + fact_time(k + 1)
        fact_next_serial = T_new[:, qn] + fact_time(k + 1)
        fact_next = (lookahead * jnp.minimum(fact_next_overlap,
                                             fact_next_serial)
                     + (1.0 - lookahead) * fact_next_serial)
        # the panel column cannot broadcast before finishing its own step
        # only when overlapping is off; with lookahead the bcast may start
        # mid-update (HPL posts it asynchronously).
        return T_new, fact_next

    T0 = jnp.zeros((P, Q), jnp.float64)
    F0 = fact_time(0)                    # panel 0: nothing to overlap with
    T, _ = jax.lax.fori_loop(0, n_panels, step, (T0, F0))
    total = jnp.max(T)
    # back substitution: ~2 N^2 flops + N broadcasts (minor)
    total = total + 2.0 * N * N / (peak * P * Q) + N / nb * alpha
    return total


def simulate_hpl_fast(cfg: HPLConfig, prm: FastSimParams) -> dict:
    with jax.enable_x64(True):
        t = float(_simulate(cfg.N, cfg.nb, cfg.P, cfg.Q,
                            dataclasses.asdict(prm)))
    return {"time_s": t, "gflops": cfg.flops() / t / 1e9,
            "tflops": cfg.flops() / t / 1e12}
