import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

The two lines above MUST run before any jax import — jax locks the device
count on first init.  This proves the distribution config is coherent
without real hardware: a sharding mismatch, compile-time OOM, or an
unsupported collective is a bug in the framework, surfaced here.

Usage:
    python -m repro.launch.dryrun --arch qwen2-0.5b --shape train_4k
    python -m repro.launch.dryrun --arch qwen2-0.5b --shape train_4k --multi-pod
    python -m repro.launch.dryrun --all            # full sweep (subprocess per cell)
"""
import argparse
import json
import subprocess
import sys
import time
import traceback
from pathlib import Path


def _cell_out(out_dir: Path, arch: str, shape: str, multi_pod: bool,
              tag: str = "") -> Path:
    mesh = "2x16x16" if multi_pod else "16x16"
    suffix = f"__{tag}" if tag else ""
    return out_dir / f"{arch}__{shape}__{mesh}{suffix}.json"


def run_cell(arch: str, shape_name: str, multi_pod: bool, out_dir: Path,
             overrides: dict | None = None, tag: str = "") -> dict:
    import jax
    import jax.numpy as jnp

    from repro.configs import get_config, get_shape, shape_applicable
    from repro.launch.mesh import make_production_mesh
    from repro.models import build_model
    from repro.models.api import (abstract_cache, abstract_params,
                                  abstract_state, input_specs,
                                  input_logical_specs)
    from repro.roofline.analysis import roofline_terms
    from repro.roofline.hlo_parse import (analyze, pattern_traffic,
                                          score_matcher, chunk_matcher)
    from repro.sharding.specs import (make_rules, tree_shardings, use_rules,
                                      resolve)
    from repro.train.step import make_train_step, state_specs

    cfg = get_config(arch)
    if overrides:
        import dataclasses
        cfg = dataclasses.replace(cfg, **overrides)
    shape = get_shape(shape_name)
    if not shape_applicable(cfg, shape):
        return {"arch": arch, "shape": shape_name, "skipped": True,
                "reason": "long_500k requires sub-quadratic attention "
                          "(see DESIGN.md §5)"}

    mesh = make_production_mesh(multi_pod=multi_pod)
    chips = mesh.devices.size
    mode = "train" if shape.kind == "train" else "serve"
    rules = make_rules(cfg, multi_pod=multi_pod, mode=mode,
                       global_batch=shape.global_batch)
    model = build_model(cfg)
    t0 = time.time()

    def bf16_params(p):
        return jax.tree.map(
            lambda s: (jax.ShapeDtypeStruct(s.shape, jnp.bfloat16)
                       if jnp.issubdtype(s.dtype, jnp.floating) else s), p)

    def sharded_bytes(abs_tree, sh_tree):
        """Exact persistent bytes per device (state / params+cache) from the
        shardings — the HBM-fit number (XLA:CPU temp_size is not a TPU
        memory plan; see EXPERIMENTS.md §Limitations)."""
        import numpy as np
        leaves = zip(jax.tree.leaves(abs_tree), jax.tree.leaves(sh_tree))
        total = 0
        for a, sh in leaves:
            shard = sh.shard_shape(a.shape)
            total += int(np.prod(shard)) * a.dtype.itemsize
        return total

    with mesh, use_rules(rules, mesh):
        in_logical = input_logical_specs(cfg, shape)
        batch_sh = {k: jax.sharding.NamedSharding(mesh, resolve(v, rules))
                    for k, v in in_logical.items()}
        batch_abs = input_specs(cfg, shape)

        if shape.kind == "train":
            step_fn, _ = make_train_step(cfg)
            sspec = state_specs(cfg, model)
            state_abs = abstract_state(cfg)
            state_sh = tree_shardings(sspec, mesh, rules, state_abs)
            persistent_bytes = sharded_bytes(state_abs, state_sh)
            jitted = jax.jit(step_fn,
                             in_shardings=(state_sh, batch_sh),
                             out_shardings=(state_sh, None),
                             donate_argnums=(0,))
            lowered = jitted.lower(state_abs, batch_abs)
        elif shape.kind == "prefill":
            params_abs = bf16_params(abstract_params(cfg))
            params_sh = tree_shardings(model.param_specs(), mesh, rules,
                                       params_abs)
            cache_abs = abstract_cache(cfg, shape)
            cache_sh = tree_shardings(model.cache_specs(), mesh, rules,
                                      cache_abs)

            def prefill_fn(params, batch):
                return model.prefill(params, batch, max_len=shape.seq_len)
            persistent_bytes = (sharded_bytes(params_abs, params_sh)
                                + sharded_bytes(cache_abs, cache_sh))
            jitted = jax.jit(prefill_fn,
                             in_shardings=(params_sh, batch_sh),
                             out_shardings=(cache_sh, None))
            lowered = jitted.lower(params_abs, batch_abs)
        else:  # decode
            params_abs = bf16_params(abstract_params(cfg))
            params_sh = tree_shardings(model.param_specs(), mesh, rules,
                                       params_abs)
            cache_abs = abstract_cache(cfg, shape)
            cache_sh = tree_shardings(model.cache_specs(), mesh, rules,
                                      cache_abs)
            tok_sh = batch_sh["tokens"]
            persistent_bytes = (sharded_bytes(params_abs, params_sh)
                                + sharded_bytes(cache_abs, cache_sh))
            jitted = jax.jit(model.decode,
                             in_shardings=(params_sh, cache_sh, tok_sh),
                             out_shardings=(cache_sh, None),
                             donate_argnums=(1,))
            lowered = jitted.lower(params_abs, cache_abs,
                                   batch_abs["tokens"])

        compiled = lowered.compile()
    t_compile = time.time() - t0

    mem = {}
    try:
        ma = compiled.memory_analysis()
        for k in ("argument_size_in_bytes", "output_size_in_bytes",
                  "temp_size_in_bytes", "generated_code_size_in_bytes",
                  "alias_size_in_bytes"):
            v = getattr(ma, k, None)
            if v is not None:
                mem[k] = int(v)
        print("memory_analysis:", mem)
    except Exception as e:  # pragma: no cover
        mem = {"error": str(e)}

    cost = {}
    try:
        ca = compiled.cost_analysis()
        if isinstance(ca, (list, tuple)):
            ca = ca[0]
        cost = {k: float(v) for k, v in ca.items()
                if isinstance(v, (int, float)) and k in
                ("flops", "bytes accessed", "transcendentals",
                 "utilization operand 0 {}", "optimal_seconds")}
        print("cost_analysis: flops=%.4g bytes=%.4g" %
              (cost.get("flops", 0), cost.get("bytes accessed", 0)))
    except Exception as e:  # pragma: no cover
        cost = {"error": str(e)}

    hlo = compiled.as_text()
    hh = analyze(hlo)   # while-loop-aware flops/bytes/collectives (per device)
    coll_by_op = hh["collectives"]
    per_dev_coll = hh["coll_wire_bytes"]

    terms = roofline_terms(
        per_device_flops=hh["flops"],
        per_device_bytes=hh["bytes"],
        per_device_coll_bytes=per_dev_coll,
        chips=chips, cfg=cfg, shape=shape)
    print("hlo_analyze: flops=%.4g bytes=%.4g coll=%.4g" %
          (hh["flops"], hh["bytes"], per_dev_coll))

    # kernel-adjusted roofline: measured traffic of score-/chunk-shaped
    # tiles (which the Pallas flash/SSD kernels keep in VMEM) is removed;
    # causally-skippable score dot flops are halved (kernels/flash_attention
    # skips above-diagonal blocks with @pl.when).
    kadj = None
    if shape.kind != "decode":
        sc_bytes = sc_dots = 0.0
        if not cfg.attention_free:
            sc = pattern_traffic(hlo, score_matcher(
                min(shape.seq_len, 32768), cfg.attn_block))
            sc_bytes += sc["bytes"]
            sc_dots += sc["dot_flops"]
        if cfg.ssm is not None and cfg.attention_free:
            # pure-SSM only: on hybrids the chunk matcher can overlap the
            # score matcher (double-count) — stay conservative
            ck = pattern_traffic(hlo, chunk_matcher(cfg.ssm.chunk_size))
            sc_bytes += ck["bytes"]
            sc_dots += ck["dot_flops"] * 0.0   # SSD chunk dots are dense
        adj_flops = hh["flops"] - 0.5 * sc_dots
        adj_bytes = max(hh["bytes"] - sc_bytes, 0.0)
        kadj = roofline_terms(
            per_device_flops=adj_flops, per_device_bytes=adj_bytes,
            per_device_coll_bytes=per_dev_coll, chips=chips,
            cfg=cfg, shape=shape)
        kadj["removed_tile_bytes"] = sc_bytes
        kadj["halved_score_dot_flops"] = sc_dots
        print("kernel-adjusted: flops=%.4g bytes=%.4g -> bound=%.4gs" %
              (adj_flops, adj_bytes, kadj["bound_s"]))

    rec = {
        "arch": arch, "shape": shape_name, "tag": tag,
        "overrides": {k: str(v) for k, v in (overrides or {}).items()},
        "mesh": "2x16x16" if multi_pod else "16x16",
        "chips": chips, "kind": shape.kind,
        "compile_s": t_compile,
        "memory_analysis": mem, "cost_analysis": cost,
        "persistent_bytes_per_device": persistent_bytes,
        "collectives": coll_by_op, "roofline": terms,
        "roofline_kernel_adjusted": kadj,
        "scheme": rules.get("tp") and "tp" or "sp",
        "ok": True,
    }
    out_path = _cell_out(out_dir, arch, shape_name, multi_pod, tag)
    out_path.parent.mkdir(parents=True, exist_ok=True)
    out_path.write_text(json.dumps(rec, indent=1))
    print(f"[dryrun] {arch} x {shape_name} x {rec['mesh']}: "
          f"compile {t_compile:.1f}s, dominant={terms['dominant']}, "
          f"bound={terms['bound_s']:.4g}s")
    return rec


def sweep(out_dir: Path, multi_pod_too: bool = True, force: bool = False):
    from repro.configs import SHAPES, list_archs, get_config, shape_applicable
    cells = []
    for arch in list_archs():
        for shape in SHAPES:
            for mp in ([False, True] if multi_pod_too else [False]):
                cells.append((arch, shape, mp))
    done = failed = skipped = 0
    for arch, shape, mp in cells:
        out = _cell_out(out_dir, arch, shape, mp)
        if out.exists() and not force:
            prev = json.loads(out.read_text())
            if prev.get("ok") or prev.get("skipped"):
                done += 1
                continue
        if not shape_applicable(get_config(arch), __import__(
                "repro.configs", fromlist=["SHAPES"]).SHAPES[shape]):
            out.parent.mkdir(parents=True, exist_ok=True)
            out.write_text(json.dumps(
                {"arch": arch, "shape": shape, "skipped": True,
                 "mesh": "2x16x16" if mp else "16x16",
                 "reason": "long_500k needs sub-quadratic attention"}))
            skipped += 1
            continue
        cmd = [sys.executable, "-m", "repro.launch.dryrun", "--arch", arch,
               "--shape", shape, "--out", str(out_dir)]
        if mp:
            cmd.append("--multi-pod")
        print(f"[sweep] {arch} x {shape} x "
              f"{'2x16x16' if mp else '16x16'}", flush=True)
        r = subprocess.run(cmd, capture_output=True, text=True,
                           timeout=7200)
        if r.returncode != 0:
            failed += 1
            out.parent.mkdir(parents=True, exist_ok=True)
            out.write_text(json.dumps(
                {"arch": arch, "shape": shape, "ok": False,
                 "mesh": "2x16x16" if mp else "16x16",
                 "error": r.stdout[-2000:] + r.stderr[-4000:]}))
            print(f"[sweep] FAILED {arch} x {shape}:\n{r.stderr[-1500:]}",
                  flush=True)
        else:
            done += 1
    print(f"[sweep] done={done} failed={failed} skipped={skipped}")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--out", default="experiments/dryrun")
    ap.add_argument("--tag", default="", help="suffix for the output record")
    ap.add_argument("--set", action="append", default=[],
                    help="config override key=value (perf hillclimb)")
    args = ap.parse_args()
    overrides = {}
    for kv in getattr(args, "set"):
        k, v = kv.split("=", 1)
        try:
            v = int(v)
        except ValueError:
            try:
                v = float(v)
            except ValueError:
                pass
        overrides[k] = v
    out_dir = Path(args.out)
    if args.all:
        sweep(out_dir, force=args.force)
        return
    try:
        run_cell(args.arch, args.shape, args.multi_pod, out_dir,
                 overrides=overrides or None, tag=args.tag)
    except Exception:
        traceback.print_exc()
        sys.exit(1)


if __name__ == "__main__":
    main()
