"""Serving launcher (reduced configs execute on CPU; production decode
shapes are exercised via launch/dryrun.py).

    python -m repro.launch.serve --arch qwen2-0.5b --smoke --requests 8
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--batch-slots", type=int, default=4)
    args = ap.parse_args()

    from repro.configs import get_config, reduced
    from repro.models import build_model
    from repro.serve.engine import Request, ServeEngine

    cfg = get_config(args.arch)
    if args.smoke:
        cfg = reduced(cfg)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    eng = ServeEngine(cfg, params, batch_slots=args.batch_slots,
                      max_len=args.prompt_len + args.max_new + 1)
    rng = np.random.default_rng(0)
    reqs = [Request(rid=i,
                    prompt=rng.integers(0, cfg.vocab_size,
                                        args.prompt_len).astype(np.int32),
                    max_new_tokens=args.max_new)
            for i in range(args.requests)]
    t0 = time.perf_counter()
    results = eng.run(reqs)
    dt = time.perf_counter() - t0
    total = sum(len(v) for v in results.values())
    print(f"[serve] {len(results)} requests, {total} tokens in {dt:.2f}s "
          f"({total/dt:.1f} tok/s) — stats {eng.stats}")


if __name__ == "__main__":
    main()
