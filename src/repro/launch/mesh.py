"""Production mesh builders.

Defined as FUNCTIONS (not module constants) so importing this module never
touches jax device state.  The single-pod production mesh is a 16x16 = 256
chip pod ("data", "model"); the multi-pod mesh is 2 pods = 512 chips
("pod", "data", "model") where the "pod" axis crosses the (slow) DCN.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_local_mesh(data: int = 1, model: int = 1):
    """Small mesh over however many (host) devices exist — used by tests."""
    return jax.make_mesh((data, model), ("data", "model"))
