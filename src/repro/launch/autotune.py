"""Simulation-driven sharding selection (beyond-paper).

The paper's §V pitch is deployment planning without touching the cluster.
Applied to our own framework: for a given (arch × shape × mesh) cell,
*dry-run every candidate sharding scheme* (tp / sp / dp + remat policies),
analyze each compiled artifact, and pick the scheme with the lowest
roofline bound — the simulator chooses the parallelism config.

    PYTHONPATH=src python -m repro.launch.autotune --arch mamba2-780m \
        --shape train_4k

Each candidate costs one lower+compile (~10 s on this container); results
land in experiments/autotune/ and the winner is printed with its full
term breakdown.
"""
import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=512")

import argparse
import json
from pathlib import Path


def candidates_for(cfg, shape):
    """Candidate (tag, overrides) list — legal schemes only."""
    from repro.sharding.specs import scheme_for
    base_scheme = scheme_for(cfg, 16)
    cands = [("default", {})]
    for scheme in ("tp", "sp", "dp"):
        if scheme == base_scheme:
            continue
        if scheme == "tp" and not (cfg.n_kv_heads % 16 == 0
                                   or (cfg.n_heads // cfg.n_kv_heads) % 16
                                   == 0 or cfg.family == "ssm"):
            continue
        cands.append((f"scheme_{scheme}", {"force_scheme": scheme}))
    if shape.kind == "train" and cfg.remat != "dots_nb":
        cands.append(("dots_nb", {"remat": "dots_nb"}))
    if shape.kind == "train" and cfg.remat != "full":
        cands.append(("remat_full", {"remat": "full"}))
    return cands


def autotune(arch: str, shape_name: str, multi_pod: bool = False,
             out_dir: str = "experiments/autotune"):
    from repro.configs import get_config, get_shape
    from repro.launch.dryrun import run_cell

    cfg = get_config(arch)
    shape = get_shape(shape_name)
    results = []
    for tag, overrides in candidates_for(cfg, shape):
        try:
            rec = run_cell(arch, shape_name, multi_pod, Path(out_dir),
                           overrides=overrides or None,
                           tag=f"auto_{tag}")
        except Exception as e:   # a candidate failing is information
            results.append({"tag": tag, "ok": False, "error": str(e)[:200]})
            continue
        k = rec.get("roofline_kernel_adjusted") or rec["roofline"]
        # feasibility: exact persistent (state/params+cache) bytes per
        # device must leave headroom for activations (XLA:CPU temp_size is
        # not a TPU memory plan — EXPERIMENTS.md §Limitations)
        hbm_bytes = rec.get("persistent_bytes_per_device", 0)
        fits = hbm_bytes <= 0.8 * 16e9
        results.append({"tag": tag, "ok": True, "fits_hbm": fits,
                        "hbm_gb": hbm_bytes / 1e9,
                        "bound_s": k["bound_s"],
                        "dominant": k["dominant"],
                        "compute_s": k["compute_s"],
                        "memory_s": k["memory_s"],
                        "collective_s": k["collective_s"],
                        "mfu": k.get("mfu_at_bound", 0.0)})
    ok = [r for r in results if r.get("ok") and r.get("fits_hbm", True)]
    ok.sort(key=lambda r: r["bound_s"])
    print(f"\n[autotune] {arch} x {shape_name} "
          f"({'2x16x16' if multi_pod else '16x16'}):")
    for r in ok:
        mark = " <== winner" if r is ok[0] else ""
        print(f"  {r['tag']:14s} bound={r['bound_s']:8.3f}s "
              f"dom={r['dominant']:10s} mfu={r['mfu']:.3f} "
              f"hbm={r['hbm_gb']:.1f}GB{mark}")
    for r in results:
        if r.get("ok") and not r.get("fits_hbm", True):
            print(f"  {r['tag']:14s} INFEASIBLE: persistent state "
                  f"{r['hbm_gb']:.1f} GB > 80% of 16 GB HBM "
                  f"(bound would be {r['bound_s']:.3f}s)")
        elif not r.get("ok"):
            print(f"  {r['tag']:14s} FAILED: {r['error']}")
    summary = Path(out_dir) / f"{arch}__{shape_name}__summary.json"
    summary.parent.mkdir(parents=True, exist_ok=True)
    summary.write_text(json.dumps(results, indent=1))
    return ok[0] if ok else None


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True)
    ap.add_argument("--multi-pod", action="store_true")
    args = ap.parse_args()
    autotune(args.arch, args.shape, args.multi_pod)


if __name__ == "__main__":
    main()
