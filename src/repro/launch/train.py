"""Training launcher.

    python -m repro.launch.train --arch qwen2-0.5b --smoke --steps 50
    python -m repro.launch.train --arch minitron-8b --shape train_4k --dryrun

Full production shapes only *lower/compile* on this CPU container (the
dry-run path); real execution is for reduced configs (--smoke).
"""
from __future__ import annotations

import argparse


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", default="train_4k")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--smoke", action="store_true",
                    help="reduced config, real execution on CPU")
    ap.add_argument("--dryrun", action="store_true",
                    help="lower+compile the production cell instead")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--global-batch", type=int, default=8)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--ckpt-dir", default=None)
    args = ap.parse_args()

    if args.dryrun:
        import os
        import subprocess
        import sys
        cmd = [sys.executable, "-m", "repro.launch.dryrun", "--arch",
               args.arch, "--shape", args.shape]
        if args.multi_pod:
            cmd.append("--multi-pod")
        raise SystemExit(subprocess.call(cmd))

    from repro.configs import get_config, reduced
    from repro.train.loop import train

    cfg = get_config(args.arch)
    if args.smoke:
        cfg = reduced(cfg)
    res = train(cfg, steps=args.steps, global_batch=args.global_batch,
                seq_len=args.seq_len, lr=args.lr,
                microbatches=args.microbatches, ckpt_dir=args.ckpt_dir)
    print(f"[train] done: loss {res['first_loss']:.4f} -> "
          f"{res['final_loss']:.4f} (median step "
          f"{res['median_step_s']*1e3:.0f} ms)")


if __name__ == "__main__":
    main()
