"""``python -m repro.campaign`` — run / merge / report.

The hpcbench-style driver surface over the campaign layer:

    # execute a spec, journal every run, print the ranked report
    python -m repro.campaign run spec.json --journal runs.ndjson

    # fold journals (partial ones from killed runs included)
    python -m repro.campaign merge a.ndjson b.ndjson --out merged.ndjson

    # render a merged (or raw) journal
    python -m repro.campaign report merged.ndjson --md report.md \
        --csv runs.csv --json report.json

``run`` also accepts ``--edition-study E1 E2 [...]``: a shorthand that
builds the longitudinal TOP500 spec (one fleet selector per vendored
sample edition) without writing a spec file — the ISSUE's two-edition
drift study is ``run --edition-study 2020_06 2020_11``.
"""
from __future__ import annotations

import argparse
import json
import sys
from typing import List, Optional

from .exec import run_campaign
from .report import (campaign_report, merge_journals, render_markdown,
                     render_text, write_csv, write_journal)
from .spec import CampaignSpec, PlatformSelector


def edition_study_spec(editions: List[str], *, name: str = "",
                       limit: int = 0) -> CampaignSpec:
    """The longitudinal TOP500 campaign: one fleet selector per vendored
    sample edition (prediction + per-fabric calibration per edition,
    drift reported between the earliest and latest)."""
    return CampaignSpec(
        name=name or f"top500-drift-{'-'.join(editions)}",
        platforms=tuple(PlatformSelector(top500=f"sample:{ed}",
                                         limit=limit)
                        for ed in editions))


def _cmd_run(args) -> int:
    if args.edition_study:
        spec = edition_study_spec(args.edition_study, limit=args.limit)
    elif args.spec:
        spec = CampaignSpec.load(args.spec)
    else:
        print("run: need a spec file or --edition-study", file=sys.stderr)
        return 2
    tuning = None
    if args.max_ranks:
        from repro.top500 import FleetTuning
        tuning = FleetTuning(max_ranks=args.max_ranks,
                             panels_cap=max(args.max_ranks * 8, 2048))
    result = run_campaign(spec, journal=args.journal, tuning=tuning,
                          strict=args.strict)
    report = campaign_report(result.records)
    out = render_markdown(report) if args.markdown \
        else render_text(report)
    print(out, end="")
    print(f"[campaign {spec.name!r}: {len(result.matrix.cases)} runs "
          f"in {result.wall_s:.1f}s"
          + (f"; journal -> {args.journal}" if args.journal else "")
          + "]", file=sys.stderr)
    return 0


def _cmd_merge(args) -> int:
    records = merge_journals(args.journals, strict=args.strict)
    write_journal(records, args.out)
    merged = records[-1]["meta"]
    print(f"merged {len(args.journals)} journal(s): "
          f"{merged['n_runs']} runs, {merged['n_summaries']} "
          f"summaries -> {args.out}", file=sys.stderr)
    return 0


def _cmd_report(args) -> int:
    records = merge_journals(args.journals, strict=args.strict)
    report = campaign_report(records)
    if args.json:
        with open(args.json, "w") as fh:
            json.dump(report, fh, indent=2, sort_keys=True)
    if args.csv:
        write_csv(records, args.csv)
    md = render_markdown(report, top=args.top)
    if args.md:
        with open(args.md, "w") as fh:
            fh.write(md)
    print(md if args.markdown else render_text(report, top=args.top),
          end="")
    return 0


def build_parser() -> argparse.ArgumentParser:
    p = argparse.ArgumentParser(
        prog="python -m repro.campaign",
        description="Declarative fleet studies over the prediction "
                    "stack: run a campaign spec, merge NDJSON journals, "
                    "render ranked + drift reports.")
    sub = p.add_subparsers(dest="cmd", required=True)

    r = sub.add_parser("run", help="execute a campaign spec")
    r.add_argument("spec", nargs="?", help="campaign spec JSON file")
    r.add_argument("--edition-study", nargs="+", metavar="EDITION",
                   help="shorthand: longitudinal study over vendored "
                        "TOP500 sample editions (e.g. 2020_06 2020_11)")
    r.add_argument("--limit", type=int, default=0,
                   help="edition-study: top-N rows per edition")
    r.add_argument("--max-ranks", type=int, default=0,
                   help="fleet proxy-grid cap (FleetTuning.max_ranks)")
    r.add_argument("--journal", help="append one NDJSON line per run")
    r.add_argument("--strict", action="store_true",
                   help="resolution errors raise instead of isolating")
    r.add_argument("--markdown", action="store_true",
                   help="print Markdown instead of aligned text")
    r.set_defaults(fn=_cmd_run)

    m = sub.add_parser("merge", help="fold NDJSON journals into one")
    m.add_argument("journals", nargs="+")
    m.add_argument("--out", required=True, help="merged NDJSON path")
    m.add_argument("--strict", action="store_true",
                   help="corrupt journal lines raise instead of skip")
    m.set_defaults(fn=_cmd_merge)

    rp = sub.add_parser("report", help="render journals as a report")
    rp.add_argument("journals", nargs="+")
    rp.add_argument("--json", help="write the report dict as JSON")
    rp.add_argument("--csv", help="write one CSV row per run")
    rp.add_argument("--md", help="write the Markdown report")
    rp.add_argument("--top", type=int, default=20,
                    help="rows per ranked table")
    rp.add_argument("--strict", action="store_true",
                    help="corrupt journal lines raise instead of skip")
    rp.add_argument("--markdown", action="store_true",
                    help="print Markdown instead of aligned text")
    rp.set_defaults(fn=_cmd_report)
    return p


def main(argv: Optional[List[str]] = None) -> int:
    args = build_parser().parse_args(argv)
    return args.fn(args)


if __name__ == "__main__":
    raise SystemExit(main())
