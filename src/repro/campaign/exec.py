"""Campaign executor: the whole matrix through the batched engines.

One ``run_campaign`` call serves the entire expanded matrix with the
same economy the layers below already guarantee:

  * every grid case becomes one ``WorkloadRequest`` into a single
    ``PredictionService.predict_batch`` — one ``sweep_models`` dispatch
    per workload family per wave, so (2 workloads x 3 platforms x axes
    x faults x seeds) costs two compiled sweeps, not N;
  * every fleet edition runs through ``top500.predict_fleet`` — one
    forced-bucket ``sweep_hpl`` compile per edition regardless of how
    many machine geometries the list mixes, per-fabric calibration
    included.

Everything reports into ONE ``MetricsRegistry`` installed as the
global metrics sink for the duration, so the fastsim/stepsim compile
counters (``fastsim.compile_misses``/``stepsim.compile_misses``) are
the ground truth for the one-compile-per-family claim — the campaign
summary carries them and tests assert on them.

Journaling: one ``campaign_run`` NDJSON line per run (pure identity +
result payload, no wall clocks — equal campaigns give byte-equal run
lines) plus one trailing ``campaign_summary`` line (spec echo, dispatch
counts, per-edition calibration, wall time, full metrics snapshot —
the only place timing lives).  With ``journal=``, lines are appended
as they are produced, so a killed run leaves a readable prefix (the
lenient ``read_manifest`` skips a torn trailing line).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Dict, List, Optional

from repro.obs import MetricsRegistry, global_metrics
from repro.obs.export import manifest_record
from repro.obs.metrics import parse_key

from .matrix import RunMatrix, expand
from .spec import CampaignSpec

#: result keys stripped from grid run records (per-request wall clocks
#: would break byte-equal journals; timing belongs to the summary)
_TIMING_KEYS = ("wall_s", "latency_s")


@dataclasses.dataclass
class CampaignResult:
    """Everything one campaign run produced: the matrix, per-run
    records (journal order), per-edition fleet reports, and the shared
    metrics registry."""
    spec: CampaignSpec
    matrix: RunMatrix
    records: List[Dict[str, Any]]
    fleet_reports: Dict[str, Any]           # edition -> FleetReport
    grid_results: Dict[int, dict]           # case index -> result
    metrics: Any
    wall_s: float

    @property
    def run_records(self) -> List[Dict[str, Any]]:
        return [r for r in self.records if r["kind"] == "campaign_run"]

    @property
    def summary(self) -> Dict[str, Any]:
        return next(r for r in self.records
                    if r["kind"] == "campaign_summary")

    def lines(self) -> List[str]:
        import json
        return [json.dumps(r, sort_keys=True) for r in self.records]

    def write_journal(self, path) -> None:
        with open(path, "w") as fh:
            for line in self.lines():
                fh.write(line + "\n")


def dispatch_counts(snapshot: Dict[str, Any]) -> Dict[str, int]:
    """Model-dispatch totals off a metrics snapshot: per compiled-sweep
    family, misses (fresh compiles) + hits (bucket reuse) = dispatches.
    This is the observable the one-compile-per-family acceptance
    criterion is asserted against."""
    out = {"fastsim_compiles": 0, "fastsim_dispatches": 0,
           "stepsim_compiles": 0, "stepsim_dispatches": 0,
           "serve_sweeps": 0, "cache_hits": 0, "cache_misses": 0,
           "coalesced": 0}
    for key, val in snapshot.get("counters", {}).items():
        name, _ = parse_key(key)
        if name == "fastsim.compile_misses":
            out["fastsim_compiles"] += int(val)
            out["fastsim_dispatches"] += int(val)
        elif name == "fastsim.compile_hits":
            out["fastsim_dispatches"] += int(val)
        elif name == "stepsim.compile_misses":
            out["stepsim_compiles"] += int(val)
            out["stepsim_dispatches"] += int(val)
        elif name == "stepsim.compile_hits":
            out["stepsim_dispatches"] += int(val)
        elif name == "serve.sweeps":
            out["serve_sweeps"] += int(val)
        elif name == "serve.cache_hits":
            out["cache_hits"] += int(val)
        elif name == "serve.cache_misses":
            out["cache_misses"] += int(val)
        elif name == "serve.coalesced":
            out["coalesced"] += int(val)
    return out


def _grid_result_payload(out: Optional[dict]) -> Optional[dict]:
    """The journaled slice of a grid result: everything the sweep
    computed, minus wall-clock fields, the (trace-sized) breakdown, and
    the ``cached`` provenance stamp (a warm-cache re-run must journal
    byte-equal ``campaign_run`` lines)."""
    if out is None:
        return None
    return {k: v for k, v in out.items()
            if k not in _TIMING_KEYS and k not in ("breakdown", "cached")}


def _fleet_entry_payload(entry) -> dict:
    err = entry.rel_err
    return {
        "family": entry.family,
        "published_tflops": entry.published_tflops,
        "predicted_tflops": entry.predicted_tflops,
        "calibrated_tflops": entry.calibrated_tflops,
        "rel_err": None if err != err else err,
        "split": entry.split,
        "proxy_scale": entry.scale,
        "proxy_cfg": {"N": entry.cfg.N, "nb": entry.cfg.nb,
                      "P": entry.cfg.P, "Q": entry.cfg.Q},
    }


def run_campaign(spec: CampaignSpec, *, journal=None, metrics=None,
                 tuning=None, calibrate: bool = True,
                 max_batch: int = 256, strict: bool = False,
                 service=None, cache=None) -> CampaignResult:
    """Execute a campaign end to end; see the module docstring for the
    batching/journaling contract.

    ``journal`` — path to append NDJSON lines to as they are produced.
    ``metrics`` — a shared ``MetricsRegistry`` (default: fresh, or the
    service's registry when ``service=`` is given).
    ``tuning``/``calibrate`` — forwarded to ``predict_fleet``.
    ``strict`` — grid resolution errors raise instead of being isolated
    into per-run ``{"status": "error"}`` records.
    ``service`` — a caller-held ``PredictionService`` to route grid
    cases through; re-running an identical campaign against a warm
    cached service is all-hits with byte-equal ``campaign_run`` lines.
    ``cache`` — forwarded to the internally-built service when
    ``service`` is not given (True/int/ResultCache, see ``repro.serve``).

    The summary's ``dispatches`` are deltas over this campaign (counter
    totals at entry are subtracted), so shared registries and reused
    services report per-campaign compile economy, not lifetime totals.
    """
    from repro.serve import PredictionService, WorkloadRequest

    if metrics is None:
        registry = service.metrics if service is not None \
            else MetricsRegistry()
    else:
        registry = metrics
    counts_start = dispatch_counts(
        registry.snapshot() if registry.enabled else {})
    matrix = expand(spec, strict=strict)
    records: List[Dict[str, Any]] = []
    t_start = time.perf_counter()

    def emit(rec: Dict[str, Any]) -> None:
        records.append(rec)
        if journal is not None:
            import json
            with open(journal, "a") as fh:
                fh.write(json.dumps(rec, sort_keys=True) + "\n")

    grid_results: Dict[int, dict] = {}
    fleet_reports: Dict[str, Any] = {}
    with global_metrics(registry):
        # ------------------------------------------------- grid cases
        grid = matrix.grid_cases
        if grid:
            svc = service if service is not None else PredictionService(
                max_batch=max_batch, metrics=registry, cache=cache)
            reqs = [WorkloadRequest(rid=c.index, workload=c.workload,
                                    platform=matrix.platforms[c.platform],
                                    faults=c.fault)
                    for c in grid]
            grid_results = svc.predict_batch(
                reqs, isolate_errors=not strict)
            for case in grid:
                meta = {"campaign": spec.name, **case.to_meta(),
                        "result": _grid_result_payload(
                            grid_results.get(case.index))}
                emit(manifest_record("campaign_run", meta=meta))

        # ------------------------------------------------ fleet cases
        for edition in matrix.editions():
            from repro.top500 import predict_fleet
            report = predict_fleet(matrix.fleets[edition], tuning=tuning,
                                   calibrate=calibrate, metrics=registry)
            fleet_reports[edition] = report
            by_name = {e.platform.name: e for e in report.entries}
            for case in matrix.fleet_cases:
                if case.edition != edition:
                    continue
                entry = by_name[case.platform]
                meta = {"campaign": spec.name, **case.to_meta(),
                        "result": _fleet_entry_payload(entry)}
                emit(manifest_record("campaign_run", meta=meta))

    wall_s = time.perf_counter() - t_start
    snap = registry.snapshot() if registry.enabled else {}
    editions_meta = {}
    for edition, report in fleet_reports.items():
        med, held = report.median_abs_err(), report.median_abs_err("test")
        editions_meta[edition] = {
            "machines": len(report.entries),
            "compiles": report.compiles,
            "median_abs_err": None if med != med else med,
            "heldout_median_abs_err": None if held != held else held,
            "calibration_factors": (
                dict(sorted(report.calibration.factors.items()))
                if report.calibration is not None else {}),
        }
    summary_meta = {
        "campaign": spec.name,
        "spec": spec.to_dict(),
        "runs": len(matrix.cases),
        "grid_runs": len(matrix.grid_cases),
        "fleet_runs": len(matrix.fleet_cases),
        "skipped": [list(kv) for kv in matrix.skipped],
        "dispatches": {k: v - counts_start.get(k, 0)
                       for k, v in dispatch_counts(snap).items()},
        "editions": editions_meta,
        "wall_s": wall_s,                 # the one timing field
    }
    emit(manifest_record("campaign_summary", meta=summary_meta,
                         metrics=registry if registry.enabled else None))
    return CampaignResult(spec=spec, matrix=matrix, records=records,
                          fleet_reports=fleet_reports,
                          grid_results=grid_results, metrics=registry,
                          wall_s=wall_s)
