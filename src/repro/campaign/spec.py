"""CampaignSpec — declarative fleet studies as frozen, shippable data.

The paper's headline number is fleet-scale (HPL across a TOP500 list at
a few percent error), yet every fleet study so far has been a one-shot
script.  hpcbench drives everything from YAML campaigns (a benchmark x
platform matrix plus merge/report tools); this module is the analogous
surface for the prediction stack: one ``CampaignSpec`` names WHAT to
study — workloads, platforms, sweep axes, fault scenarios, seeds — and
``repro.campaign.matrix.expand`` turns it into a deterministic run
matrix the executor routes through the batched engines.

Like every other spec in the repo (``Platform``, ``WorkloadSpec``,
``FaultSpec``), a campaign is frozen, hashable data with an exact JSON
round trip, so studies can be versioned, diffed, and replayed:

    spec = CampaignSpec.make(
        "edition-drift",
        workloads=["hpl"],
        platforms=[{"top500": "sample:2020_06"},
                   {"top500": "sample:2020_11"}],
        seeds=[0])
    CampaignSpec.from_json(spec.to_json()) == spec     # always

Platform selectors come in two kinds, mirroring how the repo names
machines:

  * ``{"registry": "frontera"}`` — one registered platform; expands
    against the workload/axis/fault/seed grid ("grid" runs, served
    through ``PredictionService``).
  * ``{"top500": <csv path or "sample:<edition>">}`` — a whole list
    edition; every parseable row becomes one machine ("fleet" runs,
    served through ``top500.predict_fleet`` — one compile for the whole
    edition, per-fabric calibration included).  ``edition`` labels the
    group (defaults to the sample edition or the file stem); ``limit``
    caps how many top rows are taken.

Axes are named workload knobs (``{"N": [4096, 8192]}``) crossed
cartesianly; an axis key must be a knob of at least one workload in the
campaign and is applied only to the workloads that know it.  Unknown
workload kinds, platform names, and axis keys all fail fast with
difflib close-match hints, matching the ``get_platform`` error UX.
"""
from __future__ import annotations

import dataclasses
import difflib
import json
from typing import Any, Dict, List, Optional, Sequence, Tuple

from repro.faults.spec import FaultSpec
from repro.workloads.base import WorkloadSpec

CAMPAIGN_VERSION = 1

_JSON_SCALARS = (str, int, float, bool, type(None))

#: workload knobs that are legal axis keys but absent from the kind's
#: default spec (geometry/config keys resolved per platform)
EXTRA_AXIS_KEYS: Dict[str, Tuple[str, ...]] = {
    "hpl": ("N", "nb", "P", "Q", "bcast", "lookahead"),
    "transformer": ("mesh", "pods"),
}


def _freeze(v):
    if isinstance(v, (list, tuple)):
        return tuple(_freeze(x) for x in v)
    if isinstance(v, _JSON_SCALARS):
        return v
    raise TypeError(f"campaign axis values must be JSON-safe scalars or "
                    f"lists, got {type(v).__name__}: {v!r}")


def _thaw(v):
    if isinstance(v, tuple):
        return [_thaw(x) for x in v]
    return v


def _hint(name: str, candidates: Sequence[str]) -> str:
    """The close-match suffix every campaign spec error carries (same
    UX as ``platforms.get_platform``)."""
    close = difflib.get_close_matches(name, list(candidates), n=3,
                                      cutoff=0.5)
    if close:
        return f"did you mean: {', '.join(close)}?"
    return f"known: {', '.join(sorted(candidates))}"


@dataclasses.dataclass(frozen=True)
class PlatformSelector:
    """One platform source: exactly one of ``registry`` (a registered
    platform name) or ``top500`` (a list export path, raw CSV text, or
    ``"sample:<edition>"`` for a vendored sample edition)."""
    registry: str = ""
    top500: str = ""
    edition: str = ""            # fleet group label (top500 only)
    limit: int = 0               # 0 = every parseable row

    def __post_init__(self):
        if bool(self.registry) == bool(self.top500):
            raise ValueError(
                "PlatformSelector needs exactly one of registry=<name> "
                f"or top500=<source>, got registry={self.registry!r} "
                f"top500={self.top500!r}")
        if self.limit < 0:
            raise ValueError(f"selector limit must be >= 0, "
                             f"got {self.limit}")
        if self.registry and self.edition:
            raise ValueError("edition labels apply to top500 selectors "
                             f"only (registry={self.registry!r})")

    @property
    def kind(self) -> str:
        return "registry" if self.registry else "top500"

    def edition_label(self) -> str:
        """The fleet group label: explicit ``edition``, else derived
        from the source (sample edition name or file stem)."""
        if self.edition:
            return self.edition
        src = self.top500
        if src.startswith("sample:"):
            return src[len("sample:"):]
        stem = src.replace("\\", "/").rsplit("/", 1)[-1]
        return stem.rsplit(".", 1)[0] if "." in stem else stem

    def to_dict(self) -> Dict[str, Any]:
        d: Dict[str, Any] = {}
        if self.registry:
            d["registry"] = self.registry
        else:
            d["top500"] = self.top500
        if self.edition:
            d["edition"] = self.edition
        if self.limit:
            d["limit"] = self.limit
        return d

    @classmethod
    def from_dict(cls, d: Dict[str, Any]) -> "PlatformSelector":
        return cls(registry=d.get("registry", ""),
                   top500=d.get("top500", ""),
                   edition=d.get("edition", ""),
                   limit=int(d.get("limit", 0)))


@dataclasses.dataclass(frozen=True)
class Budget:
    """Hard caps the expansion refuses to exceed — a campaign that
    would fan out past its budget raises at expand time instead of
    melting the serving layer."""
    max_runs: int = 4096

    def __post_init__(self):
        if self.max_runs < 1:
            raise ValueError(f"budget max_runs must be >= 1, "
                             f"got {self.max_runs}")

    def to_dict(self) -> Dict[str, Any]:
        return {"max_runs": self.max_runs}

    @classmethod
    def from_dict(cls, d: Dict[str, Any]) -> "Budget":
        return cls(max_runs=int(d.get("max_runs", 4096)))


def _as_workload_spec(w) -> WorkloadSpec:
    if isinstance(w, WorkloadSpec):
        return w
    if isinstance(w, str):
        # a bare kind name means the kind's default scenario — resolve
        # it now so the journaled spec records the actual knob values
        # (an unknown kind passes through; validate() hints on it)
        from repro.workloads import get_workload
        try:
            return get_workload(w).spec
        except KeyError:
            return WorkloadSpec(kind=w)
    if isinstance(w, dict):
        return WorkloadSpec.from_dict(w)
    raise TypeError(f"campaign workload must be a kind name, dict, or "
                    f"WorkloadSpec, got {type(w).__name__}")


def _as_selector(p) -> PlatformSelector:
    if isinstance(p, PlatformSelector):
        return p
    if isinstance(p, str):
        return PlatformSelector(registry=p)
    if isinstance(p, dict):
        return PlatformSelector.from_dict(p)
    raise TypeError(f"campaign platform must be a registry name, dict, "
                    f"or PlatformSelector, got {type(p).__name__}")


def _as_fault(f) -> Optional[FaultSpec]:
    if f is None or isinstance(f, FaultSpec):
        return f
    if isinstance(f, dict):
        return FaultSpec.from_dict(f)
    if isinstance(f, str):
        return FaultSpec.from_json(f)
    raise TypeError(f"campaign fault scenario must be a FaultSpec, "
                    f"dict, JSON string, or None, got {type(f).__name__}")


@dataclasses.dataclass(frozen=True)
class CampaignSpec:
    """One declarative study: ``workloads x platforms x axes x faults x
    seeds``.  Frozen and hashable; ``to_json``/``from_json`` round-trip
    exactly (normalization happens in ``__post_init__``, so equal
    studies compare equal however they were spelled)."""
    name: str
    workloads: Tuple[WorkloadSpec, ...] = ()
    platforms: Tuple[PlatformSelector, ...] = ()
    axes: Tuple[Tuple[str, Tuple[Any, ...]], ...] = ()
    faults: Tuple[Optional[FaultSpec], ...] = (None,)
    seeds: Tuple[int, ...] = (0,)
    budget: Budget = Budget()

    def __post_init__(self):
        if not self.name:
            raise ValueError("campaign needs a non-empty name")
        if not self.platforms:
            raise ValueError(f"campaign {self.name!r} selects no "
                             "platforms")
        if any(s.kind == "registry" for s in self.platforms) \
                and not self.workloads:
            raise ValueError(
                f"campaign {self.name!r} has registry platform selectors "
                "but no workloads to run on them")
        axes = []
        seen = set()
        for k, vals in self.axes:
            k = str(k)
            if k in seen:
                raise ValueError(f"campaign {self.name!r}: duplicate "
                                 f"axis {k!r}")
            seen.add(k)
            vals = tuple(_freeze(v) for v in vals)
            if not vals:
                raise ValueError(f"campaign {self.name!r}: axis {k!r} "
                                 "has no values")
            axes.append((k, vals))
        object.__setattr__(self, "axes", tuple(sorted(axes)))
        object.__setattr__(self, "workloads", tuple(self.workloads))
        object.__setattr__(self, "platforms", tuple(self.platforms))
        object.__setattr__(self, "faults", tuple(self.faults) or (None,))
        object.__setattr__(self, "seeds",
                           tuple(int(s) for s in self.seeds) or (0,))

    # ---------------------------------------------------- construction
    @classmethod
    def make(cls, name: str, *, workloads: Sequence = (),
             platforms: Sequence = (), axes: Optional[Dict] = None,
             faults: Sequence = (None,), seeds: Sequence[int] = (0,),
             max_runs: int = 4096) -> "CampaignSpec":
        """The permissive constructor: workloads as kind names / dicts /
        specs, platforms as registry names / dicts / selectors, axes as
        a plain ``{key: [values]}`` dict."""
        return cls(
            name=name,
            workloads=tuple(_as_workload_spec(w) for w in workloads),
            platforms=tuple(_as_selector(p) for p in platforms),
            axes=tuple((k, tuple(v)) for k, v in (axes or {}).items()),
            faults=tuple(_as_fault(f) for f in faults),
            seeds=tuple(seeds),
            budget=Budget(max_runs=max_runs))

    # ------------------------------------------------------ validation
    def axis_candidates(self) -> Dict[str, Tuple[str, ...]]:
        """Per workload kind, the knob names an axis may legally set:
        the kind's default-spec params, this spec's own params, and the
        per-kind extras (platform-resolved config keys)."""
        from repro.workloads import get_workload, list_workloads
        out: Dict[str, Tuple[str, ...]] = {}
        known = set(list_workloads())
        for w in self.workloads:
            if w.kind not in known:
                continue                 # reported by validate()
            keys = set(dict(w.params))
            keys.update(
                dict(type(get_workload(w.kind)).default_spec().params))
            keys.update(EXTRA_AXIS_KEYS.get(w.kind, ()))
            out[w.kind] = tuple(sorted(keys))
        return out

    def validate(self) -> None:
        """Fail fast — unknown workload kinds, registry platform names,
        and axis keys all raise ``ValueError`` with a difflib
        close-match hint (the ``get_platform`` error UX)."""
        from repro.platforms import list_platforms
        from repro.workloads import list_workloads
        kinds = list_workloads()
        for w in self.workloads:
            if w.kind not in kinds:
                raise ValueError(
                    f"campaign {self.name!r}: unknown workload kind "
                    f"{w.kind!r}; {_hint(w.kind, kinds)}")
        names = list_platforms()
        for sel in self.platforms:
            if sel.kind == "registry" and sel.registry not in names:
                raise ValueError(
                    f"campaign {self.name!r}: unknown platform "
                    f"{sel.registry!r}; {_hint(sel.registry, names)}")
        candidates = self.axis_candidates()
        legal = sorted({k for keys in candidates.values() for k in keys})
        for key, _ in self.axes:
            if not any(key in keys for keys in candidates.values()):
                raise ValueError(
                    f"campaign {self.name!r}: axis key {key!r} is not a "
                    f"knob of any campaign workload "
                    f"({', '.join(sorted(candidates)) or 'none'}); "
                    f"{_hint(key, legal)}")

    # -------------------------------------------------- serialization
    def to_dict(self) -> Dict[str, Any]:
        return {
            "campaign": CAMPAIGN_VERSION,
            "name": self.name,
            "workloads": [w.to_dict() for w in self.workloads],
            "platforms": [s.to_dict() for s in self.platforms],
            "axes": [[k, [_thaw(v) for v in vals]]
                     for k, vals in self.axes],
            "faults": [None if f is None else f.to_dict()
                       for f in self.faults],
            "seeds": list(self.seeds),
            "budget": self.budget.to_dict(),
        }

    def to_json(self, **kw) -> str:
        return json.dumps(self.to_dict(), sort_keys=True, **kw)

    @classmethod
    def from_dict(cls, d: Dict[str, Any]) -> "CampaignSpec":
        ver = d.get("campaign", CAMPAIGN_VERSION)
        if ver != CAMPAIGN_VERSION:
            raise ValueError(f"unsupported campaign spec version {ver} "
                             f"(this build speaks {CAMPAIGN_VERSION})")
        return cls(
            name=d["name"],
            workloads=tuple(WorkloadSpec.from_dict(w)
                            for w in d.get("workloads", [])),
            platforms=tuple(PlatformSelector.from_dict(s)
                            for s in d.get("platforms", [])),
            axes=tuple((k, tuple(vals))
                       for k, vals in d.get("axes", [])),
            faults=tuple(None if f is None else FaultSpec.from_dict(f)
                         for f in d.get("faults", [None])),
            seeds=tuple(d.get("seeds", [0])),
            budget=Budget.from_dict(d.get("budget", {})))

    @classmethod
    def from_json(cls, s: str) -> "CampaignSpec":
        return cls.from_dict(json.loads(s))

    def load(path) -> "CampaignSpec":
        with open(path) as fh:
            return CampaignSpec.from_json(fh.read())
    load = staticmethod(load)
