"""repro.campaign — declarative fleet studies over the prediction stack.

One frozen ``CampaignSpec`` names a study (workloads x platforms x
sweep axes x fault scenarios x seeds); ``expand`` turns it into a
deterministic run matrix; ``run_campaign`` serves the whole matrix
through the batched engines (one compiled sweep per workload family
for grid cells, one forced-bucket compile per TOP500 edition for
fleets) and journals one NDJSON manifest line per run; the report
module merges journals with the metrics monoid and renders ranked +
edition-drift reports.  ``python -m repro.campaign`` is the CLI
(``run`` / ``merge`` / ``report``).  DESIGN.md §19.

Quickstart::

    from repro.campaign import CampaignSpec, run_campaign

    spec = CampaignSpec.make(
        "what-if", workloads=["hpl", "transformer"],
        platforms=["tpu-v5e-pod", "syn-torus-fugaku-4k"],
        seeds=[0, 1])
    result = run_campaign(spec, journal="runs.ndjson")
"""
from .spec import (CAMPAIGN_VERSION, Budget, CampaignSpec,
                   PlatformSelector)
from .matrix import RunCase, RunMatrix, expand, machine_key
from .exec import CampaignResult, dispatch_counts, run_campaign
from .report import (campaign_report, edition_drift, load_journal,
                     merge_journals, render_markdown, render_text,
                     render_report, write_csv, write_journal)
from .cli import edition_study_spec, main

__all__ = [
    "CAMPAIGN_VERSION", "Budget", "CampaignSpec", "PlatformSelector",
    "RunCase", "RunMatrix", "expand", "machine_key",
    "CampaignResult", "dispatch_counts", "run_campaign",
    "campaign_report", "edition_drift", "load_journal",
    "merge_journals", "render_markdown", "render_text", "render_report",
    "write_csv", "write_journal",
    "edition_study_spec", "main",
]
