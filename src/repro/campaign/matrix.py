"""Deterministic expansion: ``CampaignSpec`` -> run matrix.

``expand`` is pure planning — no simulation, no compiles.  It resolves
every platform selector (registry lookups, TOP500 parses), crosses the
grid axes, and emits one frozen ``RunCase`` per unit of work in a fixed
order, so the same spec always yields the same matrix (and, downstream,
byte-equal run manifests modulo timing fields).

Two case kinds come out, matching the two batched execution paths:

  * ``grid``  — one (workload, registry platform, axis overrides,
    fault, seed) cell; the executor serves all of these through one
    ``PredictionService.predict_batch`` (one sweep per model family
    per wave).
  * ``fleet`` — one TOP500 machine of one list edition; the executor
    runs each edition through ``top500.predict_fleet`` (one forced-
    bucket compile per edition, per-fabric calibration included).

Incompatibilities (a workload whose ``validate`` rejects a platform, an
axis key the workload doesn't know) are *skipped with a reason* in
lenient mode — a fleet campaign should not die because one machine
can't host one workload — and raise under ``strict=True``.  Fault
scenarios are re-seeded per seed-axis value (``dataclasses.replace``),
which is how Cornebize & Legrand's "variability matters" point becomes
a reportable axis instead of noise.
"""
from __future__ import annotations

import dataclasses
import itertools
import re
from typing import Any, Dict, List, Optional, Tuple

from repro.faults.spec import FaultSpec
from repro.workloads.base import WorkloadSpec

from .spec import CampaignSpec, PlatformSelector

#: inferred TOP500 platform names carry a list-position prefix
#: ("r017-selene"); drift matching across editions keys on the slug.
_RANK_PREFIX = re.compile(r"^r\d{1,4}-")


def machine_key(platform_name: str) -> str:
    """The edition-stable identity of an inferred TOP500 platform (its
    name minus the ``rNNN-`` list-position prefix)."""
    return _RANK_PREFIX.sub("", platform_name)


@dataclasses.dataclass(frozen=True)
class RunCase:
    """One planned run.  ``key`` is the human-stable cell id (unique
    within the campaign and independent of matrix position); ``index``
    is the deterministic position used for run ids."""
    index: int
    kind: str                          # "grid" | "fleet"
    key: str
    workload: WorkloadSpec
    platform: str                      # registry name / inferred name
    overrides: Tuple[Tuple[str, Any], ...] = ()
    fault: Optional[FaultSpec] = None
    seed: int = 0
    edition: str = ""                  # fleet cases only

    @property
    def run_id(self) -> str:
        return f"{self.index:05d}"

    def to_meta(self) -> Dict[str, Any]:
        """The JSON-safe identity block this case contributes to its
        run-manifest line (fully deterministic)."""
        d: Dict[str, Any] = {
            "run": self.run_id, "cell": self.key, "kind": self.kind,
            "workload": self.workload.to_dict(),
            "platform": self.platform, "seed": self.seed,
            "overrides": {k: v for k, v in self.overrides},
            "fault": None if self.fault is None else self.fault.to_dict(),
        }
        if self.edition:
            d["edition"] = self.edition
            d["machine"] = machine_key(self.platform)
        return d


@dataclasses.dataclass
class RunMatrix:
    """The expanded campaign: grid cases + per-edition fleets, plus the
    resolution products the executor needs (Platform objects) and the
    audit trail of skipped cells."""
    spec: CampaignSpec
    cases: List[RunCase]
    platforms: Dict[str, Any]               # name -> Platform (grid)
    fleets: Dict[str, List[Any]]            # edition -> [Platform, ...]
    skipped: List[Tuple[str, str]]          # (cell key, reason)

    @property
    def grid_cases(self) -> List[RunCase]:
        return [c for c in self.cases if c.kind == "grid"]

    @property
    def fleet_cases(self) -> List[RunCase]:
        return [c for c in self.cases if c.kind == "fleet"]

    def editions(self) -> List[str]:
        seen: List[str] = []
        for c in self.fleet_cases:
            if c.edition not in seen:
                seen.append(c.edition)
        return seen


def _resolve_top500(sel: PlatformSelector) -> List[Any]:
    """A top500 selector -> inferred Platform list (list order)."""
    from repro.top500 import infer_platforms, parse_top500, \
        sample_list_path
    src = sel.top500
    if src.startswith("sample:"):
        src = sample_list_path(src[len("sample:"):])
    rows = parse_top500(src).rows
    if sel.limit:
        rows = rows[:sel.limit]
    if not rows:
        raise ValueError(f"campaign selector top500={sel.top500!r}: "
                         "no parseable rows")
    return infer_platforms(rows)


def _wl_axis_cells(spec: CampaignSpec,
                   w: WorkloadSpec) -> List[Tuple[Tuple[str, Any], ...]]:
    """The axis cross-product as applied to workload ``w``: only the
    axes ``w`` knows participate (others contribute no variation for
    this workload)."""
    keys = set(spec.axis_candidates().get(w.kind, ()))
    mine = [(k, vals) for k, vals in spec.axes if k in keys]
    if not mine:
        return [()]
    return [tuple(zip((k for k, _ in mine), combo))
            for combo in itertools.product(*(vals for _, vals in mine))]


def expand(spec: CampaignSpec, *, strict: bool = False) -> RunMatrix:
    """Expand a validated spec into its deterministic run matrix.

    Grid order: workload-major, then platform, then axis cell, then
    fault scenario, then seed — the spec's own (normalized) orders
    throughout.  Fleet order: selector order, then list order.  The
    budget is a hard cap: a matrix that would exceed
    ``spec.budget.max_runs`` raises before any case is built.
    """
    from repro.platforms import get_platform
    from repro.workloads import workload_from_spec
    spec.validate()

    cases: List[RunCase] = []
    skipped: List[Tuple[str, str]] = []
    platforms: Dict[str, Any] = {}
    fleets: Dict[str, List[Any]] = {}

    reg_sel = [s for s in spec.platforms if s.kind == "registry"]
    top_sel = [s for s in spec.platforms if s.kind == "top500"]
    for sel in reg_sel:
        platforms[sel.registry] = get_platform(sel.registry)
    for sel in top_sel:
        label = sel.edition_label()
        if label in fleets:
            raise ValueError(
                f"campaign {spec.name!r}: duplicate fleet edition label "
                f"{label!r}; set selector edition= to disambiguate")
        fleets[label] = _resolve_top500(sel)

    # ------------------------------------------------------ budget gate
    n_grid = 0
    for w in spec.workloads:
        n_grid += (len(reg_sel) * len(_wl_axis_cells(spec, w))
                   * len(spec.faults) * len(spec.seeds))
    n_fleet = sum(len(ps) for ps in fleets.values())
    if n_grid + n_fleet > spec.budget.max_runs:
        raise ValueError(
            f"campaign {spec.name!r}: matrix would be "
            f"{n_grid + n_fleet} runs ({n_grid} grid + {n_fleet} fleet), "
            f"over budget max_runs={spec.budget.max_runs}; shrink an "
            "axis or raise the budget")

    # ------------------------------------------------------- grid cases
    index = 0
    for wi, w in enumerate(spec.workloads):
        for sel in reg_sel:
            plat = platforms[sel.registry]
            for ci, cell in enumerate(_wl_axis_cells(spec, w)):
                cell_spec = w.replace(**dict(cell)) if cell else w
                try:
                    workload_from_spec(cell_spec).validate(plat)
                except (ValueError, KeyError) as exc:
                    key = f"{w.kind}[{wi}]@{sel.registry}#c{ci}"
                    if strict:
                        raise ValueError(f"campaign {spec.name!r}: cell "
                                         f"{key}: {exc}") from exc
                    skipped.append((key, str(exc)))
                    continue
                for fi, fault in enumerate(spec.faults):
                    for seed in spec.seeds:
                        if fault is not None:
                            fault_s = dataclasses.replace(fault, seed=seed)
                        else:
                            fault_s = None
                        cases.append(RunCase(
                            index=index, kind="grid",
                            key=(f"{w.kind}[{wi}]@{sel.registry}"
                                 f"#c{ci}f{fi}s{seed}"),
                            workload=cell_spec, platform=sel.registry,
                            overrides=cell, fault=fault_s, seed=seed))
                        index += 1

    # ------------------------------------------------------ fleet cases
    hpl = WorkloadSpec(kind="hpl")
    for edition, plats in fleets.items():
        for plat in plats:
            cases.append(RunCase(
                index=index, kind="fleet",
                key=f"fleet:{edition}/{machine_key(plat.name)}",
                workload=hpl, platform=plat.name, edition=edition))
            index += 1

    return RunMatrix(spec=spec, cases=cases, platforms=platforms,
                     fleets=fleets, skipped=skipped)
