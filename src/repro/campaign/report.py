"""Campaign merge + report tooling (the ``ben*`` half of the layer).

Journals are NDJSON run manifests (``campaign_run`` lines + one
``campaign_summary`` per executed campaign).  This module folds any
number of them — including partial journals from killed runs, read
leniently — into one merged artifact and renders the ranked report:

  * :func:`merge_journals` — concatenate run records and fold every
    summary's metrics snapshot with the PR 8 monoid merge
    (``obs.merge_snapshots``: counters sum, gauge peaks max,
    histograms add), emitting one trailing ``campaign_merged`` record.
  * :func:`campaign_report` — the analysis dict: ranked grid results,
    per-edition fleet summaries, and the longitudinal drift section —
    per-machine prediction drift and per-fabric calibration-factor
    drift between the earliest and latest edition present (machines
    matched by their edition-stable slug, list-position prefix
    stripped).
  * :func:`render_markdown` / :func:`render_text` / :func:`write_csv`
    — the human and spreadsheet surfaces over that dict.
"""
from __future__ import annotations

import csv
import json
from typing import Any, Dict, List, Optional, Sequence, Tuple

from repro.obs import merge_snapshots
from repro.obs.export import manifest_record, read_manifest

from .exec import dispatch_counts

#: campaign journal record kinds this module folds
RUN_KIND, SUMMARY_KIND, MERGED_KIND = ("campaign_run",
                                       "campaign_summary",
                                       "campaign_merged")


def load_journal(path, *, strict: bool = False) -> List[Dict[str, Any]]:
    """Read one NDJSON journal; lenient by default (a torn trailing
    line from a killed run is skipped, not fatal)."""
    return read_manifest(path, strict=strict)


def merge_journals(paths: Sequence, *,
                   strict: bool = False) -> List[Dict[str, Any]]:
    """Fold journals into one record list: every ``campaign_run`` line
    (journal order, journals in argument order), every per-campaign
    summary, and one trailing ``campaign_merged`` record whose metrics
    snapshot is the monoid fold of all summaries' snapshots."""
    runs: List[Dict[str, Any]] = []
    summaries: List[Dict[str, Any]] = []
    for path in paths:
        for rec in load_journal(path, strict=strict):
            if rec.get("kind") == RUN_KIND:
                runs.append(rec)
            elif rec.get("kind") in (SUMMARY_KIND, MERGED_KIND):
                summaries.append(rec)
    snaps = [r["metrics"] for r in summaries if "metrics" in r]
    merged_snap = merge_snapshots(*snaps) if snaps else None
    campaigns: List[str] = []
    editions: Dict[str, Any] = {}
    wall_s = 0.0
    for s in summaries:
        meta = s.get("meta", {})
        name = meta.get("campaign", "")
        if name and name not in campaigns:
            campaigns.append(name)
        editions.update(meta.get("editions", {}))
        wall_s += meta.get("wall_s", 0.0)
    meta = {"campaigns": campaigns, "n_runs": len(runs),
            "n_summaries": len(summaries), "editions": editions,
            "wall_s": wall_s}
    if merged_snap is not None:
        meta["dispatches"] = dispatch_counts(merged_snap)
    merged = manifest_record(MERGED_KIND, meta=meta,
                             metrics=merged_snap)
    return runs + summaries + [merged]


def write_journal(records: Sequence[Dict[str, Any]], path) -> None:
    with open(path, "w") as fh:
        for rec in records:
            fh.write(json.dumps(rec, sort_keys=True) + "\n")


# ------------------------------------------------------------- analysis
def _run_rows(records) -> List[Dict[str, Any]]:
    return [r["meta"] for r in records if r.get("kind") == RUN_KIND]


def _tflops(result: Optional[dict]) -> Optional[float]:
    if not result:
        return None
    for key in ("calibrated_tflops", "predicted_tflops", "tflops"):
        v = result.get(key)
        if v:
            return float(v)
    return None


def campaign_report(records: Sequence[Dict[str, Any]]) -> Dict[str, Any]:
    """The analysis dict a merged (or single) journal renders to."""
    rows = _run_rows(records)
    grid = [m for m in rows if m.get("kind") == "grid"]
    fleet = [m for m in rows if m.get("kind") == "fleet"]
    summaries = [r["meta"] for r in records
                 if r.get("kind") in (SUMMARY_KIND, MERGED_KIND)]

    ranked_grid = sorted(
        (m for m in grid if _tflops(m.get("result")) is not None),
        key=lambda m: -_tflops(m["result"]))
    errors = [m for m in grid
              if (m.get("result") or {}).get("status") == "error"]

    editions: Dict[str, Dict[str, Any]] = {}
    for s in summaries:
        editions.update(s.get("editions", {}))
    by_edition: Dict[str, List[dict]] = {}
    for m in fleet:
        by_edition.setdefault(m.get("edition", ""), []).append(m)

    report: Dict[str, Any] = {
        "campaigns": sorted({m.get("campaign", "") for m in rows}),
        "n_runs": len(rows), "n_grid": len(grid), "n_fleet": len(fleet),
        "n_errors": len(errors),
        "ranked_grid": ranked_grid,
        "editions": editions,
        "fleet_by_edition": {
            ed: sorted(ms, key=lambda m: -(_tflops(m["result"]) or 0.0))
            for ed, ms in by_edition.items()},
    }
    if len(by_edition) >= 2:
        report["drift"] = edition_drift(by_edition, editions)
    return report


def edition_drift(by_edition: Dict[str, List[dict]],
                  editions_meta: Dict[str, Any]) -> Dict[str, Any]:
    """The longitudinal section: earliest vs latest edition (sorted
    label order), machines matched by edition-stable slug."""
    first, last = min(by_edition), max(by_edition)
    a = {m["machine"]: m for m in by_edition[first]}
    b = {m["machine"]: m for m in by_edition[last]}
    machines: List[Dict[str, Any]] = []
    for key in sorted(set(a) & set(b)):
        ra, rb = a[key]["result"], b[key]["result"]
        pa, pb = _tflops(ra), _tflops(rb)
        pub_a = ra.get("published_tflops") or 0.0
        pub_b = rb.get("published_tflops") or 0.0
        machines.append({
            "machine": key,
            "family": rb.get("family", ra.get("family", "")),
            f"predicted_{first}": pa, f"predicted_{last}": pb,
            f"published_{first}": pub_a, f"published_{last}": pub_b,
            "predicted_drift": ((pb - pa) / pa
                                if pa and pb is not None else None),
            "published_drift": ((pub_b - pub_a) / pub_a
                                if pub_a and pub_b else None),
        })
    machines.sort(key=lambda d: -abs(d["predicted_drift"] or 0.0))

    fa = (editions_meta.get(first) or {}).get("calibration_factors", {})
    fb = (editions_meta.get(last) or {}).get("calibration_factors", {})
    factors = [{
        "family": fam,
        f"factor_{first}": fa.get(fam), f"factor_{last}": fb.get(fam),
        "drift": (fb[fam] - fa[fam]
                  if fam in fa and fam in fb else None),
    } for fam in sorted(set(fa) | set(fb))]
    return {"from": first, "to": last,
            "common_machines": len(machines),
            "appeared": sorted(set(b) - set(a)),
            "dropped": sorted(set(a) - set(b)),
            "machines": machines, "calibration_factors": factors}


# ------------------------------------------------------------ rendering
def _fmt(v, nd=3) -> str:
    if v is None:
        return "-"
    if isinstance(v, float):
        return f"{v:.{nd}f}"
    return str(v)


def _pct(v) -> str:
    return "-" if v is None else f"{v * 100:+.1f}%"


def _fault_label(fault: Optional[dict]) -> str:
    if not fault:
        return "-"
    return fault.get("name") or "+".join(
        f.get("kind", "?") for f in fault.get("faults", ())) or "-"


def _table(headers: List[str], rows: List[List[str]],
           md: bool) -> List[str]:
    if md:
        out = ["| " + " | ".join(headers) + " |",
               "|" + "|".join("---" for _ in headers) + "|"]
        out += ["| " + " | ".join(r) + " |" for r in rows]
        return out
    widths = [max(len(h), *(len(r[i]) for r in rows)) if rows else len(h)
              for i, h in enumerate(headers)]
    line = "  ".join(h.ljust(w) for h, w in zip(headers, widths))
    out = [line, "  ".join("-" * w for w in widths)]
    out += ["  ".join(c.ljust(w) for c, w in zip(r, widths))
            for r in rows]
    return out


def render_report(report: Dict[str, Any], *, markdown: bool = True,
                  top: int = 20) -> str:
    """The ranked campaign report (Markdown by default, aligned text
    with ``markdown=False``)."""
    md = markdown
    h = (lambda s: f"## {s}") if md else (lambda s: s.upper())
    lines: List[str] = []
    names = ", ".join(n for n in report["campaigns"] if n) or "campaign"
    lines.append(f"# Campaign report: {names}" if md
                 else f"CAMPAIGN REPORT: {names}")
    lines.append("")
    lines.append(f"{report['n_runs']} runs "
                 f"({report['n_grid']} grid, {report['n_fleet']} fleet), "
                 f"{report['n_errors']} errors.")

    if report["ranked_grid"]:
        lines += ["", h(f"Grid runs (top {top} by TFlop/s)"), ""]
        rows = [[m["run"], m["workload"]["kind"], m["platform"],
                 str(m["seed"]), _fault_label(m.get("fault")),
                 _fmt(_tflops(m["result"]), 1)]
                for m in report["ranked_grid"][:top]]
        lines += _table(["run", "workload", "platform", "seed", "fault",
                         "tflops"], rows, md)

    for ed, ms in sorted(report["fleet_by_edition"].items()):
        meta = report["editions"].get(ed, {})
        lines += ["", h(f"Fleet edition {ed}"), ""]
        err = meta.get("median_abs_err")
        held = meta.get("heldout_median_abs_err")
        lines.append(f"{len(ms)} machines, {meta.get('compiles', '?')} "
                     f"compile(s); median |err| {_fmt(err)} "
                     f"(held-out {_fmt(held)}).")
        lines.append("")
        rows = [[str(i + 1), m["machine"], m["result"].get("family", ""),
                 _fmt(m["result"].get("published_tflops"), 1),
                 _fmt(_tflops(m["result"]), 1),
                 _pct(m["result"].get("rel_err"))]
                for i, m in enumerate(ms[:top])]
        lines += _table(["#", "machine", "family", "published",
                         "predicted", "rel_err"], rows, md)

    drift = report.get("drift")
    if drift:
        lines += ["", h(f"Edition drift: {drift['from']} -> "
                        f"{drift['to']}"), ""]
        lines.append(f"{drift['common_machines']} machines in both "
                     f"editions; {len(drift['appeared'])} appeared, "
                     f"{len(drift['dropped'])} dropped.")
        lines.append("")
        rows = [[d["machine"], d["family"],
                 _fmt(d[f"predicted_{drift['from']}"], 1),
                 _fmt(d[f"predicted_{drift['to']}"], 1),
                 _pct(d["predicted_drift"]), _pct(d["published_drift"])]
                for d in drift["machines"][:top]]
        lines += _table(["machine", "family",
                         f"pred {drift['from']}", f"pred {drift['to']}",
                         "pred drift", "pub drift"], rows, md)
        lines += ["", h("Calibration-factor drift"), ""]
        rows = [[f["family"], _fmt(f[f"factor_{drift['from']}"]),
                 _fmt(f[f"factor_{drift['to']}"]), _fmt(f["drift"])]
                for f in drift["calibration_factors"]]
        lines += _table(["fabric family", f"factor {drift['from']}",
                         f"factor {drift['to']}", "drift"], rows, md)
    return "\n".join(lines) + "\n"


def render_markdown(report: Dict[str, Any], **kw) -> str:
    return render_report(report, markdown=True, **kw)


def render_text(report: Dict[str, Any], **kw) -> str:
    return render_report(report, markdown=False, **kw)


#: CSV columns, one row per campaign_run record
CSV_FIELDS = ("campaign", "run", "cell", "kind", "workload", "platform",
              "edition", "machine", "seed", "fault", "status", "tflops",
              "published_tflops", "rel_err", "family")


def write_csv(records: Sequence[Dict[str, Any]], path) -> int:
    """One CSV row per run record; returns the row count."""
    rows = _run_rows(records)
    with open(path, "w", newline="") as fh:
        w = csv.DictWriter(fh, fieldnames=CSV_FIELDS)
        w.writeheader()
        for m in rows:
            res = m.get("result") or {}
            w.writerow({
                "campaign": m.get("campaign", ""),
                "run": m.get("run", ""), "cell": m.get("cell", ""),
                "kind": m.get("kind", ""),
                "workload": m["workload"]["kind"],
                "platform": m.get("platform", ""),
                "edition": m.get("edition", ""),
                "machine": m.get("machine", ""),
                "seed": m.get("seed", ""),
                "fault": (_fault_label(m["fault"])
                          if m.get("fault") else ""),
                "status": res.get("status", "ok"),
                "tflops": _tflops(res),
                "published_tflops": res.get("published_tflops", ""),
                "rel_err": res.get("rel_err", ""),
                "family": res.get("family", ""),
            })
    return len(rows)
