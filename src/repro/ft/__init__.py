from .straggler import (StepTimeMonitor, simulate_fault_impact,
                        simulate_straggler_impact)
from .elastic import (ElasticPlan, elastic_restart_plan,
                      restart_plan_for_faults)

__all__ = ["StepTimeMonitor", "simulate_straggler_impact",
           "simulate_fault_impact", "ElasticPlan",
           "elastic_restart_plan", "restart_plan_for_faults"]
