from .straggler import StepTimeMonitor, simulate_straggler_impact
from .elastic import elastic_restart_plan

__all__ = ["StepTimeMonitor", "simulate_straggler_impact",
           "elastic_restart_plan"]
