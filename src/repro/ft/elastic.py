"""Elastic scaling: restart a run on a different device count.

The pieces that make this a plan rather than a prayer:
  * checkpoints store *full logical arrays* (manifest carries shapes), so
    restore re-shards onto whatever mesh exists (checkpoint.restore with
    new shardings);
  * the data pipeline is a pure function of (step, dp_rank, dp_size)
    (data/pipeline.py), so the token stream continues exactly;
  * sharding rules are derived from (cfg, mesh) (sharding/specs.py), not
    hard-coded — a (8,16) degraded mesh yields a valid rule set.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple


@dataclasses.dataclass
class ElasticPlan:
    old_mesh: Tuple[int, ...]
    new_mesh: Tuple[int, ...]
    resume_step: int
    dp_size_old: int
    dp_size_new: int
    per_device_batch_new: int
    notes: str = ""


def elastic_restart_plan(*, global_batch: int, resume_step: int,
                         old_mesh: Tuple[int, ...],
                         new_mesh: Tuple[int, ...]) -> ElasticPlan:
    """Validate that a resize keeps the global batch and data order
    intact, and compute the new per-device partitioning."""
    dp_old, dp_new = old_mesh[0], new_mesh[0]
    if global_batch % dp_new != 0:
        raise ValueError(
            f"global batch {global_batch} not divisible by new dp={dp_new};"
            " adjust microbatching before resuming")
    return ElasticPlan(
        old_mesh=old_mesh, new_mesh=new_mesh, resume_step=resume_step,
        dp_size_old=dp_old, dp_size_new=dp_new,
        per_device_batch_new=global_batch // dp_new,
        notes="same global batch; data pipeline replays from resume_step "
              "with dp_size_new shards; params re-sharded at restore")
