"""Elastic scaling: restart a run on a different device count.

The pieces that make this a plan rather than a prayer:
  * checkpoints store *full logical arrays* (manifest carries shapes), so
    restore re-shards onto whatever mesh exists (checkpoint.restore with
    new shardings);
  * the data pipeline is a pure function of (step, dp_rank, dp_size)
    (data/pipeline.py), so the token stream continues exactly;
  * sharding rules are derived from (cfg, mesh) (sharding/specs.py), not
    hard-coded — a (8,16) degraded mesh yields a valid rule set.

``restart_plan_for_faults`` closes the loop with the fault layer: a
fail-stop ``FaultSpec`` (the same object the DES ran, or the operator's
description of what actually died) maps dead chips to their
data-parallel rows, and the surviving mesh is re-planned through
``elastic_restart_plan``.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple


@dataclasses.dataclass
class ElasticPlan:
    old_mesh: Tuple[int, ...]
    new_mesh: Tuple[int, ...]
    resume_step: int
    dp_size_old: int
    dp_size_new: int
    per_device_batch_new: int
    notes: str = ""


def elastic_restart_plan(*, global_batch: int, resume_step: int,
                         old_mesh: Tuple[int, ...],
                         new_mesh: Tuple[int, ...]) -> ElasticPlan:
    """Validate that a resize keeps the global batch and data order
    intact, and compute the new per-device partitioning."""
    dp_old, dp_new = old_mesh[0], new_mesh[0]
    if global_batch % dp_new != 0:
        raise ValueError(
            f"global batch {global_batch} not divisible by new dp={dp_new};"
            " adjust microbatching before resuming")
    return ElasticPlan(
        old_mesh=old_mesh, new_mesh=new_mesh, resume_step=resume_step,
        dp_size_old=dp_old, dp_size_new=dp_new,
        per_device_batch_new=global_batch // dp_new,
        notes="same global batch; data pipeline replays from resume_step "
              "with dp_size_new shards; params re-sharded at restore")


def restart_plan_for_faults(faults, *, global_batch: int, resume_step: int,
                            old_mesh: Tuple[int, ...],
                            ranks_per_node: int = 1) -> ElasticPlan:
    """Plan the elastic restart implied by a fail-stop fault scenario.

    Dead chips are read from the scenario's ``fail_stop`` faults
    (rank-scoped directly; node-scoped via ``ranks_per_node``), mapped
    to their data-parallel rows on ``old_mesh = (rows, cols)`` with the
    mesh's row-major rank layout (``rank = row*cols + col``), and every
    row containing a casualty is evicted — tensor-parallel groups span a
    row, so one dead chip takes its whole row's replica down.  The
    surviving mesh is validated and partitioned by
    ``elastic_restart_plan``.
    """
    from repro.faults import as_fault_spec
    spec = as_fault_spec(faults)
    rows, cols = int(old_mesh[0]), int(old_mesh[1])
    dead_ranks = set()
    for f in (spec.faults if spec is not None else ()):
        if f.kind != "fail_stop":
            continue
        if f.rank >= 0:
            dead_ranks.add(f.rank)
        elif f.node >= 0:
            dead_ranks.update(range(f.node * ranks_per_node,
                                    (f.node + 1) * ranks_per_node))
    if not dead_ranks:
        raise ValueError("restart_plan_for_faults: scenario has no "
                         "fail_stop faults — nothing to restart around")
    dead_rows = sorted({r // cols for r in dead_ranks if r // cols < rows})
    if len(dead_rows) >= rows:
        raise ValueError(
            f"restart_plan_for_faults: all {rows} data-parallel rows "
            f"contain dead chips ({len(dead_ranks)} casualties) — no "
            "surviving replica to restart on")
    new_mesh = (rows - len(dead_rows), cols) + tuple(old_mesh[2:])
    plan = elastic_restart_plan(global_batch=global_batch,
                                resume_step=resume_step,
                                old_mesh=tuple(old_mesh),
                                new_mesh=new_mesh)
    plan.notes = (f"evicted dp rows {dead_rows} "
                  f"({len(dead_ranks)} dead chips); " + plan.notes)
    return plan
