"""Straggler detection + mitigation hooks.

Detection: per-step wall times vs a rolling median; a step (or, on a real
multi-host deployment, a host's all-reduce arrival time) slower than
``threshold x median`` flags a straggler.  Mitigation hooks are pluggable:
checkpoint-and-evict, re-shard data away from the slow host, or lower the
synchronization frequency (gradient accumulation).

The simulator closes the loop: ``simulate_straggler_impact`` replays the
step on the DES with a slow chip injected — now expressed as a
``repro.faults.FaultSpec`` scenario, so detection feeds the same
declarative fault layer every backend understands — and reports the
predicted step-time blowup; the operator can decide whether eviction is
worth a restart *before* touching the cluster (paper §V what-if
methodology applied to fault tolerance).  ``simulate_fault_impact`` is
the workload-generic edition: any registered workload, any platform,
any fault scenario, on either backend.
"""
from __future__ import annotations

import collections
import statistics
from typing import Callable, Dict, List, Optional


class StepTimeMonitor:
    def __init__(self, window: int = 50, threshold: float = 1.5,
                 warmup: int = 5):
        self.window = window
        self.threshold = threshold
        self.warmup = warmup
        self.times = collections.deque(maxlen=window)
        self.flags: List[int] = []
        self._step = 0
        self.on_straggler: Optional[Callable[[int, float, float], None]] = None

    def record(self, step_time: float) -> bool:
        """Returns True if this step is flagged as straggling."""
        self._step += 1
        flagged = False
        if len(self.times) >= self.warmup:
            med = statistics.median(self.times)
            if step_time > self.threshold * med:
                flagged = True
                self.flags.append(self._step)
                if self.on_straggler:
                    self.on_straggler(self._step, step_time, med)
        self.times.append(step_time)
        return flagged

    @property
    def median(self) -> float:
        return statistics.median(self.times) if self.times else 0.0


def simulate_straggler_impact(arch: str, shape: str, mesh: str = "16x16",
                              slowdown: float = 3.0, chip: int = 0) -> Dict:
    """Predicted step-time impact of one slow chip (DES what-if); a thin
    consumer of the declarative fault layer."""
    from repro.core.predict import predict_cell_des
    from repro.faults import FaultSpec
    base = predict_cell_des(arch, shape, mesh)
    slow = predict_cell_des(
        arch, shape, mesh,
        faults=FaultSpec.straggler(rank=chip, slowdown=slowdown))
    return {"baseline_s": base["step_s"], "straggler_s": slow["step_s"],
            "blowup": slow["step_s"] / max(base["step_s"], 1e-12),
            "verdict": ("evict" if slow["step_s"] > 1.3 * base["step_s"]
                        else "tolerate")}


def simulate_fault_impact(workload, platform, faults, *,
                          des: bool = False,
                          evict_threshold: float = 1.3) -> Dict:
    """Predicted impact of ANY fault scenario on any registered workload.

    ``workload`` is a kind name or ``Workload`` instance, ``platform`` a
    registry name or spec, ``faults`` anything ``as_fault_spec`` accepts.
    ``des=False`` (default) compares fastsim predictions — one batched
    dispatch, fine for straggler/bandwidth scenarios; ``des=True`` runs
    both scenarios on the DES, which additionally covers fail-stop (the
    faulted run reports ``failed=True`` and the verdict is ``restart``).
    """
    from repro.workloads import Workload, get_workload
    wl = workload if isinstance(workload, Workload) else get_workload(workload)
    if isinstance(platform, str):
        from repro.platforms import get_platform
        platform = get_platform(platform)
    if des:
        base = wl.predict_des(platform)
        faulted = wl.predict_des(platform, faults=faults)
    else:
        base = wl.predict(platform)
        faulted = wl.predict(platform, faults=faults)
    out = {"baseline_s": base["time_s"], "faulted_s": faulted["time_s"],
           "backend": "des" if des else "fastsim"}
    if faulted.get("failed"):
        out["failed"] = True
        out["n_finished"] = faulted.get("n_finished")
        out["blowup"] = float("inf")
        out["verdict"] = "restart"
    else:
        out["blowup"] = faulted["time_s"] / max(base["time_s"], 1e-12)
        out["verdict"] = ("evict" if out["blowup"] > evict_threshold
                          else "tolerate")
    return out
