"""Per-scale contention calibration: region-DES probes at 10^4+ ranks.

The calibration bridge (platforms/bridge.py) fits fastsim's contention
scales against exact DES probes, but exact probes cap near 10^3 ranks —
so fleet predictions at real machine scale reused scales fitted at toy
scale and *assumed* they transfer (ROADMAP item 4).  Representative-
region runs (``repro.scale.region``) make the probe itself cheap at any
rank count, so the scales can be fitted *at* the scale they will be used
at, and the drift between scales measured rather than assumed:

    fit = fit_contention_at_scale(plat, at_ranks=10_000)
    fit.platform.fastsim(at_ranks=10_000)   # scale-specific params

Fitted overrides land in the spec's per-scale ``contention`` table
(``Platform.with_contention``) with a provenance entry recording the
region geometry that produced them; ``Platform.fastsim(at_ranks=...)``
then applies the nearest (log-space) entry on top of the base
calibration.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict, List, Optional, Sequence, Tuple

from repro.core.apps.hpl import HPLConfig

from .region import RegionSpec, as_region


def square_grid(n_ranks: int) -> Tuple[int, int]:
    """The most nearly square (P, Q) factorization with P <= Q."""
    if n_ranks < 1:
        raise ValueError(f"n_ranks={n_ranks} must be >= 1")
    for p in range(int(math.isqrt(n_ranks)), 0, -1):
        if n_ranks % p == 0:
            return p, n_ranks // p
    raise AssertionError("unreachable: 1 divides everything")


def scaled_probe_configs(platform, at_ranks: int, *,
                         region: Optional[RegionSpec] = None,
                         nb: int = 128) -> List[HPLConfig]:
    """HPL probe configs at ``at_ranks`` sized for region runs: a nearly
    square grid, and N chosen so the panel count is a small multiple of
    the region length — enough unsimulated tail that the fitted scales
    see real extrapolation, small enough that the region DES stays
    seconds."""
    if at_ranks > platform.scale.n_ranks:
        raise ValueError(
            f"at_ranks={at_ranks} exceeds platform "
            f"{platform.name!r} capacity ({platform.scale.n_ranks})")
    region = as_region(region)
    P, Q = square_grid(at_ranks)
    return [HPLConfig(N=nb * panels, nb=nb, P=P, Q=Q, lookahead=0,
                      bcast=platform.mpi.bcast)
            for panels in (3 * region.panels, 4 * region.panels)]


@dataclasses.dataclass
class ScaleFit:
    """One per-scale calibration: ``platform`` carries the new
    ``contention`` entry (plus provenance); ``overrides`` is the fitted
    field table for ``at_ranks``."""
    platform: object                    # Platform with the entry baked in
    at_ranks: int
    overrides: Dict[str, float]
    probes: List[Tuple[HPLConfig, float]]
    region: RegionSpec
    fields: Tuple[str, ...]


def fit_contention_at_scale(platform, at_ranks: int, *,
                            region: Optional[RegionSpec] = None,
                            probe_configs: Optional[Sequence] = None,
                            fields: Optional[Sequence[str]] = None,
                            steps: int = 60, lr: float = 0.1) -> ScaleFit:
    """Fit fastsim contention scales against region-DES probes run at
    ``at_ranks`` and bake them into the spec's per-scale table."""
    from repro.platforms.bridge import (DEFAULT_FIT_FIELDS,
                                        fit_fastsim_to_des)

    region = as_region(region)
    fields = tuple(fields) if fields is not None else DEFAULT_FIT_FIELDS
    if probe_configs is None:
        probe_configs = scaled_probe_configs(platform, at_ranks,
                                             region=region)
    fit = fit_fastsim_to_des(platform, probe_configs, fields=fields,
                             steps=steps, lr=lr, regions=region)
    overrides = fit.calibration
    note = (f"region-fit panels={region.panels} warmup={region.warmup} "
            f"probes={len(fit.probes)} fields={','.join(fields)}")
    plat = platform.with_contention(at_ranks, overrides, note=note)
    return ScaleFit(platform=plat, at_ranks=at_ranks, overrides=overrides,
                    probes=fit.probes, region=region, fields=fields)


def contention_drift(platform, scales: Sequence[int], **kw
                     ) -> Tuple[object, Dict[int, Dict[str, float]]]:
    """Fit the contention scales at each rank count in ``scales`` and
    return (platform with the full table, {ranks: overrides}) — the
    fitted-scale-vs-rank-count drift the bridge used to assume away."""
    table: Dict[int, Dict[str, float]] = {}
    plat = platform
    for s in scales:
        sf = fit_contention_at_scale(plat, s, **kw)
        plat = sf.platform
        table[int(s)] = sf.overrides
    return plat, table
