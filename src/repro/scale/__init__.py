"""Representative-region simulation and per-scale calibration
(DESIGN.md §17): exact DES on one region of the iteration space,
closed-form replication of the rest, and contention scales fitted *at*
the rank count they will be used at."""
from .contention import (ScaleFit, contention_drift, fit_contention_at_scale,
                         scaled_probe_configs, square_grid)
from .region import (RegionHPLSim, RegionSpec, RegionStepSim, as_region)

__all__ = [
    "RegionSpec", "as_region", "RegionHPLSim", "RegionStepSim",
    "ScaleFit", "fit_contention_at_scale", "contention_drift",
    "scaled_probe_configs", "square_grid",
]
