"""Representative-region simulation: exact DES on one region, analytic
replication of the rest.

Ferrerón et al. ("Crossing the Architectural Barrier", PAPERS.md) show
that simulating one representative region of an iterative parallel code
exactly and replicating the remaining iterations analytically preserves
accuracy at a fraction of the cost.  Cornebize & Legrand ("Variability
Matters") motivate why the closed forms that replace the replicated
iterations must be *calibrated from the simulated region* rather than
assumed.  This module applies both ideas to the two DES applications:

  * **HPL** (``RegionHPLSim``): the first ``RegionSpec.panels`` panels of
    the right-looking LU run on the real DES (every flow, every
    contention event).  The unsimulated tail exploits LU's self-similar
    structure: the remaining panels of an ``N`` x ``N`` problem ARE a
    complete ``N - R*nb`` problem on the same grid, so the closed-form
    panel recurrence (``core.fastsim``) prices the tail with the full
    pipeline/shape arithmetic intact, and the region calibrates one
    scalar —

        s  =  (mark[R-1] - mark[W-1]) / (That(W) - That(R))

    the DES-over-closed-form time ratio on the post-warmup window
    (``That(k)`` = fastsim time of the trailing subproblem starting at
    panel ``k``).  ``time = mark[R-1] + s * That(R)``.  A scalar is the
    right amount of freedom: per-panel regressions on the region are
    ill-posed (block-cyclic features are constant within a window
    shorter than ``P`` panels), while ``s`` only asks the region "how
    much slower is the contended DES than the analytic model", which is
    exactly what a dozen panels can answer.  Without a ``Platform``
    (raw node/topology construction) there is no fastsim surface and a
    sign-constrained least-squares fit of per-panel durations against
    exact-shape features (``d_k ~= a*comp_k + b*bytes_k + c*w_k + e``)
    takes over — good on modest grids, documented weaker on large ones.

  * **transformer** (``RegionStepSim``): layers are homogeneous, so the
    first ``panels`` layers (plus the real tail collectives) run exactly
    and the steady-state per-layer delta — read from the layer-boundary
    marks — replicates the rest.

Both are exposed through the ``Workload`` protocol as
``des_app(platform, regions=...)`` / ``predict_des(..., regions=...)``;
results are stamped ``region_approx`` so downstream consumers (the
serving layer's breakdown endpoint, the calibration bridge) can tell an
extrapolated answer from an exact one.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Callable, Dict, List, Optional, Union

from repro.core.apps.hpl import HPLConfig, HPLResult, HPLSim, numroc
from repro.core.apps.transformer import StepWorkload, TransformerStepSim
from repro.core.simblas import SimBLAS


@dataclasses.dataclass(frozen=True)
class RegionSpec:
    """How much of the iteration space to simulate exactly.

    ``panels`` is the region length in iterations (HPL panels /
    transformer layers); ``warmup`` leading iterations are excluded from
    the fit window (pipeline fill distorts them).
    """
    panels: int = 12
    warmup: int = 2

    def __post_init__(self):
        if self.warmup < 1:
            raise ValueError(f"RegionSpec: warmup={self.warmup} must be "
                             ">= 1")
        if self.panels < self.warmup + 4:
            raise ValueError(
                f"RegionSpec: panels={self.panels} must be >= warmup + 4 "
                f"(need a usable fit window, warmup={self.warmup})")


Regions = Union[None, int, RegionSpec]


def as_region(regions: Regions) -> RegionSpec:
    """Normalize the ``regions=`` argument: an int is a region length."""
    if regions is None:
        return RegionSpec()
    if isinstance(regions, RegionSpec):
        return regions
    if isinstance(regions, bool):
        raise TypeError("regions must be an int or RegionSpec")
    if isinstance(regions, int):
        return RegionSpec(panels=regions)
    raise TypeError(f"regions must be None, int, or RegionSpec, got "
                    f"{type(regions).__name__}")


# --------------------------------------------------------------- HPL
def _panel_features(cfg: HPLConfig, blas: SimBLAS) -> List[List[float]]:
    """Per-panel closed-form features [comp_s, wire_bytes, w, 1] from
    exact numroc shape arithmetic — no DES, no data.

    ``comp_s`` is the critical-rank BLAS time of panel k (factorization
    + dtrsm + dgemm + dlaswp on the max local shapes); ``wire_bytes``
    the panel-broadcast pipeline plus U-strip swap volume; ``w`` carries
    the per-column latency terms (pivot allreduces).  The linear fit
    against the simulated region absorbs overlap/contention scaling.
    """
    N, nb, P, Q = cfg.N, cfg.nb, cfg.P, cfg.Q
    rows: List[List[float]] = []
    for k in range(cfg.n_panels):
        rem = N - k * nb
        w = min(nb, rem)
        pk = k % P
        mloc = max(numroc(rem, nb, (p - pk) % P, P) for p in range(P))
        nloc = max(numroc(max(rem - w, 0), nb, (q - (k + 1) % Q) % Q, Q)
                   for q in range(Q))
        comp = blas.panel_fact(mloc, w)
        nbytes = 0.0
        if Q > 1:
            nbytes += 8.0 * (mloc + w) * w          # panel broadcast
        if P > 1 and nloc > 0:
            nbytes += 8.0 * w * nloc                # U-strip swap rounds
            comp += blas.dlaswp(w, max(nloc, 1))
        if nloc > 0:
            comp += blas.dtrsm(w, nloc)
            if mloc > 0:
                comp += blas.dgemm(mloc, nloc, w)
        rows.append([comp, nbytes, float(w), 1.0])
    return rows


def _nnls(A, b):
    """Exact non-negative least squares by exhaustive support search
    (A has <= 4 columns, so <= 16 candidate supports).  Deterministic,
    no dependency beyond numpy."""
    import itertools

    import numpy as np

    m, n = A.shape
    best_r, best_th = np.inf, np.zeros(n)
    for r in range(n + 1):
        for sup in itertools.combinations(range(n), r):
            th = np.zeros(n)
            if sup:
                cols = list(sup)
                sol, *_ = np.linalg.lstsq(A[:, cols], b, rcond=None)
                if (sol < 0.0).any():
                    continue
                th[cols] = sol
            resid = float(((A @ th - b) ** 2).sum())
            if resid < best_r - 1e-18:
                best_r, best_th = resid, th
    return best_th


def _fit_tail(features: List[List[float]], durations: List[float],
              fit_lo: int, tail_lo: int) -> float:
    """Fit d_k ~= X_k . theta on panels [fit_lo, tail_lo) and return the
    predicted total duration of panels [tail_lo, end).

    Columns are max-normalized before the solve (comp is ~1e-2 s while
    bytes is ~1e6) and coefficients are sign-constrained: every feature
    is a cost, so negative weights are physically meaningless — and on
    long-tail extrapolation an unconstrained min-norm solution happily
    trades a negative bytes slope against a large constant inside the
    window, then explodes outside it."""
    import numpy as np

    X = np.asarray(features, dtype=float)
    d = np.asarray(durations, dtype=float)
    scale = np.abs(X[fit_lo:tail_lo]).max(axis=0)
    scale[scale == 0.0] = 1.0
    theta = _nnls(X[fit_lo:tail_lo] / scale, d[fit_lo:tail_lo])
    pred = (X[tail_lo:] / scale) @ theta
    return float(np.clip(pred, 0.0, None).sum())


def _closed_form_tail(cfg: HPLConfig, platform, marks: Dict[int, float],
                      region: RegionSpec) -> float:
    """Price panels [R, end) with the fastsim recurrence, calibrated by
    the region: the tail of HPL at panel ``k`` is itself a complete
    ``(N - k*nb)`` problem on the same grid, so ``That(k)`` (closed-form
    time of that subproblem, at the DES's lookahead) prices any suffix.
    One scalar ``s`` — DES seconds per closed-form second on the
    post-warmup window [W, R) — absorbs contention and rendezvous
    overheads the analytic model folds away."""
    from repro.core.fastsim import simulate_hpl_fast

    prm = dataclasses.replace(platform.fastsim(),
                              lookahead=float(cfg.lookahead))

    def t_hat(k: int) -> float:
        n = cfg.N - k * cfg.nb
        if n <= 0:
            return 0.0
        return simulate_hpl_fast(dataclasses.replace(cfg, N=n),
                                 prm)["time_s"]

    R, W = region.panels, region.warmup
    denom = t_hat(W) - t_hat(R)
    s = (marks[R - 1] - marks[W - 1]) / denom if denom > 0.0 else 1.0
    if not (s > 0.0):                   # degenerate window; trust the form
        s = 1.0
    return s * t_hat(R)


class RegionHPLSim:
    """HPL with only a representative prefix of panels simulated.

    Drop-in for ``HPLSim`` (same constructor forms — Platform, DESStack,
    or (node, topology) — plus ``region=``): ``run()`` returns an
    ``HPLResult`` whose ``time_s`` extrapolates the unsimulated panels
    from the region-calibrated closed form, stamped
    ``region_approx=True``.  Built from a ``Platform`` the tail is
    priced by the fastsim recurrence (the accurate path — see module
    docstring); otherwise the feature fit takes over.  When the config
    has no more panels than the region, the exact DES runs and the
    result is returned unchanged.
    """

    def __init__(self, cfg: HPLConfig, node, topology=None, *,
                 region: Regions = None, **hpl_kw):
        self.cfg = cfg
        self.region = as_region(region)
        self._platform = (node if topology is None
                          and hasattr(node, "fastsim") else None)
        self._truncated = cfg.n_panels > self.region.panels
        self._marks: Dict[int, float] = {}
        if self._truncated:
            hpl_kw.setdefault("max_panels", self.region.panels)
            hpl_kw.setdefault("panel_marks", self._marks)
        self.sim = HPLSim(cfg, node, topology, **hpl_kw)

    @property
    def engine(self):
        return self.sim.engine

    @property
    def trace(self):
        return self.sim.trace

    def run(self) -> HPLResult:
        res = self.sim.run()
        if not self._truncated or res.failed:
            # exact run, or a fail-stop stranded the region — nothing
            # sound to extrapolate from
            return res
        R = self.region.panels
        marks = self._marks
        if self._platform is not None:
            tail = _closed_form_tail(self.cfg, self._platform, marks,
                                     self.region)
        else:
            durations = [marks.get(0, 0.0)]
            for k in range(1, R):
                durations.append(marks.get(k, 0.0) - marks.get(k - 1, 0.0))
            feats = _panel_features(self.cfg,
                                    SimBLAS(self.sim.blas[0].node))
            tail = _fit_tail(feats, durations + [0.0] * (len(feats) - R),
                             fit_lo=self.region.warmup, tail_lo=R)
        t = marks[R - 1] + tail
        return HPLResult(
            time_s=t, gflops=self.cfg.flops() / t / 1e9,
            events=res.events, trace=res.trace,
            region_approx=True, region_panels=R)


# ------------------------------------------------------- transformer
class RegionStepSim:
    """Transformer step with only ``region.panels`` layers simulated.

    ``build(truncated_workload, layer_marks)`` constructs the inner
    ``TransformerStepSim`` (the workload layer binds platform/mesh/trace
    there).  Layers are homogeneous by construction, so the steady-state
    per-layer delta — the last two layer-boundary marks — replicates the
    unsimulated layers; the tail collectives (whose wire bytes scale
    with the FULL layer count) run exactly inside the region.
    """

    def __init__(self, workload: StepWorkload, region: Regions,
                 build: Callable[[StepWorkload, Optional[Dict[int, float]]],
                                 TransformerStepSim]):
        self.region = as_region(region)
        self.n_layers = len(workload.layers)
        self._truncated = self.n_layers > self.region.panels
        self._marks: Optional[Dict[int, float]] = None
        if self._truncated:
            self._marks = {}
            workload = StepWorkload(
                layers=workload.layers[:self.region.panels],
                tail_collectives=workload.tail_collectives,
                tail_compute_s=workload.tail_compute_s)
        self.sim = build(workload, self._marks)

    @property
    def engine(self):
        return self.sim.engine

    @property
    def trace(self):
        return self.sim.trace

    def run(self) -> Dict:
        res = self.sim.run()
        if not self._truncated or res.get("failed"):
            return res
        R = self.region.panels
        marks = self._marks
        delta = marks[R - 1] - marks[R - 2]
        out = dict(res)
        t = res["step_s"] + (self.n_layers - R) * max(delta, 0.0)
        out["step_s"] = t
        out["region_step_s"] = res["step_s"]
        out["region_approx"] = True
        out["layers_simulated"] = R
        out["layers_total"] = self.n_layers
        return out
