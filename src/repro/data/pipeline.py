"""Deterministic, elastically-shardable data pipeline.

Every batch is a pure function of (seed, step, dp_rank, dp_size): restarts
replay exactly, and an elastic resize (new dp_size) re-partitions the same
global token stream without skips or repeats — the fault-tolerance story
(DESIGN.md §4) depends on this determinism.

The synthetic LM stream is a mixture of Zipf-distributed tokens with
Markov bigram structure, so small-model training shows a real, monotonic
loss drop (used by examples/train_lm.py).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterator, Optional

import numpy as np


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 1234
    zipf_a: float = 1.2


class SyntheticLM:
    def __init__(self, cfg: DataConfig):
        self.cfg = cfg
        rng = np.random.default_rng(cfg.seed)
        v = cfg.vocab_size
        # fixed random bigram successor table: next = table[cur, digit]
        self._succ = rng.integers(0, v, size=(min(v, 4096), 8),
                                  dtype=np.int64)

    def _sample_seq(self, rng: np.random.Generator) -> np.ndarray:
        cfg = self.cfg
        v = cfg.vocab_size
        out = np.empty(cfg.seq_len, np.int64)
        cur = int(rng.integers(0, min(v, 4096)))
        for t in range(cfg.seq_len):
            if rng.random() < 0.75:       # predictable bigram transition
                cur = int(self._succ[cur % 4096, int(rng.integers(0, 8))])
            else:                          # zipf "noise" token
                cur = int(min(rng.zipf(cfg.zipf_a), v - 1))
            out[t] = cur % v
        return out

    def global_batch_at(self, step: int) -> np.ndarray:
        """The full global batch for a step — identical regardless of the
        number of data shards reading it."""
        cfg = self.cfg
        seqs = []
        for i in range(cfg.global_batch):
            rng = np.random.default_rng(
                (cfg.seed, step, i, 0x5DEECE66D))
            seqs.append(self._sample_seq(rng))
        return np.stack(seqs)

    def shard_at(self, step: int, dp_rank: int, dp_size: int) -> np.ndarray:
        cfg = self.cfg
        assert cfg.global_batch % dp_size == 0
        per = cfg.global_batch // dp_size
        seqs = []
        for j in range(per):
            i = dp_rank * per + j          # global sample index
            rng = np.random.default_rng((cfg.seed, step, i, 0x5DEECE66D))
            seqs.append(self._sample_seq(rng))
        return np.stack(seqs)


def make_batch_iterator(cfg: DataConfig, dp_rank: int = 0, dp_size: int = 1,
                        start_step: int = 0) -> Iterator[Dict]:
    ds = SyntheticLM(cfg)
    step = start_step
    while True:
        yield {"tokens": ds.shard_at(step, dp_rank, dp_size)}
        step += 1
