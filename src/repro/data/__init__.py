from .pipeline import SyntheticLM, DataConfig, make_batch_iterator

__all__ = ["SyntheticLM", "DataConfig", "make_batch_iterator"]
