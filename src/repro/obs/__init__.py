"""repro.obs — metrics & telemetry for the serving + simulation stack.

Zero-overhead-when-off metrics in the trace subsystem's null-object
style (DESIGN.md §18): ``MetricsRegistry`` (counters / gauges /
fixed-bucket histograms, mergeable and JSON round-trip), ``Timer``
spans, a Prometheus text exporter, and NDJSON run manifests.

Quickstart::

    from repro.obs import MetricsRegistry
    from repro.serve import PredictionService, WorkloadRequest

    svc = PredictionService()            # metrics on by default
    svc.predict_batch([WorkloadRequest(rid=0, workload="hpl",
                                       platform="frontera")])
    print(svc.metrics.to_prometheus())   # scrape surface
    print(svc.manifest())                # one NDJSON run manifest line

Simulation layers stay metrics-free unless opted in: hang a registry on
``engine.metrics`` (DES) or install one with ``set_global_metrics``
(fastsim / stepsim compile-cache and sweep-lane metrics).  Instrumented
runs are bit-identical to uninstrumented ones — the registry only
observes.

Serving-throughput metric families (DESIGN.md §20; all land in
snapshots, Prometheus text, and manifests like every other instrument):
``serve.cache_hits`` / ``serve.cache_misses`` / ``serve.coalesced``
count result-cache effectiveness, ``serve.cache_entries`` /
``serve.cache_occupancy`` gauge its fill level, ``serve.warm_compiles``
/ ``serve.warm_dispatches`` account the warm pool, and
``fastsim.sharded_dispatches`` / ``stepsim.sharded_dispatches`` (plus
``*.shard_devices`` gauges) record device-sharded sweep dispatches.
"""
from .export import (ManifestReadReport, append_manifest, manifest_line,
                     manifest_record, read_manifest,
                     read_manifest_report, to_prometheus,
                     validate_prometheus_text)
from .metrics import (COUNT_BUCKETS, DEFAULT_LATENCY_BUCKETS, NULL_METRICS,
                      RATIO_BUCKETS, Counter, Gauge, Histogram,
                      MetricsRegistry, Timer, get_global_metrics,
                      global_metrics, merge_snapshots, set_global_metrics)

__all__ = [
    "MetricsRegistry", "NULL_METRICS", "Counter", "Gauge", "Histogram",
    "Timer", "DEFAULT_LATENCY_BUCKETS", "COUNT_BUCKETS", "RATIO_BUCKETS",
    "merge_snapshots", "get_global_metrics", "set_global_metrics",
    "global_metrics", "to_prometheus", "validate_prometheus_text",
    "manifest_record", "manifest_line", "append_manifest", "read_manifest",
    "read_manifest_report", "ManifestReadReport",
]
