"""Exporters: Prometheus text exposition + NDJSON run manifests.

Two machine-readable surfaces over a ``MetricsRegistry`` snapshot:

  * :func:`to_prometheus` — the Prometheus text exposition format
    (``# TYPE`` comments, ``_total`` counter suffix, cumulative
    ``_bucket{le=...}`` histogram series ending in ``le="+Inf"``,
    gauge peaks as a ``_peak`` companion series).  Metric names are
    sanitized to the exposition grammar (dots become underscores);
    :func:`validate_prometheus_text` checks any exposition string
    against that grammar and the cumulative-bucket invariants, and is
    what the tests hold the exporter to.
  * :func:`manifest_record` / :func:`append_manifest` — one JSON object
    per run ("NDJSON run manifest"): a ``kind`` tag, caller metadata,
    and the full metrics snapshot, dumped with sorted keys so equal
    runs produce byte-equal lines.  This is the per-run artifact format
    the campaign layer (ROADMAP item 5) consumes: ``benchmarks/
    serve_bench.py`` and ``top500.FleetReport.manifest`` both emit it.

No wall-clock or hostname fields are injected here — determinism is the
caller's to break (pass timestamps in ``meta`` if you want them).
"""
from __future__ import annotations

import json
import re
from typing import Any, Dict, List, Mapping, Optional, Tuple

__all__ = ["to_prometheus", "validate_prometheus_text",
           "manifest_record", "manifest_line", "append_manifest",
           "read_manifest", "read_manifest_report",
           "ManifestReadReport"]

_NAME_SANITIZE = re.compile(r"[^a-zA-Z0-9_:]")
_LABEL_SANITIZE = re.compile(r"[^a-zA-Z0-9_]")

# exposition grammar (the subset we emit): metric names, optional
# label set, and a float/int value.  Validation regexes below.
_METRIC_NAME_RE = re.compile(r"^[a-zA-Z_:][a-zA-Z0-9_:]*$")
_LABEL_NAME_RE = re.compile(r"^[a-zA-Z_][a-zA-Z0-9_]*$")
_SAMPLE_RE = re.compile(
    r'^(?P<name>[a-zA-Z_:][a-zA-Z0-9_:]*)'
    r'(?:\{(?P<labels>[^{}]*)\})?'
    r' (?P<value>[+-]?(?:\d+\.?\d*(?:[eE][+-]?\d+)?|Inf|NaN))$')
_LABEL_PAIR_RE = re.compile(
    r'(?P<k>[a-zA-Z_][a-zA-Z0-9_]*)="(?P<v>(?:[^"\\\n]|\\.)*)"')


def _prom_name(name: str) -> str:
    out = _NAME_SANITIZE.sub("_", name)
    if not _METRIC_NAME_RE.match(out):
        out = "_" + out
    return out


def _fmt_labels(labels, extra: Tuple[Tuple[str, str], ...] = ()) -> str:
    pairs = [(_LABEL_SANITIZE.sub("_", k),
              v.replace("\\", r"\\").replace('"', r'\"').replace("\n", r"\n"))
             for k, v in tuple(labels) + tuple(extra)]
    if not pairs:
        return ""
    return "{" + ",".join(f'{k}="{v}"' for k, v in pairs) + "}"


def _fmt_val(v: float) -> str:
    if v != v:
        return "NaN"
    if v == float("inf"):
        return "+Inf"
    if v == float("-inf"):
        return "-Inf"
    f = float(v)
    return repr(int(f)) if f == int(f) and abs(f) < 1e15 else repr(f)


def to_prometheus(registry_or_snapshot) -> str:
    """Render a registry (or snapshot dict) in the Prometheus text
    exposition format.  Deterministic: series are emitted in sorted
    snapshot order."""
    from .metrics import parse_key
    snap = (registry_or_snapshot.snapshot()
            if hasattr(registry_or_snapshot, "snapshot")
            else registry_or_snapshot)
    lines: List[str] = []

    for key, value in snap.get("counters", {}).items():
        name, labels = parse_key(key)
        pname = _prom_name(name) + "_total"
        lines.append(f"# TYPE {pname} counter")
        lines.append(f"{pname}{_fmt_labels(labels)} {_fmt_val(value)}")

    for key, gv in snap.get("gauges", {}).items():
        name, labels = parse_key(key)
        pname = _prom_name(name)
        lines.append(f"# TYPE {pname} gauge")
        lines.append(f"{pname}{_fmt_labels(labels)} {_fmt_val(gv['value'])}")
        if gv.get("max") is not None:
            lines.append(f"# TYPE {pname}_peak gauge")
            lines.append(
                f"{pname}_peak{_fmt_labels(labels)} {_fmt_val(gv['max'])}")

    for key, hv in snap.get("histograms", {}).items():
        name, labels = parse_key(key)
        pname = _prom_name(name)
        lines.append(f"# TYPE {pname} histogram")
        cum = 0
        for bound, c in zip(hv["bounds"], hv["counts"]):
            cum += c
            lines.append(
                f"{pname}_bucket"
                f"{_fmt_labels(labels, (('le', _fmt_val(bound)),))} {cum}")
        cum += hv["counts"][len(hv["bounds"])]
        lines.append(
            f"{pname}_bucket{_fmt_labels(labels, (('le', '+Inf'),))} {cum}")
        lines.append(f"{pname}_sum{_fmt_labels(labels)} "
                     f"{_fmt_val(hv['sum'])}")
        lines.append(f"{pname}_count{_fmt_labels(labels)} {hv['count']}")
    return "\n".join(lines) + ("\n" if lines else "")


def validate_prometheus_text(text: str) -> List[Tuple[str, Dict[str, str],
                                                      float]]:
    """Check ``text`` against the exposition grammar; returns the parsed
    ``(name, labels, value)`` samples, raising ``ValueError`` on the
    first violation.  Beyond line syntax it checks the histogram
    invariants: ``_bucket`` series are cumulative (non-decreasing in
    ``le`` order), end at ``le="+Inf"``, and agree with ``_count``."""
    samples: List[Tuple[str, Dict[str, str], float]] = []
    buckets: Dict[str, List[Tuple[str, float]]] = {}
    counts: Dict[str, float] = {}
    for lineno, line in enumerate(text.splitlines(), start=1):
        if not line.strip():
            continue
        if line.startswith("#"):
            parts = line.split(None, 3)
            if len(parts) < 3 or parts[1] not in ("TYPE", "HELP"):
                raise ValueError(f"line {lineno}: bad comment {line!r}")
            if not _METRIC_NAME_RE.match(parts[2]):
                raise ValueError(
                    f"line {lineno}: bad metric name {parts[2]!r}")
            if parts[1] == "TYPE" and parts[3] not in (
                    "counter", "gauge", "histogram", "summary", "untyped"):
                raise ValueError(f"line {lineno}: bad type {parts[3]!r}")
            continue
        m = _SAMPLE_RE.match(line)
        if not m:
            raise ValueError(f"line {lineno}: bad sample line {line!r}")
        labels: Dict[str, str] = {}
        raw = m.group("labels")
        if raw:
            consumed = 0
            for lm in _LABEL_PAIR_RE.finditer(raw):
                if not _LABEL_NAME_RE.match(lm.group("k")):
                    raise ValueError(
                        f"line {lineno}: bad label name {lm.group('k')!r}")
                labels[lm.group("k")] = lm.group("v")
                consumed += len(lm.group(0))
            leftover = _LABEL_PAIR_RE.sub("", raw).strip(", ")
            if leftover:
                raise ValueError(
                    f"line {lineno}: unparsable label text {leftover!r}")
        name = m.group("name")
        value = float(m.group("value").replace("Inf", "inf"))
        samples.append((name, labels, value))
        if name.endswith("_bucket") and "le" in labels:
            series = name + _fmt_labels(
                tuple(sorted((k, v) for k, v in labels.items()
                             if k != "le")))
            buckets.setdefault(series, []).append((labels["le"], value))
        elif name.endswith("_count"):
            counts[name[:-len("_count")] + _fmt_labels(
                tuple(sorted(labels.items())))] = value
    for series, pairs in buckets.items():
        vals = [v for _, v in pairs]
        if vals != sorted(vals):
            raise ValueError(f"{series}: bucket counts not cumulative")
        if pairs[-1][0] != "+Inf":
            raise ValueError(f"{series}: last bucket must be le=\"+Inf\"")
        base = series[:series.index("_bucket")] + series[
            series.index("_bucket") + len("_bucket"):]
        if base in counts and counts[base] != pairs[-1][1]:
            raise ValueError(
                f"{series}: +Inf bucket {pairs[-1][1]} != _count "
                f"{counts[base]}")
    return samples


# ------------------------------------------------------- NDJSON manifest
MANIFEST_VERSION = 1


def manifest_record(kind: str, *, meta: Optional[Mapping[str, Any]] = None,
                    metrics=None) -> Dict[str, Any]:
    """One run manifest as a JSON-safe dict: ``kind`` tags the producer
    ("serve_wave", "fleet_run", "bench", ...), ``meta`` is caller
    payload (config, counts, walls), ``metrics`` a registry or snapshot
    whose full snapshot rides along."""
    rec: Dict[str, Any] = {"manifest": MANIFEST_VERSION, "kind": str(kind)}
    if meta:
        rec["meta"] = dict(meta)
    if metrics is not None:
        rec["metrics"] = (metrics.snapshot()
                          if hasattr(metrics, "snapshot") else dict(metrics))
    return rec


def manifest_line(kind: str, *, meta: Optional[Mapping[str, Any]] = None,
                  metrics=None) -> str:
    """The NDJSON line for one run (sorted keys: equal runs give
    byte-equal lines)."""
    return json.dumps(manifest_record(kind, meta=meta, metrics=metrics),
                      sort_keys=True)


def append_manifest(path, kind: str, *,
                    meta: Optional[Mapping[str, Any]] = None,
                    metrics=None) -> str:
    """Append one manifest line to ``path`` (the NDJSON journal form:
    one JSON object per line, concatenation-safe across runs)."""
    line = manifest_line(kind, meta=meta, metrics=metrics)
    with open(path, "a") as fh:
        fh.write(line + "\n")
    return line


class ManifestReadReport:
    """What a lenient manifest read accepted and what it skipped:
    ``records`` in file order, ``skipped`` as (1-based line, reason)
    pairs — blank lines are ignored silently (NDJSON allows them),
    corrupt lines (a journal torn by a killed run) are counted."""

    def __init__(self, records: List[Dict[str, Any]],
                 skipped: List[Tuple[int, str]]):
        self.records = records
        self.skipped = skipped

    def __iter__(self):
        return iter(self.records)

    def __len__(self):
        return len(self.records)


def read_manifest_report(path, *, strict: bool = False
                         ) -> ManifestReadReport:
    """Parse an NDJSON manifest file, tolerating the damage a killed
    run leaves behind.  Lenient mode (default) skips corrupt lines
    with a per-line reason in ``report.skipped``; ``strict=True``
    raises ``ValueError`` on the first one.  Blank lines are never an
    error."""
    records: List[Dict[str, Any]] = []
    skipped: List[Tuple[int, str]] = []
    with open(path) as fh:
        for lineno, line in enumerate(fh, start=1):
            line = line.strip()
            if not line:
                continue
            try:
                rec = json.loads(line)
            except json.JSONDecodeError as exc:
                if strict:
                    raise ValueError(
                        f"read_manifest: {path}: line {lineno}: "
                        f"{exc}") from exc
                skipped.append((lineno, str(exc)))
                continue
            if not isinstance(rec, dict):
                reason = (f"expected a JSON object, got "
                          f"{type(rec).__name__}")
                if strict:
                    raise ValueError(f"read_manifest: {path}: line "
                                     f"{lineno}: {reason}")
                skipped.append((lineno, reason))
                continue
            records.append(rec)
    return ManifestReadReport(records, skipped)


def read_manifest(path, *, strict: bool = False) -> List[Dict[str, Any]]:
    """Parse an NDJSON manifest file back into records.  Lenient by
    default — blank and corrupt lines are skipped (use
    :func:`read_manifest_report` to see what was dropped);
    ``strict=True`` raises on the first corrupt line."""
    return read_manifest_report(path, strict=strict).records
