"""MetricsRegistry — counters, gauges, and fixed-bucket histograms for
the serving + simulation stack (DESIGN.md §18).

The contract mirrors the trace subsystem's null-object pattern (§13):
when metrics are off a component carries the module-level
``NULL_METRICS`` singleton whose instruments are no-ops and whose
``enabled`` flag is False, so every instrumentation site reduces to one
attribute test and hot paths pay nothing.  Crucially an enabled
registry only *observes* — it never schedules engine events, never
perturbs sweep inputs — so instrumented runs produce bit-identical
simulation results (asserted in tests/test_obs.py for HPL and
transformer on both the DES and the batched fast paths).

Three instrument kinds, chosen for mergeability (fleet runs, CI shards
and serving replicas aggregate by snapshot merge, which must be
associative and commutative — property-tested):

  * **Counter** — monotone float add.  Merge: sum.
  * **Gauge** — last-set value plus tracked min/max.  Merge: max of
    values (gauges here are depth/high-water style readings, where max
    is the meaningful aggregate), max of maxes, min of mins.
  * **Histogram** — fixed upper-bound buckets (so two snapshots merge
    by elementwise count addition; merging histograms with different
    bounds raises) plus sum/count/min/max.  Point numbers mislead
    without distributions (Cornebize & Legrand, PAPERS.md): latency and
    throughput are recorded as histograms, never single floats.

Instruments are keyed by ``(name, labels)``; snapshots flatten the key
to ``name{k="v",...}`` with sorted labels so equal registries serialize
to equal JSON (deterministic snapshots).  ``Timer`` is the span-style
context manager over a histogram.

A process-global registry hook (``set_global_metrics``) lets the
module-shaped layers — ``core.fastsim``, ``workloads.stepsim`` — report
compile-cache and sweep-lane metrics without threading a registry
through every call; it defaults to ``NULL_METRICS`` so nothing is
recorded unless a caller opts in.
"""
from __future__ import annotations

import bisect
import contextlib
import json
import re
import time
from typing import Any, Dict, Iterable, List, Mapping, Optional, Tuple

__all__ = [
    "Counter", "Gauge", "Histogram", "Timer", "MetricsRegistry",
    "NULL_METRICS", "DEFAULT_LATENCY_BUCKETS", "merge_snapshots",
    "get_global_metrics", "set_global_metrics", "global_metrics",
]

#: default latency buckets (seconds): sub-ms fastsim dispatches through
#: multi-minute DES breakdowns land in distinct buckets
DEFAULT_LATENCY_BUCKETS: Tuple[float, ...] = (
    1e-4, 2.5e-4, 5e-4, 1e-3, 2.5e-3, 5e-3, 1e-2, 2.5e-2, 5e-2,
    1e-1, 2.5e-1, 5e-1, 1.0, 2.5, 5.0, 10.0, 30.0, 60.0, 300.0)

#: small-integer buckets for size-ish distributions (wave sizes, lanes)
COUNT_BUCKETS: Tuple[float, ...] = (
    1.0, 2.0, 4.0, 8.0, 16.0, 32.0, 64.0, 128.0, 256.0, 512.0, 1024.0)

#: unit-interval buckets (occupancy / efficiency fractions)
RATIO_BUCKETS: Tuple[float, ...] = (
    0.1, 0.2, 0.3, 0.4, 0.5, 0.6, 0.7, 0.8, 0.9, 0.95, 1.0)

Labels = Tuple[Tuple[str, str], ...]

_KEY_RE = re.compile(
    r'^(?P<name>[^{}]+)(?:\{(?P<labels>[^{}]*)\})?$')
_LABEL_RE = re.compile(r'(?P<k>[A-Za-z_][A-Za-z0-9_.]*)="(?P<v>[^"]*)"')


def _labels_of(labels: Mapping[str, Any]) -> Labels:
    return tuple(sorted((str(k), str(v)) for k, v in labels.items()))


def flatten_key(name: str, labels: Labels = ()) -> str:
    """``name`` or ``name{k="v",...}`` with sorted labels — the
    snapshot/JSON key form (parse back with :func:`parse_key`)."""
    if not labels:
        return name
    inner = ",".join(f'{k}="{v}"' for k, v in labels)
    return f"{name}{{{inner}}}"


def parse_key(key: str) -> Tuple[str, Labels]:
    m = _KEY_RE.match(key)
    if not m:
        raise ValueError(f"bad metric key {key!r}")
    raw = m.group("labels")
    if not raw:
        return m.group("name"), ()
    labels = tuple((lm.group("k"), lm.group("v"))
                   for lm in _LABEL_RE.finditer(raw))
    return m.group("name"), labels


# ---------------------------------------------------------- instruments
class Counter:
    __slots__ = ("value",)

    def __init__(self, value: float = 0.0):
        self.value = value

    def inc(self, n: float = 1.0) -> None:
        if n < 0:
            raise ValueError(f"counter increment must be >= 0, got {n}")
        self.value += n


class Gauge:
    __slots__ = ("value", "max", "min")

    def __init__(self):
        self.value: float = 0.0
        self.max: Optional[float] = None
        self.min: Optional[float] = None

    def set(self, v: float) -> None:
        v = float(v)
        self.value = v
        if self.max is None or v > self.max:
            self.max = v
        if self.min is None or v < self.min:
            self.min = v


class Histogram:
    """Fixed-bucket histogram: ``bounds`` are ascending upper bounds;
    ``counts`` has ``len(bounds) + 1`` entries, the last being the
    overflow (+Inf) bucket."""

    __slots__ = ("bounds", "counts", "sum", "count", "min", "max")

    def __init__(self, bounds: Iterable[float] = DEFAULT_LATENCY_BUCKETS):
        self.bounds: Tuple[float, ...] = tuple(float(b) for b in bounds)
        if not self.bounds or list(self.bounds) != sorted(set(self.bounds)):
            raise ValueError(
                f"histogram bounds must be ascending and distinct, "
                f"got {self.bounds}")
        self.counts: List[int] = [0] * (len(self.bounds) + 1)
        self.sum = 0.0
        self.count = 0
        self.min: Optional[float] = None
        self.max: Optional[float] = None

    def observe(self, v: float) -> None:
        v = float(v)
        self.counts[bisect.bisect_left(self.bounds, v)] += 1
        self.sum += v
        self.count += 1
        if self.max is None or v > self.max:
            self.max = v
        if self.min is None or v < self.min:
            self.min = v

    def quantile(self, q: float) -> float:
        """Bucket-interpolated quantile in [0, 1] (0.0 when empty)."""
        if self.count == 0:
            return 0.0
        target = q * self.count
        cum = 0
        lo = 0.0
        for i, c in enumerate(self.counts):
            hi = self.bounds[i] if i < len(self.bounds) else (
                self.max if self.max is not None else lo)
            if cum + c >= target and c > 0:
                frac = (target - cum) / c
                return lo + frac * (max(hi, lo) - lo)
            cum += c
            lo = hi
        return self.max if self.max is not None else 0.0

    @property
    def mean(self) -> float:
        return self.sum / self.count if self.count else 0.0


class Timer:
    """Span-style context manager: observes elapsed wall seconds into a
    histogram on exit; ``.elapsed`` holds the last measurement."""

    __slots__ = ("_hist", "_t0", "elapsed")

    def __init__(self, hist: Histogram):
        self._hist = hist
        self._t0 = 0.0
        self.elapsed: Optional[float] = None

    def __enter__(self) -> "Timer":
        self._t0 = time.perf_counter()
        return self

    def __exit__(self, *exc) -> None:
        self.elapsed = time.perf_counter() - self._t0
        self._hist.observe(self.elapsed)


# ---------------------------------------------------------- null object
class _NullCounter:
    __slots__ = ()
    value = 0.0

    def inc(self, n: float = 1.0) -> None:
        pass


class _NullGauge:
    __slots__ = ()
    value = 0.0
    max = None
    min = None

    def set(self, v: float) -> None:
        pass


class _NullHistogram:
    __slots__ = ()
    bounds: Tuple[float, ...] = ()
    sum = 0.0
    count = 0
    min = None
    max = None
    mean = 0.0

    def observe(self, v: float) -> None:
        pass

    def quantile(self, q: float) -> float:
        return 0.0


class _NullTimer:
    __slots__ = ()
    elapsed = None

    def __enter__(self) -> "_NullTimer":
        return self

    def __exit__(self, *exc) -> None:
        pass


_NULL_COUNTER = _NullCounter()
_NULL_GAUGE = _NullGauge()
_NULL_HISTOGRAM = _NullHistogram()
_NULL_TIMER = _NullTimer()


class _NullMetrics:
    """Metrics-off singleton: instruments are shared no-ops, snapshots
    are empty, and ``enabled`` is False so hot paths skip recording
    behind one attribute test."""
    enabled = False
    __slots__ = ()

    def counter(self, name: str, **labels) -> _NullCounter:
        return _NULL_COUNTER

    def gauge(self, name: str, **labels) -> _NullGauge:
        return _NULL_GAUGE

    def histogram(self, name: str, buckets=None, **labels) -> _NullHistogram:
        return _NULL_HISTOGRAM

    def timer(self, name: str, buckets=None, **labels) -> _NullTimer:
        return _NULL_TIMER

    def snapshot(self) -> Dict[str, Dict[str, Any]]:
        return {"counters": {}, "gauges": {}, "histograms": {}}

    def to_json(self, **kw) -> str:
        return json.dumps(self.snapshot(), sort_keys=True, **kw)

    def to_prometheus(self) -> str:
        return ""


NULL_METRICS = _NullMetrics()


# ------------------------------------------------------------- registry
class MetricsRegistry:
    """The enabled registry: instruments are created on first use and
    keyed ``(name, sorted labels)``; repeat lookups return the same
    object, so call sites may cache them."""

    enabled = True

    def __init__(self):
        self._counters: Dict[Tuple[str, Labels], Counter] = {}
        self._gauges: Dict[Tuple[str, Labels], Gauge] = {}
        self._histograms: Dict[Tuple[str, Labels], Histogram] = {}

    # -------------------------------------------------- instrument API
    def counter(self, name: str, **labels) -> Counter:
        key = (name, _labels_of(labels))
        c = self._counters.get(key)
        if c is None:
            c = self._counters[key] = Counter()
        return c

    def gauge(self, name: str, **labels) -> Gauge:
        key = (name, _labels_of(labels))
        g = self._gauges.get(key)
        if g is None:
            g = self._gauges[key] = Gauge()
        return g

    def histogram(self, name: str, buckets: Optional[Iterable[float]] = None,
                  **labels) -> Histogram:
        key = (name, _labels_of(labels))
        h = self._histograms.get(key)
        if h is None:
            h = self._histograms[key] = Histogram(
                DEFAULT_LATENCY_BUCKETS if buckets is None else buckets)
        return h

    def timer(self, name: str, buckets: Optional[Iterable[float]] = None,
              **labels) -> Timer:
        return Timer(self.histogram(name, buckets, **labels))

    # ------------------------------------------------------- snapshots
    def snapshot(self) -> Dict[str, Dict[str, Any]]:
        """Deterministic (key-sorted) JSON-safe snapshot of every
        instrument; equal histories give equal snapshots."""
        counters = {flatten_key(*k): c.value
                    for k, c in self._counters.items()}
        gauges = {flatten_key(*k): {"value": g.value, "max": g.max,
                                    "min": g.min}
                  for k, g in self._gauges.items()}
        hists = {flatten_key(*k): {
            "bounds": list(h.bounds), "counts": list(h.counts),
            "sum": h.sum, "count": h.count, "min": h.min, "max": h.max}
            for k, h in self._histograms.items()}
        return {"counters": dict(sorted(counters.items())),
                "gauges": dict(sorted(gauges.items())),
                "histograms": dict(sorted(hists.items()))}

    def to_json(self, **kw) -> str:
        return json.dumps(self.snapshot(), sort_keys=True, **kw)

    @classmethod
    def from_snapshot(cls, snap: Mapping[str, Any]) -> "MetricsRegistry":
        reg = cls()
        reg.merge(snap)
        return reg

    @classmethod
    def from_json(cls, s: str) -> "MetricsRegistry":
        return cls.from_snapshot(json.loads(s))

    def merge(self, other) -> "MetricsRegistry":
        """Fold another registry (or snapshot dict) into this one —
        counters add, gauges max, histogram buckets add elementwise
        (same-name histograms must share bounds).  Returns self."""
        snap = other.snapshot() if hasattr(other, "snapshot") else other
        for key, v in snap.get("counters", {}).items():
            name, labels = parse_key(key)
            self._counters.setdefault((name, labels), Counter()).value += v
        for key, gv in snap.get("gauges", {}).items():
            name, labels = parse_key(key)
            g = self._gauges.setdefault((name, labels), Gauge())
            g.value = max(g.value, gv["value"]) if g.max is not None \
                else gv["value"]
            for attr, pick in (("max", max), ("min", min)):
                mine, theirs = getattr(g, attr), gv.get(attr)
                if theirs is not None:
                    setattr(g, attr,
                            theirs if mine is None else pick(mine, theirs))
        for key, hv in snap.get("histograms", {}).items():
            name, labels = parse_key(key)
            hkey = (name, labels)
            h = self._histograms.get(hkey)
            if h is None:
                h = self._histograms[hkey] = Histogram(hv["bounds"])
            if list(h.bounds) != list(hv["bounds"]):
                raise ValueError(
                    f"cannot merge histogram {key!r}: bounds differ "
                    f"({list(h.bounds)} vs {list(hv['bounds'])})")
            for i, c in enumerate(hv["counts"]):
                h.counts[i] += c
            h.sum += hv["sum"]
            h.count += hv["count"]
            for attr, pick in (("max", max), ("min", min)):
                mine, theirs = getattr(h, attr), hv.get(attr)
                if theirs is not None:
                    setattr(h, attr,
                            theirs if mine is None else pick(mine, theirs))
        return self

    # --------------------------------------------------------- export
    def to_prometheus(self) -> str:
        from .export import to_prometheus
        return to_prometheus(self)

    def __repr__(self) -> str:
        return (f"MetricsRegistry({len(self._counters)} counters, "
                f"{len(self._gauges)} gauges, "
                f"{len(self._histograms)} histograms)")


def merge_snapshots(*snaps: Mapping[str, Any]) -> Dict[str, Any]:
    """Pure merge of snapshot dicts (associative and commutative —
    property-tested in tests/test_obs_properties.py)."""
    reg = MetricsRegistry()
    for s in snaps:
        reg.merge(s)
    return reg.snapshot()


# ------------------------------------------------------ global registry
# Module-shaped layers (fastsim, stepsim) report through this hook; it
# defaults to NULL_METRICS so uninstrumented runs record nothing and the
# guard is one `enabled` test.
_GLOBAL = NULL_METRICS


def get_global_metrics():
    return _GLOBAL


def set_global_metrics(registry) -> Any:
    """Install ``registry`` (a MetricsRegistry or NULL_METRICS) as the
    process-global sink; returns the previous one for restoration."""
    global _GLOBAL
    prev = _GLOBAL
    _GLOBAL = registry if registry is not None else NULL_METRICS
    return prev


@contextlib.contextmanager
def global_metrics(registry):
    """Scoped ``set_global_metrics`` (restores the previous sink)."""
    prev = set_global_metrics(registry)
    try:
        yield registry
    finally:
        set_global_metrics(prev)
