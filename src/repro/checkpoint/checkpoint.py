"""Sharded checkpointing with async save and elastic restore.

Layout:  <dir>/step_<n>/
            manifest.json        — treedef paths, shapes, dtypes
            arrays.npz           — one entry per flattened leaf path

Design points for the 1000+-node story (DESIGN.md §4):
  * save is pure-host (device_get) + a background thread — the train loop
    only blocks on the *previous* save (double-buffering);
  * restore takes an optional (mesh, shardings) and device_puts each leaf
    with the *new* sharding — restoring onto a different mesh shape
    (elastic resize) is the same code path;
  * atomicity via write-to-tmp + rename; `latest_step` only sees complete
    checkpoints;
  * keep_last_k garbage collection.

On a real multi-host pod each host writes its local shards; this container
is single-process so leaves are materialized whole — the manifest format
already carries per-leaf sharding specs for the multi-host extension.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
from pathlib import Path
from typing import Any, Optional

import jax
import numpy as np


def _flatten(tree):
    leaves, treedef = jax.tree_util.tree_flatten_with_path(tree)
    out = {}
    for path, leaf in leaves:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        out[key] = leaf
    return out, treedef


def save_checkpoint(ckpt_dir, step: int, state, *, keep_last: int = 3):
    ckpt_dir = Path(ckpt_dir)
    final = ckpt_dir / f"step_{step}"
    tmp = ckpt_dir / f".tmp_step_{step}"
    if tmp.exists():
        shutil.rmtree(tmp)
    tmp.mkdir(parents=True)
    flat, _ = _flatten(state)
    arrays = {}
    manifest = {"step": step, "leaves": {}}
    for key, leaf in flat.items():
        arr = np.asarray(jax.device_get(leaf))
        arrays[key] = arr
        manifest["leaves"][key] = {"shape": list(arr.shape),
                                   "dtype": str(arr.dtype)}
    np.savez(tmp / "arrays.npz", **arrays)
    (tmp / "manifest.json").write_text(json.dumps(manifest))
    if final.exists():
        shutil.rmtree(final)
    os.replace(tmp, final)
    _gc(ckpt_dir, keep_last)
    return final


def _gc(ckpt_dir: Path, keep_last: int):
    steps = sorted(int(p.name.split("_")[1])
                   for p in ckpt_dir.glob("step_*"))
    for s in steps[:-keep_last] if keep_last > 0 else []:
        shutil.rmtree(ckpt_dir / f"step_{s}", ignore_errors=True)


def latest_step(ckpt_dir) -> Optional[int]:
    ckpt_dir = Path(ckpt_dir)
    if not ckpt_dir.exists():
        return None
    steps = [int(p.name.split("_")[1]) for p in ckpt_dir.glob("step_*")
             if (p / "manifest.json").exists()]
    return max(steps) if steps else None


def restore_checkpoint(ckpt_dir, step: int, target_state, *,
                       shardings=None):
    """Restore into the structure of ``target_state``.  If ``shardings``
    (a matching tree of jax.sharding.Sharding) is given, each leaf is
    device_put with it — this is the elastic-resize path: the new mesh's
    shardings re-partition the restored full arrays."""
    path = Path(ckpt_dir) / f"step_{step}"
    data = np.load(path / "arrays.npz")
    flat_t, treedef = _flatten(target_state)
    sh_flat = None
    if shardings is not None:
        sh_map, _ = _flatten(shardings)
        sh_flat = sh_map
    out = {}
    for key, tgt in flat_t.items():
        arr = data[key]
        if tuple(arr.shape) != tuple(np.shape(tgt)):
            raise ValueError(f"shape mismatch for {key}: ckpt {arr.shape} "
                             f"vs target {np.shape(tgt)}")
        if sh_flat is not None and key in sh_flat:
            out[key] = jax.device_put(arr, sh_flat[key])
        else:
            out[key] = jax.device_put(arr.astype(arr.dtype))
    leaves = [out[k] for k in flat_t.keys()]
    return jax.tree_util.tree_unflatten(treedef, leaves)


class AsyncCheckpointer:
    """Double-buffered background saver: `save` returns immediately; the
    next `save`/`wait` blocks until the previous write finished."""

    def __init__(self, ckpt_dir, keep_last: int = 3):
        self.ckpt_dir = Path(ckpt_dir)
        self.keep_last = keep_last
        self._thread: Optional[threading.Thread] = None
        self._error: Optional[BaseException] = None

    def save(self, step: int, state):
        self.wait()
        host_state = jax.tree.map(lambda x: np.asarray(jax.device_get(x)),
                                  state)

        def work():
            try:
                save_checkpoint(self.ckpt_dir, step, host_state,
                                keep_last=self.keep_last)
            except BaseException as e:   # surfaced on next wait()
                self._error = e
        self._thread = threading.Thread(target=work, daemon=True)
        self._thread.start()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._error is not None:
            err, self._error = self._error, None
            raise err
