"""TOP500 list rows: versioned schema + a tolerant CSV/TSV parser.

The TOP500 site exports lists as CSV (older lists as TSV / Excel dumps)
whose headers drift across editions — "Rmax" vs "Rmax [TFlop/s]",
"Computer" vs "System Name", "Total Cores" vs "Cores".  This module
normalizes all of that into one frozen ``Top500Row`` with an explicit
``schema_version`` so downstream inference can evolve without silently
reinterpreting old dumps.

Only the columns the prediction pipeline consumes are modeled; anything
else in the file is ignored.  Numbers may carry thousands separators
("2,414,592") — TOP500 exports do.
"""
from __future__ import annotations

import csv
import dataclasses
import io
import os
import re
from typing import Dict, List, Optional, Tuple, Union

ROW_SCHEMA_VERSION = 1

# normalized header (lowercased, alphanumerics only) -> field name;
# every alias observed across list editions maps to one schema field.
_HEADER_ALIASES: Dict[str, str] = {
    "rank": "rank",
    "site": "site",
    "system": "system",
    "systemname": "system",
    "name": "system",
    "computer": "system",
    "country": "country",
    "year": "year",
    "totalcores": "cores",
    "cores": "cores",
    "acceleratorcoprocessorcores": "accel_cores",
    "acceleratorcores": "accel_cores",
    "coprocessorcores": "accel_cores",
    "rmaxtflops": "rmax_tflops",
    "rmax": "rmax_tflops",
    "rmaxgflops": "rmax_gflops",          # pre-2022 lists are in GFlop/s
    "rpeaktflops": "rpeak_tflops",
    "rpeak": "rpeak_tflops",
    "rpeakgflops": "rpeak_gflops",
    "powerkw": "power_kw",
    "power": "power_kw",
    "processor": "processor",
    "processortechnology": "processor",
    "acceleratorcoprocessor": "accelerator",
    "accelerator": "accelerator",
    "interconnect": "interconnect",
    "interconnectfamily": "interconnect",
    "nmax": "nmax",
    "nhalf": "nhalf",
}

_REQUIRED = ("rank", "processor", "cores", "interconnect",
             "rmax_tflops", "rpeak_tflops")


@dataclasses.dataclass(frozen=True)
class Top500Row:
    """One list entry, normalized.  ``schema_version`` stamps the layout
    this row was parsed under (see ``ROW_SCHEMA_VERSION``)."""
    rank: int
    site: str
    system: str
    processor: str               # e.g. "Xeon Platinum 8280 28C 2.7GHz"
    cores: int                   # total cores as listed (CPU + accel)
    interconnect: str            # e.g. "Mellanox InfiniBand HDR"
    rmax_tflops: float
    rpeak_tflops: float
    accel_cores: int = 0         # accelerator/co-processor cores subset
    accelerator: str = ""        # e.g. "NVIDIA Tesla V100"
    country: str = ""
    year: int = 0
    power_kw: float = 0.0
    nmax: int = 0                # published HPL Nmax when the list has it
    schema_version: int = ROW_SCHEMA_VERSION

    @property
    def cpu_cores(self) -> int:
        """Host-CPU cores: listed total minus the accelerator subset."""
        return max(self.cores - self.accel_cores, 0)

    @property
    def efficiency(self) -> float:
        """Published HPL efficiency Rmax / Rpeak."""
        return self.rmax_tflops / self.rpeak_tflops


@dataclasses.dataclass
class ParseReport:
    """What ``parse_top500`` accepted and what it skipped (lenient mode)."""
    rows: List[Top500Row]
    skipped: List[Tuple[int, str]]   # (1-based data line, reason)


def _norm_header(h: str) -> str:
    return re.sub(r"[^a-z0-9]", "", h.lower())


def _num(text: str) -> float:
    return float(text.replace(",", "").replace(" ", "") or 0)


def _sniff_delimiter(header_line: str) -> str:
    return "\t" if header_line.count("\t") >= header_line.count(",") \
        and "\t" in header_line else ","


def _row_from_record(rec: Dict[str, str]) -> Top500Row:
    missing = [f for f in _REQUIRED if f not in rec
               and not (f == "rmax_tflops" and "rmax_gflops" in rec)
               and not (f == "rpeak_tflops" and "rpeak_gflops" in rec)]
    if missing:
        raise ValueError(f"missing required column(s): {', '.join(missing)}")
    rmax = (_num(rec["rmax_tflops"]) if "rmax_tflops" in rec
            else _num(rec["rmax_gflops"]) / 1e3)
    rpeak = (_num(rec["rpeak_tflops"]) if "rpeak_tflops" in rec
             else _num(rec["rpeak_gflops"]) / 1e3)
    if rmax <= 0 or rpeak <= 0:
        raise ValueError(f"non-positive Rmax/Rpeak ({rmax}, {rpeak})")
    cores = int(_num(rec["cores"]))
    if cores <= 0:
        raise ValueError(f"non-positive core count {cores}")
    if not rec["processor"].strip():
        raise ValueError("empty processor cell")
    if not rec["interconnect"].strip():
        raise ValueError("empty interconnect cell")
    return Top500Row(
        rank=int(_num(rec["rank"])),
        site=rec.get("site", "").strip(),
        system=rec.get("system", "").strip(),
        processor=rec["processor"].strip(),
        cores=cores,
        interconnect=rec["interconnect"].strip(),
        rmax_tflops=rmax,
        rpeak_tflops=rpeak,
        accel_cores=int(_num(rec.get("accel_cores", "0") or "0")),
        accelerator=rec.get("accelerator", "").strip(),
        country=rec.get("country", "").strip(),
        year=int(_num(rec.get("year", "0") or "0")),
        power_kw=_num(rec.get("power_kw", "0") or "0"),
        nmax=int(_num(rec.get("nmax", "0") or "0")))


def parse_top500(source: Union[str, os.PathLike], *,
                 strict: bool = False) -> ParseReport:
    """Parse a TOP500 list export (CSV or TSV) into ``Top500Row``s.

    ``source`` is a path, or the raw text itself when it contains a
    newline.  Headers are normalized through the alias table; the
    delimiter is sniffed from the header line.  In lenient mode
    (default) malformed data rows are collected into ``report.skipped``
    with a reason; ``strict=True`` raises on the first bad row.  A
    missing *required column* in the header always raises.
    """
    text = str(source)
    if "\n" not in text:
        with open(source, "r", encoding="utf-8") as fh:
            text = fh.read()
    lines = text.lstrip("﻿").splitlines()
    if not lines:
        raise ValueError("parse_top500: empty input")
    delim = _sniff_delimiter(lines[0])
    reader = csv.reader(io.StringIO(text.lstrip("﻿")), delimiter=delim)
    try:
        raw_header = next(reader)
    except StopIteration:
        raise ValueError("parse_top500: empty input") from None
    fields: List[Optional[str]] = [
        _HEADER_ALIASES.get(_norm_header(h)) for h in raw_header]
    present = {f for f in fields if f}
    missing = [f for f in _REQUIRED if f not in present
               and not (f == "rmax_tflops" and "rmax_gflops" in present)
               and not (f == "rpeak_tflops" and "rpeak_gflops" in present)]
    if missing:
        raise ValueError("parse_top500: header lacks required column(s): "
                         f"{', '.join(missing)} (saw: {raw_header})")

    rows: List[Top500Row] = []
    skipped: List[Tuple[int, str]] = []
    for lineno, cells in enumerate(reader, start=1):
        if not any(c.strip() for c in cells):
            continue
        rec = {f: c for f, c in zip(fields, cells) if f}
        try:
            rows.append(_row_from_record(rec))
        except (ValueError, KeyError) as exc:
            if strict:
                raise ValueError(
                    f"parse_top500: data row {lineno}: {exc}") from exc
            skipped.append((lineno, str(exc)))
    return ParseReport(rows=rows, skipped=skipped)


#: vendored sample list editions, oldest first (the edition-drift
#: studies in repro.campaign compare any pair of these)
SAMPLE_EDITIONS: Tuple[str, ...] = ("2020_06", "2020_11")


def list_sample_editions() -> List[str]:
    return list(SAMPLE_EDITIONS)


def sample_list_path(edition: str = "2020_06") -> str:
    """Path of a vendored ~40-50-row sample list edition (default: the
    June-2020-era list the original fleet demo used)."""
    if edition not in SAMPLE_EDITIONS:
        import difflib
        close = difflib.get_close_matches(edition, SAMPLE_EDITIONS, n=3,
                                          cutoff=0.5)
        hint = (f"did you mean: {', '.join(close)}?" if close
                else f"vendored: {', '.join(SAMPLE_EDITIONS)}")
        raise ValueError(f"unknown sample edition {edition!r}; {hint}")
    return os.path.join(os.path.dirname(__file__), "data",
                        f"top500_sample_{edition}.csv")


def load_sample(strict: bool = True,
                edition: str = "2020_06") -> List[Top500Row]:
    """A vendored sample list, parsed strictly (it must be clean)."""
    return parse_top500(sample_list_path(edition), strict=strict).rows
