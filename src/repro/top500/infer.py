"""Spec inference: TOP500 row strings -> Platform specs, with provenance.

The paper hand-derives each machine's node model (sustained AVX clock,
flops/cycle, memory bandwidth) from its processor SKU and its fabric
from the interconnect product name.  This module systematizes exactly
that derivation so it runs over a whole list:

  * ``CPU_FAMILIES`` — ordered regex rules over the processor string.
    Each rule carries the ISA's DP flops/cycle, the sustained-clock
    fraction under full-width vector load (the paper's 1.8-vs-2.7 GHz
    Frontera observation, generalized), sockets per node, and per-core
    memory bandwidth/capacity.  Core count and nominal clock are parsed
    from the string itself ("28C 2.7GHz").
  * ``FABRIC_FAMILIES`` — regex rules over the interconnect string that
    pick the fabric *kind* (EDR/HDR/OPA -> fat-tree, Aries/Slingshot ->
    dragonfly, Tofu/BlueGene -> torus) and its bandwidth class; geometry
    (switch radix, group size, torus dims) is then sized to the node
    count.

Every heuristic decision is recorded in the generated ``Platform``'s
``provenance`` table — which rule fired, where the peak came from,
whether Rpeak reconciliation rescaled it — and every rule is
overridable per call (``cpu_families=``/``fabric_families=`` replace
the tables; ``overrides=`` pins spec fields directly).

Rpeak reconciliation: the list's Rpeak is authoritative (it *is*
cores x nominal clock x flops/cycle).  If the rule-derived nominal
system peak disagrees with Rpeak by more than ``rpeak_tolerance``
(wrong flops/cycle guess, unlisted accelerator), the node's nominal
peak is rescaled to Rpeak / n_nodes, and for accelerated rows the
excess over the CPU part is attributed to the accelerator section.
"""
from __future__ import annotations

import dataclasses
import math
import re
from typing import Dict, Iterable, List, Optional, Sequence, Tuple

from repro.platforms.spec import (FabricSpec, MPIStackSpec, NodeSpec,
                                  Platform, ScaleSpec)

from .rows import Top500Row


# ------------------------------------------------------------ CPU rules

@dataclasses.dataclass(frozen=True)
class CPUFamilyRule:
    """One processor family: matched against the row's processor string
    (first match wins; order the table accordingly)."""
    name: str
    pattern: str                 # case-insensitive regex
    flops_per_cycle: int         # DP FMA width per core
    sustained_frac: float        # sustained / nominal clock under vectors
    sockets_per_node: int
    mem_bw_core_gbs: float       # per-core sustained stream bandwidth
    mem_core_gb: float           # per-core memory capacity
    default_cores: int = 0       # per-socket fallback if "NNC" is absent
    default_ghz: float = 0.0     # fallback if "X.XGHz" is absent

    def matches(self, processor: str) -> bool:
        return re.search(self.pattern, processor, re.IGNORECASE) is not None


CPU_FAMILIES: Tuple[CPUFamilyRule, ...] = (
    CPUFamilyRule("a64fx", r"\bA64FX\b", 32, 0.95, 1, 21.3, 0.67, 48, 2.2),
    CPUFamilyRule("xeon-phi", r"Xeon Phi|\b72[0-9]{2}[PF]?\b.*Knights",
                  32, 0.55, 1, 6.0, 1.6, 68, 1.4),
    CPUFamilyRule("xeon-avx512",
                  r"Xeon (Platinum|Gold|Silver|Bronze|W-\d)|Xeon.*84\d\dC?",
                  32, 0.70, 2, 4.5, 3.5, 24, 2.4),
    CPUFamilyRule("xeon-avx2", r"E5-\d{4}\s?v[34]\b|E7-\d{4}\s?v[34]\b",
                  16, 0.85, 2, 4.5, 4.5, 14, 2.4),
    CPUFamilyRule("xeon-avx", r"E5-\d{4}(\s?v2)?\b|X56\d\d|E7-\d{4}",
                  8, 0.90, 2, 4.0, 4.0, 12, 2.6),
    CPUFamilyRule("epyc", r"\bEPYC\b", 16, 0.85, 2, 3.4, 4.0, 64, 2.25),
    CPUFamilyRule("power9", r"POWER9", 8, 0.95, 2, 7.0, 8.0, 22, 3.0),
    CPUFamilyRule("bgq", r"Power BQC|BQC 16C", 8, 0.95, 1, 2.7, 1.0,
                  16, 1.6),
    CPUFamilyRule("sparc64", r"SPARC64", 8, 0.95, 1, 8.0, 2.0, 8, 2.0),
    CPUFamilyRule("sw26010", r"SW26010|Sunway", 8, 0.95, 1, 0.52, 0.125,
                  260, 1.45),
    # catch-all keeps the pipeline total (provenance marks the guess)
    CPUFamilyRule("generic-x86", r".", 16, 0.80, 2, 4.0, 3.0, 16, 2.5),
)

# accelerator product -> DP peak per device (FLOP/s); used only to tag
# the accelerator section after Rpeak reconciliation.
ACCEL_PEAKS: Tuple[Tuple[str, float], ...] = (
    (r"A100", 9.7e12),
    (r"V100", 7.8e12),
    (r"P100", 4.7e12),
    (r"K\d0x?\b", 1.4e12),
    (r"MI\d+", 6.6e12),
    (r"Matrix-2000", 2.4e12),
)

_CORES_RE = re.compile(r"(\d+)\s*C\b", re.IGNORECASE)
_GHZ_RE = re.compile(r"([\d.]+)\s*GHz", re.IGNORECASE)


# --------------------------------------------------------- fabric rules

@dataclasses.dataclass(frozen=True)
class FabricFamilyRule:
    """One interconnect family: kind + bandwidth class; geometry is sized
    per machine by ``_size_fabric``.  ``family`` is the residual-
    calibration grouping key (see top500/calibrate.py)."""
    name: str
    pattern: str
    kind: str                    # fat-tree | dragonfly | torus
    family: str                  # calibration group
    link_bw: float               # per-node injection B/s
    hop_latency: float = 90e-9
    nonminimal: bool = False

    def matches(self, interconnect: str) -> bool:
        return re.search(self.pattern, interconnect,
                         re.IGNORECASE) is not None


FABRIC_FAMILIES: Tuple[FabricFamilyRule, ...] = (
    FabricFamilyRule("ib-hdr", r"\bHDR\b", "fat-tree", "infiniband",
                     200e9 / 8),
    FabricFamilyRule("ib-edr", r"\bEDR\b", "fat-tree", "infiniband",
                     100e9 / 8),
    FabricFamilyRule("ib-fdr", r"\bFDR\b", "fat-tree", "infiniband",
                     56e9 / 8),
    FabricFamilyRule("ib-qdr", r"\bQDR\b", "fat-tree", "infiniband",
                     40e9 / 8),
    FabricFamilyRule("omnipath", r"Omni[- ]?Path|\bOPA\b", "fat-tree",
                     "omnipath", 100e9 / 8),
    FabricFamilyRule("aries", r"\bAries\b", "dragonfly", "aries", 14.6e9,
                     100e-9),
    FabricFamilyRule("slingshot", r"Slingshot", "dragonfly", "slingshot",
                     25e9, 100e-9, nonminimal=True),
    FabricFamilyRule("tofu", r"\bTofu\b", "torus", "tofu", 6.8e9, 200e-9),
    FabricFamilyRule("bluegene", r"BlueGene|Blue Gene|5D Torus", "torus",
                     "bluegene", 2e9, 80e-9),
    FabricFamilyRule("th-express", r"TH Express", "fat-tree", "custom",
                     14e9),
    FabricFamilyRule("sunway-net", r"Sunway", "fat-tree", "custom", 14e9),
    FabricFamilyRule("bxi", r"\bBXI\b", "fat-tree", "custom", 100e9 / 8),
    FabricFamilyRule("eth-100g", r"100G\b.*Ethernet|Ethernet.*100G",
                     "fat-tree", "ethernet", 100e9 / 8),
    FabricFamilyRule("eth-25g", r"25G\b.*Ethernet|Ethernet.*25G",
                     "fat-tree", "ethernet", 25e9 / 8),
    FabricFamilyRule("eth-10g", r"10G\b.*Ethernet|Ethernet.*10G",
                     "fat-tree", "ethernet", 10e9 / 8),
    # generic InfiniBand (no speed grade listed) -> EDR-class
    FabricFamilyRule("ib-generic", r"Infini[Bb]and|Mellanox", "fat-tree",
                     "infiniband", 100e9 / 8),
    FabricFamilyRule("eth-generic", r"Ethernet", "fat-tree", "ethernet",
                     25e9 / 8),
    # catch-all: treat unknown/custom networks as a 100 Gb fat-tree
    FabricFamilyRule("unknown", r".", "fat-tree", "custom", 100e9 / 8),
)


def _size_fabric(rule: FabricFamilyRule, n_nodes: int) -> FabricSpec:
    """Fill in geometry for the machine's node count.  Shapes are
    conventional for the family, not per-machine wiring diagrams — the
    provenance table records which rule sized them."""
    if rule.kind == "fat-tree":
        nodes_per_edge = 32 if n_nodes >= 32 else max(n_nodes, 1)
        n_edge = (n_nodes + nodes_per_edge - 1) // nodes_per_edge
        n_core = max(2, min(16, (n_edge + 1) // 2))
        return FabricSpec(kind="fat-tree", link_bw=rule.link_bw,
                          hop_latency=rule.hop_latency,
                          nodes_per_edge=nodes_per_edge, n_core=n_core,
                          uplink_bw=2.0 * rule.link_bw)
    if rule.kind == "dragonfly":
        routers_per_group, nodes_per_router = 16, 16
        group = routers_per_group * nodes_per_router
        n_groups = max(2, (n_nodes + group - 1) // group)
        return FabricSpec(kind="dragonfly", link_bw=rule.link_bw,
                          hop_latency=rule.hop_latency,
                          n_groups=n_groups,
                          routers_per_group=routers_per_group,
                          nodes_per_router=nodes_per_router,
                          global_bw=rule.link_bw * 1.3,
                          nonminimal=rule.nonminimal)
    if rule.kind == "torus":
        return FabricSpec(kind="torus", link_bw=rule.link_bw,
                          hop_latency=rule.hop_latency,
                          dims=_torus_dims(n_nodes))
    raise ValueError(f"fabric rule {rule.name!r}: unknown kind "
                     f"{rule.kind!r}")


def _torus_dims(n_nodes: int, ndims: int = 3) -> Tuple[int, ...]:
    """Near-cubic power-of-two dims with product >= n_nodes."""
    total_log = max(int(math.ceil(math.log2(max(n_nodes, 1)))), ndims)
    base, extra = divmod(total_log, ndims)
    return tuple(2 ** (base + (1 if i < extra else 0))
                 for i in range(ndims))


# ------------------------------------------------------------ inference

def _slug(text: str, fallback: str) -> str:
    s = re.sub(r"[^\w.-]+", "-", text.strip(), flags=re.UNICODE).strip("-")
    return (s or fallback).lower()


def _near_square_grid(n_ranks: int) -> Tuple[int, int]:
    """(P, Q) with P*Q == n_ranks, P <= Q, as square as divisors allow."""
    best = (1, n_ranks)
    for p in range(int(math.isqrt(n_ranks)), 0, -1):
        if n_ranks % p == 0:
            best = (p, n_ranks // p)
            break
    return best


def memory_sized_n(n_nodes: int, hbm_bytes: float, nb: int,
                   mem_fraction: float = 0.75) -> int:
    """Largest nb-multiple N with 8*N^2 <= mem_fraction of fleet memory —
    the standard HPL problem-sizing rule."""
    n = math.sqrt(mem_fraction * n_nodes * hbm_bytes / 8.0)
    return max(int(n) // nb * nb, nb)


def infer_platform(row: Top500Row, *,
                   cpu_families: Sequence[CPUFamilyRule] = CPU_FAMILIES,
                   fabric_families: Sequence[FabricFamilyRule]
                   = FABRIC_FAMILIES,
                   overrides: Optional[Dict[str, object]] = None,
                   rpeak_tolerance: float = 0.30,
                   mem_fraction: float = 0.75,
                   default_nb: int = 256) -> Platform:
    """One list row -> one ``Platform`` with a full provenance record.

    ``overrides`` pins inferred scalar knobs by name before the spec is
    assembled: ``cores_per_node``, ``n_nodes``, ``node_peak_flops``,
    ``mem_bw``, ``hbm_bytes``, ``nb``.  Every override fires a
    provenance entry so a tuned spec still explains itself.
    """
    ov = dict(overrides or {})
    prov: List[Tuple[str, str]] = [
        ("source", f"top500 rank {row.rank} schema v{row.schema_version}"),
    ]

    cpu = next((r for r in cpu_families if r.matches(row.processor)),
               None)
    if cpu is None:
        raise ValueError(f"infer_platform: no CPU family rule matches "
                         f"processor {row.processor!r} (row rank "
                         f"{row.rank}); add a catch-all rule")
    prov.append(("cpu_family", cpu.name))

    m = _CORES_RE.search(row.processor)
    cores_per_socket = int(m.group(1)) if m else cpu.default_cores
    if not m:
        prov.append(("cores_per_socket", f"fallback {cores_per_socket}"))
    m = _GHZ_RE.search(row.processor)
    ghz = float(m.group(1)) if m else cpu.default_ghz
    if not m:
        prov.append(("clock_ghz", f"fallback {ghz}"))

    cores_per_node = int(ov.get("cores_per_node",
                                cpu.sockets_per_node * cores_per_socket))
    if "cores_per_node" in ov:
        prov.append(("cores_per_node", f"override {cores_per_node}"))
    n_nodes = int(ov.get("n_nodes",
                         max(row.cpu_cores // max(cores_per_node, 1), 1)))
    prov.append(("n_nodes",
                 f"override {n_nodes}" if "n_nodes" in ov else
                 f"{row.cpu_cores} cpu cores / {cores_per_node} per node"))

    # nominal node peak from the rule; reconcile against the listed Rpeak
    nominal_core = cpu.flops_per_cycle * ghz * 1e9
    nominal_node = nominal_core * cores_per_node
    rpeak_node = row.rpeak_tflops * 1e12 / n_nodes
    accelerated = row.accel_cores > 0 or bool(row.accelerator)
    if "node_peak_flops" in ov:
        nominal_node = float(ov["node_peak_flops"])
        prov.append(("peak_source", "override"))
    elif accelerated or abs(nominal_node - rpeak_node) \
            > rpeak_tolerance * rpeak_node:
        prov.append(("peak_source",
                     f"rpeak-rescaled (heuristic {nominal_node:.3e} vs "
                     f"rpeak/node {rpeak_node:.3e})"))
        nominal_node = rpeak_node
    else:
        prov.append(("peak_source", "processor-heuristic"))
    accel_node = max(nominal_node - nominal_core * cores_per_node, 0.0) \
        if accelerated else 0.0
    if accelerated:
        prov.append(("accelerator", row.accelerator or "unlisted"))
        for pat, dev_peak in ACCEL_PEAKS:
            if re.search(pat, row.accelerator or row.processor,
                         re.IGNORECASE):
                prov.append(("accel_device_peak", f"{dev_peak:.2e}"))
                break

    # the paper's sustained-clock derate applies to the whole node peak;
    # accelerator-resident HPL doesn't see the host's vector downclock,
    # so accelerated nodes get a milder, GPU-boost-style derate
    sustained = 0.90 if accelerated else cpu.sustained_frac
    peak_flops = nominal_node * sustained
    prov.append(("sustained_frac", f"{sustained}"))

    mem_bw = float(ov.get("mem_bw",
                          cpu.mem_bw_core_gbs * 1e9 * cores_per_node))
    hbm = float(ov.get("hbm_bytes",
                       cpu.mem_core_gb * 1e9 * cores_per_node))
    if accelerated:                  # HBM-resident HPL on the accelerator
        # HBM machines run ~0.1 B/flop (V100: 900 GB/s against 7.8 TF)
        mem_bw = max(mem_bw, 0.1 * accel_node)
        prov.append(("mem_model", "accel-hbm-floor"))

    node = NodeSpec(name=f"{cpu.name}-{cores_per_node}c",
                    peak_flops=peak_flops, mem_bw=mem_bw,
                    cores=cores_per_node,
                    gemm_efficiency=0.92, mem_efficiency=0.80,
                    blas_latency=2e-6 if accelerated else 2e-7,
                    hbm_bytes=hbm,
                    accel_peak_flops=accel_node * sustained,
                    accel_mem_bw=mem_bw if accelerated else 0.0)

    fab_rule = next((r for r in fabric_families
                     if r.matches(row.interconnect)), None)
    if fab_rule is None:
        raise ValueError(f"infer_platform: no fabric family rule "
                         f"matches interconnect {row.interconnect!r} "
                         f"(row rank {row.rank}); add a catch-all rule")
    prov.append(("fabric_family", fab_rule.name))
    prov.append(("fabric_group", fab_rule.family))
    fabric = _size_fabric(fab_rule, n_nodes)
    prov.append(("fabric_geometry",
                 f"{fabric.kind} sized for {n_nodes} nodes"))

    nb = int(ov.get("nb", default_nb))
    grid = _near_square_grid(n_nodes)
    hpl_n = row.nmax or memory_sized_n(n_nodes, hbm, nb, mem_fraction)
    prov.append(("hpl_n", "published nmax" if row.nmax else
                 f"memory rule ({mem_fraction:.2f} fill)"))

    name = f"r{row.rank:03d}-{_slug(row.system or row.site, 'unnamed')}"
    return Platform(
        name=name, node=node, fabric=fabric,
        mpi=MPIStackSpec(net_latency=2e-6),
        scale=ScaleSpec(n_nodes=n_nodes, ranks_per_node=1, grid=grid,
                        hpl_n=hpl_n, hpl_nb=nb,
                        reported_tflops=row.rmax_tflops),
        provenance=tuple(prov),
        notes=f"Inferred from TOP500 row: {row.site} / {row.system} "
              f"({row.processor}; {row.interconnect})")


def infer_platforms(rows: Iterable[Top500Row], **kw) -> List[Platform]:
    return [infer_platform(row, **kw) for row in rows]


def fabric_group(platform: Platform) -> str:
    """The calibration grouping key recorded at inference time."""
    return platform.provenance_dict.get("fabric_group", "unknown")
