"""TOP500 ingestion: list rows -> Platform specs -> fleet prediction.

The pipeline the paper's Table II does by hand, run over a whole list:

    from repro.top500 import load_sample, predict_fleet
    report = predict_fleet(load_sample())
    for e in report.ranked()[:10]:
        print(e.platform.name, e.calibrated_tflops, e.published_tflops)

Stages (one module each):
  rows.py       versioned ``Top500Row`` schema + tolerant CSV/TSV parser
  infer.py      processor/interconnect strings -> ``Platform`` specs,
                with overridable heuristic tables and provenance records
  fleet.py      memory-rule auto-tuning + ONE forced-bucket batched
                sweep for the whole fleet (scale-proxied, one compile)
  calibrate.py  per-fabric-family residual factor, train/held-out split

Registry interop: ``bulk_register(infer_platforms(rows),
namespace="top500")`` exposes an ingested list to everything that
speaks platform names (serving, benchmarks) without touching built-ins.
"""
from .rows import (ROW_SCHEMA_VERSION, SAMPLE_EDITIONS, ParseReport,
                   Top500Row, list_sample_editions, load_sample,
                   parse_top500, sample_list_path)
from .infer import (ACCEL_PEAKS, CPU_FAMILIES, CPUFamilyRule,
                    FABRIC_FAMILIES, FabricFamilyRule, fabric_group,
                    infer_platform, infer_platforms, memory_sized_n)
from .fleet import (FleetEntry, FleetReport, FleetTuning, fleet_bucket,
                    predict_fleet, tune_scenario)
from .calibrate import (CalibrationResult, DESCalibration,
                        assign_splits, calibrate_against_des,
                        calibrate_fleet)

__all__ = [
    "ROW_SCHEMA_VERSION", "SAMPLE_EDITIONS", "ParseReport", "Top500Row",
    "list_sample_editions", "load_sample", "parse_top500",
    "sample_list_path",
    "ACCEL_PEAKS", "CPU_FAMILIES", "CPUFamilyRule", "FABRIC_FAMILIES",
    "FabricFamilyRule", "fabric_group", "infer_platform",
    "infer_platforms", "memory_sized_n",
    "FleetEntry", "FleetReport", "FleetTuning", "fleet_bucket",
    "predict_fleet", "tune_scenario",
    "CalibrationResult", "DESCalibration", "assign_splits",
    "calibrate_against_des", "calibrate_fleet",
]
