"""Residual calibration: fit the systematic sim-vs-published gap.

Cornebize & Legrand's central finding is that simulation predicts
*relative* behavior faithfully while absolute accuracy hinges on
calibration.  Heuristic-inferred fleets inherit a systematic per-fabric
bias (our fat-tree geometry is conventional, not the machine's wiring;
contention scales are uncalibrated), so we fit one multiplicative
efficiency factor per fabric family — median(published / predicted)
over a deterministic training split — and report error on the held-out
rest.  The median keeps single-machine outliers (odd published runs,
mis-parsed rows) from dragging the family factor.

Split rule (deterministic, stratified): entries are grouped by family
and sorted by published Rmax; even positions train, odd positions test.
A family with a single machine trains only (its factor would otherwise
be fit on nothing); families never seen in training fall back to the
global factor.
"""
from __future__ import annotations

import dataclasses
import statistics
from typing import Dict, List

GLOBAL = "__global__"


@dataclasses.dataclass
class CalibrationResult:
    factors: Dict[str, float]          # family -> efficiency factor
    train_median_abs_err: float
    heldout_median_abs_err: float
    n_train: int
    n_test: int

    def factor_for(self, family: str) -> float:
        return self.factors.get(family, self.factors[GLOBAL])

    def to_dict(self) -> Dict:
        d = dataclasses.asdict(self)
        held = d["heldout_median_abs_err"]
        if held != held:                    # NaN -> null (strict JSON)
            d["heldout_median_abs_err"] = None
        return d


def assign_splits(entries) -> None:
    """Stamp each entry's ``split`` in place (see module docstring).
    Entries without a published Rmax can't train or score — they keep
    ``split == ""`` and only receive the fitted factor."""
    by_family: Dict[str, List] = {}
    for e in entries:
        if e.published_tflops > 0:
            by_family.setdefault(e.family, []).append(e)
    for group in by_family.values():
        group.sort(key=lambda e: -e.published_tflops)
        for i, e in enumerate(group):
            e.split = "train" if (i % 2 == 0 or len(group) == 1) \
                else "test"


def calibrate_fleet(entries) -> CalibrationResult:
    """Fit family factors on the train split, apply to every entry, and
    measure held-out error.  Mutates ``entries`` (sets ``split`` and
    ``calibrated_tflops``) and returns the fit."""
    assign_splits(entries)
    train = [e for e in entries if e.split == "train"]
    if not train:
        raise ValueError("calibrate_fleet: no entries with a published "
                         "Rmax to train on")
    ratios: Dict[str, List[float]] = {}
    for e in train:
        if e.predicted_tflops > 0:
            ratios.setdefault(e.family, []).append(
                e.published_tflops / e.predicted_tflops)
    factors = {fam: statistics.median(rs) for fam, rs in ratios.items()}
    factors[GLOBAL] = statistics.median(
        [e.published_tflops / e.predicted_tflops
         for e in train if e.predicted_tflops > 0])
    for e in entries:
        e.calibrated_tflops = e.predicted_tflops * \
            factors.get(e.family, factors[GLOBAL])
    test = [e for e in entries if e.split == "test"]
    return CalibrationResult(
        factors=factors,
        train_median_abs_err=statistics.median(
            [abs(e.rel_err) for e in train]),
        heldout_median_abs_err=statistics.median(
            [abs(e.rel_err) for e in test]) if test else float("nan"),
        n_train=len(train), n_test=len(test))
