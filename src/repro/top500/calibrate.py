"""Calibration of inferred fleets: residual factors and DES bridging.

Two paths, both recorded in ``Platform.provenance`` so every spec says
which one produced its calibration:

  * ``calibrate_fleet`` — the scalar residual path: one multiplicative
    efficiency factor per fabric family, fit on published Rmax
    (provenance: ``("calibration", "family-factor")``);
  * ``calibrate_against_des`` — the simulation path from ROADMAP: run
    the DES->fastsim gradient bridge (``fit_fastsim_to_des``) on a
    small sample of inferred specs and share each family's fitted
    contention table family-wide (provenance:
    ``("calibration", "des-bridge:<donor>")``).

Residual calibration: fit the systematic sim-vs-published gap.

Cornebize & Legrand's central finding is that simulation predicts
*relative* behavior faithfully while absolute accuracy hinges on
calibration.  Heuristic-inferred fleets inherit a systematic per-fabric
bias (our fat-tree geometry is conventional, not the machine's wiring;
contention scales are uncalibrated), so we fit one multiplicative
efficiency factor per fabric family — median(published / predicted)
over a deterministic training split — and report error on the held-out
rest.  The median keeps single-machine outliers (odd published runs,
mis-parsed rows) from dragging the family factor.

Split rule (deterministic, stratified): entries are grouped by family
and sorted by published Rmax; even positions train, odd positions test.
A family with a single machine trains only (its factor would otherwise
be fit on nothing); families never seen in training fall back to the
global factor.
"""
from __future__ import annotations

import dataclasses
import statistics
from typing import Dict, List, Optional, Sequence, Tuple

GLOBAL = "__global__"
CALIBRATION_KEY = "calibration"      # provenance key both paths stamp


def _stamp_calibration(platform, how: str):
    """A copy of ``platform`` whose provenance records the calibration
    path (first writer wins — a spec calibrated by the DES bridge keeps
    that record through a later residual pass)."""
    if CALIBRATION_KEY in platform.provenance_dict:
        return platform
    return dataclasses.replace(
        platform,
        provenance=platform.provenance + ((CALIBRATION_KEY, how),))


@dataclasses.dataclass
class CalibrationResult:
    factors: Dict[str, float]          # family -> efficiency factor
    train_median_abs_err: float
    heldout_median_abs_err: float
    n_train: int
    n_test: int

    def factor_for(self, family: str) -> float:
        return self.factors.get(family, self.factors[GLOBAL])

    def to_dict(self) -> Dict:
        d = dataclasses.asdict(self)
        held = d["heldout_median_abs_err"]
        if held != held:                    # NaN -> null (strict JSON)
            d["heldout_median_abs_err"] = None
        return d


def assign_splits(entries) -> None:
    """Stamp each entry's ``split`` in place (see module docstring).
    Entries without a published Rmax can't train or score — they keep
    ``split == ""`` and only receive the fitted factor."""
    by_family: Dict[str, List] = {}
    for e in entries:
        if e.published_tflops > 0:
            by_family.setdefault(e.family, []).append(e)
    for group in by_family.values():
        group.sort(key=lambda e: -e.published_tflops)
        for i, e in enumerate(group):
            e.split = "train" if (i % 2 == 0 or len(group) == 1) \
                else "test"


def calibrate_fleet(entries) -> CalibrationResult:
    """Fit family factors on the train split, apply to every entry, and
    measure held-out error.  Mutates ``entries`` (sets ``split`` and
    ``calibrated_tflops``) and returns the fit."""
    assign_splits(entries)
    train = [e for e in entries if e.split == "train"]
    if not train:
        raise ValueError("calibrate_fleet: no entries with a published "
                         "Rmax to train on")
    ratios: Dict[str, List[float]] = {}
    for e in train:
        if e.predicted_tflops > 0:
            ratios.setdefault(e.family, []).append(
                e.published_tflops / e.predicted_tflops)
    factors = {fam: statistics.median(rs) for fam, rs in ratios.items()}
    factors[GLOBAL] = statistics.median(
        [e.published_tflops / e.predicted_tflops
         for e in train if e.predicted_tflops > 0])
    for e in entries:
        e.calibrated_tflops = e.predicted_tflops * \
            factors.get(e.family, factors[GLOBAL])
        e.platform = _stamp_calibration(e.platform, "family-factor")
    test = [e for e in entries if e.split == "test"]
    return CalibrationResult(
        factors=factors,
        train_median_abs_err=statistics.median(
            [abs(e.rel_err) for e in train]),
        heldout_median_abs_err=statistics.median(
            [abs(e.rel_err) for e in test]) if test else float("nan"),
        n_train=len(train), n_test=len(test))


# ------------------------------------------------------ DES bridging

@dataclasses.dataclass
class DESCalibration:
    """Output of ``calibrate_against_des``: the input specs with fitted
    contention tables baked in (input order) plus the audit trail — the
    *applied* table per family (the per-field median over its donors)
    and every donor's individual ``BridgeFit``."""
    platforms: List            # Platform, with calibration + provenance
    tables: Dict[str, Dict[str, float]]   # family -> applied calibration
    fits: Dict[str, List]      # family -> [(donor name, BridgeFit), ...]
    donors: Dict[str, str]     # family -> comma-joined donor names


def _probe_platform(platform, max_nodes: int):
    """A probe-scale copy of an inferred spec: same node model, link
    bandwidths and latencies (what the bridge fits), but geometry shrunk
    so the DES probes run in seconds even for a 100k-node machine.
    Probe configs use <= 16 ranks, so the shrink does not change which
    links a probe exercises — only how big an object we build."""
    from repro.platforms.spec import FabricSpec
    n = min(platform.scale.n_nodes, max_nodes)
    fab = platform.fabric
    kw: Dict = {}
    if fab.kind == "dragonfly":
        per = max(-(-n // 4), 1)
        kw = dict(n_groups=2, routers_per_group=2, nodes_per_router=per)
    elif fab.kind == "torus":
        side = max(2, round(n ** (1.0 / len(fab.dims))))
        dims = [side] * len(fab.dims)
        while _prod(dims) < n:
            dims[0] += 1
        kw = dict(dims=tuple(dims))
    elif fab.kind == "multipod":
        side = max(2, round((n // 2) ** (1.0 / max(len(fab.dims), 1))))
        dims = [side] * len(fab.dims)
        while _prod(dims) * 2 < n:
            dims[0] += 1
        kw = dict(dims=tuple(dims), n_pods=2)
    # fat-tree topologies size themselves from n_nodes; geometry stands
    shrunk = dataclasses.replace(fab, **kw) if kw else fab
    return dataclasses.replace(
        platform, fabric=shrunk,
        scale=dataclasses.replace(platform.scale, n_nodes=n),
        calibration=())


def _prod(xs) -> int:
    out = 1
    for x in xs:
        out *= int(x)
    return out


def calibrate_against_des(platforms: Sequence, *,
                          per_family: int = 1, max_probe_nodes: int = 64,
                          steps: int = 20, lr: float = 0.1,
                          probe_configs: Optional[Sequence] = None,
                          ) -> DESCalibration:
    """Bridge-calibrate an inferred fleet against the DES instead of the
    scalar family factor (the ROADMAP follow-up to PR 4).

    Per fabric family, the ``per_family`` smallest machines are probed:
    ``fit_fastsim_to_des`` runs small DES probes on a probe-scale copy
    of the spec and gradient-fits the fastsim contention scales
    (``bcast_bw_scale``, ``swap_bw_scale``).  The per-field median of
    the family's fits is applied to every member, and each spec's
    provenance records which path (and which donor machines) produced
    its calibration — ``("calibration", "des-bridge:<donors>")`` —
    versus ``("calibration", "family-factor")`` from
    ``calibrate_fleet``.  Smoke-sized by construction: probes are
    <= 16-rank DES runs and ``steps`` defaults low.
    """
    from repro.platforms.bridge import fit_fastsim_to_des
    from .infer import fabric_group

    platforms = list(platforms)
    if not platforms:
        raise ValueError("calibrate_against_des: no platforms")
    by_family: Dict[str, List] = {}
    for p in platforms:
        by_family.setdefault(fabric_group(p), []).append(p)

    fits: Dict[str, List] = {}
    donors: Dict[str, str] = {}
    tables: Dict[str, Dict[str, float]] = {}
    for family, group in sorted(by_family.items()):
        sample = sorted(group, key=lambda p: (p.scale.n_nodes, p.name))
        sample = sample[:max(per_family, 1)]
        fitted: List[Tuple[str, object]] = []
        for donor in sample:
            probe = _probe_platform(donor, max_probe_nodes)
            fitted.append((donor.name, fit_fastsim_to_des(
                probe, probe_configs=probe_configs, steps=steps, lr=lr)))
        donors[family] = ",".join(name for name, _ in fitted)
        fits[family] = fitted
        fields = fitted[0][1].fields
        tables[family] = {
            f: statistics.median([fit.calibration[f] for _, fit in fitted])
            for f in fields}

    out = []
    for p in platforms:
        family = fabric_group(p)
        cal = p.with_calibration(tables[family])
        out.append(_stamp_calibration(
            cal, f"des-bridge:{donors[family]}"))
    return DESCalibration(platforms=out, tables=tables, fits=fits,
                          donors=donors)
