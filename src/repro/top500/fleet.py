"""Fleet predictor: every inferred platform through ONE batched sweep.

The paper predicts machines one at a time (4.8 h of SystemC per
scenario); this module predicts a whole TOP500 list in a single
compiled program.  Per machine it auto-tunes an HPL run under the
standard memory-fraction rule, then feeds the entire fleet through
``fastsim.sweep_hpl(..., bucket=...)`` — one padded scenario axis, one
compile, regardless of how many geometries are mixed.

Scale proxying (the trick that makes a 150k-node machine simulable in
a shared bucket): HPL under the memory rule is *weak-scaled* — the
per-rank local matrix ``N / sqrt(P*Q) = sqrt(mem_fraction * hbm / 8)``
is independent of machine size — so a machine larger than ``max_ranks``
is simulated as a proxy grid of at most ``max_ranks`` ranks with the
same per-rank load, same node, same fabric params, and its predicted
Rmax is the proxy's *efficiency* times the full machine's peak.
Machines at or below ``max_ranks`` simulate at full size (proxy scale
1).  The proxy decision is recorded per machine in the report.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict, List, Optional, Sequence, Tuple

from repro.platforms.spec import Platform

from .infer import fabric_group, infer_platforms, memory_sized_n
from .rows import Top500Row


@dataclasses.dataclass(frozen=True)
class FleetTuning:
    """Auto-tuner knobs: proxy size, memory fill, and panel budget."""
    mem_fraction: float = 0.75   # HPL matrix fill of fleet memory
    max_ranks: int = 1024        # proxy grid cap (P'*Q' <= max_ranks)
    panels_cap: int = 4096       # nb grows until ceil(N/nb) <= panels_cap
    nb_min: int = 128            # smallest (and default) block size
    nb_step: int = 64            # nb granularity when the cap forces it up


@dataclasses.dataclass
class FleetEntry:
    """One machine's tuned scenario + prediction, ready for ranking."""
    platform: Platform
    cfg: object                  # HPLConfig (proxy geometry)
    scale: float                 # full-machine nodes / proxy nodes
    family: str                  # fabric calibration group
    published_tflops: float
    predicted_tflops: float = 0.0     # raw fleet-sim prediction
    calibrated_tflops: float = 0.0    # after family-efficiency factor
    split: str = ""                   # "train" | "test" (calibration)

    @property
    def rel_err(self) -> float:
        """Signed relative error vs the published Rmax; NaN when the
        platform has no published number to compare against."""
        if self.published_tflops <= 0:
            return float("nan")
        pred = self.calibrated_tflops or self.predicted_tflops
        return (pred - self.published_tflops) / self.published_tflops


def tune_scenario(platform: Platform, tuning: FleetTuning):
    """(HPLConfig proxy, scale): the machine's memory-rule HPL run on at
    most ``tuning.max_ranks`` ranks with full-size per-rank load."""
    from repro.core.apps.hpl import HPLConfig

    n_ranks = platform.scale.n_ranks
    rpn = platform.scale.ranks_per_node
    r = min(n_ranks, tuning.max_ranks)
    P = int(math.isqrt(r))
    Q = r // P
    proxy_nodes = max(P * Q // rpn, 1)
    scale = platform.scale.n_nodes / proxy_nodes

    nb = tuning.nb_min
    N = memory_sized_n(proxy_nodes, platform.node.hbm_bytes, nb,
                       tuning.mem_fraction)
    if (N + nb - 1) // nb > tuning.panels_cap:
        nb = -(-N // (tuning.panels_cap * tuning.nb_step)) \
            * tuning.nb_step
        N = memory_sized_n(proxy_nodes, platform.node.hbm_bytes, nb,
                           tuning.mem_fraction)
    return HPLConfig(N=N, nb=nb, P=P, Q=Q,
                     bcast=platform.mpi.bcast), scale


def fleet_bucket(cfgs: Sequence[object]) -> Tuple[int, int, int]:
    """The shared (n_panels_max, P_max, Q_max) every scenario fits in."""
    return (max(c.n_panels for c in cfgs),
            max(c.P for c in cfgs),
            max(c.Q for c in cfgs))


def predict_fleet(source, *,
                  tuning: Optional[FleetTuning] = None,
                  calibrate: bool = True,
                  infer_kw: Optional[dict] = None,
                  metrics=None) -> "FleetReport":
    """Rows (or pre-inferred Platforms) -> ranked predicted-vs-published
    Rmax report, via one forced-bucket ``sweep_hpl`` call.

    ``source`` is a sequence of ``Top500Row`` or of ``Platform``.  With
    ``calibrate=True`` the per-fabric-family residual pass runs on a
    deterministic train split and held-out error is reported (see
    top500/calibrate.py).

    ``metrics`` (a ``repro.obs.MetricsRegistry``) opts the run into
    fleet telemetry: machine/compile counters, per-provenance-source
    counts, per-phase wall times (tune / sweep / calibrate) and the
    fitted family calibration factors as gauges.  The registry rides on
    the returned report so ``report.run_manifest()`` can emit the
    per-run NDJSON artifact the campaign layer consumes.
    """
    import time as _time

    from repro.core.fastsim import sweep_hpl, trace_count
    from repro.obs.metrics import NULL_METRICS

    m = metrics if metrics is not None else NULL_METRICS
    tuning = tuning or FleetTuning()
    items = list(source)
    if not items:
        raise ValueError("predict_fleet: no machines to predict (did "
                         "the parser skip every row?)")
    if isinstance(items[0], Top500Row):
        platforms = infer_platforms(items, **(infer_kw or {}))
    else:
        platforms = items

    t0 = _time.perf_counter()
    entries: List[FleetEntry] = []
    for plat in platforms:
        cfg, scale = tune_scenario(plat, tuning)
        entries.append(FleetEntry(
            platform=plat, cfg=cfg, scale=scale,
            family=fabric_group(plat),
            published_tflops=plat.scale.reported_tflops))
    if m.enabled:
        m.histogram("fleet.phase_wall_s", phase="tune").observe(
            _time.perf_counter() - t0)
        m.counter("fleet.machines").inc(len(entries))
        for e in entries:
            for src, _ in e.platform.provenance:
                m.counter("fleet.provenance", source=src).inc()

    bucket = fleet_bucket([e.cfg for e in entries])
    compiles0 = trace_count()
    t0 = _time.perf_counter()
    results = sweep_hpl([e.cfg for e in entries],
                        [e.platform.fastsim() for e in entries],
                        bucket=bucket)
    compiles = trace_count() - compiles0
    if m.enabled:
        m.histogram("fleet.phase_wall_s", phase="sweep").observe(
            _time.perf_counter() - t0)
        m.counter("fleet.compiles").inc(compiles)
    for e, res in zip(entries, results):
        e.predicted_tflops = res["tflops"] * e.scale

    report = FleetReport(entries=entries, bucket=bucket,
                         compiles=compiles, tuning=tuning, metrics=m)
    if calibrate:
        from .calibrate import calibrate_fleet
        t0 = _time.perf_counter()
        report.calibration = calibrate_fleet(entries)
        if m.enabled:
            m.histogram("fleet.phase_wall_s", phase="calibrate").observe(
                _time.perf_counter() - t0)
            for fam, f in sorted(report.calibration.factors.items()):
                m.gauge("fleet.calibration_factor", family=fam).set(f)
    return report


@dataclasses.dataclass
class FleetReport:
    """Ranked fleet prediction + the sweep/calibration audit trail."""
    entries: List[FleetEntry]
    bucket: Tuple[int, int, int]
    compiles: int
    tuning: FleetTuning
    calibration: Optional[object] = None    # CalibrationResult
    skipped_rows: List = dataclasses.field(default_factory=list)
    #                    ^ (line, reason) pairs the parser rejected
    metrics: Optional[object] = None        # registry the run reported to

    def ranked(self) -> List[FleetEntry]:
        """Entries by predicted Rmax, best first (the predicted list)."""
        return sorted(self.entries,
                      key=lambda e: -(e.calibrated_tflops
                                      or e.predicted_tflops))

    def median_abs_err(self, split: Optional[str] = None) -> float:
        import statistics
        errs = [abs(e.rel_err) for e in self.entries
                if (split is None or e.split == split)
                and e.published_tflops > 0]
        return statistics.median(errs) if errs else float("nan")

    def run_manifest(self, path=None, **meta) -> str:
        """One NDJSON run-manifest line for this fleet run (the per-run
        artifact the campaign layer consumes, ``repro.obs`` §manifest):
        machine/bucket/compile/error summary as ``meta``, the full
        metrics snapshot when the run was instrumented.  With ``path``
        the line is also appended to that NDJSON journal."""
        from repro.obs import append_manifest, manifest_line
        med, held = self.median_abs_err(), self.median_abs_err("test")
        base = {
            "machines": len(self.entries),
            "bucket": list(self.bucket),
            "compiles": self.compiles,
            "n_skipped": len(self.skipped_rows),
            "median_abs_err": None if med != med else med,
            "heldout_median_abs_err": None if held != held else held,
        }
        if self.calibration is not None:
            base["calibration_factors"] = dict(
                sorted(self.calibration.factors.items()))
        base.update(meta)
        m = self.metrics if self.metrics is not None \
            and getattr(self.metrics, "enabled", False) else None
        if path is not None:
            return append_manifest(path, "fleet_run", meta=base, metrics=m)
        return manifest_line("fleet_run", meta=base, metrics=m)

    def to_dict(self) -> Dict:
        med, held = self.median_abs_err(), self.median_abs_err("test")
        d: Dict = {
            "bucket": list(self.bucket),
            "compiles": self.compiles,
            "tuning": dataclasses.asdict(self.tuning),
            "median_abs_err": None if med != med else med,
            "heldout_median_abs_err": None if held != held else held,
            "skipped_rows": [list(kv) for kv in self.skipped_rows],
            "machines": [],
        }
        if self.calibration is not None:
            d["calibration"] = self.calibration.to_dict()
        d["n_skipped"] = len(self.skipped_rows)
        for pos, e in enumerate(self.ranked(), start=1):
            err = e.rel_err
            d["machines"].append({
                "predicted_rank": pos,
                "name": e.platform.name,
                "family": e.family,
                "split": e.split,
                "published_tflops": e.published_tflops,
                "predicted_tflops": e.predicted_tflops,
                "calibrated_tflops": e.calibrated_tflops,
                "rel_err": None if err != err else err,   # NaN -> null
                "proxy_scale": e.scale,
                "proxy_cfg": {"N": e.cfg.N, "nb": e.cfg.nb,
                              "P": e.cfg.P, "Q": e.cfg.Q},
                "provenance": [list(kv) for kv in e.platform.provenance],
            })
        return d
