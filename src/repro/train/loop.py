"""Training loop driver: data + step + checkpoint + straggler monitor.

Used by examples/train_lm.py and launch/train.py.  Restart-safe: resumes
from the latest checkpoint and replays the data stream deterministically.
"""
from __future__ import annotations

import time
from pathlib import Path
from typing import Callable, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import (AsyncCheckpointer, latest_step,
                              restore_checkpoint)
from repro.data import DataConfig, SyntheticLM
from repro.ft import StepTimeMonitor
from repro.train.step import TrainState, make_train_state, make_train_step


def train(cfg, *, steps: int, global_batch: int, seq_len: int,
          lr: float = 3e-4, ckpt_dir: Optional[str] = None,
          ckpt_every: int = 50, microbatches: int = 1,
          log_every: int = 10, seed: int = 0,
          log_fn: Callable[[str], None] = print) -> Dict:
    """Single-process training (CPU smoke scale). Returns final metrics."""
    dcfg = DataConfig(vocab_size=cfg.vocab_size, seq_len=seq_len,
                      global_batch=global_batch, seed=seed)
    ds = SyntheticLM(dcfg)
    step_fn, model = make_train_step(cfg, lr=lr, microbatches=microbatches)
    step_fn = jax.jit(step_fn, donate_argnums=(0,))

    state = make_train_state(cfg, jax.random.PRNGKey(seed))
    start = 0
    ckpt = None
    if ckpt_dir:
        ckpt = AsyncCheckpointer(ckpt_dir)
        last = latest_step(ckpt_dir)
        if last is not None:
            state = restore_checkpoint(ckpt_dir, last, state)
            start = last
            log_fn(f"[train] resumed from step {last}")

    monitor = StepTimeMonitor()
    losses = []
    extras = {}
    if cfg.family == "encdec":
        extras["encoder_embeds"] = jnp.zeros(
            (global_batch, cfg.encoder_seq, cfg.d_model), jnp.float32)
    if cfg.family == "vlm":
        extras["image_embeds"] = jnp.zeros(
            (global_batch, cfg.n_image_tokens, cfg.d_model), jnp.float32)

    for step in range(start, steps):
        batch = {"tokens": jnp.asarray(ds.shard_at(step, 0, 1)), **extras}
        t0 = time.perf_counter()
        state, metrics = step_fn(state, batch)
        loss = float(metrics["loss"])
        dt = time.perf_counter() - t0
        flagged = monitor.record(dt)
        losses.append(loss)
        if step % log_every == 0 or step == steps - 1:
            log_fn(f"[train] step {step:5d} loss {loss:.4f} "
                   f"({dt*1e3:.0f} ms{' STRAGGLER' if flagged else ''})")
        if ckpt and (step + 1) % ckpt_every == 0:
            ckpt.save(step + 1, state)
    if ckpt:
        ckpt.save(steps, state)
        ckpt.wait()
    return {"final_loss": losses[-1] if losses else float("nan"),
            "first_loss": losses[0] if losses else float("nan"),
            "losses": losses, "state": state,
            "median_step_s": monitor.median}
