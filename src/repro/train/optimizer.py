"""Hand-rolled optimizers (optax is not available offline).

AdamW keeps fp32 (m, v) per param; Adafactor factors the second moment for
giant models (qwen3-moe-235b: DESIGN.md §6).  Both take/return pytrees and
are pure — safe under jit/pjit; optimizer state inherits the param sharding
(factored Adafactor vectors inherit the reduced spec).
"""
from __future__ import annotations

from typing import Any, Dict, NamedTuple

import jax
import jax.numpy as jnp


def global_norm(tree) -> jnp.ndarray:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in leaves))


def clip_by_global_norm(grads, max_norm: float):
    gn = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(gn, 1e-9))
    return jax.tree.map(lambda g: g * scale, grads), gn


# ---------------------------------------------------------------- AdamW


def adamw_init(params):
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
    return {"m": jax.tree.map(zeros, params),
            "v": jax.tree.map(zeros, params),
            "count": jnp.zeros((), jnp.int32)}


def adamw_update(params, grads, state, *, lr, b1=0.9, b2=0.95, eps=1e-8,
                 weight_decay=0.1, max_grad_norm=1.0):
    grads, gn = clip_by_global_norm(grads, max_grad_norm)
    count = state["count"] + 1
    cf = count.astype(jnp.float32)
    bc1 = 1.0 - jnp.power(b1, cf)
    bc2 = 1.0 - jnp.power(b2, cf)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32)
        m = b1 * m + (1 - b1) * g
        v = b2 * v + (1 - b2) * jnp.square(g)
        mhat = m / bc1
        vhat = v / bc2
        step = mhat / (jnp.sqrt(vhat) + eps) + weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * step).astype(p.dtype), m, v

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_m = treedef.flatten_up_to(state["m"])
    flat_v = treedef.flatten_up_to(state["v"])
    out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = treedef.unflatten([o[0] for o in out])
    new_m = treedef.unflatten([o[1] for o in out])
    new_v = treedef.unflatten([o[2] for o in out])
    return new_p, {"m": new_m, "v": new_v, "count": count}, gn


# ------------------------------------------------------------- Adafactor


def _factored(shape):
    return len(shape) >= 2


def adafactor_init(params):
    def init_one(p):
        if _factored(p.shape):
            row = jnp.zeros(p.shape[:-1], jnp.float32)
            col = jnp.zeros(p.shape[:-2] + p.shape[-1:], jnp.float32)
            return {"vr": row, "vc": col}
        return {"v": jnp.zeros(p.shape, jnp.float32)}
    return {"f": jax.tree.map(init_one, params,
                              is_leaf=lambda x: isinstance(x, jnp.ndarray)),
            "count": jnp.zeros((), jnp.int32)}


def adafactor_update(params, grads, state, *, lr, decay=0.99, eps=1e-30,
                     weight_decay=0.0, max_grad_norm=1.0, clip_threshold=1.0):
    grads, gn = clip_by_global_norm(grads, max_grad_norm)
    count = state["count"] + 1

    def upd(p, g, f):
        g = g.astype(jnp.float32)
        g2 = jnp.square(g) + eps
        if _factored(p.shape):
            vr = decay * f["vr"] + (1 - decay) * jnp.mean(g2, axis=-1)
            vc = decay * f["vc"] + (1 - decay) * jnp.mean(g2, axis=-2)
            rfac = vr / jnp.maximum(jnp.mean(vr, axis=-1, keepdims=True), eps)
            update = g / (jnp.sqrt(rfac)[..., None] * jnp.sqrt(vc)[..., None, :]
                          + 1e-12)
            newf = {"vr": vr, "vc": vc}
        else:
            v = decay * f["v"] + (1 - decay) * g2
            update = g / (jnp.sqrt(v) + 1e-12)
            newf = {"v": v}
        rms = jnp.sqrt(jnp.mean(jnp.square(update)) + 1e-12)
        update = update / jnp.maximum(1.0, rms / clip_threshold)
        newp = (p.astype(jnp.float32) - lr * update
                - lr * weight_decay * p.astype(jnp.float32)).astype(p.dtype)
        return newp, newf

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_f = treedef.flatten_up_to(state["f"])
    out = [upd(p, g, f) for p, g, f in zip(flat_p, flat_g, flat_f)]
    new_p = treedef.unflatten([o[0] for o in out])
    new_f = treedef.unflatten([o[1] for o in out])
    return new_p, {"f": new_f, "count": count}, gn


def opt_init(name: str):
    return {"adamw": adamw_init, "adafactor": adafactor_init}[name]


def opt_update(name: str):
    return {"adamw": adamw_update, "adafactor": adafactor_update}[name]


def opt_state_specs(name: str, param_specs):
    """Logical specs for the optimizer state, mirroring param specs."""
    if name == "adamw":
        return {"m": param_specs, "v": param_specs, "count": None}

    def one(spec):
        spec = tuple(spec)
        if len(spec) >= 2:
            return {"vr": spec[:-1], "vc": spec[:-2] + spec[-1:]}
        return {"v": spec}
    return {"f": jax.tree.map(one, param_specs,
                              is_leaf=lambda x: type(x) is tuple),
            "count": None}
