from .optimizer import adamw_init, adamw_update, adafactor_init, adafactor_update
from .step import TrainState, make_train_state, train_step, make_train_step

__all__ = ["adamw_init", "adamw_update", "adafactor_init", "adafactor_update",
           "TrainState", "make_train_state", "train_step", "make_train_step"]
