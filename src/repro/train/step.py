"""Train state + step.

Supports microbatched gradient accumulation (compute/comm overlap: XLA
overlaps each microbatch's psum with the next microbatch's compute) and
optional int8 gradient compression for the cross-pod reduction.
"""
from __future__ import annotations

from typing import Any, Dict, NamedTuple, Optional

import jax
import jax.numpy as jnp
from jax import lax

from repro.models import build_model
from .optimizer import opt_init, opt_update, opt_state_specs


class TrainState(NamedTuple):
    params: Any
    opt: Any
    step: jnp.ndarray


def make_train_state(cfg, key) -> TrainState:
    model = build_model(cfg)
    params = model.init(key)
    opt = opt_init(cfg.optimizer)(params)
    return TrainState(params=params, opt=opt, step=jnp.zeros((), jnp.int32))


def state_specs(cfg, model) -> TrainState:
    pspec = model.param_specs()
    return TrainState(params=pspec,
                      opt=opt_state_specs(cfg.optimizer, pspec),
                      step=None)


def _quantize_int8(g):
    scale = jnp.maximum(jnp.max(jnp.abs(g)), 1e-8) / 127.0
    q = jnp.clip(jnp.round(g / scale), -127, 127).astype(jnp.int8)
    return q, scale


def _dequantize_int8(q, scale):
    return q.astype(jnp.float32) * scale


def make_train_step(cfg, *, lr=3e-4, microbatches: int = 1,
                    grad_compression: bool = False, use_kernel: bool = False):
    """Returns train_step(state, batch) -> (state, metrics)."""
    model = build_model(cfg, use_kernel=use_kernel)
    update = opt_update(cfg.optimizer)

    def loss_fn(params, batch):
        return model.loss(params, batch)

    def compute_grads(params, batch):
        if microbatches <= 1:
            (loss, metrics), grads = jax.value_and_grad(
                loss_fn, has_aux=True)(params, batch)
            return loss, metrics, grads

        def split(x):
            b = x.shape[0]
            return x.reshape((microbatches, b // microbatches) + x.shape[1:])
        micro = jax.tree.map(split, batch)

        def body(carry, mb):
            acc, lsum = carry
            (loss, metrics), grads = jax.value_and_grad(
                loss_fn, has_aux=True)(params, mb)
            acc = jax.tree.map(jnp.add, acc, grads)
            return (acc, lsum + loss), metrics
        zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
        (acc, lsum), metrics = lax.scan(body, (zeros, jnp.zeros(())), micro)
        grads = jax.tree.map(lambda g: g / microbatches, acc)
        metrics = jax.tree.map(lambda m: m[-1], metrics)
        return lsum / microbatches, metrics, grads

    def train_step(state: TrainState, batch) -> tuple:
        loss, metrics, grads = compute_grads(state.params, batch)
        if grad_compression:
            qs = jax.tree.map(_quantize_int8, grads,
                              is_leaf=lambda x: isinstance(x, jnp.ndarray))
            grads = jax.tree.map(
                lambda qsc: _dequantize_int8(*qsc), qs,
                is_leaf=lambda x: isinstance(x, tuple))
        new_params, new_opt, gnorm = update(state.params, grads, state.opt,
                                            lr=lr)
        metrics = dict(metrics, loss=loss, grad_norm=gnorm)
        return TrainState(new_params, new_opt, state.step + 1), metrics

    return train_step, model


def train_step(cfg, state, batch, **kw):
    step_fn, _ = make_train_step(cfg, **kw)
    return step_fn(state, batch)
