"""Batched fault axes — map a ``FaultSpec`` onto the closed-form models.

The DES injects faults event-by-event; the batched fastsim/stepsim paths
can't, but the straggler/bandwidth subset has a clean steady-state
mapping onto the traced parameter pytrees (``FastSimParams`` /
``StepParams``), which makes degraded scenarios ordinary *sweep axes*:
a fault grid compiles once, exactly like a hardware what-if grid
(DESIGN.md §11, §16).

Mapping semantics (whole-run steady state — start/duration windows are
DES-only precision; the closed forms see a fault as active for the
whole run):

  * straggler   — per-rank factors compose multiplicatively and the
    *max* over ranks divides ``peak_flops`` and ``mem_bw``.  For the
    transformer step this is exact: the mesh is symmetric and ring
    collectives sync every row/column, so the step time IS the
    straggler's own chain.  HPL gates more loosely — a slow rank holds
    up the serial panel chain only through its process column's syncs
    (it co-owns 1/Q of panel factorizations) and its row-ring forward,
    with the rest absorbed by pipeline slack — so when the run geometry
    is known (``grid=(P, Q)``) the slowdown is attenuated by the
    exposure fraction ``min(1, 3/(P*Q))`` (≈ three ranks' worth of the
    grid's work: the straggler, its column sync, its row forward),
    calibrated against the DES across grid geometries in
    tests/test_faults.py.
  * link_degrade — a seeded fraction ``p`` of links at ``factor``x
    capacity.  A route of ``ROUTE_LINKS`` links is degraded with
    probability ``q = 1 - (1-p)^ROUTE_LINKS``; the expected per-transfer
    time multiplier is ``(1-q) + q/factor``, so effective bandwidth
    scales by its inverse.  Node-scoped link faults (``node >= 0``)
    have no closed form here — DES-only.
  * link_flap   — link_degrade with the duty-cycle-averaged factor
    ``duty*factor + (1-duty)``.
  * latency_jitter — the per-message draw is mean-one by construction,
    so the expected-time mapping is the identity (the DES shows the
    spread; the closed form predicts the mean).
  * fail_stop   — no steady state exists (the run deadlocks); raises.

``sweep_faults`` is the one-compile entry point: one workload/platform
pair swept across a list of fault scenarios.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Sequence, Tuple

from repro.faults.spec import FaultSpec, as_fault_spec

# links per typical route (fat-tree inter-edge path; torus routes are
# comparable at small mesh radius) — the q = 1-(1-p)^L exposure model
ROUTE_LINKS = 4


def _aggregate(spec: FaultSpec) -> Tuple[float, float]:
    """(compute slowdown, bandwidth scale) for the whole-run mapping."""
    per_rank = {}
    bw_scale = 1.0
    for i, f in enumerate(spec.faults):
        if f.kind == "straggler":
            per_rank[f.rank] = per_rank.get(f.rank, 1.0) * f.factor
        elif f.kind == "fail_stop":
            raise ValueError(
                "fail_stop has no closed-form mapping (the run deadlocks)"
                " — use the DES path")
        elif f.kind in ("link_degrade", "link_flap"):
            if f.node >= 0:
                raise ValueError(
                    f"node-scoped {f.kind} faults are DES-only (no "
                    "closed-form route exposure for one node's links)")
            factor = f.factor if f.kind == "link_degrade" \
                else f.duty * f.factor + (1.0 - f.duty)
            q = 1.0 - (1.0 - f.link_frac) ** ROUTE_LINKS
            bw_scale *= 1.0 / ((1.0 - q) + q / factor)
        # latency_jitter: mean-one draw -> identity in expectation
    slowdown = max(per_rank.values()) if per_rank else 1.0
    return slowdown, bw_scale


def apply_faults(params, faults, *, grid: Optional[Tuple[int, int]] = None):
    """Return a copy of a ``FastSimParams`` or ``StepParams`` with a
    fault scenario folded into its traced leaves (None/empty spec
    returns ``params`` unchanged).  ``grid=(P, Q)`` enables the HPL
    partial-gating straggler attenuation (see module docstring)."""
    spec = as_fault_spec(faults)
    if spec is None:
        return params
    slowdown, bw_scale = _aggregate(spec)
    if grid is not None and slowdown > 1.0:
        P, Q = grid
        gate = min(1.0, 3.0 / (P * Q))
        slowdown = 1.0 + (slowdown - 1.0) * gate
    fields = {f.name for f in dataclasses.fields(params)}
    over = {"peak_flops": params.peak_flops / slowdown,
            "mem_bw": params.mem_bw / slowdown}
    if "bcast_bw_scale" in fields:           # FastSimParams (HPL)
        over["bcast_bw_scale"] = params.bcast_bw_scale * bw_scale
        over["swap_bw_scale"] = params.swap_bw_scale * bw_scale
    elif "link_bw" in fields:                # StepParams (transformer)
        over["link_bw"] = params.link_bw * bw_scale
        if "pod_bw" in fields:
            over["pod_bw"] = params.pod_bw * bw_scale
    return dataclasses.replace(params, **over)


def fault_params(params, specs: Sequence, *,
                 grid: Optional[Tuple[int, int]] = None) -> List:
    """One params variant per fault scenario (a sweep-axis builder)."""
    return [apply_faults(params, s, grid=grid) for s in specs]


def sweep_faults(workload, platform, specs: Sequence,
                 baseline: bool = True) -> List[dict]:
    """Sweep one workload/platform pair across fault scenarios in ONE
    compiled program.  With ``baseline=True`` an unfaulted lane is
    prepended, so ``out[0]`` is the healthy prediction and each result
    carries a ``slowdown_vs_healthy`` field."""
    model = workload.fastsim_model(platform)
    cfg = getattr(model, "cfg", None)          # HPL carries its geometry
    grid = (cfg.P, cfg.Q) if cfg is not None else None
    scenarios: List[Optional[FaultSpec]] = \
        ([None] if baseline else []) + list(specs)
    out = model.sweep(fault_params(model.params, scenarios, grid=grid))
    if baseline:
        t0 = out[0]["time_s"]
        for r in out:
            r["slowdown_vs_healthy"] = r["time_s"] / t0
    return out
