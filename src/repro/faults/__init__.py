"""Declarative fault injection for degraded-platform what-ifs.

``FaultSpec`` (pure data, JSON round-trip) describes a scenario;
``FaultRuntime`` injects it into a live DES; ``repro.faults.fastsim``
maps the straggler/bandwidth subset onto the batched closed-form
models as extra sweep axes.  See DESIGN.md §16.

The fastsim mapping is imported lazily (module attribute access) so
DES-only fault runs never pull in JAX.
"""
from repro.faults.inject import (FAULT_TRACK, FaultRuntime, NULL_FAULTS,
                                 install_faults)
from repro.faults.spec import (FASTSIM_KINDS, FAULT_KINDS, Fault,
                               FaultSpec, NO_FAULTS, as_fault_spec)

__all__ = [
    "FAULT_KINDS", "FASTSIM_KINDS", "Fault", "FaultSpec", "NO_FAULTS",
    "as_fault_spec", "FaultRuntime", "NULL_FAULTS", "FAULT_TRACK",
    "install_faults", "apply_faults", "fault_params", "sweep_faults",
]

_LAZY = ("apply_faults", "fault_params", "sweep_faults")


def __getattr__(name):
    if name in _LAZY:
        from repro.faults import fastsim
        return getattr(fastsim, name)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
