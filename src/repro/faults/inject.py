"""FaultRuntime — DES-side injection of a ``FaultSpec``.

The runtime hangs off ``Engine`` (``engine.faults``), mirroring the
trace recorder's NULL-object pattern: unfaulted engines carry the
module-level ``NULL_FAULTS`` singleton whose hooks are identity
functions behind ``enabled=False``, so every injection site reduces to
one attribute test and an unfaulted run schedules zero extra events —
bit-identical to pre-fault builds.

Injection points (see DESIGN.md §16):

  * compute  — ``SimBLAS``/layer compute yields are multiplied by
    ``compute_scale(rank)`` (straggler faults; multiplicative, so
    overlapping stragglers compose).
  * network  — selected links get ``Network.set_capacity`` calls at
    activation/deactivation times (degrade and flap; capacity scaling
    is multiplicative too, so restore divides).
  * MPI      — ``SimMPI.isend`` software overhead is multiplied by
    ``latency_factor(src)``, a deterministic per-message draw from
    ``1 ± sigma`` (no RNG in sim time: a counter hash seeded by the
    spec's seed).
  * liveness — fail-stop kills the registered ``Process`` of each
    target rank; peers block at their next rendezvous with it, exactly
    like a real fail-stop process (the run ends when the heap drains,
    and apps report a failed/partial result).

Every activation/deactivation is an ordinary ``engine.call_at`` event
scheduled up-front from the spec (link flaps carry a finite cycle
count), so the event heap always drains and a seeded spec replays
bit-identically run-to-run.  With tracing on, activations emit instant
markers and each active window becomes a ``cat="fault"`` span on the
dedicated ``FAULT_TRACK`` timeline (rank -1, rendered as a "faults"
thread in the Chrome export, excluded from breakdowns/critical path).
"""
from __future__ import annotations

import random
from typing import Any, Callable, Dict, List, Optional, Tuple

from repro.faults.spec import Fault, FaultSpec

FAULT_TRACK = -1          # trace rank id of the fault timeline


class _NullFaults:
    """Faults-off singleton: identity hooks behind ``enabled``."""
    enabled = False
    __slots__ = ()

    def compute_scale(self, rank: int) -> float:
        return 1.0

    def latency_factor(self, rank: int) -> float:
        return 1.0

    def alive(self, rank: int) -> bool:
        return True

    def register_rank(self, rank: int, proc) -> None:
        pass

    def finalize(self) -> None:
        pass


NULL_FAULTS = _NullFaults()


class FaultRuntime:
    """Installs a ``FaultSpec`` into a live engine/network pair.

    Construct *after* the engine and network exist and *before*
    spawning rank processes; the constructor attaches itself as
    ``engine.faults`` and schedules the whole (finite) fault timetable.
    Apps then ``register_rank(r, proc)`` each spawned process (so
    fail-stop can kill it) and call ``finalize()`` after ``run_all``
    (closes still-open fault spans in the trace).
    """
    enabled = True

    def __init__(self, spec: FaultSpec, engine, network=None,
                 n_ranks: int = 0,
                 rank_to_node: Optional[Callable[[int], int]] = None):
        if network is None and any(
                f.kind in ("link_degrade", "link_flap")
                for f in spec.faults):
            raise ValueError("link faults need a network")
        self.spec = spec
        self.engine = engine
        self.net = network
        self.n_ranks = n_ranks
        self.rank_to_node = rank_to_node or (lambda r: r)
        self._compute: Dict[int, float] = {}      # rank -> multiplier
        self._jitter: List[float] = []            # active sigmas
        self._msg_counter = 0
        self._dead: set = set()
        self._procs: Dict[int, Any] = {}
        # (fault idx, cycle) -> activation time, for trace spans
        self._open: Dict[Tuple[int, int], float] = {}
        self._links: Dict[int, List] = {}         # fault idx -> [Link]
        engine.faults = self
        self._install()

    # ------------------------------------------------------------ install
    def _install(self):
        eng = self.engine
        for i, f in enumerate(self.spec.faults):
            if f.kind in ("link_degrade", "link_flap"):
                self._links[i] = self._resolve_links(f, i)
            if f.kind == "link_flap":
                for c in range(f.cycles):
                    t_on = f.start + c * f.period
                    eng.call_at(t_on, self._activate, (i, c))
                    eng.call_at(t_on + f.duty * f.period,
                                self._deactivate, (i, c))
            else:
                eng.call_at(f.start, self._activate, (i, 0))
                end = f.end
                if end != float("inf"):
                    eng.call_at(end, self._deactivate, (i, 0))

    def _resolve_links(self, f: Fault, i: int) -> List:
        topo = self.net.topo
        if f.node >= 0:
            return list(topo.node_links(f.node))
        links = topo.iter_links()
        k = min(max(1, round(f.link_frac * len(links))), len(links))
        # seeded per-fault sample over the deterministic structural
        # order — same spec, same links, run-to-run
        rnd = random.Random((self.spec.seed << 16)
                            ^ ((i * 2654435761) & 0xffffffff))
        return rnd.sample(links, k)

    def _fault_ranks(self, f: Fault) -> List[int]:
        if f.rank >= 0:
            return [f.rank]
        return [r for r in range(self.n_ranks)
                if self.rank_to_node(r) == f.node]

    # -------------------------------------------------- timetable events
    def _activate(self, arg: Tuple[int, int]):
        i, cycle = arg
        f = self.spec.faults[i]
        if f.kind == "straggler":
            self._compute[f.rank] = \
                self._compute.get(f.rank, 1.0) * f.factor
        elif f.kind == "fail_stop":
            for r in self._fault_ranks(f):
                self._dead.add(r)
                proc = self._procs.get(r)
                if proc is not None:
                    proc.kill()
        elif f.kind in ("link_degrade", "link_flap"):
            for l in self._links[i]:
                self.net.set_capacity(l, l.capacity * f.factor)
        elif f.kind == "latency_jitter":
            self._jitter.append(f.sigma)
        tr = self.engine.trace
        if tr.enabled:
            tr.instant(FAULT_TRACK, f"fault_on:{f.kind}",
                       args=self._span_args(f, i))
        self._open[(i, cycle)] = self.engine.now

    def _deactivate(self, arg: Tuple[int, int]):
        i, cycle = arg
        f = self.spec.faults[i]
        if f.kind == "straggler":
            self._compute[f.rank] = \
                self._compute.get(f.rank, 1.0) / f.factor
        elif f.kind in ("link_degrade", "link_flap"):
            for l in self._links[i]:
                self.net.set_capacity(l, l.capacity / f.factor)
        elif f.kind == "latency_jitter":
            self._jitter.remove(f.sigma)
        self._close_span(i, cycle)

    def _span_args(self, f: Fault, i: int) -> Dict[str, Any]:
        args: Dict[str, Any] = {"kind": f.kind, "fault": i}
        if f.rank >= 0:
            args["rank"] = f.rank
        if f.node >= 0:
            args["node"] = f.node
        if f.kind != "fail_stop":
            args["factor"] = f.factor if f.kind != "latency_jitter" \
                else f.sigma
        if i in self._links:
            args["links"] = len(self._links[i])
        return args

    def _close_span(self, i: int, cycle: int):
        t0 = self._open.pop((i, cycle), None)
        tr = self.engine.trace
        if t0 is not None and tr.enabled:
            f = self.spec.faults[i]
            tr.complete(FAULT_TRACK, "fault", f.kind, t0,
                        args=self._span_args(f, i))

    # ------------------------------------------------------- query hooks
    def compute_scale(self, rank: int) -> float:
        return self._compute.get(rank, 1.0)

    def latency_factor(self, rank: int) -> float:
        if not self._jitter:
            return 1.0
        scale = 1.0
        for sigma in self._jitter:
            self._msg_counter += 1
            h = (self._msg_counter * 2654435761 + rank * 97
                 + self.spec.seed * 40503) & 0xffffffff
            scale *= 1.0 + sigma * (h / 0xffffffff * 2.0 - 1.0)
        return scale

    def alive(self, rank: int) -> bool:
        return rank not in self._dead

    @property
    def dead_ranks(self) -> List[int]:
        return sorted(self._dead)

    # ----------------------------------------------------- app lifecycle
    def register_rank(self, rank: int, proc) -> None:
        self._procs[rank] = proc
        if rank in self._dead:       # fail-stopped before registration
            proc.kill()

    def finalize(self) -> None:
        """Close still-open fault spans (open-ended faults) at run end."""
        for (i, cycle) in sorted(self._open):
            self._close_span(i, cycle)


def install_faults(faults, engine, network=None, n_ranks: int = 0,
                   rank_to_node=None):
    """Normalize a ``faults=`` argument and attach a runtime to the
    engine; returns ``engine.faults`` (NULL_FAULTS when empty/None)."""
    from repro.faults.spec import as_fault_spec
    spec = as_fault_spec(faults)
    if spec is not None:
        FaultRuntime(spec, engine, network=network, n_ranks=n_ranks,
                     rank_to_node=rank_to_node)
    return engine.faults
