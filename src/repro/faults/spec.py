"""FaultSpec — degraded-platform scenarios as declarative, seeded data.

The paper's methodology predicts the *happy path*; Cornebize & Legrand
("Variability Matters", PAPERS.md) show that real TOP500-scale runs are
shaped by platform misbehaviour — slow nodes, flapping links, fail-stop
ranks.  This module makes that misbehaviour a first-class scenario axis:
a ``FaultSpec`` is a frozen, hashable, JSON-round-trip bundle of
``Fault`` records plus a seed, exactly like ``WorkloadSpec``/``Platform``
specs, so a degraded scenario can be shipped to the serving layer,
diffed, swept, and replayed bit-identically.

Fault kinds (the ``kind`` field):

  * ``straggler``      — rank ``rank`` computes ``factor``x slower over
    ``[start, start+duration)`` (duration 0 = rest of the run).
  * ``fail_stop``      — rank ``rank`` (or every rank on node ``node``)
    stops dead at ``start``; peers block at their next rendezvous with
    it, exactly like a real fail-stop process.
  * ``link_degrade``   — selected links run at ``factor``x capacity over
    ``[start, start+duration)``.  Selection: ``node`` (all links
    adjacent to that node) or ``link_frac`` (a seeded fraction of all
    links).
  * ``link_flap``      — selected links oscillate: ``factor``x capacity
    for ``duty*period`` then restored, repeated ``cycles`` times from
    ``start`` (a finite schedule, so the event heap always drains).
  * ``latency_jitter`` — every MPI send pays overhead scaled by a
    deterministic per-message draw from ``1 ± sigma`` while active.

Injection happens in ``repro.faults.inject.FaultRuntime`` (DES) and
``repro.faults.fastsim`` (batched closed-form mapping); this module is
pure data.
"""
from __future__ import annotations

import dataclasses
import json
import math
from typing import Any, Dict, List, Optional, Sequence, Tuple

FAULT_KINDS = ("straggler", "fail_stop", "link_degrade", "link_flap",
               "latency_jitter")

# which kinds the batched fastsim/stepsim mapping can express (the
# DES covers all of FAULT_KINDS) — see DESIGN.md §16 coverage matrix
FASTSIM_KINDS = ("straggler", "link_degrade", "link_flap",
                 "latency_jitter")


@dataclasses.dataclass(frozen=True)
class Fault:
    """One fault event.  A single flat record keeps JSON round-trip and
    property-based generation trivial; ``__post_init__`` enforces the
    per-kind field contracts."""
    kind: str
    start: float = 0.0           # sim seconds
    duration: float = 0.0        # 0 = until the end of the run
    rank: int = -1               # straggler / fail_stop target
    node: int = -1               # link faults: links adjacent to node
    link_frac: float = 0.0       # link faults: seeded fraction of links
    factor: float = 1.0          # compute slowdown / capacity multiplier
    period: float = 0.0          # link_flap cycle length (s)
    duty: float = 0.5            # link_flap: degraded fraction of period
    cycles: int = 0              # link_flap repetitions
    sigma: float = 0.0           # latency_jitter spread

    def __post_init__(self):
        if self.kind not in FAULT_KINDS:
            raise ValueError(f"fault kind {self.kind!r} not in "
                             f"{FAULT_KINDS}")
        if self.start < 0 or self.duration < 0:
            raise ValueError(f"{self.kind}: start/duration must be >= 0")
        if self.kind == "straggler":
            if self.rank < 0:
                raise ValueError("straggler needs a rank >= 0")
            if self.factor <= 0:
                raise ValueError("straggler factor must be > 0 (compute-"
                                 "time multiplier; 2.0 = chip at 0.5x)")
        elif self.kind == "fail_stop":
            if self.rank < 0 and self.node < 0:
                raise ValueError("fail_stop needs a rank or a node")
        elif self.kind in ("link_degrade", "link_flap"):
            if self.node < 0 and not 0.0 < self.link_frac <= 1.0:
                raise ValueError(f"{self.kind} needs a node or a "
                                 "link_frac in (0, 1]")
            if not 0.0 < self.factor <= 1.0:
                raise ValueError(f"{self.kind} factor must be in (0, 1] "
                                 "(capacity multiplier)")
            if self.kind == "link_flap":
                if self.period <= 0 or self.cycles < 1:
                    raise ValueError("link_flap needs period > 0 and "
                                     "cycles >= 1 (finite schedule)")
                if not 0.0 < self.duty < 1.0:
                    raise ValueError("link_flap duty must be in (0, 1)")
        elif self.kind == "latency_jitter":
            if not 0.0 < self.sigma < 1.0:
                raise ValueError("latency_jitter needs sigma in (0, 1)")

    @property
    def end(self) -> float:
        """Deactivation time; ``inf`` for open-ended faults."""
        if self.kind == "fail_stop":
            return math.inf
        if self.kind == "link_flap":
            return self.start + self.cycles * self.period
        return self.start + self.duration if self.duration > 0 else math.inf

    def to_dict(self) -> Dict[str, Any]:
        return dataclasses.asdict(self)

    @classmethod
    def from_dict(cls, d: Dict[str, Any]) -> "Fault":
        return cls(**d)


@dataclasses.dataclass(frozen=True)
class FaultSpec:
    """A degraded-platform scenario: a tuple of faults plus the seed
    that makes every seeded choice (link sampling, jitter draws)
    replay bit-identically."""
    faults: Tuple[Fault, ...] = ()
    seed: int = 0
    name: str = ""

    def __post_init__(self):
        object.__setattr__(self, "faults", tuple(self.faults))

    # ------------------------------------------------------ constructors
    @staticmethod
    def straggler(rank: int, slowdown: float = 2.0, *, start: float = 0.0,
                  duration: float = 0.0, seed: int = 0) -> "FaultSpec":
        """One chip computing ``slowdown``x slower (2.0 = 0.5x speed)."""
        return FaultSpec(faults=(Fault("straggler", rank=rank,
                                       factor=slowdown, start=start,
                                       duration=duration),), seed=seed)

    @staticmethod
    def fail_stop(rank: int = -1, *, node: int = -1, at: float = 0.0,
                  seed: int = 0) -> "FaultSpec":
        return FaultSpec(faults=(Fault("fail_stop", rank=rank, node=node,
                                       start=at),), seed=seed)

    @staticmethod
    def degraded_links(frac: float, factor: float = 0.5, *,
                       start: float = 0.0, duration: float = 0.0,
                       seed: int = 0) -> "FaultSpec":
        """A seeded ``frac`` of all links at ``factor``x bandwidth."""
        return FaultSpec(faults=(Fault("link_degrade", link_frac=frac,
                                       factor=factor, start=start,
                                       duration=duration),), seed=seed)

    # ------------------------------------------------------- combinators
    def __add__(self, other: "FaultSpec") -> "FaultSpec":
        """Union of two scenarios (left spec's seed/name win)."""
        return FaultSpec(faults=self.faults + tuple(other.faults),
                         seed=self.seed, name=self.name or other.name)

    def with_fault(self, fault: Fault) -> "FaultSpec":
        return dataclasses.replace(self, faults=self.faults + (fault,))

    # ---------------------------------------------------------- queries
    @property
    def is_empty(self) -> bool:
        return not self.faults

    def by_kind(self, kind: str) -> List[Fault]:
        return [f for f in self.faults if f.kind == kind]

    def fastsim_supported(self) -> bool:
        """True when every fault has a batched closed-form mapping."""
        return all(f.kind in FASTSIM_KINDS for f in self.faults)

    # -------------------------------------------------- serialization
    def to_dict(self) -> Dict[str, Any]:
        return {"seed": self.seed, "name": self.name,
                "faults": [f.to_dict() for f in self.faults]}

    def to_json(self, **kw) -> str:
        return json.dumps(self.to_dict(), **kw)

    @classmethod
    def from_dict(cls, d: Dict[str, Any]) -> "FaultSpec":
        return cls(seed=d.get("seed", 0), name=d.get("name", ""),
                   faults=tuple(Fault.from_dict(f)
                                for f in d.get("faults", [])))

    @classmethod
    def from_json(cls, s: str) -> "FaultSpec":
        return cls.from_dict(json.loads(s))


NO_FAULTS = FaultSpec()


def as_fault_spec(faults) -> Optional[FaultSpec]:
    """Normalize a ``faults=`` argument: None/empty -> None, FaultSpec
    passes through, a dict/JSON string parses.  Returning None for the
    empty spec keeps the unfaulted paths bit-identical to pre-fault
    builds (no runtime is even constructed)."""
    if faults is None:
        return None
    if isinstance(faults, FaultSpec):
        return None if faults.is_empty else faults
    if isinstance(faults, str):
        return as_fault_spec(FaultSpec.from_json(faults))
    if isinstance(faults, dict):
        return as_fault_spec(FaultSpec.from_dict(faults))
    raise TypeError(f"faults must be a FaultSpec, dict, JSON string, or "
                    f"None, got {type(faults).__name__}")
