"""Trace analysis: breakdowns and critical-path extraction.

Works on the leaf spans of a TraceRecorder (``cat`` in compute/comm,
``nested`` False — time inside a collective is carried by the collective
span itself, and ``phase`` spans are presentation overlays).  Because
every rank is a sequential virtual thread, leaf spans on one rank never
overlap, which gives the accounting identity the tests enforce:

    compute + comm + idle == makespan        (per rank, idle >= 0)

Critical-path extraction walks the recorded happens-before graph
backwards from the last-finishing span.  Predecessor candidates of a
span are (a) the previous leaf span on the same rank, (b) the post
anchors of the messages it received (send->recv edges), and (c) for a
collective member, the last-arriving member of the same collective
instance (the rank everyone ended up waiting for).  The path is built as
disjoint time segments clipped at the running frontier, so its length is
<= makespan by construction and equals it exactly for a serial chain.
"""
from __future__ import annotations

import bisect
import dataclasses
from typing import Dict, List, Optional, Tuple

_EPS = 1e-12


def _leaf_spans(trace) -> List:
    return [s for s in trace.spans
            if not s.nested and s.cat in ("compute", "comm")]


def rank_breakdown(trace, makespan: Optional[float] = None
                   ) -> Dict[int, Dict[str, float]]:
    """Per-rank {compute, comm, idle, total}; idle is the remainder up to
    the global makespan (ranks that finish early idle at the end)."""
    T = trace.makespan if makespan is None else makespan
    out: Dict[int, Dict[str, float]] = {}
    for s in _leaf_spans(trace):
        acc = out.setdefault(s.rank, {"compute": 0.0, "comm": 0.0})
        acc[s.cat] += s.dur
    for acc in out.values():
        acc["idle"] = T - acc["compute"] - acc["comm"]
        acc["total"] = T
    return out


def phase_breakdown(trace) -> Dict[str, float]:
    """Total time in each application phase, summed over ranks."""
    out: Dict[str, float] = {}
    for s in trace.spans:
        if s.cat == "phase":
            out[s.name] = out.get(s.name, 0.0) + s.dur
    return out


def collective_breakdown(trace) -> Dict[str, Dict[str, float]]:
    """Per-collective-kind attribution over non-nested collective spans:
    total rank-seconds, call count, mean seconds per member call."""
    out: Dict[str, Dict[str, float]] = {}
    for s in trace.spans:
        if s.coll is None or s.nested:
            continue
        acc = out.setdefault(s.name, {"seconds": 0.0, "calls": 0})
        acc["seconds"] += s.dur
        acc["calls"] += 1
    for acc in out.values():
        acc["mean_s"] = acc["seconds"] / max(acc["calls"], 1)
    return out


@dataclasses.dataclass
class CriticalPath:
    length_s: float                      # sum of disjoint path segments
    makespan_s: float
    spans: List                          # path spans, start -> finish
    by_cat: Dict[str, float]             # path time per category
    by_name: Dict[str, float]            # path time per span name

    @property
    def coverage(self) -> float:
        """Fraction of the makespan explained by the path."""
        return self.length_s / self.makespan_s if self.makespan_s else 0.0


def critical_path(trace) -> CriticalPath:
    spans = _leaf_spans(trace)
    T = trace.makespan
    if not spans:
        return CriticalPath(0.0, T, [], {}, {})
    by_sid = {s.sid: s for s in trace.spans}

    # per-rank timelines ordered by (t0, sid) for prev-span lookup
    by_rank: Dict[int, List] = {}
    for s in spans:
        by_rank.setdefault(s.rank, []).append(s)
    starts: Dict[int, List[Tuple[float, int]]] = {}
    for r, ss in by_rank.items():
        ss.sort(key=lambda s: (s.t0, s.sid))
        starts[r] = [(s.t0, s.sid) for s in ss]

    def rank_prev(s):
        i = bisect.bisect_left(starts[s.rank], (s.t0, s.sid))
        return by_rank[s.rank][i - 1] if i > 0 else None

    def anchor_leaf(sid):
        """Map a (possibly nested) span to the leaf span covering it on
        its rank — e.g. an isend anchor inside a collective maps to the
        enclosing collective span."""
        a = by_sid[sid]
        if not a.nested and a.cat in ("compute", "comm"):
            return a
        lst = starts.get(a.rank)
        if not lst:
            return None
        i = bisect.bisect_right(lst, (a.t0, a.sid))
        return by_rank[a.rank][i - 1] if i > 0 else None

    # last-arriving member per collective instance
    last_arriver: Dict = {}
    for key, sids in trace.coll_members.items():
        members = [by_sid[i] for i in sids]
        last_arriver[key] = max(members, key=lambda s: (s.t0, s.sid))

    cur = max(spans, key=lambda s: (s.t1, s.sid))
    frontier = T
    path: List = []
    length = 0.0
    by_cat: Dict[str, float] = {}
    by_name: Dict[str, float] = {}
    seen = set()
    while cur is not None and cur.sid not in seen:
        seen.add(cur.sid)
        cands = []
        prev = rank_prev(cur)
        if prev is not None:
            cands.append(prev)
        for dep in cur.deps:
            a = anchor_leaf(dep)
            if a is not None and a.sid != cur.sid:
                cands.append(a)
        if cur.coll is not None:
            la = last_arriver.get(cur.coll)
            if la is not None and la.sid != cur.sid:
                cands.append(la)
        cands = [c for c in cands if c.sid not in seen]
        pred = max(cands, key=lambda s: (s.t1, s.sid)) if cands else None
        # the span's own contribution starts only after its predecessor
        # finished — a recv blocked from t0 waiting for a slow sender
        # contributes just the transfer tail, and the walk routes the
        # rest of the time through the sender's chain
        seg_start = cur.t0 if pred is None else max(cur.t0, pred.t1)
        seg = max(0.0, min(cur.t1, frontier) - seg_start)
        if seg > 0.0:
            path.append(cur)
            length += seg
            by_cat[cur.cat] = by_cat.get(cur.cat, 0.0) + seg
            by_name[cur.name] = by_name.get(cur.name, 0.0) + seg
        frontier = min(frontier, seg_start)
        cur = pred
    path.reverse()
    return CriticalPath(length, T, path, by_cat, by_name)


def summarize(trace) -> dict:
    """One JSON-friendly report: what the service/benchmarks return."""
    T = trace.makespan
    ranks = rank_breakdown(trace, T)
    n = max(len(ranks), 1)
    tot = {k: sum(r[k] for r in ranks.values()) / n
           for k in ("compute", "comm", "idle")}
    cp = critical_path(trace)
    return {
        "makespan_s": T,
        "n_ranks": len(ranks),
        "n_spans": len(trace.spans),
        "n_msgs": len(trace.msgs),
        "compute_frac": tot["compute"] / T if T else 0.0,
        "comm_frac": tot["comm"] / T if T else 0.0,
        "idle_frac": tot["idle"] / T if T else 0.0,
        "phases": phase_breakdown(trace),
        "collectives": collective_breakdown(trace),
        "critical_path_s": cp.length_s,
        "critical_path_coverage": cp.coverage,
        "critical_path_by_cat": cp.by_cat,
        "faults": [dict({"name": s.name, "t0": s.t0, "t1": s.t1},
                        **(s.args or {}))
                   for s in trace.spans if s.cat == "fault"],
    }
