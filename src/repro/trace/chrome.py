"""Chrome trace-event JSON export.

Emits the subset of the Trace Event Format that Perfetto and
``chrome://tracing`` render: one process ("DES"), one thread track per
rank, complete events (``ph="X"``) for spans, instant events (``"i"``)
for markers, and async begin/end pairs (``"b"``/``"e"``) for in-flight
p2p messages so a message posted under lookahead shows as a slice
spanning its whole network lifetime.  Timestamps are microseconds of
simulated time.

Open a dump at https://ui.perfetto.dev (drag the file in) or at
chrome://tracing.
"""
from __future__ import annotations

import json
from typing import Optional

# Keys every renderable event must carry (also what the schema test and
# external validators check).
REQUIRED_KEYS = ("ph", "ts", "pid", "tid", "name")

_PID = 0          # single simulated process; tracks are ranks


def _us(t: float) -> float:
    return t * 1e6


def to_chrome_json(trace, path: Optional[str] = None) -> dict:
    """Serialize a TraceRecorder to a Chrome trace-event dict; write it
    to ``path`` (if given) and return it."""
    events = [{
        "ph": "M", "ts": 0, "pid": _PID, "tid": 0,
        "name": "process_name", "args": {"name": "DES"},
    }]
    ranks = ({s.rank for s in trace.spans}
             | {m.src for m in trace.msgs}
             | {m.dst for m in trace.msgs})
    ranks |= {r for r, _, _, _ in trace.instants}
    for r in sorted(ranks):
        # rank -1 is the fault timeline (repro.faults.inject.FAULT_TRACK)
        events.append({"ph": "M", "ts": 0, "pid": _PID, "tid": r,
                       "name": "thread_name",
                       "args": {"name": "faults" if r < 0
                                else f"rank {r}"}})
        events.append({"ph": "M", "ts": 0, "pid": _PID, "tid": r,
                       "name": "thread_sort_index",
                       "args": {"sort_index": r}})

    for s in trace.spans:
        if s.t1 <= s.t0 and s.name == "isend":
            continue                      # post anchors render as arrows
        ev = {"ph": "X", "ts": _us(s.t0), "dur": _us(s.dur),
              "pid": _PID, "tid": s.rank, "name": s.name, "cat": s.cat}
        if s.args:
            ev["args"] = s.args
        events.append(ev)

    for rank, name, t, args in trace.instants:
        ev = {"ph": "i", "ts": _us(t), "pid": _PID, "tid": rank,
              "name": name, "s": "t"}
        if args:
            ev["args"] = args
        events.append(ev)

    end = trace.makespan
    for m in trace.msgs:
        name = f"msg {m.src}->{m.dst}"
        common = {"pid": _PID, "cat": "msg", "id": m.mid, "name": name}
        events.append({"ph": "b", "ts": _us(m.t_post), "tid": m.src,
                       "args": {"bytes": m.nbytes, "tag": repr(m.tag)},
                       **common})
        t_done = m.t_done if m.t_done is not None else end
        events.append({"ph": "e", "ts": _us(t_done), "tid": m.dst,
                       **common})

    out = {"traceEvents": events, "displayTimeUnit": "ms",
           "otherData": {"makespan_s": end}}
    if path is not None:
        with open(path, "w") as fh:
            json.dump(out, fh)
    return out


def validate_chrome_events(doc: dict) -> None:
    """Schema check: raises ValueError unless every event carries the
    required trace-event keys with sane types."""
    if "traceEvents" not in doc:
        raise ValueError("missing traceEvents")
    for ev in doc["traceEvents"]:
        for k in REQUIRED_KEYS:
            if k not in ev:
                raise ValueError(f"event {ev!r} missing {k!r}")
        if not isinstance(ev["ts"], (int, float)):
            raise ValueError(f"event {ev!r} has non-numeric ts")
        if ev["ph"] == "X" and "dur" not in ev:
            raise ValueError(f"complete event {ev!r} missing dur")
