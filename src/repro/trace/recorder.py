"""TraceRecorder — per-rank event timelines for the DES.

The recorder hangs off ``Engine`` (``engine.trace``).  When tracing is
off the engine carries the module-level ``NULL_RECORDER`` singleton whose
methods are no-ops and whose ``enabled`` flag is False, so every
instrumentation site reduces to one attribute test and the hot event
loop pays nothing; crucially the recorder never schedules engine events,
so a traced run replays the exact same heap sequence as an untraced one
(trace=True and trace=False give bit-identical simulated times).

Three record kinds:

  * spans    — ``(rank, cat, name, t0, t1)`` intervals.  ``cat`` is one
    of ``compute`` (SimBLAS / NodeModel work), ``comm`` (SimMPI ops) or
    ``phase`` (application-level overlays: panel factorization, panel
    bcast, ...).  Spans emitted while a collective is open on the rank
    are flagged ``nested`` and excluded from breakdowns/critical path
    (the enclosing collective span carries the time).
  * instants — zero-width markers.
  * messages — one async record per p2p message, opened at ``isend``
    post and closed when the matching ``recv`` completes; these become
    Chrome async slices and the send->recv happens-before edges.

Happens-before edges recorded: per-rank program order (spans on one rank
are sequential by construction), send->recv (``deps`` on the recv span
point at the sender's post anchor), and collective membership (member
spans of one collective instance share a ``coll`` key; the analysis
treats the last-arriving member as the dependency of every other
member's exit).
"""
from __future__ import annotations

from typing import Any, Dict, List, Optional, Tuple


class Span:
    __slots__ = ("sid", "rank", "cat", "name", "t0", "t1", "coll",
                 "nested", "deps", "args")

    def __init__(self, sid: int, rank: int, cat: str, name: str,
                 t0: float, t1: float, coll=None, nested: bool = False,
                 deps: Optional[List[int]] = None,
                 args: Optional[Dict[str, Any]] = None):
        self.sid = sid
        self.rank = rank
        self.cat = cat
        self.name = name
        self.t0 = t0
        self.t1 = t1
        self.coll = coll             # collective instance key, if any
        self.nested = nested         # emitted inside an open collective
        self.deps = deps or []       # sids this span happens-after
        self.args = args

    @property
    def dur(self) -> float:
        return self.t1 - self.t0

    def __repr__(self):
        return (f"Span({self.sid}, r{self.rank}, {self.cat}:{self.name}, "
                f"[{self.t0:.3e}, {self.t1:.3e}])")


class Message:
    __slots__ = ("mid", "src", "dst", "nbytes", "tag", "t_post", "t_done",
                 "post_sid")

    def __init__(self, mid: int, src: int, dst: int, nbytes: float, tag,
                 t_post: float, post_sid: int):
        self.mid = mid
        self.src = src
        self.dst = dst
        self.nbytes = nbytes
        self.tag = tag
        self.t_post = t_post
        self.t_done: Optional[float] = None   # closed at recv completion
        self.post_sid = post_sid


class _NullRecorder:
    """Tracing-off singleton: every hook is a no-op behind ``enabled``."""
    enabled = False
    __slots__ = ()

    def complete(self, *a, **k):
        return -1

    def compute(self, *a, **k):
        return -1

    def instant(self, *a, **k):
        pass

    def coll_begin(self, *a, **k):
        return None

    def coll_end(self, *a, **k):
        pass

    def in_coll(self, rank) -> bool:
        return False

    def msg_post(self, *a, **k):
        pass

    def recv_done(self, *a, **k):
        return -1


NULL_RECORDER = _NullRecorder()


class TraceRecorder:
    enabled = True

    def __init__(self, engine):
        self.engine = engine
        self.spans: List[Span] = []
        self.instants: List[Tuple[int, str, float, Optional[dict]]] = []
        self.msgs: List[Message] = []
        self.coll_members: Dict[Any, List[int]] = {}   # coll key -> [sid]
        self._msg_by_event: Dict[int, Message] = {}    # id(Event) -> Message
        self._coll_depth: Dict[int, int] = {}          # rank -> open colls

    # ------------------------------------------------------------- state
    @property
    def makespan(self) -> float:
        return self.engine.now

    @property
    def now(self) -> float:
        return self.engine.now

    def in_coll(self, rank: int) -> bool:
        return self._coll_depth.get(rank, 0) > 0

    # ------------------------------------------------------------- spans
    def complete(self, rank: int, cat: str, name: str, t0: float, *,
                 t1: Optional[float] = None, coll=None,
                 nested: bool = False, deps: Optional[List[int]] = None,
                 args: Optional[Dict[str, Any]] = None) -> int:
        """Record a finished span [t0, t1] (t1 defaults to sim-now)."""
        sid = len(self.spans)
        self.spans.append(Span(sid, rank, cat, name, t0,
                               self.engine.now if t1 is None else t1,
                               coll=coll, nested=nested, deps=deps,
                               args=args))
        return sid

    def compute(self, rank: int, name: str, dur: float,
                args: Optional[Dict[str, Any]] = None) -> int:
        """A compute span starting now and lasting ``dur`` (the caller is
        about to ``yield dur``)."""
        now = self.engine.now
        return self.complete(rank, "compute", name, now, t1=now + dur,
                             args=args)

    def instant(self, rank: int, name: str,
                args: Optional[Dict[str, Any]] = None):
        self.instants.append((rank, name, self.engine.now, args))

    # ------------------------------------------------------- collectives
    def coll_begin(self, rank: int, name: str, op_id, group, nbytes):
        """Open a collective span on ``rank``.  Returns an opaque token
        for ``coll_end``.  The key (name, op_id) ties together the member
        spans of one collective instance across ranks."""
        depth = self._coll_depth.get(rank, 0)
        self._coll_depth[rank] = depth + 1
        key = (name, op_id)
        return (self.engine.now, key, depth > 0, len(group), nbytes)

    def coll_end(self, rank: int, token):
        t0, key, nested, n, nbytes = token
        self._coll_depth[rank] -= 1
        sid = self.complete(rank, "comm", key[0], t0, coll=key,
                            nested=nested,
                            args={"group": n, "bytes": nbytes})
        self.coll_members.setdefault(key, []).append(sid)

    # ---------------------------------------------------------- messages
    def msg_post(self, src: int, dst: int, nbytes: float, tag, event):
        """Called at isend post time; ``event`` is the transfer-complete
        Event whose identity the matching recv will present."""
        now = self.engine.now
        sid = self.complete(src, "comm", "isend", now, t1=now,
                            nested=self.in_coll(src),
                            args={"dst": dst, "bytes": nbytes})
        msg = Message(len(self.msgs), src, dst, nbytes, tag, now, sid)
        self.msgs.append(msg)
        self._msg_by_event[id(event)] = msg

    def recv_done(self, rank: int, src: int, t0: float, event) -> int:
        """Called when a recv's transfer completes: closes the message
        async slice and records the recv span with its send dep."""
        msg = self._msg_by_event.pop(id(event), None)
        deps = None
        nbytes = 0.0
        if msg is not None:
            msg.t_done = self.engine.now
            deps = [msg.post_sid]
            nbytes = msg.nbytes
        return self.complete(rank, "comm", "recv", t0,
                             nested=self.in_coll(rank), deps=deps,
                             args={"src": src, "bytes": nbytes})

    # ------------------------------------------------------------ export
    def to_chrome_json(self, path: Optional[str] = None):
        """Chrome trace-event JSON (loads in Perfetto / chrome://tracing);
        returns the dict, and writes it to ``path`` if given."""
        from .chrome import to_chrome_json
        return to_chrome_json(self, path)

    def summary(self) -> dict:
        """Makespan + per-rank breakdown + collective attribution +
        critical path, as one JSON-friendly dict."""
        from .analysis import summarize
        return summarize(self)
