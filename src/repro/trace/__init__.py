"""DES observability: per-rank timelines, Chrome-trace export, and
critical-path analysis.

Turn it on at any public layer — ``Engine(trace=True)``,
``HPLSim(cfg, platform, trace=True)``, ``platform.des(trace=True)``,
``TransformerStepSim(..., trace=True)`` — then::

    res = HPLSim(cfg, platform, trace=True).run()
    res.trace.to_chrome_json("run.json")     # open in ui.perfetto.dev
    res.trace.summary()                      # breakdowns + critical path

See DESIGN.md §13 for the recorder lifecycle and overhead contract.
"""
from .analysis import (CriticalPath, collective_breakdown, critical_path,
                       phase_breakdown, rank_breakdown, summarize)
from .chrome import REQUIRED_KEYS, to_chrome_json, validate_chrome_events
from .recorder import NULL_RECORDER, Message, Span, TraceRecorder

__all__ = [
    "TraceRecorder", "NULL_RECORDER", "Span", "Message",
    "to_chrome_json", "validate_chrome_events", "REQUIRED_KEYS",
    "rank_breakdown", "phase_breakdown", "collective_breakdown",
    "critical_path", "CriticalPath", "summarize",
]
