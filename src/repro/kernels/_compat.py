"""Pallas API compatibility across jax generations.

jax 0.4.x names the TPU compile options ``pltpu.TPUCompilerParams``;
newer releases renamed it ``pltpu.CompilerParams``.
"""
from jax.experimental.pallas import tpu as _pltpu

CompilerParams = getattr(_pltpu, "CompilerParams", None) \
    or _pltpu.TPUCompilerParams
