"""jit'd waterfilling using the Pallas masked-row-min kernel."""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from .kernel import masked_min_rows, INF
from .ref import waterfill_ref


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


@partial(jax.jit, static_argnames=("max_iters", "use_kernel"))
def waterfill(adj, caps, max_iters: int = 64, use_kernel: bool = True):
    """Max-min fair rates via progressive filling; the per-iteration
    masked row-min runs through the Pallas kernel."""
    F, L = adj.shape
    adjf = adj.astype(jnp.float32)
    interpret = not _on_tpu()

    def minrows(share):
        if use_kernel and F % 8 == 0 and L % 128 == 0:
            return masked_min_rows(adj, share, bf=min(256, F),
                                   bl=min(256, L), interpret=interpret)
        return jnp.min(jnp.where(adj > 0, share[None, :], INF), axis=1)

    def body(state):
        rates, frozen, rem, it = state
        active = 1.0 - frozen
        nl = adjf.T @ active
        share = jnp.where(nl > 0, rem / jnp.maximum(nl, 1.0), INF)
        fmin = minrows(share)
        fmin = jnp.where(active > 0, fmin, INF)
        smin = jnp.min(fmin)
        freeze_now = (jnp.abs(fmin - smin) <= 1e-6 * smin) & (active > 0)
        new_rates = jnp.where(freeze_now, smin, rates)
        used = adjf.T @ jnp.where(freeze_now, smin, 0.0)
        return (new_rates, frozen + freeze_now.astype(jnp.float32),
                jnp.maximum(rem - used, 0.0), it + 1)

    def cond(state):
        _, frozen, _, it = state
        return (it < max_iters) & (jnp.sum(frozen) < F)

    state = (jnp.zeros((F,), jnp.float32), jnp.zeros((F,), jnp.float32),
             caps.astype(jnp.float32), jnp.asarray(0))
    rates, _, _, _ = jax.lax.while_loop(cond, body, state)
    return jnp.where(jnp.sum(adj, axis=1) == 0, INF, rates)
