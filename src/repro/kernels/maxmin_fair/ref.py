"""Pure-jnp oracle for the max-min fair bandwidth allocation.

The paper's stream-level network model allocates link bandwidth max-min
fairly across flows (progressive filling).  At exascale flow counts the
allocation is the simulator's hot loop; this module is the dense jnp
reference, the Pallas kernel tiles the flow x link masked reductions.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

INF = jnp.float32(3.4e38)


def masked_min_rows_ref(adj, vals):
    """adj: (F, L) bool/int; vals: (L,) f32 -> per-flow min over its links.
    Flows with no links get +INF."""
    masked = jnp.where(adj > 0, vals[None, :], INF)
    return jnp.min(masked, axis=1)


def waterfill_ref(adj, caps, max_iters: int = 64):
    """Progressive-filling max-min allocation.

    adj: (F, L) 0/1; caps: (L,) f32.  Returns rates (F,) f32.
    Each iteration: fair share per link = remaining / active flows; every
    unfrozen flow whose minimum share equals the global bottleneck share
    freezes at that rate.
    """
    F, L = adj.shape
    adjf = adj.astype(jnp.float32)

    def body(state):
        rates, frozen, rem, it = state
        active = 1.0 - frozen                                  # (F,)
        nl = adjf.T @ active                                   # (L,)
        share = jnp.where(nl > 0, rem / jnp.maximum(nl, 1.0), INF)
        fmin = masked_min_rows_ref(adj, share)                 # (F,)
        fmin = jnp.where(active > 0, fmin, INF)
        smin = jnp.min(fmin)
        freeze_now = (jnp.abs(fmin - smin) <= 1e-6 * smin) & (active > 0)
        new_rates = jnp.where(freeze_now, smin, rates)
        used = adjf.T @ jnp.where(freeze_now, smin, 0.0)
        return (new_rates, frozen + freeze_now.astype(jnp.float32),
                jnp.maximum(rem - used, 0.0), it + 1)

    def cond(state):
        _, frozen, _, it = state
        return (it < max_iters) & (jnp.sum(frozen) < F)

    rates0 = jnp.zeros((F,), jnp.float32)
    state = (rates0, jnp.zeros((F,), jnp.float32), caps.astype(jnp.float32),
             jnp.asarray(0))
    rates, _, _, _ = jax.lax.while_loop(cond, body, state)
    # flows with no links: infinite rate (self-sends)
    no_links = jnp.sum(adj, axis=1) == 0
    return jnp.where(no_links, INF, rates)
