"""Masked row-min over a flow x link incidence — Pallas TPU kernel.

This is the inner op of progressive-filling max-min fairness (the
stream-level network model's hot loop): for every flow, the minimum fair
share over the links it crosses.  Tiled (bf x bl) with a running-min VMEM
accumulator across link blocks; int8 incidence keeps the HBM footprint at
F x L bytes (100k flows x 8k links = 0.8 GB, streamable).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu
from .._compat import CompilerParams as _CompilerParams


INF = 3.4e38


def _minrows_kernel(adj_ref, vals_ref, out_ref, acc_ref, *, n_l_blocks):
    li = pl.program_id(1)

    @pl.when(li == 0)
    def _init():
        acc_ref[...] = jnp.full_like(acc_ref, INF)

    adj = adj_ref[...]                       # (bf, bl) int8
    vals = vals_ref[...]                     # (1, bl) f32
    masked = jnp.where(adj > 0, vals, INF)   # broadcast over rows
    acc_ref[...] = jnp.minimum(acc_ref[...],
                               jnp.min(masked, axis=1, keepdims=True))

    @pl.when(li == n_l_blocks - 1)
    def _finish():
        out_ref[...] = acc_ref[...]


def masked_min_rows(adj, vals, *, bf: int = 256, bl: int = 256,
                    interpret: bool = False):
    """adj: (F, L) int8/bool; vals: (L,) f32 -> (F,) f32 row-min."""
    F, L = adj.shape
    bf = min(bf, F)
    bl = min(bl, L)
    assert F % bf == 0 and L % bl == 0, (F, bf, L, bl)
    nf, nl = F // bf, L // bl
    vals2 = vals.reshape(1, L).astype(jnp.float32)
    kernel = functools.partial(_minrows_kernel, n_l_blocks=nl)
    out = pl.pallas_call(
        kernel,
        grid=(nf, nl),
        in_specs=[
            pl.BlockSpec((bf, bl), lambda fi, li: (fi, li)),
            pl.BlockSpec((1, bl), lambda fi, li: (0, li)),
        ],
        out_specs=pl.BlockSpec((bf, 1), lambda fi, li: (fi, 0)),
        out_shape=jax.ShapeDtypeStruct((F, 1), jnp.float32),
        scratch_shapes=[pltpu.VMEM((bf, 1), jnp.float32)],
        interpret=interpret,
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "arbitrary")),
    )(adj.astype(jnp.int8), vals2)
    return out[:, 0]
