"""Pure-jnp oracles for the Mamba-2 SSD chunk-scan kernel."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def ssd_ref_sequential(xh, dt, A, Bh, Ch):
    """Exact sequential state-space recurrence (the ground truth).

    xh: (B,S,H,P); dt: (B,S,H) f32 (post-softplus); A: (H,) f32 < 0;
    Bh, Ch: (B,S,H,N).  h_t = exp(A dt_t) h_{t-1} + dt_t B_t x_t^T;
    y_t = C_t . h_t.
    """
    b, s, h, p = xh.shape
    n = Bh.shape[-1]

    def step(hstate, inp):
        x_t, dt_t, B_t, C_t = inp
        decay = jnp.exp(dt_t * A)                       # (B,H)
        hstate = (hstate * decay[..., None, None]
                  + jnp.einsum("bhn,bhp->bhpn",
                               B_t * dt_t[..., None], x_t))
        y = jnp.einsum("bhn,bhpn->bhp", C_t, hstate)
        return hstate, y

    h0 = jnp.zeros((b, h, p, n), jnp.float32)
    xs = (jnp.moveaxis(xh.astype(jnp.float32), 1, 0),
          jnp.moveaxis(dt, 1, 0),
          jnp.moveaxis(Bh.astype(jnp.float32), 1, 0),
          jnp.moveaxis(Ch.astype(jnp.float32), 1, 0))
    _, ys = jax.lax.scan(step, h0, xs)
    return jnp.moveaxis(ys, 0, 1).astype(xh.dtype)      # (B,S,H,P)


def ssd_ref_chunked(xh, dt, A, Bh, Ch, chunk: int):
    """The chunked SSD algorithm in pure jnp (same math as the kernel)."""
    from repro.models.mamba2 import ssd_chunked
    y, _ = ssd_chunked(xh, dt, A, Bh, Ch, chunk)
    return y
