"""Mamba-2 SSD chunk scan as a Pallas TPU kernel.

TPU-native mapping of the SSD (state-space duality) algorithm
[arXiv:2405.21060]:
  * grid (B, H, NC) with the chunk axis innermost (*arbitrary* semantics):
    the inter-chunk state (P, N) f32 is carried in VMEM scratch — the
    sequential recurrence never leaves the chip;
  * intra-chunk work is three MXU matmuls per chunk: CB^T (Q x Q), the
    masked-decay attention-like product with x (Q x P), and the state
    outer products (exactly the "dual" quadratic form of SSD);
  * chunk length Q defaults to 256 and P, N are 64/128 — all MXU-aligned;
    VMEM per step ~ Q*(P+2N)*4B + Q^2*4B ≈ 0.6 MB at Q=256.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu
from .._compat import CompilerParams as _CompilerParams



def _ssd_kernel(x_ref, dt_ref, a_ref, b_ref, c_ref, y_ref, h_ref, *,
                chunk: int):
    ci = pl.program_id(2)

    @pl.when(ci == 0)
    def _init():
        h_ref[...] = jnp.zeros_like(h_ref)

    x = x_ref[0, :, 0, :].astype(jnp.float32)          # (Q, P)
    dt = dt_ref[0, :, 0]                               # (Q,)
    a = a_ref[0]                                       # scalar A_h < 0
    B = b_ref[0, :, 0, :].astype(jnp.float32)          # (Q, N)
    C = c_ref[0, :, 0, :].astype(jnp.float32)          # (Q, N)

    adt = dt * a                                       # (Q,) <= 0
    cum = jnp.cumsum(adt)                              # (Q,)
    # intra-chunk: L[i,j] = exp(cum_i - cum_j) for i >= j
    li = cum[:, None] - cum[None, :]
    iq = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0)
    jq = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    L = jnp.where(iq >= jq, jnp.exp(li), 0.0)
    CB = jax.lax.dot_general(C, B, (((1,), (1,)), ((), ())),
                             preferred_element_type=jnp.float32)  # (Q,Q)
    M = CB * L * dt[None, :]
    y_intra = jax.lax.dot_general(M, x, (((1,), (0,)), ((), ())),
                                  preferred_element_type=jnp.float32)
    # inter-chunk: y += (C * exp(cum)) @ h_prev^T     h: (P, N)
    Cdec = C * jnp.exp(cum)[:, None]
    y_inter = jax.lax.dot_general(Cdec, h_ref[...],
                                  (((1,), (1,)), ((), ())),
                                  preferred_element_type=jnp.float32)
    y_ref[0, :, 0, :] = (y_intra + y_inter).astype(y_ref.dtype)
    # state update: h = exp(cum_last) * h + sum_q decay_q dt_q x_q B_q^T
    last = cum[chunk - 1]
    decay = jnp.exp(last - cum) * dt                   # (Q,)
    Bw = B * decay[:, None]                            # (Q, N)
    hS = jax.lax.dot_general(x, Bw, (((0,), (0,)), ((), ())),
                             preferred_element_type=jnp.float32)  # (P, N)
    h_ref[...] = jnp.exp(last) * h_ref[...] + hS


def ssd_scan(xh, dt, A, Bh, Ch, chunk: int = 256, *,
             interpret: bool = False):
    """xh: (B,S,H,P); dt: (B,S,H) f32; A: (H,); Bh/Ch: (B,S,H,N).

    Returns y: (B,S,H,P).  S must be a multiple of `chunk` (callers pad
    with dt=0 — identity transition — as models/mamba2.py does).
    """
    b, s, h, p = xh.shape
    n = Bh.shape[-1]
    chunk = min(chunk, s)
    assert s % chunk == 0, (s, chunk)
    nc = s // chunk
    kernel = functools.partial(_ssd_kernel, chunk=chunk)
    return pl.pallas_call(
        kernel,
        grid=(b, h, nc),
        in_specs=[
            pl.BlockSpec((1, chunk, 1, p),
                         lambda bi, hi, ci: (bi, ci, hi, 0)),
            pl.BlockSpec((1, chunk, 1),
                         lambda bi, hi, ci: (bi, ci, hi)),
            pl.BlockSpec((1,), lambda bi, hi, ci: (hi,)),
            pl.BlockSpec((1, chunk, 1, n),
                         lambda bi, hi, ci: (bi, ci, hi, 0)),
            pl.BlockSpec((1, chunk, 1, n),
                         lambda bi, hi, ci: (bi, ci, hi, 0)),
        ],
        out_specs=pl.BlockSpec((1, chunk, 1, p),
                               lambda bi, hi, ci: (bi, ci, hi, 0)),
        out_shape=jax.ShapeDtypeStruct((b, s, h, p), xh.dtype),
        scratch_shapes=[pltpu.VMEM((p, n), jnp.float32)],
        interpret=interpret,
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
    )(xh, dt, A, Bh, Ch)
